"""Speculative decoding lanes demo: draft-verify decode, bitwise-safe.

    PYTHONPATH=src python examples/speculative_serve.py

A reduced smollm backbone decodes 7 requests through 3 lanes twice, with
a draft model proposing K=3 tokens per round and the target verifying
all of them in ONE forward (``SpeculativeLaneDecoder``).  Accepted
tokens are the target's own argmaxes, so the output is bitwise-identical
to plain fused decode no matter how good the draft is — the draft moves
throughput, never content:

* draft = the target's own parameters -> near-100% acceptance (each
  verify round commits K+1 tokens);
* draft = an independently-initialised model -> ~0% acceptance (every
  round still makes 1 token of progress: the bonus token).

Per-request acceptance rates feed the scheduler (``Request.accept_rate``,
policy ``sjf_effective``) and the wasted draft positions fold into the
engine's ``dead_steps`` accounting.
"""

import numpy as np

from repro.configs import get_config
from repro.serving.engine import BatchedRealEngine


def main():
    cfg = get_config("smollm-360m").reduced()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).tolist()
               for n in (5, 11, 23, 7, 3, 15, 9)]
    maxes = [10, 25, 6, 18, 4, 12, 9]

    ref = BatchedRealEngine(cfg, max_len=64, segment_len=4, n_lanes=3,
                            seed=0)
    want = [ref.generate_reference(p, max_new_tokens=m)["tokens"]
            for p, m in zip(prompts, maxes)]
    print(f"reference: {sum(len(w) for w in want)} tokens over "
          f"{len(prompts)} requests (serial fused decode)")

    engines = {
        "agreeing draft (target params)": BatchedRealEngine(
            cfg, max_len=64, segment_len=4, n_lanes=3, seed=0,
            params=ref.params, draft_cfg=cfg, draft_params=ref.params,
            draft_k=3),
        "independent draft (seed 7)": BatchedRealEngine(
            cfg, max_len=64, segment_len=4, n_lanes=3, seed=0,
            params=ref.params, draft_cfg=cfg, draft_k=3, draft_seed=7),
    }
    for name, eng in engines.items():
        outs = eng.generate_batch(prompts, max_new_tokens=maxes)
        ok = all(list(o["tokens"]) == list(w) for o, w in zip(outs, want))
        st = eng.lane_manager.stats
        print(f"\n{name}:")
        print(f"  bitwise-equal to fused reference: {ok}")
        print(f"  accept_rate={eng.accept_rate:.3f} "
              f"(drafted {eng.drafted_total}, accepted "
              f"{eng.accepted_total}), dead_steps={eng.dead_steps}")
        print(f"  admitted {st['admitted']} (back-fills "
              f"{st['backfills']}), retired {st['retired']}")
        for o in outs[:3]:
            print(f"    req accept_rate={o['accept_rate']}")


if __name__ == "__main__":
    main()
