"""End-to-end driver: serve requests through the Clairvoyant sidecar.

Two modes:

    PYTHONPATH=src python examples/serve_sidecar.py            # in-process
    PYTHONPATH=src python examples/serve_sidecar.py --http     # over the wire

**In-process** (default): a reduced smollm backbone actually decodes each
request on CPU (RealEngine); admission ordering comes from the trained
predictor + SJF queue.  Shows the paper's n=8 dispatch-order result with
real token generation, then a larger simulated-time batch for the
latency stats.

**HTTP** (``--http``): boots the asyncio HTTP/SSE sidecar on a loopback
port, fires an asyncio client pool of streaming chat-completion requests
at it (predictor-scored SJF admission, virtual-time sim backends), and
reports *client-observed* wire TTFT and per-class P50 sojourn for SJF vs
FCFS — the paper's HoL-mitigation win measured end to end through a real
socket.
"""

import argparse
import asyncio
import json
import time

import numpy as np

from repro.configs import get_config
from repro.core.gbdt import GBDTParams
from repro.core.predictor import Predictor
from repro.core.scheduler import Request, SJFQueue
from repro.data.corpus import sample_dataset
from repro.data.tokenizer import HashTokenizer
from repro.serving.engine import RealEngine
from repro.serving.openai_api import CompletionRequest
from repro.serving.server import ClairvoyantServer


def main_inprocess(args):
    print("training predictor...")
    train = sample_dataset("sharegpt", n=2400, seed=0, balanced=True)
    pred = Predictor.train(train.prompts, train.lengths,
                           GBDTParams(num_rounds=args.rounds))

    # --- real decode through the SJF queue (n=8, 4 short + 4 long) --------
    cfg = get_config("smollm-360m").reduced()
    engine = RealEngine(cfg, max_len=96)
    tok = HashTokenizer(cfg.vocab_size)

    ds = sample_dataset("sharegpt", n=4000, seed=1)
    shorts = [i for i in range(len(ds)) if ds.lengths[i] < 120][:4]
    longs = [i for i in range(len(ds)) if ds.lengths[i] >= 1000][:4]

    q = SJFQueue(policy="sjf")
    for j, i in enumerate(longs + shorts):  # adversarial: longs arrive first
        klass = "short" if i in shorts else "long"
        q.push(Request(req_id=j, prompt=ds.prompts[i],
                       p_long=pred.p_long(ds.prompts[i]), klass=klass))

    print("dispatch order (longs arrived first; SJF should flip them):")
    order = []
    while True:
        r = q.pop(now=0.0)
        if r is None:
            break
        n_new = 4 if r.klass == "short" else 16
        out = engine.generate(tok.encode(r.prompt)[:24], max_new_tokens=n_new)
        order.append(r.klass)
        print(f"  {r.klass:5s} p_long={r.p_long:.2f} "
              f"generated {len(out['tokens'])} tokens "
              f"in {out['service_s']*1e3:.0f} ms (ttft {out['ttft_s']*1e3:.0f} ms)")
    n_short_first = order[:4].count("short")
    print(f"shorts in the first 4 dispatches: {n_short_first}/4")

    # --- batched latency stats (simulated clock, 100 requests) ------------
    server_args = dict(n_replicas=1, predictor=pred, seed=0)
    results = {}
    for policy in ("fcfs", "sjf"):
        server = ClairvoyantServer(policy=policy, tau=None, **server_args)
        ds2 = sample_dataset("sharegpt", n=100, seed=2)
        rng = np.random.default_rng(3)
        # batched admission: one predictor call for the whole burst
        server.submit_many(
            [CompletionRequest(prompt=p) for p in ds2.prompts],
            arrivals=rng.uniform(0, 0.05, 100),
            true_output_tokens=[int(l) for l in ds2.lengths],
            klasses=[("short", "medium", "long")[int(c)]
                     for c in ds2.classes])
        server.drain()
        results[policy] = server.percentile(50, "short")
        print(f"{policy}: short P50 sojourn {results[policy]:.1f}s")
    print(f"SJF short-P50 reduction: "
          f"{100*(1-results['sjf']/results['fcfs']):.0f}%")


# ------------------------------------------------------------ HTTP mode
async def _stream_request(port, body):
    """One raw streaming chat completion; returns (wire_ttft_s, sojourn_s)
    measured from just before connect."""
    t0 = time.monotonic()
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    payload = json.dumps(body).encode()
    writer.write((
        "POST /v1/chat/completions HTTP/1.1\r\nHost: example\r\n"
        "Content-Type: application/json\r\n"
        f"Content-Length: {len(payload)}\r\nConnection: close\r\n\r\n"
    ).encode() + payload)
    await writer.drain()
    ttft, buf = None, b""
    while b"data: [DONE]" not in buf:
        chunk = await reader.read(4096)
        if not chunk:
            break
        buf += chunk
        if ttft is None and b'"content"' in buf:
            ttft = time.monotonic() - t0
    writer.close()
    try:
        await writer.wait_closed()
    except (ConnectionError, OSError):
        pass
    return ttft, time.monotonic() - t0


async def _wire_burst(policy, pred, ds, time_scale, n_replicas):
    from repro.serving.backends import SimTextBackend
    from repro.serving.http_sidecar import Sidecar
    from repro.serving.service_time import ServiceTimeModel
    model = ServiceTimeModel(prefill_tok_per_s=8000.0,
                             decode_tok_per_s=60.0)
    backends = [SimTextBackend(model, replica_id=i, time_scale=time_scale)
                for i in range(n_replicas)]
    server = ClairvoyantServer(policy=policy, tau=None, predictor=pred,
                               engines=backends, service_model=model,
                               deadline_mode="sojourn", seed=0)
    sc = Sidecar(server, port=0)
    await sc.start()
    rng = np.random.default_rng(4)
    klasses = [("short", "medium", "long")[int(c)] for c in ds.classes]

    async def one(i):
        await asyncio.sleep(float(rng.uniform(0, 0.02)))
        return await _stream_request(sc.port, {
            "prompt": ds.prompts[i], "max_tokens": 2048,
            "output_tokens": int(ds.lengths[i]), "stream": True})

    try:
        out = await asyncio.gather(*[one(i) for i in range(len(ds))])
    finally:
        await sc.shutdown(drain_s=10.0)
    assert len(sc.server._terminal) == len(ds), "lost requests on the wire"
    ttft = np.array([t for t, _ in out])
    sojourn = np.array([s for _, s in out])
    return {"ttft": ttft, "sojourn": sojourn,
            "short": np.array([k == "short" for k in klasses])}


def main_http(args):
    print("training predictor...")
    train = sample_dataset("sharegpt", n=2400, seed=0, balanced=True)
    pred = Predictor.train(train.prompts, train.lengths,
                           GBDTParams(num_rounds=args.rounds))
    ds = sample_dataset("sharegpt", n=args.requests, seed=2)
    print(f"firing {args.requests} streaming requests over loopback HTTP "
          f"({args.replicas} replica(s), time_scale={args.time_scale})...")
    results = {}
    for policy in ("fcfs", "sjf"):
        r = asyncio.run(_wire_burst(policy, pred, ds, args.time_scale,
                                    args.replicas))
        short, soj = r["short"], r["sojourn"]
        results[policy] = np.percentile(soj[short], 50)
        print(f"{policy}: wire TTFT P50 "
              f"{np.percentile(r['ttft'], 50)*1e3:.0f} ms | "
              f"short P50 {np.percentile(soj[short], 50)*1e3:.0f} ms "
              f"P95 {np.percentile(soj[short], 95)*1e3:.0f} ms | "
              f"long P50 {np.percentile(soj[~short], 50)*1e3:.0f} ms")
    print(f"SJF short-P50 reduction over the wire: "
          f"{100*(1-results['sjf']/results['fcfs']):.0f}%")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--http", action="store_true",
                    help="serve over loopback HTTP/SSE instead of "
                         "in-process")
    ap.add_argument("--requests", type=int, default=80)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--rounds", type=int, default=80)
    ap.add_argument("--time-scale", type=float, default=0.004)
    args = ap.parse_args()
    if args.http:
        main_http(args)
    else:
        main_inprocess(args)


if __name__ == "__main__":
    main()
