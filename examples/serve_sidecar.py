"""End-to-end driver: serve a (small, real) model with batched requests
through the Clairvoyant sidecar — deliverable (b)'s serving scenario.

    PYTHONPATH=src python examples/serve_sidecar.py

A reduced smollm backbone actually decodes each request on CPU (RealEngine);
admission ordering comes from the trained predictor + SJF queue.  Shows the
paper's n=8 dispatch-order result with real token generation, then a larger
simulated-time batch for the latency stats.
"""

import time

import numpy as np

from repro.configs import get_config
from repro.core.gbdt import GBDTParams
from repro.core.predictor import Predictor
from repro.core.scheduler import Request, SJFQueue
from repro.data.corpus import sample_dataset
from repro.data.tokenizer import HashTokenizer
from repro.serving.engine import RealEngine
from repro.serving.openai_api import CompletionRequest
from repro.serving.server import ClairvoyantServer


def main():
    print("training predictor...")
    train = sample_dataset("sharegpt", n=2400, seed=0, balanced=True)
    pred = Predictor.train(train.prompts, train.lengths,
                           GBDTParams(num_rounds=80))

    # --- real decode through the SJF queue (n=8, 4 short + 4 long) --------
    cfg = get_config("smollm-360m").reduced()
    engine = RealEngine(cfg, max_len=96)
    tok = HashTokenizer(cfg.vocab_size)

    ds = sample_dataset("sharegpt", n=4000, seed=1)
    shorts = [i for i in range(len(ds)) if ds.lengths[i] < 120][:4]
    longs = [i for i in range(len(ds)) if ds.lengths[i] >= 1000][:4]

    q = SJFQueue(policy="sjf")
    for j, i in enumerate(longs + shorts):  # adversarial: longs arrive first
        klass = "short" if i in shorts else "long"
        q.push(Request(req_id=j, prompt=ds.prompts[i],
                       p_long=pred.p_long(ds.prompts[i]), klass=klass))

    print("dispatch order (longs arrived first; SJF should flip them):")
    order = []
    while True:
        r = q.pop(now=0.0)
        if r is None:
            break
        n_new = 4 if r.klass == "short" else 16
        out = engine.generate(tok.encode(r.prompt)[:24], max_new_tokens=n_new)
        order.append(r.klass)
        print(f"  {r.klass:5s} p_long={r.p_long:.2f} "
              f"generated {len(out['tokens'])} tokens "
              f"in {out['service_s']*1e3:.0f} ms (ttft {out['ttft_s']*1e3:.0f} ms)")
    n_short_first = order[:4].count("short")
    print(f"shorts in the first 4 dispatches: {n_short_first}/4")

    # --- batched latency stats (simulated clock, 100 requests) ------------
    server_args = dict(n_replicas=1, predictor=pred, seed=0)
    results = {}
    for policy in ("fcfs", "sjf"):
        server = ClairvoyantServer(policy=policy, tau=None, **server_args)
        ds2 = sample_dataset("sharegpt", n=100, seed=2)
        rng = np.random.default_rng(3)
        # batched admission: one predictor call for the whole burst
        server.submit_many(
            [CompletionRequest(prompt=p) for p in ds2.prompts],
            arrivals=rng.uniform(0, 0.05, 100),
            true_output_tokens=[int(l) for l in ds2.lengths],
            klasses=[("short", "medium", "long")[int(c)]
                     for c in ds2.classes])
        server.drain()
        results[policy] = server.percentile(50, "short")
        print(f"{policy}: short P50 sojourn {results[policy]:.1f}s")
    print(f"SJF short-P50 reduction: "
          f"{100*(1-results['sjf']/results['fcfs']):.0f}%")


if __name__ == "__main__":
    main()
