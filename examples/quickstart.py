"""Quickstart: train the Clairvoyant predictor and schedule a mixed burst.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper end-to-end in miniature: synthesize a ShareGPT-profile
corpus -> extract the 19 lexical features -> train the GBDT -> check ranking
accuracy -> run FCFS vs SJF on a burst and print the short-request speedup.
"""

import numpy as np

from repro.core.gbdt import GBDTParams
from repro.core.predictor import Predictor
from repro.core.ranking import ranking_accuracy
from repro.core.scheduler import Request
from repro.core.simulation import simulate
from repro.data.corpus import sample_dataset
from repro.serving.service_time import PAPER_4090_LONG, PAPER_4090_SHORT


def main():
    # 1. data + predictor ---------------------------------------------------
    train = sample_dataset("sharegpt", n=3000, seed=0, balanced=True)
    test = sample_dataset("sharegpt", n=900, seed=1, balanced=True)
    print(f"training on {len(train)} prompts...")
    pred = Predictor.train(train.prompts, train.lengths,
                           GBDTParams(num_rounds=100))
    scores = pred.p_long_batch(test.prompts)
    ra = ranking_accuracy(test.lengths, scores)
    print(f"ranking accuracy: {100*ra:.1f}%  (paper band 62-96%)")

    # 2. one prediction, the admission path ---------------------------------
    prompt = "Write a detailed essay about the roman empire"
    print(f"P(Long) for {prompt!r}: {pred.p_long(prompt):.3f}")
    prompt2 = "What is photosynthesis? briefly"
    print(f"P(Long) for {prompt2!r}: {pred.p_long(prompt2):.3f}")

    # 3. burst: FCFS vs SJF -------------------------------------------------
    rng = np.random.default_rng(2)
    ds = sample_dataset("sharegpt", n=3000, seed=3)
    shorts = [i for i in range(len(ds)) if ds.lengths[i] < 200][:50]
    longs = [i for i in range(len(ds)) if ds.lengths[i] >= 800][:50]
    scores = pred.p_long_batch([ds.prompts[i] for i in shorts + longs])
    # fixed service draws + random arrival order (fair FCFS baseline)
    services = [float((PAPER_4090_SHORT if j < 50 else PAPER_4090_LONG)
                      .sample(rng)) for j in range(100)]
    arrivals = rng.permutation(100) * 1e-4

    def reqs():
        return [Request(req_id=j, arrival=float(arrivals[j]),
                        p_long=float(scores[j]), true_service=services[j],
                        klass="short" if j < 50 else "long")
                for j in range(100)]

    fcfs = simulate(reqs(), policy="fcfs")
    sjf = simulate(reqs(), policy="sjf")
    f50 = fcfs.percentile(50, "short")
    s50 = sjf.percentile(50, "short")
    print(f"burst of 100: short P50 FCFS={f50:.0f}s SJF={s50:.0f}s "
          f"(-{100*(1-s50/f50):.0f}%)")


if __name__ == "__main__":
    main()
