"""KV-budgeted micro-batching demo: a 4-lane drain with real decode.

    PYTHONPATH=src python examples/batched_serve.py

A reduced smollm backbone decodes 10 requests through 4 concurrent lanes
under an explicit KV-memory budget (``BatchedRealEngine``): admission is
policy-ordered (sjf_oracle here — no predictor training, to keep the
demo fast), finished lanes retire at fused-decode segment boundaries and
the vacant cache slot is back-filled from the queue by a fresh prefill.
Every token sequence is bitwise-identical to a serial greedy run — the
lanes change throughput, never output.  A second pass with a budget of
~1.5 lanes shows memory-aware admission serializing the same workload.
"""

import time

import numpy as np

from repro.configs import get_config
from repro.serving.batching import kv_bytes_per_token
from repro.serving.engine import BatchedRealEngine
from repro.serving.openai_api import CompletionRequest
from repro.serving.server import ClairvoyantServer


def drain(engine, n=10):
    server = ClairvoyantServer(policy="sjf_oracle", tau=None,
                               engines=[engine])
    rng = np.random.default_rng(0)
    lengths = rng.integers(4, 28, n)
    server.submit_many(
        [CompletionRequest(prompt=f"request number {i} "
                           + "lorem ipsum " * int(rng.integers(1, 6)))
         for i in range(n)],
        true_output_tokens=[int(x) for x in lengths],
        klasses=["short" if x < 16 else "long" for x in lengths])
    t0 = time.perf_counter()
    server.drain(max_new_tokens=28)
    wall = time.perf_counter() - t0
    return server, wall


def main():
    cfg = get_config("smollm-360m").reduced()
    bpt = kv_bytes_per_token(cfg)
    print(f"model: {cfg.name}, KV bytes/token across the stack: {bpt}")

    eng4 = BatchedRealEngine(cfg, max_len=96, segment_len=8, n_lanes=4)
    # warm the compile caches (prefill buckets + lane segment) so the
    # printed walls show steady-state serving, not jit
    eng4.generate_batch([np.arange(p) % cfg.vocab_size
                         for p in (8, 16, 24, 40)], max_new_tokens=4)

    server, wall4 = drain(eng4)
    toks = sum(r.tokens_generated for r in server.responses)
    st = eng4.lane_manager.stats
    print(f"\n4 lanes, budget {eng4.budget_bytes} B: {toks} tokens in "
          f"{wall4*1e3:.0f} ms ({toks/wall4:.0f} tok/s aggregate)")
    print(f"  admitted {st['admitted']} (back-fills {st['backfills']}), "
          f"peak KV {eng4.lane_manager.budget.peak_bytes} B")
    for r in sorted(server.responses, key=lambda r: r.queue_wait_s)[:4]:
        print(f"  req {r.request_id}: wait {r.queue_wait_s*1e3:6.0f} ms, "
              f"service {r.service_s*1e3:6.0f} ms, "
              f"{r.tokens_generated} tokens [{r.klass}]")

    # same params, just over half the observed peak KV: admission must
    # block — memory pressure serializes part of the same workload
    tight = BatchedRealEngine(
        cfg, params=eng4.params, max_len=96, segment_len=8, n_lanes=4,
        budget_bytes=int(0.55 * eng4.lane_manager.budget.peak_bytes))
    tight.generate_batch([np.arange(p) % cfg.vocab_size
                          for p in (8, 16, 24, 40)], max_new_tokens=4)
    server_t, wall_t = drain(tight)
    st = tight.lane_manager.stats
    toks_t = sum(r.tokens_generated for r in server_t.responses)
    print(f"\nsame 4 lanes, budget {tight.budget_bytes} B "
          f"(~55% of the 4-lane peak): {toks_t} tokens in "
          f"{wall_t*1e3:.0f} ms ({toks_t/wall_t:.0f} tok/s)")
    print(f"  admission blocked on budget {st['blocked_on_budget']} times "
          f"— memory pressure serializes, outputs stay identical")

    same = all(a.tokens_generated == b.tokens_generated
               for a, b in zip(sorted(server.responses,
                                      key=lambda r: r.request_id),
                               sorted(server_t.responses,
                                      key=lambda r: r.request_id)))
    print(f"\ntoken counts identical across budgets: {same}")


if __name__ == "__main__":
    main()
