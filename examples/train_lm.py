"""Train a language model end-to-end with the framework's training stack.

    PYTHONPATH=src python examples/train_lm.py                # CPU-sized
    PYTHONPATH=src python examples/train_lm.py --full         # ~360M config

Exercises: sharded train step, deterministic data pipeline, AdamW,
activation remat, async checkpointing + resume, straggler monitor.
The default config is CPU-budget-sized; --full selects the real smollm-360m
(use on a TPU host; a few hundred steps of the reduced config take ~a minute
here, which is the point of the example).
"""

import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    argv = ["--arch", "smollm-360m", "--steps", str(args.steps),
            "--ckpt", args.ckpt, "--ckpt-every", "50",
            "--batch", "8", "--seq", "128", "--lr", "3e-3"]
    if not args.full:
        argv.append("--reduced")
    losses = train_mod.main(argv)
    drop = losses[0] - losses[-1]
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} (-{drop:.3f}) "
          f"over {args.steps} steps; checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
