"""Beyond-paper: predictive routing across replicas + failover.

    PYTHONPATH=src python examples/multireplica_routing.py

The same P(Long) signal the paper uses for queue ORDERING also improves
PLACEMENT: join-shortest-predicted-work (JSPW) vs blind round-robin across 4
serial replicas, plus a mid-run replica failure with requeue.
"""

import numpy as np

from repro.core.gbdt import GBDTParams
from repro.core.predictor import Predictor
from repro.data.corpus import sample_dataset
from repro.serving.openai_api import CompletionRequest
from repro.serving.server import ClairvoyantServer


def run(policy: str, use_predictor_for_routing: bool, pred, n=200, seed=0):
    server = ClairvoyantServer(policy=policy, tau=None, n_replicas=4,
                               predictor=pred if policy == "sjf" else None,
                               seed=seed)
    if not use_predictor_for_routing:
        # blind baseline: round-robin placement, no backlog awareness
        def rr_route(req, proba=None, now=0.0):
            rep = server.router.replicas[req.req_id % 4]
            rep.queue.push(req)
            return rep.replica_id
        server.router.route = rr_route
    ds = sample_dataset("sharegpt", n=n, seed=seed + 1)
    rng = np.random.default_rng(seed + 2)
    arrivals = np.sort(rng.uniform(0, 5.0, n))
    for i in range(n):
        klass = ("short", "medium", "long")[int(ds.classes[i])]
        server.submit(CompletionRequest(prompt=ds.prompts[i]),
                      arrival=float(arrivals[i]),
                      true_output_tokens=int(ds.lengths[i]), klass=klass)
    server.drain()
    return server


def main():
    train = sample_dataset("sharegpt", n=2400, seed=0, balanced=True)
    pred = Predictor.train(train.prompts, train.lengths,
                           GBDTParams(num_rounds=80))

    blind = run("sjf", use_predictor_for_routing=False, pred=pred)
    jspw = run("sjf", use_predictor_for_routing=True, pred=pred)
    print("4 replicas, 200 mixed requests:")
    for name, s in (("round-robin", blind), ("JSPW", jspw)):
        print(f"  {name:11s}: short P50 {s.percentile(50,'short'):7.2f}s "
              f"P95 {s.percentile(95,'short'):7.2f}s | "
              f"long P95 {s.percentile(95,'long'):7.2f}s | "
              f"makespan {max(r.queue_wait_s + r.service_s for r in s.responses):6.1f}s")

    # --- failover: kill a replica with a loaded queue ----------------------
    server = ClairvoyantServer(policy="sjf", tau=None, n_replicas=4,
                               predictor=pred, seed=9)
    ds = sample_dataset("sharegpt", n=100, seed=10)
    for i in range(100):
        klass = ("short", "medium", "long")[int(ds.classes[i])]
        server.submit(CompletionRequest(prompt=ds.prompts[i]),
                      true_output_tokens=int(ds.lengths[i]), klass=klass)
    victim = max(server.router.queue_lengths(),
                 key=server.router.queue_lengths().get)
    moved = server.router.fail_replica(victim, now=0.0)
    server.drain()
    print(f"failed replica {victim}: {len(moved)} requests requeued, "
          f"{len(server.responses)} of 100 served "
          f"(failed_over={server.router.stats['failed_over']})")


if __name__ == "__main__":
    main()
