"""Beyond-paper: predictive routing across replicas with the PR-4 policy
registry — preemptive SRPT placement, per-tenant fair share, hedged
re-routing of overdue requests, and failover.

    PYTHONPATH=src python examples/multireplica_routing.py

The same P(Long) signal the paper uses for queue ORDERING also improves
PLACEMENT (join-shortest-predicted-work vs blind round-robin across 4
serial replicas).  Policies are first-class registry values
(``repro.core.policy``): the demo flips between the paper's ``sjf``, the
preemptive ``srpt`` and two-tenant ``fair_share`` by passing a policy
spec — no per-policy code paths.
"""

import numpy as np

from repro.core.gbdt import GBDTParams
from repro.core.policy import WeightedFairShare, get_policy
from repro.core.predictor import Predictor
from repro.data.corpus import sample_dataset
from repro.serving.openai_api import CompletionRequest
from repro.serving.server import ClairvoyantServer


def run(policy, pred, n=200, seed=0, jspw=True, tenants=None):
    """One 4-replica drain under a policy spec (registry name or Policy
    instance); ``jspw=False`` swaps in blind round-robin placement."""
    pol = get_policy(policy)
    server = ClairvoyantServer(policy=pol, tau=None, n_replicas=4,
                               predictor=pred if pol.uses_predictor
                               else None, seed=seed)
    if not jspw:
        def rr_route(req, proba=None, now=0.0, **kw):
            rep = server.router.replicas[req.req_id % 4]
            rep.queue.push(req)
            return rep.replica_id
        server.router.route = rr_route
    ds = sample_dataset("sharegpt", n=n, seed=seed + 1)
    rng = np.random.default_rng(seed + 2)
    arrivals = np.sort(rng.uniform(0, 5.0, n))
    for i in range(n):
        klass = ("short", "medium", "long")[int(ds.classes[i])]
        tenant = tenants[i % len(tenants)] if tenants else "default"
        server.submit(CompletionRequest(prompt=ds.prompts[i], tenant=tenant),
                      arrival=float(arrivals[i]),
                      true_output_tokens=int(ds.lengths[i]), klass=klass)
    server.drain()
    return server


def main():
    train = sample_dataset("sharegpt", n=2400, seed=0, balanced=True)
    pred = Predictor.train(train.prompts, train.lengths,
                           GBDTParams(num_rounds=80))

    # --- policy registry sweep over the same 4-replica fleet ---------------
    print("4 replicas, 200 mixed requests (JSPW placement):")
    rr = run("sjf", pred, jspw=False)
    rows = [("sjf round-robin", rr)]
    for policy in ("sjf", "srpt", "sjf_quantile"):
        rows.append((policy + " JSPW", run(policy, pred)))
    for name, s in rows:
        print(f"  {name:16s}: short P50 {s.percentile(50, 'short'):7.2f}s "
              f"P95 {s.percentile(95, 'short'):7.2f}s | "
              f"long P95 {s.percentile(95, 'long'):7.2f}s")

    # --- per-tenant fair share: a flooding tenant only delays itself ------
    fs = WeightedFairShare(weights=(("light", 1.0), ("heavy", 1.0)))
    # 7 of 8 requests belong to "heavy"; fair share keeps "light" flowing
    tenants = ["heavy"] * 7 + ["light"]
    fair = run(fs, pred, tenants=tenants)
    plain = run("fcfs", pred, tenants=tenants)
    for name, s in (("fcfs", plain), ("fair_share", fair)):
        waits = {}
        for r in s.responses:
            req = s._inflight.get(r.request_id)
            waits.setdefault(req.tenant if req else "?", []).append(
                r.queue_wait_s)
        light = float(np.mean(waits.get("light", [0.0])))
        heavy = float(np.mean(waits.get("heavy", [0.0])))
        print(f"  {name:11s}: light-tenant mean wait {light:6.2f}s "
              f"vs heavy {heavy:6.2f}s")

    # --- hedge_overdue: re-route requests that missed their deadline ------
    server = ClairvoyantServer(policy="sjf", tau=None, n_replicas=4,
                               predictor=pred, seed=5)
    ds = sample_dataset("sharegpt", n=80, seed=6)
    for i in range(80):
        klass = ("short", "medium", "long")[int(ds.classes[i])]
        # 10 stale requests queued at t=0 (a straggling replica held them);
        # the rest arrived recently and are within deadline
        arrival = 0.0 if i < 10 else 25.0
        server.submit(CompletionRequest(prompt=ds.prompts[i]),
                      arrival=arrival,
                      true_output_tokens=int(ds.lengths[i]), klass=klass)
    moved = server.router.hedge_overdue(now=30.0, deadline=20.0)
    print(f"hedged dispatch: {len(moved)} of 80 queued requests exceeded "
          f"the 20 s queue-wait deadline at t=30 and were re-routed to the "
          f"least-loaded other replica "
          f"(hedged={server.router.stats['hedged']})")
    server.drain()
    print(f"  drained {len(server.responses)} of 80 after hedging")

    # --- failover: kill a replica with a loaded queue ----------------------
    server = ClairvoyantServer(policy="sjf", tau=None, n_replicas=4,
                               predictor=pred, seed=9)
    ds = sample_dataset("sharegpt", n=100, seed=10)
    for i in range(100):
        klass = ("short", "medium", "long")[int(ds.classes[i])]
        server.submit(CompletionRequest(prompt=ds.prompts[i]),
                      true_output_tokens=int(ds.lengths[i]), klass=klass)
    victim = max(server.router.queue_lengths(),
                 key=server.router.queue_lengths().get)
    moved = server.router.fail_replica(victim, now=0.0)
    server.drain()
    print(f"failed replica {victim}: {len(moved)} requests requeued, "
          f"{len(server.responses)} of 100 served "
          f"(failed_over={server.router.stats['failed_over']})")


if __name__ == "__main__":
    main()
