import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, time
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.models.model import LM
from repro.models.frontends import input_specs, batch_axes
from repro.sharding import use_mesh
from repro.sharding.partition import tree_shardings
from repro.launch.mesh import make_production_mesh

arch = sys.argv[1] if len(sys.argv) > 1 else "granite-8b"
shape_name = sys.argv[2] if len(sys.argv) > 2 else "prefill_32k"

cfg = get_config(arch)
shape = SHAPES[shape_name]
mesh = make_production_mesh(multi_pod=False)
lm = LM(cfg)

t0 = time.time()
p_shapes, p_axes = lm.abstract_params()
p_sh = tree_shardings(p_shapes, p_axes, mesh)
b_specs = input_specs(cfg, shape)
b_sh = tree_shardings(b_specs, batch_axes(cfg, shape), mesh)
print(f"abstract {time.time()-t0:.1f}s")

def prefill_fn(params, batch):
    logits, caches = lm.prefill(params, batch)
    return logits

t0 = time.time()
with use_mesh(mesh):
    lowered = jax.jit(prefill_fn, in_shardings=(p_sh, b_sh)).lower(p_shapes, b_specs)
print(f"lower {time.time()-t0:.1f}s")
t0 = time.time()
compiled = lowered.compile()
print(f"compile {time.time()-t0:.1f}s")
ma = compiled.memory_analysis()
print("per-device output bytes:", ma.output_size_in_bytes/2**30, "GiB; temp:", ma.temp_size_in_bytes/2**30, "GiB; args:", ma.argument_size_in_bytes/2**30)
ca = compiled.cost_analysis()
print("flops:", ca.get("flops", 0)/1e12, "Tflop; bytes:", ca.get("bytes accessed", 0)/2**30)
