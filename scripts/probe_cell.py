import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, time
import jax, jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.models.model import LM
from repro.models.frontends import input_specs, batch_axes
from repro.sharding import use_mesh
from repro.sharding.partition import tree_shardings
from repro.launch.mesh import make_production_mesh
from repro.training.train_loop import abstract_train_state, make_train_step
from repro.training.optimizer import OptConfig

arch, shape_name = sys.argv[1], sys.argv[2]
multi_pod = len(sys.argv) > 3 and sys.argv[3] == "mp"
cfg = get_config(arch)
shape = SHAPES[shape_name]
mesh = make_production_mesh(multi_pod=multi_pod)
lm = LM(cfg)

t0 = time.time()
b_specs = input_specs(cfg, shape)
b_sh = tree_shardings(b_specs, batch_axes(cfg, shape), mesh)

if shape.kind == "train":
    opt = OptConfig(moment_dtype="bfloat16" if cfg.param_count() > 1e11 else "float32")
    s_shapes, s_axes = abstract_train_state(cfg, opt)
    s_sh = tree_shardings(s_shapes, s_axes, mesh)
    step = make_train_step(cfg, opt)
    with use_mesh(mesh):
        lowered = jax.jit(step, in_shardings=(s_sh, b_sh), out_shardings=(s_sh, None), donate_argnums=(0,)).lower(s_shapes, b_specs)
elif shape.kind == "prefill":
    p_shapes, p_axes = lm.abstract_params()
    p_sh = tree_shardings(p_shapes, p_axes, mesh)
    def fn(params, batch):
        return lm.prefill(params, batch)[0]
    with use_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=(p_sh, b_sh)).lower(p_shapes, b_specs)
else:
    p_shapes, p_axes = lm.abstract_params()
    p_sh = tree_shardings(p_shapes, p_axes, mesh)
    c_shapes = jax.eval_shape(lambda: lm.init_cache(shape.global_batch, shape.seq_len, t0=shape.seq_len - 1))
    c_sh = tree_shardings(c_shapes, lm.cache_axes(), mesh)
    def fn(params, caches, batch):
        return lm.decode_step(params, caches, batch)
    with use_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=(p_sh, c_sh, b_sh), out_shardings=(None, c_sh), donate_argnums=(1,)).lower(p_shapes, c_shapes, b_specs)
t1 = time.time()
compiled = lowered.compile()
t2 = time.time()
ma = compiled.memory_analysis()
ca = compiled.cost_analysis()
tot = (ma.output_size_in_bytes + ma.temp_size_in_bytes + ma.argument_size_in_bytes)/2**30
print(f"{arch} {shape_name} mp={multi_pod}: lower {t1-t0:.1f}s compile {t2-t1:.1f}s | args {ma.argument_size_in_bytes/2**30:.2f} temp {ma.temp_size_in_bytes/2**30:.2f} out {ma.output_size_in_bytes/2**30:.2f} GiB/dev | flops {ca.get('flops',0)/1e12:.2f}T bytes {ca.get('bytes accessed',0)/2**30:.1f}GiB")
