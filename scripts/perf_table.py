"""Render the §Perf hillclimb table from results/perf/*.json."""

import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1]

ORDER = [
    ("gemma-2b decode_32k", [
        ("gemma_decode_base", "baseline (scan, 2D rules)"),
        ("gemma_decode_bf16cache", "H1: f32 KV-cache casts dominate -> native-dtype einsums"),
        ("gemma_decode_servingrules", "H2: FSDP regather dominates coll -> replicate weights over data"),
        ("gemma_decode_unrolled", "H3: scan ys copy the KV cache per layer -> unroll 18 layers (in-place aliasing)"),
        ("gemma_decode_combined", "H1+H2+H3 combined"),
    ]),
    ("xlstm-350m decode_32k", [
        ("xlstm_decode_base", "baseline"),
        ("xlstm_decode_servingrules", "H2: replicate weights over data"),
        ("xlstm_decode_combined", "H2 + unrolled layers"),
    ]),
    ("llama4-maverick-400b-a17b train_4k", [
        ("llama4_train_base", "baseline (mb=8, bf16 moments)"),
        ("llama4_train_bf16grads", "H4: f32 weight-grad gathers -> bf16 custom-VJP matmuls"),
        ("llama4_train_bf16_mb16", "H4 + mb=16 (halve activation working set)"),
    ]),
]


def main():
    d = ROOT / "results" / "perf"
    print("| cell | change | compute(ms) | memory(ms) | coll(ms) | "
          "max-term Δ vs base | GiB/dev |")
    print("|---|---|---|---|---|---|---|")
    for cell, rows in ORDER:
        base_max = None
        for name, desc in rows:
            f = d / f"{name}.json"
            if not f.exists():
                print(f"| {cell} | {desc} | (not run) | | | | |")
                continue
            r = json.loads(f.read_text())
            mx = max(r["compute_s"], r["memory_s"], r["collective_s"])
            if base_max is None:
                base_max = mx
                delta = "—"
            else:
                delta = f"{100*(mx/base_max-1):+.0f}%"
            gib = sum(r["bytes_per_device"].values()) / 2 ** 30
            print(f"| {cell} | {desc} | {r['compute_s']*1e3:.2f} | "
                  f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
                  f"{delta} | {gib:.1f} |")


if __name__ == "__main__":
    main()
