import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing harness: hypothesis -> change -> re-lower -> measure.

Each experiment compiles ONE cell's production graph with a set of gated
changes and reports the roofline terms measured identically to the baseline
(same scan graph, same collective parse), so before/after deltas are
like-for-like.  Results land in results/perf/<experiment>.json.

    PYTHONPATH=src python scripts/perf_iter.py gemma_decode_bf16cache
    PYTHONPATH=src python scripts/perf_iter.py --list
"""

import argparse
import json
import pathlib
import time

import jax

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.launch import roofline as rl
from repro.launch.dryrun import _knobs, build_cell
from repro.launch.mesh import make_production_mesh
from repro.sharding import use_mesh
from repro.sharding.rules import SERVING_RULES

OUT = pathlib.Path(__file__).resolve().parents[1] / "results" / "perf"


def measure(arch, shape_name, *, rules=None, unroll_layers=False,
            decode_cast_f32=True, bf16_grad_matmuls=False,
            microbatches=None):
    import repro.models.attention as attn
    import repro.models.layers as layers
    import repro.models.transformer as tfm

    cfg = get_config(arch)
    knobs = _knobs(arch)
    if microbatches is not None:
        knobs["microbatches"] = microbatches
    attn.PERF["decode_cast_f32"] = decode_cast_f32
    layers.PERF["bf16_grad_matmuls"] = bf16_grad_matmuls
    old_unroll = tfm.SCAN_UNROLL["n"]
    if unroll_layers:
        tfm.SCAN_UNROLL["n"] = cfg.pattern_repeats
    try:
        mesh = make_production_mesh(multi_pod=False)
        t0 = time.time()
        with use_mesh(mesh, rules=rules):
            fn, args = build_cell(arch, shape_name, mesh, unroll=False,
                                  rules=rules, **knobs)
            compiled = fn.lower(*args).compile()
        compile_s = time.time() - t0
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        coll = rl.collective_bytes(compiled.as_text())
        mult = cfg.pattern_repeats * (
            knobs["microbatches"] if SHAPES[shape_name].kind == "train" else 1)
        if unroll_layers:
            mult = knobs["microbatches"] if SHAPES[shape_name].kind == "train" else 1
        report = rl.RooflineReport(
            arch=arch, shape=shape_name, mesh="pod16x16", chips=mesh.size,
            model_flops=rl.model_flops(cfg, SHAPES[shape_name]),
            hlo_flops=float(ca.get("flops", 0.0)) * mult,
            hlo_bytes=float(ca.get("bytes accessed", 0.0)) * mult,
            coll_bytes=coll,
            bytes_per_device={"args": ma.argument_size_in_bytes,
                              "temp": ma.temp_size_in_bytes,
                              "out": ma.output_size_in_bytes},
            flops_source="scan-corrected" if not unroll_layers else "unrolled",
            analytic_bytes_dev=rl.analytic_bytes(cfg, SHAPES[shape_name],
                                                 mesh.size,
                                                 knobs["microbatches"]),
        )
        d = report.to_dict()
        d["compile_s"] = compile_s
        return d
    finally:
        attn.PERF["decode_cast_f32"] = True
        layers.PERF["bf16_grad_matmuls"] = False
        tfm.SCAN_UNROLL["n"] = old_unroll


EXPERIMENTS = {
    # --- gemma-2b decode_32k: the paper-representative serving cell --------
    "gemma_decode_base": dict(arch="gemma-2b", shape="decode_32k"),
    "gemma_decode_bf16cache": dict(arch="gemma-2b", shape="decode_32k",
                                   decode_cast_f32=False),
    "gemma_decode_servingrules": dict(arch="gemma-2b", shape="decode_32k",
                                      rules=SERVING_RULES),
    "gemma_decode_unrolled": dict(arch="gemma-2b", shape="decode_32k",
                                  unroll_layers=True),
    "gemma_decode_combined": dict(arch="gemma-2b", shape="decode_32k",
                                  decode_cast_f32=False, rules=SERVING_RULES,
                                  unroll_layers=True),
    # --- xlstm decode_32k: the collective-bound cell ------------------------
    "xlstm_decode_base": dict(arch="xlstm-350m", shape="decode_32k"),
    "xlstm_decode_servingrules": dict(arch="xlstm-350m", shape="decode_32k",
                                      rules=SERVING_RULES),
    "xlstm_decode_combined": dict(arch="xlstm-350m", shape="decode_32k",
                                  rules=SERVING_RULES, unroll_layers=True),
    # --- llama4 train_4k: worst fraction / doesn't fit ----------------------
    "llama4_train_base": dict(arch="llama4-maverick-400b-a17b",
                              shape="train_4k"),
    "llama4_train_bf16grads": dict(arch="llama4-maverick-400b-a17b",
                                   shape="train_4k", bf16_grad_matmuls=True),
    "llama4_train_mb16": dict(arch="llama4-maverick-400b-a17b",
                              shape="train_4k", microbatches=16),
    "llama4_train_bf16_mb16": dict(arch="llama4-maverick-400b-a17b",
                                   shape="train_4k", bf16_grad_matmuls=True,
                                   microbatches=16),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("names", nargs="*")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list:
        for k in EXPERIMENTS:
            print(k)
        return
    OUT.mkdir(parents=True, exist_ok=True)
    for name in args.names or EXPERIMENTS:
        spec = dict(EXPERIMENTS[name])
        arch, shape = spec.pop("arch"), spec.pop("shape")
        d = measure(arch, shape, **spec)
        (OUT / f"{name}.json").write_text(json.dumps(d, indent=2))
        gib = sum(d["bytes_per_device"].values()) / 2 ** 30
        print(f"{name}: mem={d['memory_s']*1e3:.2f}ms "
              f"coll={d['collective_s']*1e3:.2f}ms "
              f"compute={d['compute_s']*1e3:.2f}ms "
              f"footprint={gib:.1f}GiB compile={d['compile_s']:.0f}s")


if __name__ == "__main__":
    main()
