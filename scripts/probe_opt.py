import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.models.frontends import input_specs, batch_axes
from repro.sharding import use_mesh
from repro.sharding.partition import tree_shardings
from repro.launch.mesh import make_production_mesh
from repro.training.train_loop import abstract_train_state, make_train_step, TrainState
from repro.training.optimizer import OptConfig, apply_updates

cfg = get_config("smollm-360m")
shape = SHAPES["train_4k"]
mesh = make_production_mesh()
opt = OptConfig()
s_shapes, s_axes = abstract_train_state(cfg, opt)
s_sh = tree_shardings(s_shapes, s_axes, mesh)
b_specs = input_specs(cfg, shape)
b_sh = tree_shardings(b_specs, batch_axes(cfg, shape), mesh)

# optimizer alone: grads shaped like params
def opt_only(state, batch):
    grads = jax.tree.map(lambda p: jnp.ones_like(p), state.params)
    p, o, m = apply_updates(state.params, grads, state.opt, opt)
    return TrainState(p, o), m

with use_mesh(mesh):
    c = jax.jit(opt_only, in_shardings=(s_sh, b_sh), out_shardings=(s_sh, None), donate_argnums=(0,)).lower(s_shapes, b_specs).compile()
print("opt_only temp:", c.memory_analysis().temp_size_in_bytes/2**30)

step = make_train_step(cfg, opt)
with use_mesh(mesh):
    c2 = jax.jit(step, in_shardings=(s_sh, b_sh), out_shardings=(s_sh, None), donate_argnums=(0,)).lower(s_shapes, b_specs).compile()
print("full temp:", c2.memory_analysis().temp_size_in_bytes/2**30)
# without donation/out_shardings
with use_mesh(mesh):
    c3 = jax.jit(step, in_shardings=(s_sh, b_sh)).lower(s_shapes, b_specs).compile()
print("full nodonate temp:", c3.memory_analysis().temp_size_in_bytes/2**30)
