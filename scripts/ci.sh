#!/usr/bin/env bash
# CI entry point: tier-1 tests + the perf microbenchmarks.
#
#   scripts/ci.sh            # full tier-1 + predictor/sim/serve benches
#                            # (write BENCH_predictor.json / BENCH_sim.json /
#                            # BENCH_serve.json)
#   SKIP_BENCH=1 scripts/ci.sh   # tests only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== repo hygiene: no tracked bytecode =="
if git ls-files | grep -E '(^|/)__pycache__/|\.py[co]$' ; then
    echo "ERROR: compiled bytecode is tracked (see list above);"
    echo "       git rm --cached it and rely on .gitignore"
    exit 1
fi

echo "== assert-stripped import check (python -O) =="
# asserts vanish under -O: policy/engine validation must rely on real
# exceptions, so the hot modules have to import and resolve cleanly
python -O -c "import repro.core.sim_fast, repro.core.policy; \
repro.core.policy.get_policy('sjf'); \
import repro.core.sweep, repro.core.scheduler"

echo "== tier-1 tests (includes sim trace-equivalence suite) =="
python -m pytest -x -q

if [ -z "${SKIP_BENCH:-}" ]; then
    echo "== predictor microbenchmark =="
    python -m benchmarks.run predictor
    echo "== BENCH_predictor.json =="
    cat BENCH_predictor.json
    echo "== simulation sweep benchmark =="
    python -m benchmarks.run sim
    echo "== BENCH_sim.json =="
    cat BENCH_sim.json
    echo "== serving benchmark (fused decode + end-to-end) =="
    python -m benchmarks.run serve
    echo "== BENCH_serve.json =="
    cat BENCH_serve.json
    echo "== scheduling-policy sweep benchmark =="
    python -m benchmarks.run policies
    echo "== BENCH_policies.json =="
    cat BENCH_policies.json
fi
