#!/usr/bin/env bash
# CI entry point: tier-1 tests + the predictor microbenchmark.
#
#   scripts/ci.sh            # full tier-1 + predictor bench (writes
#                            # BENCH_predictor.json at the repo root)
#   SKIP_BENCH=1 scripts/ci.sh   # tests only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

if [ -z "${SKIP_BENCH:-}" ]; then
    echo "== predictor microbenchmark =="
    python -m benchmarks.run predictor
    echo "== BENCH_predictor.json =="
    cat BENCH_predictor.json
fi
