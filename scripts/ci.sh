#!/usr/bin/env bash
# CI entry point: tier-1 tests + the perf microbenchmarks.
#
#   scripts/ci.sh            # full tier-1 + example smoke runs + the
#                            # predictor/sim/serve/policies/batching benches
#                            # (write the BENCH_*.json records)
#   SKIP_BENCH=1 scripts/ci.sh   # tests + example smoke runs only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== repo hygiene: no tracked bytecode =="
if git ls-files | grep -E '(^|/)__pycache__/|\.py[co]$' ; then
    echo "ERROR: compiled bytecode is tracked (see list above);"
    echo "       git rm --cached it and rely on .gitignore"
    exit 1
fi

echo "== assert-stripped import check (python -O) =="
# asserts vanish under -O: policy/engine validation must rely on real
# exceptions, so the hot modules have to import and resolve cleanly
python -O -c "import repro.core.sim_fast, repro.core.policy; \
repro.core.policy.get_policy('sjf'); \
repro.core.policy.get_policy('sjf_effective'); \
import repro.core.sweep, repro.core.scheduler, repro.serving.batching; \
import repro.serving.http_sidecar, repro.serving.backends; \
import repro.serving.paging, repro.kernels.decode_attention; \
import repro.serving.generate, repro.core.calibration; \
import repro.serving.observability, repro.serving.metrics_http"

echo "== tier-1 tests (includes sim trace-equivalence suite) =="
python -m pytest -x -q

echo "== example smoke runs (multi-replica routing, batched serve) =="
python examples/multireplica_routing.py
python examples/batched_serve.py

echo "== fixed-seed chaos smoke (no-lost-requests invariant) =="
# a seeded FaultPlan over the sim-engine server: every submitted request
# must terminate with exactly one terminal status, whatever faults fire
python - <<'PY'
from repro.serving.faults import FaultPlan
from repro.serving.openai_api import CompletionRequest
from repro.serving.server import ClairvoyantServer

n = 200
plan = FaultPlan.random(seed=1234, horizon=300.0, crash_mtbf=60.0,
                        crash_mttr=5.0, transient_rate=1 / 40.0,
                        stall_mtbf=100.0, predictor_mtbf=120.0)
server = ClairvoyantServer(policy="sjf", predictor=None, fault_plan=plan,
                           deadline_s=60.0, seed=0)
for i in range(n):
    server.submit(CompletionRequest(prompt=f"req {i}"), arrival=i * 2.0,
                  true_output_tokens=40 if i % 3 else 300,
                  klass="long" if i % 3 == 0 else "short")
server.drain()
statuses = sorted(r.status for r in server.responses)
assert len(server.responses) == n, \
    f"lost requests: {n - len(server.responses)}"
assert len(set(r.request_id for r in server.responses)) == n, \
    "duplicate terminal responses"
print(f"chaos smoke OK: {n} requests, statuses "
      f"{ {s: statuses.count(s) for s in set(statuses)} }, "
      f"fault_stats {server.fault_stats}")
PY

echo "== fixed-seed paging smoke (prefix hits + no-lost under eviction) =="
# a shared-prefix workload against a pool too small for concurrent longs:
# paged eviction must fire, every request must still retire with its exact
# tokens, the prefix cache must actually hit, and the pool must drain empty
python - <<'PY'
import numpy as np

from repro.configs import get_config
from repro.serving.engine import BatchedRealEngine, PagedBatchedEngine

cfg = get_config("smollm-360m").reduced()
base = BatchedRealEngine(cfg, max_len=64, segment_len=4, n_lanes=3, seed=0)
eng = PagedBatchedEngine(cfg, params=base.params, max_len=64, segment_len=4,
                         n_lanes=3, seed=0, page_size=8,
                         budget_bytes=9 * 8 * base._bytes_per_token)
rng = np.random.default_rng(7)
prefix = rng.integers(1, cfg.vocab_size, size=24).astype(np.int64)
prompts = [np.concatenate(
    [prefix, rng.integers(1, cfg.vocab_size, size=8)]).astype(np.int64)
    for _ in range(8)]
maxes = [32, 32, 6, 6, 6, 6, 32, 6]
res = eng.generate_batch(prompts, maxes)
lost = [i for i, r in enumerate(res) if r is None]
assert not lost, f"lost requests under paged eviction: {lost}"
al = dict(eng.allocator.stats)
mgr = eng.lane_manager.stats
assert al["prefix_hit_pages"] > 0, f"prefix cache never hit: {al}"
assert mgr["preemptions"] >= 1, f"tight pool never preempted: {mgr}"
assert eng.allocator.used_pages == 0, "pages leaked after full drain"
eng.allocator.check()
print(f"paging smoke OK: {len(res)} requests retired, "
      f"{al['prefix_hit_pages']} prefix-hit pages, "
      f"{mgr['preemptions']} preemptions, pool drained clean")
PY

echo "== fixed-seed speculative smoke (bitwise equality + acceptance) =="
# draft-verify lanes against the fused reference: the speculative path
# must emit bitwise-identical tokens (accepted tokens are target argmaxes)
# with a nonzero acceptance rate, and the DES key must degenerate to
# plain SJF at draft_k=0
python - <<'PY'
import numpy as np

from repro.configs import get_config
from repro.core.policy import get_policy
from repro.serving.engine import BatchedRealEngine
from repro.serving.service_time import expected_speedup

cfg = get_config("smollm-360m").reduced()
rng = np.random.default_rng(0)
prompts = [rng.integers(1, cfg.vocab_size, size=int(n)).astype(np.int64)
           for n in (5, 11, 23, 7)]
maxes = [10, 18, 6, 12]
ref = BatchedRealEngine(cfg, max_len=64, segment_len=4, n_lanes=3, seed=0)
want = [ref.generate_reference(p, max_new_tokens=m)["tokens"]
        for p, m in zip(prompts, maxes)]
spec = BatchedRealEngine(cfg, max_len=64, segment_len=4, n_lanes=3, seed=0,
                         params=ref.params, draft_cfg=cfg,
                         draft_params=ref.params, draft_k=3)
outs = spec.generate_batch(prompts, maxes)
bad = [i for i, (o, w) in enumerate(zip(outs, want))
       if list(o["tokens"]) != list(w)]
assert not bad, f"speculative tokens diverge from fused reference: {bad}"
assert spec.accept_rate > 0.0, f"zero acceptance: {spec.accept_rate}"
assert expected_speedup(0.9, 0) == 1.0, "draft_k=0 must be identity"
assert get_policy("sjf_effective").name == "sjf_effective"
print(f"speculative smoke OK: {len(outs)} requests bitwise-equal, "
      f"accept_rate={spec.accept_rate:.3f} "
      f"(drafted {spec.drafted_total}, accepted {spec.accepted_total}, "
      f"dead_steps {spec.dead_steps})")
PY

echo "== fixed-seed instrumented chaos smoke (span-tree completeness) =="
# the chaos drain again, this time under full tracing: every terminal
# request must carry exactly one complete span tree (the trace mirror of
# the no-lost-requests invariant), and one /metrics render must parse as
# valid Prometheus exposition — any malformed line fails the build
python - <<'PY'
from repro.serving.faults import FaultPlan
from repro.serving.observability import Observability, parse_prometheus
from repro.serving.openai_api import CompletionRequest
from repro.serving.server import ClairvoyantServer

n = 150
plan = FaultPlan.random(seed=4321, horizon=300.0, crash_mtbf=60.0,
                        crash_mttr=5.0, transient_rate=1 / 40.0,
                        stall_mtbf=100.0)
obs = Observability.default()
server = ClairvoyantServer(policy="sjf", predictor=None, fault_plan=plan,
                           deadline_s=60.0, seed=0, observability=obs)
ids = []
for i in range(n):
    req = CompletionRequest(prompt=f"req {i}")
    server.submit(req, arrival=i * 1.5,
                  true_output_tokens=40 if i % 3 else 300,
                  klass="long" if i % 3 == 0 else "short")
    ids.append(req.request_id)
server.cancel(ids[5])
server.drain()
assert len(server.responses) == n, "lost requests"
rec = obs.recorder
ok_ids = [r.request_id for r in server.responses if r.ok]
problems = rec.validate(server._terminal, ok_ids)
assert not problems, f"span-tree problems: {problems[:5]}"
for rid in ids:
    assert len(rec.span_tree(rid)["roots"]) == 1, f"req {rid}: bad tree"
families = parse_prometheus(obs.render_metrics())   # raises on bad lines
assert "clairvoyant_terminals_total" in families
print(f"instrumented chaos smoke OK: {n} span trees complete "
      f"({len(rec)} spans, {rec.dropped} dropped), "
      f"{len(families)} metric families parse clean")
PY

echo "== sidecar wire smoke (loopback HTTP/SSE, fixed seed) =="
# boots the asyncio sidecar on a loopback port and exercises the wire
# envelope: streaming SSE, non-streaming JSON, a rate-limit 429, a
# /metrics scrape (fails on malformed exposition lines), and a client
# disconnect -> cancelled terminal; fails on leaked asyncio tasks
# or connections still tracked after the graceful drain
python - <<'PY'
import asyncio, json

from repro.serving.backends import SimTextBackend
from repro.serving.http_sidecar import Sidecar
from repro.serving.observability import parse_prometheus
from repro.serving.server import ClairvoyantServer
from repro.serving.service_time import ServiceTimeModel


async def req(port, body, headers=None, disconnect_after=None):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    payload = json.dumps(body).encode()
    hdrs = {"Host": "ci", "Content-Type": "application/json",
            "Content-Length": str(len(payload)), "Connection": "close"}
    hdrs.update(headers or {})
    writer.write(("POST /v1/chat/completions HTTP/1.1\r\n" + "".join(
        f"{k}: {v}\r\n" for k, v in hdrs.items()) + "\r\n"
    ).encode() + payload)
    await writer.drain()
    if disconnect_after is not None:
        await asyncio.sleep(disconnect_after)
        writer.close()
        return None, b""
    data = await asyncio.wait_for(reader.read(), 30.0)
    writer.close()
    return int(data.split(None, 2)[1]), data


async def main():
    model = ServiceTimeModel(prefill_tok_per_s=8000.0,
                             decode_tok_per_s=60.0)
    backends = [SimTextBackend(model, replica_id=i, time_scale=0.01)
                for i in range(2)]
    server = ClairvoyantServer(policy="sjf", tau=1.0, engines=backends,
                               service_model=model,
                               deadline_mode="sojourn", seed=1234)
    sc = Sidecar(server, port=0, tenant_rate=1.0, tenant_burst=1.0)
    await sc.start()

    st, data = await req(sc.port, {"prompt": "stream", "max_tokens": 32,
                                   "output_tokens": 24, "stream": True},
                         headers={"X-Tenant": "t-stream"})
    assert st == 200 and b"data: [DONE]" in data, "streaming smoke failed"
    st, data = await req(sc.port, {"prompt": "plain", "max_tokens": 8,
                                   "output_tokens": 8},
                         headers={"X-Tenant": "t-plain"})
    body = json.loads(data.split(b"\r\n\r\n", 1)[1])
    assert st == 200 and body["clairvoyant"]["status"] == "ok"

    # one real scrape: the exposition must parse clean line-by-line
    reader, writer = await asyncio.open_connection("127.0.0.1", sc.port)
    writer.write(b"GET /metrics HTTP/1.1\r\nHost: ci\r\n"
                 b"Connection: close\r\n\r\n")
    await writer.drain()
    data = await asyncio.wait_for(reader.read(), 10.0)
    writer.close()
    head, text = data.split(b"\r\n\r\n", 1)
    assert head.split(None, 2)[1] == b"200", head
    fams = parse_prometheus(text.decode())
    assert "clairvoyant_terminals_total" in fams, sorted(fams)
    assert "clairvoyant_wire_total" in fams, sorted(fams)
    st, _ = await req(sc.port, {"prompt": "a", "max_tokens": 4,
                                "output_tokens": 4},
                      headers={"X-Tenant": "ci"})
    st2, data = await req(sc.port, {"prompt": "b", "max_tokens": 4,
                                    "output_tokens": 4},
                          headers={"X-Tenant": "ci"})
    assert (st, st2) == (200, 429), f"rate limit smoke: {st}, {st2}"
    await req(sc.port, {"prompt": "bail", "max_tokens": 512,
                        "output_tokens": 300, "stream": True},
              headers={"X-Tenant": "t-bail"}, disconnect_after=0.08)
    for _ in range(300):
        if len(server._terminal) == 4:
            break
        await asyncio.sleep(0.01)
    await sc.shutdown(drain_s=2.0)
    statuses = sorted(server._terminal.values())
    assert statuses == ["cancelled", "ok", "ok", "ok"], statuses
    leaked = [t for t in asyncio.all_tasks()
              if t is not asyncio.current_task() and not t.done()]
    assert not leaked, f"leaked asyncio tasks: {leaked}"
    assert not sc._conns, f"unclosed connections: {sc._conns}"
    print(f"sidecar wire smoke OK: terminals {statuses}, "
          f"wire_stats {sc.wire_stats}")


asyncio.run(main())
PY

if [ -z "${SKIP_BENCH:-}" ]; then
    echo "== predictor microbenchmark =="
    python -m benchmarks.run predictor
    echo "== BENCH_predictor.json =="
    cat BENCH_predictor.json
    echo "== simulation sweep benchmark =="
    python -m benchmarks.run sim
    echo "== BENCH_sim.json =="
    cat BENCH_sim.json
    echo "== serving benchmark (fused decode + end-to-end) =="
    python -m benchmarks.run serve
    echo "== BENCH_serve.json =="
    cat BENCH_serve.json
    echo "== scheduling-policy sweep benchmark =="
    python -m benchmarks.run policies
    echo "== BENCH_policies.json =="
    cat BENCH_policies.json
    echo "== micro-batching benchmark (lane scaling + c-server grid) =="
    python -m benchmarks.run batching
    echo "== BENCH_batching.json =="
    cat BENCH_batching.json
    echo "== fault-injection benchmark (degradation curves + shedding) =="
    python -m benchmarks.run faults
    echo "== BENCH_faults.json =="
    cat BENCH_faults.json
    echo "== sidecar wire benchmark (TTFT overhead + SJF-over-HTTP) =="
    python -m benchmarks.run sidecar
    echo "== BENCH_sidecar.json =="
    cat BENCH_sidecar.json
    echo "== paged-KV benchmark (A/B vs worst-case + prefix reuse) =="
    python -m benchmarks.run paging
    echo "== BENCH_paging.json =="
    cat BENCH_paging.json
    echo "== speculative decoding benchmark (draft-verify lanes) =="
    python -m benchmarks.run speculative
    echo "== BENCH_speculative.json =="
    cat BENCH_speculative.json
    echo "== observability benchmark (trace overhead + ranking fidelity) =="
    python -m benchmarks.run observability
    echo "== BENCH_observability.json =="
    cat BENCH_observability.json
fi
