#!/usr/bin/env bash
# CI entry point: tier-1 tests + the perf microbenchmarks.
#
#   scripts/ci.sh            # full tier-1 + example smoke runs + the
#                            # predictor/sim/serve/policies/batching benches
#                            # (write the BENCH_*.json records)
#   SKIP_BENCH=1 scripts/ci.sh   # tests + example smoke runs only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== repo hygiene: no tracked bytecode =="
if git ls-files | grep -E '(^|/)__pycache__/|\.py[co]$' ; then
    echo "ERROR: compiled bytecode is tracked (see list above);"
    echo "       git rm --cached it and rely on .gitignore"
    exit 1
fi

echo "== assert-stripped import check (python -O) =="
# asserts vanish under -O: policy/engine validation must rely on real
# exceptions, so the hot modules have to import and resolve cleanly
python -O -c "import repro.core.sim_fast, repro.core.policy; \
repro.core.policy.get_policy('sjf'); \
import repro.core.sweep, repro.core.scheduler, repro.serving.batching"

echo "== tier-1 tests (includes sim trace-equivalence suite) =="
python -m pytest -x -q

echo "== example smoke runs (multi-replica routing, batched serve) =="
python examples/multireplica_routing.py
python examples/batched_serve.py

echo "== fixed-seed chaos smoke (no-lost-requests invariant) =="
# a seeded FaultPlan over the sim-engine server: every submitted request
# must terminate with exactly one terminal status, whatever faults fire
python - <<'PY'
from repro.serving.faults import FaultPlan
from repro.serving.openai_api import CompletionRequest
from repro.serving.server import ClairvoyantServer

n = 200
plan = FaultPlan.random(seed=1234, horizon=300.0, crash_mtbf=60.0,
                        crash_mttr=5.0, transient_rate=1 / 40.0,
                        stall_mtbf=100.0, predictor_mtbf=120.0)
server = ClairvoyantServer(policy="sjf", predictor=None, fault_plan=plan,
                           deadline_s=60.0, seed=0)
for i in range(n):
    server.submit(CompletionRequest(prompt=f"req {i}"), arrival=i * 2.0,
                  true_output_tokens=40 if i % 3 else 300,
                  klass="long" if i % 3 == 0 else "short")
server.drain()
statuses = sorted(r.status for r in server.responses)
assert len(server.responses) == n, \
    f"lost requests: {n - len(server.responses)}"
assert len(set(r.request_id for r in server.responses)) == n, \
    "duplicate terminal responses"
print(f"chaos smoke OK: {n} requests, statuses "
      f"{ {s: statuses.count(s) for s in set(statuses)} }, "
      f"fault_stats {server.fault_stats}")
PY

if [ -z "${SKIP_BENCH:-}" ]; then
    echo "== predictor microbenchmark =="
    python -m benchmarks.run predictor
    echo "== BENCH_predictor.json =="
    cat BENCH_predictor.json
    echo "== simulation sweep benchmark =="
    python -m benchmarks.run sim
    echo "== BENCH_sim.json =="
    cat BENCH_sim.json
    echo "== serving benchmark (fused decode + end-to-end) =="
    python -m benchmarks.run serve
    echo "== BENCH_serve.json =="
    cat BENCH_serve.json
    echo "== scheduling-policy sweep benchmark =="
    python -m benchmarks.run policies
    echo "== BENCH_policies.json =="
    cat BENCH_policies.json
    echo "== micro-batching benchmark (lane scaling + c-server grid) =="
    python -m benchmarks.run batching
    echo "== BENCH_batching.json =="
    cat BENCH_batching.json
    echo "== fault-injection benchmark (degradation curves + shedding) =="
    python -m benchmarks.run faults
    echo "== BENCH_faults.json =="
    cat BENCH_faults.json
fi
