import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, re
import jax
from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.models.frontends import input_specs, batch_axes
from repro.sharding import use_mesh
from repro.sharding.partition import tree_shardings
from repro.launch.mesh import make_production_mesh
from repro.training.train_loop import abstract_train_state, make_train_step
from repro.training.optimizer import OptConfig

cfg = get_config(sys.argv[1])
shape = SHAPES["train_4k"]
mesh = make_production_mesh()
opt = OptConfig()
s_shapes, s_axes = abstract_train_state(cfg, opt)
s_sh = tree_shardings(s_shapes, s_axes, mesh)
b_specs = input_specs(cfg, shape)
b_sh = tree_shardings(b_specs, batch_axes(cfg, shape), mesh)
step = make_train_step(cfg, opt)
with use_mesh(mesh):
    c = jax.jit(step, in_shardings=(s_sh, b_sh), out_shardings=(s_sh, None), donate_argnums=(0,)).lower(s_shapes, b_specs).compile()
txt = c.as_text()
pat = sys.argv[2]
for i, line in enumerate(txt.splitlines()):
    if pat in line:
        print(line.strip()[:240])
