import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, re
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.models.frontends import input_specs, batch_axes
from repro.sharding import use_mesh
from repro.sharding.partition import tree_shardings
from repro.launch.mesh import make_production_mesh
from repro.training.train_loop import abstract_train_state, make_train_step
from repro.training.optimizer import OptConfig

arch = sys.argv[1]
cfg = get_config(arch)
shape = SHAPES["train_4k"]
mesh = make_production_mesh()
opt = OptConfig()
s_shapes, s_axes = abstract_train_state(cfg, opt)
s_sh = tree_shardings(s_shapes, s_axes, mesh)
b_specs = input_specs(cfg, shape)
b_sh = tree_shardings(b_specs, batch_axes(cfg, shape), mesh)
step = make_train_step(cfg, opt, microbatches=8)
with use_mesh(mesh):
    c = jax.jit(step, in_shardings=(s_sh, b_sh), out_shardings=(s_sh, None), donate_argnums=(0,)).lower(s_shapes, b_specs).compile()
print("temp GiB:", c.memory_analysis().temp_size_in_bytes/2**30)
txt = c.as_text()
DT = {"f32":4,"bf16":2,"s32":4,"u32":4,"f64":8,"s64":8,"pred":1,"u8":1,"s8":1,"f16":2,"u64":8,"s16":2,"u16":2}
sizes = {}
for m in re.finditer(r"(\w+)\[([\d,]+)\]", txt):
    dt, dims = m.group(1), m.group(2)
    if dt not in DT: continue
    n = 1
    for d in dims.split(","): n *= int(d)
    b = n * DT[dt]
    key = f"{dt}[{dims}]"
    if b > 2**28:
        sizes[key] = (b, sizes.get(key, (0,0))[1] + 1)
for k,(b,cnt) in sorted(sizes.items(), key=lambda kv: -kv[1][0])[:15]:
    print(f"{b/2**30:8.2f} GiB x{cnt:4d}  {k}")
