import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, time
import jax, jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.models.model import LM
from repro.models.frontends import input_specs, batch_axes
from repro.sharding import use_mesh
from repro.sharding.partition import tree_shardings
from repro.launch.mesh import make_production_mesh

arch = sys.argv[1]
cfg = get_config(arch)
shape = SHAPES["train_4k"]
mesh = make_production_mesh()
lm = LM(cfg)
p_shapes, p_axes = lm.abstract_params()
p_sh = tree_shardings(p_shapes, p_axes, mesh)
b_specs = input_specs(cfg, shape)
b_sh = tree_shardings(b_specs, batch_axes(cfg, shape), mesh)

def probe(name, fn):
    with use_mesh(mesh):
        c = jax.jit(fn, in_shardings=(p_sh, b_sh)).lower(p_shapes, b_specs).compile()
    ma = c.memory_analysis()
    print(f"{name}: temp {ma.temp_size_in_bytes/2**30:.2f} GiB/dev")

probe("loss_fwd", lambda p, b: lm.loss(p, b))
probe("grad", lambda p, b: jax.value_and_grad(lm.loss)(p, b)[0])
probe("grad_noremat", lambda p, b: jax.value_and_grad(lambda pp, bb: lm.loss(pp, bb, remat=False))(p, b)[0])
