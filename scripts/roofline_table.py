"""Rebuild the §Roofline table uniformly from the raw dry-run JSONs.

Derived quantities (compute floor, analytic memory, fraction) are recomputed
here from the raw stored fields, so cells measured before/after roofline.py
refinements render consistently.

    PYTHONPATH=src python scripts/roofline_table.py [--mesh pod16x16]
"""

import argparse
import json
import pathlib

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.launch import roofline as rl

ROOT = pathlib.Path(__file__).resolve().parents[1]


def rebuild(d: dict) -> rl.RooflineReport:
    cfg = get_config(d["arch"])
    shape = SHAPES[d["shape"]]
    knobs = d.get("knobs", {})
    return rl.RooflineReport(
        arch=d["arch"], shape=d["shape"], mesh=d["mesh"], chips=d["chips"],
        model_flops=rl.model_flops(cfg, shape),
        hlo_flops=d["hlo_flops"], hlo_bytes=d["hlo_bytes"],
        coll_bytes=d["coll_bytes"],
        bytes_per_device=d["bytes_per_device"],
        flops_source=d["flops_source"],
        analytic_bytes_dev=rl.analytic_bytes(
            cfg, shape, d["chips"], knobs.get("microbatches", 1)),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--dir", default=str(ROOT / "results" / "dryrun"))
    args = ap.parse_args()
    rows = []
    for f in sorted(pathlib.Path(args.dir).glob("*.json")):
        d = json.loads(f.read_text())
        if args.mesh != "all" and d["mesh"] != args.mesh:
            continue
        rows.append((rebuild(d), d))
    print("| arch | shape | compute(ms) | memory(ms) | analytic-mem(ms) | "
          "coll(ms) | bottleneck | useful | roofline-frac | GiB/dev | src |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    shape_order = {s: i for i, s in enumerate(SHAPES)}
    rows.sort(key=lambda rd: (rd[0].arch, shape_order[rd[0].shape]))
    for r, d in rows:
        gib = sum(d["bytes_per_device"].values()) / 2 ** 30
        print(f"| {r.arch} | {r.shape} | {r.compute_s*1e3:.2f} | "
              f"{r.memory_s*1e3:.2f} | {r.analytic_memory_s*1e3:.2f} | "
              f"{r.collective_s*1e3:.2f} | {r.bottleneck} | "
              f"{r.usefulness:.2f} | {r.roofline_fraction:.3f} | {gib:.1f} | "
              f"{r.flops_source[:4]} |")


if __name__ == "__main__":
    main()
