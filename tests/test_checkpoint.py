"""Checkpoint/restart fault tolerance: atomicity, integrity, resume, reshard."""

import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ckpt


def _state(seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (8, 16)),
            "blocks": ({"a": jnp.arange(12.0).reshape(3, 4)},
                       {"a": jnp.ones((3, 4))}),
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    s = _state()
    ckpt.save(s, tmp_path, step=10)
    r = ckpt.restore(s, tmp_path)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_and_retention(tmp_path):
    s = _state()
    for step in (1, 5, 3, 9):
        ckpt.save(s, tmp_path, step=step, keep_last=2)
    assert ckpt.latest_step(tmp_path) == 9
    assert ckpt.all_steps(tmp_path) == [5, 9]


def test_crash_mid_save_is_invisible(tmp_path):
    """A .tmp directory (simulated crash) must never be picked up."""
    s = _state()
    ckpt.save(s, tmp_path, step=4)
    fake = tmp_path / "step_000009.tmp.deadbeef"
    fake.mkdir()
    (fake / "leaf_00000.npy").write_bytes(b"garbage")
    assert ckpt.latest_step(tmp_path) == 4
    ckpt.restore(s, tmp_path)  # restores step 4, not the wreck


def test_integrity_check_detects_corruption(tmp_path):
    s = _state()
    d = ckpt.save(s, tmp_path, step=2)
    leaf = d / "leaf_00000.npy"
    arr = np.load(leaf)
    arr.ravel()[0] += 1.0
    np.save(leaf, arr)
    with pytest.raises(IOError, match="integrity"):
        ckpt.restore(s, tmp_path)


def test_restore_onto_different_sharding(tmp_path):
    """The elastic path: save on one layout, restore onto another mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    s = _state()
    ckpt.save(s, tmp_path, step=1)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree.map(lambda l: NamedSharding(mesh, P()), s)
    r = ckpt.restore(s, tmp_path, shardings=sh)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(s["w"]))


def test_async_checkpointer(tmp_path):
    s = _state()
    saver = ckpt.AsyncCheckpointer(tmp_path, keep_last=2)
    for step in (1, 2, 3):
        saver.save(s, step)
    saver.wait()
    assert ckpt.latest_step(tmp_path) == 3
    assert len(ckpt.all_steps(tmp_path)) == 2


def test_train_resume_equivalence(tmp_path):
    """Kill/restart: N steps straight == N/2 steps + restart + N/2 steps."""
    from repro.launch import train as train_mod
    args = ["--arch", "smollm-360m", "--reduced", "--batch", "4",
            "--seq", "32", "--lr", "1e-3"]
    losses_straight = train_mod.main(args + ["--steps", "6"])
    ck = str(tmp_path / "ck")
    train_mod.main(args + ["--steps", "3", "--ckpt", ck,
                           "--ckpt-every", "100"])
    losses_resumed = train_mod.main(args + ["--steps", "3", "--ckpt", ck,
                                            "--ckpt-every", "100"])
    np.testing.assert_allclose(losses_straight[3:], losses_resumed,
                               rtol=1e-4, atol=1e-5)
