"""KV-budgeted continuous micro-batching (PR 5 tentpole): lane-batched
real decode equivalence, memory-aware admission, the c-server DES and its
bitwise c=1 contracts, and the batch-degree sweep grid."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.sim_fast import (RequestBatch, simulate_batch,
                                 simulate_batch_servers)
from repro.core.simulation import ServiceDist, simulate_servers
from repro.serving.batching import (KVBudget, LaneManager,
                                    kv_bytes_per_token)
from repro.serving.engine import BatchedRealEngine

SHORT = ServiceDist(mean=3.5, std=0.8)
LONG = ServiceDist(mean=8.9, std=2.0)


# ------------------------------------------------------------- KVBudget
def test_kv_bytes_per_token_counts_attention_layers():
    cfg = get_config("smollm-360m").reduced()    # 1 attn layer, f32
    assert kv_bytes_per_token(cfg) == \
        2 * cfg.num_kv_heads * cfg.head_dim * 4
    big = get_config("smollm-360m")              # 32 layers, bf16
    assert kv_bytes_per_token(big) == \
        2 * 32 * big.num_kv_heads * big.head_dim * 2


def test_kv_budget_reserve_release_peak():
    b = KVBudget(100)
    b.reserve(60)
    assert not b.fits(50) and b.fits(40)
    with pytest.raises(ValueError):
        b.reserve(50)
    b.reserve(40)
    b.release(60)
    assert b.available_bytes == 60 and b.peak_bytes == 100
    with pytest.raises(ValueError):
        KVBudget(0)


def test_lane_manager_budget_blocks_admission_in_order():
    """The head that does not fit blocks; nothing bypasses it."""
    mgr = LaneManager(4, KVBudget(100), bytes_per_token=1, capacity=64)
    mgr.admit(0, req_id=1, prompt_len=30, max_new=30)      # 60 bytes
    assert mgr.footprint(30, 30) == 60
    assert mgr.footprint(60, 30) == 64                     # capacity-capped
    assert not mgr.can_admit(30, 30)                       # 60 > 40 left
    assert mgr.can_admit(10, 10)
    st = mgr.retire(0)
    assert st.req_id == 1 and mgr.budget.used_bytes == 0
    assert mgr.can_admit(200, 200)                         # idle override


def test_lane_manager_evict_tracks_resume_state():
    mgr = LaneManager(2, KVBudget(1000), bytes_per_token=1, capacity=64)
    st = mgr.admit(1, req_id=7, prompt_len=5, max_new=10, tenant="acme")
    st.tokens = [3, 1, 4]
    out = mgr.evict(1)
    assert out.evictions == 1 and out.tokens == [3, 1, 4]
    assert out.tenant == "acme"
    assert mgr.stats["evictions"] == 1 and mgr.stats["retired"] == 0
    assert mgr.free_lanes() == [0, 1]


# ------------------------------------------------- BatchedRealEngine
@pytest.fixture(scope="module")
def engine():
    cfg = get_config("smollm-360m").reduced()
    return BatchedRealEngine(cfg, max_len=64, segment_len=4, n_lanes=3,
                             seed=0)


def _prompts(engine, sizes, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, engine.cfg.vocab_size, n) for n in sizes]


def test_lane_decode_bitwise_equals_reference_with_backfill(engine):
    """7 requests through 3 lanes: every token sequence must equal an
    independent seed-loop run — including the 4 admitted mid-stream when
    earlier lanes retire (the back-fill join points)."""
    prompts = _prompts(engine, (5, 11, 23, 7, 3, 15, 9))
    maxes = [10, 25, 6, 18, 4, 12, 9]
    outs = engine.generate_batch(prompts, max_new_tokens=maxes)
    for out, ids, m in zip(outs, prompts, maxes):
        ref = engine.generate_reference(ids, max_new_tokens=m)
        assert out["tokens"] == ref["tokens"]
        assert not out["cancelled"]
    assert engine.lane_manager.stats["backfills"] == 4
    assert engine.lane_manager.stats["retired"] == 7


def test_lane_decode_eos_early_exit(engine):
    prompts = _prompts(engine, (10, 6, 14), seed=2)
    ref = engine.generate_reference(prompts[0], max_new_tokens=24)
    eos = ref["tokens"][5]
    outs = engine.generate_batch(prompts, max_new_tokens=24, eos_id=eos)
    for out, ids in zip(outs, prompts):
        assert out["tokens"] == engine.generate_reference(
            ids, max_new_tokens=24, eos_id=eos)["tokens"]


def test_lane_decode_max_len_truncation(engine):
    """A lane near the ring budget stops exactly like the oracle while
    the other lanes keep decoding."""
    prompts = _prompts(engine, (engine.max_len - 4, 6), seed=3)
    outs = engine.generate_batch(prompts, max_new_tokens=16)
    for out, ids in zip(outs, prompts):
        assert out["tokens"] == engine.generate_reference(
            ids, max_new_tokens=16)["tokens"]
    assert len(outs[0]["tokens"]) == 4


def test_tight_budget_serializes_but_stays_equivalent(engine):
    """A budget of ~1.2 lanes forces admission to block on memory; token
    sequences must still match the serial oracle exactly."""
    bpt = kv_bytes_per_token(engine.cfg)
    tight = BatchedRealEngine(engine.cfg, params=engine.params,
                              max_len=64, segment_len=4, n_lanes=3,
                              budget_bytes=int(64 * bpt * 1.2))
    prompts = _prompts(tight, (40, 40, 40, 8), seed=4)
    outs = tight.generate_batch(prompts, max_new_tokens=20)
    for out, ids in zip(outs, prompts):
        assert out["tokens"] == tight.generate_reference(
            ids, max_new_tokens=20)["tokens"]
    assert tight.lane_manager.stats["blocked_on_budget"] > 0
    # the 40-token prompts (footprint 60/64 of budget) never overlapped
    peak = tight.lane_manager.budget.peak_bytes
    assert peak <= tight.budget_bytes


def test_lane_cancel_evicts_at_segment_boundary(engine):
    """A per-lane cancel observed between segments evicts only that lane;
    the survivors decode to completion unchanged."""
    prompts = _prompts(engine, (9, 13, 5), seed=5)
    seen = {"segments": 0}

    def cancel_check(state):
        return state.meta.get("i") == 1 and seen["segments"] >= 2

    results = {}

    def on_finish(state, out):
        results[state.meta["i"]] = out

    n = len(prompts)
    cursor = {"i": 0}

    def source(k):
        out = []
        while k > 0 and cursor["i"] < n:
            i = cursor["i"]
            cursor["i"] += 1
            out.append({"req_id": i, "ids": prompts[i], "max_new": 30,
                        "meta": {"i": i}})
            k -= 1
        return out

    orig = engine._lane_decoder.run_segment

    def counting(*a, **kw):
        seen["segments"] += 1
        return orig(*a, **kw)

    engine._lane_decoder.run_segment = counting
    try:
        engine.run_lanes(source, on_finish, cancel_check=cancel_check)
    finally:
        engine._lane_decoder.run_segment = orig
    assert results[1]["cancelled"] and results[1]["evictions"] == 1
    # cancelled at a boundary: a prefix of the full sequence
    full = engine.generate_reference(prompts[1], max_new_tokens=30)["tokens"]
    assert results[1]["tokens"] == full[: len(results[1]["tokens"])]
    assert 1 <= len(results[1]["tokens"]) < len(full)
    for i in (0, 2):
        assert not results[i]["cancelled"]
        assert results[i]["tokens"] == engine.generate_reference(
            prompts[i], max_new_tokens=30)["tokens"]


# ------------------------------------------------- server batched drain
def test_server_drain_batched_completes_all():
    """ClairvoyantServer + BatchedRealEngine: the whole backlog drains
    through the lanes, every response carries measured wall-clock times,
    and lane back-fill pulled from the policy queue (pop_many)."""
    from repro.serving.openai_api import CompletionRequest
    from repro.serving.server import ClairvoyantServer

    cfg = get_config("smollm-360m").reduced()
    eng = BatchedRealEngine(cfg, max_len=64, segment_len=4, n_lanes=2,
                            seed=0)
    server = ClairvoyantServer(policy="sjf_oracle", tau=None, engines=[eng])
    words = ["write a short note about topic %d" % i for i in range(6)]
    server.submit_many(
        [CompletionRequest(prompt=w) for w in words],
        true_output_tokens=[6, 20, 9, 14, 5, 11],
        klasses=["short"] * 6)
    resp = server.drain(max_new_tokens=20)
    assert len(resp) == 6
    # PR 6: terminal responses leave the in-flight table (no-lost-requests
    # bookkeeping), so compare against the submitted ids instead
    assert not server._inflight
    assert sorted(r.request_id for r in resp) == list(range(1, 7))
    for r in resp:
        assert r.status == "ok"
        assert r.tokens_generated >= 1
        assert r.service_s > 0 and r.queue_wait_s >= 0
    assert eng.lane_manager.stats["retired"] == 6
    assert eng.lane_manager.stats["backfills"] == 4    # 6 reqs, 2 lanes
    assert eng.busy_until > 0


def test_server_drain_batched_oracle_order_under_lanes():
    """sjf_oracle with 2 lanes: the two shortest requests are admitted
    into the initial lanes (policy order drives lane admission)."""
    from repro.serving.openai_api import CompletionRequest
    from repro.serving.server import ClairvoyantServer

    cfg = get_config("smollm-360m").reduced()
    eng = BatchedRealEngine(cfg, max_len=64, segment_len=4, n_lanes=2,
                            seed=0)
    server = ClairvoyantServer(policy="sjf_oracle", tau=None, engines=[eng])
    toks = [40, 4, 30, 6]                   # two longs first (HoL setup)
    ids = server.submit_many(
        [CompletionRequest(prompt="p %d" % i) for i in range(4)],
        true_output_tokens=toks,
        klasses=["long", "short", "long", "short"])
    assert ids == [0, 0, 0, 0]
    resp = server.drain(max_new_tokens=40)
    order = [r.klass for r in sorted(resp, key=lambda r: r.queue_wait_s)]
    assert order[:2] == ["short", "short"]


# ------------------------------------------------------- c-server DES
def test_cserver_c1_bitwise_equals_serial_engines():
    """c=1 with unit slowdown: key-policy traces == the non-preemptive
    engine (and therefore simulate_reference); srpt == the preemptive
    engine.  Bitwise, across seeds and taus."""
    for seed in range(8):
        rng = np.random.default_rng(seed)
        batch = RequestBatch.poisson(rng, 150, 0.12, SHORT, LONG)
        for pol in ("fcfs", "sjf", "sjf_oracle", "sjf_quantile", "srpt"):
            for tau in (None, 10.5):
                a = simulate_batch(batch, policy=pol, tau=tau)
                b = simulate_batch_servers(batch, policy=pol, tau=tau,
                                           n_servers=1)
                assert np.array_equal(a.start, b.start), (seed, pol, tau)
                assert np.array_equal(a.finish, b.finish), (seed, pol, tau)
                assert np.array_equal(a.promoted, b.promoted)
                assert a.promotions == b.promotions
                assert a.preemptions == b.preemptions


def test_cserver_rejects_quantum_policies():
    batch = RequestBatch.from_arrays([0.0], [1.0])
    with pytest.raises(ValueError, match="srpt"):
        simulate_batch_servers(batch, policy="mlfq", n_servers=2)


def test_cserver_full_concurrency_is_delay_free():
    """c >= n with ideal scaling: every request starts at its arrival."""
    rng = np.random.default_rng(7)
    batch = RequestBatch.poisson(rng, 60, 0.3, SHORT, LONG)
    r = simulate_batch_servers(batch, policy="fcfs", n_servers=60)
    assert np.array_equal(r.start, batch.arrival)
    np.testing.assert_allclose(r.finish,
                               batch.arrival + batch.true_service)


def test_cserver_slowdown_stretches_concurrent_service():
    """Two unit jobs at t=0 on 2 lanes with s(2)=2: each progresses at
    half rate while both run -> both finish at 2.0 (processor sharing
    arithmetic); with s(2)=1 they finish at 1.0."""
    batch = RequestBatch.from_arrays([0.0, 0.0], [1.0, 1.0])
    slow = simulate_batch_servers(batch, policy="fcfs", n_servers=2,
                                  slowdown=(1.0, 2.0))
    np.testing.assert_allclose(slow.finish, [2.0, 2.0])
    ideal = simulate_batch_servers(batch, policy="fcfs", n_servers=2,
                                   slowdown=(1.0, 1.0))
    np.testing.assert_allclose(ideal.finish, [1.0, 1.0])


def test_cserver_rate_rescales_when_a_lane_retires():
    """Jobs (1.0, 2.0) at t=0, c=2, s=(1, 2): both run at rate 1/2;
    job A done at t=2 (1.0 work), job B then runs alone at full rate,
    finishing its remaining 1.0 at t=3."""
    batch = RequestBatch.from_arrays([0.0, 0.0], [1.0, 2.0])
    r = simulate_batch_servers(batch, policy="fcfs", n_servers=2,
                               slowdown=(1.0, 2.0))
    np.testing.assert_allclose(r.finish, [2.0, 3.0])


def test_cserver_memory_budget_serializes():
    """Per-request demand == budget: lanes exist but memory admits one at
    a time -> the trace equals the serial engine's."""
    rng = np.random.default_rng(9)
    batch = RequestBatch.poisson(rng, 100, 0.12, SHORT, LONG)
    a = simulate_batch(batch, policy="sjf", tau=None)
    b = simulate_batch_servers(batch, policy="sjf", n_servers=4,
                               mem_tokens=np.full(100, 10.0),
                               mem_budget=10.0)
    assert np.array_equal(a.start, b.start)
    assert np.array_equal(a.finish, b.finish)


def test_cserver_memory_budget_bounds_concurrency():
    """Budget of 2 units with unit demands behaves exactly like c=2."""
    rng = np.random.default_rng(10)
    batch = RequestBatch.poisson(rng, 80, 0.25, SHORT, LONG)
    by_lanes = simulate_batch_servers(batch, policy="sjf", n_servers=2)
    by_mem = simulate_batch_servers(batch, policy="sjf", n_servers=8,
                                    mem_tokens=np.ones(80),
                                    mem_budget=2.0)
    assert np.array_equal(by_lanes.start, by_mem.start)
    assert np.array_equal(by_lanes.finish, by_mem.finish)


def test_cserver_batching_recovers_sojourn_on_bursts():
    """More lanes -> shorter mean sojourn on a burst, even under a
    non-trivial slowdown (aggregate throughput still grows)."""
    rng = np.random.default_rng(11)
    batch = RequestBatch.burst(rng, 20, 20, SHORT, LONG)
    s = (1.0, 1.2, 1.4, 1.6)
    means = [simulate_batch_servers(batch, policy="sjf", n_servers=c,
                                    slowdown=s[:c]).mean()
             for c in (1, 2, 4)]
    assert means[0] > means[1] > means[2]


def test_cserver_srpt_preempts_across_lanes():
    """c=2 srpt: a short arriving while two longs run evicts the worse
    lane and finishes first."""
    batch = RequestBatch.from_arrays(
        [0.0, 0.0, 1.0], [10.0, 12.0, 1.0], p_long=[1.0, 1.0, 0.0])
    r = simulate_batch_servers(batch, policy="srpt", n_servers=2)
    assert r.preemptions == 1
    assert r.start[2] == 1.0                  # dispatched on arrival
    assert r.finish[2] == 2.0
    assert r.finish[2] < r.finish[0] < r.finish[1]


def test_simulate_servers_front_end():
    """The Request-object front end writes back start/finish and matches
    simulate() at c=1."""
    from repro.core.simulation import poisson_workload, simulate
    rng = np.random.default_rng(3)
    es = 0.5 * SHORT.mean + 0.5 * LONG.mean
    reqs = poisson_workload(rng, 300, 0.74 / es, SHORT, LONG)
    a = simulate(list(reqs), policy="sjf", tau=10.5)
    starts = {r.req_id: r.start for r in a.requests}
    b = simulate_servers(list(reqs), policy="sjf", tau=10.5, n_servers=1)
    assert {r.req_id: r.start for r in b.requests} == starts
    b_mean = b.mean()        # snapshot: the engines mutate the Requests
    c4 = simulate_servers(list(reqs), policy="sjf", tau=10.5, n_servers=4)
    assert c4.mean() < b_mean


# ------------------------------------------------------- sweep grid
def test_sweep_lanes_grid_shape_and_anchors():
    from repro.core.sweep import sweep_lanes
    res = sweep_lanes(
        conditions=[("fcfs", None), ("sjf", None), ("srpt", None)],
        lanes=(1, 2, 4), seeds=range(3), n=300, rho=0.74,
        short=SHORT, long=LONG, slowdown=(1.0, 1.25, 1.5, 1.75),
        budgets=(None, 800.0))
    m = res.metric("short_p50")
    assert m.shape == (3, 3, 2)[:2] + (2, 3)
    # c=1 unbudgeted rows must equal the serial sweep engine (anchor)
    from repro.core.sweep import sweep_poisson
    anchor = sweep_poisson(
        conditions=[("fcfs", None), ("sjf", None)],
        rhos=(0.74,), seeds=range(3), n=300, short=SHORT, long=LONG)
    np.testing.assert_array_equal(m[:2, 0, 0, :],
                                  anchor.metric("short_p50")[:, 0, :])
    # batching helps FCFS: more lanes -> lower seed-mean short P50
    fcfs = m[0].mean(-1)          # (L, B)
    assert fcfs[2, 0] < fcfs[0, 0]
    # a finite KV budget costs throughput vs unbudgeted at high c
    assert np.isfinite(m).all()


def test_sweep_lane_batches_keeps_tenant_keys():
    """fair_share rows must key per tenant (regression: the lane grid
    once dropped tenant codes, silently collapsing every request into
    one tenant): the c=1 row equals simulate_batch on the same
    two-tenant batch, which differs from the tenant-blind ordering."""
    from repro.core.sweep import sweep_lane_batches
    rng = np.random.default_rng(5)
    batch = RequestBatch.poisson(rng, 120, 0.12, SHORT, LONG)
    batch.tenant = (np.arange(120) % 3 == 0).astype(np.int32)
    batch.tenants = ("heavy", "light")
    flat = sweep_lane_batches([batch], [("fair_share", None)], lanes=(1,))
    want = simulate_batch(batch, policy="fair_share", tau=None)
    got = flat["mean_sojourn"][0, 0, 0, 0]
    assert got == float((want.finish - batch.arrival).mean())


def test_sweep_lanes_batching_vs_scheduling_decomposition():
    """The question the grid answers: plain FCFS batching at c=4 recovers
    much of SJF's short-P50 win, and predictive admission still adds on
    top (sjf@c <= fcfs@c for every c, seed-averaged)."""
    from repro.core.sweep import sweep_lanes
    res = sweep_lanes(
        conditions=[("fcfs", None), ("sjf", None)],
        lanes=(1, 4), seeds=range(3), n=400, rho=0.74,
        short=SHORT, long=LONG, slowdown=(1.0, 1.2, 1.4, 1.6))
    p50 = res.metric("short_p50").mean(-1)[:, :, 0]   # (C, L)
    fcfs1, fcfs4 = p50[0]
    sjf1, sjf4 = p50[1]
    assert fcfs4 < fcfs1                  # batching alone helps
    assert sjf4 <= fcfs4                  # admission still adds on top
    assert sjf1 < fcfs1
