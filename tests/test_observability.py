"""Observability suite (PR 10): flight recorder, /metrics, ranking monitor.

Covers trace lifecycle invariants (every admitted request yields exactly
one complete span tree; spans on exclusive tracks nest and never
overlap; Perfetto JSON round-trips with monotone ``ts``), Prometheus
exposition validity, the online ranking-fidelity monitor (recovery of a
known pairwise accuracy, inversion-drift alert within one window), the
DES-vs-live span-schema parity, and the sidecar's /metrics, /healthz
engine stats, and /readyz ranking + breaker detail.
"""

import asyncio
import json
import math

import numpy as np
import pytest

from repro.core.scheduler import Request
from repro.core.simulation import _spread_for_accuracy, simulate
from repro.serving.faults import CircuitBreaker, FaultPlan, RetryPolicy
from repro.serving.observability import (FlightRecorder, Histogram,
                                         MetricsRegistry, Observability,
                                         RankingMonitor, parse_prometheus,
                                         record_service_spans)
from repro.serving.openai_api import CompletionRequest
from repro.serving.server import ClairvoyantServer
from repro.serving.service_time import ServiceTimeModel


# ------------------------------------------------------------- recorder units
def test_recorder_ring_drops_and_counts():
    rec = FlightRecorder(capacity=4)
    for i in range(7):
        rec.span("decode", i, float(i), float(i) + 0.5)
    assert len(rec) == 4 and rec.dropped == 3
    assert [s.req_id for s in rec.spans()] == [3, 4, 5, 6]
    rec.clear()
    assert len(rec) == 0 and rec.dropped == 0


def test_request_span_stretches_over_straggler_children():
    rec = FlightRecorder()
    rec.span("decode", 1, 0.0, 5.0)           # child outlives the sojourn
    rec.request_span(1, 0.0, 3.0)
    root = rec.span_tree(1)["root"]
    assert root is not None and root.t1 == 5.0
    assert rec.validate([1]) == []


def test_validate_flags_missing_root_and_out_of_bounds():
    rec = FlightRecorder()
    rec.span("decode", 1, 0.0, 1.0)
    probs = rec.validate([1])
    assert any("root" in p for p in probs)     # no request span at all
    rec2 = FlightRecorder()
    rec2.span("request", 2, 0.0, 1.0, track="req2")
    rec2.span("decode", 2, 0.5, 2.0)           # ends after the root
    assert any("outside root" in p for p in rec2.validate([2]))


def test_validate_flags_partial_overlap_on_exclusive_track():
    rec = FlightRecorder()
    rec.span("decode", 1, 0.0, 2.0, track="replica0")
    rec.span("decode", 2, 1.0, 3.0, track="replica0")   # partial overlap
    assert any("overlaps" in p for p in rec.validate([]))
    # nesting and disjointness are both fine
    rec2 = FlightRecorder()
    rec2.span("decode", 1, 0.0, 2.0, track="replica0")
    rec2.span("decode_segment", 1, 0.5, 1.5, track="replica0")
    rec2.span("decode", 2, 2.0, 3.0, track="replica0")
    assert rec2.validate([]) == []


def test_async_spans_exempt_from_track_overlap():
    rec = FlightRecorder()
    rec.span("queue_wait", 1, 0.0, 5.0, track="req1")
    rec.span("queue_wait", 2, 1.0, 6.0, track="req1")   # same track, async
    assert rec.validate([]) == []


def test_record_service_spans_segments_cap():
    rec = FlightRecorder()
    record_service_spans(rec, 7, start=1.0, finish=9.0, arrival=0.0,
                         ttft=0.5, out_tokens=1000, segment_tokens=8,
                         max_segments=4)
    segs = [s for s in rec.spans() if s.name == "decode_segment"]
    assert len(segs) == 4                      # capped, not 125
    assert segs[0].t0 == pytest.approx(1.5)
    assert segs[-1].t1 == pytest.approx(9.0)
    # segments tile the decode span exactly
    for a, b in zip(segs, segs[1:]):
        assert a.t1 == pytest.approx(b.t0)


def test_perfetto_round_trips_with_monotone_ts():
    rec = FlightRecorder()
    for i in range(6):
        record_service_spans(rec, i, start=i * 1.0, finish=i * 1.0 + 0.9,
                             arrival=i * 0.5, ttft=0.1, out_tokens=32)
        rec.request_span(i, i * 0.5, i * 1.0 + 0.9)
    rec.instant("route", 0, 0.25, track="replica0")
    doc = json.loads(json.dumps(rec.to_perfetto()))
    evs = [e for e in doc["traceEvents"] if e["ph"] != "M"]
    ts = [e["ts"] for e in evs]
    assert ts == sorted(ts)
    assert {e["ph"] for e in evs} >= {"X", "b", "e", "i"}
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "M"}
    assert names == {"process_name", "thread_name"}
    assert doc["otherData"]["dropped_spans"] == 0
    # jsonl export parses line by line
    for line in rec.jsonl_lines():
        assert json.loads(line)["type"] in ("span", "instant")


# ------------------------------------------------------------------- metrics
def test_metrics_render_is_valid_exposition():
    reg = MetricsRegistry()
    c = reg.counter("clairvoyant_test_total", "Things counted")
    g = reg.gauge("clairvoyant_test_depth", "A gauge")
    h = reg.histogram("clairvoyant_test_seconds", "A histogram",
                      buckets=(0.1, 1.0, 10.0))
    c.inc(3, status="ok", klass="short")
    c.inc(2, status="shed", klass="")
    g.set(7.5, replica="0")
    for v in (0.05, 0.5, 5.0, 50.0):
        h.observe(v)
    fams = parse_prometheus(reg.render())
    assert fams["clairvoyant_test_total"][0][2] in (2.0, 3.0)
    hist = {n: v for n, lab, v in fams["clairvoyant_test_seconds"]}
    assert hist["clairvoyant_test_seconds_count"] == 4
    assert hist["clairvoyant_test_seconds_sum"] == pytest.approx(55.55)
    buckets = [(lab["le"], v) for n, lab, v in
               fams["clairvoyant_test_seconds"]
               if n.endswith("_bucket")]
    assert buckets == [("0.1", 1.0), ("1", 2.0), ("10", 3.0),
                       ("+Inf", 4.0)]


def test_histogram_fold_is_incremental():
    h = Histogram("x_seconds", "x", buckets=(1.0,))
    h.observe(0.5)
    assert h.count() == 1
    h.observe(2.0)
    h.observe(0.1)
    assert h.count() == 3                      # re-fold picks up new values


def test_parse_prometheus_rejects_malformed():
    with pytest.raises(ValueError):
        parse_prometheus("foo_total 1")        # no TYPE declaration
    with pytest.raises(ValueError):
        parse_prometheus("# TYPE x counter\nx{bad-label=\"1\"} 1")
    with pytest.raises(ValueError):
        parse_prometheus("# TYPE x counter\nx one_point_five")
    ok = parse_prometheus("# TYPE x counter\nx{a=\"b\"} 1.5\n")
    assert ok["x"] == [("x", {"a": "b"}, 1.5)]


# ------------------------------------------------------------ ranking monitor
def _feed_two_class(mon, rng, n, accuracy, invert=False,
                    s_short=1.0, s_long=8.0):
    """Noisy P(Long) keys at a target cross-class pairwise accuracy;
    within-class services are identical so those pairs are ties
    (excluded), leaving concordance == cross-class accuracy."""
    spread = _spread_for_accuracy(accuracy)
    for i in range(n):
        long = bool(i % 2)
        base = 0.75 if long else 0.25
        key = float(np.clip(rng.normal(base, spread), 0.0, 1.0))
        if invert:
            key = 1.0 - key
        mon.record(key, s_long if long else s_short,
                   p_long=key, is_long=long)


def test_ranking_monitor_recovers_known_accuracy():
    mon = RankingMonitor(window=512)
    _feed_two_class(mon, np.random.default_rng(7), 512, accuracy=0.87)
    snap = mon.snapshot()
    assert abs(snap["concordance"] - 0.87) <= 0.05
    assert not snap["alert"]
    assert snap["long_calibration_drift"] is not None
    assert snap["long_calibration_drift"] < 0.15


def test_ranking_monitor_alerts_on_inversion_within_one_window():
    mon = RankingMonitor(window=256, alert_threshold=0.6)
    rng = np.random.default_rng(3)
    _feed_two_class(mon, rng, 256, accuracy=0.9)
    assert not mon.snapshot()["alert"]
    # drift injection: the predictor inverts; within ONE window the
    # concordance collapses and the alert trips
    _feed_two_class(mon, rng, 256, accuracy=0.9, invert=True)
    snap = mon.snapshot()
    assert snap["alert"] and snap["concordance"] < 0.3


def test_ranking_monitor_ties_and_empty():
    mon = RankingMonitor(window=16)
    assert math.isnan(mon.concordance())
    for _ in range(4):
        mon.record(0.5, 2.0)                   # all ties -> still NaN
    assert math.isnan(mon.concordance())
    assert mon.snapshot()["concordance"] is None


def test_snapshot_cached_refreshes_on_dirty_threshold():
    mon = RankingMonitor(window=64)            # refresh every 8 records
    rng = np.random.default_rng(0)
    _feed_two_class(mon, rng, 16, accuracy=1.0)
    first = mon.snapshot_cached()
    mon.record(0.9, 9.0)
    assert mon.snapshot_cached() is first      # < window//8 new samples
    _feed_two_class(mon, rng, 8, accuracy=1.0)
    assert mon.snapshot_cached() is not first


# --------------------------------------------- traced drains (sim, chaos)
def _traced_chaos_server(seed, n_replicas=1, **kw):
    plan = FaultPlan.random(
        seed=seed, horizon=150.0, crash_mtbf=25.0, crash_mttr=3.0,
        transient_rate=1 / 20.0, stall_mtbf=40.0, stall_s=8.0,
        n_replicas=n_replicas)
    return ClairvoyantServer(policy="sjf", predictor=None, fault_plan=plan,
                             n_replicas=n_replicas, seed=seed,
                             retry=RetryPolicy(seed=seed),
                             observability=Observability.default(), **kw)


def test_chaos_sim_drain_span_trees_complete():
    """Every admitted request yields exactly one complete span tree,
    even under injected crashes/transients/cancels (the trace mirror of
    the no-lost-requests invariant)."""
    for trial in range(4):
        rng = np.random.default_rng(100 + trial)
        server = _traced_chaos_server(seed=trial, n_replicas=1 + trial % 2,
                                      deadline_s=None if trial % 2 else 40.0)
        n = 40
        ids = []
        for i in range(n):
            req = CompletionRequest(prompt=f"chaos {trial}:{i}")
            server.submit(req, arrival=float(rng.uniform(0, 100)),
                          true_output_tokens=int(rng.integers(20, 600)),
                          klass="short" if rng.random() < 0.6 else "long")
            ids.append(req.request_id)
        server.cancel(ids[1])
        server.drain()
        assert len(server.responses) == n
        rec = server.obs.recorder
        ok_ids = [r.request_id for r in server.responses if r.ok]
        problems = rec.validate(server._terminal, ok_ids)
        assert problems == [], f"trial {trial}: {problems[:5]}"
        # exactly one root per terminal
        for rid in ids:
            assert len(rec.span_tree(rid)["roots"]) == 1


def test_traced_preemptive_drain_validates():
    server = ClairvoyantServer(policy="srpt", predictor=None, seed=0,
                               observability=Observability.default())
    rng = np.random.default_rng(2)
    for i in range(30):
        server.submit(CompletionRequest(prompt=f"p{i}"),
                      arrival=float(rng.uniform(0, 20)),
                      true_output_tokens=int(rng.integers(20, 900)),
                      klass="short" if i % 3 else "long")
    server.drain()
    rec = server.obs.recorder
    ok_ids = [r.request_id for r in server.responses if r.ok]
    assert rec.validate(server._terminal, ok_ids) == []


def test_untraced_server_has_no_observability_cost_points():
    server = ClairvoyantServer(policy="sjf", predictor=None, seed=0)
    assert server.obs is None
    assert server.router.recorder is None
    server.submit(CompletionRequest(prompt="x"), true_output_tokens=10,
                  klass="short")
    server.drain()
    assert len(server.responses) == 1


def test_predictor_stage_spans_and_latency(small_predictor):
    obs = Observability.default()
    server = ClairvoyantServer(policy="sjf", predictor=small_predictor,
                               seed=0, observability=obs)
    reqs = [CompletionRequest(prompt=f"tell me about topic {i} " * (2 + i))
            for i in range(8)]
    server.submit_many(reqs, true_output_tokens=[30 + 10 * i
                                                for i in range(8)])
    server.drain()
    rec = obs.recorder
    names = rec.schema()
    assert "feature_extract" in names and "predict" in names
    h = obs.metrics._metrics["clairvoyant_predictor_latency_seconds"]
    assert h.count() == 8                      # per-request latencies


@pytest.fixture(scope="module")
def small_predictor():
    from repro.core.gbdt import GBDTParams
    from repro.core.predictor import Predictor
    from repro.data.corpus import sample_dataset
    ds = sample_dataset("sharegpt", n=600, seed=42, balanced=True)
    return Predictor.train(ds.prompts, ds.lengths, GBDTParams(num_rounds=20))


# ----------------------------------------------------- DES-vs-live parity
def test_des_trace_schema_matches_sim_drain():
    """The DES post-processor and the server's virtual-time drain emit
    the same span vocabulary for the same workload."""
    model = ServiceTimeModel(prefill_tok_per_s=8000.0,
                             decode_tok_per_s=60.0)
    rng = np.random.default_rng(5)
    otoks = [int(rng.integers(20, 400)) for _ in range(20)]
    arrivals = sorted(float(rng.uniform(0, 5)) for _ in range(20))

    obs = Observability.default()
    server = ClairvoyantServer(policy="sjf_oracle", predictor=None,
                               service_model=model, seed=0,
                               observability=obs)
    reqs = [CompletionRequest(prompt=f"parity {i}") for i in range(20)]
    server.submit_many(reqs, arrivals=arrivals, true_output_tokens=otoks,
                       klasses=["short"] * 20)
    server.drain()

    des_rec = FlightRecorder()
    des_reqs = [Request(req_id=reqs[i].request_id, prompt=f"parity {i}",
                        arrival=arrivals[i],
                        true_service=model.service(
                            len(f"parity {i}".split()), otoks[i]),
                        meta={"output_tokens": otoks[i]})
                for i in range(20)]
    simulate(des_reqs, policy="sjf_oracle", recorder=des_rec)

    assert set(server.obs.recorder.schema()) == set(des_rec.schema())
    assert des_rec.validate([r.req_id for r in des_reqs],
                            [r.req_id for r in des_reqs]) == []


def _dispatch_order(rec, track="replica0"):
    pref = [s for s in rec.spans()
            if s.name == "prefill" and s.track == track]
    pref.sort(key=lambda s: s.t0)
    return [s.req_id for s in pref]


def test_des_and_live_wire_traces_match_at_c1():
    """A live loopback (sidecar) drain and a DES drain of the same
    workload export the same span schema and the same dispatch order at
    c=1 under the oracle SJF key."""
    from repro.serving.backends import HTTPBackend, SimTextBackend
    from repro.serving.http_sidecar import Sidecar

    model = ServiceTimeModel(prefill_tok_per_s=8000.0,
                             decode_tok_per_s=60.0)

    async def run():
        backend = SimTextBackend(model, replica_id=0, time_scale=0.05)
        srv = ClairvoyantServer(policy="sjf_oracle", predictor=None,
                                service_model=model, engines=[backend],
                                seed=0, deadline_mode="sojourn",
                                observability=Observability.default())
        sc = Sidecar(srv, port=0, max_new_tokens=512)
        await sc.start()
        client = HTTPBackend("127.0.0.1", sc.port)

        async def call(otok):
            payload = json.dumps(
                {"messages": [{"role": "user", "content": "same prompt"}],
                 "max_tokens": int(otok), "output_tokens": int(otok)}
            ).encode()
            r, w, status, _ = await client._request(
                "POST", "/v1/chat/completions", payload)
            doc = json.loads(await r.read(-1))
            w.close()
            assert status == 200
            return doc

        # the head request holds the serial lane long enough for the
        # rest to queue; the queue then drains in oracle-SJF order
        head = asyncio.create_task(call(200))
        await asyncio.sleep(0.08)
        rest = [asyncio.create_task(call(o)) for o in (32, 8, 24, 16, 40)]
        await asyncio.gather(head, *rest)
        await sc.shutdown(drain_s=2.0)
        return srv

    srv = asyncio.run(run())
    live_rec = srv.obs.recorder
    assert live_rec.validate(
        srv._terminal,
        [r.request_id for r in srv.responses if r.ok]) == []
    live_order = _dispatch_order(live_rec)
    assert len(live_order) == 6

    # rebuild the workload for the DES from the live trace: arrivals are
    # the queue_wait span starts, service the oracle key's service time
    arrival_of = {s.req_id: s.t0 for s in live_rec.spans()
                  if s.name == "queue_wait"}
    otok_of = {r.request_id: r.tokens_generated for r in srv.responses}
    ptoks = len("same prompt".split())
    des_rec = FlightRecorder()
    des_reqs = [Request(req_id=rid, prompt="same prompt",
                        arrival=arrival_of[rid],
                        true_service=model.service(ptoks, otok_of[rid]),
                        meta={"output_tokens": otok_of[rid]})
                for rid in live_order]
    simulate(des_reqs, policy="sjf_oracle", recorder=des_rec)

    assert set(des_rec.schema()) == set(live_rec.schema())
    assert _dispatch_order(des_rec) == live_order


# ------------------------------------------------------------ sidecar wire
def test_sidecar_metrics_healthz_readyz():
    from repro.serving.backends import HTTPBackend, SimTextBackend
    from repro.serving.http_sidecar import METRICS_CONTENT_TYPE, Sidecar

    model = ServiceTimeModel(prefill_tok_per_s=8000.0,
                             decode_tok_per_s=60.0)

    async def run():
        backends = [SimTextBackend(model, replica_id=i, time_scale=0.003)
                    for i in range(2)]
        srv = ClairvoyantServer(policy="sjf_oracle", predictor=None,
                                service_model=model, engines=backends,
                                seed=0, deadline_mode="sojourn",
                                breaker=CircuitBreaker())
        sc = Sidecar(srv, port=0, max_new_tokens=32)
        # no bundle attached: the sidecar builds the metrics+ranking
        # default (tracing off)
        assert srv.obs is not None and srv.obs.recorder is None
        await sc.start()
        client = HTTPBackend("127.0.0.1", sc.port)
        outs = await asyncio.gather(*[
            client.generate(f"prompt {i} " * (2 + i % 3),
                            max_new_tokens=8 + 4 * (i % 3))
            for i in range(8)])
        assert all(not o["cancelled"] for o in outs)

        r, w, status, hdrs = await client._request("GET", "/metrics")
        text = (await r.read(-1)).decode()
        w.close()
        assert status == 200
        assert hdrs.get("content-type") == METRICS_CONTENT_TYPE
        fams = parse_prometheus(text)          # raises on malformed lines
        assert "clairvoyant_terminals_total" in fams
        assert "clairvoyant_wire_total" in fams
        assert "clairvoyant_queue_depth" in fams
        term = sum(v for n, lab, v in fams["clairvoyant_terminals_total"]
                   if n.endswith("_total"))
        assert term == 8

        r, w, status, _ = await client._request("GET", "/healthz")
        doc = json.loads(await r.read(-1))
        w.close()
        assert status == 200
        assert [e["replica"] for e in doc["engines"]] == [0, 1]
        assert sum(e["served"] for e in doc["engines"]) == 8

        r, w, status, _ = await client._request("GET", "/readyz")
        doc = json.loads(await r.read(-1))
        w.close()
        assert status == 200 and doc["ready"]
        assert doc["ranking"]["recorded"] == 8
        assert all(rep["breaker"] == "closed" for rep in doc["replicas"])

        # the clairvoyant response block carries the ranking snapshot
        payload = json.dumps({"messages": [{"role": "user",
                                            "content": "once more"}],
                              "max_tokens": 8}).encode()
        r, w, status, _ = await client._request(
            "POST", "/v1/chat/completions", payload)
        doc = json.loads(await r.read(-1))
        w.close()
        assert "ranking" in doc["clairvoyant"]
        assert doc["clairvoyant"]["ranking"]["recorded"] >= 8
        await sc.shutdown(drain_s=2.0)

    asyncio.run(run())


def test_metrics_http_server_scrapes():
    from repro.serving.backends import HTTPBackend
    from repro.serving.metrics_http import CONTENT_TYPE, MetricsServer

    async def run():
        obs = Observability.default(tracing=False)
        obs.metrics.counter("clairvoyant_demo_total", "demo").inc(2)
        ms = MetricsServer(obs, port=0)
        await ms.start()
        client = HTTPBackend("127.0.0.1", ms.port)
        r, w, status, hdrs = await client._request("GET", "/metrics")
        text = (await r.read(-1)).decode()
        w.close()
        assert status == 200 and hdrs.get("content-type") == CONTENT_TYPE
        fams = parse_prometheus(text)
        assert fams["clairvoyant_demo_total"][0][2] == 2.0
        r, w, status, _ = await client._request("GET", "/nope")
        await r.read(-1)
        w.close()
        assert status == 404
        await ms.stop()

    asyncio.run(run())
