"""Serving integration: the paper's n=8 dispatch-order test, disconnects,
routing, failover, real-engine decode."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.gbdt import GBDTParams
from repro.core.predictor import Predictor
from repro.core.router import PredictiveRouter
from repro.core.scheduler import Request
from repro.data.corpus import sample_dataset
from repro.serving.engine import RealEngine
from repro.serving.openai_api import CompletionRequest
from repro.serving.server import ClairvoyantServer
from repro.serving.service_time import ServiceTimeModel


@pytest.fixture(scope="module")
def predictor():
    ds = sample_dataset("sharegpt", n=2400, seed=42, balanced=True)
    return Predictor.train(ds.prompts, ds.lengths, GBDTParams(num_rounds=60))


def _mixed_requests(n_short=4, n_long=4, seed=0):
    """4 Short + 4 Long real prompts (the paper's M1 end-to-end test)."""
    ds = sample_dataset("sharegpt", n=4000, seed=seed)
    shorts = [i for i in range(len(ds)) if ds.lengths[i] < 120][:n_short]
    longs = [i for i in range(len(ds)) if ds.lengths[i] >= 1000][:n_long]
    return ([(ds.prompts[i], int(ds.lengths[i]), "short") for i in shorts]
            + [(ds.prompts[i], int(ds.lengths[i]), "long") for i in longs])


def test_sjf_dispatch_order_end_to_end(predictor):
    """Paper §3.4: n=8 burst — all Short complete before any Long.

    Like the paper's test (dispatch-LOGIC validation), the 8 requests are
    drawn so the predictor separates them; cross-class fidelity on arbitrary
    prompts is measured by the ranking benchmarks, not here.
    """
    cands = _mixed_requests(n_short=12, n_long=12)
    scores = predictor.p_long_batch([c[0] for c in cands])
    shorts = sorted((c for c, s in zip(cands, scores) if c[2] == "short"),
                    key=lambda c: scores[cands.index(c)])[:4]
    longs = sorted((c for c, s in zip(cands, scores) if c[2] == "long"),
                   key=lambda c: -scores[cands.index(c)])[:4]
    server = ClairvoyantServer(policy="sjf", tau=None, predictor=predictor)
    for prompt, toks, klass in shorts + longs:
        server.submit(CompletionRequest(prompt=prompt), arrival=0.0,
                      true_output_tokens=toks, klass=klass)
    resp = server.drain()
    finish = {server._klass_of(r): [] for r in resp}
    for r in resp:
        finish[server._klass_of(r)].append(r.queue_wait_s + r.service_s)
    assert max(finish["short"]) < min(finish["long"]), \
        "a long request finished before a short one under SJF"


def test_fcfs_interleaves(predictor):
    server = ClairvoyantServer(policy="fcfs", predictor=None)
    reqs = _mixed_requests()
    # long first in arrival order -> HOLB under FCFS
    order = [reqs[4], reqs[0], reqs[5], reqs[1]]
    for i, (prompt, toks, klass) in enumerate(order):
        server.submit(CompletionRequest(prompt=prompt), arrival=float(i) * 1e-3,
                      true_output_tokens=toks, klass=klass)
    resp = server.drain()
    shorts = [r for r in resp if server._klass_of(r) == "short"]
    assert min(s.queue_wait_s for s in shorts) > 0, \
        "FCFS should block shorts behind the long head-of-line job"


def test_disconnect_cancellation(predictor):
    server = ClairvoyantServer(policy="sjf", predictor=predictor)
    ids = []
    for prompt, toks, klass in _mixed_requests():
        req = CompletionRequest(prompt=prompt)
        # ids are now assigned by the server at admission (per-server space)
        server.submit(req, true_output_tokens=toks, klass=klass)
        ids.append(req.request_id)
    assert server.cancel(ids[0]) and server.cancel(ids[-1])
    assert not server.cancel(ids[0])        # double-cancel is a no-op
    resp = server.drain()
    # PR 6: cancelled requests now get a terminal "cancelled" response
    # instead of vanishing — no request is ever lost
    assert len(resp) == 8
    served = {r.request_id for r in resp if r.status == "ok"}
    assert ids[0] not in served and ids[-1] not in served
    assert len(served) == 6
    by_id = {r.request_id: r for r in resp}
    for rid in (ids[0], ids[-1]):
        assert by_id[rid].status == "cancelled"
        assert "disconnect" in by_id[rid].error


def test_router_jspw_balances_predicted_work():
    router = PredictiveRouter(n_replicas=3)
    rng = np.random.default_rng(0)
    for i in range(60):
        proba = rng.dirichlet((1, 1, 1))
        router.route(Request(req_id=i), proba=proba)
    sizes = list(router.queue_lengths().values())
    assert max(sizes) - min(sizes) <= 2, f"imbalanced: {sizes}"


def test_router_failover_requeues_all():
    router = PredictiveRouter(n_replicas=2)
    for i in range(10):
        router.route(Request(req_id=i))
    victim = max(router.queue_lengths(), key=router.queue_lengths().get)
    n_victim = router.queue_lengths()[victim]
    drained = router.fail_replica(victim)
    assert len(drained) == n_victim
    assert sum(router.queue_lengths().values()) == 10
    assert router.queue_lengths()[victim] == 0


def test_real_engine_generates():
    cfg = get_config("smollm-360m").reduced()
    eng = RealEngine(cfg, max_len=64)
    out = eng.generate(np.arange(8) % cfg.vocab_size, max_new_tokens=6)
    assert len(out["tokens"]) == 6
    assert all(0 <= t < cfg.vocab_size for t in out["tokens"])
    assert out["ttft_s"] > 0 and out["service_s"] >= out["ttft_s"]


def test_submit_many_matches_submit(predictor):
    """Batched admission (one proba_batch call) routes and scores exactly
    like per-request submit."""
    reqs = _mixed_requests()
    a = ClairvoyantServer(policy="sjf", predictor=predictor)
    b = ClairvoyantServer(policy="sjf", predictor=predictor)
    for i, (prompt, toks, klass) in enumerate(reqs):
        a.submit(CompletionRequest(prompt=prompt), arrival=i * 1e-3,
                 true_output_tokens=toks, klass=klass)
    b.submit_many([CompletionRequest(prompt=p) for p, _, _ in reqs],
                  arrivals=[i * 1e-3 for i in range(len(reqs))],
                  true_output_tokens=[t for _, t, _ in reqs],
                  klasses=[k for _, _, k in reqs])
    ra, rb = a.drain(), b.drain()
    assert [r.p_long for r in ra] == pytest.approx([r.p_long for r in rb])
    assert [r.sojourn_s for r in ra] == pytest.approx(
        [r.sojourn_s for r in rb])
    assert [r.klass for r in rb] == [r.klass for r in ra]


def test_server_drains_real_engine(predictor):
    """End-to-end: predictor -> SJF queue -> fused real decode.  Shorts
    dispatch before longs and every response carries real measured time."""
    # like the n=8 dispatch test: pick candidates the predictor separates
    pool = _mixed_requests(n_short=8, n_long=8)
    scores = predictor.p_long_batch([c[0] for c in pool])
    ranked = sorted(zip(pool, scores), key=lambda cs: cs[1])
    shorts = [c for c, _ in ranked if c[2] == "short"][:2]
    longs = [c for c, _ in reversed(ranked) if c[2] == "long"][:2]
    cands = shorts + longs
    cfg = get_config("smollm-360m").reduced()
    eng = RealEngine(cfg, max_len=96, segment_len=8)
    # compile prefill buckets + decode segment outside the measured drain
    for plen in (8, 24, 64):
        eng.generate(np.arange(plen) % cfg.vocab_size, max_new_tokens=9)
    server = ClairvoyantServer(policy="sjf", predictor=predictor,
                               engines=[eng])
    server.submit_many(
        [CompletionRequest(prompt=p) for p, _, _ in cands],
        true_output_tokens=[8 if k == "short" else 32
                            for _, _, k in cands],
        klasses=[k for _, _, k in cands])
    resp = server.drain(max_new_tokens=32)
    assert len(resp) == 4 and eng.served == 4 + 3   # 3 warm-up calls
    assert all(r.tokens_generated > 0 and r.service_s > 0 for r in resp)
    finish = {"short": [], "long": []}
    for r in resp:
        finish[r.klass].append(r.queue_wait_s + r.service_s)
    assert max(finish["short"]) < min(finish["long"])


def test_server_cancel_midflight_flags_engine():
    cfg = get_config("smollm-360m").reduced()
    eng = RealEngine(cfg, max_len=64)
    server = ClairvoyantServer(policy="fcfs", engines=[eng])
    server._decoding[0] = 42          # request 42 currently decoding
    assert server.cancel(42)
    assert eng._cancel, "mid-flight cancel must flag the fused loop"
    assert not server.cancel(43)


class _TwoClassPredictor:
    """Deterministic stand-in predictor: prompts starting with 'long' get
    P(Long)=1, everything else P(Long)=0 (isolates preemption logic from
    GBDT fidelity)."""

    def proba_batch(self, prompts):
        return np.array([[0.0, 0.0, 1.0] if p.startswith("long")
                         else [1.0, 0.0, 0.0] for p in prompts])

    def p_long_batch(self, prompts):
        return self.proba_batch(prompts)[:, 2]


def test_sim_drain_preemptive_srpt_rescues_shorts():
    """Virtual-time drain under SRPT: the long arrives first and is
    decoding when the shorts (virtually) arrive; SRPT slices its service
    at their arrival events, so short sojourns shrink vs FCFS, which
    serves the head-of-line long to completion."""
    def build(policy):
        server = ClairvoyantServer(policy=policy,
                                   predictor=_TwoClassPredictor())
        server.submit(CompletionRequest(prompt="long " + "x " * 40),
                      arrival=0.0, true_output_tokens=600, klass="long")
        for i in range(3):
            server.submit(CompletionRequest(prompt="quick question"),
                          arrival=1.0 + 0.1 * i, true_output_tokens=30,
                          klass="short")
        return server, server.drain()
    _, fcfs = build("fcfs")
    srv, srpt = build("srpt")
    fcfs_short = [r.queue_wait_s + r.service_s for r in fcfs
                  if r.klass == "short"]
    srpt_short = [r.queue_wait_s + r.service_s for r in srpt
                  if r.klass == "short"]
    assert np.median(srpt_short) < np.median(fcfs_short)
    assert len(srpt) == len(fcfs) == 4
    # the arriving shorts actually preempted the in-service long
    assert srv.router.replicas[0].queue.stats["preemptions"] >= 1
    # work conservation: the long started first yet completes last
    by_klass = {r.klass: r for r in srpt}
    assert by_klass["long"].queue_wait_s == 0.0
    assert max((r.queue_wait_s + r.service_s, r.klass)
               for r in srpt)[1] == "long"


def test_real_engine_preemption_resumes_bitwise():
    """Live preemption (§3.4 + cheap re-prefill resume): a short arriving
    mid-decode evicts the long at a segment boundary; the long resumes by
    re-prefilling prompt + generated prefix, and its final token sequence
    is bitwise-identical to an uninterrupted decode."""
    cfg = get_config("smollm-360m").reduced()
    eng = RealEngine(cfg, max_len=96, segment_len=4)

    # engine-level resume equivalence: interrupt once, re-prefill with the
    # generated prefix, concatenate — must equal the uninterrupted decode
    ids = np.arange(8) % cfg.vocab_size
    full = eng.generate(ids, max_new_tokens=16)["tokens"]
    polls = []

    def cancel_after_one_segment():
        polls.append(1)
        return len(polls) == 2

    out1 = eng.generate(ids, max_new_tokens=16,
                        cancel_cb=cancel_after_one_segment)
    assert out1["cancelled"] and 1 <= len(out1["tokens"]) < 16
    resumed_ids = np.concatenate([ids, np.asarray(out1["tokens"])])
    out2 = eng.generate(resumed_ids,
                        max_new_tokens=16 - len(out1["tokens"]))
    assert list(out1["tokens"]) + list(out2["tokens"]) == list(full)

    # server-level: the short evicts the decoding long and finishes first
    server = ClairvoyantServer(policy="srpt",
                               predictor=_TwoClassPredictor(),
                               engines=[eng])
    long_req = CompletionRequest(prompt="long story please")
    short_req = CompletionRequest(prompt="quick question")
    server.submit(long_req, arrival=0.0, true_output_tokens=600,
                  klass="long")
    # arrives (virtually) almost immediately: any wall-clock progress on
    # the long's decode makes it eligible to preempt
    server.submit(short_req, arrival=1e-6, true_output_tokens=30,
                  klass="short")
    resp = server.drain(max_new_tokens=24)
    assert len(resp) == 2
    rep = server.router.replicas[0]
    assert rep.queue.stats["preemptions"] >= 1
    assert resp[0].request_id == short_req.request_id
    by_id = {r.request_id: r for r in resp}
    # the long's full token budget was still generated across its slices
    assert by_id[long_req.request_id].tokens_generated == 24
    assert by_id[long_req.request_id].service_s > 0


def test_service_time_model_monotone():
    cfg = get_config("gemma3-4b-edge")
    m = ServiceTimeModel.from_arch(cfg, chips=1)
    assert m.service(64, 800) > m.service(64, 100) > m.service(64, 10)
    assert m.service(1024, 100) > m.service(64, 100)
