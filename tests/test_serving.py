"""Serving integration: the paper's n=8 dispatch-order test, disconnects,
routing, failover, real-engine decode."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.gbdt import GBDTParams
from repro.core.predictor import Predictor
from repro.core.router import PredictiveRouter
from repro.core.scheduler import Request
from repro.data.corpus import sample_dataset
from repro.serving.engine import RealEngine
from repro.serving.openai_api import CompletionRequest
from repro.serving.server import ClairvoyantServer
from repro.serving.service_time import ServiceTimeModel


@pytest.fixture(scope="module")
def predictor():
    ds = sample_dataset("sharegpt", n=2400, seed=42, balanced=True)
    return Predictor.train(ds.prompts, ds.lengths, GBDTParams(num_rounds=60))


def _mixed_requests(n_short=4, n_long=4, seed=0):
    """4 Short + 4 Long real prompts (the paper's M1 end-to-end test)."""
    ds = sample_dataset("sharegpt", n=4000, seed=seed)
    shorts = [i for i in range(len(ds)) if ds.lengths[i] < 120][:n_short]
    longs = [i for i in range(len(ds)) if ds.lengths[i] >= 1000][:n_long]
    return ([(ds.prompts[i], int(ds.lengths[i]), "short") for i in shorts]
            + [(ds.prompts[i], int(ds.lengths[i]), "long") for i in longs])


def test_sjf_dispatch_order_end_to_end(predictor):
    """Paper §3.4: n=8 burst — all Short complete before any Long.

    Like the paper's test (dispatch-LOGIC validation), the 8 requests are
    drawn so the predictor separates them; cross-class fidelity on arbitrary
    prompts is measured by the ranking benchmarks, not here.
    """
    cands = _mixed_requests(n_short=12, n_long=12)
    scores = predictor.p_long_batch([c[0] for c in cands])
    shorts = sorted((c for c, s in zip(cands, scores) if c[2] == "short"),
                    key=lambda c: scores[cands.index(c)])[:4]
    longs = sorted((c for c, s in zip(cands, scores) if c[2] == "long"),
                   key=lambda c: -scores[cands.index(c)])[:4]
    server = ClairvoyantServer(policy="sjf", tau=None, predictor=predictor)
    for prompt, toks, klass in shorts + longs:
        server.submit(CompletionRequest(prompt=prompt), arrival=0.0,
                      true_output_tokens=toks, klass=klass)
    resp = server.drain()
    finish = {server._klass_of(r): [] for r in resp}
    for r in resp:
        finish[server._klass_of(r)].append(r.queue_wait_s + r.service_s)
    assert max(finish["short"]) < min(finish["long"]), \
        "a long request finished before a short one under SJF"


def test_fcfs_interleaves(predictor):
    server = ClairvoyantServer(policy="fcfs", predictor=None)
    reqs = _mixed_requests()
    # long first in arrival order -> HOLB under FCFS
    order = [reqs[4], reqs[0], reqs[5], reqs[1]]
    for i, (prompt, toks, klass) in enumerate(order):
        server.submit(CompletionRequest(prompt=prompt), arrival=float(i) * 1e-3,
                      true_output_tokens=toks, klass=klass)
    resp = server.drain()
    shorts = [r for r in resp if server._klass_of(r) == "short"]
    assert min(s.queue_wait_s for s in shorts) > 0, \
        "FCFS should block shorts behind the long head-of-line job"


def test_disconnect_cancellation(predictor):
    server = ClairvoyantServer(policy="sjf", predictor=predictor)
    ids = []
    for prompt, toks, klass in _mixed_requests():
        req = CompletionRequest(prompt=prompt)
        ids.append(req.request_id)
        server.submit(req, true_output_tokens=toks, klass=klass)
    assert server.cancel(ids[0]) and server.cancel(ids[-1])
    assert not server.cancel(ids[0])        # double-cancel is a no-op
    resp = server.drain()
    served = {r.request_id for r in resp}
    assert ids[0] not in served and ids[-1] not in served
    assert len(served) == 6


def test_router_jspw_balances_predicted_work():
    router = PredictiveRouter(n_replicas=3)
    rng = np.random.default_rng(0)
    for i in range(60):
        proba = rng.dirichlet((1, 1, 1))
        router.route(Request(req_id=i), proba=proba)
    sizes = list(router.queue_lengths().values())
    assert max(sizes) - min(sizes) <= 2, f"imbalanced: {sizes}"


def test_router_failover_requeues_all():
    router = PredictiveRouter(n_replicas=2)
    for i in range(10):
        router.route(Request(req_id=i))
    victim = max(router.queue_lengths(), key=router.queue_lengths().get)
    n_victim = router.queue_lengths()[victim]
    drained = router.fail_replica(victim)
    assert len(drained) == n_victim
    assert sum(router.queue_lengths().values()) == 10
    assert router.queue_lengths()[victim] == 0


def test_real_engine_generates():
    cfg = get_config("smollm-360m").reduced()
    eng = RealEngine(cfg, max_len=64)
    out = eng.generate(np.arange(8) % cfg.vocab_size, max_new_tokens=6)
    assert len(out["tokens"]) == 6
    assert all(0 <= t < cfg.vocab_size for t in out["tokens"])
    assert out["ttft_s"] > 0 and out["service_s"] >= out["ttft_s"]


def test_service_time_model_monotone():
    cfg = get_config("gemma3-4b-edge")
    m = ServiceTimeModel.from_arch(cfg, chips=1)
    assert m.service(64, 800) > m.service(64, 100) > m.service(64, 10)
    assert m.service(1024, 100) > m.service(64, 100)
