"""Scheduler unit + property tests: heap invariants, SJF ordering,
starvation bound, cancellation, conservation.

Property tests use seeded ``np.random.default_rng`` loops (this container
has no hypothesis package).
"""

import numpy as np
import pytest

from repro.core.scheduler import MinHeap, Request, SJFQueue


# --------------------------------------------------------------- MinHeap
def test_heap_pops_sorted():
    rng = np.random.default_rng(0)
    for trial in range(40):
        n = int(rng.integers(0, 200))
        keys = rng.normal(0, 1e3, n).astype(np.float32).tolist()
        h = MinHeap()
        for i, k in enumerate(keys):
            h.push(k, i, None)
            assert h.invariant_ok()
        out = [h.pop()[0] for _ in range(len(keys))]
        assert out == sorted(out)


def test_heap_fifo_tiebreak():
    rng = np.random.default_rng(1)
    for trial in range(40):
        n = int(rng.integers(2, 100))
        keys = rng.integers(0, 6, n).tolist()
        h = MinHeap()
        for i, k in enumerate(keys):
            h.push(k, i, i)
        prev = {}
        while len(h):
            k, seq, _ = h.pop()
            if k in prev:
                assert seq > prev[k], "equal keys must pop in FIFO order"
            prev[k] = seq


# --------------------------------------------------------------- SJFQueue
def _mk(i, arrival=0.0, p_long=0.5, service=1.0):
    return Request(req_id=i, arrival=arrival, p_long=p_long,
                   true_service=service)


def test_sjf_orders_by_p_long():
    q = SJFQueue(policy="sjf")
    for i, p in enumerate([0.9, 0.1, 0.5, 0.3]):
        q.push(_mk(i, p_long=p))
    order = [q.pop(now=0.0).p_long for _ in range(4)]
    assert order == sorted(order)


def test_fcfs_orders_by_arrival():
    q = SJFQueue(policy="fcfs")
    for i, a in enumerate([3.0, 1.0, 2.0]):
        q.push(_mk(i, arrival=a, p_long=1 - a))
    order = [q.pop(now=10.0).arrival for _ in range(3)]
    assert order == sorted(order)


def test_starvation_promotion():
    q = SJFQueue(policy="sjf", tau=5.0)
    q.push(_mk(0, arrival=0.0, p_long=0.99))   # long job, would starve
    q.push(_mk(1, arrival=4.0, p_long=0.01))
    # at t=6 the long job has waited 6 > tau -> promoted despite p_long
    got = q.pop(now=6.0)
    assert got.req_id == 0 and got.promoted
    assert q.stats["promotions"] == 1


def test_no_promotion_below_tau():
    q = SJFQueue(policy="sjf", tau=10.0)
    q.push(_mk(0, arrival=0.0, p_long=0.99))
    q.push(_mk(1, arrival=4.0, p_long=0.01))
    assert q.pop(now=6.0).req_id == 1  # SJF order holds


def test_cancellation_is_lazy_and_complete():
    q = SJFQueue(policy="sjf")
    for i in range(5):
        q.push(_mk(i, p_long=i / 10))
    assert q.cancel(0) and q.cancel(3)
    assert not q.cancel(99)
    got = [q.pop(now=0.0).req_id for _ in range(len(q))]
    assert got == [1, 2, 4]
    assert q.pop(now=0.0) is None


def test_mass_cancellation_tombstones_and_promotion():
    """Tombstone/promotion interaction: cancel most of a large queue
    (including every older request), then pop with the starvation guard
    armed.  The guard must skip cancelled FIFO entries, promote the
    oldest *live* waiter, and never dispatch a tombstone."""
    q = SJFQueue(policy="sjf", tau=5.0)
    n = 200
    for i in range(n):
        # older requests get low p_long so SJF would prefer them
        q.push(_mk(i, arrival=float(i) * 0.01, p_long=i / n))
    # cancel everything except two high-p_long stragglers
    keep = {150, 199}
    for i in range(n):
        if i not in keep:
            assert q.cancel(i)
    assert len(q) == 2
    assert q.stats["cancellations"] == n - 2
    # tau exceeded for req 150 (arrival 1.5) at now=100 -> promoted
    got = q.pop(now=100.0)
    assert got.req_id == 150 and got.promoted and not got.cancelled
    # next pop drains the heap past all tombstones to the last live entry
    got2 = q.pop(now=100.0)
    assert got2.req_id == 199 and not got2.cancelled
    assert q.pop(now=100.0) is None
    assert q.stats["dispatched"] == 2
    # cancelling after dispatch is a no-op
    assert not q.cancel(150)


def test_conservation_every_request_dispatched_once():
    """No request is lost or duplicated, under any policy/tau."""
    rng = np.random.default_rng(2)
    for trial in range(50):
        n = int(rng.integers(1, 80))
        policy = ["fcfs", "sjf", "sjf_oracle"][int(rng.integers(0, 3))]
        tau = None if rng.random() < 0.3 else float(rng.uniform(0.5, 50))
        q = SJFQueue(policy=policy, tau=tau)
        for i in range(n):
            p = float(rng.random())
            q.push(Request(req_id=i, arrival=float(rng.uniform(0, 100)),
                           p_long=p, true_service=p))
        seen = set()
        t = 0.0
        while True:
            r = q.pop(now=t)
            if r is None:
                break
            assert r.req_id not in seen
            seen.add(r.req_id)
            t += 1.0
        assert seen == set(range(n))


def test_starvation_wait_bound():
    """With the guard on, at every dispatch decision the oldest waiter is
    dispatched if it exceeded tau — so queue wait beyond tau never grows by
    more than one service slot per dispatch."""
    rng = np.random.default_rng(0)
    for trial in range(30):
        n = int(rng.integers(1, 60))
        tau = float(rng.uniform(1.0, 10.0))
        q = SJFQueue(policy="sjf", tau=tau)
        for i in range(n):
            q.push(Request(req_id=i, arrival=0.0,
                           p_long=float(rng.random()), true_service=1.0))
        t = 0.0
        while True:
            oldest = q.oldest_wait(now=t)
            r = q.pop(now=t)
            if r is None:
                break
            if oldest > tau:
                # guard must fire for the longest-waiting request
                assert r.promoted or (t - r.arrival) >= tau
            t += 1.0
