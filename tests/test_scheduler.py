"""Scheduler unit + property tests: heap invariants, SJF ordering,
starvation bound, cancellation, conservation."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.scheduler import MinHeap, Request, SJFQueue


# --------------------------------------------------------------- MinHeap
@given(st.lists(st.floats(allow_nan=False, allow_infinity=False,
                          width=32), max_size=200))
def test_heap_pops_sorted(keys):
    h = MinHeap()
    for i, k in enumerate(keys):
        h.push(k, i, None)
        assert h.invariant_ok()
    out = [h.pop()[0] for _ in range(len(keys))]
    assert out == sorted(out)


@given(st.lists(st.integers(0, 5), min_size=2, max_size=100))
def test_heap_fifo_tiebreak(keys):
    h = MinHeap()
    for i, k in enumerate(keys):
        h.push(k, i, i)
    prev = {}
    while len(h):
        k, seq, _ = h.pop()
        if k in prev:
            assert seq > prev[k], "equal keys must pop in FIFO order"
        prev[k] = seq


# --------------------------------------------------------------- SJFQueue
def _mk(i, arrival=0.0, p_long=0.5, service=1.0):
    return Request(req_id=i, arrival=arrival, p_long=p_long,
                   true_service=service)


def test_sjf_orders_by_p_long():
    q = SJFQueue(policy="sjf")
    for i, p in enumerate([0.9, 0.1, 0.5, 0.3]):
        q.push(_mk(i, p_long=p))
    order = [q.pop(now=0.0).p_long for _ in range(4)]
    assert order == sorted(order)


def test_fcfs_orders_by_arrival():
    q = SJFQueue(policy="fcfs")
    for i, a in enumerate([3.0, 1.0, 2.0]):
        q.push(_mk(i, arrival=a, p_long=1 - a))
    order = [q.pop(now=10.0).arrival for _ in range(3)]
    assert order == sorted(order)


def test_starvation_promotion():
    q = SJFQueue(policy="sjf", tau=5.0)
    q.push(_mk(0, arrival=0.0, p_long=0.99))   # long job, would starve
    q.push(_mk(1, arrival=4.0, p_long=0.01))
    # at t=6 the long job has waited 6 > tau -> promoted despite p_long
    got = q.pop(now=6.0)
    assert got.req_id == 0 and got.promoted
    assert q.stats["promotions"] == 1


def test_no_promotion_below_tau():
    q = SJFQueue(policy="sjf", tau=10.0)
    q.push(_mk(0, arrival=0.0, p_long=0.99))
    q.push(_mk(1, arrival=4.0, p_long=0.01))
    assert q.pop(now=6.0).req_id == 1  # SJF order holds


def test_cancellation_is_lazy_and_complete():
    q = SJFQueue(policy="sjf")
    for i in range(5):
        q.push(_mk(i, p_long=i / 10))
    assert q.cancel(0) and q.cancel(3)
    assert not q.cancel(99)
    got = [q.pop(now=0.0).req_id for _ in range(len(q))]
    assert got == [1, 2, 4]
    assert q.pop(now=0.0) is None


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 1), st.floats(0, 100)),
                min_size=1, max_size=80),
       st.sampled_from(["fcfs", "sjf", "sjf_oracle"]),
       st.one_of(st.none(), st.floats(0.5, 50)))
def test_conservation_every_request_dispatched_once(entries, policy, tau):
    """No request is lost or duplicated, under any policy/tau."""
    q = SJFQueue(policy=policy, tau=tau)
    for i, (p, a) in enumerate(entries):
        q.push(Request(req_id=i, arrival=a, p_long=p, true_service=p))
    seen = set()
    t = 0.0
    while True:
        r = q.pop(now=t)
        if r is None:
            break
        assert r.req_id not in seen
        seen.add(r.req_id)
        t += 1.0
    assert seen == set(range(len(entries)))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 60), st.floats(1.0, 10.0))
def test_starvation_wait_bound(n, tau):
    """With the guard on, at every dispatch decision the oldest waiter is
    dispatched if it exceeded tau — so queue wait beyond tau never grows by
    more than one service slot per dispatch."""
    rng = np.random.default_rng(0)
    q = SJFQueue(policy="sjf", tau=tau)
    for i in range(n):
        q.push(Request(req_id=i, arrival=0.0, p_long=float(rng.random()),
                       true_service=1.0))
    t = 0.0
    while True:
        oldest = q.oldest_wait(now=t)
        r = q.pop(now=t)
        if r is None:
            break
        if oldest > tau:
            # guard must fire for the longest-waiting request
            assert r.promoted or (t - r.arrival) >= tau
        t += 1.0
