"""Scheduler unit + property tests: heap invariants, SJF ordering,
starvation bound, cancellation, conservation.

Property tests use seeded ``np.random.default_rng`` loops (this container
has no hypothesis package).
"""

import numpy as np
import pytest

from repro.core.scheduler import ArrayHeap, MinHeap, Request, SJFQueue


# --------------------------------------------------------------- MinHeap
def test_heap_pops_sorted():
    rng = np.random.default_rng(0)
    for trial in range(40):
        n = int(rng.integers(0, 200))
        keys = rng.normal(0, 1e3, n).astype(np.float32).tolist()
        h = MinHeap()
        for i, k in enumerate(keys):
            h.push(k, i, None)
            assert h.invariant_ok()
        out = [h.pop()[0] for _ in range(len(keys))]
        assert out == sorted(out)


def test_heap_fifo_tiebreak():
    rng = np.random.default_rng(1)
    for trial in range(40):
        n = int(rng.integers(2, 100))
        keys = rng.integers(0, 6, n).tolist()
        h = MinHeap()
        for i, k in enumerate(keys):
            h.push(k, i, i)
        prev = {}
        while len(h):
            k, seq, _ = h.pop()
            if k in prev:
                assert seq > prev[k], "equal keys must pop in FIFO order"
            prev[k] = seq


# --------------------------------------------------------------- ArrayHeap
def test_array_heap_pops_sorted_with_fifo_tiebreak():
    rng = np.random.default_rng(5)
    for trial in range(30):
        n = int(rng.integers(1, 200))
        keys = rng.integers(0, 8, n).astype(float).tolist()
        h = ArrayHeap()
        for i, k in enumerate(keys):
            h.push(k, i, i)
            assert h.invariant_ok()
        out = [h.pop() for _ in range(len(h))]
        assert [k for k, _, _ in out] == sorted(keys)
        prev = {}
        for k, seq, _ in out:
            if k in prev:
                assert seq > prev[k], "equal keys must pop FIFO"
            prev[k] = seq
        with pytest.raises(IndexError):
            h.pop()


def test_array_heap_kill_is_lazy_and_compacts():
    rng = np.random.default_rng(6)
    for trial in range(20):
        n = int(rng.integers(40, 300))
        keys = rng.normal(0, 10, n).tolist()
        h = ArrayHeap()
        for i, k in enumerate(keys):
            h.push(k, i, i)
        dead = set(int(i) for i in
                   rng.choice(n, size=int(rng.integers(1, n)), replace=False))
        for i in dead:
            assert h.kill(i)
            assert not h.kill(i)          # double-kill is a no-op
        assert len(h) == n - len(dead)
        assert h.invariant_ok()           # compaction keeps the heap valid
        out = [h.pop() for _ in range(len(h))]
        assert {i for _, _, i in out} == set(range(n)) - dead
        assert [k for k, _, _ in out] == sorted(k for i, k in enumerate(keys)
                                                if i not in dead)


def test_array_heap_interleaved_push_kill_pop():
    rng = np.random.default_rng(7)
    h = ArrayHeap()
    live = {}
    next_id = 0
    popped = []
    for step in range(3000):
        op = rng.random()
        if op < 0.5 or not live:
            h.push(float(rng.integers(0, 50)), next_id, next_id)
            live[next_id] = True
            next_id += 1
        elif op < 0.75:
            victim = int(rng.choice(list(live)))
            assert h.kill(victim)
            del live[victim]
        else:
            k, _, i = h.pop()
            assert i in live
            del live[i]
            popped.append((k, i))
        assert len(h) == len(live)
    assert h.invariant_ok()


# --------------------------------------------------------------- SJFQueue
def _mk(i, arrival=0.0, p_long=0.5, service=1.0):
    return Request(req_id=i, arrival=arrival, p_long=p_long,
                   true_service=service)


def test_sjf_orders_by_p_long():
    q = SJFQueue(policy="sjf")
    for i, p in enumerate([0.9, 0.1, 0.5, 0.3]):
        q.push(_mk(i, p_long=p))
    order = [q.pop(now=0.0).p_long for _ in range(4)]
    assert order == sorted(order)


def test_fcfs_orders_by_arrival():
    q = SJFQueue(policy="fcfs")
    for i, a in enumerate([3.0, 1.0, 2.0]):
        q.push(_mk(i, arrival=a, p_long=1 - a))
    order = [q.pop(now=10.0).arrival for _ in range(3)]
    assert order == sorted(order)


def test_starvation_promotion():
    q = SJFQueue(policy="sjf", tau=5.0)
    q.push(_mk(0, arrival=0.0, p_long=0.99))   # long job, would starve
    q.push(_mk(1, arrival=4.0, p_long=0.01))
    # at t=6 the long job has waited 6 > tau -> promoted despite p_long
    got = q.pop(now=6.0)
    assert got.req_id == 0 and got.promoted
    assert q.stats["promotions"] == 1


def test_no_promotion_below_tau():
    q = SJFQueue(policy="sjf", tau=10.0)
    q.push(_mk(0, arrival=0.0, p_long=0.99))
    q.push(_mk(1, arrival=4.0, p_long=0.01))
    assert q.pop(now=6.0).req_id == 1  # SJF order holds


def _lane_backfill_queue(tau=5.0):
    q = SJFQueue(policy="sjf", tau=tau)
    q.push(_mk(0, arrival=0.0, p_long=0.99))   # oldest, worst key
    q.push(_mk(1, arrival=4.0, p_long=0.01))
    q.push(_mk(2, arrival=4.5, p_long=0.02))
    q.push(_mk(3, arrival=4.6, p_long=0.03))
    return q


def test_pop_many_matches_sequential_pops():
    """pop_many(k) must equal k sequential pops — the starvation guard is
    re-evaluated between pops, so a promoted waiter claims the next lane."""
    a = _lane_backfill_queue()
    b = _lane_backfill_queue()
    got = [r.req_id for r in a.pop_many(4, now=6.0)]
    want = [b.pop(now=6.0).req_id for _ in range(4)]
    assert got == want
    # at t=6 the aged long job (wait 6 > tau=5) heads the batch
    assert got[0] == 0 and a.stats["promotions"] == 1


def test_pop_many_observes_promotions_between_pops():
    """Regression against the naive batched back-fill (heap top-k in one
    go): with tau=5.5 the guard does NOT fire for the first pop (wait
    5.0 <= tau) but MUST fire for a later one once only the aged request
    remains over tau — the naive key order [1, 2, 3, 0] is wrong."""
    q = _lane_backfill_queue(tau=5.5)
    naive = sorted([0, 1, 2, 3],
                   key=lambda i: [0.99, 0.01, 0.02, 0.03][i])
    got = [r.req_id for r in q.pop_many(4, now=5.0)]
    assert got == [1, 2, 3, 0] == naive  # tau never crossed at now=5.0
    q2 = _lane_backfill_queue(tau=5.5)
    got2 = [r.req_id for r in q2.pop_many(4, now=5.6)]
    # wait(req 0) = 5.6 > tau at every decision: promoted to the head
    assert got2 == [0, 1, 2, 3] and q2.stats["promotions"] == 1


def test_pop_many_stops_at_empty_queue():
    q = _lane_backfill_queue()
    assert len(q.pop_many(10, now=0.0)) == 4
    assert q.pop_many(3, now=0.0) == []


def test_cancellation_is_lazy_and_complete():
    q = SJFQueue(policy="sjf")
    for i in range(5):
        q.push(_mk(i, p_long=i / 10))
    assert q.cancel(0) and q.cancel(3)
    assert not q.cancel(99)
    got = [q.pop(now=0.0).req_id for _ in range(len(q))]
    assert got == [1, 2, 4]
    assert q.pop(now=0.0) is None


def test_mass_cancellation_tombstones_and_promotion():
    """Tombstone/promotion interaction: cancel most of a large queue
    (including every older request), then pop with the starvation guard
    armed.  The guard must skip cancelled FIFO entries, promote the
    oldest *live* waiter, and never dispatch a tombstone."""
    q = SJFQueue(policy="sjf", tau=5.0)
    n = 200
    for i in range(n):
        # older requests get low p_long so SJF would prefer them
        q.push(_mk(i, arrival=float(i) * 0.01, p_long=i / n))
    # cancel everything except two high-p_long stragglers
    keep = {150, 199}
    for i in range(n):
        if i not in keep:
            assert q.cancel(i)
    assert len(q) == 2
    assert q.stats["cancellations"] == n - 2
    # tau exceeded for req 150 (arrival 1.5) at now=100 -> promoted
    got = q.pop(now=100.0)
    assert got.req_id == 150 and got.promoted and not got.cancelled
    # next pop drains the heap past all tombstones to the last live entry
    got2 = q.pop(now=100.0)
    assert got2.req_id == 199 and not got2.cancelled
    assert q.pop(now=100.0) is None
    assert q.stats["dispatched"] == 2
    # cancelling after dispatch is a no-op
    assert not q.cancel(150)


def test_cancel_then_repush_same_req_id():
    """A client retry after disconnect reuses its req_id: the queue must
    accept the re-push (evicting the heap tombstone) and dispatch the
    retried request once."""
    q = SJFQueue(policy="sjf")
    q.push(_mk(0, p_long=0.2))
    q.push(_mk(1, p_long=0.5))
    assert q.cancel(0)
    q.push(_mk(0, p_long=0.9))               # retry, worse priority now
    assert len(q) == 2
    got = [q.pop(now=0.0).req_id for _ in range(2)]
    assert got == [1, 0]
    assert q.pop(now=0.0) is None
    assert q.stats["dispatched"] == 2 and q.stats["cancellations"] == 1
    h = ArrayHeap()
    h.push(1.0, 0, 7)
    with pytest.raises(ValueError):          # live duplicates still rejected
        h.push(2.0, 1, 7)


def test_promotion_fifo_order_under_simultaneous_arrivals():
    """Equal arrival times: the guard promotes in push (seq) order, not by
    p_long — the FIFO is the tie-break, matching the simulation engines."""
    q = SJFQueue(policy="sjf", tau=1.0)
    for i, p in enumerate([0.9, 0.5, 0.7, 0.2]):
        q.push(_mk(i, arrival=0.0, p_long=p))
    got = [q.pop(now=10.0).req_id for _ in range(4)]
    assert got == [0, 1, 2, 3]              # all starving -> pure FIFO
    assert q.stats["promotions"] == 4
    assert all(r == i for i, r in enumerate(got))


def test_tau_zero_promotes_any_positive_wait():
    """tau=0 is a valid guard (not falsy-None): strictly positive wait
    promotes; zero wait does not."""
    q = SJFQueue(policy="sjf", tau=0.0)
    q.push(_mk(0, arrival=0.0, p_long=0.9))
    q.push(_mk(1, arrival=0.0, p_long=0.1))
    # at now=0 the wait is exactly 0, NOT > tau: SJF order applies
    assert q.pop(now=0.0).req_id == 1
    assert q.stats["promotions"] == 0
    # any positive wait now promotes the survivor
    got = q.pop(now=1e-9)
    assert got.req_id == 0 and got.promoted
    assert q.stats["promotions"] == 1


def test_promotion_skips_tombstoned_fifo_head():
    """Cancel the oldest waiter, then pop with the guard armed: the guard
    must skip the tombstone and promote the oldest LIVE request, and the
    cancelled request must never be dispatched."""
    q = SJFQueue(policy="sjf", tau=2.0)
    q.push(_mk(0, arrival=0.0, p_long=0.4))   # oldest; will be cancelled
    q.push(_mk(1, arrival=1.0, p_long=0.8))   # oldest live -> promoted
    q.push(_mk(2, arrival=9.0, p_long=0.1))   # below tau, better p_long
    assert q.cancel(0)
    got = q.pop(now=10.0)
    assert got.req_id == 1 and got.promoted
    # the heap tombstone of req 0 must be skipped on the next pop too
    assert q.pop(now=10.2).req_id == 2
    assert q.pop(now=10.4) is None
    assert q.stats == {"promotions": 1, "cancellations": 1, "dispatched": 2,
                       "preemptions": 0, "requeues": 0}


def test_conservation_every_request_dispatched_once():
    """No request is lost or duplicated, under any policy/tau."""
    rng = np.random.default_rng(2)
    for trial in range(50):
        n = int(rng.integers(1, 80))
        policy = ["fcfs", "sjf", "sjf_oracle"][int(rng.integers(0, 3))]
        tau = None if rng.random() < 0.3 else float(rng.uniform(0.5, 50))
        q = SJFQueue(policy=policy, tau=tau)
        for i in range(n):
            p = float(rng.random())
            q.push(Request(req_id=i, arrival=float(rng.uniform(0, 100)),
                           p_long=p, true_service=p))
        seen = set()
        t = 0.0
        while True:
            r = q.pop(now=t)
            if r is None:
                break
            assert r.req_id not in seen
            seen.add(r.req_id)
            t += 1.0
        assert seen == set(range(n))


def test_starvation_wait_bound():
    """With the guard on, at every dispatch decision the oldest waiter is
    dispatched if it exceeded tau — so queue wait beyond tau never grows by
    more than one service slot per dispatch."""
    rng = np.random.default_rng(0)
    for trial in range(30):
        n = int(rng.integers(1, 60))
        tau = float(rng.uniform(1.0, 10.0))
        q = SJFQueue(policy="sjf", tau=tau)
        for i in range(n):
            q.push(Request(req_id=i, arrival=0.0,
                           p_long=float(rng.random()), true_service=1.0))
        t = 0.0
        while True:
            oldest = q.oldest_wait(now=t)
            r = q.pop(now=t)
            if r is None:
                break
            if oldest > tau:
                # guard must fire for the longest-waiting request
                assert r.promoted or (t - r.arrival) >= tau
            t += 1.0
