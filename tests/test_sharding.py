"""Sharding rules: divisibility fallback, spec resolution, constraint no-op."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.sharding import constrain, use_mesh
from repro.sharding.rules import DEFAULT_RULES, is_axes_leaf, spec_for


def _mesh22():
    # 4 fake CPU devices would be needed; tests run on 1, so synthesize specs
    # against an abstract mesh via jax.make_mesh on the single device when
    # possible, else build spec logic directly.
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_divisible():
    mesh = _mesh22()
    spec = spec_for((32, 64), ("batch", "mlp"), mesh)
    # axes of size 1 shard trivially; canonical trailing-None trimming
    assert isinstance(spec, P)


def test_divisibility_fallback_drops_axis():
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))
    spec = spec_for((15, 64), ("heads", "head_dim"), FakeMesh)
    assert spec == P()  # 15 heads not divisible by 16 -> unsharded


def test_composite_batch_axes():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        devices = np.empty((2, 16, 16))
    spec = spec_for((256, 4096), ("batch", "seq"), FakeMesh)
    assert spec == P(("pod", "data"))
    # batch=1 (long_500k decode): everything falls back
    spec1 = spec_for((1, 4096), ("batch", "seq"), FakeMesh)
    assert spec1 == P()


def test_axis_used_once_per_tensor():
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))
    # both logical axes map to "model": first wins, second falls back
    spec = spec_for((64, 64), ("heads", "mlp"), FakeMesh)
    assert spec == P("model")


def test_kv_cache_spec_seq_sharded():
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))
    spec = spec_for((4, 128, 32768, 8, 128),
                    (None, "batch", "kv_seq", "kv_heads", "head_dim"),
                    FakeMesh)
    assert spec == P(None, "data", "model")


def test_constrain_is_identity_off_mesh():
    x = jnp.ones((4, 4))
    assert constrain(x, "batch", "embed") is x


def test_constrain_inside_jit_single_device_mesh():
    mesh = _mesh22()
    with use_mesh(mesh):
        y = jax.jit(lambda x: constrain(x, "batch", "embed"))(jnp.ones((4, 4)))
    np.testing.assert_array_equal(np.asarray(y), np.ones((4, 4)))


def test_is_axes_leaf():
    assert is_axes_leaf(("embed_w", "qkv"))
    assert is_axes_leaf((None, "batch"))
    assert is_axes_leaf(())
    assert not is_axes_leaf(({"a": 1},))
    assert not is_axes_leaf([1, 2])


def test_param_axes_cover_param_tree():
    """Every param leaf has an axes annotation of matching rank."""
    from repro.configs import ARCH_NAMES, get_config
    from repro.models.model import LM
    for name in ARCH_NAMES:
        lm = LM(get_config(name).reduced())
        shapes, axes = lm.abstract_params()
        jax.tree.map(
            lambda s, a: (_ for _ in ()).throw(
                AssertionError(f"{name}: rank mismatch {s.shape} vs {a}"))
            if len(s.shape) != len(a) else None,
            shapes, axes, is_leaf=lambda x: hasattr(x, "shape"))
