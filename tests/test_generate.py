"""Fused on-device generation: bitwise equivalence against the seed
per-token loop, ring-buffer KV cache semantics, bucketed prefill, and
mid-generation cancellation (PR 3 tentpole)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import LM
from repro.serving.engine import RealEngine
from repro.serving.generate import (FusedDecoder, bucket_for,
                                    geometric_buckets)


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("smollm-360m").reduced()
    return RealEngine(cfg, max_len=96, segment_len=8)


# ---------------------------------------------------------------- equivalence

@pytest.mark.parametrize("plen", [1, 3, 8, 17, 33, 64])
def test_fused_matches_oracle_bitwise(engine, plen):
    """Fused scan decode == retained Python-loop oracle, token for token."""
    rng = np.random.default_rng(plen)
    ids = rng.integers(0, engine.cfg.vocab_size, plen)
    fused = engine.generate(ids, max_new_tokens=24)
    seed = engine.generate_reference(ids, max_new_tokens=24)
    assert fused["tokens"] == seed["tokens"]
    assert len(fused["tokens"]) == 24
    assert not fused["cancelled"]


def test_fused_eos_early_exit(engine):
    rng = np.random.default_rng(7)
    ids = rng.integers(0, engine.cfg.vocab_size, 10)
    ref = engine.generate_reference(ids, max_new_tokens=24)
    eos = ref["tokens"][5]            # a token the greedy path will emit
    fused = engine.generate(ids, max_new_tokens=24, eos_id=eos)
    seed = engine.generate_reference(ids, max_new_tokens=24, eos_id=eos)
    assert fused["tokens"] == seed["tokens"]
    assert len(fused["tokens"]) < 24
    assert fused["tokens"][-1] == eos


def test_fused_max_len_truncation(engine):
    """plen + generated never exceeds max_len, exactly like the oracle."""
    rng = np.random.default_rng(11)
    ids = rng.integers(0, engine.cfg.vocab_size, engine.max_len - 6)
    fused = engine.generate(ids, max_new_tokens=32)
    seed = engine.generate_reference(ids, max_new_tokens=32)
    assert fused["tokens"] == seed["tokens"]
    assert len(fused["tokens"]) == 6


def test_fused_single_token_budget(engine):
    rng = np.random.default_rng(13)
    ids = rng.integers(0, engine.cfg.vocab_size, 5)
    fused = engine.generate(ids, max_new_tokens=1)
    seed = engine.generate_reference(ids, max_new_tokens=1)
    assert fused["tokens"] == seed["tokens"] and len(fused["tokens"]) == 1


def test_segment_length_does_not_change_tokens(engine):
    rng = np.random.default_rng(17)
    ids = rng.integers(0, engine.cfg.vocab_size, 12)
    outs = [engine.generate(ids, max_new_tokens=20, segment_len=k)["tokens"]
            for k in (1, 4, 20)]
    assert outs[0] == outs[1] == outs[2]


# ------------------------------------------------------------------ caches

def test_fused_cache_matches_sequential_decode(engine):
    """The fused segment's final ring cache == init-from-prefill + one
    decode_step per token (the seed cache update path)."""
    cfg = engine.cfg
    rng = np.random.default_rng(3)
    ids = rng.integers(0, cfg.vocab_size, 9)
    n_new = 12

    # two prefills: the fused path donates its cache buffers.
    logits_a, caches_a, plen = engine._run_prefill(ids)
    logits_b, caches_b, _ = engine._run_prefill(ids)
    tok = int(np.argmax(np.asarray(logits_a)[0]))

    dec = FusedDecoder(engine.lm, engine.max_len, segment_len=5)
    fused = dec.decode(engine.params, caches_a, tok, plen, n_new)

    seq_tok = tok
    for _ in range(n_new - 1):
        logits_b, caches_b = engine._decode(
            engine.params, caches_b,
            {"tokens": jnp.full((1, 1), seq_tok, jnp.int32)})
        seq_tok = int(np.argmax(np.asarray(logits_b)[0]))

    for got, want in zip(jax.tree.leaves(fused["caches"]),
                         jax.tree.leaves(caches_b)):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=1e-6, rtol=1e-6)
    # fill level advanced exactly n_new - 1 decode steps past the prompt
    assert int(np.asarray(fused["caches"][0]["t"])[0]) == plen + n_new - 1


def test_ring_buffer_wraps_onto_oldest_slots():
    """Past capacity S, step t lands at slot t % S and the cache holds
    exactly the S most recent tokens' KV (checked against a large cache —
    layer-1 K/V depend only on (token, position), so they must be equal)."""
    cfg = get_config("smollm-360m").reduced()   # single attn block
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    S, T = 8, 13
    ring = lm.init_cache(1, S)
    big = lm.init_cache(1, 32)
    rng = np.random.default_rng(5)
    toks = rng.integers(0, cfg.vocab_size, T)
    step = jax.jit(lm.decode_step)
    for tok in toks:
        batch = {"tokens": jnp.full((1, 1), int(tok), jnp.int32)}
        _, ring = step(params, ring, batch)
        _, big = step(params, big, batch)

    ring_k = np.asarray(ring[0]["k"], np.float32)[0, 0]   # (S, KV, hd)
    big_k = np.asarray(big[0]["k"], np.float32)[0, 0]
    assert int(np.asarray(ring[0]["t"])[0]) == T
    for s in range(S):
        p = s + S if s + S < T else s        # latest write to this slot
        np.testing.assert_array_equal(ring_k[s], big_k[p],
                                      err_msg=f"slot {s} != position {p}")


def test_ring_decode_attends_window_only():
    """Once wrapped, the all-true mask attends exactly the live window."""
    from repro.models.attention import decode_attention
    rng = np.random.default_rng(9)
    B, S, KV, H, hd = 1, 8, 2, 4, 16
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    out_wrapped = decode_attention(q, k, v, jnp.asarray(20, jnp.int32))
    out_full = decode_attention(q, k, v, jnp.asarray(S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(out_wrapped), np.asarray(out_full),
                               atol=1e-6)


# ----------------------------------------------------------------- bucketing

def test_geometric_buckets_cover_max_len():
    assert geometric_buckets(96) == (16, 32, 64, 96)
    assert geometric_buckets(128) == (16, 32, 64, 128)
    assert bucket_for(1, (16, 32)) == 16
    assert bucket_for(17, (16, 32)) == 32
    assert bucket_for(33, (16, 32)) == 33      # beyond last: exact (seed)


def test_bucketed_prefill_matches_exact(engine):
    """Right-padding to a bucket must not change the last-position logits
    or the cache fill level (causal attention; pads are masked dead)."""
    lm, params = engine.lm, engine.params
    rng = np.random.default_rng(21)
    for plen in (3, 17, 30):
        ids = rng.integers(0, engine.cfg.vocab_size, plen)
        exact_logits, exact_caches = lm.prefill(
            params, {"tokens": jnp.asarray(ids, jnp.int32)[None]},
            pad_to=engine.max_len)
        bucket_logits, bucket_caches, got_plen = engine._run_prefill(ids)
        assert got_plen == plen
        np.testing.assert_allclose(np.asarray(bucket_logits),
                                   np.asarray(exact_logits),
                                   atol=1e-4, rtol=1e-4)
        assert (int(np.argmax(np.asarray(bucket_logits)[0]))
                == int(np.argmax(np.asarray(exact_logits)[0])))
        assert int(np.asarray(bucket_caches[0]["t"])[0]) == plen
        assert bucket_caches[0]["k"].shape == exact_caches[0]["k"].shape


def test_bucketing_disabled_for_stateful_stacks():
    """SSM/hybrid stacks must prefill at exact length (pads would corrupt
    the recurrent state)."""
    cfg = get_config("jamba-v0.1-52b").reduced()
    eng = RealEngine(cfg, max_len=64)
    assert not eng._bucketing and eng.buckets == ()
    out = eng.generate(np.arange(7) % cfg.vocab_size, max_new_tokens=4)
    assert len(out["tokens"]) == 4


# -------------------------------------------------------------- cancellation

def test_mid_generation_cancellation(engine):
    """§3.4 drain: the cancel flag stops the fused loop at the next segment
    boundary with the tokens generated so far."""
    rng = np.random.default_rng(23)
    ids = rng.integers(0, engine.cfg.vocab_size, 12)
    calls = {"n": 0}

    def cancel_after_two_segments():
        calls["n"] += 1
        return calls["n"] > 2

    out = engine.generate(ids, max_new_tokens=64,
                          cancel_cb=cancel_after_two_segments)
    assert out["cancelled"]
    # prefill token + exactly two full segments
    assert len(out["tokens"]) == 1 + 2 * engine.segment_len
    assert out["segments"] == 2
    # the engine flag is consumed: the next request decodes normally
    out2 = engine.generate(ids, max_new_tokens=8)
    assert not out2["cancelled"] and len(out2["tokens"]) == 8


def test_request_cancel_flag(engine):
    """A disconnect arriving mid-flight (request_cancel) is observed at the
    next segment boundary."""
    rng = np.random.default_rng(29)
    ids = rng.integers(0, engine.cfg.vocab_size, 6)
    state = {"n": 0}

    def cb():                      # fires while segment 1 is about to launch
        state["n"] += 1
        if state["n"] == 1:
            engine.request_cancel()
        return False

    out = engine.generate(ids, max_new_tokens=64, cancel_cb=cb)
    assert out["cancelled"]
    assert len(out["tokens"]) == 1 + engine.segment_len
    assert out["segments"] == 1
