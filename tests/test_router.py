"""PredictiveRouter tests: JSPW vs JSQ placement, failover re-enqueue,
and the hedged-dispatch deadline path."""

import numpy as np
import pytest

from repro.core.router import PredictiveRouter
from repro.core.scheduler import Request


def _req(i, arrival=0.0, p_long=0.5):
    return Request(req_id=i, arrival=arrival, p_long=p_long)


# probas whose expected service under (2, 10, 30) is tiny vs huge
P_SHORT = np.array([1.0, 0.0, 0.0])       # E[S] = 2
P_LONG = np.array([0.0, 0.0, 1.0])        # E[S] = 30


def test_jspw_places_by_predicted_work_not_queue_length():
    """Replica 0 holds three predicted-short requests (6s of work),
    replica 1 one predicted-long (30s).  JSPW sends the next request to
    the replica with LESS predicted work despite its LONGER queue."""
    router = PredictiveRouter(n_replicas=2)
    for i in range(3):
        router.replicas[0].queue.push(_req(i))
        router.replicas[0].predicted_backlog += router.predicted_service(
            P_SHORT)
    router.replicas[1].queue.push(_req(3))
    router.replicas[1].predicted_backlog += router.predicted_service(P_LONG)
    assert len(router.replicas[0].queue) > len(router.replicas[1].queue)
    chosen = router.route(_req(4), proba=P_SHORT)
    assert chosen == 0, "JSPW must follow predicted work, not queue length"


def test_jsq_fallback_without_predictor_balances_counts():
    """No proba -> every request carries the same mean estimate, so the
    cost degenerates to backlog count x constant: join-shortest-queue."""
    router = PredictiveRouter(n_replicas=3)
    for i in range(9):
        router.route(_req(i))                 # no proba: JSQ behavior
    sizes = sorted(router.queue_lengths().values())
    assert sizes == [3, 3, 3]
    est = float(router.service_estimate.mean())
    for r in router.replicas:
        assert r.predicted_backlog == pytest.approx(3 * est)


def test_failover_reroutes_drained_requests():
    router = PredictiveRouter(n_replicas=2)
    for i in range(8):
        router.route(_req(i))
    victim = 0
    n_victim = router.queue_lengths()[victim]
    drained = router.fail_replica(victim)
    assert len(drained) == n_victim
    assert all(r.meta["failed_over"] for r in drained)
    assert router.stats["failed_over"] == n_victim
    assert router.queue_lengths()[victim] == 0
    assert router.queue_lengths()[1] == 8
    assert not router.replicas[victim].healthy
    # requests drained out of a failed replica are NOT client cancellations
    assert all(not r.cancelled for r in drained)
    # losing the last healthy replica leaves its backlog unroutable
    with pytest.raises(RuntimeError):
        router.fail_replica(1)
    with pytest.raises(RuntimeError):
        router.route(_req(99))


def test_hedge_overdue_moves_requests_past_deadline_once():
    router = PredictiveRouter(n_replicas=2)
    # replica 0 is the straggler: stuck busy, old requests queued on it
    old = [_req(i, arrival=0.0) for i in range(2)]
    fresh = _req(2, arrival=9.9)
    for r in old + [fresh]:
        router.replicas[0].queue.push(r)
        r.meta["predicted_service"] = 2.0
        router.replicas[0].predicted_backlog += 2.0
    moved = router.hedge_overdue(now=10.0, deadline=5.0)
    assert {r.req_id for r in moved} == {0, 1}
    assert router.stats["hedged"] == 2
    # moved to the OTHER replica, not cancelled, marked hedged
    assert router.queue_lengths() == {0: 1, 1: 2}
    assert all(r.meta["hedged"] and not r.cancelled for r in moved)
    # the straggler's predicted backlog was released
    assert router.replicas[0].predicted_backlog == pytest.approx(2.0)
    # the under-deadline request stayed put
    assert fresh.req_id in {r.req_id for r in
                            router.replicas[0].queue.waiting()}
    # later, the fresh request crosses the deadline too — but the already
    # hedged ones never bounce back and forth
    moved2 = router.hedge_overdue(now=20.0, deadline=5.0)
    assert {r.req_id for r in moved2} == {fresh.req_id}
    assert router.hedge_overdue(now=30.0, deadline=5.0) == []
    assert router.stats["hedged"] == 3


def test_hedge_noop_with_single_replica():
    router = PredictiveRouter(n_replicas=1)
    router.route(_req(0, arrival=0.0))
    assert router.hedge_overdue(now=100.0, deadline=1.0) == []
    assert router.stats["hedged"] == 0


def test_on_dispatch_releases_backlog():
    router = PredictiveRouter(n_replicas=1)
    req = _req(0)
    router.route(req, proba=P_LONG)
    est = router.predicted_service(P_LONG)
    assert router.replicas[0].predicted_backlog == pytest.approx(est)
    got = router.replicas[0].queue.pop(now=0.0)
    router.on_dispatch(0, got, now=0.0)
    assert router.replicas[0].predicted_backlog == 0.0
    assert router.replicas[0].busy_until == pytest.approx(est)


def test_router_accepts_policy_instances():
    from repro.core.policy import PredictedSRPT
    router = PredictiveRouter(n_replicas=2, policy=PredictedSRPT())
    assert all(r.queue.policy == "srpt" for r in router.replicas)
    router.route(_req(0))
    assert router.stats["routed"] == 1


def test_fail_replica_preserves_arrivals_and_excludes_dead():
    """PR 6 robustness: drained requests keep their ORIGINAL arrival time
    (sojourn accounting spans the failover) and never land back on the
    dead replica."""
    router = PredictiveRouter(n_replicas=3)
    reqs = [_req(i, arrival=0.5 * i) for i in range(9)]
    for r in reqs:
        router.route(r, now=r.arrival)
    arrivals = {r.req_id: r.arrival for r in reqs}
    drained = router.fail_replica(0, now=10.0)
    assert drained
    for r in drained:
        assert r.arrival == arrivals[r.req_id]
    assert router.queue_lengths()[0] == 0
    alive = {r.req_id
             for rep in router.replicas[1:] for r in rep.queue.waiting()}
    assert {r.req_id for r in reqs} == alive
    # subsequent routing also skips the dead replica
    for i in range(20, 26):
        assert router.route(_req(i)) != 0


def test_breaker_opens_and_reroutes_then_probe_recloses():
    """Circuit-breaker lifecycle through the router: repeated failures
    open replica 0's breaker, traffic flows to replica 1 during cooldown,
    then exactly one half-open probe re-admits and success re-closes."""
    from repro.serving.faults import CircuitBreaker

    router = PredictiveRouter(
        n_replicas=2, breaker=CircuitBreaker(failure_threshold=2,
                                             recovery_s=30.0))
    # per-replica clones: tripping replica 0 must not affect replica 1
    assert router.replicas[0].breaker is not router.replicas[1].breaker
    router.record_failure(0, now=0.0)
    assert router.eligible(0, now=0.0)       # below threshold
    router.record_failure(0, now=1.0)
    assert router.replicas[0].breaker.state == "open"
    assert not router.eligible(0, now=5.0)
    assert router.eligible(1, now=5.0)
    assert router.stats["breaker_opens"] == 1
    # cooldown: everything routes to replica 1
    for i in range(4):
        assert router.route(_req(i), now=5.0 + i) == 1
    # eligibility scans during cooldown never consumed the probe slot
    after = 31.0
    assert router.eligible(0, now=after)
    assert router.replicas[0].breaker.state == "open"
    # first routed request past recovery_s IS the committed probe
    probe_rep = router.route(_req(10), now=after)
    assert probe_rep == 0                    # replica 1 carries 4 reqs
    assert router.replicas[0].breaker.state == "half_open"
    # while the probe is in flight, no second request is admitted there
    assert not router.eligible(0, now=after)
    assert router.route(_req(11), now=after) == 1
    router.record_success(0)
    assert router.stats["breaker_probes"] == 1
    assert router.replicas[0].breaker.state == "closed"
    assert router.eligible(0, now=after)


def test_on_engine_failure_fails_over_or_requeues_solo():
    from repro.serving.faults import CircuitBreaker

    # two replicas: the failed request moves to the healthy one
    router = PredictiveRouter(n_replicas=2)
    req = _req(0, arrival=1.0)
    rep = router.route(req, now=1.0)
    got = router.replicas[rep].queue.pop(now=1.0)
    router.on_dispatch(rep, got, now=1.0)
    new_rep = router.on_engine_failure(rep, got, now=2.0)
    assert new_rep == 1 - rep
    assert got.meta["failed_over"] and got.arrival == 1.0
    assert router.stats["failed_over"] == 1
    # solo replica: nowhere to fail over -> requeued in place, not lost
    solo = PredictiveRouter(n_replicas=1,
                            breaker=CircuitBreaker(failure_threshold=100))
    req2 = _req(0, arrival=0.0)
    solo.route(req2, now=0.0)
    got2 = solo.replicas[0].queue.pop(now=0.0)
    solo.on_dispatch(0, got2, now=0.0)
    assert solo.on_engine_failure(0, got2, now=1.0) == 0
    assert len(solo.replicas[0].queue) == 1
