"""Policy-layer tests: registry, scalar/array key agreement, aging rule,
preemptive SRPT / MLFQ semantics, fair share, and python-vs-native
preemptive engine equivalence.

Property tests use seeded ``np.random.default_rng`` loops (this container
has no hypothesis package).
"""

import math

import numpy as np
import pytest

from repro.core.policy import (AgingRule, FCFS, MLFQ, MODE_QUANTUM,
                               MODE_SRPT, OracleSJF, Policy, PredictedSJF,
                               PredictedSRPT, QuantileSJF, SEED_POLICIES,
                               WeightedFairShare, get_policy,
                               registered_names)
from repro.core.scheduler import Request, SJFQueue
from repro.core.sim_fast import (RequestBatch, dispatch_key, simulate_batch,
                                 simulate_grid_preempt)
from repro.core.simulation import (ServiceDist, simulate, simulate_reference)
from repro.core.sweep import sweep_burst


def _reqs(entries, tenants=None):
    return [Request(req_id=i, arrival=a, true_service=s, p_long=p,
                    klass="short" if p < 0.5 else "long",
                    tenant=(tenants[i] if tenants else "default"))
            for i, (a, s, p) in enumerate(entries)]


# ------------------------------------------------------------------ registry

def test_registry_resolves_names_and_instances():
    assert get_policy("sjf").name == "sjf"
    pol = PredictedSRPT()
    assert get_policy(pol) is pol
    assert set(SEED_POLICIES) <= set(registered_names())
    for name in ("srpt", "sjf_quantile", "mlfq", "fair_share"):
        assert name in registered_names()


def test_unknown_policy_is_value_error_listing_names():
    with pytest.raises(ValueError) as ei:
        get_policy("does_not_exist")
    msg = str(ei.value)
    for name in SEED_POLICIES:
        assert name in msg
    with pytest.raises(ValueError):
        dispatch_key("nope", np.zeros(1), np.zeros(1), np.zeros(1))
    with pytest.raises(TypeError):
        get_policy(3.14)
    with pytest.raises(ValueError):
        SJFQueue(policy="bogus")


def test_aging_rule_modes():
    assert AgingRule("promote_oldest").effective_tau(5.0) == 5.0
    assert AgingRule("promote_oldest", tau=2.0).effective_tau(None) == 2.0
    assert AgingRule("none").effective_tau(5.0) is None
    with pytest.raises(ValueError):
        AgingRule("exponential_boost")
    # a policy whose aging rule is "none" ignores the per-queue tau
    q = SJFQueue(policy=PredictedSJF(aging=AgingRule("none")), tau=1.0)
    q.push(Request(req_id=0, arrival=0.0, p_long=0.9))
    q.push(Request(req_id=1, arrival=0.5, p_long=0.1))
    assert q.pop(now=100.0).req_id == 1        # no promotion ever
    assert q.stats["promotions"] == 0


# --------------------------------------------------- request NaN accessors

def test_request_wait_sojourn_nan_before_dispatch():
    r = Request(req_id=0, arrival=3.0)
    assert math.isnan(r.wait) and math.isnan(r.sojourn)
    assert "nan" in f"{r.wait:.2f}"            # formatting never raises
    assert math.isnan(float(np.mean([r.wait])))
    r.start, r.finish = 4.0, 6.0
    assert r.wait == 1.0 and r.sojourn == 3.0


# ----------------------------------------------- scalar/array key agreement

def test_scalar_and_array_keys_agree():
    rng = np.random.default_rng(0)
    n = 64
    entries = [(float(a), float(s), float(p)) for a, s, p in
               zip(np.sort(rng.uniform(0, 10, n)), rng.uniform(0.1, 9, n),
                   rng.random(n))]
    tenants = [("acme", "globex", "initech")[int(i)] for i in
               rng.integers(0, 3, n)]
    reqs = _reqs(entries, tenants=tenants)
    batch = RequestBatch.from_requests(reqs)
    for name in registered_names():
        pol = get_policy(name).fresh()
        arr_keys = pol.key_array(batch.arrival, batch.p_long,
                                 batch.true_service, tenant=batch.tenant,
                                 tenants=batch.tenants)
        # scalar keys computed in the same (arrival) order
        scalar = np.array([pol.fresh().key(r) if name != "fair_share"
                           else np.nan for r in reqs])
        if name == "fair_share":
            fs = pol.fresh()
            scalar = np.array([fs.key(r) for r in reqs])
        assert np.allclose(arr_keys, scalar, rtol=1e-12), name


def test_seed_key_arrays_unchanged():
    arrival = np.array([3.0, 1.0, 2.0])
    p_long = np.array([0.2, 0.9, 0.5])
    service = np.array([4.0, 8.0, 1.0])
    assert np.array_equal(dispatch_key("fcfs", arrival, p_long, service),
                          arrival)
    assert np.array_equal(dispatch_key("sjf", arrival, p_long, service),
                          p_long)
    assert np.array_equal(dispatch_key("sjf_oracle", arrival, p_long,
                                       service), service)


def test_quantile_key_penalises_uncertainty():
    pol = QuantileSJF()

    def k(p):
        return pol.key(Request(req_id=0, p_long=p))

    assert k(0.0) < k(0.25) < k(0.5)
    # uncertainty premium over the posterior MEAN peaks mid-posterior
    premium = [k(p) - pol.predicted_service(p) for p in (0.0, 0.25, 0.5)]
    assert premium[1] > premium[0] and premium[2] > premium[0]
    # the behavior plain SJF cannot express: a 60%-confident "short"
    # (p=0.4) is hedged to sort WITH the longs, while a 95%-confident
    # short (p=0.05) keeps its early rank
    assert k(0.4) > k(0.05)
    assert k(0.4) >= k(0.9)                 # sjf would order 0.4 << 0.9
    sjf = PredictedSJF()
    assert sjf.key(Request(req_id=0, p_long=0.4)) \
        < sjf.key(Request(req_id=0, p_long=0.9))


# -------------------------------------------------------- preemptive engine

def test_preemptive_engines_python_native_bitwise():
    from repro.core import _native
    if _native.native_des_preempt() is None:
        pytest.skip("no C compiler")
    rng = np.random.default_rng(11)
    for trial in range(40):
        n = int(rng.integers(2, 150))
        arrival = np.sort(np.round(rng.uniform(0, 40, n), 2))
        service = np.round(rng.uniform(0.05, 9, n), 3)
        key = np.round(rng.uniform(0.5, 12, n), 2)
        quanta = np.round(rng.uniform(0.2, 14, n), 2)
        tau = [None, -1.0, 0.0, 4.0, 60.0][trial % 5]
        mode = [MODE_SRPT, MODE_QUANTUM][trial % 2]
        outs = [simulate_grid_preempt(arrival[None], service[None],
                                      key[None], (tau,), (mode,),
                                      quanta[None], engine=eng)
                for eng in ("python", "native")]
        for a, b in zip(*outs):
            assert np.array_equal(a, b), (trial, mode, tau)


def test_preemptive_conservation_and_bounds():
    """Every request finishes exactly once; per-request service is
    conserved (finish - start >= service, equality when never preempted);
    the server is work-conserving (makespan >= total work)."""
    rng = np.random.default_rng(5)
    for policy in ("srpt", "mlfq"):
        for trial in range(20):
            n = int(rng.integers(2, 80))
            entries = [(float(a), float(s), float(p)) for a, s, p in
                       zip(np.sort(rng.uniform(0, 30, n)),
                           rng.uniform(0.1, 8, n), rng.random(n))]
            batch = RequestBatch.from_requests(_reqs(entries))
            res = simulate_batch(batch, policy=policy,
                                 tau=float(rng.uniform(1, 30)))
            assert np.all(res.finish > res.start - 1e-12)
            assert np.all(res.finish - res.start
                          >= batch.true_service - 1e-9)
            assert np.all(res.start >= batch.arrival - 1e-12)
            total = batch.true_service.sum()
            assert res.makespan >= total - 1e-6


def test_srpt_beats_sjf_short_p50_on_longs_first_burst():
    """Acceptance: preemptive SRPT gives strictly lower short-class P50
    sojourn than non-preemptive SJF when longs arrive first."""
    longs = [(0.0 + 0.001 * i, 10.0, 1.0) for i in range(5)]
    shorts = [(0.5 + 0.01 * i, 1.0, 0.0) for i in range(10)]
    batch = RequestBatch.from_requests(_reqs(longs + shorts))
    sjf = simulate_batch(batch, policy="sjf")
    srpt = simulate_batch(batch, policy="srpt")
    assert srpt.preemptions > 0
    assert srpt.percentile(50, klass="short") \
        < sjf.percentile(50, klass="short")
    # randomized variant: SRPT never loses on short P50 under longs-first
    rng = np.random.default_rng(1)
    S, L = ServiceDist(1.0, 0.2), ServiceDist(12.0, 2.0)
    for trial in range(10):
        entries = ([(float(rng.uniform(0, 0.05)), float(L.sample(rng)), 1.0)
                    for _ in range(5)]
                   + [(float(rng.uniform(0.5, 2.0)), float(S.sample(rng)),
                       0.0) for _ in range(20)])
        b = RequestBatch.from_requests(_reqs(entries))
        p_sjf = simulate_batch(b, policy="sjf").percentile(50, "short")
        p_srpt = simulate_batch(b, policy="srpt").percentile(50, "short")
        assert p_srpt <= p_sjf + 1e-9, trial


def test_mlfq_demotes_mispredicted_long():
    """A confidently-'short' prediction on a long job exhausts its level-0
    budget and is demoted, so later shorts overtake it."""
    mispredicted_long = [(0.0, 50.0, 0.05)]       # predicted short, runs 50s
    shorts = [(1.0 + i, 1.0, 0.1) for i in range(8)]
    batch = RequestBatch.from_requests(_reqs(mispredicted_long + shorts))
    sjf = simulate_batch(batch, policy="sjf")     # no defence: blocks 50s
    mlfq = simulate_batch(batch, policy="mlfq")
    short_mask = batch.p_long < 0.5
    # under mlfq the true-long job finishes LAST despite its low p_long
    assert np.argmax(mlfq.finish) == 0
    assert mlfq.percentile(50, klass="short") \
        < sjf.percentile(50, klass="short")


def test_srpt_reduces_to_sjf_order_without_arrival_overlap():
    """With all requests present at t=0 (no later arrivals), SRPT never
    preempts and serves in predicted-service order, like sjf_oracle on
    the predicted estimate."""
    entries = [(0.0, 3.0, p) for p in (0.9, 0.1, 0.5, 0.3)]
    batch = RequestBatch.from_requests(_reqs(entries))
    res = simulate_batch(batch, policy="srpt")
    assert res.preemptions == 0
    order = np.argsort(res.start)
    assert list(batch.p_long[order]) == sorted(batch.p_long)


# --------------------------------------------------------------- fair share

def test_fair_share_isolates_light_tenant():
    """Tenant A floods 20 requests at t~0; tenant B sends 3.  Under fair
    share B's mean sojourn beats A's; under FCFS B (arriving after the
    flood) waits behind all of A."""
    flood = [(0.001 * i, 2.0, 0.5) for i in range(20)]
    light = [(0.05 + 0.001 * i, 2.0, 0.5) for i in range(3)]
    tenants = ["acme"] * 20 + ["globex"] * 3
    reqs = _reqs(flood + light, tenants=tenants)
    batch = RequestBatch.from_requests(reqs)
    fair = simulate_batch(batch, policy="fair_share")
    fcfs = simulate_batch(batch, policy="fcfs")
    a = batch.tenant == 0
    b = batch.tenant == 1
    soj_fair = fair.finish - batch.arrival
    soj_fcfs = fcfs.finish - batch.arrival
    assert soj_fair[b].mean() < soj_fcfs[b].mean()
    assert soj_fair[b].mean() < soj_fair[a].mean()


def test_fair_share_virtual_time_stops_history_replay():
    """SCFQ floor: after tenant A accumulates lots of dispatched credit,
    a late-joining tenant B starts from the CURRENT virtual time, not
    zero — so A's next request competes on equal terms instead of being
    starved until B replays A's whole history."""
    q = SJFQueue(policy="fair_share")
    for i in range(50):                    # A's long-dispatched history
        q.push(Request(req_id=i, arrival=float(i), p_long=0.5,
                       tenant="acme"))
        assert q.pop(now=float(i)).tenant == "acme"
    # B joins late; A keeps submitting
    q.push(Request(req_id=100, arrival=50.0, p_long=0.5, tenant="globex"))
    q.push(Request(req_id=101, arrival=50.0, p_long=0.5, tenant="acme"))
    q.push(Request(req_id=102, arrival=50.1, p_long=0.5, tenant="globex"))
    order = [q.pop(now=51.0).req_id for _ in range(3)]
    # B's first request dispatches next (fresh tenant gets one step of
    # priority), but A's request is NOT starved behind all of B's
    assert order[0] == 100
    assert order[1] == 101, "A must not wait for B to replay its history"
    assert order[2] == 102


def test_sim_drain_preemptive_respects_busy_engine():
    """A second drain under a preemptive policy cannot schedule work into
    time the engine already spent on the first drain."""
    from repro.serving.server import ClairvoyantServer
    from repro.serving.openai_api import CompletionRequest
    server = ClairvoyantServer(policy="srpt")
    server.submit(CompletionRequest(prompt="x " * 50), arrival=0.0,
                  true_output_tokens=600, klass="long")
    server.drain()
    busy = server.engines[0].busy_until
    assert busy > 0
    server.submit(CompletionRequest(prompt="quick"), arrival=1.0,
                  true_output_tokens=30, klass="short")
    resp = server.drain()
    late = resp[-1]
    assert late.klass == "short"
    # started only after the engine freed up: wait covers the busy window
    assert late.queue_wait_s >= busy - 1.0 - 1e-9


def test_fair_share_weights_bias_dispatch():
    pol = WeightedFairShare(weights=(("vip", 4.0),))
    reqs = _reqs([(0.0, 1.0, 0.5), (0.0, 1.0, 0.5)],
                 tenants=["vip", "basic"])
    fs = pol.fresh()
    k_vip = fs.key(reqs[0])
    k_basic = fs.key(reqs[1])
    assert k_vip < k_basic                     # 4x weight => 1/4 the charge


# ------------------------------------------------------- cross-layer checks

def test_simulate_routes_preemptive_policies():
    entries = [(0.0, 10.0, 1.0), (0.5, 1.0, 0.0), (0.6, 1.0, 0.0)]
    res = simulate(_reqs(entries), policy="srpt")
    assert len(res.requests) == 3
    assert max(r.finish for r in res.requests) == res.makespan
    # the long was preempted by the shorts: its finish trails theirs even
    # though it started first
    by_id = {r.req_id: r for r in res.requests}
    assert by_id[0].start < by_id[1].start
    assert by_id[0].finish > by_id[2].finish
    with pytest.raises(ValueError):
        simulate_reference(_reqs(entries), policy="srpt")


def test_sweep_mixes_preemptive_and_key_policies():
    S, L = ServiceDist(1.0, 0.2), ServiceDist(10.0, 1.5)
    conds = [("fcfs", None), ("sjf", 6.0), ("srpt", 6.0), ("mlfq", None),
             ("sjf_quantile", None), ("fair_share", None)]
    res = sweep_burst(conds, seeds=(0, 1), n_short=30, n_long=10,
                      short=S, long=L)
    for m in ("short_p50", "long_p95", "mean_sojourn", "makespan"):
        assert np.isfinite(res.metric(m)).all(), m
    # per-cell agreement with simulate_batch for the srpt row
    ci = res.conditions.index(("srpt", 6.0))
    rng = np.random.default_rng(0)
    batch = RequestBatch.burst(rng, 30, 10, S, L)
    cell = simulate_batch(batch, policy="srpt", tau=6.0)
    assert np.isclose(res.metric("short_p50")[ci, 0, 0],
                      cell.percentile(50, "short"), rtol=1e-12)
    # burst regime: SRPT short P50 never worse than FCFS
    fi = res.conditions.index(("fcfs", None))
    assert (res.metric("short_p50")[ci] <= res.metric("short_p50")[fi]
            + 1e-9).all()


def test_queue_peek_and_requeue():
    q = SJFQueue(policy="srpt")
    q.push(Request(req_id=0, arrival=0.0, p_long=1.0))   # pred 8.9
    q.push(Request(req_id=1, arrival=0.1, p_long=0.0))   # pred 3.5
    key, req = q.peek()
    assert req.req_id == 1 and len(q) == 2               # peek != pop
    got = q.pop(now=0.2)
    assert got.req_id == 1
    # evict-style requeue: smaller key jumps the remaining queue
    got.meta["resume_tokens"] = [7]
    q.push_requeue(got, key=0.5)
    assert q.stats["preemptions"] == 1
    assert q.pop(now=0.2).req_id == 1
    assert q.pop(now=0.2).req_id == 0
