"""DES correctness: work conservation, SJF optimality, P-K agreement,
and trace equivalence of every fast engine against the seed loop.

Property tests use seeded ``np.random.default_rng`` loops (this container
has no hypothesis package).
"""

import numpy as np
import pytest

from repro.core.scheduler import Request
from repro.core.sim_fast import RequestBatch, simulate_batch
from repro.core.simulation import (ServiceDist, burst_workload, cs2,
                                   pk_wait_fcfs, poisson_workload, simulate,
                                   simulate_reference)
from repro.core.sweep import sweep_batches, sweep_poisson


def _reqs(entries):
    return [Request(req_id=i, arrival=a, true_service=s, p_long=p,
                    klass="short" if p < 0.5 else "long")
            for i, (a, s, p) in enumerate(entries)]


def test_work_conservation_and_no_overlap():
    rng = np.random.default_rng(0)
    for trial in range(60):
        n = int(rng.integers(1, 60))
        policy = ["fcfs", "sjf", "sjf_oracle"][int(rng.integers(0, 3))]
        entries = [(float(rng.uniform(0, 50)), float(rng.uniform(0.1, 10)),
                    float(rng.random())) for _ in range(n)]
        res = simulate(_reqs(entries), policy=policy)
        assert len(res.requests) == len(entries)
        # serial server: intervals must not overlap, and server never idles
        # while work is queued
        iv = sorted((r.start, r.finish) for r in res.requests)
        for (s1, f1), (s2, f2) in zip(iv, iv[1:]):
            assert s2 >= f1 - 1e-9
        total = sum(s for _, s, _ in entries)
        assert res.makespan >= total - 1e-6


def test_sjf_oracle_minimises_mean_wait_in_burst():
    rng = np.random.default_rng(0)
    short, long = ServiceDist(2.0, 0.3), ServiceDist(20.0, 2.0)
    r1 = burst_workload(rng, 20, 20, short, long)
    rng = np.random.default_rng(0)
    r2 = burst_workload(rng, 20, 20, short, long)
    fcfs = simulate(r1, policy="fcfs")
    sjf = simulate(r2, policy="sjf_oracle")
    assert sjf.mean(attr="wait") < fcfs.mean(attr="wait")


def test_fcfs_matches_pollaczek_khinchine():
    """M/G/1 FCFS mean wait within ~12% of the P-K formula (paper §2.4)."""
    rng = np.random.default_rng(7)
    short, long = ServiceDist(2.0, 0.5), ServiceDist(10.0, 1.5)
    n, rho = 40000, 0.6
    es = 0.5 * (short.mean + long.mean)
    lam = rho / es
    reqs = poisson_workload(rng, n, lam, short, long, mix_long=0.5)
    services = np.array([r.true_service for r in reqs])
    res = simulate(reqs, policy="fcfs")
    measured = res.mean(attr="wait")
    predicted = pk_wait_fcfs(lam, services.mean(),
                             np.mean(services ** 2))
    assert abs(measured - predicted) / predicted < 0.12


def test_cs2_mixed_exceeds_homogeneous():
    """Table 1 structure: mixing short+long inflates Cs2."""
    rng = np.random.default_rng(1)
    short = ServiceDist(2.1, 1.1).sample(rng, 5000)
    long = ServiceDist(29.7, 11.7).sample(rng, 5000)
    mixed = np.where(rng.random(5000) < 0.8, short, long)
    assert cs2(mixed) > 1.0 > max(cs2(short), cs2(long))


# ------------------------------------------------------- trace equivalence

def _engines():
    from repro.core import _native
    return ["python"] + (["native"] if _native.native_des() else [])


def test_trace_equivalence_randomized_streams():
    """Fast engines vs the seed loop: bitwise-identical start/finish/
    promoted per request, identical promotion counts — every policy,
    tau in {None, negative (promote-always), 0, small, large}, randomized
    arrival streams with duplicate arrivals and tied keys."""
    rng = np.random.default_rng(11)
    for trial in range(40):
        n = int(rng.integers(1, 120))
        policy = ["fcfs", "sjf", "sjf_oracle"][int(rng.integers(0, 3))]
        tau = [None, -1.0, 0.0, float(rng.uniform(0.1, 5.0)),
               float(rng.uniform(5.0, 80.0))][int(rng.integers(0, 5))]
        arrival = np.round(rng.uniform(0, 30, n), 2)   # rounded: duplicates
        service = np.round(rng.uniform(0.05, 8, n), 3)
        p_long = np.round(rng.random(n), 1)            # coarse: tied keys

        def mk():
            return [Request(req_id=i, arrival=float(arrival[i]),
                            true_service=float(service[i]),
                            p_long=float(p_long[i]))
                    for i in range(n)]

        ref = simulate_reference(mk(), policy=policy, tau=tau)
        ref_by_id = {r.req_id: (r.start, r.finish, r.promoted)
                     for r in ref.requests}
        for eng in _engines():
            fast = simulate(mk(), policy=policy, tau=tau, engine=eng)
            assert fast.promotions == ref.promotions, (policy, tau, eng)
            assert fast.makespan == ref.makespan
            for r in fast.requests:
                assert ref_by_id[r.req_id] == (r.start, r.finish,
                                               r.promoted), \
                    (policy, tau, eng, r.req_id)


def test_trace_equivalence_poisson_and_burst_batches():
    """simulate_batch (SoA front end) vs the reference on generated
    workloads, all three policies."""
    rng = np.random.default_rng(3)
    short, long = ServiceDist(2.0, 0.5), ServiceDist(12.0, 2.0)
    batches = [RequestBatch.poisson(rng, 400, 0.3, short, long),
               RequestBatch.burst(rng, 60, 20, short, long)]
    for batch in batches:
        for policy in ("fcfs", "sjf", "sjf_oracle"):
            for tau in (None, 6.0):
                ref = simulate_reference(batch.to_requests(), policy=policy,
                                         tau=tau)
                ref_start = np.array(
                    [r.start for r in sorted(ref.requests,
                                             key=lambda r: r.req_id)])
                for eng in _engines():
                    res = simulate_batch(batch, policy=policy, tau=tau,
                                         engine=eng)
                    assert np.array_equal(res.start, ref_start)
                    assert res.promotions == ref.promotions
                    soj = res.finish - batch.arrival
                    assert np.isclose(
                        res.percentile(50, klass="short"),
                        float(np.percentile(
                            soj[batch.klass == 1], 50)))


def test_sweep_matches_per_cell_reference():
    """One-shot sweep metrics == per-cell reference percentiles."""
    short, long = ServiceDist(2.0, 0.5), ServiceDist(10.0, 2.0)
    conditions = [("fcfs", None), ("sjf", 6.0), ("sjf_oracle", None)]
    res = sweep_poisson(conditions, rhos=(0.6,), seeds=(0, 1), n=300,
                        short=short, long=long)
    es = 0.5 * (short.mean + long.mean)
    for ci, (policy, tau) in enumerate(conditions):
        for si, seed in enumerate((0, 1)):
            rng = np.random.default_rng(seed)
            batch = RequestBatch.poisson(rng, 300, 0.6 / es, short, long)
            ref = simulate_reference(batch.to_requests(), policy=policy,
                                     tau=tau)
            assert np.isclose(res.metric("short_p50")[ci, 0, si],
                              ref.percentile(50, "short"), rtol=1e-12)
            assert np.isclose(res.metric("long_p95")[ci, 0, si],
                              ref.percentile(95, "long"), rtol=1e-12)
            assert res.metric("promotions")[ci, 0, si] == ref.promotions


def test_jax_engine_matches_dispatch_order():
    """The vmapped JAX scan engine: identical dispatch order (float32 clock
    cannot flip these comparisons) and times within float32 tolerance."""
    jax = pytest.importorskip("jax")
    from repro.core.sim_jax import simulate_grid_jax
    from repro.core.sim_fast import dispatch_key
    rng = np.random.default_rng(5)
    n, G = 80, 6
    arrival = np.sort(np.round(rng.uniform(0, 20, (G, n)), 2), axis=1)
    service = np.round(rng.uniform(0.5, 4, (G, n)), 2)
    p_long = np.round(rng.random((G, n)), 2)
    taus = [None, 0.0, 3.0, None, 8.0, 1.0]
    policies = ["fcfs", "sjf", "sjf", "sjf_oracle", "sjf", "sjf"]
    key = np.stack([dispatch_key(p, arrival[g], p_long[g], service[g])
                    for g, p in enumerate(policies)])
    start, finish, promoted, promos = simulate_grid_jax(
        arrival, service, key, taus)
    for g in range(G):
        reqs = [Request(req_id=i, arrival=float(arrival[g, i]),
                        true_service=float(service[g, i]),
                        p_long=float(p_long[g, i])) for i in range(n)]
        ref = simulate_reference(reqs, policy=policies[g], tau=taus[g])
        ref_start = np.array([r.start for r in sorted(ref.requests,
                                                      key=lambda r: r.req_id)])
        assert np.allclose(start[g], ref_start, rtol=1e-5, atol=1e-4), g
        # same dispatch ORDER, not just close times
        assert np.array_equal(np.argsort(start[g], kind="stable"),
                              np.argsort(ref_start, kind="stable")), g
        assert int(promos[g]) == ref.promotions, g


def test_starvation_timeout_bounds_long_wait():
    rng = np.random.default_rng(3)
    short, long = ServiceDist(1.0, 0.1), ServiceDist(10.0, 1.0)
    reqs = burst_workload(rng, 80, 5, short, long)
    tau = 20.0
    res = simulate(reqs, policy="sjf", tau=tau)
    assert res.promotions > 0
    # guarantee: once past tau, a request is dispatched after at most the
    # requests that arrived BEFORE it (promotion is FIFO among starvers)
    max_service = max(r.true_service for r in res.requests)
    by_arrival = sorted(res.requests, key=lambda r: r.arrival)
    for rank, r in enumerate(by_arrival):
        if r.klass == "long":
            bound = tau + (rank + 1) * max_service + 1e-6
            assert r.start - r.arrival <= bound
    # and strictly better than the worst no-guard outcome for the earliest long
    first_long = next(r for r in by_arrival if r.klass == "long")
    total_work = sum(r.true_service for r in res.requests)
    assert first_long.start - first_long.arrival < total_work
