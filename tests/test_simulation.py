"""DES correctness: work conservation, SJF optimality, P-K agreement.

Property tests use seeded ``np.random.default_rng`` loops (this container
has no hypothesis package).
"""

import numpy as np
import pytest

from repro.core.scheduler import Request
from repro.core.simulation import (ServiceDist, burst_workload, cs2,
                                   pk_wait_fcfs, poisson_workload, simulate)


def _reqs(entries):
    return [Request(req_id=i, arrival=a, true_service=s, p_long=p,
                    klass="short" if p < 0.5 else "long")
            for i, (a, s, p) in enumerate(entries)]


def test_work_conservation_and_no_overlap():
    rng = np.random.default_rng(0)
    for trial in range(60):
        n = int(rng.integers(1, 60))
        policy = ["fcfs", "sjf", "sjf_oracle"][int(rng.integers(0, 3))]
        entries = [(float(rng.uniform(0, 50)), float(rng.uniform(0.1, 10)),
                    float(rng.random())) for _ in range(n)]
        res = simulate(_reqs(entries), policy=policy)
        assert len(res.requests) == len(entries)
        # serial server: intervals must not overlap, and server never idles
        # while work is queued
        iv = sorted((r.start, r.finish) for r in res.requests)
        for (s1, f1), (s2, f2) in zip(iv, iv[1:]):
            assert s2 >= f1 - 1e-9
        total = sum(s for _, s, _ in entries)
        assert res.makespan >= total - 1e-6


def test_sjf_oracle_minimises_mean_wait_in_burst():
    rng = np.random.default_rng(0)
    short, long = ServiceDist(2.0, 0.3), ServiceDist(20.0, 2.0)
    r1 = burst_workload(rng, 20, 20, short, long)
    rng = np.random.default_rng(0)
    r2 = burst_workload(rng, 20, 20, short, long)
    fcfs = simulate(r1, policy="fcfs")
    sjf = simulate(r2, policy="sjf_oracle")
    assert sjf.mean(attr="wait") < fcfs.mean(attr="wait")


def test_fcfs_matches_pollaczek_khinchine():
    """M/G/1 FCFS mean wait within ~12% of the P-K formula (paper §2.4)."""
    rng = np.random.default_rng(7)
    short, long = ServiceDist(2.0, 0.5), ServiceDist(10.0, 1.5)
    n, rho = 40000, 0.6
    es = 0.5 * (short.mean + long.mean)
    lam = rho / es
    reqs = poisson_workload(rng, n, lam, short, long, mix_long=0.5)
    services = np.array([r.true_service for r in reqs])
    res = simulate(reqs, policy="fcfs")
    measured = res.mean(attr="wait")
    predicted = pk_wait_fcfs(lam, services.mean(),
                             np.mean(services ** 2))
    assert abs(measured - predicted) / predicted < 0.12


def test_cs2_mixed_exceeds_homogeneous():
    """Table 1 structure: mixing short+long inflates Cs2."""
    rng = np.random.default_rng(1)
    short = ServiceDist(2.1, 1.1).sample(rng, 5000)
    long = ServiceDist(29.7, 11.7).sample(rng, 5000)
    mixed = np.where(rng.random(5000) < 0.8, short, long)
    assert cs2(mixed) > 1.0 > max(cs2(short), cs2(long))


def test_starvation_timeout_bounds_long_wait():
    rng = np.random.default_rng(3)
    short, long = ServiceDist(1.0, 0.1), ServiceDist(10.0, 1.0)
    reqs = burst_workload(rng, 80, 5, short, long)
    tau = 20.0
    res = simulate(reqs, policy="sjf", tau=tau)
    assert res.promotions > 0
    # guarantee: once past tau, a request is dispatched after at most the
    # requests that arrived BEFORE it (promotion is FIFO among starvers)
    max_service = max(r.true_service for r in res.requests)
    by_arrival = sorted(res.requests, key=lambda r: r.arrival)
    for rank, r in enumerate(by_arrival):
        if r.klass == "long":
            bound = tau + (rank + 1) * max_service + 1e-6
            assert r.start - r.arrival <= bound
    # and strictly better than the worst no-guard outcome for the earliest long
    first_long = next(r for r in by_arrival if r.klass == "long")
    total_work = sum(r.true_service for r in res.requests)
    assert first_long.start - first_long.arrival < total_work
