"""Robustness suite (PR 6): fault injection + handling.

Covers the no-lost-requests invariant under fuzzed chaos plans (sim,
real, and batched drains), graceful predictor degradation, deadline
shedding, retry/backoff + circuit-breaker units, the DES fault mirror's
bitwise no-fault contract, and the compile-at-first-use native fallback.
"""

import copy

import numpy as np
import pytest

from repro.core import _native
from repro.core.sim_fast import (RequestBatch, ServerFaults, dispatch_key,
                                 simulate_grid, simulate_grid_faults)
from repro.core.simulation import (ServiceDist, poisson_workload, simulate,
                                   simulate_faulty)
from repro.serving.faults import (CircuitBreaker, EngineCrash, FaultPlan,
                                  FaultSpec, FaultInjector, RetryPolicy,
                                  TransientBackendError, as_injector)
from repro.serving.openai_api import STATUSES, CompletionRequest
from repro.serving.server import ClairvoyantServer

SHORT = ServiceDist(mean=3.5, std=0.8)
LONG = ServiceDist(mean=8.9, std=2.0)


# ----------------------------------------------------------- faults units
def test_fault_spec_validates_kind():
    with pytest.raises(ValueError):
        FaultSpec(kind="meteor_strike")


def test_fault_plan_random_is_deterministic():
    kw = dict(horizon=200.0, crash_mtbf=30.0, transient_rate=1 / 20.0,
              stall_mtbf=50.0, predictor_mtbf=80.0, n_replicas=3)
    a, b = FaultPlan.random(seed=5, **kw), FaultPlan.random(seed=5, **kw)
    assert a.specs == b.specs and len(a) > 0
    assert FaultPlan.random(seed=6, **kw).specs != a.specs
    assert all(s.at < 200.0 for s in a)


def test_injector_consumes_one_shot_specs_once():
    inj = FaultInjector(FaultPlan([
        FaultSpec(kind="transient", at=1.0, replica=0),
        FaultSpec(kind="crash", at=5.0, replica=-1, repair_s=2.0),
    ]))
    assert inj.transient_due(0, 0.5) is None        # not due yet
    assert inj.transient_due(1, 2.0) is None        # wrong replica
    assert inj.transient_due(0, 2.0) is not None
    assert inj.transient_due(0, 2.0) is None        # consumed
    assert inj.crash_between(2, 0.0, 4.0) is None   # trigger not in window
    crash = inj.crash_between(2, 4.0, 6.0)          # replica -1 matches any
    assert crash is not None and crash.repair_s == 2.0
    assert inj.crash_between(2, 4.0, 6.0) is None
    inj.reset()
    assert inj.transient_due(0, 2.0) is not None    # reset re-arms


def test_injector_windows_do_not_fire_out():
    inj = FaultInjector(FaultPlan([
        FaultSpec(kind="stall", at=2.0, duration=3.0, factor=4.0),
        FaultSpec(kind="predictor_down", at=0.0, duration=1.0),
        FaultSpec(kind="overflow", at=10.0, duration=1.0),
    ]))
    assert inj.stall_factor(0, 1.0) == 1.0
    assert inj.stall_factor(0, 3.0) == 4.0
    assert inj.stall_factor(0, 3.0) == 4.0          # windows are reusable
    assert inj.stall_factor(0, 5.0) == 1.0          # half-open interval
    assert inj.predictor_down(0.5) and not inj.predictor_down(1.5)
    assert inj.overflow_active(10.5) and not inj.overflow_active(11.5)
    assert as_injector(inj) is inj and as_injector(None) is None


def test_retry_policy_backoff_grows_and_jitter_is_bounded():
    rp = RetryPolicy(max_retries=3, base_s=0.1, multiplier=2.0,
                     jitter=0.5, seed=1)
    waits = [rp.backoff(a) for a in range(4)]
    for a, w in enumerate(waits):
        lo = 0.1 * 2.0 ** a
        assert lo <= w < lo * 1.5
    # deterministic for a given seed + call sequence
    rp2 = RetryPolicy(max_retries=3, base_s=0.1, multiplier=2.0,
                      jitter=0.5, seed=1)
    assert waits == [rp2.backoff(a) for a in range(4)]


def test_circuit_breaker_lifecycle():
    br = CircuitBreaker(failure_threshold=2, recovery_s=10.0)
    assert br.state == "closed" and br.allow(0.0)
    br.record_failure(1.0)
    assert br.state == "closed"                     # below threshold
    br.record_failure(2.0)
    assert br.state == "open"
    assert not br.allow(5.0)                        # cooling down
    assert br.allow(12.0) and br.state == "half_open"
    assert not br.allow(12.0)                       # one probe at a time
    br.record_failure(12.5)                         # probe failed: re-open
    assert br.state == "open" and not br.allow(13.0)
    assert br.allow(22.6) and br.state == "half_open"
    br.record_success()
    assert br.state == "closed" and br.failures == 0 and br.allow(23.0)


def test_breaker_would_allow_is_side_effect_free():
    br = CircuitBreaker(failure_threshold=1, recovery_s=5.0)
    br.record_failure(0.0)
    for _ in range(3):                              # pure: never commits the
        assert br.would_allow(6.0)                  # half-open probe slot
    assert br.state == "open"
    assert br.allow(6.0) and br.state == "half_open"
    assert not br.would_allow(6.0)                  # probe slot committed


# --------------------------------------- no-lost-requests chaos fuzz (sim)
def _chaos_server(seed, n_replicas=1, deadline_s=None, max_queue_depth=None):
    plan = FaultPlan.random(
        seed=seed, horizon=150.0, crash_mtbf=25.0, crash_mttr=3.0,
        transient_rate=1 / 20.0, stall_mtbf=40.0, stall_s=8.0,
        predictor_mtbf=60.0, n_replicas=n_replicas)
    return ClairvoyantServer(policy="sjf", predictor=None, fault_plan=plan,
                             n_replicas=n_replicas, deadline_s=deadline_s,
                             max_queue_depth=max_queue_depth, seed=seed)


def test_chaos_fuzz_sim_no_lost_requests():
    """Every submitted request terminates with exactly one terminal
    response, for any seeded fault plan, replica count, and deadline."""
    for trial in range(8):
        rng = np.random.default_rng(trial)
        n = int(rng.integers(20, 60))
        server = _chaos_server(
            seed=trial, n_replicas=1 + trial % 2,
            deadline_s=None if trial % 3 else 40.0,
            max_queue_depth=None if trial % 4 else 30)
        ids = []
        for i in range(n):
            req = CompletionRequest(prompt=f"req {trial}:{i}")
            server.submit(req, arrival=float(rng.uniform(0, 120)),
                          true_output_tokens=int(rng.integers(20, 600)),
                          klass="short" if rng.random() < 0.6 else "long")
            ids.append(req.request_id)    # assigned by the server at admit
        # a couple of client disconnects while queued
        server.cancel(ids[0])
        server.cancel(ids[n // 2])
        server.drain()
        assert len(server.responses) == n, \
            f"trial {trial}: lost {n - len(server.responses)} requests"
        seen = [r.request_id for r in server.responses]
        assert len(set(seen)) == n, f"trial {trial}: duplicate terminals"
        assert set(seen) == set(ids)
        assert all(r.status in STATUSES for r in server.responses)


def test_duplicate_terminal_response_raises():
    server = ClairvoyantServer(policy="sjf", predictor=None)
    req = CompletionRequest(prompt="x")
    server.submit(req, true_output_tokens=10, klass="short")
    server.drain()
    dup = copy.deepcopy(server.responses[0])
    with pytest.raises(RuntimeError, match="already terminated"):
        server._finish(dup)


def test_mid_drain_raise_loses_no_request():
    """Regression: an engine exception raised mid-drain (organic bug, not
    an injected fault) must not drop the popped request."""
    server = ClairvoyantServer(policy="sjf", predictor=None, seed=0)
    orig = server._sim_execute
    calls = {"n": 0}

    def flaky(eng, rid, t, req):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("organic mid-drain bug")
        return orig(eng, rid, t, req)

    server._sim_execute = flaky
    for i in range(5):
        server.submit(CompletionRequest(prompt=f"r{i}"), arrival=0.0,
                      true_output_tokens=30, klass="short")
    server.drain()
    assert len(server.responses) == 5
    assert all(r.status == "ok" for r in server.responses)
    assert sum(r.retries for r in server.responses) == 1
    assert server.fault_stats["retries"] == 1


def test_mid_drain_unrecoverable_fails_terminally():
    """A persistently-raising engine exhausts retries: terminal ``failed``
    responses with the error attached, never an exception to the caller."""
    server = ClairvoyantServer(policy="sjf", predictor=None,
                               retry=RetryPolicy(max_retries=1, seed=0))

    def broken(eng, rid, t, req):
        raise RuntimeError("backend is gone")

    server._sim_execute = broken
    for i in range(3):
        server.submit(CompletionRequest(prompt=f"r{i}"),
                      true_output_tokens=30, klass="short")
    server.drain()
    assert len(server.responses) == 3
    assert all(r.status == "failed" for r in server.responses)
    assert all("backend is gone" in r.error for r in server.responses)
    assert all(r.retries == 2 for r in server.responses)  # 1 + 1 retry
    assert server.fault_stats["failures"] == 3


# ------------------------------------------------- injected fault handling
def test_sim_crash_repair_is_work_conserving():
    from repro.data.tokenizer import approx_token_len
    plan = FaultPlan([FaultSpec(kind="crash", at=5.0, repair_s=2.0)])
    server = ClairvoyantServer(policy="sjf", predictor=None,
                               fault_plan=plan, seed=0)
    req = CompletionRequest(prompt="steady request")
    server.submit(req, arrival=0.0, true_output_tokens=600, klass="long")
    server.drain()
    (resp,) = server.responses
    full = server.service_model.service(approx_token_len(req.prompt), 600)
    assert full > 5.0                      # the crash lands mid-service
    assert resp.status == "ok" and resp.retries == 1
    # 5s served, 2s repair, then only the REMAINDER runs again
    assert resp.sojourn_s == pytest.approx(full + 2.0)
    assert server.fault_stats["crashes"] == 1
    assert server.fault_stats["requeues"] == 1


def test_sim_transient_retries_with_backoff():
    plan = FaultPlan([FaultSpec(kind="transient", at=0.0)])
    server = ClairvoyantServer(policy="sjf", predictor=None,
                               fault_plan=plan, seed=0)
    server.submit(CompletionRequest(prompt="x"), true_output_tokens=40,
                  klass="short")
    server.drain()
    (resp,) = server.responses
    assert resp.status == "ok" and resp.retries == 1
    assert server.fault_stats["transients"] == 1
    assert resp.queue_wait_s > 0.0         # the backoff delay is charged


def test_deadline_shedding_bounds_the_queue():
    server = ClairvoyantServer(policy="fcfs", predictor=None,
                               deadline_s=8.0, seed=0)
    for i in range(10):
        server.submit(CompletionRequest(prompt=f"r{i}"), arrival=0.0,
                      true_output_tokens=300, klass="long")
    server.drain()
    assert len(server.responses) == 10
    shed = [r for r in server.responses if r.status == "shed"]
    ok = [r for r in server.responses if r.status == "ok"]
    assert shed and ok
    assert all("deadline" in r.error for r in shed)
    assert all(r.service_s == 0.0 and r.tokens_generated == 0 for r in shed)
    # served requests all dispatched within budget
    assert all(r.queue_wait_s <= 8.0 for r in ok)
    assert server.fault_stats["sheds"] == len(shed)
    # percentile() defaults to ok responses only; pooling needs statuses=None
    assert np.isfinite(server.percentile(99))
    assert server.percentile(99) == server.percentile(99, statuses=("ok",))
    assert len(server.ok_responses) == len(ok)


def test_queue_overflow_sheds_at_admission():
    server = ClairvoyantServer(policy="sjf", predictor=None,
                               max_queue_depth=2, seed=0)
    placements = [
        server.submit(CompletionRequest(prompt=f"r{i}"), arrival=0.0,
                      true_output_tokens=40, klass="short")
        for i in range(5)]
    assert placements[:2] == [0, 0] and placements[2:] == [-1, -1, -1]
    server.drain()
    statuses = sorted(r.status for r in server.responses)
    assert statuses == ["ok", "ok", "shed", "shed", "shed"]
    assert all(r.error == "admission queue overflow"
               for r in server.responses if r.status == "shed")


def test_overflow_window_sheds_during_interval():
    plan = FaultPlan([FaultSpec(kind="overflow", at=10.0, duration=5.0)])
    server = ClairvoyantServer(policy="sjf", predictor=None,
                               fault_plan=plan, seed=0)
    a = server.submit(CompletionRequest(prompt="a"), arrival=9.0,
                      true_output_tokens=40, klass="short")
    b = server.submit(CompletionRequest(prompt="b"), arrival=12.0,
                      true_output_tokens=40, klass="short")
    c = server.submit(CompletionRequest(prompt="c"), arrival=16.0,
                      true_output_tokens=40, klass="short")
    assert (a, b, c) == (0, -1, 0)


# --------------------------------------------- predictor degradation (FCFS)
class _FlakyPredictor:
    """Scores by prompt content; raises (or emits NaN) when failing."""

    def __init__(self):
        self.mode = "ok"                   # ok | raise | nan

    def proba_batch(self, prompts):
        if self.mode == "raise":
            raise RuntimeError("predictor OOD crash")
        out = np.array([[0.05, 0.05, 0.9] if "long" in p
                        else [0.9, 0.05, 0.05] for p in prompts])
        if self.mode == "nan":
            out[0, 2] = np.nan
        return out


def _degradation_phase(server, tag):
    prompts = [f"long {tag} 0", f"short {tag} 1", f"short {tag} 2",
               f"long {tag} 3"]
    toks = [500, 40, 40, 500]
    klasses = ["long", "short", "short", "long"]
    before = len(server.responses)
    for p, tk, kl in zip(prompts, toks, klasses):
        server.submit(CompletionRequest(prompt=p), arrival=0.0,
                      true_output_tokens=tk, klass=kl)
    server.drain()
    return server.responses[before:]


def test_predictor_outage_degrades_to_fcfs_then_recovers():
    pred = _FlakyPredictor()
    server = ClairvoyantServer(policy="sjf", predictor=pred, seed=0)

    # phase 1: predictor down -> FCFS admission, no exception to callers
    pred.mode = "raise"
    phase1 = _degradation_phase(server, "p1")
    assert server.degraded
    assert server.fault_stats["predictor_failures"] >= 1
    assert server.fault_stats["degraded_admissions"] == 4
    assert all(r.degraded for r in phase1)
    assert all(r.p_long == 0.0 for r in phase1)
    # FCFS: completion follows submission order — the long head blocks
    assert [r.klass for r in phase1] == ["long", "short", "short", "long"]

    # phase 2: predictor healed -> SJF restored (shorts jump the longs)
    pred.mode = "ok"
    phase2 = _degradation_phase(server, "p2")
    assert not server.degraded
    assert not any(r.degraded for r in phase2)
    assert [r.klass for r in phase2] == ["short", "short", "long", "long"]

    # phase 3: non-finite scores degrade exactly like an exception
    pred.mode = "nan"
    phase3 = _degradation_phase(server, "p3")
    assert server.degraded and all(r.degraded for r in phase3)
    assert [r.klass for r in phase3] == ["long", "short", "short", "long"]


def test_predictor_outage_window_from_fault_plan():
    pred = _FlakyPredictor()
    plan = FaultPlan([FaultSpec(kind="predictor_down", at=0.0,
                                duration=10.0)])
    server = ClairvoyantServer(policy="sjf", predictor=pred,
                               fault_plan=plan, seed=0)
    server.submit(CompletionRequest(prompt="long x"), arrival=5.0,
                  true_output_tokens=500, klass="long")
    assert server.degraded                  # inside the outage window
    server.submit(CompletionRequest(prompt="long y"), arrival=15.0,
                  true_output_tokens=500, klass="long")
    assert not server.degraded              # window closed, healed
    server.drain()
    assert [r.degraded for r in server.responses] == [True, False]


# ------------------------------------------------ real + batched chaos
def test_real_engine_injected_crash_retries_and_completes():
    from repro.configs import get_config
    from repro.serving.engine import RealEngine

    cfg = get_config("smollm-360m").reduced()
    eng = RealEngine(cfg, max_len=64, segment_len=8)
    plan = FaultPlan([FaultSpec(kind="crash", after_polls=2, replica=0,
                                repair_s=0.02)])
    server = ClairvoyantServer(policy="sjf_oracle", engines=[eng],
                               fault_plan=plan, seed=0)
    for i in range(3):
        server.submit(CompletionRequest(prompt=f"real req {i}"),
                      true_output_tokens=12, klass="short")
    resp = server.drain(max_new_tokens=12)
    assert len(resp) == 3
    assert all(r.status == "ok" for r in resp)
    assert all(r.tokens_generated == 12 for r in resp)
    assert server.fault_stats["crashes"] == 1
    assert sum(r.retries for r in resp) == 1


def test_batched_lane_crash_resumes_work_conserving():
    from repro.configs import get_config
    from repro.serving.engine import BatchedRealEngine

    cfg = get_config("smollm-360m").reduced()
    eng = BatchedRealEngine(cfg, max_len=64, segment_len=4, n_lanes=2)
    plan = FaultPlan([FaultSpec(kind="lane_crash", after_polls=1,
                                replica=0)])
    server = ClairvoyantServer(policy="sjf_oracle", engines=[eng],
                               fault_plan=plan, seed=0)
    for i in range(4):
        server.submit(CompletionRequest(prompt=f"lane req {i}"),
                      true_output_tokens=10, klass="short")
    resp = server.drain(max_new_tokens=10)
    assert len(resp) == 4
    assert all(r.status == "ok" for r in resp)
    assert server.fault_stats["crashes"] == 1
    victims = [r for r in resp if r.retries == 1]
    assert len(victims) == 1
    # resume re-prefill is work-conserving: full token count delivered
    assert victims[0].tokens_generated == 10


def test_batched_whole_engine_crash_evicts_and_drains():
    from repro.configs import get_config
    from repro.serving.engine import BatchedRealEngine

    cfg = get_config("smollm-360m").reduced()
    eng = BatchedRealEngine(cfg, max_len=64, segment_len=4, n_lanes=2)
    plan = FaultPlan([FaultSpec(kind="crash", after_polls=1, replica=0,
                                repair_s=0.0)])
    server = ClairvoyantServer(policy="sjf_oracle", engines=[eng],
                               fault_plan=plan, seed=0)
    for i in range(4):
        server.submit(CompletionRequest(prompt=f"crash req {i}"),
                      true_output_tokens=8, klass="short")
    resp = server.drain(max_new_tokens=8)
    assert len(resp) == 4
    assert all(r.status == "ok" for r in resp)
    assert server.fault_stats["crashes"] >= 1


# --------------------------------------------------------- DES fault mirror
def test_simulate_faulty_nofault_is_bitwise_trace_equal():
    rng = np.random.default_rng(0)
    for trial in range(12):
        n = int(rng.integers(5, 150))
        reqs = poisson_workload(np.random.default_rng(trial), n, 0.12,
                                SHORT, LONG)
        pol = ["fcfs", "sjf", "sjf_oracle"][trial % 3]
        tau = [None, -1.0, 0.0, 4.0, 60.0][trial % 5]
        a = simulate(copy.deepcopy(reqs), policy=pol, tau=tau)
        b = simulate_faulty(copy.deepcopy(reqs), policy=pol, tau=tau)
        assert b.shed == 0 and b.requeues == 0
        assert a.promotions == b.promotions
        ra = sorted(a.requests, key=lambda r: r.req_id)
        rb = sorted(b.requests, key=lambda r: r.req_id)
        for x, y in zip(ra, rb):
            assert x.start == y.start and x.finish == y.finish \
                and x.promoted == y.promoted, f"trial {trial} diverged"


def test_simulate_grid_faults_nofault_matches_every_engine():
    rng = np.random.default_rng(1)
    n = 80
    arr = np.sort(np.round(rng.exponential(1.0, n).cumsum(), 2))
    svc = rng.uniform(0.5, 9.0, n)
    key = dispatch_key("sjf", arr, np.round(rng.uniform(0, 1, n), 1), svc)
    for engine in ("python", "auto"):
        s0, f0, p0, m0 = simulate_grid(arr[None], svc[None], key[None],
                                       (3.0,), engine=engine)
        s1, f1, p1, m1, shed, tmo, rq = simulate_grid_faults(
            arr[None], svc[None], key[None], (3.0,), ServerFaults())
        assert np.array_equal(s0, s1) and np.array_equal(f0, f1)
        assert np.array_equal(p0, p1) and np.array_equal(m0, m1)
        assert not shed.any() and not tmo.any() and rq[0] == 0


def test_server_faults_validates_windows():
    with pytest.raises(ValueError):
        ServerFaults(downs=((5.0, 3.0),))            # up <= down
    with pytest.raises(ValueError):
        ServerFaults(downs=((0.0, 5.0), (4.0, 8.0)))  # overlapping
    with pytest.raises(ValueError):
        ServerFaults(slowdowns=((0.0, 5.0, 0.5),))   # factor <= 1
    f = ServerFaults.random(np.random.default_rng(0), 500.0, mtbf=50.0,
                            mttr=5.0, stall_mtbf=100.0)
    ServerFaults(downs=f.downs, slowdowns=f.slowdowns)  # self-consistent
    assert ServerFaults.random(np.random.default_rng(0), 500.0).downs == ()


def test_des_crash_requeue_is_work_conserving():
    arr = np.array([0.0, 0.1])
    svc = np.array([4.0, 1.0])
    key = dispatch_key("fcfs", arr, svc * 0, svc)
    flt = ServerFaults(downs=((2.0, 5.0),))
    s, f, p, m, shed, _tmo, rq = simulate_grid_faults(
        arr[None], svc[None], key[None], (None,), flt)
    # req0 serves 2s, crashes, resumes at t=5 for the REMAINING 2s
    assert rq[0] == 1 and not shed.any()
    assert f[0][0] == pytest.approx(7.0) and f[0][1] == pytest.approx(8.0)
    assert s[0][0] == 0.0                   # start records FIRST dispatch


def test_des_stall_window_stretches_service():
    arr = np.array([0.0])
    svc = np.array([4.0])
    key = dispatch_key("fcfs", arr, svc * 0, svc)
    flt = ServerFaults(slowdowns=((0.0, 2.0, 2.0),))
    _, f, _, _, _, _, _ = simulate_grid_faults(
        arr[None], svc[None], key[None], (None,), flt)
    # 2s wall inside the 2x window = 1s of work; 3s more outside
    assert f[0][0] == pytest.approx(5.0)


def test_des_deadline_sheds_only_undispatched_work():
    arr = np.array([0.0, 0.1, 0.2])
    svc = np.array([10.0, 1.0, 1.0])
    key = dispatch_key("fcfs", arr, svc * 0, svc)
    s, f, p, m, shed, _tmo, rq = simulate_grid_faults(
        arr[None], svc[None], key[None], (None,), ServerFaults(),
        deadline=5.0)
    assert shed[0].tolist() == [False, True, True]
    assert np.isnan(f[0][1]) and np.isnan(f[0][2])
    # a crashed-and-requeued request is NOT shed (service already started)
    flt = ServerFaults(downs=((2.0, 9.0),))
    s, f, p, m, shed, _tmo, rq = simulate_grid_faults(
        arr[None][:, :1], svc[None][:, :1], key[None][:, :1], (None,),
        flt, deadline=5.0)
    assert not shed.any() and rq[0] == 1
    assert f[0][0] == pytest.approx(17.0)   # 2 + 7 down + 8 remaining


def test_simulate_faulty_percentiles_exclude_shed():
    reqs = poisson_workload(np.random.default_rng(2), 200, 0.3, SHORT, LONG)
    res = simulate_faulty(reqs, policy="sjf", tau=None,
                          faults=ServerFaults(downs=((10.0, 30.0),)),
                          deadline=25.0)
    assert res.shed > 0 and res.served == 200 - res.shed
    assert np.isfinite(res.percentile(99))
    assert all(r.meta.get("shed") for r in res.requests
               if r.finish is not None and np.isnan(r.finish))


def test_sweep_faults_grid_shapes_and_nofault_column():
    from repro.core.sweep import FAULT_METRICS, sweep_faults
    conditions = [("fcfs", None), ("sjf", 10.5)]
    res = sweep_faults(conditions, mtbfs=(float("inf"), 60.0),
                       repairs=(4.0, 12.0), seeds=(0, 1), n=200,
                       short=SHORT, long=LONG, rho=0.74)
    assert res.conditions == (("fcfs", None), ("sjf", 10.5))
    for m in FAULT_METRICS:
        assert res.metric(m).shape == (2, 2, 2, 2)
    # the mtbf=inf column is repair-invariant (no crash windows exist)
    np.testing.assert_array_equal(res.metric("short_p50")[:, 0, 0],
                                  res.metric("short_p50")[:, 0, 1])
    assert (res.metric("requeues")[:, 0] == 0).all()
    assert (res.metric("requeues")[:, 1] > 0).any()
    assert (res.metric("goodput") > 0).all()
    # faults hurt: faulted mean sojourn >= the no-fault column's
    assert (res.metric("mean_sojourn")[:, 1, 1]
            >= res.metric("mean_sojourn")[:, 0, 1]).all()


# ------------------------------------------------- native compile fallback
def test_native_fallback_numpy_scorer_is_bitwise_equal(monkeypatch):
    from repro.core.ensemble_pack import pack_ensemble
    from repro.core.gbdt import GBDTParams, train_gbdt
    params = GBDTParams(num_rounds=6, max_depth=3, n_classes=3)
    rng = np.random.default_rng(0)
    y = rng.integers(0, 3, 400)
    X = rng.normal(0, 1, (400, 9)).astype(np.float32)
    X[:, 0] += y * 1.3
    model = train_gbdt(X, y, params)
    packed = pack_ensemble(model)
    dense = model.predict_margin_dense(X)
    monkeypatch.setitem(_native._cache, "gbdt", None)  # "no C compiler"
    assert _native.native_scorer() is None
    np.testing.assert_array_equal(packed.predict_margin(X), dense)


def test_native_fallback_heapq_des_is_bitwise_equal(monkeypatch):
    rng = np.random.default_rng(3)
    n = 120
    arr = np.sort(np.round(rng.exponential(0.8, n).cumsum(), 2))
    svc = rng.uniform(0.5, 9.0, n)
    key = dispatch_key("sjf", arr, np.round(rng.uniform(0, 1, n), 1), svc)
    want = simulate_grid(arr[None], svc[None], key[None], (5.0,),
                         engine="python")
    monkeypatch.setitem(_native._cache, "des", None)   # "no C compiler"
    assert _native.native_des() is None
    got = simulate_grid(arr[None], svc[None], key[None], (5.0,),
                        engine="auto")                 # silently degrades
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    with pytest.raises(RuntimeError):                  # explicit native: loud
        simulate_grid(arr[None], svc[None], key[None], (5.0,),
                      engine="native")


def test_compile_failure_degrades_to_none(monkeypatch):
    """A compiler failure at first use caches None — every consumer sees
    the fallback, nothing raises."""
    monkeypatch.setattr(_native, "_cache", {})
    monkeypatch.setattr(_native, "_compile_lib", lambda *a, **k: None)
    assert _native.native_scorer() is None
    assert _native.native_des() is None
    assert _native.native_des_preempt() is None
    assert "des" in _native._cache                     # cached, not retried
