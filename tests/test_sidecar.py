"""Wire-level robustness suite (PR 7): the asyncio HTTP/SSE sidecar.

Covers the per-server request-id regression, DES in-service ``timeout``
semantics (sojourn deadlines in the fault engine + the sweep column),
SSE framing, deadline/backpressure/rate-limit status codes, disconnect
cancellation (queued and mid-generation), graceful shutdown under load,
and the acceptance gate: a >=200-request loopback chaos drain (seeded
crashes + transients, >=10% client disconnects, sub-service deadlines)
that loses zero requests — every admitted request exits with exactly
one terminal status and every surviving client reads a well-formed
JSON or SSE response.
"""

import asyncio
import json

import numpy as np
import pytest

from repro.core.sim_fast import (ServerFaults, dispatch_key,
                                 simulate_grid_faults)
from repro.core.simulation import (ServiceDist, poisson_workload,
                                   simulate_faulty)
from repro.serving.backends import SimTextBackend, tokens_to_text
from repro.serving.faults import FaultPlan, FaultSpec, RetryPolicy
from repro.serving.http_sidecar import Sidecar, TokenBucket
from repro.serving.openai_api import (HTTP_STATUS, STATUSES,
                                      CompletionRequest)
from repro.serving.server import ClairvoyantServer
from repro.serving.service_time import ServiceTimeModel

SHORT = ServiceDist(mean=3.5, std=0.8)
LONG = ServiceDist(mean=8.9, std=2.0)


# ------------------------------------------------- per-server id regression
def test_request_ids_are_per_server():
    """Two servers must not share an id space (the old process-global
    counter cross-poisoned `_terminal` bookkeeping between servers)."""
    a = ClairvoyantServer(policy="fcfs", n_replicas=1, seed=0)
    b = ClairvoyantServer(policy="fcfs", n_replicas=1, seed=0)
    ra = [CompletionRequest(prompt=f"a{i}") for i in range(3)]
    rb = [CompletionRequest(prompt=f"b{i}") for i in range(2)]
    for r in ra:
        a.submit(r, true_output_tokens=4)
    for r in rb:
        b.submit(r, true_output_tokens=4)
    assert [r.request_id for r in ra] == [1, 2, 3]
    assert [r.request_id for r in rb] == [1, 2]      # NOT [4, 5]
    a.drain(), b.drain()
    assert set(a._terminal) == {1, 2, 3} and set(b._terminal) == {1, 2}


def test_duplicate_request_id_rejected_and_allocate_reserves():
    s = ClairvoyantServer(policy="fcfs", n_replicas=1, seed=0)
    s.submit(CompletionRequest(prompt="x", request_id=7),
             true_output_tokens=4)
    with pytest.raises(ValueError):
        s.submit(CompletionRequest(prompt="y", request_id=7),
                 true_output_tokens=4)
    assert s.allocate_id() == 8                      # bumped past explicit
    r = CompletionRequest(prompt="z")
    s.submit(r, true_output_tokens=4)
    assert r.request_id == 9


# ------------------------------------------- DES in-service timeout (sojourn)
def test_des_sojourn_timeout_vs_queue_deadline():
    arr = np.array([0.0, 3.0])
    svc = np.array([10.0, 1.0])
    key = dispatch_key("fcfs", arr, svc * 0, svc)
    # queue-wait semantics (PR 6): started work always completes; the
    # second request sheds after waiting past its budget
    _, f, _, _, shed, tmo, _ = simulate_grid_faults(
        arr[None], svc[None], key[None], (None,), ServerFaults(),
        deadline=4.0)
    assert shed[0].tolist() == [False, True] and not tmo.any()
    assert f[0][0] == pytest.approx(10.0)
    # sojourn semantics: the first request is abandoned AT its deadline
    # (t=4) freeing the server; the second now starts at 4 and makes it
    s, f, _, _, shed, tmo, _ = simulate_grid_faults(
        arr[None], svc[None], key[None], (None,), ServerFaults(),
        deadline=4.0, in_service_timeout=True)
    assert tmo[0].tolist() == [True, False]
    assert shed[0].tolist() == [False, False]
    assert f[0][0] == pytest.approx(4.0)             # freed at expiry
    assert s[0][1] == pytest.approx(4.0)
    assert f[0][1] == pytest.approx(5.0)


def test_des_completion_exactly_at_deadline_is_ok():
    arr = np.array([0.0])
    svc = np.array([5.0])
    key = dispatch_key("fcfs", arr, svc * 0, svc)
    _, f, _, _, shed, tmo, _ = simulate_grid_faults(
        arr[None], svc[None], key[None], (None,), ServerFaults(),
        deadline=5.0, in_service_timeout=True)
    assert not tmo.any() and not shed.any()
    assert f[0][0] == pytest.approx(5.0)


def test_simulate_faulty_counts_timeouts():
    reqs = poisson_workload(np.random.default_rng(3), 200, 0.3,
                            SHORT, LONG)
    res = simulate_faulty(reqs, policy="sjf", deadline=9.0,
                          in_service_timeout=True)
    assert res.timeouts > 0
    assert res.served == 200 - res.shed - res.timeouts
    tagged = [r for r in res.requests if r.meta.get("timeout")]
    assert len(tagged) == res.timeouts
    for r in tagged:                                 # abandoned at expiry
        assert r.finish == pytest.approx(r.arrival + 9.0)


def test_sweep_faults_timeout_rate_column():
    from repro.core.sweep import FAULT_METRICS, sweep_faults
    assert "timeout_rate" in FAULT_METRICS
    res = sweep_faults([("fcfs", None), ("sjf", 10.5)],
                       mtbfs=(float("inf"),), repairs=(4.0,),
                       seeds=(0, 1), n=150, short=SHORT, long=LONG,
                       rho=0.9, deadline=12.0, in_service_timeout=True)
    tr = res.metric("timeout_rate")
    assert tr.shape == (2, 1, 1, 2) and (tr > 0).any()
    # goodput accounts for both shed AND timed-out work
    assert (res.metric("goodput")
            <= 1.0 - res.metric("timeout_rate") + 1e-12).all()


def test_server_sim_drain_sojourn_timeout():
    srv = ClairvoyantServer(policy="fcfs", n_replicas=1, deadline_s=5.0,
                            deadline_mode="sojourn", seed=0)
    long_req = CompletionRequest(prompt="long")
    srv.submit(long_req, arrival=0.0, true_output_tokens=2000)
    srv.drain()
    resp = srv.responses[0]
    assert resp.status == "timeout" and "in service" in resp.error
    assert srv.fault_stats["timeouts"] == 1
    # same workload under queue-wait semantics completes
    srv2 = ClairvoyantServer(policy="fcfs", n_replicas=1, deadline_s=5.0,
                             deadline_mode="queue", seed=0)
    srv2.submit(CompletionRequest(prompt="long"), arrival=0.0,
                true_output_tokens=2000)
    srv2.drain()
    assert srv2.responses[0].status == "ok"


# ------------------------------------------------------------ wire helpers
def _make_sidecar(n_replicas=2, time_scale=0.01, specs=None, **kw):
    model = ServiceTimeModel(prefill_tok_per_s=8000.0,
                             decode_tok_per_s=60.0)
    backends = [SimTextBackend(model, replica_id=i, time_scale=time_scale)
                for i in range(n_replicas)]
    sidecar_kw = {k: kw.pop(k) for k in
                  ("max_inflight", "tenant_rate", "tenant_burst",
                   "drain_s", "write_timeout_s") if k in kw}
    server = ClairvoyantServer(
        policy="sjf", tau=1.0, engines=backends, service_model=model,
        deadline_mode="sojourn", seed=0,
        fault_plan=FaultPlan(specs) if specs else None,
        retry=RetryPolicy(max_retries=2, base_s=0.01, seed=0), **kw)
    return Sidecar(server, port=0, **sidecar_kw)


def _parse_http(data: bytes):
    head, _, body = data.partition(b"\r\n\r\n")
    lines = head.decode("latin-1").split("\r\n")
    status = int(lines[0].split()[1])
    hdrs = {}
    for ln in lines[1:]:
        k, _, v = ln.partition(":")
        hdrs[k.strip().lower()] = v.strip()
    return status, hdrs, body


def _parse_sse(body: bytes):
    frames = []
    for block in body.decode().split("\n\n"):
        block = block.strip()
        if not block:
            continue
        assert block.startswith("data: "), f"bad SSE frame: {block!r}"
        frames.append(block[len("data: "):])
    return frames


async def _request(port, body=None, headers=None, method="POST",
                   path="/v1/chat/completions", disconnect_after=None):
    """One raw loopback HTTP exchange.  Returns ("json", status, obj),
    ("sse", status, frames) or ("disconnected", None, None)."""
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    try:
        payload = b"" if body is None else json.dumps(body).encode()
        hdrs = {"Host": "loopback", "Connection": "close"}
        if payload:
            hdrs["Content-Type"] = "application/json"
            hdrs["Content-Length"] = str(len(payload))
        hdrs.update(headers or {})
        writer.write((f"{method} {path} HTTP/1.1\r\n" + "".join(
            f"{k}: {v}\r\n" for k, v in hdrs.items()) + "\r\n"
        ).encode() + payload)
        await writer.drain()
        if disconnect_after is not None:
            await asyncio.sleep(disconnect_after)
            return "disconnected", None, None
        data = await asyncio.wait_for(reader.read(), timeout=30.0)
        status, rhdrs, rbody = _parse_http(data)
        if rhdrs.get("content-type", "").startswith("text/event-stream"):
            return "sse", status, _parse_sse(rbody)
        return "json", status, json.loads(rbody) if rbody else {}
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass


def _no_leaked_tasks():
    cur = asyncio.current_task()
    return [t for t in asyncio.all_tasks() if t is not cur and not t.done()]


# ------------------------------------------------------------- wire units
def test_token_bucket():
    tb = TokenBucket(rate=2.0, burst=2.0)
    assert tb.allow(0.0) == (True, 0.0)
    assert tb.allow(0.0)[0]
    ok, after = tb.allow(0.0)
    assert not ok and after == pytest.approx(0.5)
    ok, _ = tb.allow(0.6)                            # refilled > 1 token
    assert ok


def test_sse_framing_and_stream_roundtrip():
    async def run():
        sc = _make_sidecar(n_replicas=1)
        await sc.start()
        try:
            kind, status, frames = await _request(sc.port, {
                "messages": [{"role": "user", "content": "stream please"}],
                "max_tokens": 64, "stream": True, "output_tokens": 24})
            assert (kind, status) == ("sse", 200)
            assert frames[-1] == "[DONE]"
            chunks = [json.loads(f) for f in frames[:-1]]
            assert all(c["object"] == "chat.completion.chunk"
                       for c in chunks)
            assert len({c["id"] for c in chunks}) == 1
            text = "".join(c["choices"][0]["delta"].get("content", "")
                           for c in chunks)
            # deltas reassemble the full completion text
            assert text.split() == [f"t{i}" for i in range(24)]
            finals = [c["choices"][0]["finish_reason"] for c in chunks]
            assert finals[-1] == "stop" and set(finals[:-1]) == {None}
        finally:
            await sc.shutdown(drain_s=1.0)
        assert not _no_leaked_tasks()
    asyncio.run(run())


def test_non_stream_completion_body():
    async def run():
        sc = _make_sidecar(n_replicas=1)
        await sc.start()
        try:
            kind, status, obj = await _request(sc.port, {
                "messages": [{"role": "user", "content": "hello"}],
                "max_tokens": 8, "output_tokens": 8})
            assert (kind, status) == ("json", 200)
            assert obj["object"] == "chat.completion"
            assert obj["choices"][0]["finish_reason"] == "stop"
            assert obj["choices"][0]["message"]["content"] \
                == tokens_to_text(range(8))
            cl = obj["clairvoyant"]
            assert cl["status"] == "ok" and cl["ttft_s"] is not None
        finally:
            await sc.shutdown(drain_s=1.0)
    asyncio.run(run())


def test_health_and_ready_endpoints():
    async def run():
        sc = _make_sidecar(n_replicas=2)
        await sc.start()
        try:
            kind, status, obj = await _request(sc.port, method="GET",
                                               path="/healthz")
            assert status == 200 and obj["status"] == "ok"
            assert len(obj["replicas"]) == 2
            kind, status, obj = await _request(sc.port, method="GET",
                                               path="/readyz")
            assert status == 200 and obj["ready"]
            sc._stopping = True                      # draining: not ready
            kind, status, obj = await _request(sc.port, method="GET",
                                               path="/readyz")
            assert status == 503 and not obj["ready"]
            sc._stopping = False
            kind, status, _ = await _request(sc.port, method="GET",
                                             path="/nope")
            assert status == 404
        finally:
            await sc.shutdown(drain_s=1.0)
    asyncio.run(run())


def test_tenant_rate_limit_429_never_reaches_scheduler():
    async def run():
        sc = _make_sidecar(n_replicas=1, tenant_rate=1.0, tenant_burst=1.0)
        await sc.start()
        try:
            body = {"prompt": "hi", "max_tokens": 4, "output_tokens": 4}
            kind, status, _ = await _request(
                sc.port, body, headers={"X-Tenant": "acme"})
            assert status == 200
            kind, status, obj = await _request(
                sc.port, body, headers={"X-Tenant": "acme"})
            assert status == 429 and obj["error"]["type"] == "shed"
            # a different tenant has its own bucket
            kind, status, _ = await _request(
                sc.port, body, headers={"X-Tenant": "other"})
            assert status == 200
        finally:
            await sc.shutdown(drain_s=1.0)
        # the rate-limited request was refused at the wire: only the two
        # admitted ones ever reached the scheduler's terminal gate
        assert sorted(sc.server._terminal) == [1, 2]
        assert sc.wire_stats["rate_limited"] == 1
    asyncio.run(run())


def test_inflight_cap_returns_503_with_retry_after():
    async def run():
        sc = _make_sidecar(n_replicas=1, max_inflight=1)
        await sc.start()
        try:
            slow = asyncio.create_task(_request(sc.port, {
                "prompt": "slow", "max_tokens": 512,
                "output_tokens": 400}))
            await asyncio.sleep(0.05)                # slow one is in flight
            reader, writer = await asyncio.open_connection(
                "127.0.0.1", sc.port)
            payload = json.dumps({"prompt": "x", "max_tokens": 4}).encode()
            writer.write((
                "POST /v1/chat/completions HTTP/1.1\r\nHost: t\r\n"
                f"Content-Length: {len(payload)}\r\n\r\n"
            ).encode() + payload)
            await writer.drain()
            status, hdrs, body = _parse_http(await reader.read())
            writer.close()
            assert status == 503 and "retry-after" in hdrs
            assert json.loads(body)["error"]["type"] == "shed"
            kind, status, _ = await slow
            assert status == 200
        finally:
            await sc.shutdown(drain_s=1.0)
    asyncio.run(run())


def test_deadline_timeout_and_predispatch_shed():
    async def run():
        sc = _make_sidecar(n_replicas=1)
        await sc.start()
        try:
            # expiry mid-generation: 504 with terminal status "timeout"
            kind, status, obj = await _request(sc.port, {
                "prompt": "too slow", "max_tokens": 512,
                "output_tokens": 400, "timeout_s": 0.05})
            assert (kind, status) == ("json", 504)
            assert obj["error"]["type"] == "timeout"
            # expiry while queued behind a long request: shed (429),
            # never dispatched
            blocker = asyncio.create_task(_request(sc.port, {
                "prompt": "blocker", "max_tokens": 512,
                "output_tokens": 400}))
            await asyncio.sleep(0.03)
            kind, status, obj = await _request(
                sc.port, {"prompt": "impatient", "max_tokens": 4,
                          "output_tokens": 4},
                headers={"X-Deadline-S": "0.01"})
            assert status == 429 and obj["error"]["type"] == "shed"
            await blocker
        finally:
            await sc.shutdown(drain_s=2.0)
        st = sc.server._terminal
        assert st[1] == "timeout" and st[3] == "shed" and st[2] == "ok"
        assert sc.server.fault_stats["timeouts"] == 1
    asyncio.run(run())


def test_disconnect_cancels_queued_and_midgeneration():
    async def run():
        sc = _make_sidecar(n_replicas=1)
        await sc.start()
        try:
            # A holds the replica mid-generation, B sits queued
            a = asyncio.create_task(_request(
                sc.port, {"prompt": "a", "max_tokens": 512,
                          "output_tokens": 300, "stream": True},
                disconnect_after=0.08))
            await asyncio.sleep(0.03)
            b = asyncio.create_task(_request(
                sc.port, {"prompt": "b", "max_tokens": 8,
                          "output_tokens": 8},
                disconnect_after=0.02))
            assert (await b)[0] == "disconnected"    # B: cancelled queued
            assert (await a)[0] == "disconnected"    # A: cancelled mid-gen
            for _ in range(200):
                if len(sc.server._terminal) == 2:
                    break
                await asyncio.sleep(0.01)
            st = dict(sc.server._terminal)
            # the freed replica still serves new work
            kind, status, _ = await _request(
                sc.port, {"prompt": "after", "max_tokens": 4,
                          "output_tokens": 4})
            assert status == 200
        finally:
            await sc.shutdown(drain_s=2.0)
        assert st == {1: "cancelled", 2: "cancelled"}
        by_id = {r.request_id: r for r in sc.server.responses}
        assert "mid-generation" in by_id[1].error
        assert "queued" in by_id[2].error
        assert sc.wire_stats["disconnects"] == 2
    asyncio.run(run())


# ------------------------------------------------- graceful shutdown gate
def test_graceful_shutdown_under_load_loses_nothing():
    async def run():
        sc = _make_sidecar(n_replicas=2, time_scale=0.01)
        await sc.start()
        clients = [asyncio.create_task(_request(sc.port, {
            "prompt": f"req {i}", "max_tokens": 256,
            "output_tokens": 80 + i, "stream": i % 2 == 0}))
            for i in range(24)]
        await asyncio.sleep(0.1)                     # mid-load SIGTERM
        await sc.shutdown(drain_s=0.2)
        outcomes = await asyncio.gather(*clients, return_exceptions=True)
        # late arrivals may be refused 503 (draining) — those were never
        # admitted; every ADMITTED request has exactly one terminal
        n_admitted = sc.server._next_id - 1
        assert n_admitted > 0
        assert sorted(sc.server._terminal) == list(
            range(1, n_admitted + 1))
        assert len(sc.server.responses) == n_admitted
        statuses = set(sc.server._terminal.values())
        assert statuses <= set(STATUSES)
        assert "cancelled" in statuses               # the drain cut someone
        # every client that kept its socket got a well-formed response
        for out in outcomes:
            assert not isinstance(out, Exception), out
            kind, status, frames = out
            if kind == "sse":
                assert frames[-1] == "[DONE]"
            else:
                assert status in (200, 429, 499, 502, 503, 504)
        assert not _no_leaked_tasks()
    asyncio.run(run())


# --------------------------------------------- THE acceptance chaos drain
def test_wire_chaos_drain_no_lost_requests():
    """>=200 loopback HTTP requests against a seeded fault plan (segment
    crashes + dispatch transients), >=10% random client disconnects and
    sub-service deadlines: zero lost requests, one terminal each."""
    N = 220
    rng = np.random.default_rng(7)
    specs = [FaultSpec(kind="crash", after_polls=p, repair_s=0.02)
             for p in (25, 80, 160, 260, 380)]
    specs += [FaultSpec(kind="transient", at=float(a))
              for a in rng.uniform(0.0, 1.5, 8)]

    async def one_client(i, port):
        otoks = int(rng.integers(4, 120))
        body = {"prompt": f"chaos request {i} " + "x" * int(
            rng.integers(0, 64)), "max_tokens": 512,
            "output_tokens": otoks, "stream": bool(rng.random() < 0.5)}
        headers = {"X-Tenant": f"t{i % 5}"}
        disconnect_after = None
        if rng.random() < 0.15:                      # impatient client
            disconnect_after = float(rng.uniform(0.0, 0.08))
        elif rng.random() < 0.18:                    # sub-service deadline
            headers["X-Deadline-S"] = f"{rng.uniform(0.004, 0.03):.4f}"
        await asyncio.sleep(float(rng.uniform(0, 0.4)))
        try:
            return await _request(port, body, headers=headers,
                                  disconnect_after=disconnect_after)
        except (ConnectionError, asyncio.IncompleteReadError) as e:
            return "conn_error", None, repr(e)

    async def run():
        sc = _make_sidecar(n_replicas=3, time_scale=0.008, specs=specs,
                           max_inflight=N + 8)
        await sc.start()
        outcomes = await asyncio.gather(
            *[one_client(i, sc.port) for i in range(N)])
        # wait for stragglers (disconnect terminals land asynchronously)
        for _ in range(600):
            if len(sc.server._terminal) == N:
                break
            await asyncio.sleep(0.01)
        await sc.shutdown(drain_s=2.0)
        srv = sc.server

        # ---- zero lost requests: ids 1..N, one terminal each ----
        assert sorted(srv._terminal) == list(range(1, N + 1))
        assert len(srv.responses) == N               # _finish raises on dup
        statuses = list(srv._terminal.values())
        assert set(statuses) <= set(STATUSES)
        counts = {s: statuses.count(s) for s in set(statuses)}
        assert counts.get("ok", 0) >= N // 2         # chaos, not collapse
        assert counts.get("cancelled", 0) >= 1       # disconnects landed
        assert counts.get("timeout", 0) + counts.get("shed", 0) >= 1
        assert srv.fault_stats["crashes"] + srv.fault_stats["transients"] \
            > 0                                      # the plan actually hit

        # ---- every surviving client read a well-formed terminal ----
        valid_codes = set(HTTP_STATUS.values())
        for out in outcomes:
            kind, status, payload = out
            if kind in ("disconnected", "conn_error"):
                continue
            if kind == "sse":
                assert status == 200 and payload[-1] == "[DONE]"
                for f in payload[:-1]:
                    json.loads(f)                    # every frame is JSON
            else:
                assert status in valid_codes
                assert isinstance(payload, dict)
                if status != 200:
                    assert payload["error"]["type"] in STATUSES
        assert not _no_leaked_tasks()
    asyncio.run(run())


# ------------------------------------- HTTP backend streaming passthrough
async def _dribble_upstream(frames, gap_s=0.12, record=None):
    """Minimal OpenAI-ish SSE upstream that writes one frame per gap —
    the loopback oracle for passthrough: a buffering client cannot see
    frame k before frame k+1 is even sent."""
    sent_t = []
    seen = {"payload": None, "reset": False}

    async def handle(reader, writer):
        data = b""
        while b"\r\n\r\n" not in data:
            data += await reader.read(4096)
        head, _, body = data.partition(b"\r\n\r\n")
        clen = 0
        for ln in head.split(b"\r\n"):
            if ln.lower().startswith(b"content-length:"):
                clen = int(ln.split(b":")[1])
        while len(body) < clen:
            body += await reader.read(4096)
        seen["payload"] = json.loads(body)
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Connection: close\r\n\r\n")
        try:
            for fr in frames:
                await writer.drain()
                writer.write(b"data: " + json.dumps(fr).encode() + b"\n\n")
                sent_t.append(asyncio.get_event_loop().time())
                await asyncio.sleep(gap_s)
            writer.write(b"data: [DONE]\n\n")
            await writer.drain()
        except (ConnectionError, OSError):
            seen["reset"] = True
        finally:
            writer.close()

    server = await asyncio.start_server(handle, "127.0.0.1", 0)
    if record is not None:
        record.update(seen=seen, sent_t=sent_t, server=server)
    return server, server.sockets[0].getsockname()[1]


def _delta_frame(text, finish=None):
    return {"choices": [{"delta": {"content": text},
                         "finish_reason": finish}]}


def test_http_backend_restreams_sse_at_arrival():
    """The http adapter must forward upstream SSE deltas as they arrive
    (ROADMAP item-3 leftover), not buffer the body: with the upstream
    dribbling a frame every 120 ms, every delta's arrival time must
    precede the send time of the LAST frame."""
    from repro.serving.backends import HTTPBackend

    async def run():
        rec = {}
        frames = [_delta_frame(f"w{i} ") for i in range(4)]
        frames[-1]["choices"][0]["finish_reason"] = "stop"
        server, port = await _dribble_upstream(frames, record=rec)
        got = []
        loop = asyncio.get_event_loop()
        try:
            be = HTTPBackend("127.0.0.1", port)
            out = await be.generate(
                "hi", max_new_tokens=16,
                on_segment=lambda d: got.append((loop.time(), d)))
        finally:
            server.close()
            await server.wait_closed()
        assert rec["seen"]["payload"]["stream"] is True
        assert [d for _, d in got] == [f"w{i} " for i in range(4)]
        assert out["text"] == "w0 w1 w2 w3 "
        assert not out["cancelled"]
        last_sent = rec["sent_t"][-1]
        # passthrough: the first three deltas were in hand BEFORE the
        # upstream emitted its final frame (a buffered client sees
        # everything only after the stream closes)
        for t, _ in got[:-1]:
            assert t < last_sent, (got, rec["sent_t"])
    asyncio.run(run())


def test_http_backend_streams_for_cancel_only_and_stops_early():
    """A cancel_cb alone must also select streaming — the buffered path
    cannot observe cancellation until the upstream finishes — and a
    mid-stream cancel closes the upstream connection early."""
    from repro.serving.backends import HTTPBackend

    async def run():
        rec = {}
        frames = [_delta_frame(f"w{i} ") for i in range(50)]
        server, port = await _dribble_upstream(frames, gap_s=0.05,
                                               record=rec)
        fired = []

        def cancel_cb():
            return len(fired) >= 2
        try:
            be = HTTPBackend("127.0.0.1", port)
            # cancel-only: no on_segment, still streams
            out = await be.generate(
                "hi", max_new_tokens=64, cancel_cb=lambda: (
                    fired.append(1), len(fired) > 3)[1])
        finally:
            server.close()
            await server.wait_closed()
        assert rec["seen"]["payload"]["stream"] is True
        assert out["cancelled"]
        # far fewer than 50 frames were ever consumed
        assert len(out["text"].split()) < 10
    asyncio.run(run())
