"""Starvation-timeout calibration (core/calibration.py): tau = 3 x
mu_short, where mu_short is the mean Short *sojourn* under a mixed
concurrent burst — NOT the isolated sequential service time (the paper
is emphatic about the distinction; these are its first tests)."""

import numpy as np
import pytest

from repro.core.calibration import (TAU_MULTIPLIER, calibrate_tau,
                                    measure_mu_short)
from repro.core.simulation import ServiceDist

SHORT = ServiceDist(mean=3.5, std=0.8)
LONG = ServiceDist(mean=8.9, std=2.0)


def test_measure_mu_short_is_deterministic():
    a = measure_mu_short(SHORT, LONG, seed=0)
    b = measure_mu_short(SHORT, LONG, seed=0)
    assert a == b
    assert np.isfinite(a) and a > 0.0


def test_mu_short_is_sojourn_not_service():
    """Under a 100-request concurrent burst the mean Short sojourn is
    dominated by queueing, so it must far exceed the isolated mean
    service time — the distinction §3.4 hinges on."""
    mu = measure_mu_short(SHORT, LONG, n_short=50, n_long=50, seed=0)
    assert mu > 5.0 * SHORT.mean


def test_mu_short_scales_with_backlog():
    """More competing work -> longer Short sojourns (mu is a queueing
    quantity, so it must respond to load)."""
    light = measure_mu_short(SHORT, LONG, n_short=10, n_long=10, seed=0)
    heavy = measure_mu_short(SHORT, LONG, n_short=50, n_long=50, seed=0)
    assert heavy > light


def test_mu_short_policy_dependence():
    """SJF runs shorts first, so their mean sojourn under the burst must
    beat FCFS on the same workload seed."""
    sjf = measure_mu_short(SHORT, LONG, policy="sjf", seed=0)
    fcfs = measure_mu_short(SHORT, LONG, policy="fcfs", seed=0)
    assert sjf < fcfs


def test_calibrate_tau_is_multiplier_times_mu():
    mu = measure_mu_short(SHORT, LONG, seed=3)
    assert calibrate_tau(SHORT, LONG, seed=3) == TAU_MULTIPLIER * mu
    assert calibrate_tau(SHORT, LONG, multiplier=5.0, seed=3) == 5.0 * mu


def test_calibrate_tau_forwards_kwargs():
    a = calibrate_tau(SHORT, LONG, n_short=20, n_long=20, seed=7)
    b = calibrate_tau(SHORT, LONG, n_short=20, n_long=20, seed=8)
    assert a != b          # the seed reaches the workload generator


def test_calibrated_tau_bounds_long_wait_in_simulation():
    """End-to-end property (the guard's whole purpose, Table 9): under
    steady-state Poisson load with NOISY predictions, SJF with the
    calibrated tau caps the worst Long-class wait near tau, at near-zero
    short-P50 cost versus guard-off SJF on the same workload."""
    from repro.core.simulation import (imperfect_predictor,
                                      poisson_workload, simulate)
    tau = calibrate_tau(SHORT, LONG, n_short=10, n_long=10, seed=0)
    es = 0.5 * SHORT.mean + 0.5 * LONG.mean
    reqs = poisson_workload(
        np.random.default_rng(1), 2000, 0.74 / es, SHORT, LONG,
        p_long_fn=imperfect_predictor(np.random.default_rng(2), 0.87))
    guarded = simulate([_copy(r) for r in reqs], policy="sjf", tau=tau)
    free = simulate([_copy(r) for r in reqs], policy="sjf", tau=None)
    assert guarded.promotions > 0
    g_max = guarded.percentile(100, klass="long", attr="wait")
    f_max = free.percentile(100, klass="long", attr="wait")
    assert g_max < f_max                     # tail starvation capped...
    assert (guarded.percentile(50, klass="short")
            < 1.05 * free.percentile(50, klass="short"))  # ...cheaply


def _copy(r):
    from repro.core.scheduler import Request
    return Request(req_id=r.req_id, arrival=r.arrival, p_long=r.p_long,
                   true_service=r.true_service, klass=r.klass)
