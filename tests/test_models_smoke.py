"""Per-architecture reduced-config smoke tests (required deliverable f).

Each assigned architecture is instantiated at a REDUCED config of the same
family and runs a real forward + train step + prefill/decode on CPU,
asserting output shapes and finiteness.  FULL configs are only ever touched
by the dry-run (ShapeDtypeStruct, no allocation).
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.configs.base import SHAPES
from repro.models.frontends import make_batch
from repro.models.model import LM

B, S = 2, 16


def _reduced_lm(name):
    cfg = get_config(name).reduced()
    return cfg, LM(cfg)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name):
    cfg, lm = _reduced_lm(name)
    params = lm.init(jax.random.key(0))
    batch = make_batch(cfg, SHAPES["train_4k"], batch_size=B, seq_len=S)
    logits, aux = lm.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{name}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{name}: non-finite aux loss"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_grads_finite(name):
    cfg, lm = _reduced_lm(name)
    params = lm.init(jax.random.key(0))
    batch = make_batch(cfg, SHAPES["train_4k"], batch_size=B, seq_len=S)
    loss, grads = jax.value_and_grad(lm.loss)(params, batch)
    assert bool(jnp.isfinite(loss)), f"{name}: non-finite loss"
    bad = [p for p, leaf in jax.tree_util.tree_leaves_with_path(grads)
           if not bool(jnp.isfinite(leaf).all())]
    assert not bad, f"{name}: non-finite grads at {bad[:3]}"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_count_formula_matches_actual(name):
    """configs.base.param_count is the roofline's N — keep it exact."""
    cfg = get_config(name).reduced()
    lm = LM(cfg)
    assert cfg.param_count() == lm.param_count_actual()
    full = get_config(name)
    assert full.param_count() == LM(full).param_count_actual()


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_matches_forward(name):
    """Teacher-forced decode must reproduce the training forward logits."""
    cfg = get_config(name).reduced()
    if cfg.num_experts:
        # disable capacity dropping so routing is identical across paths
        cfg = dataclasses.replace(cfg, moe_capacity_factor=float(cfg.num_experts))
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    batch = make_batch(cfg, SHAPES["train_4k"], batch_size=B, seq_len=S)

    full_logits, _ = lm.forward(params, batch, remat=False)

    prompt = {k: (v[:, : S // 2]
                  if k in ("tokens", "frames") else v)
              for k, v in batch.items() if k != "labels"}
    logits_p, caches = lm.prefill(params, prompt, pad_to=S)
    assert jnp.allclose(logits_p, full_logits[:, S // 2 - 1], atol=2e-2), (
        f"{name}: prefill last-logit mismatch "
        f"{float(jnp.abs(logits_p - full_logits[:, S//2-1]).max())}")

    for t in range(S // 2, S // 2 + 3):
        if cfg.audio_frontend:
            step = {"frames": batch["frames"][:, t:t + 1]}
        else:
            step = {"tokens": batch["tokens"][:, t:t + 1]}
        if cfg.num_image_tokens:
            step["image_embeds"] = batch["image_embeds"]
        logits_d, caches = lm.decode_step(params, caches, step)
        err = float(jnp.abs(logits_d - full_logits[:, t]).max())
        assert err < 2e-2, f"{name}: decode step {t} mismatch {err}"
