"""Ranking-accuracy metric (Algorithm 1) properties + baselines.

Property tests use seeded ``np.random.default_rng`` loops (this container
has no hypothesis package).
"""

import numpy as np

from repro.core.ranking import (class_labels, classification_accuracy,
                                fit_prompt_length_threshold,
                                prompt_length_rule_scores, ranking_accuracy)


def test_perfect_ranker_scores_one():
    lengths = np.array([50, 60, 1000, 2000])
    scores = np.array([0.1, 0.2, 0.8, 0.9])
    assert ranking_accuracy(lengths, scores) == 1.0


def test_inverted_ranker_scores_zero():
    lengths = np.array([50, 60, 1000, 2000])
    scores = np.array([0.9, 0.8, 0.2, 0.1])
    assert ranking_accuracy(lengths, scores) == 0.0


def test_medium_excluded():
    lengths = np.array([50, 400, 500, 1000])
    # medium scores are irrelevant
    a = ranking_accuracy(lengths, np.array([0.1, 0.0, 1.0, 0.9]))
    b = ranking_accuracy(lengths, np.array([0.1, 0.9, 0.1, 0.9]))
    assert a == b == 1.0


def test_ties_conventions():
    lengths = np.array([50, 1000])
    tied = np.array([0.5, 0.5])
    assert ranking_accuracy(lengths, tied, ties="loss") == 0.0
    assert ranking_accuracy(lengths, tied, ties="half") == 0.5


def test_matches_naive_pair_count():
    """Vectorized metric equals the O(n^2) pair count (seeded rng loop)."""
    rng = np.random.default_rng(0)
    for trial in range(100):
        n = int(rng.integers(2, 120))
        lengths = rng.integers(0, 3000, n)
        scores = rng.random(n)
        if rng.random() < 0.3:       # force score ties sometimes
            scores = np.round(scores, 1)
        s = scores[lengths < 200]
        l = scores[lengths >= 800]
        if len(s) == 0 or len(l) == 0:
            assert np.isnan(ranking_accuracy(lengths, scores))
            continue
        naive = sum(float(lj > si) for si in s for lj in l) / (len(s) * len(l))
        assert abs(ranking_accuracy(lengths, scores) - naive) < 1e-12


def test_scale_invariance():
    """Monotone transforms of scores leave the metric unchanged.

    The transform must be EXACT in floats: an affine shift (x*7+3) absorbs
    subnormal differences and creates ties, legitimately flipping strict
    comparisons.  A power-of-two scale is exact.
    """
    rng = np.random.default_rng(1)
    for trial in range(100):
        n = int(rng.integers(2, 60))
        lengths = rng.integers(0, 3000, n)
        scores = rng.random(n)
        a = ranking_accuracy(lengths, scores)
        b = ranking_accuracy(lengths, scores * 8.0)
        assert (np.isnan(a) and np.isnan(b)) or a == b


def test_class_labels_boundaries():
    np.testing.assert_array_equal(class_labels(np.array([0, 199, 200, 799, 800])),
                                  [0, 0, 1, 1, 2])


def test_length_rule_threshold_fits_train():
    rng = np.random.default_rng(0)
    lengths = rng.choice([50, 1500], 400)
    plens = np.where(lengths > 800, 30, 10) + rng.integers(0, 5, 400)
    thr = fit_prompt_length_threshold(plens, lengths)
    acc = ranking_accuracy(lengths, prompt_length_rule_scores(plens, thr),
                           ties="half")
    assert acc > 0.9
