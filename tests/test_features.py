"""Feature extraction: exact 19-dim contract + properties."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import features as F


def test_feature_vector_is_19_dim():
    v = F.extract("Write a python function for binary search?")
    assert v.shape == (F.N_FEATURES,) == (19,)
    assert len(F.FEATURE_NAMES) == 19


def test_known_prompt_features():
    v = F.extract("Explain photosynthesis briefly?")
    assert v[0] == len("Explain photosynthesis briefly?") // 4
    assert v[2] == 1.0          # "briefly" length constraint
    assert v[3] == 1.0          # ends with ?
    assert v[6 + F.VERB_INDEX["explain"]] == 1.0


def test_code_and_format_keywords():
    v = F.extract("Implement an algorithm and return json")
    assert v[1] == 1.0 and v[4] == 1.0
    assert v[6 + F.VERB_INDEX["implement"]] == 1.0


def test_other_verb_bucket():
    v = F.extract("Ponder the sea")
    assert v[6 + len(F.INSTRUCTION_VERBS)] == 1.0


@settings(max_examples=200, deadline=None)
@given(st.text(max_size=400))
def test_extract_total_properties(s):
    v = F.extract(s)
    assert v.shape == (19,)
    assert np.isfinite(v).all()
    assert v[6:].sum() == 1.0            # verb one-hot sums to exactly 1
    assert set(np.unique(v[1:5])) <= {0.0, 1.0}
    assert v[0] == len(s) // 4
    assert v[5] >= 0


@settings(max_examples=50, deadline=None)
@given(st.lists(st.text(max_size=100), min_size=1, max_size=20))
def test_batch_matches_single(prompts):
    X = F.extract_batch(prompts)
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(X[i], F.extract(p))
