"""Feature extraction: exact 19-dim contract + randomized properties.

Property tests use seeded ``np.random.default_rng`` loops (this container
has no hypothesis package).
"""

import random
import string

import numpy as np

from repro.core import features as F


def _random_corpus(n_template=2000, n_noise=800):
    rng = random.Random(0)
    words = ["write", "a", "python", "function", "so", "that", "such",
             "briefly", "json", "table", "because", "which", "who?", "(who)",
             "that.", "essay", "one", "sentence", "tl;dr", "c++", "x" * 50,
             "", "whereby", "although", "step-by-step", "short", "answer",
             "in", "detail", "javascript", "mysql", "tables", "lists",
             "summarise", "don't", "if", "the", "this", "whether", "motif"]
    cases = ["Explain photosynthesis briefly?", "such that it works",
             "I did it so that he would see", "Ponder the sea", "", "   ",
             "???", "that,which", "multi\nline so that\nprompt?",
             "caffé ünïcode json?", "tl;dr please", "that that that",
             "WHAT is a short answer"]
    for _ in range(n_template):
        cases.append(" ".join(rng.choice(words)
                              for _ in range(rng.randint(0, 20))))
    for _ in range(n_noise):
        cases.append("".join(rng.choice(string.printable)
                             for _ in range(rng.randint(0, 120))))
    return cases


def test_feature_vector_is_19_dim():
    v = F.extract("Write a python function for binary search?")
    assert v.shape == (F.N_FEATURES,) == (19,)
    assert len(F.FEATURE_NAMES) == 19


def test_known_prompt_features():
    v = F.extract("Explain photosynthesis briefly?")
    assert v[0] == len("Explain photosynthesis briefly?") // 4
    assert v[2] == 1.0          # "briefly" length constraint
    assert v[3] == 1.0          # ends with ?
    assert v[6 + F.VERB_INDEX["explain"]] == 1.0


def test_code_and_format_keywords():
    v = F.extract("Implement an algorithm and return json")
    assert v[1] == 1.0 and v[4] == 1.0
    assert v[6 + F.VERB_INDEX["implement"]] == 1.0


def test_other_verb_bucket():
    v = F.extract("Ponder the sea")
    assert v[6 + len(F.INSTRUCTION_VERBS)] == 1.0


def test_clause_markers_counted_once():
    """Regression: the seed double-counted "so that" / "such that" (once
    via the "that" token, once via a substring count)."""
    assert F.extract("I did it so that he would see")[5] == 1.0
    assert F.extract("works such that it passes")[5] == 1.0
    assert F.extract("so that and such that")[5] == 2.0
    # control: independent markers still accumulate
    assert F.extract("because although whereas")[5] == 3.0
    # punctuation delimits tokens
    assert F.extract("that,which")[5] == 2.0


def test_extract_total_properties():
    """Shape/range invariants over random text (seeded rng loop)."""
    for s in _random_corpus(600, 400):
        v = F.extract(s)
        assert v.shape == (19,)
        assert np.isfinite(v).all()
        assert v[6:].sum() == 1.0        # verb one-hot sums to exactly 1
        assert set(np.unique(v[1:5])) <= {0.0, 1.0}
        assert v[0] == len(s) // 4
        assert v[5] >= 0


def test_batch_matches_single_and_reference():
    """The vectorized batch path, the scalar path, and the seed-style
    reference scan agree exactly on a mixed random corpus."""
    cases = _random_corpus()
    X = F.extract_batch(cases)
    assert X.shape == (len(cases), 19)
    for i, s in enumerate(cases):
        np.testing.assert_array_equal(X[i], F.extract(s), err_msg=repr(s))
        np.testing.assert_array_equal(X[i], F.extract_reference(s),
                                      err_msg=repr(s))


def test_leading_verb_past_scan_window():
    """Regression: a first token pushed past / across the batch verb-scan
    window must not be truncated or dropped."""
    cases = [" " * 45 + "explain x", '"' * 45 + "explain x",
             "x" * 60 + " explain", " " * 40 + "listshort stuff", " " * 60]
    X = F.extract_batch(cases)
    for i, c in enumerate(cases):
        np.testing.assert_array_equal(X[i], F.extract(c), err_msg=repr(c))


def test_batch_of_sizes():
    for n in (0, 1, 2, 7):
        prompts = ["Explain x?" for _ in range(n)]
        assert F.extract_batch(prompts).shape == (n, 19)
