"""Speculative decoding: draft-verify lanes and acceptance-aware admission.

The load-bearing invariant everywhere: accepted tokens are the TARGET's
own argmaxes, so speculative output is bitwise-identical to the plain
fused path regardless of draft quality — an agreeing draft (the target's
own parameters) and an adversarial one (independent init, ~0%%
acceptance) must produce the same tokens, differing only in round
counts and acceptance stats.  The scheduling half mirrors the backend
as a service-rate modifier whose 1.0 / K=0 settings are IEEE-exact
identities.
"""

import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.engine import (BatchedRealEngine, PagedBatchedEngine,
                                  RealEngine)

CFG = get_config("smollm-360m").reduced()

# the 7-request / 3-lane workload of tests/test_batching.py (4 back-fills)
_PLENS = (5, 11, 23, 7, 3, 15, 9)
_MAXES = [10, 25, 6, 18, 4, 12, 9]


def _prompts(rng=None):
    rng = rng or np.random.default_rng(0)
    return [rng.integers(0, CFG.vocab_size, int(n)).tolist()
            for n in _PLENS]


@pytest.fixture(scope="module")
def ref_engine():
    return RealEngine(CFG, max_len=64, seed=0)


@pytest.fixture(scope="module")
def spec_engine(ref_engine):
    """Serial speculative engine, draft = the target's own parameters."""
    return RealEngine(CFG, params=ref_engine.params, max_len=64, seed=0,
                      draft_cfg=CFG, draft_params=ref_engine.params,
                      draft_k=3)


@pytest.fixture(scope="module")
def indep_engine(ref_engine):
    """Serial speculative engine, independently-seeded draft (~0%%
    acceptance — every verify round still emits the bonus token)."""
    return RealEngine(CFG, params=ref_engine.params, max_len=64, seed=0,
                      draft_cfg=CFG, draft_k=3, draft_seed=7)


# ------------------------------------------------------------ serial decoder

def test_serial_bitwise_across_prompt_lengths(ref_engine, spec_engine,
                                              indep_engine):
    rng = np.random.default_rng(1)
    for plen in (1, 3, 9, 17, 40):
        ids = rng.integers(0, CFG.vocab_size, plen).tolist()
        for max_new in (1, 7, 16):
            want = ref_engine.generate_reference(
                ids, max_new_tokens=max_new)["tokens"]
            for eng in (spec_engine, indep_engine):
                got = eng.generate(ids, max_new_tokens=max_new)
                assert got["tokens"] == want, \
                    f"plen={plen} max_new={max_new}"


def test_all_rejected_still_progresses(ref_engine, indep_engine):
    """An adversarial draft wastes every proposal, yet each verify round
    commits the bonus token — output matches and progress is linear."""
    ids = list(range(8))
    want = ref_engine.generate_reference(ids, max_new_tokens=12)["tokens"]
    got = indep_engine.generate(ids, max_new_tokens=12)
    assert got["tokens"] == want
    assert got["drafted"] > 0 and got["accepted"] == 0
    assert got["accept_rate"] == 0.0


def test_accept_rate_reported(spec_engine):
    out = spec_engine.generate(list(range(6)), max_new_tokens=16)
    assert out["drafted"] > 0
    assert out["accept_rate"] == out["accepted"] / out["drafted"]
    assert out["accept_rate"] > 0.5        # agreeing draft accepts most


def test_eos_inside_draft_block_truncates(ref_engine, spec_engine,
                                          indep_engine):
    """Pick an eos that fires mid-stream; the speculative path must stop
    at exactly the same token as the serial oracle even when the eos
    lands inside an accepted draft block."""
    ids = list(range(5))
    free = ref_engine.generate_reference(ids, max_new_tokens=16)["tokens"]
    assert len(free) > 3
    eos = free[len(free) // 2]             # guaranteed to occur
    want = ref_engine.generate_reference(ids, max_new_tokens=16,
                                         eos_id=eos)["tokens"]
    assert len(want) < len(free)
    for eng in (spec_engine, indep_engine):
        got = eng.generate(ids, max_new_tokens=16, eos_id=eos)
        assert got["tokens"] == want


def test_draft_k_zero_degenerates_to_fused(ref_engine):
    """draft_cfg without draft_k >= 1 is NOT speculative: plain fused
    path, no acceptance keys in the result."""
    eng = RealEngine(CFG, params=ref_engine.params, max_len=64, seed=0,
                     draft_cfg=CFG, draft_k=0)
    assert not eng.speculative
    ids = list(range(7))
    out = eng.generate(ids, max_new_tokens=10)
    assert out["tokens"] == ref_engine.generate_reference(
        ids, max_new_tokens=10)["tokens"]
    assert "accept_rate" not in out


def test_speculative_decoder_rejects_bad_k(ref_engine):
    from repro.serving.generate import SpeculativeDecoder
    with pytest.raises(ValueError):
        SpeculativeDecoder(ref_engine.lm, ref_engine.lm, max_len=64,
                           draft_k=0)


# ------------------------------------------------------------- batched lanes

@pytest.fixture(scope="module")
def batched_ref():
    return BatchedRealEngine(CFG, max_len=64, segment_len=4, n_lanes=3,
                             seed=0)


@pytest.fixture(scope="module")
def batched_want(batched_ref):
    return [batched_ref.generate_reference(p, max_new_tokens=m)["tokens"]
            for p, m in zip(_prompts(), _MAXES)]


@pytest.mark.parametrize("draft_seed", [None, 7])
def test_batched_retire_backfill_bitwise(batched_ref, batched_want,
                                         draft_seed):
    """Retire + back-fill with speculation on: 7 requests through 3
    lanes (4 back-fills), agreeing and adversarial drafts, all bitwise."""
    kw = dict(draft_params=batched_ref.params) if draft_seed is None \
        else dict(draft_seed=draft_seed)
    eng = BatchedRealEngine(CFG, max_len=64, segment_len=4, n_lanes=3,
                            seed=0, params=batched_ref.params,
                            draft_cfg=CFG, draft_k=3, **kw)
    outs = eng.generate_batch(_prompts(), max_new_tokens=_MAXES)
    for o, w in zip(outs, batched_want):
        assert list(o["tokens"]) == list(w)
    st = eng.lane_manager.stats
    assert st["backfills"] == 4 and st["retired"] == 7
    assert st["drafted"] == eng.drafted_total > 0
    if draft_seed is None:                 # agreeing draft
        assert eng.accept_rate > 0.5
        assert o["accept_rate"] is not None
    else:                                  # adversarial draft
        assert eng.accept_rate < 0.1
        # every wasted draft position lands in dead_steps
        assert eng.dead_steps >= eng.drafted_total - eng.accepted_total


def test_batched_eos_bitwise(batched_ref, batched_want):
    eng = BatchedRealEngine(CFG, max_len=64, segment_len=4, n_lanes=3,
                            seed=0, params=batched_ref.params,
                            draft_cfg=CFG, draft_params=batched_ref.params,
                            draft_k=3)
    eos = batched_want[1][len(batched_want[1]) // 2]
    want = [batched_ref.generate_reference(p, max_new_tokens=m,
                                           eos_id=eos)["tokens"]
            for p, m in zip(_prompts(), _MAXES)]
    assert any(len(w) < len(f) for w, f in zip(want, batched_want))
    outs = eng.generate_batch(_prompts(), max_new_tokens=_MAXES,
                              eos_id=eos)
    for o, w in zip(outs, want):
        assert list(o["tokens"]) == list(w)


def test_draft_kv_charged_to_budget(batched_ref):
    """Ring lanes charge target + draft bytes per token: the speculative
    default budget is strictly larger, and the manager's per-token rate
    includes the draft cache."""
    from repro.serving.batching import kv_bytes_per_token
    eng = BatchedRealEngine(CFG, max_len=64, segment_len=4, n_lanes=3,
                            seed=0, params=batched_ref.params,
                            draft_cfg=CFG, draft_params=batched_ref.params,
                            draft_k=3)
    bpt = kv_bytes_per_token(CFG)
    assert eng._draft_bytes_per_token == bpt
    eng.generate_batch(_prompts()[:3], max_new_tokens=4)
    assert eng.lane_manager.bytes_per_token == 2 * bpt
    assert eng.budget_bytes == batched_ref.budget_bytes * 2


# -------------------------------------------------------------- paged lanes

def test_paged_speculative_bitwise(batched_ref, batched_want):
    for kw in (dict(draft_params=batched_ref.params),
               dict(draft_seed=7)):
        eng = PagedBatchedEngine(CFG, max_len=64, segment_len=4,
                                 n_lanes=3, page_size=16, seed=0,
                                 params=batched_ref.params, draft_cfg=CFG,
                                 draft_k=3, **kw)
        assert eng._overhead_pages > 0     # draft KV held as overhead
        outs = eng.generate_batch(_prompts(), max_new_tokens=_MAXES)
        for o, w in zip(outs, batched_want):
            assert list(o["tokens"]) == list(w)
        eng.allocator.check()


def test_paged_tight_budget_bitwise(batched_ref, batched_want):
    """A pool too small for 3 concurrent speculative lanes serializes
    admission but never changes tokens."""
    from repro.serving.batching import kv_bytes_per_token
    eng = PagedBatchedEngine(CFG, max_len=64, segment_len=4, n_lanes=3,
                             page_size=16, seed=0,
                             params=batched_ref.params, draft_cfg=CFG,
                             draft_params=batched_ref.params, draft_k=3,
                             budget_bytes=10 * 16 * kv_bytes_per_token(CFG))
    assert eng.n_pages == 10
    outs = eng.generate_batch(_prompts(), max_new_tokens=_MAXES)
    for o, w in zip(outs, batched_want):
        assert list(o["tokens"]) == list(w)
    eng.allocator.check()


def test_paged_overhead_pages_accounting():
    """Admission reserves the draft ring as unmapped overhead pages and
    releases them at retire — the allocator balances."""
    from repro.serving.paging import BlockAllocator, PagedLaneManager
    alloc = BlockAllocator(n_pages=16, page_size=16)
    mgr = PagedLaneManager(n_lanes=2, allocator=alloc, bytes_per_token=4,
                           capacity=64, overhead_pages=3)
    assert alloc.can_allocate(16)          # empty pool
    mgr.admit(0, req_id=1, prompt_len=17, max_new=8, ids=list(range(17)))
    assert len(mgr._overhead[0]) == 3      # draft ring pinned
    # 2 prompt pages + 3 overhead held -> only 11 of 16 remain
    assert not alloc.can_allocate(12)
    assert alloc.can_allocate(11)
    # a second admit must clear its own overhead too
    assert mgr.can_admit(17, 8, ids=list(range(100, 117)))
    mgr.retire(0)
    assert 0 not in mgr._overhead
    assert alloc.can_allocate(16)          # everything returned
    alloc.check()
    # a pool that cannot hold one sequence + overhead is rejected
    with pytest.raises(ValueError):
        PagedLaneManager(n_lanes=1, allocator=BlockAllocator(5, 16),
                         bytes_per_token=4, capacity=64, overhead_pages=3)


# --------------------------------------------------- scheduling-layer mirror

def test_expected_speedup_math():
    from repro.serving.service_time import expected_speedup
    assert expected_speedup(0.5, 0) == 1.0
    a = np.array([0.1, 0.5, 0.9])
    s = expected_speedup(a, 4)
    assert s.shape == (3,) and np.all(np.diff(s) > 0)
    assert s[0] < 1.0 < s[2]               # speculation is not free
    # closed form at a=0.9, k=4, cost=0.15
    want = ((1 - 0.9 ** 5) / 0.1) / (4 * 0.15 + 1)
    assert np.isclose(expected_speedup(0.9, 4), want)


def test_effective_rate_identity():
    from repro.serving.service_time import ServiceTimeModel
    m0 = ServiceTimeModel(8000.0, 60.0)
    m1 = ServiceTimeModel(8000.0, 60.0, effective_rate=1.0)
    assert m0.service(64, 1400) == m1.service(64, 1400)
    assert np.array_equal(m0.service_batch([3, 64], [10, 1400]),
                          m1.service_batch([3, 64], [10, 1400]))
    m2 = ServiceTimeModel(8000.0, 60.0, effective_rate=2.0)
    assert m2.service(64, 1400) < m0.service(64, 1400)


def test_calibration_identity_and_scaling():
    from repro.core.calibration import measure_mu_short
    from repro.core.simulation import ServiceDist
    S, L = ServiceDist(3.5, 0.8), ServiceDist(8.9, 2.0)
    assert measure_mu_short(S, L) == measure_mu_short(S, L,
                                                      effective_rate=1.0)
    assert measure_mu_short(S, L, effective_rate=2.0) \
        < measure_mu_short(S, L)
    with pytest.raises(ValueError):
        measure_mu_short(S, L, effective_rate=-1.0)


def test_simulate_speculative_identity():
    import copy
    from repro.core.simulation import (ServiceDist, poisson_workload,
                                       simulate, simulate_speculative)
    rng = np.random.default_rng(3)
    reqs = poisson_workload(rng, 150, 0.2, ServiceDist(3.5, 0.8),
                            ServiceDist(8.9, 2.0))
    a, b = copy.deepcopy(reqs), copy.deepcopy(reqs)
    r0 = simulate(a, policy="sjf", tau=10.0)
    r1 = simulate_speculative(b, policy="sjf", tau=10.0, draft_k=0)
    key = lambda r: r.req_id
    for x, y in zip(sorted(r0.requests, key=key),
                    sorted(r1.requests, key=key)):
        assert x.start == y.start and x.finish == y.finish
    assert r0.promotions == r1.promotions


def test_simulate_speculative_speedup():
    from repro.core.simulation import (ServiceDist, poisson_workload,
                                       simulate_speculative)
    rng = np.random.default_rng(4)
    reqs = poisson_workload(rng, 150, 0.2, ServiceDist(3.5, 0.8),
                            ServiceDist(8.9, 2.0))
    for r in reqs:
        r.accept_rate = 0.9
    hi = simulate_speculative(reqs, policy="sjf", draft_k=4)
    mk_hi = hi.makespan
    for r in reqs:
        r.accept_rate = 0.0
    mk_lo = simulate_speculative(reqs, policy="sjf", draft_k=4).makespan
    assert mk_hi < mk_lo                   # acceptance buys wall-clock


def test_effective_sjf_keys():
    from repro.core.policy import get_policy
    from repro.core.scheduler import Request
    pol = get_policy("sjf_effective")
    hi = Request(req_id=0, p_long=0.9, accept_rate=0.95)
    lo = Request(req_id=1, p_long=0.2, accept_rate=0.0)
    # a long request that drafts well can outrank a short one that
    # drafts terribly
    assert pol.key(hi) < pol.key(lo)
    ka = pol.key_array(np.zeros(2), np.array([0.9, 0.2]), np.zeros(2),
                       accept_rate=np.array([0.95, 0.0]))
    assert np.isclose(ka[0], pol.key(hi))
    assert np.isclose(ka[1], pol.key(lo))
    # NaN / None fall back to the prior
    none_req = Request(req_id=2, p_long=0.2)
    kn = pol.key_array(np.zeros(1), np.array([0.2]), np.zeros(1),
                       accept_rate=np.array([np.nan]))
    assert np.isclose(kn[0], pol.key(none_req))
    # uniform acceptance degenerates to token-count SJF ordering
    sjf = get_policy("sjf")
    p = np.linspace(0.0, 1.0, 17)
    z = np.zeros(17)
    assert np.array_equal(
        np.argsort(pol.key_array(z, p, z, accept_rate=np.full(17, 0.7))),
        np.argsort(sjf.key_array(z, p, z)))


def test_sweep_speculative_acceptance_aware_wins():
    """Heterogeneous acceptance: keying on effective service (predicted /
    expected speedup) beats token-count SJF on short-request P50."""
    from repro.core.simulation import ServiceDist
    from repro.core.sweep import sweep_speculative
    res = sweep_speculative(
        conditions=[("sjf", None), ("sjf_effective", None)],
        draft_ks=(0, 4), accept_dists=("uniform",), seeds=range(5),
        n=500, short=ServiceDist(3.5, 0.8), long=ServiceDist(8.9, 2.0),
        rho=0.8)
    sp50 = res.metric("short_p50")
    # K=0 cells: identical grid for both (speculation off => same keys
    # up to a monotone transform, same services)
    assert np.allclose(sp50[0, 0], sp50[1, 0])
    # K=4: acceptance-aware admission wins the seed-mean
    assert sp50[1, 1].mean() <= sp50[0, 1].mean()
    assert res.metric("mean_sojourn")[1, 1].mean() \
        <= res.metric("mean_sojourn")[0, 1].mean()
