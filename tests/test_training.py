"""Training substrate: optimizer correctness, accumulation equivalence,
grad compression, straggler/elastic logic."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.lm_data import LMDataConfig, SyntheticLMStream
from repro.training.grad_compress import (apply_error_feedback,
                                          init_error_state)
from repro.training.optimizer import (OptConfig, apply_updates,
                                      init_opt_state)
from repro.training.straggler import HostMonitor, StepTimer
from repro.training.train_loop import (init_train_state, make_train_step)


def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.asarray([5.0, -3.0])}
    cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
    state = init_opt_state(params, cfg)
    for _ in range(120):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_adafactor_reduces_quadratic_loss():
    params = {"w": jnp.ones((4, 6)) * 3.0}
    cfg = OptConfig(lr=0.5, kind="adafactor", weight_decay=0.0,
                    warmup_steps=1)
    state = init_opt_state(params, cfg)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_grad_clipping():
    params = {"w": jnp.zeros(3)}
    cfg = OptConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0, warmup_steps=1)
    state = init_opt_state(params, cfg)
    _, _, metrics = apply_updates(params, {"w": jnp.full(3, 100.0)}, state, cfg)
    assert float(metrics["grad_norm"]) > 100.0  # reported pre-clip


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_config("smollm-360m").reduced()
    opt = OptConfig(lr=1e-3, warmup_steps=1)
    data = SyntheticLMStream(LMDataConfig(vocab_size=cfg.vocab_size,
                                          seq_len=32, global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    s1 = init_train_state(cfg, opt, jax.random.key(0))
    s2 = init_train_state(cfg, opt, jax.random.key(0))
    full = make_train_step(cfg, opt, microbatches=1)
    micro = make_train_step(cfg, opt, microbatches=4)
    s1, m1 = jax.jit(full)(s1, batch)
    s2, m2 = jax.jit(micro)(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-4, rtol=5e-3)


def test_loss_decreases_over_short_run():
    from repro.launch import train as train_mod
    losses = train_mod.main(["--arch", "smollm-360m", "--reduced",
                             "--steps", "30", "--batch", "8", "--seq", "64",
                             "--lr", "1e-2"])
    assert losses[-1] < losses[0] - 0.4, (losses[0], losses[-1])


def test_error_feedback_residual_is_exact():
    grads = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, 64),
                              jnp.float32)}
    err = init_error_state(grads)
    deq, new_err = apply_error_feedback(grads, err)
    np.testing.assert_allclose(np.asarray(deq["w"] + new_err["w"]),
                               np.asarray(grads["w"]), atol=1e-6)


def test_compressed_allreduce_single_device_identity():
    from repro.training.grad_compress import make_compressed_allreduce
    mesh = jax.make_mesh((1,), ("data",))
    fn = make_compressed_allreduce(mesh)
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(0, 1, (8, 16)),
                          jnp.float32)}
    out = fn(g)
    # int8 quantization error only (scale = max/127)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=float(jnp.abs(g["w"]).max()) / 100)


def test_straggler_detection():
    t = StepTimer(warmup=3)
    flagged = [t.observe(i, 1.0 + 0.01 * i) for i in range(10)]
    assert not any(flagged)
    assert t.observe(10, 10.0)  # 10x blowup flagged


def test_host_monitor():
    m = HostMonitor()
    for i in range(10):
        m.observe("h0", 1.0)
        m.observe("h1", 1.05)
        m.observe("h2", 2.5)
    assert m.stragglers() == ["h2"]


def test_elastic_plan():
    from repro.training.elastic import plan_remesh
    plan = plan_remesh(device_count=1, model_parallel=1, old_data_parallel=4)
    assert plan.microbatch_scale == 4
    with pytest.raises(ValueError):
        # model axis cannot exceed the surviving device count
        plan_remesh(device_count=1, model_parallel=2, old_data_parallel=4)
