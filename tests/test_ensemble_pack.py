"""Equivalence suite for the packed-ensemble fast path.

Asserts that on trained models of several shapes, the seed dense
traversal, the pruned/binned numpy traversal, the native (C) scorer, the
packed jnp oracle, and both Pallas kernels (interpret mode) agree within
rtol 1e-5.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import _native
from repro.core.ensemble_pack import pack_ensemble
from repro.core.gbdt import GBDTParams, train_gbdt
from repro.kernels import ref
from repro.kernels.gbdt_infer import (gbdt_margins_kernel,
                                      gbdt_margins_packed_kernel)

SHAPES = [
    GBDTParams(num_rounds=12, max_depth=6, n_classes=3),
    GBDTParams(num_rounds=8, max_depth=3, n_classes=2),
    GBDTParams(num_rounds=5, max_depth=4, n_classes=4),
    GBDTParams(num_rounds=6, max_depth=2, n_classes=3, subsample=0.8),
    GBDTParams(num_rounds=3, max_depth=1, n_classes=2),
]


def _problem(params, n=700, f=11, seed=0):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, params.n_classes, n)
    X = rng.normal(0, 1, (n, f)).astype(np.float32)
    X[:, 0] += y * 1.3
    X[:, f // 2] += (y == params.n_classes - 1) * 1.7
    return X, y


def _allclose(a, b, msg):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5, err_msg=msg)


@pytest.mark.parametrize("params", SHAPES,
                         ids=[f"r{p.num_rounds}d{p.max_depth}k{p.n_classes}"
                              for p in SHAPES])
def test_all_paths_agree(params):
    X, y = _problem(params)
    model = train_gbdt(X, y, params)
    packed = pack_ensemble(model)
    dense = model.predict_margin_dense(X)

    # host numpy traversal is bitwise identical to the dense path
    K = packed.n_classes
    np.testing.assert_array_equal(
        packed._predict_margin_numpy(packed.bin_input(X)), dense)

    # default host path (native when a compiler exists, numpy otherwise)
    _allclose(packed.predict_margin(X), dense, "host fast path")

    # jnp oracles
    _allclose(ref.gbdt_margins_ref(
        jnp.asarray(X), jnp.asarray(model.feature),
        jnp.asarray(model.threshold), jnp.asarray(model.value),
        n_classes=K), dense, "dense jnp oracle")
    _allclose(ref.gbdt_margins_packed_ref(
        jnp.asarray(X), jnp.asarray(packed.pfeat), jnp.asarray(packed.pthr),
        jnp.asarray(packed.pchild), jnp.asarray(packed.pvalue),
        depth=packed.depth, n_classes=K), dense, "packed jnp oracle")

    # Pallas kernels, interpret mode, forcing multi-block grids
    _allclose(gbdt_margins_kernel(
        jnp.asarray(X), jnp.asarray(model.feature),
        jnp.asarray(model.threshold), jnp.asarray(model.value),
        n_classes=K, block_b=128, block_t=2 * K, interpret=True),
        dense, "dense Pallas kernel")
    _allclose(gbdt_margins_packed_kernel(
        jnp.asarray(X), jnp.asarray(packed.pfeat), jnp.asarray(packed.pthr),
        jnp.asarray(packed.pchild), jnp.asarray(packed.pvalue),
        depth=packed.depth, n_classes=K, block_b=128, block_t=2 * K,
        interpret=True), dense, "packed Pallas kernel")


def test_packed_prunes_dead_nodes():
    params = GBDTParams(num_rounds=20, max_depth=6)
    X, y = _problem(params, n=1500, f=19)
    model = train_gbdt(X, y, params)
    packed = pack_ensemble(model)
    assert packed.num_nodes < model.feature.size
    assert packed.depth <= params.max_depth
    # leaves are self-loops with unsatisfiable thresholds
    leaf = packed.child == np.arange(packed.num_nodes, dtype=np.int32)
    assert leaf.any()
    assert (packed.thr_bin[leaf] == 0xFFFF).all()


def test_binned_compare_is_exact_on_edge_values():
    """Bin compares must reproduce float compares exactly at thresholds."""
    params = GBDTParams(num_rounds=10, max_depth=4)
    X, y = _problem(params, n=900, f=7, seed=3)
    model = train_gbdt(X, y, params)
    packed = pack_ensemble(model)
    # probe exactly at every threshold the ensemble uses (x == thr goes
    # right), plus NaN/inf corners on the numpy path
    thr = model.threshold[model.feature >= 0]
    probes = np.zeros((thr.size, 7), np.float32)
    for i, t in enumerate(thr[:200]):
        probes[i, :] = t
    Xp = np.vstack([X, probes[:200]])
    np.testing.assert_array_equal(
        packed._predict_margin_numpy(packed.bin_input(Xp)),
        model.predict_margin_dense(Xp))
    # NaN sorts past the last edge -> goes right, same as the dense path
    Xn = np.full((3, 7), np.nan, np.float32)
    Xn[1] = np.inf
    Xn[2] = -np.inf
    np.testing.assert_array_equal(
        packed._predict_margin_numpy(packed.bin_input(Xn)),
        model.predict_margin_dense(Xn))


def test_model_predict_margin_uses_packed_cache():
    params = GBDTParams(num_rounds=6, max_depth=3)
    X, y = _problem(params, n=400, f=5, seed=1)
    model = train_gbdt(X, y, params)
    p1 = model.packed()
    assert model.packed() is p1                 # cached
    assert model.packed(rebuild=True) is not p1
    _allclose(model.predict_margin(X), model.predict_margin_dense(X),
              "GBDTModel.predict_margin")


def test_native_scorer_matches_numpy_when_available():
    fn = _native.native_scorer()
    if fn is None:
        pytest.skip("no C compiler in this environment")
    params = GBDTParams(num_rounds=10, max_depth=5)
    X, y = _problem(params, n=800, f=9, seed=2)
    model = train_gbdt(X, y, params)
    packed = pack_ensemble(model)
    got = packed._predict_margin_native(packed.bin_input(X), fn)
    _allclose(got, model.predict_margin_dense(X), "native scorer")
