"""Data pipeline: corpus statistics, splits (Table 3), LM stream determinism."""

import numpy as np
import pytest

from repro.core.ranking import class_labels
from repro.data.corpus import PROFILES, sample_dataset
from repro.data.lm_data import LMDataConfig, PrefetchLoader, SyntheticLMStream
from repro.data.pipeline import (MODEL_SPLITS, load_model_splits,
                                 stratified_split)
from repro.data.tokenizer import HashTokenizer, approx_token_len


def test_profiles_match_published_long_rates():
    for name, prof in PROFILES.items():
        n = 20000
        ds = sample_dataset(name, n=n, seed=0)
        y = class_labels(ds.lengths)
        got = (y == 2).mean()
        want = prof.class_probs[2]
        assert abs(got - want) < max(0.015, 0.5 * want), \
            f"{name}: long rate {got:.4f} vs published {want:.4f}"


def test_alpaca_degeneracy_structural():
    """The brevity constraint: ~4 Long in 52002 (paper Table 2)."""
    ds = sample_dataset("alpaca", n=52002, seed=1)
    n_long = int((class_labels(ds.lengths) == 2).sum())
    assert n_long < 25, f"alpaca profile produced {n_long} Long examples"


def test_table3_split_sizes():
    for m, spec in MODEL_SPLITS.items():
        sp = load_model_splits(m)
        assert len(sp.train) == 3 * spec["train"]
        assert len(sp.val) == 3 * spec["val"]
        assert len(sp.test) == 3 * spec["test"]
        # balanced classes in every split
        for part in (sp.train, sp.val, sp.test):
            counts = np.bincount(part.y, minlength=3)
            assert counts.min() == counts.max()


def test_split_raises_on_starved_class():
    ds = sample_dataset("alpaca", n=30000, seed=0)
    with pytest.raises(ValueError, match="starvation"):
        stratified_split(ds, {"train": 1600, "val": 200, "test": 200})


def test_splits_deterministic():
    a = load_model_splits("A")
    b = load_model_splits("A")
    np.testing.assert_array_equal(a.train.X, b.train.X)
    np.testing.assert_array_equal(a.test.lengths, b.test.lengths)


def test_lm_stream_sharding_and_determinism():
    cfg = LMDataConfig(vocab_size=128, seq_len=16, global_batch=8)
    full = SyntheticLMStream(cfg, 0, 1).batch(7)
    h0 = SyntheticLMStream(cfg, 0, 2).batch(7)
    h1 = SyntheticLMStream(cfg, 1, 2).batch(7)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(full["labels"][:, :-1],
                                  full["tokens"][:, 1:])


def test_prefetch_loader_order():
    cfg = LMDataConfig(vocab_size=64, seq_len=8, global_batch=4)
    stream = SyntheticLMStream(cfg)
    loader = PrefetchLoader(stream, start_step=3)
    it = iter(loader)
    steps = [next(it)[0] for _ in range(4)]
    loader.close()
    assert steps == [3, 4, 5, 6]


def test_tokenizer():
    assert approx_token_len("abcd" * 10) == 10
    tok = HashTokenizer(1000)
    ids = tok.encode("hello world hello")
    assert ids[0] == ids[2] and 0 <= ids.max() < 1000
    batch = tok.encode_batch(["a b", "c"], pad_to=4)
    assert batch.shape == (2, 4) and batch[1, 1] == 0
