"""Block-paged KV cache (ROADMAP item 2): allocator invariants under a
chaos fuzz, the paged Pallas decode kernel vs its gather oracle and the
dense ring kernel, PagedBatchedEngine bitwise-vs-reference (backfill,
growth preemption, prefix reuse across drains), dead-step accounting,
and the paged DES (c=1 bitwise contract, bounded pool, prefix sharing,
live-order agreement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.scheduler import Request
from repro.core.simulation import (ServiceDist, poisson_workload,
                                   simulate_paged, simulate_servers)
from repro.kernels.decode_attention import (decode_attention_kernel,
                                            paged_decode_attention_kernel)
from repro.kernels.ref import paged_decode_attention_ref
from repro.serving.engine import BatchedRealEngine, PagedBatchedEngine
from repro.serving.paging import (BlockAllocator, PageError,
                                  PagedLaneManager, chain_hashes, pages_for)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------- BlockAllocator
def test_pages_for_and_chain_hashes():
    assert pages_for(0, 4) == 0
    assert pages_for(1, 4) == 1
    assert pages_for(4, 4) == 1
    assert pages_for(5, 4) == 2
    ids = list(range(8))
    hs = chain_hashes(ids, 4)
    assert len(hs) == 2                      # full pages only
    # chained: the second page's hash depends on the first page's tokens
    other = chain_hashes([9, 9, 9, 9] + ids[4:], 4)
    assert hs[0] != other[0] and hs[1] != other[1]
    # deterministic + prefix-stable
    assert chain_hashes(ids + [99], 4)[:2] == hs


def test_allocator_alloc_release_conservation():
    al = BlockAllocator(8, 4)
    pages = al.allocate(3)
    assert al.used_pages == 3 and al.reclaimable_pages == 5
    with pytest.raises(PageError):
        al.allocate(6)                       # all-or-nothing: no partial grab
    al.check()
    assert al.used_pages == 3
    al.release_seq(pages)
    assert al.used_pages == 0 and al.reclaimable_pages == 8
    al.check()


def test_allocator_register_match_revive_and_drop():
    al = BlockAllocator(8, 4)
    ids = list(range(12))
    pages = al.allocate(3)
    al.register(pages, chain_hashes(ids, 4))
    al.release_seq(pages)                    # registered pages park in LRU
    assert al.used_pages == 0 and al.reclaimable_pages == 8
    hit_tokens, hit_pages = al.match_prefix(ids + [50, 51])
    assert hit_tokens == 12 and hit_pages == pages   # revived, refcount 1
    assert al.used_pages == 3
    al.release_seq(hit_pages)
    al.drop_cache()                          # pool rebuilt: content is gone
    assert al.probe_prefix(chain_hashes(ids, 4)) == 0
    al.check()


def test_allocator_lru_reclaim_forgets_content():
    al = BlockAllocator(4, 4)
    a = al.allocate(2)
    al.register(a, chain_hashes([1, 2, 3, 4, 5, 6, 7, 8], 4))
    al.release_seq(a)
    b = al.allocate(4)                       # must cannibalise the LRU
    assert al.stats["cache_evictions"] == 2
    assert al.probe_prefix(chain_hashes([1, 2, 3, 4, 5, 6, 7, 8], 4)) == 0
    al.release_seq(b)
    al.check()


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_paged_manager_chaos_fuzz(seed):
    """Randomised admit/grow/retire/evict/preempt/crash sequences: the
    allocator invariants (refcounts never negative, free + cached + held
    conservation, index consistency) hold after every single op, and a
    full drain returns the pool to empty."""
    rng = np.random.default_rng(seed)
    N_PAGES, PS, LANES, CAP = 24, 4, 4, 32
    al = BlockAllocator(N_PAGES, PS)
    mgr = PagedLaneManager(LANES, al, bytes_per_token=1, capacity=CAP)
    ids_by_lane = {}
    rid = 0
    for _ in range(500):
        op = int(rng.integers(0, 8))
        free = [ln for ln in range(LANES) if mgr.lanes[ln] is None]
        busy = mgr.busy_lanes()
        if op <= 2 and free:                 # admit (small alphabet so
            lane = int(rng.choice(free))     # prefixes collide and share)
            n = int(rng.integers(1, CAP + 1))
            ids = rng.integers(0, 3, size=n).tolist()
            rid += 1
            try:
                mgr.admit(lane, req_id=rid, prompt_len=n,
                          max_new=int(rng.integers(1, 16)), ids=ids)
                ids_by_lane[lane] = ids
            except PageError:
                pass                         # full pool must not leak refs
        elif op == 3 and busy:               # register prompt, then retire
            lane = int(rng.choice(busy))
            if rng.random() < 0.7:
                mgr.register_prompt(lane, ids_by_lane[lane])
            mgr.retire(lane)
        elif op == 4 and busy:               # cancellation eviction
            mgr.evict(int(rng.choice(busy)))
        elif op == 5 and busy:               # pool-exhaustion preemption
            mgr.preempt(int(rng.choice(busy)))
        elif op == 6 and busy:               # decode growth, page by page
            lane = int(rng.choice(busy))
            mgr.grow(lane, len(mgr.lanes[lane].pages)
                     + int(rng.integers(1, 4)))
        elif op == 7 and rng.random() < 0.3:  # crash: engine rebuilds
            al.reset_transient()
            if rng.random() < 0.5:
                al.drop_cache()              # pools re-zeroed -> forget
            mgr = PagedLaneManager(LANES, al, bytes_per_token=1,
                                   capacity=CAP)
            ids_by_lane.clear()
        al.check()
    for ln in list(mgr.busy_lanes()):
        mgr.retire(ln)
    al.check()
    assert al.used_pages == 0
    al.reset_transient()
    assert al.reclaimable_pages == N_PAGES


# ------------------------------------------------------------ paged kernel
@pytest.mark.parametrize("B,KV,G,hd,ps,P", [
    (3, 2, 4, 64, 16, 4),
    (2, 1, 8, 32, 8, 6),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_kernel_matches_oracle_and_dense(B, KV, G, hd, ps, P,
                                                      dtype):
    """Paged kernel == gather oracle == per-lane dense ring kernel, with
    unallocated table slots pointing at a garbage-filled trash page (the
    fill-level mask must discard it)."""
    n_pages = B * P + 1
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd), dtype)
    kp = jax.random.normal(ks[1], (n_pages, KV, ps, hd), dtype)
    vp = jax.random.normal(ks[2], (n_pages, KV, ps, hd), dtype)
    kp = kp.at[0].set(1e4)                   # poison the trash page
    vp = vp.at[0].set(-1e4)
    rng = np.random.default_rng(0)
    bt = rng.permutation(np.arange(1, n_pages))[:B * P] \
        .reshape(B, P).astype(np.int32)
    t = rng.integers(0, P * ps, size=B).astype(np.int32)
    for b in range(B):                       # slots beyond the fill level
        for p in range(P):                   # are unallocated -> trash
            if p * ps > t[b]:
                bt[b, p] = 0
    out = paged_decode_attention_kernel(q, kp, vp, bt, t, interpret=True)
    want = paged_decode_attention_ref(q, kp, vp, jnp.asarray(bt),
                                      jnp.asarray(t))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
    # cross-check per lane against the dense ring kernel on the gathered
    # logical window (scalar fill level)
    k_d = kp[bt].transpose(0, 2, 1, 3, 4).reshape(B, KV, P * ps, hd)
    v_d = vp[bt].transpose(0, 2, 1, 3, 4).reshape(B, KV, P * ps, hd)
    for b in range(B):
        dense = decode_attention_kernel(q[b:b + 1], k_d[b:b + 1],
                                        v_d[b:b + 1], int(t[b]),
                                        block_kv=ps, interpret=True)
        np.testing.assert_allclose(np.asarray(out[b], np.float32),
                                   np.asarray(dense[0], np.float32),
                                   **_tol(dtype))


# ------------------------------------------------------ PagedBatchedEngine
@pytest.fixture(scope="module")
def cfg():
    return get_config("smollm-360m").reduced()


@pytest.fixture(scope="module")
def base(cfg):
    return BatchedRealEngine(cfg, max_len=64, segment_len=4, n_lanes=3,
                             seed=0)


@pytest.fixture(scope="module")
def paged(cfg, base):
    return PagedBatchedEngine(cfg, params=base.params, max_len=64,
                              segment_len=4, n_lanes=3, seed=0, page_size=8)


def _prompts(cfg, rng, sizes):
    return [rng.integers(1, cfg.vocab_size, size=n).astype(np.int64)
            for n in sizes]


def test_paged_decode_bitwise_with_backfill(cfg, base, paged):
    """Roomy pool: every request's tokens are bitwise-identical to the
    serial reference, lanes back-fill, and a post-drain crash recovery
    leaves the pool empty and consistent."""
    rng = np.random.default_rng(0)
    prompts = _prompts(cfg, rng, (5, 17, 9, 23, 3, 12))
    maxes = [20, 8, 30, 12, 25, 16]
    refs = [base.generate_reference(p, m)["tokens"]
            for p, m in zip(prompts, maxes)]
    res = paged.generate_batch(prompts, maxes)
    for i, (r, ref) in enumerate(zip(res, refs)):
        assert r is not None, f"request {i} lost"
        assert r["tokens"] == list(ref), (i, r["tokens"], list(ref))
    paged.allocator.reset_transient()
    assert paged.allocator.used_pages == 0
    paged.allocator.check()


def test_tight_pool_growth_preemption_stays_bitwise(cfg, base):
    """A 10-page pool cannot hold three full lanes: decode growth hits
    exhaustion, the youngest lane is preempted and later resumed — and
    the output stays bitwise-equal.  Dead steps stay bounded by the
    segment geometry."""
    rng = np.random.default_rng(0)
    prompts = _prompts(cfg, rng, (5, 17, 9, 23, 3, 12))
    maxes = [20, 8, 30, 12, 25, 16]
    refs = [base.generate_reference(p, m)["tokens"]
            for p, m in zip(prompts, maxes)]
    bpt = base._bytes_per_token
    tight = PagedBatchedEngine(cfg, params=base.params, max_len=64,
                               segment_len=4, n_lanes=3, seed=0,
                               page_size=8, budget_bytes=10 * 8 * bpt)
    assert tight.n_pages == 10
    res = tight.generate_batch(prompts, maxes)
    for i, (r, ref) in enumerate(zip(res, refs)):
        assert r is not None, f"request {i} lost"
        assert r["tokens"] == list(ref), (i, r["tokens"], list(ref))
    stats = tight.lane_manager.stats
    assert stats["preemptions"] >= 1
    # a lane can idle at most segment_len - 1 steps per terminal event
    terminals = (stats["retired"] + stats["evictions"]
                 + stats["preemptions"])
    assert 0 <= tight.dead_steps <= terminals * (tight.segment_len - 1)
    assert stats["dead_steps"] == tight.dead_steps
    tight.allocator.reset_transient()
    assert tight.allocator.used_pages == 0
    tight.allocator.check()


def test_prefix_reuse_bitwise_within_and_across_drains(cfg, base, paged):
    """Four requests share a 24-token system prompt: warm admissions
    skip the shared pages (within a drain via live sharing, across
    drains via the LRU cache) and decode stays bitwise-equal to the
    cold-start reference."""
    rng = np.random.default_rng(7)
    sys_p = rng.integers(1, cfg.vocab_size, size=24).astype(np.int64)
    share = [np.concatenate([sys_p,
                             rng.integers(1, cfg.vocab_size, size=k)])
             for k in (4, 6, 3, 5)]
    refs = [base.generate_reference(p, 10)["tokens"] for p in share]
    st0 = dict(paged.allocator.stats)
    res = paged.generate_batch(share, 10)
    for i, (r, ref) in enumerate(zip(res, refs)):
        assert r["tokens"] == list(ref), ("cold", i)
    st1 = dict(paged.allocator.stats)
    assert st1["prefix_hits"] > st0["prefix_hits"]
    # second drain: the prompts are fully warm from the LRU cache
    res = paged.generate_batch(share, 10)
    for i, (r, ref) in enumerate(zip(res, refs)):
        assert r["tokens"] == list(ref), ("warm", i)
    st2 = paged.allocator.stats
    assert (st2["prefix_hit_pages"] - st1["prefix_hit_pages"]
            > st1["prefix_hit_pages"] - st0["prefix_hit_pages"])
    paged.allocator.check()


def test_prefix_plus_tight_pool_no_lost_requests(cfg, base):
    """Regression: shared-prefix prompts under a pool of ~two worst-case
    sequences drive preempt/resume cycles where every lane can drain
    while the just-preempted head sits deferred — the run loop must lift
    the deferral and re-admit (no lost requests), resumed requests must
    re-admit on their full remaining footprint (no admit/re-prefill/
    preempt livelock), and the output stays bitwise-equal throughout."""
    rng = np.random.default_rng(5)
    bpt = base._bytes_per_token
    eng = PagedBatchedEngine(cfg, params=base.params, max_len=64,
                             segment_len=4, n_lanes=4, seed=0, page_size=8,
                             budget_bytes=9 * 8 * bpt)
    prefix = rng.integers(1, cfg.vocab_size, size=24).astype(np.int64)
    maxes = [32, 32, 6, 6, 6, 6, 32, 6]      # longs head the queue
    prompts = [np.concatenate([prefix,
                               rng.integers(1, cfg.vocab_size, size=8)])
               for _ in maxes]
    refs = [base.generate_reference(p, m)["tokens"]
            for p, m in zip(prompts, maxes)]
    res = eng.generate_batch(prompts, maxes)
    for i, (r, ref) in enumerate(zip(res, refs)):
        assert r is not None, f"request {i} lost"
        assert r["tokens"] == list(ref), (i, r["tokens"], list(ref))
    eng.allocator.reset_transient()
    assert eng.allocator.used_pages == 0
    eng.allocator.check()


# --------------------------------------------------------------- paged DES
SHORT, LONG = ServiceDist(0.2, 0.05), ServiceDist(1.5, 0.3)


def _workload(seed, n=60):
    rng = np.random.default_rng(seed)
    reqs = poisson_workload(rng, n, lam=2.0, short=SHORT, long=LONG,
                            mix_long=0.3)
    ptok = rng.integers(8, 64, size=n)
    ttok = ptok + rng.integers(16, 128, size=n)
    return reqs, ptok, ttok


@pytest.mark.parametrize("policy", ["fcfs", "sjf", "srpt"])
def test_paged_des_c1_bitwise_equals_serial(policy):
    """Solo lane: the page model is inert (no concurrent competitor, so
    exhaustion never fires) and the paged DES reproduces the serial
    server trace bitwise."""
    for seed in (3, 11):
        reqs, ptok, ttok = _workload(seed)
        a = simulate_servers(reqs, policy=policy, n_servers=1)
        sa = [(r.req_id, r.start, r.finish) for r in a.requests]
        b = simulate_paged(reqs, policy=policy, n_servers=1,
                           prompt_tokens=ptok, total_tokens=ttok,
                           page_size=16, n_pages=1000)
        sb = [(r.req_id, r.start, r.finish) for r in b.requests]
        assert sa == sb, (seed, policy, sa[:3], sb[:3])


def test_paged_des_tight_pool_bounded_no_losses():
    """12-page pool under 4 lanes: exhaustion preempts, every request
    still finishes, and the held-page peak never exceeds the pool."""
    reqs, ptok, ttok = _workload(3)
    r = simulate_paged(reqs, policy="sjf", n_servers=4,
                       slowdown=(1.0, 1.1, 1.25, 1.4),
                       prompt_tokens=ptok, total_tokens=ttok,
                       page_size=16, n_pages=12)
    assert all(np.isfinite(q.finish) for q in r.requests)
    assert r.preemptions > 0
    assert r.peak_pages <= 12 + 1e-9


def test_paged_des_prefix_sharing_improves_sojourn():
    """Half the requests share a 32-token system prefix: warm admits are
    counted and mean sojourn improves vs the cold run (paired)."""
    reqs, ptok, ttok = _workload(3)
    n = len(reqs)
    grp = np.where(np.arange(n) % 2 == 0, 0, -1)
    sh = np.where(grp == 0, 32.0, 0.0)
    sv = np.where(grp == 0, 0.05, 0.0)
    cold = simulate_paged(reqs, policy="sjf", n_servers=4,
                          prompt_tokens=ptok + 32, total_tokens=ttok + 32,
                          page_size=16, n_pages=40)
    cold_mean = cold.mean()                  # captured before the warm run
    warm = simulate_paged(reqs, policy="sjf", n_servers=4,
                          prompt_tokens=ptok + 32, total_tokens=ttok + 32,
                          page_size=16, n_pages=40, share_group=grp,
                          shared_tokens=sh, prefill_saved=sv)
    assert warm.prefix_hits > 0
    assert warm.mean() < cold_mean


def test_paged_des_matches_live_order_at_c1(cfg, base):
    """Acceptance gate: DES-predicted and live dispatch orderings agree
    at c=1.  Both sides run sjf_oracle over the same backlog — the DES
    by true service, the live engine through ClairvoyantServer with a
    solo paged lane."""
    from repro.serving.openai_api import CompletionRequest
    from repro.serving.server import ClairvoyantServer

    toks = [40, 4, 30, 6]                    # two longs first (HoL setup)
    des_reqs = []
    for i, tk in enumerate(toks):
        q = Request(req_id=i + 1, arrival=0.0, true_service=tk / 10.0,
                    klass="long" if tk > 20 else "short")
        q.p_long = 1.0 if tk > 20 else 0.0
        des_reqs.append(q)
    des = simulate_paged(des_reqs, policy="sjf_oracle", n_servers=1,
                         prompt_tokens=np.full(4, 8),
                         total_tokens=np.array([8 + t for t in toks]),
                         page_size=8, n_pages=64)
    des_order = [q.req_id for q in
                 sorted(des.requests, key=lambda q: q.start)]

    eng = PagedBatchedEngine(cfg, params=base.params, max_len=64,
                             segment_len=4, n_lanes=1, seed=0, page_size=8)
    server = ClairvoyantServer(policy="sjf_oracle", tau=None, engines=[eng])
    server.submit_many(
        [CompletionRequest(prompt="p %d" % i) for i in range(4)],
        true_output_tokens=toks,
        klasses=["long", "short", "long", "short"])
    resp = server.drain(max_new_tokens=40)
    live_order = [r.request_id for r in
                  sorted(resp, key=lambda r: r.queue_wait_s)]
    assert live_order == des_order == [2, 4, 3, 1]


def test_sweep_paging_grid_shapes():
    from repro.core.sweep import PAGING_METRICS, sweep_paging
    conditions = [("fcfs", None), ("sjf", None)]
    res = sweep_paging(conditions, page_sizes=(8, 16),
                       budgets=(256.0, 1024.0), share_ratios=(0.0, 0.6),
                       seeds=(0, 1), n=80, rho=0.7, short=SHORT, long=LONG)
    shape = (2, 2, 2, 2, 2)
    for m in PAGING_METRICS:
        arr = res.metric(m)
        assert arr.shape == shape, (m, arr.shape)
        assert np.all(np.isfinite(arr)), m
    # warm admits only happen when a share group exists
    hits = res.metric("prefix_hits")
    assert np.all(hits[..., 0, :] == 0)      # share ratio 0.0
    assert np.all(hits[..., 1, :] > 0)       # share ratio 0.6
    # the pool bound holds in every cell
    for pi, ps in enumerate((8, 16)):
        for bi, budget in enumerate((256.0, 1024.0)):
            assert np.all(res.metric("peak_pages")[:, pi, bi]
                          <= budget // ps + 1e-9)
