"""GBDT training/inference: learning, determinism, serialization, ranking."""

import numpy as np
import pytest

from repro.core.gbdt import GBDTModel, GBDTParams, train_gbdt


def _problem(n=1200, seed=0):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 3, n)
    X = rng.normal(0, 1, (n, 19)).astype(np.float32)
    X[:, 0] += y * 1.5
    X[:, 4] += (y == 2) * 2.0
    return X, y


def test_learns_separable_signal():
    X, y = _problem()
    m = train_gbdt(X, y, GBDTParams(num_rounds=60))
    acc = (m.predict_proba(X).argmax(1) == y).mean()
    assert acc > 0.9


def test_deterministic_given_seed():
    X, y = _problem()
    p = GBDTParams(num_rounds=20, seed=42)
    m1, m2 = train_gbdt(X, y, p), train_gbdt(X, y, p)
    np.testing.assert_array_equal(m1.value, m2.value)
    np.testing.assert_array_equal(m1.feature, m2.feature)


def test_proba_is_distribution():
    X, y = _problem(400)
    m = train_gbdt(X, y, GBDTParams(num_rounds=15))
    p = m.predict_proba(X)
    assert (p >= 0).all()
    np.testing.assert_allclose(p.sum(1), 1.0, atol=1e-6)


def test_save_load_roundtrip(tmp_path):
    X, y = _problem(300)
    m = train_gbdt(X, y, GBDTParams(num_rounds=10))
    path = str(tmp_path / "model.pkl")
    m.save(path)
    m2 = GBDTModel.load(path)
    np.testing.assert_array_equal(m.predict_margin(X), m2.predict_margin(X))


def test_degenerate_class_predicts_majority():
    """The paper's Table 2 finding: <200 Long examples -> degenerate model."""
    rng = np.random.default_rng(1)
    n = 2000
    y = np.zeros(n, np.int64)
    y[:4] = 2  # four Long examples, alpaca-style
    X = rng.normal(0, 1, (n, 19)).astype(np.float32)
    m = train_gbdt(X, y, GBDTParams(num_rounds=30))
    preds = m.predict_proba(X).argmax(1)
    assert (preds == 0).mean() > 0.99


def test_monotone_feature_gives_perfect_ranking():
    from repro.core.ranking import ranking_accuracy
    rng = np.random.default_rng(2)
    n = 900
    lengths = rng.choice([50, 400, 1200], n)
    X = np.zeros((n, 19), np.float32)
    X[:, 0] = lengths + rng.normal(0, 1, n)  # nearly clean signal
    y = np.where(lengths < 200, 0, np.where(lengths < 800, 1, 2))
    m = train_gbdt(X, y, GBDTParams(num_rounds=40))
    assert ranking_accuracy(lengths, m.predict_proba(X)[:, 2]) > 0.99


def test_fast_trainer_matches_reference_quality():
    """The depth-frontier trainer is not structurally identical to the
    seed trainer (histogram subtraction drifts near-tied gains, see
    _build_tree), but it must match its predictive quality."""
    from repro.core.gbdt import train_gbdt_reference
    X, y = _problem(900, seed=5)
    for params in (GBDTParams(num_rounds=25),
                   GBDTParams(num_rounds=15, subsample=0.7)):
        fast = train_gbdt(X, y, params)
        ref = train_gbdt_reference(X, y, params)
        acc_fast = (fast.predict_proba(X).argmax(1) == y).mean()
        acc_ref = (ref.predict_proba(X).argmax(1) == y).mean()
        assert abs(acc_fast - acc_ref) < 0.03, (acc_fast, acc_ref)
