"""Pallas kernel validation: interpret-mode vs pure-jnp oracles across
shape/dtype sweeps (required deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gbdt import GBDTParams, train_gbdt
from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_kernel
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.gbdt_infer import gbdt_margins_kernel


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,H,KV,S,hd", [
    (1, 4, 4, 128, 64),     # MHA
    (2, 8, 2, 256, 64),     # GQA
    (1, 4, 1, 128, 128),    # MQA
    (2, 6, 2, 384, 32),     # non-pow2 heads, 3 kv blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_kernel(B, H, KV, S, hd, dtype, causal):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    out = flash_attention_kernel(q, k, v, causal=causal, block_q=64,
                                 block_kv=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,KV,G,S,hd,t", [
    (2, 4, 1, 256, 64, 255),
    (1, 2, 4, 512, 128, 300),   # partially filled cache
    (3, 1, 8, 256, 64, 17),     # MQA, mostly-empty cache
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_kernel(B, KV, G, S, hd, t, dtype):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    out = decode_attention_kernel(q, k, v, t, block_kv=128, interpret=True)
    want = ref.decode_attention_ref(q, k, v, t)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def _toy_ensemble(seed=0, rounds=20):
    rng = np.random.default_rng(seed)
    B, F = 600, 19
    y = rng.integers(0, 3, B)
    X = rng.normal(0, 1, (B, F)).astype(np.float32)
    X[:, 0] += y * 1.2
    X[:, 3] += (y == 2) * 1.5
    model = train_gbdt(X, y, GBDTParams(num_rounds=rounds))
    return model, X


@pytest.mark.parametrize("batch", [1, 7, 128, 300])
def test_gbdt_kernel_matches_ref_and_numpy(batch):
    model, X = _toy_ensemble()
    Xb = X[:batch] if batch <= len(X) else np.tile(X, (3, 1))[:batch]
    want_np = model.predict_margin(Xb)
    got_ref = ref.gbdt_margins_ref(jnp.asarray(Xb), jnp.asarray(model.feature),
                                   jnp.asarray(model.threshold),
                                   jnp.asarray(model.value))
    got_krn = gbdt_margins_kernel(jnp.asarray(Xb), jnp.asarray(model.feature),
                                  jnp.asarray(model.threshold),
                                  jnp.asarray(model.value), interpret=True)
    np.testing.assert_allclose(np.asarray(got_ref), want_np, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_krn), want_np, atol=1e-4)


def test_ops_wrappers_model_layout():
    """ops.* accept model layout (B,S,H,hd) and agree with models/attention."""
    from repro.models.attention import flash_attention as jnp_flash
    ks = jax.random.split(jax.random.key(2), 3)
    B, S, H, KV, hd = 2, 128, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = ops.flash_attention(q, k, v, causal=True)
    want = jnp_flash(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
