"""Shared helpers for the per-table benchmarks."""

from __future__ import annotations

import functools
import time

import numpy as np

from repro.core.gbdt import GBDTParams
from repro.core.predictor import Predictor
from repro.data.pipeline import DataSplits, load_model_splits

ROUNDS = 150  # boosting rounds for benchmark-trained models (speed/fidelity)


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The harness contract: ``name,us_per_call,derived`` CSV lines."""
    print(f"{name},{us_per_call:.3f},{derived}")


@functools.lru_cache(maxsize=None)
def model_and_splits(model: str, rounds: int = ROUNDS,
                     drop_features: tuple = ()) -> tuple:
    sp = load_model_splits(model)
    Xtr = sp.train.X.copy()
    Xte = sp.test.X.copy()
    for f in drop_features:          # drop-one ablation: zero the column(s)
        Xtr[:, f] = 0.0
        Xte[:, f] = 0.0
    t0 = time.time()
    pred = Predictor.train_on_features(Xtr, sp.train.y,
                                       GBDTParams(num_rounds=rounds))
    train_s = time.time() - t0
    return pred, sp, Xte, train_s


def timed(fn, *args, repeat: int = 3):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args)
        best = min(best, time.perf_counter() - t0)
    return out, best
