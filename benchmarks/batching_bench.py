"""Batching benchmark: lane-scaling throughput, s(c) calibration, and the
batch-degree DES grid (writes ``BENCH_batching.json``).

Three measurements on the reduced smollm backbone (CPU container):

* **lane scaling** — aggregate decode tokens/s through
  ``BatchedRealEngine`` at c in {1, 2, 4, 8} lanes, all lanes saturated
  (c equal-length requests, no back-fill), against the c=1 serial fused
  path (``RealEngine.generate``).  The acceptance bar: c=4 aggregate
  >= 2x the serial fused path.
* **s(c) calibration** — the per-lane slowdown the c-server DES needs:
  ``s(c) = wall_c / wall_1`` for a fixed per-lane token count (each
  lane's tokens take s(c) x longer when c lanes share the backend);
  aggregate speedup is ``c / s(c)``.
* **batch-degree grid** — ``core.sweep.sweep_lane_batches``: FCFS vs SJF
  vs SRPT x c in {1, 2, 4, 8} x KV budget on the paper's rho = 0.74
  Poisson workload with NOISY predictor scores (~0.87 ranking accuracy,
  like BENCH_policies), using the s(c) measured above.  This quantifies
  the ROADMAP question: how much of the paper's short-P50 win does plain
  batching recover with no scheduling at all, and how much does
  predictive admission still add on top.

    PYTHONPATH=src python -m benchmarks.run batching
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit

MAX_LEN = 192         # long decodes: steady-state lanes, not fill overhead
SEGMENT = 16          # the serve-path default; same segment on both sides
N_NEW = 160
PROMPT_LEN = 16
LANES = (1, 2, 4, 8)
REPEAT = 5


def _measure_lanes(result: dict):
    from repro.configs import get_config
    from repro.serving.engine import BatchedRealEngine, RealEngine

    cfg = get_config("smollm-360m").reduced()
    serial = RealEngine(cfg, max_len=MAX_LEN, segment_len=SEGMENT, seed=0)
    rng = np.random.default_rng(0)
    ids = rng.integers(0, cfg.vocab_size, PROMPT_LEN)

    serial.generate(ids, max_new_tokens=N_NEW)          # compile
    engines = {}
    for c in LANES:
        engines[c] = BatchedRealEngine(cfg, params=serial.params,
                                       max_len=MAX_LEN, segment_len=SEGMENT,
                                       n_lanes=c)
        engines[c].generate_batch([ids] * c, max_new_tokens=4)   # compile

    # interleave serial/lane rounds so host-load drift (this is a shared,
    # cpu-share-throttled container) hits every engine equally; best-of
    walls = {c: float("inf") for c in LANES}
    walls["serial"] = float("inf")
    for _ in range(REPEAT):
        t0 = time.perf_counter()
        serial.generate(ids, max_new_tokens=N_NEW)
        walls["serial"] = min(walls["serial"], time.perf_counter() - t0)
        for c in LANES:
            t0 = time.perf_counter()
            engines[c].generate_batch([ids] * c, max_new_tokens=N_NEW)
            walls[c] = min(walls[c], time.perf_counter() - t0)

    serial_tok_s = N_NEW / walls["serial"]
    result["tok_per_s_serial_fused"] = serial_tok_s
    emit("batching_serial_fused", walls["serial"] / N_NEW * 1e6,
         f"{serial_tok_s:.0f} tok/s (c=1 fused path)")
    slowdown = []
    for c in LANES:
        # per-lane stretch: each lane's fixed token count takes s(c) x
        # longer than on the 1-lane engine (>= 1; sub-1 readings are the
        # 1-lane run's fixed costs, clamped for the DES)
        s_c = max(walls[c] / walls[1], 1.0)
        slowdown.append(s_c)
        agg = c * N_NEW / walls[c]
        result[f"tok_per_s_lanes_c{c}"] = agg
        result[f"slowdown_s{c}"] = s_c
        emit(f"batching_lanes_c{c}", walls[c] / (c * N_NEW) * 1e6,
             f"{agg:.0f} tok/s aggregate, s({c})={s_c:.2f}, "
             f"speedup c/s(c)={c / s_c:.2f}x")
    # dense s(k) for every k <= max lanes (the DES re-scales at every
    # busy-count change, not just the measured ones): linear interpolation
    # over the measured lane counts
    dense = np.interp(np.arange(1, max(LANES) + 1), LANES, slowdown)
    slowdown = [float(x) for x in np.maximum(dense, 1.0)]
    result["slowdown"] = [round(s, 4) for s in slowdown]
    result["agg_speedup_c4_vs_serial"] = \
        result["tok_per_s_lanes_c4"] / serial_tok_s
    result["meets_2x_at_c4"] = bool(result["agg_speedup_c4_vs_serial"] >= 2.0)
    emit("batching_c4_vs_serial",
         walls[4] / (4 * N_NEW) * 1e6,
         f"c=4 aggregate {result['tok_per_s_lanes_c4']:.0f} tok/s = "
         f"{result['agg_speedup_c4_vs_serial']:.2f}x the c=1 fused path "
         f"(bar: >= 2x)")
    return slowdown


def _grid(result: dict, slowdown, n: int = 1000, seeds: int = 5):
    from repro.core.sim_fast import RequestBatch
    from repro.core.simulation import _spread_for_accuracy
    from repro.core.sweep import sweep_lane_batches
    from repro.serving.service_time import (PAPER_4090_LONG,
                                            PAPER_4090_SHORT)

    short, long = PAPER_4090_SHORT, PAPER_4090_LONG
    es = 0.5 * (short.mean + long.mean)
    tau = 3.0 * short.mean
    spread = _spread_for_accuracy(0.87)
    # memory-token budgets: None = lane-limited; 600 tokens ~ one long
    # request's KV residency (60 tok/s x ~8.9 s) plus a short's, so the
    # finite budget bites exactly when several longs want lanes at once
    budgets = (None, 600.0)
    # two load points: the paper's rho = 0.74 (c=1-feasible — batching
    # alone drains the queue) and a capacity-matched overload row,
    # rho2 = 0.74 x 4/s(4): deep overload for one lane, but at c=4 the
    # EFFECTIVE utilization is back at the paper's operating point — the
    # load regime batching newly opens, where admission matters again.
    # The guard runs at the paper's tau in the steady-state row; in the
    # overload row every wait exceeds any fixed tau, so an armed guard
    # collapses all policies to FCFS (the Table-8 burst effect) — it is
    # disabled there, as in the burst replication.
    rho2 = round(0.74 * 4.0 / slowdown[3], 2)
    rhos = (0.74, rho2)
    taus = {0.74: tau, rho2: None}
    grid = {}
    for rho in rhos:
        conditions = [("fcfs", taus[rho]), ("sjf", taus[rho]),
                      ("srpt", taus[rho])]
        batches = []
        for s in range(seeds):
            rng = np.random.default_rng(s)
            b = RequestBatch.poisson(rng, n, rho / es, short, long)
            base = np.where(b.p_long > 0.5, 0.75, 0.25)
            b.p_long = np.clip(rng.normal(base, spread), 0.0, 1.0)
            batches.append(b)
        t0 = time.perf_counter()
        flat = sweep_lane_batches(batches, conditions, LANES,
                                  budgets=budgets, slowdown=slowdown)
        dt = time.perf_counter() - t0
        cells = len(conditions) * len(LANES) * len(budgets) * seeds
        emit(f"batching_grid_rho{rho}", dt / cells * 1e6,
             f"{cells} DES cells (3 policies x {len(LANES)} lane counts x "
             f"{len(budgets)} budgets x {seeds} seeds, n={n}) in {dt:.2f}s")
        for ci, (pol, _) in enumerate(conditions):
            for li, c in enumerate(LANES):
                for bi, budget in enumerate(budgets):
                    label = f"rho{rho}_{pol}_c{c}" + \
                        ("" if budget is None else f"_kv{int(budget)}")
                    grid[label] = {
                        m: round(float(flat[m][ci, li, bi].mean()), 3)
                        for m in ("short_p50", "short_p99", "long_p50",
                                  "long_p99", "mean_sojourn")}
    result["grid"] = grid
    result["grid_axes"] = {"policies": [p for p, _ in conditions],
                           "lanes": list(LANES), "rhos": list(rhos),
                           "budgets_tokens": [b for b in budgets],
                           "tau": tau, "n": n, "seeds": seeds,
                           "ranking_accuracy": 0.87,
                           "slowdown": [round(s, 4) for s in slowdown]}

    # the decomposition headline, per load point: how much of the
    # scheduling win batching recovers alone, and what admission adds
    for rho in rhos:
        f1 = grid[f"rho{rho}_fcfs_c1"]["short_p50"]
        s1 = grid[f"rho{rho}_sjf_c1"]["short_p50"]
        f4 = grid[f"rho{rho}_fcfs_c4"]["short_p50"]
        s4 = grid[f"rho{rho}_sjf_c4"]["short_p50"]
        r4 = grid[f"rho{rho}_srpt_c4"]["short_p50"]
        key = f"rho{rho}"
        result[f"{key}_short_p50"] = {"fcfs_c1": f1, "sjf_c1": s1,
                                      "fcfs_c4": f4, "sjf_c4": s4,
                                      "srpt_c4": r4}
        result[f"{key}_sjf_win_pct_c1"] = round(100 * (1 - s1 / f1), 1)
        result[f"{key}_sjf_win_pct_on_top_of_c4"] = \
            round(100 * (1 - s4 / f4), 1)
        result[f"{key}_srpt_win_pct_on_top_of_c4"] = \
            round(100 * (1 - r4 / f4), 1)
        emit(f"batching_decomposition_rho{rho}", 0.0,
             f"short P50 fcfs@c1 {f1:.1f}s sjf@c1 {s1:.1f}s "
             f"(sjf win {result[f'{key}_sjf_win_pct_c1']:.0f}%) | "
             f"fcfs@c4 {f4:.1f}s sjf@c4 {s4:.1f}s srpt@c4 {r4:.1f}s "
             f"(admission on top of batching: sjf "
             f"{result[f'{key}_sjf_win_pct_on_top_of_c4']:.0f}%, srpt "
             f"{result[f'{key}_srpt_win_pct_on_top_of_c4']:.0f}%)")


def run() -> dict:
    result: dict = {"max_len": MAX_LEN, "segment_len": SEGMENT,
                    "max_new_tokens": N_NEW, "lanes": list(LANES)}
    slowdown = _measure_lanes(result)
    _grid(result, slowdown)
    return result


if __name__ == "__main__":
    run()
