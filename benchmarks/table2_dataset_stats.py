"""Table 2: Long-class representation across the seven dataset profiles.

The paper's central data finding: curated instruction datasets (Alpaca,
CodeAlpaca) are degenerate SJF training sources (<0.02% Long).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.ranking import class_labels
from repro.data.corpus import PROFILES, sample_dataset

VERDICT = {
    "sharegpt": "Yes (balanced)", "lmsys": "Yes (filtered)",
    "oasst1": "Yes (limited)", "alpaca": "No (starvation)",
    "codealpaca": "No (starvation)", "dolly": "Test-only",
    "cnn_dailymail": "Test-only",
}


def run(sample_n: int = 30000, seed: int = 0) -> dict:
    out = {}
    for name, prof in PROFILES.items():
        t0 = time.perf_counter()
        n = min(prof.published_total, sample_n)
        ds = sample_dataset(name, n=n, seed=seed)
        y = class_labels(ds.lengths)
        counts = np.bincount(y, minlength=3)
        # scale the empirical draw to the published dataset size
        scaled = np.round(counts / n * prof.published_total).astype(int)
        pct_long = 100.0 * scaled[2] / prof.published_total
        paper_pct = 100.0 * prof.published_counts[2] / sum(prof.published_counts)
        dt = (time.perf_counter() - t0) * 1e6
        out[name] = dict(counts=scaled.tolist(),
                         published=list(prof.published_counts),
                         pct_long=pct_long, paper_pct_long=paper_pct)
        emit(f"table2_{name}", dt,
             f"short/med/long={scaled[0]}/{scaled[1]}/{scaled[2]} "
             f"%long={pct_long:.3f} (paper {paper_pct:.3f}) "
             f"usable={VERDICT[name]}")
    return out


if __name__ == "__main__":
    run()
