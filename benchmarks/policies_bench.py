"""Policies sweep: every registered scheduling policy on the paper's two
workloads (deliverable of the policy-layer PR).

Compares short-class P50/P99 (and long-class P50/P99) across the full
policy registry — the seed fcfs / sjf / sjf_oracle plus preemptive SRPT,
quantile-aware SJF, MLFQ and per-tenant fair share — under

* the §5.4 steady-state condition: Poisson arrivals at rho = 0.74,
  n = 2000 x ``seeds`` runs, RTX 4090 service calibration;
* the §5.5 stress condition: a 100-request burst (50 short / 50 long),
  tau = None as in the Table 8 replication (in the burst regime an armed
  guard promotes everything and every key policy collapses to FCFS).

P(Long) scores are NOISY (the §5.2 predictor fidelity, ~0.87 pairwise
ranking accuracy, via ``simulation.imperfect_predictor``'s spread) rather
than oracle 0/1: with perfect scores every scalar key policy is a
monotone relabeling of the same ordering, which would hide exactly the
differences (quantile hedging, MLFQ demotion) this sweep measures.

Each workload x policy grid runs through ``core.sweep`` in one engine
call (preemptive rows on the preemptive C/heapq engine, key rows on the
non-preemptive one), plus a two-tenant fair-share isolation cell.
Writes ``BENCH_policies.json``:

    PYTHONPATH=src python -m benchmarks.run policies
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.sim_fast import RequestBatch, simulate_batch
from repro.core.simulation import _spread_for_accuracy
from repro.core.sweep import sweep_batches
from repro.serving.service_time import PAPER_4090_LONG, PAPER_4090_SHORT

RANKING_ACCURACY = 0.87          # the paper's cross-dataset predictor


def _noisy_p_long(rng, batch: RequestBatch) -> None:
    """Replace oracle 0/1 scores with predictor-fidelity noisy ones."""
    spread = _spread_for_accuracy(RANKING_ACCURACY)
    base = np.where(batch.p_long > 0.5, 0.75, 0.25)
    batch.p_long = np.clip(rng.normal(base, spread), 0.0, 1.0)


def run(n: int = 2000, seeds: int = 5, rho: float = 0.74) -> dict:
    short, long = PAPER_4090_SHORT, PAPER_4090_LONG
    tau = 3.0 * short.mean                       # the paper's tau = 3x
    es = 0.5 * (short.mean + long.mean)

    conditions = [("fcfs", "fcfs"),
                  ("sjf", "sjf"),
                  ("sjf_oracle", "sjf_oracle"),
                  ("srpt", "srpt"),
                  ("sjf_quantile", "sjf_quantile"),
                  ("mlfq", "mlfq"),
                  ("fair_share", "fair_share")]

    out: dict = {"n": n, "seeds": seeds, "rho": rho, "tau": tau,
                 "ranking_accuracy": RANKING_ACCURACY}
    for wl, tau_wl in (("poisson", tau), ("burst", None)):
        batches = []
        for s in range(seeds):
            rng = np.random.default_rng(s)
            if wl == "poisson":
                b = RequestBatch.poisson(rng, n, rho / es, short, long)
            else:
                b = RequestBatch.burst(rng, 50, 50, short, long)
            _noisy_p_long(rng, b)
            batches.append(b)
        t0 = time.perf_counter()
        flat = sweep_batches(batches, [(p, tau_wl) for _, p in conditions])
        dt = (time.perf_counter() - t0) * 1e6 / (len(conditions) * seeds)
        for ci, (label, _) in enumerate(conditions):
            cell = {m: float(flat[m][ci].mean())
                    for m in ("short_p50", "short_p99", "long_p50",
                              "long_p99", "promotions")}
            out.setdefault(label, {})[wl] = cell
            emit(f"policies_{wl}_{label}", dt,
                 f"shortP50={cell['short_p50']:.2f}s "
                 f"shortP99={cell['short_p99']:.2f}s "
                 f"longP50={cell['long_p50']:.2f}s "
                 f"longP99={cell['long_p99']:.2f}s")

    # two-tenant isolation cell: tenant A floods 80 requests, tenant B
    # sends 20 — fair share must shield B from A's backlog
    rng = np.random.default_rng(0)
    b = RequestBatch.burst(rng, 50, 50, short, long)
    _noisy_p_long(rng, b)
    b.tenant = (np.arange(len(b)) % 5 == 0).astype(np.int32)  # 20% tenant B
    b.tenants = ("flood", "light")
    light = b.tenant == 1
    soj = {}
    for pol in ("fcfs", "fair_share"):
        res = simulate_batch(b, policy=pol)
        soj[pol] = float((res.finish - b.arrival)[light].mean())
    out["fair_share_light_tenant_mean_sojourn"] = soj["fair_share"]
    out["fcfs_light_tenant_mean_sojourn"] = soj["fcfs"]
    speedup = soj["fcfs"] / soj["fair_share"]
    emit("policies_fair_share_isolation", 0.0,
         f"light-tenant mean sojourn {soj['fair_share']:.1f}s vs "
         f"{soj['fcfs']:.1f}s under FCFS ({speedup:.2f}x)")

    # headline: SRPT vs non-preemptive SJF on steady-state short latency
    red = (1.0 - out["srpt"]["poisson"]["short_p50"]
           / out["sjf"]["poisson"]["short_p50"]) * 100.0
    out["srpt_short_p50_reduction_vs_sjf_poisson_pct"] = red
    out["srpt_beats_sjf_poisson"] = bool(
        out["srpt"]["poisson"]["short_p50"]
        <= out["sjf"]["poisson"]["short_p50"] + 1e-9)
    emit("policies_summary", 0.0,
         f"srpt_vs_sjf_poisson_shortP50={red:+.1f}% "
         f"(preemption rescues shorts stuck behind in-service longs)")
    return out


if __name__ == "__main__":
    run()
