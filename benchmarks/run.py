"""Benchmark harness: one module per paper table/figure (deliverable d).

Prints ``name,us_per_call,derived`` CSV per the harness contract.  Two
suites additionally write machine-readable perf records at the repo root,
tracked across PRs:

* ``predictor`` -> ``BENCH_predictor.json`` (feature-extraction us,
  single / batch host-scorer us, Pallas us, train seconds, speedups);
* ``sim`` -> ``BENCH_sim.json`` (one-shot sweep vs per-event reference
  wall clock on a table9-sized grid, trace-equivalence verdict);
* ``serve`` -> ``BENCH_serve.json`` (seed vs fused real-decode tokens/s,
  TTFT, per-token dispatch overhead, end-to-end queue-to-completion P50);
* ``policies`` -> ``BENCH_policies.json`` (short/long P50+P99 for every
  registered scheduling policy under Poisson rho=0.74 and 100-req burst);
* ``batching`` -> ``BENCH_batching.json`` (lane-scaling tok/s through the
  micro-batched engine, the s(c) slowdown calibration, and the
  policy x lane-count x KV-budget DES grid);
* ``faults`` -> ``BENCH_faults.json`` (fault-injection degradation
  curves: SJF-vs-FCFS short-P50 and goodput across crash-MTBF x repair
  grids, overload shedding P99 bound, serving-layer chaos drain);
* ``sidecar`` -> ``BENCH_sidecar.json`` (loopback HTTP/SSE: streaming
  TTFT overhead vs in-process, client-observed SJF-vs-FCFS short P50);
* ``paging`` -> ``BENCH_paging.json`` (block-paged admission vs
  worst-case KVBudget accounting at an identical byte budget: aggregate
  tok/s + short P50, prefix-reuse warm-prefill speedup, and the
  page-size x budget x share-ratio DES grid);
* ``speculative`` -> ``BENCH_speculative.json`` (draft-verify lanes at
  c=4 vs the fused lane path — aggregate tok/s speedup with bitwise
  token equality, adversarial-draft contrast, and the acceptance-aware
  admission policy x draft-K x acceptance-distribution DES grid);
* ``observability`` -> ``BENCH_observability.json`` (flight-recorder /
  metrics overhead on the loopback wire drain, ranking-monitor fidelity
  recovery + inversion-alert, DES-vs-live trace parity).

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run predictor  # one suite
"""

from __future__ import annotations

import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSONS = {
    "predictor": os.path.join(_ROOT, "BENCH_predictor.json"),
    "sim": os.path.join(_ROOT, "BENCH_sim.json"),
    "serve": os.path.join(_ROOT, "BENCH_serve.json"),
    "policies": os.path.join(_ROOT, "BENCH_policies.json"),
    "batching": os.path.join(_ROOT, "BENCH_batching.json"),
    "faults": os.path.join(_ROOT, "BENCH_faults.json"),
    "sidecar": os.path.join(_ROOT, "BENCH_sidecar.json"),
    "paging": os.path.join(_ROOT, "BENCH_paging.json"),
    "speculative": os.path.join(_ROOT, "BENCH_speculative.json"),
    "observability": os.path.join(_ROOT, "BENCH_observability.json"),
}


def main() -> None:
    from benchmarks import (batching_bench, faults_bench, fig3_rho_sweep,
                            observability_bench, paging_bench,
                            policies_bench, predictor_latency,
                            serve_bench, sidecar_bench, sim_bench,
                            speculative_bench, table1_service_stats,
                            table2_dataset_stats, table4_ablation,
                            table5_ranking, table6_cross, table7_baselines,
                            table8_burst, table9_tau)

    suites = {
        "table1": table1_service_stats.run,
        "table2": table2_dataset_stats.run,
        "table4": table4_ablation.run,
        "table5": table5_ranking.run,
        "table6": table6_cross.run,
        "table7": table7_baselines.run,
        "table8": table8_burst.run,
        "table9": table9_tau.run,
        "fig3": fig3_rho_sweep.run,
        "predictor": predictor_latency.run,
        "sim": sim_bench.run,
        "serve": serve_bench.run,
        "policies": policies_bench.run,
        "batching": batching_bench.run,
        "faults": faults_bench.run,
        "sidecar": sidecar_bench.run,
        "paging": paging_bench.run,
        "speculative": speculative_bench.run,
        "observability": observability_bench.run,
    }
    wanted = sys.argv[1:] or list(suites)
    t0 = time.time()
    for name in wanted:
        fn = suites.get(name)
        if fn is None:
            sys.exit(f"unknown suite {name!r}; available: {', '.join(suites)}")
        print(f"# --- {name} ---")
        result = fn()
        path = BENCH_JSONS.get(name)
        if path and isinstance(result, dict):
            with open(path, "w") as f:
                json.dump({k: round(v, 4) if isinstance(v, float) else v
                           for k, v in result.items()}, f, indent=2)
                f.write("\n")
            print(f"# wrote {path}")
    print(f"# total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
