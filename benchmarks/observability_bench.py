"""Observability overhead + fidelity benchmark (writes
``BENCH_observability.json``).

Three questions the flight recorder / metrics / ranking monitor must
answer before "always-on observability" is credible:

* **What does instrumentation cost?** — the same seeded loopback burst
  is drained through the HTTP sidecar three ways: a no-op
  ``Observability()`` bundle (baseline), the metrics+ranking default
  (recorder off), and the fully traced bundle (recorder + metrics +
  ranking).  The acceptance bar: fully instrumented throughput >= 0.95x
  baseline, and recorder-off indistinguishable from baseline — no
  measurable slowdown (>= 0.95x; a *faster* reading is run-to-run
  noise on a loopback drain, not a cost, so the gate is one-sided).  The
  virtual-time sim drain's per-request tracing cost is reported
  alongside (microseconds per request, informational).
* **Does the ranking monitor read true?** — a drain scored by a noisy
  two-class predictor synthesised at 0.87 pairwise accuracy must
  recover ~0.87 (+/- 0.05) windowed concordance, and an injected
  prediction inversion must trip the alert within one window — visible
  in the rendered /metrics exposition, not just in-process.
* **Do sim and live traces agree?** — a DES drain and a live loopback
  drain of the same workload must export Perfetto traces with identical
  span schemas and matching dispatch order at c=1 under the oracle key.

    PYTHONPATH=src python -m benchmarks.run observability
"""

from __future__ import annotations

import asyncio
import json
import time

import numpy as np

from benchmarks.common import emit

BURST_N = 96
REPS = 5
# large enough that simulated service dominates the drain wall (as model
# compute would in a real deployment) instead of the Python wire envelope
TIME_SCALE = 0.01
SHORT_TOKS, LONG_TOKS = 12, 96
SIM_N = 400


def _model():
    from repro.serving.service_time import ServiceTimeModel
    return ServiceTimeModel(prefill_tok_per_s=8000.0,
                            decode_tok_per_s=60.0)


def _make_sidecar(obs, model, n_replicas=2):
    from repro.serving.backends import SimTextBackend
    from repro.serving.http_sidecar import Sidecar
    from repro.serving.server import ClairvoyantServer
    backends = [SimTextBackend(model, replica_id=i, time_scale=TIME_SCALE)
                for i in range(n_replicas)]
    server = ClairvoyantServer(policy="sjf_oracle", tau=None,
                               engines=backends, service_model=model,
                               deadline_mode="sojourn", seed=0,
                               observability=obs)
    return Sidecar(server, port=0, max_inflight=BURST_N + 8)


async def _drain_burst(obs) -> float:
    """Fire the seeded burst at a fresh sidecar; returns wall seconds
    from first submit to last terminal."""
    from repro.serving.backends import HTTPBackend
    model = _model()
    sc = _make_sidecar(obs, model)
    await sc.start()
    client = HTTPBackend("127.0.0.1", sc.port)
    rng = np.random.default_rng(0)
    kinds = rng.random(BURST_N) < 0.6

    async def one(i):
        otoks = SHORT_TOKS if kinds[i] else LONG_TOKS
        await client.generate(f"burst request {i}", max_new_tokens=otoks,
                              extra={"output_tokens": int(otoks)})

    t0 = time.monotonic()
    try:
        await asyncio.gather(*[one(i) for i in range(BURST_N)])
        wall = time.monotonic() - t0
    finally:
        await sc.shutdown(drain_s=5.0)
    assert len(sc.server._terminal) == BURST_N
    return wall


def _bench_overhead(result: dict) -> None:
    from repro.serving.observability import Observability
    configs = {
        "baseline": lambda: Observability(),             # all components off
        "recorder_off": lambda: Observability.default(tracing=False),
        "instrumented": lambda: Observability.default(tracing=True),
    }
    asyncio.run(_drain_burst(configs["baseline"]()))     # warm-up, discard
    walls: dict = {name: [] for name in configs}
    for _ in range(REPS):
        # interleave configs so drift (GC pressure, allocator state)
        # hits all three equally instead of biasing whichever runs last
        for name, mk in configs.items():
            walls[name].append(asyncio.run(_drain_burst(mk())))
    tput: dict = {}
    for name in configs:
        # best-of-reps: scheduling jitter only ever slows a drain down,
        # so min wall is the stable estimator of the config's cost
        tput[name] = BURST_N / float(np.min(walls[name]))
        result[f"wire_tput_{name}_rps"] = tput[name]
    # ratios are paired per round: the three configs of one round run
    # back-to-back, so contention episodes hit them alike and the
    # median per-round ratio cancels that common-mode drift
    base = np.asarray(walls["baseline"])

    def ratio(name):
        return float(np.median(base / np.asarray(walls[name])))

    r_instr = ratio("instrumented")
    r_off = ratio("recorder_off")
    result["wire_tput_instrumented_ratio"] = r_instr
    result["wire_tput_recorder_off_ratio"] = r_off
    result["overhead_ok"] = bool(r_instr >= 0.95)
    # one-sided: recorder-off must show no measurable slowdown; a
    # faster-than-baseline reading is loopback jitter, not a cost
    result["recorder_off_indistinguishable"] = bool(r_off >= 0.95)
    emit("observability_wire_overhead", 1e6 / tput["instrumented"],
         f"instr={r_instr:.3f}x off={r_off:.3f}x of baseline "
         f"(bar: instr>=0.95x)")

    # virtual-time sim drain: tracing cost per request (informational —
    # virtual drains do no wire work, so this is the worst case)
    from repro.serving.openai_api import CompletionRequest
    from repro.serving.server import ClairvoyantServer

    def sim_drain(obs):
        srv = ClairvoyantServer(policy="sjf_oracle", predictor=None,
                                service_model=_model(), seed=0,
                                observability=obs)
        rng = np.random.default_rng(1)
        srv.submit_many(
            [CompletionRequest(prompt=f"sim {i}") for i in range(SIM_N)],
            arrivals=[float(a) for a in
                      np.sort(rng.uniform(0, 50, SIM_N))],
            true_output_tokens=[int(t) for t in
                                rng.integers(16, 400, SIM_N)])
        t0 = time.perf_counter()
        srv.drain()
        return time.perf_counter() - t0

    from repro.serving.observability import Observability as _Obs
    base = min(sim_drain(_Obs()) for _ in range(REPS))
    traced = min(sim_drain(_Obs.default(tracing=True)) for _ in range(REPS))
    result["sim_drain_us_per_req_base"] = base / SIM_N * 1e6
    result["sim_drain_us_per_req_traced"] = traced / SIM_N * 1e6
    emit("observability_sim_trace_cost",
         (traced - base) / SIM_N * 1e6,
         f"virtual drain: {base/SIM_N*1e6:.1f} -> "
         f"{traced/SIM_N*1e6:.1f} us/req with full tracing")


class _NoisyOraclePredictor:
    """Two-class scorer at a target cross-class pairwise accuracy (the
    bench analogue of ``simulation.imperfect_predictor``): prompts
    tagged ``long`` score around 0.75, others around 0.25."""

    def __init__(self, accuracy: float, seed: int = 0, invert=False):
        from repro.core.simulation import _spread_for_accuracy
        self.spread = _spread_for_accuracy(accuracy)
        self.rng = np.random.default_rng(seed)
        self.invert = invert

    def p_long_batch(self, prompts):
        base = np.where([("long" in p) for p in prompts], 0.75, 0.25)
        p = np.clip(self.rng.normal(base, self.spread), 0.0, 1.0)
        return 1.0 - p if self.invert else p

    def proba_batch(self, prompts):
        pl = self.p_long_batch(prompts)
        return np.stack([1.0 - pl, np.zeros_like(pl), pl], axis=1)


def _ranked_drain(accuracy, invert=False, n=256):
    from repro.serving.observability import Observability
    from repro.serving.openai_api import CompletionRequest
    from repro.serving.server import ClairvoyantServer
    obs = Observability.default(tracing=False, window=n)
    srv = ClairvoyantServer(
        policy="sjf", predictor=_NoisyOraclePredictor(accuracy, invert=invert),
        service_model=_model(), seed=0, observability=obs)
    rng = np.random.default_rng(2)
    kinds = rng.random(n) < 0.5
    srv.submit_many(
        # constant prompt per class: within-class services are then
        # exactly identical (ties, excluded from concordance)
        [CompletionRequest(prompt="long request" if kinds[i] else
                           "short request")
         for i in range(n)],
        arrivals=[0.01 * i for i in range(n)],
        # within-class services identical -> those pairs are ties
        # (excluded), so concordance == cross-class accuracy
        true_output_tokens=[LONG_TOKS * 8 if kinds[i] else SHORT_TOKS
                            for i in range(n)],
        klasses=["long" if kinds[i] else "short" for i in range(n)])
    srv.drain()
    return obs


def _bench_ranking(result: dict) -> None:
    from repro.serving.observability import parse_prometheus
    target = 0.87
    obs = _ranked_drain(target)
    snap = obs.ranking.snapshot()
    err = abs(snap["concordance"] - target)
    result["ranking_target"] = target
    result["ranking_measured"] = snap["concordance"]
    result["ranking_recovered_ok"] = bool(err <= 0.05)

    obs_inv = _ranked_drain(0.9, invert=True)
    # the alert must be visible in the scraped exposition, not just
    # in-process
    fams = parse_prometheus(obs_inv.render_metrics())
    alert_v = fams["clairvoyant_ranking_alert"][0][2]
    conc_v = fams["clairvoyant_ranking_concordance"][0][2]
    result["ranking_inverted_concordance"] = conc_v
    result["ranking_inversion_alert_ok"] = bool(alert_v == 1.0)
    emit("observability_ranking", snap["concordance"] * 1e6,
         f"measured={snap['concordance']:.3f} (target {target}+/-0.05) "
         f"inverted={conc_v:.3f} alert={int(alert_v)}")


def _bench_parity(result: dict) -> None:
    from repro.core.scheduler import Request
    from repro.core.simulation import simulate
    from repro.serving.backends import HTTPBackend, SimTextBackend
    from repro.serving.http_sidecar import Sidecar
    from repro.serving.observability import FlightRecorder, Observability
    from repro.serving.server import ClairvoyantServer
    model = _model()

    async def live():
        backend = SimTextBackend(model, replica_id=0, time_scale=0.05)
        srv = ClairvoyantServer(policy="sjf_oracle", predictor=None,
                                service_model=model, engines=[backend],
                                seed=0, deadline_mode="sojourn",
                                observability=Observability.default())
        sc = Sidecar(srv, port=0, max_new_tokens=512)
        await sc.start()
        client = HTTPBackend("127.0.0.1", sc.port)

        async def call(otok):
            await client.generate("same prompt", max_new_tokens=otok,
                                  extra={"output_tokens": int(otok)})

        head = asyncio.create_task(call(200))
        await asyncio.sleep(0.08)
        rest = [asyncio.create_task(call(o)) for o in (32, 8, 24, 16, 40)]
        await asyncio.gather(head, *rest)
        await sc.shutdown(drain_s=2.0)
        return srv

    srv = asyncio.run(live())
    rec = srv.obs.recorder

    def order(r):
        pref = sorted((s for s in r.spans()
                       if s.name == "prefill" and s.track == "replica0"),
                      key=lambda s: s.t0)
        return [s.req_id for s in pref]

    live_order = order(rec)
    arrival_of = {s.req_id: s.t0 for s in rec.spans()
                  if s.name == "queue_wait"}
    otok_of = {r.request_id: r.tokens_generated for r in srv.responses}
    des_rec = FlightRecorder()
    ptoks = len("same prompt".split())
    simulate([Request(req_id=rid, prompt="same prompt",
                      arrival=arrival_of[rid],
                      true_service=model.service(ptoks, otok_of[rid]),
                      meta={"output_tokens": otok_of[rid]})
              for rid in live_order],
             policy="sjf_oracle", recorder=des_rec)
    schema_ok = set(des_rec.schema()) == set(rec.schema())
    order_ok = order(des_rec) == live_order
    result["parity_schema"] = sorted(rec.schema())
    result["parity_schema_ok"] = bool(schema_ok)
    result["parity_dispatch_order_ok"] = bool(order_ok)
    # both traces must be valid Perfetto JSON
    json.loads(json.dumps(rec.to_perfetto()))
    json.loads(json.dumps(des_rec.to_perfetto()))
    emit("observability_parity", 0.0,
         f"schema_ok={schema_ok} dispatch_order_ok={order_ok} "
         f"({len(live_order)} reqs at c=1)")


def run() -> dict:
    result: dict = {"burst_n": BURST_N, "reps": REPS,
                    "time_scale": TIME_SCALE, "sim_n": SIM_N}
    _bench_overhead(result)
    _bench_ranking(result)
    _bench_parity(result)
    return result


if __name__ == "__main__":
    run()
