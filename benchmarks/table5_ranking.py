"""Table 5: in-distribution ranking vs classification accuracy (Models A/B/C).

Paper: A 76.3/47.6, B 95.6/66.8, C 62.2/41.0 — ranking beats classification
by 21-29 pp, the metric argument at the heart of §4.1.
"""

from __future__ import annotations

import time

from benchmarks.common import emit, model_and_splits

PAPER = {"A": (76.29, 47.6), "B": (95.62, 66.8), "C": (62.21, 41.0)}


def run() -> dict:
    from repro.core.ranking import classification_accuracy, ranking_accuracy
    out = {}
    for m in "ABC":
        pred, sp, Xte, train_s = model_and_splits(m)
        t0 = time.perf_counter()
        proba = pred.model.predict_proba(Xte)
        dt = (time.perf_counter() - t0) / len(Xte) * 1e6
        ra = 100 * ranking_accuracy(sp.test.lengths, proba[:, 2])
        ca = 100 * classification_accuracy(sp.test.lengths, proba)
        out[m] = dict(ranking=ra, classification=ca, train_s=train_s)
        emit(f"table5_model_{m}", dt,
             f"ranking={ra:.1f}% class={ca:.1f}% delta=+{ra-ca:.1f}pp "
             f"(paper {PAPER[m][0]}/{PAPER[m][1]}) train={train_s:.1f}s")
    return out


if __name__ == "__main__":
    run()
