"""Table 1: service-time statistics / C_s^2 under workload compositions.

Paper (Apple M1, Ollama, Gemma3:4b, n=204): short-only C_s^2=0.26,
long-only 0.15, mixed 50/50 1.03, mixed 80/20 2.59.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.simulation import cs2
from repro.serving.service_time import PAPER_M1_LONG, PAPER_M1_SHORT

PAPER = {"short_only": 0.26, "long_only": 0.15,
         "mixed_50_50": 1.03, "mixed_80_20": 2.59}


def run(n: int = 204, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    short = PAPER_M1_SHORT.sample(rng, n)
    long = PAPER_M1_LONG.sample(rng, n)
    mixes = {
        "short_only": short,
        "long_only": long,
        "mixed_50_50": np.where(rng.random(n) < 0.5, short, long),
        "mixed_80_20": np.where(rng.random(n) < 0.8, short, long),
    }
    out = {}
    for name, s in mixes.items():
        t0 = time.perf_counter()
        c = cs2(s)
        dt = (time.perf_counter() - t0) * 1e6
        out[name] = dict(es=float(s.mean()), std=float(s.std()), cs2=c,
                         paper_cs2=PAPER[name])
        emit(f"table1_{name}", dt,
             f"E[S]={s.mean():.1f}s std={s.std():.1f}s Cs2={c:.2f} "
             f"(paper {PAPER[name]})")
    return out


if __name__ == "__main__":
    run()
