"""Speculative decoding benchmark: draft-verify lanes at c=4
(writes ``BENCH_speculative.json``).

Two measurements:

* **lane A/B** — the same 12-request backlog through the PR-5 lane path
  (fused decode, one token per target forward) and the speculative lane
  path (``SpeculativeLaneDecoder``: K draft proposals verified in ONE
  batched target forward per round), both at c=4 on the reduced smollm
  backbone.  The high-acceptance pair is constructed, not hoped for: the
  target is an R-repeat stack whose repeats 1..R-1 have zeroed output
  projections (``wo`` / ``w_down`` -> identity residual blocks), and the
  draft is the first repeat of the SAME parameters — target and draft
  logits are bitwise-identical, so acceptance is ~100% at a genuinely
  R-times-deeper target cost (R=12, K=7, vocab shrunk so the
  depth-independent head matmul does not mask the depth ratio on a CPU
  host).  Accepted tokens are target argmaxes
  either way, so both paths must produce bitwise-equal tokens (asserted;
  also asserted for an adversarial independently-seeded draft).
  Acceptance bar (ISSUE 9): >= 1.5x aggregate tok/s.
* **DES grid** — ``core.sweep.sweep_speculative``: policy x draft-K x
  acceptance-distribution on the paper's calibration, showing
  acceptance-aware admission (``sjf_effective``) beating token-count SJF
  on short-P50 under heterogeneous acceptance and degenerating to it at
  K=0.

    PYTHONPATH=src python -m benchmarks.run speculative
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import emit

MAX_LEN = 128
SEGMENT = 8
LANES = 4
DRAFT_K = 7
REPEATS = 12         # target depth multiplier (draft = first repeat)
VOCAB = 2048         # shrunk so the depth-independent head matmul does
                     # not dominate the per-step cost on this host
PROMPT_LEN = 16
NEW_TOKENS = 48
N_REQ = 12
BEST_OF = 3


def _zero_tail_repeats(blocks):
    """Zero the residual-output projections of repeats 1..R-1: those
    blocks become exact identities, so the R-repeat stack computes
    bitwise the same logits as its first repeat alone."""
    import jax
    from jax.tree_util import DictKey, tree_map_with_path

    def f(path, x):
        names = [p.key for p in path if isinstance(p, DictKey)]
        if names and names[-1] in ("wo", "w_down"):
            return x.at[1:].set(0.0)
        return x

    return tree_map_with_path(f, blocks)


def _mk_engines():
    import jax

    from repro.configs import get_config
    from repro.serving.engine import BatchedRealEngine

    cfg1 = dataclasses.replace(get_config("smollm-360m").reduced(),
                               vocab_size=VOCAB)
    cfg_t = dataclasses.replace(
        cfg1, name=cfg1.name + f"-x{REPEATS}",
        num_layers=REPEATS * len(cfg1.block_pattern))

    seed_eng = BatchedRealEngine(cfg_t, max_len=MAX_LEN,
                                 segment_len=SEGMENT, n_lanes=LANES,
                                 seed=0)
    params = dict(seed_eng.params)
    params["blocks"] = _zero_tail_repeats(params["blocks"])
    draft_params = dict(params)
    draft_params["blocks"] = jax.tree.map(lambda x: x[:1],
                                          params["blocks"])

    base = BatchedRealEngine(cfg_t, params=params, max_len=MAX_LEN,
                             segment_len=SEGMENT, n_lanes=LANES, seed=0)
    spec = BatchedRealEngine(cfg_t, params=params, max_len=MAX_LEN,
                             segment_len=SEGMENT, n_lanes=LANES, seed=0,
                             draft_cfg=cfg1, draft_params=draft_params,
                             draft_k=DRAFT_K)
    adv = BatchedRealEngine(cfg_t, params=params, max_len=MAX_LEN,
                            segment_len=SEGMENT, n_lanes=LANES, seed=0,
                            draft_cfg=cfg1, draft_k=DRAFT_K, draft_seed=7)
    return cfg_t, base, spec, adv


def _workload(cfg, rng):
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=PROMPT_LEN).astype(np.int64)
               for _ in range(N_REQ)]
    return prompts, [NEW_TOKENS] * N_REQ


def _drain(eng, prompts, maxes):
    t0 = time.perf_counter()
    res = eng.generate_batch(prompts, maxes)
    return time.perf_counter() - t0, res


def _ab(result: dict) -> None:
    cfg, base, spec, adv = _mk_engines()
    rng = np.random.default_rng(0)
    prompts, maxes = _workload(cfg, rng)
    base.generate_batch(prompts[:LANES], 4)          # compile
    spec.generate_batch(prompts[:LANES], 4)
    adv.generate_batch(prompts[:LANES], 4)

    best = {}
    outs = {}
    for name, eng in (("fused", base), ("speculative", spec),
                      ("adversarial", adv)):
        w = np.inf
        for _ in range(BEST_OF):
            wall, res = _drain(eng, prompts, maxes)
            w = min(w, wall)
        best[name], outs[name] = w, res

    want = [list(r["tokens"]) for r in outs["fused"]]
    for name in ("speculative", "adversarial"):
        got = [list(r["tokens"]) for r in outs[name]]
        assert got == want, f"{name} draft changed tokens"
    toks = sum(len(w) for w in want)

    result["tokens"] = toks
    result["lanes"] = LANES
    result["draft_k"] = DRAFT_K
    result["target_repeats"] = REPEATS
    result["agg_tok_s_fused"] = toks / best["fused"]
    result["agg_tok_s_speculative"] = toks / best["speculative"]
    result["agg_tok_s_adversarial"] = toks / best["adversarial"]
    result["speedup_tok_s"] = best["fused"] / best["speculative"]
    result["slowdown_adversarial"] = best["fused"] / best["adversarial"]
    result["accept_rate_speculative"] = spec.accept_rate
    result["accept_rate_adversarial"] = adv.accept_rate
    result["dead_steps_speculative"] = spec.dead_steps
    result["dead_steps_adversarial"] = adv.dead_steps
    result["bitwise_equal"] = True                   # asserted above
    result["meets_1p5x_tok_s"] = bool(result["speedup_tok_s"] >= 1.5)
    result["acceptance_pass"] = result["meets_1p5x_tok_s"]
    assert spec.accept_rate > 0.9, \
        f"constructed high-acceptance pair drifted: {spec.accept_rate}"
    emit("speculative_ab_tok_s", best["speculative"] / toks * 1e6,
         f"speculative {result['agg_tok_s_speculative']:.0f} tok/s vs "
         f"fused {result['agg_tok_s_fused']:.0f} at c={LANES} = "
         f"{result['speedup_tok_s']:.2f}x (accept "
         f"{spec.accept_rate:.2f}, K={DRAFT_K}, {REPEATS}x-deep target)")
    emit("speculative_adversarial", best["adversarial"] / toks * 1e6,
         f"adversarial draft {result['agg_tok_s_adversarial']:.0f} tok/s "
         f"({result['slowdown_adversarial']:.2f}x, accept "
         f"{adv.accept_rate:.2f}, {adv.dead_steps} dead steps) — "
         f"bitwise-equal tokens regardless")


def _grid(result: dict, n: int = 500, seeds=(0, 1, 2, 3, 4)) -> None:
    from repro.core.sweep import sweep_speculative
    from repro.serving.service_time import PAPER_4090_LONG, PAPER_4090_SHORT

    conditions = [("fcfs", None), ("sjf", None), ("sjf_effective", None)]
    draft_ks = (0, 2, 4)
    dists = ("uniform", "bimodal")
    t0 = time.perf_counter()
    res = sweep_speculative(conditions, draft_ks, dists, seeds, n=n,
                            short=PAPER_4090_SHORT, long=PAPER_4090_LONG,
                            rho=0.8)
    dt = time.perf_counter() - t0
    cells = len(conditions) * len(draft_ks) * len(dists) * len(seeds)
    emit("speculative_grid", dt / cells * 1e6,
         f"{cells} DES cells ({len(conditions)} policies x "
         f"{len(draft_ks)} Ks x {len(dists)} acceptance dists x "
         f"{len(seeds)} seeds, n={n}) in {dt:.2f}s")
    grid = {}
    for m in ("short_p50", "mean_sojourn"):
        v = res.metric(m).mean(-1)                   # seed-avg (C, K, A)
        for ci, (pol, _) in enumerate(res.conditions):
            for ki, k in enumerate(res.draft_ks):
                for ai, d in enumerate(res.accept_dists):
                    grid[f"{m}_{pol}_k{k}_{d}"] = float(v[ci, ki, ai])
    result["grid"] = grid
    sjf = res.metric("short_p50")[1].mean(-1)        # (K, A)
    eff = res.metric("short_p50")[2].mean(-1)
    result["des_short_p50_sjf_k4_uniform"] = float(sjf[2, 0])
    result["des_short_p50_effective_k4_uniform"] = float(eff[2, 0])
    result["des_effective_wins_short_p50"] = bool(eff[2, 0] <= sjf[2, 0])
    result["des_k0_degenerate"] = bool(
        np.allclose(res.metric("short_p50")[1, 0],
                    res.metric("short_p50")[2, 0]))
    emit("speculative_des_effective",
         abs(sjf[2, 0] - eff[2, 0]) * 1e6,
         f"short P50 at K=4 uniform acceptance: sjf {sjf[2, 0]:.2f}s -> "
         f"sjf_effective {eff[2, 0]:.2f}s "
         f"(wins={result['des_effective_wins_short_p50']}, "
         f"K=0 degenerate={result['des_k0_degenerate']})")


def run() -> dict:
    result: dict = {}
    _ab(result)
    _grid(result)
    return result


if __name__ == "__main__":
    run()
