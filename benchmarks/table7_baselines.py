"""Table 7: baseline comparison — FCFS(random) / prompt-length rule /
keyword heuristic / Clairvoyant GBDT, pairwise ranking accuracy.

Paper: rule 52-56%, keyword 4.6-36.3% (below random!), GBDT 67-95%.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, model_and_splits
from repro.core.ranking import (fit_prompt_length_threshold,
                                keyword_heuristic_scores,
                                prompt_length_rule_scores, ranking_accuracy)

PAPER = {"sharegpt": (52.4, 36.3, 74.9), "lmsys": (52.3, 4.6, 95.1),
         "oasst1": (55.8, 18.5, 67.1)}
DATASET_OF = {"A": "sharegpt", "B": "lmsys", "C": "oasst1"}


def run() -> dict:
    out = {}
    for m in "ABC":
        ds = DATASET_OF[m]
        pred, sp, Xte, _ = model_and_splits(m)
        lengths = sp.test.lengths

        t0 = time.perf_counter()
        rng = np.random.default_rng(0)
        fcfs = 100 * ranking_accuracy(lengths, rng.random(len(lengths)))
        thr = fit_prompt_length_threshold(sp.train.X[:, 0], sp.train.lengths)
        rule = 100 * ranking_accuracy(
            lengths, prompt_length_rule_scores(Xte[:, 0], thr), ties="half")
        kw = 100 * ranking_accuracy(
            lengths, keyword_heuristic_scores(Xte), ties="half")
        gbdt = 100 * ranking_accuracy(
            lengths, pred.model.predict_p_long(Xte))
        dt = (time.perf_counter() - t0) * 1e6
        out[ds] = dict(fcfs=fcfs, rule=rule, keyword=kw, gbdt=gbdt)
        p = PAPER[ds]
        emit(f"table7_{ds}", dt,
             f"fcfs={fcfs:.1f}% rule={rule:.1f}%(paper {p[0]}) "
             f"keyword={kw:.1f}%(paper {p[1]}) gbdt={gbdt:.1f}%(paper {p[2]})")
    return out


if __name__ == "__main__":
    run()
