"""Table 7: baseline comparison — FCFS(random) / prompt-length rule /
keyword heuristic / Clairvoyant GBDT, pairwise ranking accuracy.

Paper: rule 52-56%, keyword 4.6-36.3% (below random!), GBDT 67-95%.

The (model x baseline-method) grid runs through ``sweep.run_grid``.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, model_and_splits
from repro.core.ranking import (fit_prompt_length_threshold,
                                keyword_heuristic_scores,
                                prompt_length_rule_scores, ranking_accuracy)
from repro.core.sweep import run_grid

PAPER = {"sharegpt": (52.4, 36.3, 74.9), "lmsys": (52.3, 4.6, 95.1),
         "oasst1": (55.8, 18.5, 67.1)}
DATASET_OF = {"A": "sharegpt", "B": "lmsys", "C": "oasst1"}
METHODS = ("fcfs", "rule", "keyword", "gbdt")


def _score(m: str, method: str) -> float:
    pred, sp, Xte, _ = model_and_splits(m)
    lengths = sp.test.lengths
    if method == "fcfs":
        rng = np.random.default_rng(0)
        return 100 * ranking_accuracy(lengths, rng.random(len(lengths)))
    if method == "rule":
        thr = fit_prompt_length_threshold(sp.train.X[:, 0], sp.train.lengths)
        return 100 * ranking_accuracy(
            lengths, prompt_length_rule_scores(Xte[:, 0], thr), ties="half")
    if method == "keyword":
        return 100 * ranking_accuracy(
            lengths, keyword_heuristic_scores(Xte), ties="half")
    return 100 * ranking_accuracy(lengths, pred.model.predict_p_long(Xte))


def run() -> dict:
    for m in "ABC":                      # train outside the timed region
        model_and_splits(m)
    t0 = time.perf_counter()
    grid = run_grid({"m": "ABC", "method": METHODS}, _score)
    dt = (time.perf_counter() - t0) * 1e6 / 3

    out = {}
    for m in "ABC":
        ds = DATASET_OF[m]
        vals = {meth: grid[(m, meth)] for meth in METHODS}
        out[ds] = vals
        p = PAPER[ds]
        emit(f"table7_{ds}", dt,
             f"fcfs={vals['fcfs']:.1f}% rule={vals['rule']:.1f}%(paper {p[0]}) "
             f"keyword={vals['keyword']:.1f}%(paper {p[1]}) "
             f"gbdt={vals['gbdt']:.1f}%(paper {p[2]})")
    return out


if __name__ == "__main__":
    run()
