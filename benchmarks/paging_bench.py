"""Paged-KV benchmark: block-paged admission vs worst-case KVBudget
accounting at an IDENTICAL byte budget (writes ``BENCH_paging.json``).

Three measurements on the reduced smollm backbone (CPU container):

* **accounting A/B** — the same 12-request backlog (4 longs of 96 new
  tokens, 8 shorts of 12, FCFS order with the longs in front — the
  head-of-line setup) through ``BatchedRealEngine`` (admission charges
  the worst-case ``prompt + max_new`` footprint up front) and
  ``PagedBatchedEngine`` (admission charges the prompt's pages; decode
  growth is paid page-by-page with preemption on exhaustion), both
  capped at the byte budget of exactly TWO worst-case longs.  The
  worst-case engine can only hold two longs; the paged engine admits
  shorts into the idle lanes immediately.  Acceptance bar (ISSUE 8):
  >= 1.3x aggregate tok/s OR >= 25% short-P50 improvement.
* **prefix reuse** — the same backlog re-prompted with a shared 48-token
  system prefix: warm admissions skip the shared pages and prefill only
  the suffix bucket (16 tokens vs the 128-token padded cold prefill).
  Reported: tok/s for the cold pass (within-drain sharing only) and the
  fully-warm second pass, plus prefix-hit pages and dead-step counts.
* **DES grid** — ``core.sweep.sweep_paging``: policy x page size x byte
  budget x prefix-share ratio on the paper's rho = 0.74 Poisson
  workload, quantifying how much sojourn page-granular accounting
  recovers at a fixed budget and how page size and sharing move it.

    PYTHONPATH=src python -m benchmarks.run paging
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit

MAX_LEN = 128
SEGMENT = 8
LANES = 4
PAGE = 16
PROMPT_LEN = 16
LONG_NEW, SHORT_NEW = 96, 12
# FCFS arrival order: two longs head the queue (HoL), shorts behind
PATTERN = "LLSSSSLSSLSS"
REPEAT = 3


def _mk_engines(cfg):
    from repro.serving.engine import BatchedRealEngine, PagedBatchedEngine

    worst = BatchedRealEngine(cfg, max_len=MAX_LEN, segment_len=SEGMENT,
                              n_lanes=LANES, seed=0)
    bpt = worst._bytes_per_token
    # exactly two worst-case longs: the worst-case engine serializes the
    # backlog into long pairs (admission charges prompt + max_new up
    # front), so queued shorts wait a full long decode behind the
    # reservation; page-granular accounting admits them into the idle
    # lanes at one page each — the phantom-byte recovery the short-P50
    # number measures
    budget = 2 * (PROMPT_LEN + LONG_NEW) * bpt
    worst = BatchedRealEngine(cfg, params=worst.params, max_len=MAX_LEN,
                              segment_len=SEGMENT, n_lanes=LANES, seed=0,
                              budget_bytes=budget)
    paged = PagedBatchedEngine(cfg, params=worst.params, max_len=MAX_LEN,
                               segment_len=SEGMENT, n_lanes=LANES, seed=0,
                               page_size=PAGE, budget_bytes=budget)
    return worst, paged, budget


def _workload(cfg, rng, prefix=None):
    maxes = [LONG_NEW if c == "L" else SHORT_NEW for c in PATTERN]
    prompts = []
    for _ in PATTERN:
        p = rng.integers(1, cfg.vocab_size, size=PROMPT_LEN).astype(np.int64)
        if prefix is not None:
            p = np.concatenate([prefix, p])
        prompts.append(p)
    return prompts, maxes


def _drain(eng, prompts, maxes):
    t0 = time.perf_counter()
    res = eng.generate_batch(prompts, maxes)
    wall = time.perf_counter() - t0
    toks = sum(len(r["tokens"]) for r in res)
    # sojourn from drain start: finish_t is absolute monotonic time
    t_run0 = min(r["admit_t"] for r in res)
    soj = np.array([r["finish_t"] - t_run0 for r in res])
    short_soj = soj[[c == "S" for c in PATTERN]]
    return wall, toks, float(np.median(short_soj)), res


def _ab(result: dict) -> None:
    from repro.configs import get_config

    cfg = get_config("smollm-360m").reduced()
    worst, paged, budget = _mk_engines(cfg)
    result["budget_bytes"] = budget
    result["n_pages"] = paged.n_pages
    rng = np.random.default_rng(0)
    warm_p, warm_m = _workload(cfg, rng)
    worst.generate_batch(warm_p[:LANES], 4)          # compile
    paged.generate_batch(warm_p[:LANES], 4)
    paged.allocator.drop_cache()

    best = {"worst": (np.inf,) * 3, "paged": (np.inf,) * 3}
    for rep in range(REPEAT):
        # fresh prompts each repeat so the paged engine's prefix cache
        # cannot warm-hit the previous round (same shapes: no recompile)
        prompts, maxes = _workload(cfg, np.random.default_rng(100 + rep))
        for name, eng in (("worst", worst), ("paged", paged)):
            wall, toks, sp50, _ = _drain(eng, prompts, maxes)
            if wall < best[name][0]:
                best[name] = (wall, toks, sp50)
    (w_wall, w_toks, w_sp50) = best["worst"]
    (p_wall, p_toks, p_sp50) = best["paged"]
    assert w_toks == p_toks, "engines produced different token counts"
    result["agg_tok_s_worstcase"] = w_toks / w_wall
    result["agg_tok_s_paged"] = p_toks / p_wall
    result["speedup_tok_s"] = (p_toks / p_wall) / (w_toks / w_wall)
    result["short_p50_s_worstcase"] = w_sp50
    result["short_p50_s_paged"] = p_sp50
    result["short_p50_improvement_pct"] = 100 * (1 - p_sp50 / w_sp50)
    result["preemptions_paged"] = paged.lane_manager.stats["preemptions"]
    result["dead_steps_paged"] = paged.dead_steps
    result["dead_steps_worstcase"] = worst.dead_steps
    result["meets_1p3x_tok_s"] = bool(result["speedup_tok_s"] >= 1.3)
    result["meets_25pct_short_p50"] = \
        bool(result["short_p50_improvement_pct"] >= 25.0)
    result["acceptance_pass"] = bool(result["meets_1p3x_tok_s"]
                                     or result["meets_25pct_short_p50"])
    emit("paging_ab_tok_s", p_wall / p_toks * 1e6,
         f"paged {result['agg_tok_s_paged']:.0f} tok/s vs worst-case "
         f"{result['agg_tok_s_worstcase']:.0f} at the same "
         f"{budget} B budget = {result['speedup_tok_s']:.2f}x")
    emit("paging_ab_short_p50", w_sp50 * 1e6,
         f"short P50 {w_sp50:.2f}s (worst-case) -> {p_sp50:.2f}s (paged): "
         f"{result['short_p50_improvement_pct']:.0f}% better "
         f"({result['preemptions_paged']} preemptions, "
         f"{result['dead_steps_paged']} dead lane-steps)")

    # ---- prefix reuse: shared 48-token system prompt, same budget
    prefix = rng.integers(1, cfg.vocab_size, size=48).astype(np.int64)
    prompts, maxes = _workload(cfg, rng, prefix=prefix)
    _drain(paged, prompts, maxes)      # warm the extend-prefill compiles
    _drain(worst, prompts, maxes)      # warm the 64-token prompt bucket
    paged.allocator.reset_transient()
    paged.allocator.drop_cache()       # forget content: next pass is cold
    h0 = dict(paged.allocator.stats)
    cold_wall, toks, _, _ = _drain(paged, prompts, maxes)
    h1 = dict(paged.allocator.stats)
    warm_wall, toks2, _, _ = _drain(paged, prompts, maxes)
    h2 = dict(paged.allocator.stats)
    ww = min(_drain(worst, prompts, maxes)[0] for _ in range(2))
    result["prefix_tok_s_worstcase"] = toks / ww
    result["prefix_tok_s_paged_cold"] = toks / cold_wall
    result["prefix_tok_s_paged_warm"] = toks2 / warm_wall
    result["prefix_hit_pages_cold"] = \
        h1["prefix_hit_pages"] - h0["prefix_hit_pages"]
    result["prefix_hit_pages_warm"] = \
        h2["prefix_hit_pages"] - h1["prefix_hit_pages"]
    result["prefix_speedup_warm_vs_worstcase"] = ww / warm_wall
    result["meets_1p3x_tok_s_prefix"] = \
        bool(result["prefix_speedup_warm_vs_worstcase"] >= 1.3)
    result["acceptance_pass"] = bool(result["acceptance_pass"]
                                     or result["meets_1p3x_tok_s_prefix"])
    emit("paging_prefix_reuse", warm_wall / toks2 * 1e6,
         f"shared 48-tok prefix: {result['prefix_tok_s_paged_warm']:.0f} "
         f"tok/s warm vs {result['prefix_tok_s_paged_cold']:.0f} cold vs "
         f"{result['prefix_tok_s_worstcase']:.0f} worst-case "
         f"({result['prefix_speedup_warm_vs_worstcase']:.2f}x warm; "
         f"{result['prefix_hit_pages_warm']} hit pages warm, "
         f"{result['prefix_hit_pages_cold']} cold)")


def _grid(result: dict, n: int = 400, seeds=(0, 1, 2)) -> None:
    from repro.core.sweep import sweep_paging
    from repro.serving.service_time import PAPER_4090_LONG, PAPER_4090_SHORT

    short, long = PAPER_4090_SHORT, PAPER_4090_LONG
    es = 0.5 * (short.mean + long.mean)
    conditions = [("fcfs", None), ("sjf", None)]
    page_sizes = (8, 16, 32)
    budgets = (600.0, 1200.0, 2400.0)        # memory tokens
    shares = (0.0, 0.5)
    t0 = time.perf_counter()
    res = sweep_paging(conditions, page_sizes, budgets, shares, seeds,
                       n=n, rho=0.74, short=short, long=long)
    dt = time.perf_counter() - t0
    cells = 2 * len(page_sizes) * len(budgets) * len(shares) * len(seeds)
    emit("paging_grid", dt / cells * 1e6,
         f"{cells} DES cells (2 policies x {len(page_sizes)} page sizes x "
         f"{len(budgets)} budgets x {len(shares)} share ratios x "
         f"{len(seeds)} seeds, n={n}) in {dt:.2f}s")
    grid = {}
    for ci, (pol, _) in enumerate(conditions):
        for pi, ps in enumerate(page_sizes):
            for bi, b in enumerate(budgets):
                for ri, r in enumerate(shares):
                    label = f"{pol}_ps{ps}_kv{int(b)}_share{r}"
                    grid[label] = {
                        m: round(float(res.metric(m)[ci, pi, bi, ri].mean()),
                                 3)
                        for m in ("short_p50", "mean_sojourn", "preemptions",
                                  "prefix_hits", "peak_pages")}
    result["grid"] = grid
    result["grid_axes"] = {"policies": ["fcfs", "sjf"],
                           "page_sizes": list(page_sizes),
                           "budgets_tokens": list(budgets),
                           "share_ratios": list(shares),
                           "rho": 0.74, "n": n, "seeds": list(seeds),
                           "mean_service_s": round(es, 3)}
    tight, roomy = grid["sjf_ps16_kv600_share0.0"], \
        grid["sjf_ps16_kv2400_share0.0"]
    shared = grid["sjf_ps16_kv600_share0.5"]
    result["grid_headline"] = {
        "sjf_mean_sojourn_kv600": tight["mean_sojourn"],
        "sjf_mean_sojourn_kv2400": roomy["mean_sojourn"],
        "sjf_preemptions_kv600": tight["preemptions"],
        "sjf_kv600_share0.5_mean_sojourn": shared["mean_sojourn"],
        "sjf_kv600_share0.5_prefix_hits": shared["prefix_hits"],
    }
    emit("paging_grid_headline", 0.0,
         f"sjf@kv600: mean sojourn {tight['mean_sojourn']:.2f}s "
         f"({tight['preemptions']:.0f} preempts) -> "
         f"{shared['mean_sojourn']:.2f}s with 50% prefix sharing "
         f"({shared['prefix_hits']:.0f} warm admits); roomy kv2400 "
         f"{roomy['mean_sojourn']:.2f}s")


def run() -> dict:
    result: dict = {"max_len": MAX_LEN, "segment_len": SEGMENT,
                    "n_lanes": LANES, "page_size": PAGE,
                    "pattern": PATTERN, "long_new": LONG_NEW,
                    "short_new": SHORT_NEW}
    _ab(result)
    _grid(result)
    return result
