"""Fault-injection benchmark: does the scheduling win survive chaos?
(writes ``BENCH_faults.json``)

Three measurements, all in virtual time (the DES fault engine) plus one
serving-layer chaos drain on the wall clock:

* **degradation curves** — ``core.sweep.sweep_faults``: FCFS vs SJF x
  crash-MTBF in {inf, 240, 120, 60} s x repair time in {5, 15} s on the
  paper's rho = 0.74 Poisson workload with NOISY predictor scores (~0.87
  ranking accuracy, like BENCH_policies/BENCH_batching).  Fault
  timelines and workloads are fully paired across conditions.  The
  acceptance bar: SJF keeps a short-class P50 win over FCFS at every
  nonzero failure rate — HoL mitigation is not a fair-weather property.
* **shedding bounds the tail** — overload row (rho = 1.3, guard off as
  in the burst replication): served-request short-P99 with a deadline
  budget vs without.  Unbounded overload grows the tail with the queue;
  a deadline budget caps queueing delay at dispatch, so the served tail
  stays ~deadline + service while shed_rate absorbs the excess.
* **serving-layer chaos drain** — a ``ClairvoyantServer`` (virtual-time
  sim engines) run under a seeded ``FaultPlan`` (transients + crashes +
  stalls): per-request drain overhead of the fault/retry layer vs a
  clean drain, plus the no-lost-requests accounting (terminal statuses
  sum to submissions).

    PYTHONPATH=src python -m benchmarks.run faults
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit

MTBFS = (float("inf"), 240.0, 120.0, 60.0)
REPAIRS = (5.0, 15.0)
SEEDS = 5
N = 1000
RHO = 0.74
ACC = 0.87


def _noisy_batches(n, rho, seeds, short, long):
    from repro.core.sim_fast import RequestBatch
    from repro.core.simulation import _spread_for_accuracy
    es = 0.5 * (short.mean + long.mean)
    spread = _spread_for_accuracy(ACC)
    batches = []
    for s in range(seeds):
        rng = np.random.default_rng(s)
        b = RequestBatch.poisson(rng, n, rho / es, short, long)
        base = np.where(b.p_long > 0.5, 0.75, 0.25)
        b.p_long = np.clip(rng.normal(base, spread), 0.0, 1.0)
        batches.append(b)
    return batches


def _degradation(result: dict):
    from repro.core.sweep import sweep_faults
    from repro.serving.service_time import (PAPER_4090_LONG,
                                            PAPER_4090_SHORT)

    short, long = PAPER_4090_SHORT, PAPER_4090_LONG
    tau = 3.0 * short.mean
    conditions = [("fcfs", None), ("sjf", tau)]
    batches = _noisy_batches(N, RHO, SEEDS, short, long)
    t0 = time.perf_counter()
    res = sweep_faults(conditions, MTBFS, REPAIRS, range(SEEDS),
                       n=N, short=short, long=long, rho=RHO,
                       batches=batches)
    dt = time.perf_counter() - t0
    cells = len(conditions) * len(MTBFS) * len(REPAIRS) * SEEDS
    emit("faults_grid", dt / cells * 1e6,
         f"{cells} DES cells (2 policies x {len(MTBFS)} MTBFs x "
         f"{len(REPAIRS)} repairs x {SEEDS} seeds, n={N}) in {dt:.2f}s")

    sp = res.metric("short_p50")          # (C, F, R, S)
    gp = res.metric("goodput")
    rq = res.metric("requeues")
    curves = {}
    win_cells = []
    for fi, mtbf in enumerate(MTBFS):
        for ri, rep in enumerate(REPAIRS):
            label = ("mtbf_inf" if not np.isfinite(mtbf)
                     else f"mtbf{int(mtbf)}_mttr{int(rep)}")
            if not np.isfinite(mtbf) and ri > 0:
                continue                  # one no-fault column is enough
            f50 = float(sp[0, fi, ri].mean())
            s50 = float(sp[1, fi, ri].mean())
            win = 100.0 * (1.0 - s50 / f50)
            curves[label] = {
                "fcfs_short_p50": round(f50, 3),
                "sjf_short_p50": round(s50, 3),
                "sjf_win_pct": round(win, 1),
                "fcfs_goodput": round(float(gp[0, fi, ri].mean()), 4),
                "sjf_goodput": round(float(gp[1, fi, ri].mean()), 4),
                "requeues_per_run": round(float(rq[1, fi, ri].mean()), 2),
            }
            if np.isfinite(mtbf):
                win_cells.append(win > 0.0)
            emit(f"faults_{label}", 0.0,
                 f"short P50 fcfs {f50:.1f}s sjf {s50:.1f}s "
                 f"(win {win:.0f}%), goodput "
                 f"{curves[label]['sjf_goodput']:.3f} req/s")
    result["degradation"] = curves
    result["degradation_axes"] = {
        "policies": ["fcfs", "sjf"], "mtbfs_s": list(MTBFS),
        "repairs_s": list(REPAIRS), "rho": RHO, "n": N, "seeds": SEEDS,
        "tau": tau, "ranking_accuracy": ACC}
    result["sjf_win_survives_all_fault_cells"] = bool(all(win_cells))


def _shedding(result: dict):
    from repro.core.sweep import sweep_faults
    from repro.serving.service_time import (PAPER_4090_LONG,
                                            PAPER_4090_SHORT)

    short, long = PAPER_4090_SHORT, PAPER_4090_LONG
    rho_over = 1.3
    deadline = 6.0 * short.mean           # generous vs service, tiny vs
    conditions = [("fcfs", None), ("sjf", None)]   # overload queue growth
    batches = _noisy_batches(N, rho_over, SEEDS, short, long)
    rows = {}
    for dl in (None, deadline):
        res = sweep_faults(conditions, (float("inf"),), (5.0,),
                           range(SEEDS), n=N, short=short, long=long,
                           rho=rho_over, deadline=dl, batches=batches)
        for ci, (pol, _) in enumerate(conditions):
            key = f"{pol}_" + ("noshed" if dl is None else "shed")
            rows[key] = {
                "short_p99": round(float(
                    res.metric("short_p99")[ci, 0, 0].mean()), 2),
                "short_p50": round(float(
                    res.metric("short_p50")[ci, 0, 0].mean()), 2),
                "shed_rate": round(float(
                    res.metric("shed_rate")[ci, 0, 0].mean()), 3),
                "goodput": round(float(
                    res.metric("goodput")[ci, 0, 0].mean()), 4),
            }
    result["overload_shedding"] = rows
    result["overload_shedding_axes"] = {
        "rho": rho_over, "deadline_s": deadline, "n": N, "seeds": SEEDS}
    bound = rows["sjf_shed"]["short_p99"]
    unbound = rows["sjf_noshed"]["short_p99"]
    result["shed_p99_reduction_pct"] = round(100 * (1 - bound / unbound), 1)
    emit("faults_overload_shed", 0.0,
         f"rho={rho_over} short P99: unbounded {unbound:.0f}s -> deadline "
         f"{deadline:.0f}s budget {bound:.0f}s "
         f"({result['shed_p99_reduction_pct']:.0f}% lower, shed_rate "
         f"{rows['sjf_shed']['shed_rate']:.2f})")


def _chaos_drain(result: dict):
    from repro.serving.faults import FaultPlan
    from repro.serving.openai_api import CompletionRequest
    from repro.serving.server import ClairvoyantServer

    n = 400
    rng = np.random.default_rng(0)
    toks = np.where(rng.random(n) < 0.5,
                    rng.integers(30, 90, n), rng.integers(400, 700, n))
    arrivals = np.sort(rng.uniform(0.0, n * 0.5, n))

    def drive(plan):
        server = ClairvoyantServer(policy="sjf", predictor=None,
                                   fault_plan=plan, seed=0)
        for i in range(n):
            server.submit(CompletionRequest(prompt=f"req {i}"),
                          arrival=float(arrivals[i]),
                          true_output_tokens=int(toks[i]),
                          klass="short" if toks[i] < 200 else "long")
        t0 = time.perf_counter()
        server.drain()
        return server, time.perf_counter() - t0

    plan = FaultPlan.random(seed=7, horizon=float(arrivals[-1]),
                            crash_mtbf=40.0, crash_mttr=5.0,
                            transient_rate=1 / 30.0, stall_mtbf=60.0,
                            stall_s=10.0)
    clean_server, clean_dt = drive(None)
    chaos_server, chaos_dt = drive(plan)

    statuses = {}
    for r in chaos_server.responses:
        statuses[r.status] = statuses.get(r.status, 0) + 1
    lost = n - len(chaos_server.responses)
    result["chaos_drain"] = {
        "n": n, "clean_us_per_req": round(clean_dt / n * 1e6, 1),
        "chaos_us_per_req": round(chaos_dt / n * 1e6, 1),
        "fault_layer_overhead_x": round(chaos_dt / max(clean_dt, 1e-9), 2),
        "statuses": statuses, "lost_requests": lost,
        "fault_stats": dict(chaos_server.fault_stats),
    }
    emit("faults_chaos_drain", chaos_dt / n * 1e6,
         f"{n} reqs under chaos plan: statuses {statuses}, lost {lost}, "
         f"retries {chaos_server.fault_stats['retries']}, crashes "
         f"{chaos_server.fault_stats['crashes']} "
         f"({result['chaos_drain']['fault_layer_overhead_x']:.2f}x clean)")
    result["no_lost_requests"] = bool(lost == 0)


def run() -> dict:
    result: dict = {}
    _degradation(result)
    _shedding(result)
    _chaos_drain(result)
    return result


if __name__ == "__main__":
    run()
