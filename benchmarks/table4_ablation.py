"""Table 4: drop-one feature-group ablation (ranking accuracy delta, pp).

Paper: prompt_token_len universally harmful to drop (-3.09 pp avg);
instruction_verb mixed (-5.04 LMSYS, +3.21 OASST1); format/clause
net-harmful (positive delta when dropped).

The (feature-group x model) grid is evaluated through ``sweep.run_grid``
in one call (models and per-group retrains cached by ``model_and_splits``).
"""

from __future__ import annotations

import time

from benchmarks.common import emit, model_and_splits
from repro.core.features import FEATURE_GROUPS
from repro.core.ranking import ranking_accuracy
from repro.core.sweep import run_grid

PAPER_AVG = {
    "prompt_token_len": -3.09, "instruction_verb": -1.78,
    "has_code_keyword": -1.51, "ends_with_question": -1.13,
    "has_length_constraint": -0.12, "has_format_keyword": +0.78,
    "clause_count": +1.07,
}


def _accuracy(m: str, drop: tuple = ()) -> float:
    # no-drop goes through the same cache key as the other suites
    pred, sp, Xte, _ = (model_and_splits(m, drop_features=drop) if drop
                        else model_and_splits(m))
    return 100 * ranking_accuracy(sp.test.lengths,
                                  pred.model.predict_p_long(Xte))


def run() -> dict:
    base = run_grid({"m": "ABC"}, _accuracy)

    t0 = time.perf_counter()
    grid = run_grid(
        {"group": tuple(FEATURE_GROUPS), "m": "ABC"},
        lambda group, m: _accuracy(m, drop=tuple(FEATURE_GROUPS[group])))
    dt = (time.perf_counter() - t0) * 1e6 / len(FEATURE_GROUPS)

    out = {}
    for group in FEATURE_GROUPS:
        deltas = {m: grid[(group, m)] - base[(m,)] for m in "ABC"}
        avg = sum(deltas.values()) / 3
        out[group] = dict(**deltas, avg=avg)
        emit(f"table4_drop_{group}", dt,
             f"A={deltas['A']:+.2f}pp B={deltas['B']:+.2f}pp "
             f"C={deltas['C']:+.2f}pp avg={avg:+.2f}pp "
             f"(paper avg {PAPER_AVG[group]:+.2f})")
    return out


if __name__ == "__main__":
    run()
