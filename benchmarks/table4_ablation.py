"""Table 4: drop-one feature-group ablation (ranking accuracy delta, pp).

Paper: prompt_token_len universally harmful to drop (-3.09 pp avg);
instruction_verb mixed (-5.04 LMSYS, +3.21 OASST1); format/clause
net-harmful (positive delta when dropped).
"""

from __future__ import annotations

import time

from benchmarks.common import emit, model_and_splits
from repro.core.features import FEATURE_GROUPS
from repro.core.ranking import ranking_accuracy

PAPER_AVG = {
    "prompt_token_len": -3.09, "instruction_verb": -1.78,
    "has_code_keyword": -1.51, "ends_with_question": -1.13,
    "has_length_constraint": -0.12, "has_format_keyword": +0.78,
    "clause_count": +1.07,
}


def run() -> dict:
    base = {}
    for m in "ABC":
        pred, sp, Xte, _ = model_and_splits(m)
        base[m] = 100 * ranking_accuracy(
            sp.test.lengths, pred.model.predict_p_long(Xte))

    out = {}
    for group, cols in FEATURE_GROUPS.items():
        deltas = {}
        t0 = time.perf_counter()
        for m in "ABC":
            pred, sp, Xte, _ = model_and_splits(m, drop_features=tuple(cols))
            ra = 100 * ranking_accuracy(
                sp.test.lengths, pred.model.predict_p_long(Xte))
            deltas[m] = ra - base[m]
        dt = (time.perf_counter() - t0) * 1e6
        avg = sum(deltas.values()) / 3
        out[group] = dict(**deltas, avg=avg)
        emit(f"table4_drop_{group}", dt,
             f"A={deltas['A']:+.2f}pp B={deltas['B']:+.2f}pp "
             f"C={deltas['C']:+.2f}pp avg={avg:+.2f}pp "
             f"(paper avg {PAPER_AVG[group]:+.2f})")
    return out


if __name__ == "__main__":
    run()
