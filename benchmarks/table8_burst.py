"""Table 8: end-to-end burst latency — 100 concurrent requests (50S/50L),
FCFS vs Clairvoyant SJF, 5 runs (n=250 per cell).

Paper (RTX 4090): short P50 -70% (gemma3:4b) / -76% (llama3.1:8b); long P50
+21-27%.  We report (a) the paper-calibrated 4090 service model — the
faithful replication — and (b) this framework's own TPU-v5e engine model
(gemma3-4b-edge @ 1 chip), with the REAL trained predictor scoring the real
synthetic prompts (dolly-profile, as in the paper's benchmark).

Requests are built as SoA ``RequestBatch`` rows (batched predictor scores,
batched service-time draws via ``ServiceTimeModel.service_batch``), and
each backend's whole policy x run grid runs through ``core.sweep`` in one
engine call; sojourns are pooled across runs per policy, as before.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, model_and_splits
from repro.configs import get_config
from repro.core.sim_fast import RequestBatch
from repro.core.sweep import sweep_batches
from repro.data.corpus import sample_dataset
from repro.serving.service_time import (PAPER_4090_LONG, PAPER_4090_SHORT,
                                        ServiceTimeModel)

POLICIES = ("fcfs", "sjf", "sjf_oracle")


def _burst_batch(rng, predictor, service_batch_fn, n_short=50, n_long=50,
                 seed=0, dataset="dolly") -> RequestBatch:
    """Real prompts, real predictor scores, oracle service times — SoA."""
    # dolly's Long rate is ~0.6% (Table 2) — draw enough to find 50 Longs
    ds = sample_dataset(dataset, n=20000, seed=seed)
    short_idx = np.where(ds.lengths < 200)[0][:n_short]
    long_idx = np.where(ds.lengths >= 800)[0][:n_long]
    idx = np.concatenate([short_idx, long_idx])
    assert len(idx) == n_short + n_long, "not enough long examples drawn"
    prompts = [ds.prompts[i] for i in idx]
    lengths = np.asarray(ds.lengths)[idx]
    return RequestBatch.from_arrays(
        arrival=rng.uniform(0, 0.05, len(idx)),
        true_service=service_batch_fn(lengths, rng),
        p_long=predictor.p_long_batch(prompts),
        klass=np.where(lengths < 200, "short", "long"))


def run(runs: int = 5) -> dict:
    pred, _, _, _ = model_and_splits("A")  # ShareGPT model, as deployed
    cfg = get_config("gemma3-4b-edge")
    tpu_model = ServiceTimeModel.from_arch(cfg, chips=1)

    def svc_4090(tokens, rng):
        n = len(tokens)
        return np.where(tokens < 200, PAPER_4090_SHORT.sample(rng, n),
                        PAPER_4090_LONG.sample(rng, n))

    def svc_tpu(tokens, rng):
        return (tpu_model.service_batch(64, tokens)
                * rng.normal(1.0, 0.1, len(tokens)))

    out = {}
    # dolly = the paper's cross-distribution deployment; sharegpt = the same
    # predictor serving its own training distribution (in-dist bound)
    cells = (("4090calib", svc_4090, "dolly"),
             ("4090calib_indist", svc_4090, "sharegpt"),
             ("tpu_v5e", svc_tpu, "dolly"))
    conditions = [(p, None) for p in POLICIES]
    for backend, svc, dataset in cells:
        t0 = time.perf_counter()
        batches = [_burst_batch(np.random.default_rng(r), pred, svc, seed=r,
                                dataset=dataset) for r in range(runs)]
        # tau = 3 x mu_short: burst regime — negligible effect (§5.5);
        # one engine call for the whole policy x run grid
        _, (arrival, klass, start, finish, _) = sweep_batches(
            batches, conditions, return_arrays=True)
        dt = (time.perf_counter() - t0) * 1e6 / runs
        sojourn = finish - arrival
        res = {}
        for ci, policy in enumerate(POLICIES):
            rows = slice(ci * runs, (ci + 1) * runs)
            res[policy] = {}
            for code, k in ((1, "short"), (3, "long")):
                v = sojourn[rows][klass[rows] == code]
                res[policy][k] = dict(p50=float(np.percentile(v, 50)),
                                      p95=float(np.percentile(v, 95)),
                                      p99=float(np.percentile(v, 99)),
                                      n=int(v.size))
                emit(f"table8_{backend}_{policy}_{k}", dt,
                     f"P50={res[policy][k]['p50']:.1f}s "
                     f"P95={res[policy][k]['p95']:.1f}s "
                     f"P99={res[policy][k]['p99']:.1f}s n={res[policy][k]['n']}")
        red = 100 * (1 - res["sjf"]["short"]["p50"] / res["fcfs"]["short"]["p50"])
        infl = 100 * (res["sjf"]["long"]["p50"] / res["fcfs"]["long"]["p50"] - 1)
        red_o = 100 * (1 - res["sjf_oracle"]["short"]["p50"]
                       / res["fcfs"]["short"]["p50"])
        emit(f"table8_{backend}_summary", 0.0,
             f"short_P50_reduction={red:.0f}% oracle_bound={red_o:.0f}% "
             f"(paper 70-76%) long_P50_inflation={infl:+.0f}% "
             f"(paper +21-27%)")
        out[backend] = dict(res=res, reduction=red, inflation=infl,
                            oracle=red_o)
    return out


if __name__ == "__main__":
    run()
