"""Table 6: cross-distribution ranking accuracy matrix.

Off-diagonal = true cross-distribution transfer (paper band 52-66%);
diagonal includes training data and is optimistic.  CNN/DailyMail excluded
(1 Long example renders the metric unreliable) — same exclusion as the paper.
"""

from __future__ import annotations

import time

from benchmarks.common import emit, model_and_splits
from repro.core.ranking import ranking_accuracy
from repro.data.pipeline import heldout_eval_set

EVAL_SETS = ("sharegpt", "lmsys", "oasst1", "dolly")
TRAIN_OF = {"A": "sharegpt", "B": "lmsys", "C": "oasst1"}
PAPER = {  # train -> test
    ("A", "sharegpt"): 86.4, ("A", "lmsys"): 53.6, ("A", "oasst1"): 56.3,
    ("A", "dolly"): 52.7,
    ("B", "sharegpt"): 62.7, ("B", "lmsys"): 98.3, ("B", "oasst1"): 65.3,
    ("B", "dolly"): 58.4,
    ("C", "sharegpt"): 58.0, ("C", "lmsys"): 65.3, ("C", "oasst1"): 90.4,
    ("C", "dolly"): 57.7,
}


def run() -> dict:
    out = {}
    evals = {ds: heldout_eval_set(ds) for ds in EVAL_SETS}
    for m in "ABC":
        pred, _, _, _ = model_and_splits(m)
        for ds in EVAL_SETS:
            ev = evals[ds]
            t0 = time.perf_counter()
            p = pred.model.predict_proba(ev.X)
            dt = (time.perf_counter() - t0) / len(ev.X) * 1e6
            ra = 100 * ranking_accuracy(ev.lengths, p[:, 2])
            diag = "(diag)" if TRAIN_OF[m] == ds else ""
            out[(m, ds)] = ra
            emit(f"table6_{m}_to_{ds}", dt,
                 f"ranking={ra:.1f}% (paper {PAPER[(m, ds)]}){diag}")
    return out


if __name__ == "__main__":
    run()
