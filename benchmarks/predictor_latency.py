"""Predictor latency (paper §3.3: 0.029 ms/request via ONNX Runtime C API).

This container's admission path is host-side (no ONNX RT offline); this
suite benchmarks the seed implementations against the fast path side by
side:

  * feature extraction — seed per-keyword scans (``extract_reference``)
    vs the vectorized single-pass batch matcher (``extract_batch``);
  * GBDT scoring — seed dense complete-tree traversal
    (``predict_margin_dense``) vs the pruned/binned packed path (native
    scorer with numpy traversal fallback), single-request and batched;
  * the tree-parallel Pallas kernels (interpret mode on CPU; compiled
    path on real TPU), dense and packed layouts;
  * training — seed per-node trainer (``train_gbdt_reference``) vs the
    depth-frontier/histogram-subtraction trainer (``train_gbdt``).

``run`` returns the numbers consumed by ``benchmarks.run`` to write
``BENCH_predictor.json``, including allclose checks of every fast path
against the seed dense margins.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, model_and_splits
from repro.core.features import extract_batch, extract_reference
from repro.core.gbdt import (GBDTParams, _softmax, train_gbdt,
                             train_gbdt_reference)
from repro.data.corpus import sample_dataset

_TRAIN_ROUNDS = 150


def _best(fn, reps: int = 10) -> float:
    import gc
    fn()
    best = float("inf")
    gc.disable()
    try:
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
    finally:
        gc.enable()
    return best


def _best_pair(fn_a, fn_b, reps: int = 10):
    """Best-of-N for two rivals, interleaved so host noise (this container
    is a 2-core VM with very jittery timings) hits both sides equally."""
    import gc
    fn_a(), fn_b()
    best_a = best_b = float("inf")
    gc.disable()
    try:
        for _ in range(reps):
            t0 = time.perf_counter()
            fn_a()
            best_a = min(best_a, time.perf_counter() - t0)
            t0 = time.perf_counter()
            fn_b()
            best_b = min(best_b, time.perf_counter() - t0)
    finally:
        gc.enable()
    return best_a, best_b


def run() -> dict:
    pred, sp, _, _ = model_and_splits("A")
    model = pred.model
    packed = model.packed()
    ds = sample_dataset("sharegpt", n=512, seed=3)
    prompts = ds.prompts
    n = len(prompts)
    out = {}

    # --- feature extraction: seed scan vs batch fast path ------------------
    ref_s, fast_s = _best_pair(
        lambda: [extract_reference(p) for p in prompts],
        lambda: extract_batch(prompts), 25)
    out["feature_us_ref"] = ref_s / n * 1e6
    out["feature_us_fast"] = fast_s / n * 1e6
    out["feature_speedup"] = ref_s / fast_s
    emit("predictor_feature_extraction_ref", out["feature_us_ref"],
         "per prompt (seed per-keyword scan)")
    emit("predictor_feature_extraction_fast", out["feature_us_fast"],
         f"per prompt (batch matcher; {out['feature_speedup']:.1f}x)")

    X = extract_batch(prompts)
    dense_margins = model.predict_margin_dense(X)
    p_long_dense = _softmax(dense_margins)[:, 2]

    # --- single-request scoring -------------------------------------------
    x1 = X[:1]
    d1 = _best(lambda: _softmax(model.predict_margin_dense(x1))[:, 2], 30)
    f1 = _best(lambda: model.predict_p_long(x1), 30)
    out["single_us_dense"] = d1 * 1e6
    out["single_us_fast"] = f1 * 1e6
    out["single_speedup"] = d1 / f1
    emit("predictor_single_dense", d1 * 1e6,
         f"{d1*1e3:.3f} ms/request (paper ONNX-C 0.029 ms); seed traversal")
    emit("predictor_single_fast", f1 * 1e6,
         f"{f1*1e3:.3f} ms/request packed ({out['single_speedup']:.1f}x); "
         "4+ orders below ~2s generation")

    # --- batched scoring ---------------------------------------------------
    db, fb = _best_pair(
        lambda: _softmax(model.predict_margin_dense(X))[:, 2],
        lambda: model.predict_p_long(X), 6)
    out["batch_us_dense"] = db / n * 1e6
    out["batch_us_fast"] = fb / n * 1e6
    out["batch_speedup"] = db / fb
    emit("predictor_batch512_dense", out["batch_us_dense"],
         "per request amortised (seed dense traversal)")
    emit("predictor_batch512_fast", out["batch_us_fast"],
         f"per request amortised (packed host path; "
         f"{out['batch_speedup']:.1f}x)")
    out["batch_allclose"] = bool(np.allclose(
        model.predict_p_long(X), p_long_dense, rtol=1e-5, atol=1e-5))

    # --- Pallas kernels (interpret on CPU; compiled on TPU) ----------------
    from repro.kernels import ops
    Xj = jnp.asarray(X)
    ft = jnp.asarray(model.feature)
    th = jnp.asarray(model.threshold)
    vl = jnp.asarray(model.value)
    ops.gbdt_margins(Xj, ft, th, vl).block_until_ready()      # compile
    kd = _best(lambda: ops.gbdt_margins(Xj, ft, th, vl).block_until_ready(),
               3)
    out["pallas_dense_us"] = kd / n * 1e6
    emit("predictor_batch512_pallas_dense", out["pallas_dense_us"],
         "per request (tree-parallel dense kernel, interpret mode)")
    # device-resident packed tensors, converted once like the dense setup
    pf, pt, pc, pv = (jnp.asarray(packed.pfeat), jnp.asarray(packed.pthr),
                      jnp.asarray(packed.pchild), jnp.asarray(packed.pvalue))

    def packed_kernel():
        return ops.gbdt_margins_packed(
            Xj, pf, pt, pc, pv, depth=int(packed.depth),
            n_classes=int(packed.n_classes)).block_until_ready()

    packed_kernel()                                           # compile
    kp = _best(packed_kernel, 3)
    out["pallas_packed_us"] = kp / n * 1e6
    emit("predictor_batch512_pallas_packed", out["pallas_packed_us"],
         "per request (tree-parallel packed kernel, interpret mode)")
    out["pallas_allclose"] = bool(np.allclose(
        np.asarray(packed_kernel()), dense_margins, rtol=1e-5, atol=1e-5))
    out["pallas_auto_layout"] = ops.preferred_gbdt_layout()
    out["pallas_auto_allclose"] = bool(np.allclose(
        np.asarray(ops.gbdt_margins_best(Xj, model)), dense_margins,
        rtol=1e-5, atol=1e-5))
    emit("predictor_pallas_auto_layout", 0.0,
         f"gbdt_margins_best selects {out['pallas_auto_layout']} on "
         f"{__import__('jax').default_backend()} "
         "(dense: 3 gathers/level beats packed's 4 in interpret mode; "
         "packed wins on TPU VMEM traffic)")

    # --- training ----------------------------------------------------------
    Xtr, ytr = sp.train.X, sp.train.y
    params = GBDTParams(num_rounds=_TRAIN_ROUNDS)
    t0 = time.perf_counter()
    train_gbdt(Xtr, ytr, params)
    t_fast = time.perf_counter() - t0
    t0 = time.perf_counter()
    train_gbdt_reference(Xtr, ytr, params)
    t_ref = time.perf_counter() - t0
    out["train_s_ref"] = t_ref
    out["train_s_fast"] = t_fast
    out["train_speedup"] = t_ref / t_fast
    emit("predictor_train_ref", t_ref * 1e6,
         f"{t_ref:.2f}s for {_TRAIN_ROUNDS} rounds (seed trainer)")
    emit("predictor_train_fast", t_fast * 1e6,
         f"{t_fast:.2f}s for {_TRAIN_ROUNDS} rounds "
         f"({out['train_speedup']:.1f}x)")
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
