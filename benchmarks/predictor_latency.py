"""Predictor latency (paper §3.3: 0.029 ms/request via ONNX Runtime C API).

This container's admission path is numpy (no ONNX RT offline); we report:
  * feature extraction (pure string scan)
  * single-request numpy traversal (the per-request admission decision)
  * amortised batch numpy (what the sidecar actually runs under load)
  * the Pallas batch kernel in interpret mode (compiled-TPU stand-in)
All must sit far below generation time (~seconds) — the paper's argument is
about orders of magnitude, not the absolute figure.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, model_and_splits
from repro.core.features import extract, extract_batch
from repro.data.corpus import sample_dataset


def run() -> dict:
    pred, _, _, _ = model_and_splits("A")
    ds = sample_dataset("sharegpt", n=512, seed=3)
    prompts = ds.prompts
    out = {}

    # feature extraction
    t0 = time.perf_counter()
    for p in prompts:
        extract(p)
    feat_us = (time.perf_counter() - t0) / len(prompts) * 1e6
    emit("predictor_feature_extraction", feat_us, "per prompt (string scan)")

    X = extract_batch(prompts)

    # single-request numpy path
    x1 = X[:1]
    pred.model.predict_p_long(x1)  # warm
    t0 = time.perf_counter()
    for _ in range(200):
        pred.model.predict_p_long(x1)
    single_us = (time.perf_counter() - t0) / 200 * 1e6
    emit("predictor_single_numpy", single_us,
         f"{single_us/1e3:.3f} ms/request (paper ONNX-C 0.029 ms); "
         "4+ orders below ~2s generation")

    # batched numpy
    t0 = time.perf_counter()
    for _ in range(20):
        pred.model.predict_p_long(X)
    batch_us = (time.perf_counter() - t0) / 20 / len(X) * 1e6
    emit("predictor_batch512_numpy", batch_us, "per request, amortised")

    # Pallas kernel (interpret on CPU; compiled on TPU)
    from repro.kernels import ops
    ft = jnp.asarray(pred.model.feature)
    th = jnp.asarray(pred.model.threshold)
    vl = jnp.asarray(pred.model.value)
    Xj = jnp.asarray(X)
    ops.gbdt_margins(Xj, ft, th, vl).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(5):
        ops.gbdt_margins(Xj, ft, th, vl).block_until_ready()
    k_us = (time.perf_counter() - t0) / 5 / len(X) * 1e6
    emit("predictor_batch512_pallas_interpret", k_us,
         "per request (interpret mode; compiled path on real TPU)")
    out.update(feature_us=feat_us, single_us=single_us, batch_us=batch_us,
               pallas_us=k_us)
    return out


if __name__ == "__main__":
    run()
