"""Figure 3: SJF short-P50 reduction vs queue utilisation rho.

Paper: benefit peaks ~17% at rho=0.74, ~10% at 0.85, <3% below rho=0.5;
burst is the upper bound (70-76%).  DES calibrated to the RTX 4090
service times, tau = 3 x mu_short.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.calibration import measure_mu_short
from repro.core.simulation import poisson_workload, simulate
from repro.serving.service_time import PAPER_4090_LONG, PAPER_4090_SHORT

PAPER = {0.3: "<3", 0.5: "<3", 0.74: "~17", 0.85: "~10"}


def run(n: int = 2000, seeds: int = 5) -> dict:
    short, long = PAPER_4090_SHORT, PAPER_4090_LONG
    es = 0.5 * (short.mean + long.mean)
    tau = 3.0 * short.mean  # 10.5 s, per the Fig 3 caption calibration
    out = {}
    for rho in (0.3, 0.5, 0.74, 0.85, 0.95):
        lam = rho / es
        t0 = time.perf_counter()
        reductions = []
        for s in range(seeds):
            rng = np.random.default_rng(s)
            reqs = poisson_workload(rng, n, lam, short, long, mix_long=0.5)
            import copy
            f = simulate(copy.deepcopy(reqs), policy="fcfs")
            j = simulate(copy.deepcopy(reqs), policy="sjf", tau=tau)
            fp, jp = f.percentile(50, "short"), j.percentile(50, "short")
            reductions.append(100 * (1 - jp / fp))
        dt = (time.perf_counter() - t0) * 1e6 / seeds
        red = float(np.mean(reductions))
        std = float(np.std(reductions))
        out[rho] = red
        paper = PAPER.get(rho, "n/a")
        emit(f"fig3_rho_{rho}", dt,
             f"short_P50_reduction={red:.1f}%+-{std:.1f} (paper {paper}%)")
    return out


if __name__ == "__main__":
    run()
