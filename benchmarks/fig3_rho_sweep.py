"""Figure 3: SJF short-P50 reduction vs queue utilisation rho.

Paper: benefit peaks ~17% at rho=0.74, ~10% at 0.85, <3% below rho=0.5;
burst is the upper bound (70-76%).  DES calibrated to the RTX 4090
service times, tau = 3 x mu_short.

The full (fcfs, sjf) x rho x seed grid runs through ``core.sweep`` in ONE
engine call; the FCFS/SJF comparison is paired per (rho, seed) workload,
as the seed benchmark did via deepcopy.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.calibration import measure_mu_short
from repro.core.sweep import sweep_poisson
from repro.serving.service_time import PAPER_4090_LONG, PAPER_4090_SHORT

PAPER = {0.3: "<3", 0.5: "<3", 0.74: "~17", 0.85: "~10"}
RHOS = (0.3, 0.5, 0.74, 0.85, 0.95)


def run(n: int = 2000, seeds: int = 5) -> dict:
    short, long = PAPER_4090_SHORT, PAPER_4090_LONG
    tau = 3.0 * short.mean  # 10.5 s, per the Fig 3 caption calibration

    t0 = time.perf_counter()
    res = sweep_poisson([("fcfs", None), ("sjf", tau)], rhos=RHOS,
                        seeds=range(seeds), n=n, short=short, long=long,
                        mix_long=0.5)
    dt = (time.perf_counter() - t0) * 1e6 / (len(RHOS) * seeds)

    sp50 = res.metric("short_p50")                     # (2, R, S)
    reductions = 100.0 * (1.0 - sp50[1] / sp50[0])     # paired per seed
    out = {}
    for ri, rho in enumerate(RHOS):
        red = float(reductions[ri].mean())
        std = float(reductions[ri].std())
        out[rho] = red
        paper = PAPER.get(rho, "n/a")
        emit(f"fig3_rho_{rho}", dt,
             f"short_P50_reduction={red:.1f}%+-{std:.1f} (paper {paper}%)")
    return out


if __name__ == "__main__":
    run()
