"""Simulation-engine benchmark: seed per-event loop vs vectorized sweep.

A table9-sized grid — 5 (policy, tau) conditions x 5 seeds, n=2000
Poisson arrivals at rho=0.74 — is the paper's smallest end-to-end unit of
work.  This suite times it three ways:

  * ``old``      — ``simulate_reference`` per cell over Python ``Request``
    objects + ``SimResult`` percentile extraction (the seed path);
  * ``new``      — the whole grid through ``core.sweep`` in ONE call
    (SoA workloads, compiled C engine, vectorized metrics);
  * ``fallback`` — the same one-shot sweep on the stdlib-heapq engine
    (what a host without a C compiler gets).

It also checks bitwise trace equivalence (same per-request start/finish/
promoted and promotion counts under identical tie-breaking) of both fast
engines against the reference on every cell, and workload materialisation
cost (per-object generator vs vectorized ``RequestBatch.poisson``).

``benchmarks.run sim`` writes the result to ``BENCH_sim.json``.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import _native
from repro.core.sim_fast import RequestBatch, simulate_batch
from repro.core.simulation import poisson_workload, simulate_reference
from repro.core.sweep import METRICS, sweep_batches
from repro.serving.service_time import PAPER_4090_LONG, PAPER_4090_SHORT


def _best(fn, reps: int = 3) -> float:
    fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _old_sweep(batches, conditions):
    """The seed path: per-cell object simulation + percentile extraction."""
    out = np.empty((len(conditions), len(batches), 4))
    for c, (policy, tau) in enumerate(conditions):
        for g, reqs in enumerate(batches):
            res = simulate_reference(reqs, policy=policy, tau=tau)
            out[c, g] = (res.percentile(50, "short"),
                         res.percentile(95, "short"),
                         res.percentile(50, "long"),
                         res.percentile(95, "long"))
    return out


def run(n: int = 2000, seeds: int = 5, rho: float = 0.74) -> dict:
    short, long = PAPER_4090_SHORT, PAPER_4090_LONG
    es = 0.5 * (short.mean + long.mean)
    lam = rho / es
    mu = short.mean
    conditions = [("fcfs", None), ("sjf", 1 * mu), ("sjf", 3 * mu),
                  ("sjf", 5 * mu), ("sjf", None)]
    cells = len(conditions) * seeds

    # --- workload materialisation: per-object vs SoA --------------------
    t_obj = _best(lambda: [poisson_workload(np.random.default_rng(s), n,
                                            lam, short, long, mix_long=0.5)
                           for s in range(seeds)])
    t_soa = _best(lambda: [RequestBatch.poisson(np.random.default_rng(s), n,
                                                lam, short, long,
                                                mix_long=0.5)
                           for s in range(seeds)])
    out = {"n": n, "seeds": seeds, "conditions": len(conditions),
           "cells": cells, "rho": rho,
           "workload_old_s": t_obj, "workload_new_s": t_soa,
           "workload_speedup": t_obj / t_soa}
    emit("sim_workload_old", t_obj / seeds * 1e6, "per 2000-req stream "
         "(per-object generator)")
    emit("sim_workload_new", t_soa / seeds * 1e6,
         f"per stream (RequestBatch SoA; {out['workload_speedup']:.1f}x)")

    batches = [RequestBatch.poisson(np.random.default_rng(s), n, lam, short,
                                    long, mix_long=0.5)
               for s in range(seeds)]
    obj_batches = [b.to_requests() for b in batches]

    # --- trace equivalence on every cell, both engines ------------------
    engines = ["python"] + (["native"] if _native.native_des() else [])
    equivalent = True
    for policy, tau in conditions:
        for b, reqs in zip(batches, obj_batches):
            ref = simulate_reference(reqs, policy=policy, tau=tau)
            rs = np.array([r.start for r in sorted(ref.requests,
                                                   key=lambda r: r.req_id)])
            rf = np.array([r.finish for r in sorted(ref.requests,
                                                    key=lambda r: r.req_id)])
            for eng in engines:
                fast = simulate_batch(b, policy=policy, tau=tau, engine=eng)
                if not (np.array_equal(fast.start, rs)
                        and np.array_equal(fast.finish, rf)
                        and fast.promotions == ref.promotions):
                    equivalent = False
    out["trace_equivalent"] = equivalent
    out["native"] = _native.native_des() is not None
    emit("sim_trace_equivalence", 0.0,
         f"bitwise={'PASS' if equivalent else 'FAIL'} over {cells} cells "
         f"x {len(engines)} engines")

    # --- full-sweep wall clock ------------------------------------------
    t_old = _best(lambda: _old_sweep(obj_batches, conditions))
    t_new = _best(lambda: sweep_batches(batches, conditions))
    t_fb = _best(lambda: sweep_batches(batches, conditions,
                                       backend="python"))
    out.update(old_s=t_old, new_s=t_new, fallback_s=t_fb,
               speedup=t_old / t_new, fallback_speedup=t_old / t_fb,
               old_us_per_req=t_old / (cells * n) * 1e6,
               new_us_per_req=t_new / (cells * n) * 1e6)
    emit("sim_sweep_old", t_old / cells * 1e6,
         f"per cell ({t_old:.2f}s total, simulate_reference loop)")
    emit("sim_sweep_new", t_new / cells * 1e6,
         f"per cell ({t_new*1e3:.0f}ms total, one-shot sweep; "
         f"{out['speedup']:.1f}x)")
    emit("sim_sweep_fallback", t_fb / cells * 1e6,
         f"per cell (heapq fallback engine; {out['fallback_speedup']:.1f}x)")
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
