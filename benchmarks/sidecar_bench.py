"""Wire-level sidecar benchmark (writes ``BENCH_sidecar.json``).

Two questions the HTTP/SSE sidecar must answer before it can claim to
be a faithful deployment of the paper's proxy:

* **What does the wire cost?** — streaming TTFT measured by a loopback
  HTTP client (connect -> POST -> first SSE delta byte) vs the same
  backend awaited in-process (``backend.generate`` ttft).  The
  acceptance bar: wire TTFT <= 2x in-process (the envelope adds
  connection setup, HTTP parse, admission, dispatch hop, and SSE
  framing — it must not add a queue's worth of latency).
* **Does the scheduling win survive the wire?** — an 80-request
  short/long burst served twice through real loopback HTTP under
  ``sjf_oracle`` vs ``fcfs`` (same seeded workload, same arrival
  pattern, 1 replica).  Client-observed short-class P50 sojourn must
  keep the HoL-mitigation win end to end: socket -> parse -> admission
  -> SJF queue -> dispatch -> SSE out.

    PYTHONPATH=src python -m benchmarks.run sidecar
"""

from __future__ import annotations

import asyncio
import json
import time

import numpy as np

from benchmarks.common import emit

TTFT_REPS = 20
BURST_N = 80
TIME_SCALE = 0.004                      # burst: wall s per virtual s
SHORT_TOKS, LONG_TOKS = 16, 240


def _ttft_model():
    from repro.serving.service_time import ServiceTimeModel
    # decode fast / overhead visible: each request is ~40 ms wall, with
    # a ~25 ms prefill so the TTFT being compared is not measurement noise
    return ServiceTimeModel(prefill_tok_per_s=8000.0,
                            decode_tok_per_s=2000.0, overhead_s=0.02)


def _make_sidecar(policy, model, time_scale, n_replicas=1):
    from repro.serving.backends import SimTextBackend
    from repro.serving.http_sidecar import Sidecar
    from repro.serving.server import ClairvoyantServer
    backends = [SimTextBackend(model, replica_id=i, time_scale=time_scale)
                for i in range(n_replicas)]
    server = ClairvoyantServer(policy=policy, tau=None, engines=backends,
                               service_model=model,
                               deadline_mode="sojourn", seed=0)
    return Sidecar(server, port=0, max_inflight=BURST_N + 8)


async def _stream_once(port, body):
    """POST one streaming request; returns (ttft_s, done_s) measured
    from just before connect to first delta frame / [DONE]."""
    t0 = time.monotonic()
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    payload = json.dumps(body).encode()
    writer.write((
        "POST /v1/chat/completions HTTP/1.1\r\nHost: bench\r\n"
        "Content-Type: application/json\r\n"
        f"Content-Length: {len(payload)}\r\nConnection: close\r\n\r\n"
    ).encode() + payload)
    await writer.drain()
    ttft = None
    buf = b""
    while b"data: [DONE]" not in buf:
        chunk = await reader.read(4096)
        if not chunk:
            break
        buf += chunk
        if ttft is None and b'"content"' in buf:
            ttft = time.monotonic() - t0
    writer.close()
    try:
        await writer.wait_closed()
    except (ConnectionError, OSError):
        pass
    return ttft, time.monotonic() - t0


def _bench_ttft(result: dict) -> None:
    from repro.serving.backends import SimTextBackend
    model = _ttft_model()
    prompt = "measure the first token latency of this request"
    body = {"prompt": prompt, "max_tokens": 32, "stream": True,
            "output_tokens": 32}

    async def run():
        # in-process floor: await the backend directly, no wire
        be = SimTextBackend(model, time_scale=1.0)
        direct = []
        for _ in range(TTFT_REPS):
            out = await be.generate(prompt, max_new_tokens=32)
            direct.append(out["ttft_s"])
        sc = _make_sidecar("fcfs", model, time_scale=1.0)
        await sc.start()
        try:
            await _stream_once(sc.port, body)        # warm-up
            wire = []
            for _ in range(TTFT_REPS):
                ttft, _ = await _stream_once(sc.port, body)
                wire.append(ttft)
        finally:
            await sc.shutdown(drain_s=1.0)
        return float(np.median(direct)), float(np.median(wire))

    d_med, w_med = asyncio.run(run())
    ratio = w_med / d_med
    result["ttft_inprocess_ms"] = d_med * 1e3
    result["ttft_wire_ms"] = w_med * 1e3
    result["ttft_wire_overhead_x"] = ratio
    result["ttft_wire_overhead_ok"] = bool(ratio <= 2.0)
    emit("sidecar_ttft_wire", w_med * 1e6,
         f"inproc={d_med*1e3:.1f}ms overhead={ratio:.2f}x (bar: <=2x)")


async def _burst(policy, model, seed=0):
    """Fire the seeded short/long burst at a fresh sidecar; returns
    per-class client-observed sojourn arrays."""
    rng = np.random.default_rng(seed)
    kinds = rng.random(BURST_N) < 0.6                # 60% short
    sc = _make_sidecar(policy, model, TIME_SCALE)
    await sc.start()

    async def one(i):
        await asyncio.sleep(float(rng.uniform(0, 0.01)))
        otoks = SHORT_TOKS if kinds[i] else LONG_TOKS
        t0 = time.monotonic()
        await _stream_once(sc.port, {
            "prompt": f"burst request {i}", "max_tokens": 512,
            "output_tokens": int(otoks), "stream": True})
        return time.monotonic() - t0

    try:
        sojourn = np.array(await asyncio.gather(
            *[one(i) for i in range(BURST_N)]))
    finally:
        await sc.shutdown(drain_s=5.0)
    assert len(sc.server._terminal) == BURST_N       # nothing lost
    return sojourn[kinds], sojourn[~kinds]


def _bench_sjf_win(result: dict) -> None:
    from repro.serving.service_time import ServiceTimeModel
    model = ServiceTimeModel(prefill_tok_per_s=8000.0,
                             decode_tok_per_s=60.0)
    t0 = time.time()
    s_sjf, l_sjf = asyncio.run(_burst("sjf_oracle", model))
    s_fcfs, l_fcfs = asyncio.run(_burst("fcfs", model))
    p50_sjf = float(np.percentile(s_sjf, 50))
    p50_fcfs = float(np.percentile(s_fcfs, 50))
    result["wire_short_p50_sjf_s"] = p50_sjf
    result["wire_short_p50_fcfs_s"] = p50_fcfs
    result["wire_short_p50_speedup"] = p50_fcfs / p50_sjf
    result["wire_long_p50_sjf_s"] = float(np.percentile(l_sjf, 50))
    result["wire_long_p50_fcfs_s"] = float(np.percentile(l_fcfs, 50))
    result["wire_sjf_win_ok"] = bool(p50_sjf < p50_fcfs)
    emit("sidecar_sjf_short_p50", p50_sjf * 1e6,
         f"fcfs={p50_fcfs*1e3:.0f}ms win={p50_fcfs/p50_sjf:.2f}x "
         f"burst={BURST_N} wall={time.time()-t0:.1f}s")


def run() -> dict:
    result: dict = {"ttft_reps": TTFT_REPS, "burst_n": BURST_N,
                    "time_scale": TIME_SCALE}
    _bench_ttft(result)
    _bench_sjf_win(result)
    return result


if __name__ == "__main__":
    run()
