"""Serve benchmark: seed per-token loop vs fused on-device decode, plus the
first end-to-end number in the repo that exercises predictor -> SJF queue ->
real decode in one path (writes ``BENCH_serve.json``).

Three measurements on a reduced smollm backbone (CPU container):

* **decode microbench** — tokens/s, TTFT and per-token latency for the seed
  per-token Python loop (``RealEngine.generate_reference``: one jit dispatch
  + host argmax + token re-upload per step) vs the fused segmented loop
  (``RealEngine.generate``).  Per-token *dispatch overhead* is each path's
  per-token latency minus the device compute floor, where the floor is the
  per-token latency of a single max-length segment (one dispatch for the
  whole generation — pure ``lax.while_loop`` decode).
* **bitwise equivalence** — the fused token sequence must equal the oracle's.
* **end-to-end serving** — a 16-request burst (longs arriving first: the
  paper's HoL-blocking setup) through ``ClairvoyantServer`` backed by
  ``RealEngine``, FCFS vs SJF, batched admission via ``submit_many``;
  reports queue-to-completion P50 by class in real wall-clock seconds.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit

MAX_LEN = 160
SEGMENT = 16
N_NEW = 96
PROMPT_LEN = 24
REPEAT = 7


def _per_tok_us(out) -> float:
    return (out["service_s"] - out["ttft_s"]) / max(1, len(out["tokens"]) - 1) * 1e6


def _best(fn, repeat=REPEAT):
    """Best-of-N by wall time; returns the fastest repeat's output so its
    internal ttft/service timings match the reported number."""
    best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        o = fn()
        dt = time.perf_counter() - t0
        if dt < best:
            best, out = dt, o
    return out, best


def _decode_microbench(result: dict) -> None:
    from repro.configs import get_config
    from repro.serving.engine import RealEngine

    cfg = get_config("smollm-360m").reduced()
    eng = RealEngine(cfg, max_len=MAX_LEN, segment_len=SEGMENT)
    rng = np.random.default_rng(0)
    ids = rng.integers(0, cfg.vocab_size, PROMPT_LEN)

    # compile everything outside the timed region
    eng.generate_reference(ids, max_new_tokens=N_NEW)
    eng.generate(ids, max_new_tokens=N_NEW)
    eng.generate(ids, max_new_tokens=N_NEW, segment_len=N_NEW)

    seed, _ = _best(lambda: eng.generate_reference(ids, max_new_tokens=N_NEW))
    fused, _ = _best(lambda: eng.generate(ids, max_new_tokens=N_NEW))
    oneshot, _ = _best(
        lambda: eng.generate(ids, max_new_tokens=N_NEW, segment_len=N_NEW))

    floor = _per_tok_us(oneshot)           # device compute, 1 dispatch total
    per_seed, per_fused = _per_tok_us(seed), _per_tok_us(fused)
    ov_seed = max(per_seed - floor, 0.0)
    ov_fused = max(per_fused - floor, 1e-3)

    result.update({
        "equivalent_tokens": seed["tokens"] == fused["tokens"],
        "tok_per_s_seed": len(seed["tokens"]) / seed["service_s"],
        "tok_per_s_fused": len(fused["tokens"]) / fused["service_s"],
        "ttft_ms_seed": seed["ttft_s"] * 1e3,
        "ttft_ms_fused": fused["ttft_s"] * 1e3,
        "per_tok_us_seed": per_seed,
        "per_tok_us_fused": per_fused,
        "per_tok_us_compute_floor": floor,
        "dispatch_overhead_us_seed": ov_seed,
        "dispatch_overhead_us_fused": ov_fused,
        "dispatch_overhead_reduction_x": ov_seed / ov_fused,
    })
    emit("serve_decode_seed", per_seed,
         f"{result['tok_per_s_seed']:.0f} tok/s ttft {seed['ttft_s']*1e3:.2f} ms")
    emit("serve_decode_fused", per_fused,
         f"{result['tok_per_s_fused']:.0f} tok/s ttft {fused['ttft_s']*1e3:.2f} ms "
         f"segment={SEGMENT} equivalent={result['equivalent_tokens']}")
    emit("serve_dispatch_overhead", ov_fused,
         f"seed {ov_seed:.0f} us/tok -> fused {ov_fused:.0f} us/tok "
         f"({result['dispatch_overhead_reduction_x']:.1f}x reduction, "
         f"floor {floor:.0f} us/tok)")


def _end_to_end(result: dict) -> None:
    from repro.configs import get_config
    from repro.core.gbdt import GBDTParams
    from repro.core.predictor import Predictor
    from repro.data.corpus import sample_dataset
    from repro.serving.engine import RealEngine
    from repro.serving.openai_api import CompletionRequest
    from repro.serving.server import ClairvoyantServer

    ds = sample_dataset("sharegpt", n=2400, seed=42, balanced=True)
    predictor = Predictor.train(ds.prompts, ds.lengths,
                                GBDTParams(num_rounds=60))

    pool = sample_dataset("sharegpt", n=4000, seed=1)
    shorts = [i for i in range(len(pool)) if pool.lengths[i] < 120][:10]
    longs = [i for i in range(len(pool)) if pool.lengths[i] >= 1000][:6]
    cfg = get_config("smollm-360m").reduced()

    # one engine for both policies (identical params -> shared compiles);
    # compile every prefill bucket + the decode segment before the measured
    # drains, so P50s reflect queueing + decode, not jit.
    eng = RealEngine(cfg, max_len=MAX_LEN, segment_len=SEGMENT, seed=0)
    for b in eng.buckets:
        eng.generate(np.arange(b) % cfg.vocab_size, max_new_tokens=2)

    e2e = {}
    for policy in ("fcfs", "sjf"):
        eng.busy_until, eng.served = 0.0, 0
        server = ClairvoyantServer(
            policy=policy, tau=None,
            predictor=predictor if policy == "sjf" else None, engines=[eng])
        # adversarial burst: the long requests hit the queue first (HoL).
        order = longs + shorts
        reqs = [CompletionRequest(prompt=pool.prompts[i]) for i in order]
        server.submit_many(
            reqs,
            arrivals=[j * 1e-4 for j in range(len(order))],
            true_output_tokens=[64 if i in longs else 8 for i in order],
            klasses=["long" if i in longs else "short" for i in order])
        t0 = time.perf_counter()
        server.drain(max_new_tokens=64)
        wall = time.perf_counter() - t0
        e2e[policy] = {
            "short_p50_ms": server.percentile(50, "short") * 1e3,
            "long_p50_ms": server.percentile(50, "long") * 1e3,
            "wall_s": wall,
        }
    red = 100 * (1 - e2e["sjf"]["short_p50_ms"] / e2e["fcfs"]["short_p50_ms"])
    e2e["short_p50_reduction_pct"] = red
    result["e2e"] = {k: ({kk: round(vv, 3) for kk, vv in v.items()}
                         if isinstance(v, dict) else round(v, 2))
                     for k, v in e2e.items()}
    emit("serve_e2e_short_p50", e2e["sjf"]["short_p50_ms"] * 1e3,
         f"fcfs {e2e['fcfs']['short_p50_ms']:.1f} ms -> "
         f"sjf {e2e['sjf']['short_p50_ms']:.1f} ms ({red:.0f}% reduction), "
         f"real fused decode, n=16 burst")


def run() -> dict:
    result: dict = {"max_len": MAX_LEN, "segment_len": SEGMENT,
                    "max_new_tokens": N_NEW}
    _decode_microbench(result)
    _end_to_end(result)
    return result


if __name__ == "__main__":
    run()
