"""Table 9: starvation-timeout sensitivity (Poisson arrivals, rho=0.74,
n=2000 x 5 seeds, service N(3.5,0.8) short / N(8.9,2.0) long, 50/50).

Paper: FCFS short P50 9.70s; tau=3x 8.03s (-17%); pure SJF 5.97s (-38%) at
long-P95 79.3s (+53%).

The whole conditions x seeds grid runs through ``core.sweep`` in ONE
engine call (vectorized SoA workloads, compiled DES inner loop) instead
of the seed's per-object loop per cell.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.calibration import measure_mu_short
from repro.core.sweep import sweep_poisson
from repro.serving.service_time import PAPER_4090_LONG, PAPER_4090_SHORT

PAPER = {"fcfs": (9.70, 43.71, 15.60, 51.79),
         "tau1x": (8.38, 18.15, 15.18, 69.35),
         "tau3x": (8.03, 23.46, 16.83, 60.45),
         "tau5x": (7.02, 28.56, 16.07, 55.17),
         "tauInf": (5.97, 14.72, 14.14, 79.32)}


def run(n: int = 2000, seeds: int = 5, rho: float = 0.74) -> dict:
    short, long = PAPER_4090_SHORT, PAPER_4090_LONG

    # Fig 3 caption: the 4090 steady-state calibration uses mu_short = 3.5 s
    # (tau = 3x = 10.5 s).  The burst-measured variant (measure_mu_short) is
    # the M1 deployment path (§3.4) and is exercised in launch/serve.py.
    mu_short = short.mean
    conditions = [("fcfs", "fcfs", None),
                  ("tau1x", "sjf", 1.0 * mu_short),
                  ("tau3x", "sjf", 3.0 * mu_short),
                  ("tau5x", "sjf", 5.0 * mu_short),
                  ("tauInf", "sjf", None)]

    t0 = time.perf_counter()
    res = sweep_poisson([(p, t) for _, p, t in conditions], rhos=(rho,),
                        seeds=range(seeds), n=n, short=short, long=long,
                        mix_long=0.5)
    dt = (time.perf_counter() - t0) * 1e6 / (len(conditions) * seeds)

    out = {}
    for ci, (name, _, _) in enumerate(conditions):
        means = {(k, q): float(res.metric(f"{k}_p{q}")[ci, 0].mean())
                 for k in ("short", "long") for q in (50, 95)}
        p = PAPER[name]
        out[name] = means
        emit(f"table9_{name}", dt,
             f"shortP50={means[('short',50)]:.2f}s(paper {p[0]}) "
             f"shortP95={means[('short',95)]:.2f}s(paper {p[1]}) "
             f"longP50={means[('long',50)]:.2f}s(paper {p[2]}) "
             f"longP95={means[('long',95)]:.2f}s(paper {p[3]}) "
             f"mu_short={mu_short:.1f}s")
    return out


if __name__ == "__main__":
    run()
