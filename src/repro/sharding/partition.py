"""Resolve logical-axis annotations to concrete shardings for whole pytrees."""

from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.sharding.rules import spec_for


def tree_specs(shapes: Any, axes: Any, mesh: Mesh, rules=None):
    """PartitionSpec tree: ``shapes`` leaves are arrays/ShapeDtypeStructs,
    ``axes`` carries matching tuples of logical axis names."""
    return jax.tree.map(
        lambda s, a: spec_for(s.shape, a, mesh, rules), shapes, axes,
        is_leaf=lambda x: hasattr(x, "shape"))


def tree_shardings(shapes: Any, axes: Any, mesh: Mesh, rules=None):
    return jax.tree.map(
        lambda s, a: NamedSharding(mesh, spec_for(s.shape, a, mesh, rules)),
        shapes, axes, is_leaf=lambda x: hasattr(x, "shape"))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, PartitionSpec())
