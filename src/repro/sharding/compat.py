"""Cross-version jax compat shims for the sharding substrate."""

from __future__ import annotations

import jax


def shard_map_fn():
    """The shard_map entry point across jax versions.

    Newer jax exposes ``jax.shard_map``; the 0.4.x line in this container
    only has ``jax.experimental.shard_map.shard_map``.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map
    return shard_map


def shard_map_unchecked(fn, mesh, in_specs, out_specs):
    """shard_map with replication checking off, across jax versions
    (``check_vma`` on current jax, ``check_rep`` on the 0.4.x line)."""
    shard_map = shard_map_fn()
    try:
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)
    except TypeError:
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
