"""Sharding context + activation-constraint hook used throughout the models.

The launcher activates a mesh with :func:`use_mesh`; model code calls
:func:`constrain` on activations with logical axis names.  Outside a mesh
context (CPU smoke tests, single device) ``constrain`` is the identity, so the
same model code runs everywhere.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Optional, Tuple

import jax

from repro.sharding.rules import DEFAULT_RULES, sharding_for, spec_for

_state = threading.local()


def current_mesh():
    return getattr(_state, "mesh", None)


def current_rules():
    return getattr(_state, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def use_mesh(mesh, rules: Optional[Mapping[str, Tuple[str, ...]]] = None):
    prev_mesh = getattr(_state, "mesh", None)
    prev_rules = getattr(_state, "rules", DEFAULT_RULES)
    _state.mesh = mesh
    _state.rules = rules if rules is not None else DEFAULT_RULES
    try:
        yield
    finally:
        _state.mesh = prev_mesh
        _state.rules = prev_rules


def constrain(x, *axes: Optional[str]):
    """Constrain activation ``x`` to the logical axes under the active mesh."""
    mesh = current_mesh()
    if mesh is None or mesh.size == 1:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"constrain: {len(axes)} axes for rank-{x.ndim} tensor")
    sh = sharding_for(x.shape, axes, mesh, current_rules())
    return jax.lax.with_sharding_constraint(x, sh)


__all__ = [
    "use_mesh",
    "constrain",
    "current_mesh",
    "current_rules",
    "spec_for",
    "sharding_for",
    "DEFAULT_RULES",
]
