"""Logical-axis sharding rules with divisibility fallback.

Tensors in the model code are annotated with *logical* axis names
("batch", "heads", "mlp", ...).  A rules table maps each logical axis to a
mesh-axis tuple.  ``spec_for`` resolves annotations to a concrete
``PartitionSpec`` given actual dimension sizes, degrading gracefully:

* a logical axis whose dimension is not divisible by the mapped mesh axes is
  left unsharded (the fallback that lets e.g. 15-head smollm and batch=1
  long-context decode compile on a fixed 16x16 mesh);
* composite mappings like ("pod", "data") drop trailing mesh axes until the
  product divides the dimension;
* a mesh axis may be consumed at most once per tensor (PartitionSpec rule) —
  first annotation wins, later ones fall back to None.
"""

from __future__ import annotations

from typing import Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# logical axis -> preferred mesh axes (in priority order; composite tuples
# shard one dimension over several mesh axes)
DEFAULT_RULES: dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),                 # replicated by default (activations)
    # Sequence parallelism for the inter-block residual stream: the scanned
    # carry is saved once per layer for the backward pass, so leaving it
    # replicated across the model axis costs layers x (B,S,D) per device
    # (55 GiB/device on granite@train_4k).  Sharding the sequence dim over
    # "model" between blocks (Megatron SP) cuts that 16x; GSPMD inserts the
    # all-gather before QKV and the reduce-scatter after the block.
    "seq_sp": ("model",),
    "kv_seq": ("model",),      # decode-time KV cache sequence dim (SP)
    "embed": (),               # activation d_model stays replicated across TP
    # weight d_model dim: FSDP-sharded over the data axis — combined with the
    # "model"-axis TP split this is 2D (FSDP x TP) weight sharding, without
    # which 400B-class params cannot fit 16 GB/chip (50 GB/chip at TP-16).
    "embed_w": ("data",),
    "heads": ("model",),
    "kv_heads": (),            # usually too few to shard 16-way; see kv_seq
    "head_dim": (),
    "qkv": ("model",),         # flattened heads*head_dim projection dim
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    # MoE dispatch groups: fully local per device (sort/pack never cross a
    # device); the group->expert reshard is the canonical MoE all-to-all.
    "moe_groups": ("pod", "data", "model"),
    "expert_mlp": (),          # per-expert ff dim: experts already claim model
    "cap": (),
    "ssm_inner": ("model",),
    "ssm_state": (),
    "conv": (),
    "image_seq": (),
}


# Serving rule-set (§Perf): small models replicate weights across the data
# axis (no per-token FSDP regather on the decode path); the model axis keeps
# TP.  Used by the decode-cell perf experiments and launch/serve.
SERVING_RULES = dict(DEFAULT_RULES)
SERVING_RULES["embed_w"] = ()


def _mesh_axis_sizes(mesh: Mesh) -> Mapping[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def is_axes_leaf(x) -> bool:
    """True for a tuple of logical-axis names (str/None) — an annotation leaf.
    Distinguishes ('embed_w', 'qkv') from structural tuples of subtrees."""
    return isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)


def resolve_axis(
    logical: Optional[str],
    dim: int,
    mesh: Mesh,
    rules: Mapping[str, Tuple[str, ...]],
    used: set,
) -> Optional[Tuple[str, ...]]:
    """Resolve one logical axis to mesh axes (or None), respecting divisibility."""
    if logical is None:
        return None
    mapped = rules.get(logical, ())
    sizes = _mesh_axis_sizes(mesh)
    # keep only axes present in this mesh and not already used in this spec
    avail = [a for a in mapped if a in sizes and a not in used]
    # drop trailing axes until the product divides the dimension
    while avail:
        prod = 1
        for a in avail:
            prod *= sizes[a]
        if prod > 0 and dim % prod == 0 and prod > 1:
            for a in avail:
                used.add(a)
            return tuple(avail)
        avail.pop()
    return None


def spec_for(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[Mapping[str, Tuple[str, ...]]] = None,
) -> PartitionSpec:
    rules = DEFAULT_RULES if rules is None else rules
    assert len(shape) == len(axes), (shape, axes)
    used: set = set()
    parts = []
    for dim, logical in zip(shape, axes):
        resolved = resolve_axis(logical, dim, mesh, rules, used)
        if resolved is None:
            parts.append(None)
        elif len(resolved) == 1:
            parts.append(resolved[0])
        else:
            parts.append(resolved)
    # trim trailing Nones (canonical form)
    while parts and parts[-1] is None:
        parts.pop()
    return PartitionSpec(*parts)


def sharding_for(shape, axes, mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, axes, mesh, rules))
