"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).

Single pod:  (16, 16)    axes ("data", "model")   — 256 chips (v5e pod)
Multi-pod:   (2, 16, 16) axes ("pod", "data", "model") — 512 chips

The "pod" axis composes with "data" for batch sharding (DP across pods;
see sharding/rules.py "batch").  Pipeline parallelism over the pod axis is an
opt-in training config (training/pipeline.py).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist, as a (data, model) mesh — smoke tests, CPU."""
    n = jax.device_count()
    return jax.make_mesh((n, 1), ("data", "model"))


def make_elastic_mesh(device_count: int, model_parallel: int = 16):
    """Rebuild a mesh after losing nodes (elastic scaling path).

    Keeps the model axis intact (TP sharding of weights must survive) and
    shrinks the data axis to whatever is left: 512 -> 256 -> 128 ...
    """
    if device_count % model_parallel:
        raise ValueError(
            f"{device_count} devices not divisible by model={model_parallel}")
    return jax.make_mesh((device_count // model_parallel, model_parallel),
                         ("data", "model"),
                         devices=jax.devices()[:device_count])
