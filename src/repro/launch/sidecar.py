"""Launch the HTTP/SSE sidecar: Clairvoyant behind a real socket.

    PYTHONPATH=src python -m repro.launch.sidecar --port 8080 \
        --backend sim --replicas 2 --policy sjf

then talk OpenAI chat-completions to it:

    curl -s localhost:8080/v1/chat/completions -d '{
        "messages": [{"role": "user", "content": "hello"}],
        "max_tokens": 32}'

Backends (one per replica):

* ``sim``  — virtual service times from the arch's ``ServiceTimeModel``,
  slept on the event loop and streamed as synthetic text
  (``--time-scale`` compresses wall time; the default for demos).
* ``real`` — an actual fused on-device decode per request
  (``RealEngine`` on the reduced smollm-360m stack, off the event loop
  via a worker thread).
* ``http`` — proxy to external OpenAI-compatible upstreams
  (``--upstream host:port``, repeatable), with connect/read timeouts
  feeding the retry policy and per-replica circuit breakers.

SIGINT/SIGTERM trigger a graceful drain: the listener closes, in-flight
work gets ``--drain-s`` seconds to finish, stragglers are cancelled at
the next segment boundary — every admitted request still leaves with
exactly one terminal status.

Observability (PR 10): the main port always serves Prometheus text on
``GET /metrics``; ``--metrics-port`` additionally exposes it on a
dedicated scrape port (so load balancers need not route scrapes through
the serving listener).  ``--trace-out FILE`` enables the flight
recorder and writes a Chrome/Perfetto ``trace_event`` JSON of every
request's span timeline at shutdown; ``--log-json FILE`` writes the
same spans as structured JSONL.
"""

from __future__ import annotations

import argparse
import asyncio
import signal

from repro.configs import get_config
from repro.core.calibration import calibrate_tau
from repro.core.simulation import ServiceDist
from repro.launch.serve import build_predictor
from repro.serving.faults import CircuitBreaker, FaultPlan, RetryPolicy
from repro.serving.http_sidecar import Sidecar
from repro.serving.server import ClairvoyantServer
from repro.serving.service_time import ServiceTimeModel


def build_sidecar(args) -> Sidecar:
    cfg = get_config(args.arch)
    model = ServiceTimeModel.from_arch(cfg, chips=args.chips)
    if getattr(args, "speculative", False):
        # mirror draft-verify decode in the cost model (and therefore in
        # the tau calibration below): decode runs at the expected
        # speculative speedup of the assumed acceptance rate
        from dataclasses import replace as _replace

        from repro.serving.service_time import expected_speedup
        model = _replace(model, effective_rate=float(
            expected_speedup(args.accept_rate, args.draft_k)))
    from repro.core.policy import get_policy
    predictor = build_predictor(args.dataset) \
        if get_policy(args.policy).uses_predictor and not args.no_predictor \
        else None
    short_dist = ServiceDist(model.service(64, 60),
                             0.3 * model.service(64, 60))
    long_dist = ServiceDist(model.service(64, 1400),
                            0.3 * model.service(64, 1400))
    tau = calibrate_tau(short_dist, long_dist, multiplier=args.tau_mult)

    if args.backend == "sim":
        from repro.serving.backends import SimTextBackend
        backends = [SimTextBackend(model, replica_id=i,
                                   time_scale=args.time_scale)
                    for i in range(args.replicas)]
    elif args.backend == "real":
        from repro.serving.backends import InProcessBackend
        from repro.serving.engine import RealEngine
        rcfg = get_config("smollm-360m").reduced()
        spec_kw = {}
        if getattr(args, "speculative", False):
            dcfg = get_config(args.draft_model).reduced() \
                if args.draft_model else rcfg
            spec_kw = dict(draft_cfg=dcfg, draft_k=args.draft_k,
                           draft_seed=args.seed)
        backends = [InProcessBackend(RealEngine(rcfg, max_len=96,
                                                **spec_kw))
                    for _ in range(args.replicas)]
        for i, b in enumerate(backends):
            b.replica_id = i
    else:                                    # http: proxy to upstreams
        from repro.serving.backends import HTTPBackend
        if not args.upstream:
            raise SystemExit("--backend http requires --upstream host:port")
        backends = []
        for i, up in enumerate(args.upstream):
            host, _, port = up.partition(":")
            backends.append(HTTPBackend(host, int(port or 80),
                                        replica_id=i, model=args.model))

    fault_plan = FaultPlan.random(
        seed=args.seed, horizon=3600.0, n_replicas=len(backends),
        crash_mtbf=args.chaos_crash_mtbf or None,
        transient_rate=args.chaos_transient_rate or None) \
        if args.chaos_crash_mtbf or args.chaos_transient_rate else None

    server = ClairvoyantServer(
        policy=args.policy, tau=tau, predictor=predictor,
        service_model=model, engines=backends, seed=args.seed,
        fault_plan=fault_plan, retry=RetryPolicy(seed=args.seed),
        deadline_s=args.deadline_s, deadline_mode="sojourn",
        max_queue_depth=args.max_queue_depth,
        breaker=CircuitBreaker(recovery_s=args.breaker_recovery_s))
    if getattr(args, "trace_out", None) or getattr(args, "log_json", None):
        # tracing requested: attach a full bundle (recorder + metrics +
        # ranking) before the Sidecar builds its metrics-only default
        from repro.serving.observability import Observability
        server.attach_observability(Observability.default(tracing=True))
    return Sidecar(server, host=args.host, port=args.port,
                   model=args.model, max_inflight=args.max_inflight,
                   tenant_rate=args.tenant_rate,
                   tenant_burst=args.tenant_burst,
                   drain_s=args.drain_s,
                   max_new_tokens=args.max_new_tokens)


async def serve(args) -> None:
    sidecar = build_sidecar(args)
    await sidecar.start()
    print(f"sidecar listening on {sidecar.address} "
          f"(policy={args.policy}, backend={args.backend}, "
          f"replicas={len(sidecar.backends)})", flush=True)
    metrics_srv = None
    if getattr(args, "metrics_port", None) is not None:
        from repro.serving.metrics_http import MetricsServer
        metrics_srv = MetricsServer(sidecar.obs, host=args.host,
                                    port=args.metrics_port)
        await metrics_srv.start()
        print(f"metrics on http://{args.host}:{metrics_srv.port}/metrics",
              flush=True)
    stop = asyncio.Event()
    loop = asyncio.get_running_loop()
    for sig in (signal.SIGINT, signal.SIGTERM):
        try:
            loop.add_signal_handler(sig, stop.set)
        except NotImplementedError:          # non-unix
            pass
    await stop.wait()
    print("draining...", flush=True)
    await sidecar.shutdown()
    if metrics_srv is not None:
        await metrics_srv.stop()
    rec = sidecar.obs.recorder
    if rec is not None:
        if getattr(args, "trace_out", None):
            rec.write_perfetto(args.trace_out)
            print(f"perfetto trace ({len(rec)} spans) -> {args.trace_out}",
                  flush=True)
        if getattr(args, "log_json", None):
            rec.write_jsonl(args.log_json)
            print(f"span JSONL -> {args.log_json}", flush=True)
    srv = sidecar.server
    done = len(srv.responses)
    ok = sum(1 for r in srv.responses if r.ok)
    print(f"drained: {done} terminals ({ok} ok), "
          f"fault_stats={srv.fault_stats}, "
          f"wire_stats={sidecar.wire_stats}", flush=True)


def main(argv=None):
    from repro.core.policy import registered_names
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--policy", default="sjf",
                    choices=sorted(registered_names()))
    ap.add_argument("--backend", default="sim",
                    choices=("sim", "real", "http"))
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--upstream", action="append", default=[],
                    help="host:port of an OpenAI-compatible upstream "
                         "(repeat for multiple replicas; --backend http)")
    ap.add_argument("--model", default="clairvoyant-sim")
    ap.add_argument("--arch", default="gemma3-4b-edge")
    ap.add_argument("--chips", type=int, default=1)
    ap.add_argument("--dataset", default="sharegpt")
    ap.add_argument("--no-predictor", action="store_true")
    ap.add_argument("--tau-mult", type=float, default=3.0)
    ap.add_argument("--time-scale", type=float, default=0.02,
                    help="sim backend: wall seconds per virtual second")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="server-wide sojourn deadline (per-request "
                         "X-Deadline-S overrides)")
    ap.add_argument("--max-queue-depth", type=int, default=None)
    ap.add_argument("--max-inflight", type=int, default=256)
    ap.add_argument("--max-new-tokens", type=int, default=64)
    ap.add_argument("--tenant-rate", type=float, default=None,
                    help="per-tenant token-bucket rate (req/s); "
                         "unset = no rate limiting")
    ap.add_argument("--tenant-burst", type=float, default=10.0)
    ap.add_argument("--drain-s", type=float, default=30.0)
    ap.add_argument("--breaker-recovery-s", type=float, default=5.0)
    ap.add_argument("--speculative", action="store_true",
                    help="draft-verify decode: the real backend runs a "
                         "draft model per replica; the sim backend (and "
                         "the tau calibration) apply the expected "
                         "speculative speedup to the service-time model")
    ap.add_argument("--draft-model", default=None,
                    help="draft arch name (default: the reduced target "
                         "arch — 100%% acceptance sanity mode)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="draft tokens proposed per verify step")
    ap.add_argument("--accept-rate", type=float, default=0.7,
                    help="assumed draft acceptance rate for the "
                         "service-time mirror (sim backend/calibration)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="also serve Prometheus /metrics on this "
                         "dedicated port (0 = ephemeral); the main port "
                         "serves /metrics regardless")
    ap.add_argument("--trace-out", default=None,
                    help="enable the flight recorder and write a "
                         "Chrome/Perfetto trace_event JSON of every "
                         "request's span timeline here at shutdown")
    ap.add_argument("--log-json", default=None,
                    help="enable the flight recorder and write the span "
                         "log as structured JSONL here at shutdown")
    ap.add_argument("--chaos-crash-mtbf", type=float, default=0.0,
                    help=">0: inject engine crashes at this MTBF (s)")
    ap.add_argument("--chaos-transient-rate", type=float, default=0.0,
                    help=">0: injected transient errors per second")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    asyncio.run(serve(args))


if __name__ == "__main__":
    main()
