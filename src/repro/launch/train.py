"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt

Features exercised: sharded train step (pjit on the local mesh), synthetic
deterministic data stream (elastic-resume safe), async checkpointing with
atomic commits, auto-resume from the latest step, straggler monitoring,
optional int8 gradient compression (--compress, demonstration path).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.lm_data import LMDataConfig, SyntheticLMStream
from repro.launch.mesh import make_local_mesh
from repro.models.frontends import batch_axes
from repro.sharding import use_mesh
from repro.sharding.partition import tree_shardings
from repro.training import checkpoint as ckpt_lib
from repro.training.optimizer import OptConfig
from repro.training.straggler import StepTimer
from repro.training.train_loop import (TrainState, abstract_train_state,
                                       init_train_state, make_train_step)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.audio_frontend or cfg.num_image_tokens:
        raise SystemExit("train.py drives text archs; use examples/ for "
                         "multimodal smoke runs")
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1))

    mesh = make_local_mesh()
    data = SyntheticLMStream(LMDataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))

    step_fn = make_train_step(cfg, opt_cfg, microbatches=args.microbatches)
    s_shapes, s_axes = abstract_train_state(cfg, opt_cfg)
    s_sh = tree_shardings(s_shapes, s_axes, mesh)

    start_step = 0
    with use_mesh(mesh):
        if args.ckpt and ckpt_lib.latest_step(args.ckpt) is not None:
            start_step = ckpt_lib.latest_step(args.ckpt)
            state = ckpt_lib.restore(s_shapes, args.ckpt, shardings=s_sh)
            print(f"resumed from step {start_step}")
        else:
            state = init_train_state(cfg, opt_cfg, jax.random.key(0))
        jit_step = jax.jit(step_fn, in_shardings=(s_sh, None),
                           out_shardings=(s_sh, None), donate_argnums=(0,))

        saver = ckpt_lib.AsyncCheckpointer(args.ckpt) if args.ckpt else None
        timer = StepTimer()
        losses = []
        for step in range(start_step, start_step + args.steps):
            batch = {k: jax.numpy.asarray(v)
                     for k, v in data.batch(step).items()}
            t0 = time.monotonic()
            state, metrics = jit_step(state, batch)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            if timer.observe(step, dt):
                print(f"step {step}: straggler flagged ({dt:.2f}s)")
            losses.append(loss)
            if step % args.log_every == 0:
                print(f"step {step}: loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} {dt:.2f}s")
            if saver and (step + 1) % args.ckpt_every == 0:
                saver.save(state, step + 1)
        if saver:
            saver.save(state, start_step + args.steps)
            saver.wait()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
