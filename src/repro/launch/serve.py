"""End-to-end serving driver: the paper's deployment, as a CLI.

    PYTHONPATH=src python -m repro.launch.serve --policy sjf --requests 100 \
        --replicas 1 --rho 0.74

Trains the predictor on the sharegpt-profile corpus, calibrates tau =
3 x mu_short on the target service-time model, then serves a mixed workload
under the chosen policy and prints the per-class latency percentiles — the
one-command version of the paper's §5.4 experiment.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config
from repro.core.calibration import calibrate_tau
from repro.core.gbdt import GBDTParams
from repro.core.predictor import Predictor
from repro.core.simulation import ServiceDist
from repro.data.corpus import CLASS_NAMES, sample_dataset
from repro.serving.openai_api import CompletionRequest
from repro.serving.server import ClairvoyantServer
from repro.serving.service_time import ServiceTimeModel


def build_predictor(dataset: str = "sharegpt", rounds: int = 120,
                    seed: int = 42) -> Predictor:
    ds = sample_dataset(dataset, n=6000, seed=seed, balanced=True)
    return Predictor.train(ds.prompts, ds.lengths,
                           GBDTParams(num_rounds=rounds))


def main(argv=None):
    from repro.core.policy import registered_names
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="sjf",
                    choices=sorted(registered_names()))
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--arch", default="gemma3-4b-edge",
                    help="backend arch for the service-time model")
    ap.add_argument("--chips", type=int, default=1)
    ap.add_argument("--rho", type=float, default=0.0,
                    help=">0: Poisson arrivals at this utilisation; "
                         "0: concurrent burst")
    ap.add_argument("--tau-mult", type=float, default=3.0)
    ap.add_argument("--dataset", default="sharegpt")
    ap.add_argument("--speculative", action="store_true",
                    help="mirror draft-verify decode in the service-time "
                         "model: decode runs at the expected speculative "
                         "speedup of --accept-rate")
    ap.add_argument("--draft-model", default=None,
                    help="draft arch: sets the draft/target cost ratio "
                         "from the two archs' active parameter counts "
                         "(default 0.15)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="draft tokens proposed per verify step")
    ap.add_argument("--accept-rate", type=float, default=0.7,
                    help="assumed draft acceptance rate")
    ap.add_argument("--trace-out", default=None,
                    help="enable the flight recorder and write a "
                         "Chrome/Perfetto trace_event JSON of the "
                         "drain's span timeline here (virtual time)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    model = ServiceTimeModel.from_arch(cfg, chips=args.chips)
    if args.speculative:
        from dataclasses import replace as _replace

        from repro.serving.service_time import expected_speedup
        draft_cost = 0.15
        if args.draft_model:
            dcfg = get_config(args.draft_model)
            draft_cost = (dcfg.active_param_count()
                          / cfg.active_param_count())
        rate = float(expected_speedup(args.accept_rate, args.draft_k,
                                      draft_cost))
        model = _replace(model, effective_rate=rate)
        print(f"speculative mirror: K={args.draft_k} "
              f"accept={args.accept_rate} draft_cost={draft_cost:.3f} "
              f"-> expected speedup {rate:.2f}x")
    rng = np.random.default_rng(args.seed)

    from repro.core.policy import get_policy
    predictor = build_predictor(args.dataset) \
        if get_policy(args.policy).uses_predictor else None

    # tau = 3 x mu_short, measured under mixed queueing conditions (§3.4)
    short_dist = ServiceDist(model.service(64, 60),
                             0.3 * model.service(64, 60))
    long_dist = ServiceDist(model.service(64, 1400),
                            0.3 * model.service(64, 1400))
    tau = calibrate_tau(short_dist, long_dist, multiplier=args.tau_mult)
    print(f"calibrated tau = {tau:.2f}s")

    server = ClairvoyantServer(policy=args.policy, tau=tau,
                               n_replicas=args.replicas,
                               predictor=predictor, service_model=model,
                               seed=args.seed)
    if args.trace_out:
        from repro.serving.observability import Observability
        server.attach_observability(Observability.default(tracing=True))

    ds = sample_dataset(args.dataset, n=args.requests, seed=args.seed + 1)
    if args.rho > 0:
        es = np.mean([server.service_model.service(64, int(l))
                      for l in ds.lengths])
        lam = args.rho / es
        arrivals = np.cumsum(rng.exponential(1 / lam, args.requests))
    else:
        arrivals = rng.uniform(0, 0.05, args.requests)  # burst (<=50 ms)

    # batched admission: ONE feature-extraction + GBDT call for the burst
    server.submit_many(
        [CompletionRequest(prompt=ds.prompts[i])
         for i in range(args.requests)],
        arrivals=[float(a) for a in arrivals],
        true_output_tokens=[int(l) for l in ds.lengths],
        klasses=[CLASS_NAMES[int(c)] for c in ds.classes])
    server.drain()

    if args.trace_out:
        rec = server.obs.recorder
        rec.write_perfetto(args.trace_out)
        print(f"perfetto trace ({len(rec)} spans) -> {args.trace_out}")
    print(f"policy={args.policy} replicas={args.replicas} "
          f"promotions={server.promotions}")
    for klass in ("short", "long"):
        print(f"  {klass:6s} P50={server.percentile(50, klass):8.2f}s "
              f"P95={server.percentile(95, klass):8.2f}s "
              f"P99={server.percentile(99, klass):8.2f}s")
    return server


if __name__ == "__main__":
    main()
