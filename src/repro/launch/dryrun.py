import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture x input-shape) cell of the assignment
matrix on the production meshes — (16,16) single-pod and (2,16,16) multi-pod
— and derives the roofline terms (deliverable g) from the compiled artifacts.

Per cell, TWO graphs are built:
  * the PRODUCTION graph (layer-scan + remat + microbatching): this is what
    must compile; memory_analysis() proves the per-device footprint, and its
    HLO text provides collective bytes (while-trip multiplicity applied);
  * a COST graph (layers unrolled, microbatches=1): XLA's cost analysis
    counts while bodies once, so FLOPs/bytes are read from the unrolled
    graph where they are exact.  Falls back to scan-corrected estimates for
    stacks too deep to unroll.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both
  python -m repro.launch.dryrun --summarize
"""

import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCH_NAMES, SHAPE_NAMES, get_config
from repro.configs.base import SHAPES
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models.frontends import batch_axes, input_specs
from repro.models.model import LM
from repro.sharding import use_mesh
from repro.sharding.partition import tree_shardings
from repro.training.optimizer import OptConfig
from repro.training.train_loop import abstract_train_state, make_train_step

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# Per-arch training knobs (memory iterations recorded in EXPERIMENTS.md §Perf)
TRAIN_KNOBS = {
    "llama4-maverick-400b-a17b": dict(microbatches=8, moment_dtype="bfloat16",
                                      accum_dtype="bfloat16"),
    "dbrx-132b": dict(microbatches=8, moment_dtype="bfloat16",
                      accum_dtype="bfloat16"),
    "llama-3.2-vision-90b": dict(microbatches=8, moment_dtype="bfloat16",
                                 accum_dtype="bfloat16"),
    "qwen3-32b": dict(microbatches=4),
    "jamba-v0.1-52b": dict(microbatches=8, accum_dtype="bfloat16"),
    "granite-8b": dict(microbatches=2),
}
MAX_UNROLL_LAYERS = 128


def _knobs(arch: str) -> dict:
    base = dict(microbatches=1, moment_dtype="float32",
                accum_dtype="float32")
    base.update(TRAIN_KNOBS.get(arch, {}))
    return base


def build_cell(arch: str, shape_name: str, mesh, *, unroll: bool,
               microbatches: int, moment_dtype: str, accum_dtype: str,
               rules=None):
    """Returns (jitted_fn, example_args) for one cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    lm = LM(cfg)
    b_specs = input_specs(cfg, shape)
    b_sh = tree_shardings(b_specs, batch_axes(cfg, shape), mesh, rules)

    if shape.kind == "train":
        opt = OptConfig(moment_dtype=moment_dtype)
        s_shapes, s_axes = abstract_train_state(cfg, opt)
        s_sh = tree_shardings(s_shapes, s_axes, mesh, rules)
        step = make_train_step(cfg, opt, microbatches=microbatches,
                               accum_dtype=accum_dtype)
        if unroll:
            import repro.models.transformer as tfm
            step = _with_unroll(step, cfg)
        fn = jax.jit(step, in_shardings=(s_sh, b_sh),
                     out_shardings=(s_sh, None), donate_argnums=(0,))
        return fn, (s_shapes, b_specs)

    p_shapes, p_axes = lm.abstract_params()
    p_sh = tree_shardings(p_shapes, p_axes, mesh, rules)

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            return lm.prefill(params, batch)[0]
        fn = prefill_fn if not unroll else _with_unroll(prefill_fn, cfg)
        jfn = jax.jit(fn, in_shardings=(p_sh, b_sh))
        return jfn, (p_shapes, b_specs)

    # decode: one new token against a seq_len KV cache (serve_step)
    shape_cfg = SHAPES[shape_name]
    c_shapes = jax.eval_shape(
        lambda: lm.init_cache(shape_cfg.global_batch, shape_cfg.seq_len,
                              t0=shape_cfg.seq_len - 1))
    c_sh = tree_shardings(c_shapes, lm.cache_axes(), mesh, rules)

    def serve_step(params, caches, batch):
        return lm.decode_step(params, caches, batch)
    fn = serve_step if not unroll else _with_unroll(serve_step, cfg)
    jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, b_sh),
                  out_shardings=(None, c_sh), donate_argnums=(1,))
    return jfn, (p_shapes, c_shapes, b_specs)


def _with_unroll(fn, cfg):
    """Wrap fn so the layer scan is fully unrolled (cost graph)."""
    import repro.models.transformer as tfm

    def wrapped(*args):
        old = tfm.SCAN_UNROLL["n"]
        tfm.SCAN_UNROLL["n"] = cfg.pattern_repeats
        try:
            return fn(*args)
        finally:
            tfm.SCAN_UNROLL["n"] = old
    return wrapped


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path, skip_cost: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    knobs = _knobs(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    # --- production graph ------------------------------------------------
    with use_mesh(mesh):
        fn, args = build_cell(arch, shape_name, mesh, unroll=False, **knobs)
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    print(f"[{arch} {shape_name} {mesh_name}] memory_analysis: "
          f"args={ma.argument_size_in_bytes/2**30:.2f}GiB "
          f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
          f"out={ma.output_size_in_bytes/2**30:.2f}GiB per device")
    ca_raw = compiled.cost_analysis()
    print(f"[{arch} {shape_name} {mesh_name}] cost_analysis(raw scan): "
          f"flops={ca_raw.get('flops', 0.0):.3e} "
          f"bytes={ca_raw.get('bytes accessed', 0.0):.3e}")
    coll = rl.collective_bytes(compiled.as_text())
    prod_compile_s = time.time() - t0

    # --- cost graph (unrolled, mb=1) --------------------------------------
    flops_source = "unrolled"
    hlo_flops = hlo_bytes = None
    if not skip_cost and cfg.num_layers <= MAX_UNROLL_LAYERS:
        try:
            with use_mesh(mesh):
                cfn, cargs = build_cell(arch, shape_name, mesh, unroll=True,
                                        **{**knobs, "microbatches": 1})
                ccomp = cfn.lower(*cargs).compile()
            cca = ccomp.cost_analysis()
            hlo_flops = float(cca.get("flops", 0.0))
            hlo_bytes = float(cca.get("bytes accessed", 0.0))
        except Exception as e:  # fall back to scan correction
            print(f"  cost graph failed ({type(e).__name__}); "
                  "using scan-corrected estimate")
    if hlo_flops is None:
        flops_source = "scan-corrected"
        mult = cfg.pattern_repeats * knobs["microbatches"]
        hlo_flops = float(ca_raw.get("flops", 0.0)) * mult
        hlo_bytes = float(ca_raw.get("bytes accessed", 0.0)) * mult

    report = rl.RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=mesh.size,
        model_flops=rl.model_flops(cfg, shape),
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes, coll_bytes=coll,
        bytes_per_device={
            "args": ma.argument_size_in_bytes,
            "temp": ma.temp_size_in_bytes,
            "out": ma.output_size_in_bytes,
        },
        flops_source=flops_source,
        analytic_bytes_dev=rl.analytic_bytes(cfg, shape, mesh.size,
                                             knobs["microbatches"]),
    )
    d = report.to_dict()
    d["compile_s"] = prod_compile_s
    d["knobs"] = knobs
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{arch}__{shape_name}__{mesh_name}.json").write_text(
        json.dumps(d, indent=2))
    print(f"[{arch} {shape_name} {mesh_name}] roofline: "
          f"compute={report.compute_s*1e3:.2f}ms memory={report.memory_s*1e3:.2f}ms "
          f"collective={report.collective_s*1e3:.2f}ms "
          f"bottleneck={report.bottleneck} "
          f"fraction={report.roofline_fraction:.3f} ({flops_source})")
    return d


def summarize(out_dir: pathlib.Path) -> str:
    rows = []
    for f in sorted(out_dir.glob("*.json")):
        rows.append(json.loads(f.read_text()))
    lines = ["| arch | shape | mesh | compute(ms) | memory(ms) | coll(ms) | "
             "bottleneck | useful | roofline-frac | GiB/dev |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        gib = (r["bytes_per_device"]["args"] + r["bytes_per_device"]["temp"]
               + r["bytes_per_device"]["out"]) / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} | "
            f"{r['collective_s']*1e3:.2f} | {r['bottleneck']} | "
            f"{r['usefulness']:.2f} | {r['roofline_fraction']:.3f} | "
            f"{gib:.1f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(RESULTS_DIR))
    ap.add_argument("--skip-cost", action="store_true")
    ap.add_argument("--summarize", action="store_true")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)

    if args.summarize:
        print(summarize(out_dir))
        return

    archs = ARCH_NAMES if args.arch == "all" else (args.arch,)
    shapes = SHAPE_NAMES if args.shape == "all" else (args.shape,)
    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            if not cfg.supports_shape(shape_name):
                print(f"[{arch} {shape_name}] SKIP (long_500k needs "
                      "sub-quadratic attention; see DESIGN.md)")
                continue
            for multi_pod in meshes:
                # roofline table is single-pod; multi-pod proves the pod axis
                try:
                    run_cell(arch, shape_name, multi_pod, out_dir,
                             skip_cost=args.skip_cost or multi_pod)
                except Exception:
                    failures.append((arch, shape_name, multi_pod))
                    traceback.print_exc()
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete: all cells lowered + compiled")


if __name__ == "__main__":
    main()
