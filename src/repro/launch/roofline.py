"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), in seconds:

  compute    = FLOPs            / (chips * 197e12   bf16 FLOP/s)
  memory     = HBM bytes        / (chips * 819e9    B/s)
  collective = collective bytes / (chips * 50e9     B/s ICI link)

FLOPs/bytes come from ``compiled.cost_analysis()``.  XLA's HloCostAnalysis
counts a ``while`` body ONCE, so the production (layer-scanned) graph
undercounts by ~the repeat count; the dry-run therefore lowers a second,
fully-unrolled cost graph where cost_analysis is exact (with a scan-corrected
fallback when unrolling is too large to compile).  Collective bytes are
parsed from the HLO text with while-trip multiplicity applied.

MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params — the
"useful compute" numerator for the usefulness ratio.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e)
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# wire-traffic factor per participant relative to the full buffer size
_WIRE_FACTOR = {
    "all-gather": 1.0,        # (n-1)/n ~ 1 of the gathered buffer
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s*"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text."""
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->", stripped)
        if m and stripped.endswith("{"):
            cur = m.group(1)
            comps[cur] = []
        elif stripped == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


def _while_multiplicity(hlo: str, comps: Dict[str, str]) -> Dict[str, float]:
    """computation name -> product of enclosing while trip counts."""
    # find while instructions: body=%b, condition=%c
    parents: Dict[str, list] = {}
    for comp_name, body in comps.items():
        for m in re.finditer(r"while\([^)]*\).*?condition=%?([\w.\-]+),\s*"
                             r"body=%?([\w.\-]+)", body):
            cond, wbody = m.group(1), m.group(2)
            trips = _trip_count(comps.get(cond, ""))
            parents.setdefault(wbody, []).append((comp_name, trips))

    mult: Dict[str, float] = {}

    def resolve(name: str, seen=()) -> float:
        if name in mult:
            return mult[name]
        if name in seen:
            return 1.0
        best = 1.0
        for parent, trips in parents.get(name, []):
            best = max(best, trips * resolve(parent, seen + (name,)))
        if name not in parents:
            best = 1.0
        mult[name] = best
        return best

    for name in comps:
        resolve(name)
    return mult


def _trip_count(cond_body: str) -> float:
    consts = [int(x) for x in re.findall(r"constant\((\d+)\)", cond_body)]
    return float(max(consts)) if consts else 1.0


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Total wire bytes per collective kind, while-multiplicity-aware."""
    comps = _split_computations(hlo)
    mult = _while_multiplicity(hlo, comps)
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for comp_name, body in comps.items():
        m = mult.get(comp_name, 1.0)
        for line in body.splitlines():
            im = _INSTR_RE.search(line)
            if not im:
                continue
            op = im.group(3).replace("-start", "")
            shape_bytes = _shape_bytes(im.group(2))
            out[op] += shape_bytes * _WIRE_FACTOR[op] * m
    return out


@dataclass
class RooflineReport:
    """All hlo_* quantities are PER-DEVICE (XLA cost analysis and the
    partitioned HLO text both describe one participant); ``model_flops`` is
    global and divided by ``chips`` where compared.  The roofline terms are
    therefore  per-device work / per-chip bandwidth — identical to the
    global/(chips*bw) formulation."""
    arch: str
    shape: str
    mesh: str
    chips: int
    model_flops: float                 # analytic useful FLOPs / step (global)
    hlo_flops: float                   # per-device, exact (unrolled) or corrected
    hlo_bytes: float
    coll_bytes: Dict[str, float]
    bytes_per_device: Dict[str, float]
    flops_source: str = "unrolled"

    analytic_bytes_dev: float = 0.0    # analytic HBM-traffic floor / device

    @property
    def coll_bytes_total(self) -> float:
        return sum(self.coll_bytes.values())

    @property
    def compute_s(self) -> float:
        # HLO flops floor-corrected by the analytic model: inner sequence
        # scans (flash attention chunks, mamba chunks) are while loops that
        # cost_analysis counts once, so the analytic count is a hard floor.
        return max(self.hlo_flops, self.model_flops / self.chips) / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def analytic_memory_s(self) -> float:
        """Analytic HBM-traffic floor (params/cache/activations once each).
        The gap memory_s / analytic_memory_s is the memory-waste factor the
        §Perf iterations drive down (HLO 'bytes accessed' also over-counts
        fused intermediates; both numbers are reported)."""
        return self.analytic_bytes_dev / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_total / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def usefulness(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return (self.model_flops / self.chips) / self.hlo_flops \
            if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / max(term) — fraction of roofline achieved."""
        peak = self.model_flops / self.chips / PEAK_FLOPS
        denom = max(self.compute_s, self.memory_s, self.collective_s)
        return peak / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "model_flops": self.model_flops,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_bytes_total": self.coll_bytes_total,
            "bytes_per_device": self.bytes_per_device,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "analytic_memory_s": self.analytic_memory_s,
            "bottleneck": self.bottleneck, "usefulness": self.usefulness,
            "roofline_fraction": self.roofline_fraction,
            "flops_source": self.flops_source,
        }


def analytic_bytes(cfg, shape, chips: int, microbatches: int = 1) -> float:
    """Per-device analytic HBM traffic per step (a floor, not a fit):

    train:   params fwd read + bwd read (x microbatches, FSDP regather) +
             grads + optimizer m/v read+write + activation carry rw
    prefill: params once + activations (~12 bytes/token/d_model/layer) + KV write
    decode:  params once + full KV/state cache read + write of one slot
    """
    n_params = cfg.param_count()
    p_bytes = 2.0 * n_params / chips                     # bf16 shard
    d = cfg.d_model
    L = cfg.num_layers
    attn_layers = sum(k in ("attn", "attn_moe", "xattn")
                      for k in cfg.block_pattern) * cfg.pattern_repeats
    kv_per_tok = 2 * cfg.kv_dim * 2 * attn_layers        # bytes, bf16

    if shape.kind == "train":
        tokens_dev = shape.tokens / chips
        act = 12.0 * tokens_dev * d * L * 2 / 16         # remat carry + block io (SP/16)
        opt = 4.0 * 2 * n_params / chips * 2             # m,v f32 read+write
        grads = 4.0 * n_params / chips
        return p_bytes * (2 * microbatches) + grads + opt + act
    if shape.kind == "prefill":
        tokens_dev = shape.tokens / chips
        act = 12.0 * tokens_dev * d * L
        kv = kv_per_tok * shape.tokens / chips
        return p_bytes + act + kv
    # decode
    kv_read = kv_per_tok * shape.seq_len * shape.global_batch / chips
    state = 0.0
    for k in cfg.block_pattern:
        if k in ("mamba", "mamba_moe"):
            state += 4 * cfg.d_inner * cfg.ssm_state_dim
        if k == "mlstm":
            state += 4 * cfg.num_heads * cfg.head_dim ** 2
        if k == "slstm":
            state += 4 * 4 * cfg.attn_dim
    state_read = 2 * state * cfg.pattern_repeats * shape.global_batch / chips
    act = 12.0 * shape.global_batch * d * L / chips
    n_active = cfg.active_param_count()
    return 2.0 * n_active / chips + kv_read + state_read + act


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs per step: 6*N_active*tokens (train),
    2*N_active*tokens (prefill), 2*N_active*batch (decode, per token) plus
    attention KV-cache reading for decode."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        # + attention score/value FLOPs: 2 * 2 * H*hd * S^2/2 * B per attn layer
        attn_layers = sum(k in ("attn", "attn_moe", "xattn")
                          for k in cfg.block_pattern) * cfg.pattern_repeats
        attn = 2.0 * cfg.attn_dim * shape.seq_len ** 2 * shape.global_batch \
            * attn_layers
        return 2.0 * n_active * shape.tokens + attn
    # decode: one token for the whole batch
    attn_layers = sum(k in ("attn", "attn_moe")
                      for k in cfg.block_pattern) * cfg.pattern_repeats
    attn = 4.0 * cfg.attn_dim * shape.seq_len * shape.global_batch * attn_layers
    return 2.0 * n_active * shape.global_batch + attn
