"""From-scratch histogram gradient-boosted trees (the Clairvoyant predictor).

The paper trains an XGBoost classifier (3-class softmax objective, 300
estimators, max_depth 6, lr 0.1, seed 42) and exports it to ONNX.  Neither
xgboost nor onnxruntime exist in this offline container — and the framework
mandate is to build every substrate — so this module implements the same
model class from scratch:

* second-order boosting (gradient + hessian) with the multi-class softmax
  objective (one tree per class per round, exactly XGBoost's ``multi:softprob``
  layout);
* histogram split finding (features pre-binned to <=256 bins) with the
  standard gain  0.5 * (GL^2/(HL+l) + GR^2/(HR+l) - G^2/(H+l));
* L2 leaf regularisation, min-child-weight pruning, learning-rate shrinkage.

Trained models export to dense "ensemble tensors" — complete-binary-tree
arrays — which are what the jnp reference (kernels/ref.py) and the Pallas
batched-inference kernel (kernels/gbdt_infer.py) consume.  The numpy batch
path below is the host-side admission path (the 0.029 ms analogue).
"""

from __future__ import annotations

import dataclasses
import pickle
from dataclasses import dataclass

import numpy as np

MAX_BINS = 256


@dataclass
class GBDTParams:
    num_rounds: int = 300
    max_depth: int = 6
    learning_rate: float = 0.1
    reg_lambda: float = 1.0
    min_child_weight: float = 1.0
    gamma: float = 0.0
    n_classes: int = 3
    seed: int = 42
    subsample: float = 1.0


@dataclass
class GBDTModel:
    """Dense complete-binary-tree ensemble.

    All arrays have leading dim T = num_rounds * n_classes (tree t belongs to
    class ``t % n_classes``) and node dim N = 2**(max_depth+1) - 1 in
    breadth-first layout (children of i at 2i+1 / 2i+2).  ``feature[i] < 0``
    marks a leaf; traversal goes left iff x[feature] < threshold.
    """

    feature: np.ndarray    # (T, N) int32, -1 for leaf / dead node
    threshold: np.ndarray  # (T, N) float32
    value: np.ndarray      # (T, N) float32 (leaf contribution)
    n_classes: int
    max_depth: int
    base_score: float = 0.0

    @property
    def num_trees(self) -> int:
        return self.feature.shape[0]

    def predict_margin(self, X: np.ndarray) -> np.ndarray:
        """(B, n_classes) raw margins; vectorised level-by-level traversal."""
        X = np.asarray(X, np.float32)
        B = X.shape[0]
        T, N = self.feature.shape
        margins = np.full((B, self.n_classes), self.base_score, np.float32)
        # node index per (tree, sample)
        idx = np.zeros((T, B), np.int32)
        for _ in range(self.max_depth):
            feat = self.feature[np.arange(T)[:, None], idx]      # (T, B)
            thr = self.threshold[np.arange(T)[:, None], idx]
            is_leaf = feat < 0
            f = np.maximum(feat, 0)
            go_left = X[np.arange(B)[None, :], f] < thr
            nxt = np.where(go_left, 2 * idx + 1, 2 * idx + 2)
            idx = np.where(is_leaf, idx, nxt)
        vals = self.value[np.arange(T)[:, None], idx]            # (T, B)
        for c in range(self.n_classes):
            margins[:, c] += vals[c::self.n_classes].sum(axis=0)
        return margins

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        m = self.predict_margin(X)
        m = m - m.max(axis=1, keepdims=True)
        e = np.exp(m)
        return e / e.sum(axis=1, keepdims=True)

    def predict_p_long(self, X: np.ndarray, long_class: int = 2) -> np.ndarray:
        """The scheduler's priority key."""
        return self.predict_proba(X)[:, long_class]

    def save(self, path: str) -> None:
        with open(path, "wb") as f:
            pickle.dump(dataclasses.asdict(self), f)

    @classmethod
    def load(cls, path: str) -> "GBDTModel":
        with open(path, "rb") as f:
            d = pickle.load(f)
        return cls(**d)


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

def _bin_features(X: np.ndarray):
    """Pre-bin features; returns (binned uint8 (B,F), thresholds list[F])."""
    B, F = X.shape
    binned = np.zeros((B, F), np.uint8)
    thresholds = []
    for f in range(F):
        vals = np.unique(X[:, f])
        if len(vals) > MAX_BINS:
            qs = np.quantile(X[:, f], np.linspace(0, 1, MAX_BINS + 1)[1:-1])
            edges = np.unique(qs)
        else:
            edges = (vals[:-1] + vals[1:]) / 2.0  # midpoints between uniques
        thresholds.append(edges.astype(np.float32))
        binned[:, f] = np.searchsorted(edges, X[:, f], side="right")
    return binned, thresholds


def _softmax(m):
    m = m - m.max(axis=1, keepdims=True)
    e = np.exp(m)
    return e / e.sum(axis=1, keepdims=True)


def train_gbdt(X: np.ndarray, y: np.ndarray,
               params: GBDTParams | None = None) -> GBDTModel:
    """Fit the boosted ensemble.  X: (B, F) float; y: (B,) int class labels."""
    p = params or GBDTParams()
    rng = np.random.default_rng(p.seed)
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.int64)
    B, F = X.shape
    K = p.n_classes
    N = 2 ** (p.max_depth + 1) - 1
    T = p.num_rounds * K

    binned, thresholds = _bin_features(X)
    nbins = max(len(t) + 1 for t in thresholds) if thresholds else 1
    y_onehot = np.eye(K, dtype=np.float32)[y]

    feature = np.full((T, N), -1, np.int32)
    threshold = np.zeros((T, N), np.float32)
    value = np.zeros((T, N), np.float32)

    margins = np.zeros((B, K), np.float32)

    t = 0
    for _round in range(p.num_rounds):
        probs = _softmax(margins)
        G_all = probs - y_onehot                     # (B, K)
        H_all = np.maximum(probs * (1.0 - probs), 1e-6)
        if p.subsample < 1.0:
            mask = rng.random(B) < p.subsample
        else:
            mask = None
        for k in range(K):
            g, h = G_all[:, k].copy(), H_all[:, k].copy()
            if mask is not None:
                g, h = g * mask, h * mask
            _build_tree(binned, thresholds, g, h, p,
                        feature[t], threshold[t], value[t])
            margins[:, k] += _eval_tree_binned(
                binned, thresholds, feature[t], threshold[t], value[t], X)
            t += 1

    return GBDTModel(feature=feature, threshold=threshold, value=value,
                     n_classes=K, max_depth=p.max_depth)


def _eval_tree_binned(binned, thresholds, feature, threshold, value, X):
    B = X.shape[0]
    idx = np.zeros(B, np.int32)
    depth = int(np.log2(feature.shape[0] + 1)) - 1
    for _ in range(depth):
        feat = feature[idx]
        leaf = feat < 0
        f = np.maximum(feat, 0)
        go_left = X[np.arange(B), f] < threshold[idx]
        nxt = np.where(go_left, 2 * idx + 1, 2 * idx + 2)
        idx = np.where(leaf, idx, nxt)
    return value[idx]


def _build_tree(binned, thresholds, g, h, p: GBDTParams,
                feature_out, threshold_out, value_out):
    """Grow one depth-wise tree in place (breadth-first array layout)."""
    B, F = binned.shape
    lam = p.reg_lambda
    # joint (feature, bin) keys so one bincount builds the whole histogram
    keys_full = (binned.astype(np.int32)
                 + np.arange(F, dtype=np.int32)[None, :] * MAX_BINS)
    active = {0: np.arange(B)}

    def leaf_weight(gs, hs):
        return float(-p.learning_rate * gs / (hs + lam))

    for depth in range(p.max_depth + 1):
        next_active = {}
        for node, idx in active.items():
            gs, hs = float(g[idx].sum()), float(h[idx].sum())
            value_out[node] = leaf_weight(gs, hs)
            if depth == p.max_depth or len(idx) < 2 or hs < 2 * p.min_child_weight:
                continue  # stays leaf (feature_out[node] == -1)
            # histogram over (feature, bin) via one flat bincount each
            keys = keys_full[idx].ravel()
            Gh = np.bincount(keys, weights=np.repeat(g[idx], F),
                             minlength=F * MAX_BINS).reshape(F, MAX_BINS)
            Hh = np.bincount(keys, weights=np.repeat(h[idx], F),
                             minlength=F * MAX_BINS).reshape(F, MAX_BINS)
            GL = np.cumsum(Gh, axis=1)[:, :-1]            # left of each edge
            HL = np.cumsum(Hh, axis=1)[:, :-1]
            GR, HR = gs - GL, hs - HL
            valid = (HL >= p.min_child_weight) & (HR >= p.min_child_weight)
            gain = 0.5 * (GL ** 2 / (HL + lam) + GR ** 2 / (HR + lam)
                          - gs ** 2 / (hs + lam)) - p.gamma
            gain = np.where(valid, gain, -np.inf)
            # mask bins beyond each feature's threshold count
            for f in range(F):
                gain[f, len(thresholds[f]):] = -np.inf
            best = np.unravel_index(np.argmax(gain), gain.shape)
            if not np.isfinite(gain[best]) or gain[best] <= 0:
                continue
            f_best, b_best = int(best[0]), int(best[1])
            feature_out[node] = f_best
            threshold_out[node] = thresholds[f_best][b_best]
            go_left = binned[idx, f_best] <= b_best
            li, ri = idx[go_left], idx[~go_left]
            next_active[2 * node + 1] = li
            next_active[2 * node + 2] = ri
        active = next_active
        if not active:
            break
