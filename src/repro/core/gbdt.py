"""From-scratch histogram gradient-boosted trees (the Clairvoyant predictor).

The paper trains an XGBoost classifier (3-class softmax objective, 300
estimators, max_depth 6, lr 0.1, seed 42) and exports it to ONNX.  Neither
xgboost nor onnxruntime exist in this offline container — and the framework
mandate is to build every substrate — so this module implements the same
model class from scratch:

* second-order boosting (gradient + hessian) with the multi-class softmax
  objective (one tree per class per round, exactly XGBoost's ``multi:softprob``
  layout);
* histogram split finding (features pre-binned to <=256 bins) with the
  standard gain  0.5 * (GL^2/(HL+l) + GR^2/(HR+l) - G^2/(H+l));
* L2 leaf regularisation, min-child-weight pruning, learning-rate shrinkage.

The trainer works depth-by-depth over *all* frontier nodes at once:

* one flat ``bincount`` per depth builds the histograms of every node at
  that depth (node-compact x feature x bin keys), instead of one bincount
  pair per node;
* the **histogram-subtraction trick**: only the smaller child of each
  split is binned — the sibling histogram is ``parent - small`` — halving
  the bincount rows below the root;
* gradient/hessian weight duplication (``np.repeat``) happens once per
  depth on the binned half, not once per node on every row;
* split gains for the whole depth frontier are scored with one vectorized
  ``(nodes, F, bins)`` pass;
* each round's margin update reuses the sample->leaf routing computed
  during growth — no post-hoc tree traversal.

Trained models export to dense "ensemble tensors" — complete-binary-tree
arrays — which are what the jnp reference (kernels/ref.py) and the Pallas
batched-inference kernel (kernels/gbdt_infer.py) consume.  Admission-path
inference goes through the pruned SoA fast path in
``repro.core.ensemble_pack`` (``predict_margin``); the seed's dense
level-by-level traversal is kept as ``predict_margin_dense`` — the
equivalence oracle and the "old" side of the predictor benchmark.
"""

from __future__ import annotations

import dataclasses
import pickle
from dataclasses import dataclass

import numpy as np

MAX_BINS = 256


@dataclass
class GBDTParams:
    num_rounds: int = 300
    max_depth: int = 6
    learning_rate: float = 0.1
    reg_lambda: float = 1.0
    min_child_weight: float = 1.0
    gamma: float = 0.0
    n_classes: int = 3
    seed: int = 42
    subsample: float = 1.0


@dataclass
class GBDTModel:
    """Dense complete-binary-tree ensemble.

    All arrays have leading dim T = num_rounds * n_classes (tree t belongs to
    class ``t % n_classes``) and node dim N = 2**(max_depth+1) - 1 in
    breadth-first layout (children of i at 2i+1 / 2i+2).  ``feature[i] < 0``
    marks a leaf; traversal goes left iff x[feature] < threshold.
    """

    feature: np.ndarray    # (T, N) int32, -1 for leaf / dead node
    threshold: np.ndarray  # (T, N) float32
    value: np.ndarray      # (T, N) float32 (leaf contribution)
    n_classes: int
    max_depth: int
    base_score: float = 0.0

    @property
    def num_trees(self) -> int:
        return self.feature.shape[0]

    def packed(self, rebuild: bool = False):
        """Pruned/binned SoA export (cached; see ensemble_pack).

        The cache is keyed on identity only — call ``packed(rebuild=True)``
        after mutating the ensemble tensors in place.
        """
        cached = self.__dict__.get("_packed")
        if cached is None or rebuild:
            from repro.core.ensemble_pack import pack_ensemble
            cached = pack_ensemble(self)
            self.__dict__["_packed"] = cached
        return cached

    def predict_margin(self, X: np.ndarray) -> np.ndarray:
        """(B, n_classes) raw margins via the packed fast path."""
        return self.packed().predict_margin(X)

    def predict_margin_dense(self, X: np.ndarray) -> np.ndarray:
        """Seed implementation: vectorised level-by-level dense traversal."""
        X = np.asarray(X, np.float32)
        B = X.shape[0]
        T, N = self.feature.shape
        margins = np.full((B, self.n_classes), self.base_score, np.float32)
        # node index per (tree, sample)
        idx = np.zeros((T, B), np.int32)
        for _ in range(self.max_depth):
            feat = self.feature[np.arange(T)[:, None], idx]      # (T, B)
            thr = self.threshold[np.arange(T)[:, None], idx]
            is_leaf = feat < 0
            f = np.maximum(feat, 0)
            go_left = X[np.arange(B)[None, :], f] < thr
            nxt = np.where(go_left, 2 * idx + 1, 2 * idx + 2)
            idx = np.where(is_leaf, idx, nxt)
        vals = self.value[np.arange(T)[:, None], idx]            # (T, B)
        for c in range(self.n_classes):
            margins[:, c] += vals[c::self.n_classes].sum(axis=0)
        return margins

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        m = self.predict_margin(X)
        m = m - m.max(axis=1, keepdims=True)
        e = np.exp(m)
        return e / e.sum(axis=1, keepdims=True)

    def predict_p_long(self, X: np.ndarray, long_class: int = 2) -> np.ndarray:
        """The scheduler's priority key."""
        return self.predict_proba(X)[:, long_class]

    def save(self, path: str) -> None:
        with open(path, "wb") as f:
            pickle.dump({fl.name: getattr(self, fl.name)
                         for fl in dataclasses.fields(self)}, f)

    @classmethod
    def load(cls, path: str) -> "GBDTModel":
        with open(path, "rb") as f:
            d = pickle.load(f)
        return cls(**d)


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

def _bin_features(X: np.ndarray):
    """Pre-bin features; returns (binned uint8 (B,F), thresholds list[F])."""
    B, F = X.shape
    binned = np.zeros((B, F), np.uint8)
    thresholds = []
    for f in range(F):
        vals = np.unique(X[:, f])
        if len(vals) > MAX_BINS:
            qs = np.quantile(X[:, f], np.linspace(0, 1, MAX_BINS + 1)[1:-1])
            edges = np.unique(qs)
        else:
            edges = (vals[:-1] + vals[1:]) / 2.0  # midpoints between uniques
        thresholds.append(edges.astype(np.float32))
        binned[:, f] = np.searchsorted(edges, X[:, f], side="right")
    return binned, thresholds


def _softmax(m):
    m = m - m.max(axis=1, keepdims=True)
    e = np.exp(m)
    return e / e.sum(axis=1, keepdims=True)


def train_gbdt(X: np.ndarray, y: np.ndarray,
               params: GBDTParams | None = None) -> GBDTModel:
    """Fit the boosted ensemble.  X: (B, F) float; y: (B,) int class labels."""
    p = params or GBDTParams()
    rng = np.random.default_rng(p.seed)
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.int64)
    B, F = X.shape
    K = p.n_classes
    N = 2 ** (p.max_depth + 1) - 1
    T = p.num_rounds * K

    binned, thresholds = _bin_features(X)
    y_onehot = np.eye(K, dtype=np.float32)[y]

    feature = np.full((T, N), -1, np.int32)
    threshold = np.zeros((T, N), np.float32)
    value = np.zeros((T, N), np.float32)

    margins = np.zeros((B, K), np.float32)

    # Invariants hoisted out of the per-tree loop.  The histogram axis is
    # *compact*: feature f owns len(thresholds[f])+1 adjacent columns (its
    # real bin count), not a fixed MAX_BINS stripe — for the 19 mostly
    # boolean/low-cardinality Clairvoyant features this shrinks every
    # histogram, cumsum, and gain pass by an order of magnitude.
    nb = np.asarray([len(th) + 1 for th in thresholds], np.int32)
    off = np.zeros(F, np.int32)
    np.cumsum(nb[:-1], out=off[1:])
    layout = _BinLayout(
        off=off,
        total=int(nb.sum()),
        col2f=np.repeat(np.arange(F, dtype=np.int32), nb),
        col2b=np.concatenate([np.arange(n, dtype=np.int32) for n in nb]),
        basecol=np.repeat(off, nb).astype(np.intp),
        valid=np.concatenate([(np.arange(n) < n - 1) for n in nb]),
    )
    keys = binned.astype(np.int32) + off[None, :]            # (B, F)

    t = 0
    for _round in range(p.num_rounds):
        probs = _softmax(margins)
        G_all = probs - y_onehot                     # (B, K)
        H_all = np.maximum(probs * (1.0 - probs), 1e-6)
        if p.subsample < 1.0:
            mask = rng.random(B) < p.subsample
        else:
            mask = None
        for k in range(K):
            g, h = G_all[:, k], H_all[:, k]
            if mask is not None:
                g, h = g * mask, h * mask
            leaf = _build_tree(binned, thresholds, keys, layout, g, h, p,
                               feature[t], threshold[t], value[t])
            # routing computed during growth — no re-traversal
            margins[:, k] += value[t][leaf]
            t += 1

    return GBDTModel(feature=feature, threshold=threshold, value=value,
                     n_classes=K, max_depth=p.max_depth)


def _eval_tree_binned(binned, thresholds, feature, threshold, value, X):
    """Dense single-tree traversal (kept as an oracle for the trainer)."""
    B = X.shape[0]
    idx = np.zeros(B, np.int32)
    depth = int(np.log2(feature.shape[0] + 1)) - 1
    for _ in range(depth):
        feat = feature[idx]
        leaf = feat < 0
        f = np.maximum(feat, 0)
        go_left = X[np.arange(B), f] < threshold[idx]
        nxt = np.where(go_left, 2 * idx + 1, 2 * idx + 2)
        idx = np.where(leaf, idx, nxt)
    return value[idx]


# ---------------------------------------------------------------------------
# Reference (seed) trainer — per-node histograms, full re-traversal per
# round.  Kept as the "old" side of benchmarks/predictor_latency.py.
# ---------------------------------------------------------------------------

def train_gbdt_reference(X: np.ndarray, y: np.ndarray,
                         params: GBDTParams | None = None) -> GBDTModel:
    """Seed implementation of :func:`train_gbdt` (slow; benchmark baseline)."""
    p = params or GBDTParams()
    rng = np.random.default_rng(p.seed)
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.int64)
    B, F = X.shape
    K = p.n_classes
    N = 2 ** (p.max_depth + 1) - 1
    T = p.num_rounds * K

    binned, thresholds = _bin_features(X)
    y_onehot = np.eye(K, dtype=np.float32)[y]

    feature = np.full((T, N), -1, np.int32)
    threshold = np.zeros((T, N), np.float32)
    value = np.zeros((T, N), np.float32)
    margins = np.zeros((B, K), np.float32)

    t = 0
    for _round in range(p.num_rounds):
        probs = _softmax(margins)
        G_all = probs - y_onehot
        H_all = np.maximum(probs * (1.0 - probs), 1e-6)
        if p.subsample < 1.0:
            mask = rng.random(B) < p.subsample
        else:
            mask = None
        for k in range(K):
            g, h = G_all[:, k].copy(), H_all[:, k].copy()
            if mask is not None:
                g, h = g * mask, h * mask
            _build_tree_reference(binned, thresholds, g, h, p,
                                  feature[t], threshold[t], value[t])
            margins[:, k] += _eval_tree_binned(
                binned, thresholds, feature[t], threshold[t], value[t], X)
            t += 1

    return GBDTModel(feature=feature, threshold=threshold, value=value,
                     n_classes=K, max_depth=p.max_depth)


def _build_tree_reference(binned, thresholds, g, h, p: GBDTParams,
                          feature_out, threshold_out, value_out):
    """Seed tree grower: one histogram pair per node, per-node np.repeat."""
    B, F = binned.shape
    lam = p.reg_lambda
    keys_full = (binned.astype(np.int32)
                 + np.arange(F, dtype=np.int32)[None, :] * MAX_BINS)
    active = {0: np.arange(B)}

    def leaf_weight(gs, hs):
        return float(-p.learning_rate * gs / (hs + lam))

    for depth in range(p.max_depth + 1):
        next_active = {}
        for node, idx in active.items():
            gs, hs = float(g[idx].sum()), float(h[idx].sum())
            value_out[node] = leaf_weight(gs, hs)
            if depth == p.max_depth or len(idx) < 2 \
                    or hs < 2 * p.min_child_weight:
                continue  # stays leaf (feature_out[node] == -1)
            keys = keys_full[idx].ravel()
            Gh = np.bincount(keys, weights=np.repeat(g[idx], F),
                             minlength=F * MAX_BINS).reshape(F, MAX_BINS)
            Hh = np.bincount(keys, weights=np.repeat(h[idx], F),
                             minlength=F * MAX_BINS).reshape(F, MAX_BINS)
            GL = np.cumsum(Gh, axis=1)[:, :-1]
            HL = np.cumsum(Hh, axis=1)[:, :-1]
            GR, HR = gs - GL, hs - HL
            valid = (HL >= p.min_child_weight) & (HR >= p.min_child_weight)
            with np.errstate(divide="ignore", invalid="ignore"):
                gain = 0.5 * (GL ** 2 / (HL + lam) + GR ** 2 / (HR + lam)
                              - gs ** 2 / (hs + lam)) - p.gamma
            gain = np.where(valid, gain, -np.inf)
            for f in range(F):
                gain[f, len(thresholds[f]):] = -np.inf
            best = np.unravel_index(np.argmax(gain), gain.shape)
            if not np.isfinite(gain[best]) or gain[best] <= 0:
                continue
            f_best, b_best = int(best[0]), int(best[1])
            feature_out[node] = f_best
            threshold_out[node] = thresholds[f_best][b_best]
            go_left = binned[idx, f_best] <= b_best
            next_active[2 * node + 1] = idx[go_left]
            next_active[2 * node + 2] = idx[~go_left]
        active = next_active
        if not active:
            break


@dataclass
class _BinLayout:
    """Compact histogram axis: feature f owns columns off[f]..off[f]+nb-1."""
    off: np.ndarray       # (F,) first column of each feature
    total: int            # total histogram columns
    col2f: np.ndarray     # (total,) owning feature of each column
    col2b: np.ndarray     # (total,) local bin of each column
    basecol: np.ndarray   # (total,) off[col2f], for segmented cumsum
    valid: np.ndarray     # (total,) bool, splittable columns


def _depth_hist(keys, layout, comp_of_row, rows, g, h, n_nodes):
    """Histograms for ``n_nodes`` compact node ids over ``rows`` in one
    bincount pair.  Returns (G, H) of shape (n_nodes, total_cols)."""
    F = keys.shape[1]
    stride = layout.total
    ck = (keys[rows] + (comp_of_row * stride)[:, None]).ravel()
    wg = np.repeat(g[rows], F)
    wh = np.repeat(h[rows], F)
    Gh = np.bincount(ck, weights=wg, minlength=n_nodes * stride)
    Hh = np.bincount(ck, weights=wh, minlength=n_nodes * stride)
    return Gh.reshape(n_nodes, stride), Hh.reshape(n_nodes, stride)


def _seg_cumsum(H, layout):
    """Within-feature prefix sums over the compact column axis."""
    csp = np.zeros((H.shape[0], layout.total + 1), H.dtype)
    np.cumsum(H, axis=1, out=csp[:, 1:])
    return csp[:, 1:] - csp[:, layout.basecol]


def _build_tree(binned, thresholds, keys, layout, g, h, p: GBDTParams,
                feature_out, threshold_out, value_out):
    """Grow one depth-wise tree in place; returns each sample's leaf slot.

    Per depth: score every frontier node's splits in one vectorized
    ``(nodes, total_bins)`` pass, route samples of splitting nodes, then
    bin only the smaller child of each split (sibling = parent - small).

    Sibling subtraction accumulates ~1e-6 relative float drift in the
    derived histograms, so near-tied split gains can resolve differently
    than in ``_build_tree_reference`` — the two trainers produce
    equal-quality but not structurally identical ensembles (most visibly
    with ``subsample < 1``).  Determinism for a fixed seed is unaffected.
    """
    B, F = binned.shape
    lam, lr, mcw = p.reg_lambda, p.learning_rate, p.min_child_weight
    N = feature_out.shape[0]
    nb0 = int(layout.off[1]) if F > 1 else layout.total

    node = np.zeros(B, np.int32)          # current slot per sample
    active = np.ones(B, bool)             # rows not yet settled at a leaf
    all_rows = np.arange(B)

    slots = np.zeros(1, np.int64)         # frontier node slots at this depth
    Gh, Hh = _depth_hist(keys, layout, np.zeros(B, np.int64), all_rows,
                         g, h, 1)
    counts = np.asarray([B])

    for depth in range(p.max_depth + 1):
        n = slots.shape[0]
        gs = Gh[:, :nb0].sum(axis=1)                       # (n,) node totals
        hs = Hh[:, :nb0].sum(axis=1)
        value_out[slots] = -lr * gs / (hs + lam)
        can_split = (depth < p.max_depth) & (counts >= 2) & (hs >= 2 * mcw)
        if not can_split.any():
            break
        GL = _seg_cumsum(Gh, layout)                       # (n, total)
        HL = _seg_cumsum(Hh, layout)
        GR = gs[:, None] - GL
        HR = hs[:, None] - HL
        ok = (HL >= mcw) & (HR >= mcw) & layout.valid[None]
        with np.errstate(divide="ignore", invalid="ignore"):
            gain = 0.5 * (GL ** 2 / (HL + lam) + GR ** 2 / (HR + lam)
                          - (gs ** 2 / (hs + lam))[:, None]) - p.gamma
        gain = np.where(ok, gain, -np.inf)
        bidx = gain.argmax(axis=1)
        best = gain[np.arange(n), bidx]
        do = can_split & np.isfinite(best) & (best > 0)
        if not do.any():
            break
        f_best = layout.col2f[bidx]
        b_best = layout.col2b[bidx]
        sslots = slots[do]
        feature_out[sslots] = f_best[do]
        threshold_out[sslots] = [thresholds[f][b]
                                 for f, b in zip(f_best[do], b_best[do])]

        # route the rows of splitting nodes; everyone else settles
        sf = np.full(N, -1, np.int32)
        sb = np.zeros(N, np.int32)
        sf[sslots] = f_best[do]
        sb[sslots] = b_best[do]
        rows = all_rows[active]
        nf = sf[node[rows]]
        splitting = nf >= 0
        active[rows[~splitting]] = False
        rows = rows[splitting]
        nf = nf[splitting]
        go_left = binned[rows, nf] <= sb[node[rows]]
        node[rows] = 2 * node[rows] + 2 - go_left

        # histogram subtraction: bin the smaller child, derive the sibling
        cnts = np.bincount(node[rows], minlength=N)
        lch = 2 * sslots + 1
        rch = 2 * sslots + 2
        left_small = cnts[lch] <= cnts[rch]
        small = np.where(left_small, lch, rch)
        big = np.where(left_small, rch, lch)
        comp = np.full(N, -1, np.int64)
        comp[small] = np.arange(small.shape[0])
        crow = comp[node[rows]]
        sel = crow >= 0
        Gh_s, Hh_s = _depth_hist(keys, layout, crow[sel], rows[sel], g, h,
                                 small.shape[0])
        Gh_b = Gh[do] - Gh_s
        Hh_b = Hh[do] - Hh_s
        slots = np.concatenate([small, big])
        Gh = np.concatenate([Gh_s, Gh_b])
        Hh = np.concatenate([Hh_s, Hh_b])
        counts = np.concatenate([cnts[small], cnts[big]])
    return node
