"""Clairvoyant core: the paper's contribution as a composable library.

features   — the 19 lexical features (§3.2)
gbdt       — from-scratch XGBoost-class boosted trees (§4.3)
predictor  — features + ensemble -> P(Long)
scheduler  — SJF indexed array-heap + starvation timeout (§3.4)
simulation — serial-backend DES, workload generators, P-K theory (§2.4, §5.5)
sim_fast   — SoA request batches + compiled/vectorized DES engines
sim_jax    — the same DES as a vmapped JAX scan (device replication axis)
sweep      — one-shot policy x tau x rho x seed grids over the DES
ranking    — ranking accuracy (Algorithm 1) + Table 7 baselines
calibration— tau = 3 x mu_short (§3.4)
router     — beyond-paper: predictive multi-replica placement
"""

from repro.core.features import FEATURE_NAMES, N_FEATURES, extract, extract_batch
from repro.core.gbdt import GBDTModel, GBDTParams, train_gbdt
from repro.core.predictor import Predictor
from repro.core.ranking import (classification_accuracy, class_labels,
                                ranking_accuracy)
from repro.core.scheduler import ArrayHeap, MinHeap, Request, SJFQueue
from repro.core.sim_fast import (BatchSimResult, RequestBatch,
                                 simulate_batch)
from repro.core.simulation import (ServiceDist, SimResult, burst_workload,
                                   poisson_workload, simulate,
                                   simulate_reference)
from repro.core.sweep import (SweepResult, run_grid, sweep_batches,
                              sweep_burst, sweep_poisson)

__all__ = [
    "FEATURE_NAMES", "N_FEATURES", "extract", "extract_batch",
    "GBDTModel", "GBDTParams", "train_gbdt", "Predictor",
    "classification_accuracy", "class_labels", "ranking_accuracy",
    "ArrayHeap", "MinHeap", "Request", "SJFQueue",
    "ServiceDist", "SimResult", "burst_workload", "poisson_workload",
    "simulate", "simulate_reference",
    "BatchSimResult", "RequestBatch", "simulate_batch",
    "SweepResult", "run_grid", "sweep_batches", "sweep_burst",
    "sweep_poisson",
]
