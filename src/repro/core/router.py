"""Cluster-level predictive routing (beyond-paper extension).

At fleet scale each model-parallel replica is a serial backend with its own
Clairvoyant admission queue.  The same P(Long) signal the paper uses for
*ordering* is used here for *placement*: join-shortest-predicted-work (JSPW)
— route each request to the replica with the least predicted outstanding
work, where predicted work is the expected service time under the predictor's
class posterior.  Falls back to join-shortest-queue when no predictor is
available.  Hedged dispatch re-enqueues requests from replicas that miss a
deadline (straggler mitigation on the serving path).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.scheduler import Request, SJFQueue


@dataclass
class ReplicaState:
    replica_id: int
    queue: SJFQueue
    busy_until: float = 0.0          # time the in-flight request finishes
    predicted_backlog: float = 0.0   # sum of predicted service of queued reqs
    healthy: bool = True


class PredictiveRouter:
    """JSPW router over N replica admission queues.

    ``policy`` is a registry name or :class:`repro.core.policy.Policy`
    instance; each replica queue resolves it through the policy layer, so
    the fleet can run any registered policy (including preemptive ones on
    backends that support eviction).
    """

    def __init__(self, n_replicas: int, policy="sjf",
                 tau: Optional[float] = None,
                 service_estimate=(2.0, 10.0, 30.0)):
        """service_estimate: expected service seconds per (short, med, long)."""
        self.replicas = [ReplicaState(i, SJFQueue(policy=policy, tau=tau))
                         for i in range(n_replicas)]
        self.service_estimate = np.asarray(service_estimate, float)
        self.stats = {"routed": 0, "hedged": 0, "failed_over": 0}

    def predicted_service(self, proba: np.ndarray) -> float:
        """E[service | predictor posterior]."""
        return float(np.dot(np.asarray(proba, float), self.service_estimate))

    def route(self, req: Request, proba: Optional[np.ndarray] = None,
              now: float = 0.0, exclude: Optional[int] = None,
              est: Optional[float] = None) -> int:
        """``est`` overrides the service estimate when the caller already
        knows it (hedging/failover re-routes of scored requests)."""
        if est is None:
            est = (self.predicted_service(proba) if proba is not None
                   else float(self.service_estimate.mean()))
        best, best_cost = None, float("inf")
        for r in self.replicas:
            if not r.healthy or r.replica_id == exclude:
                continue
            cost = max(r.busy_until - now, 0.0) + r.predicted_backlog + est
            if cost < best_cost:
                best, best_cost = r, cost
        if best is None:
            raise RuntimeError("no healthy replicas")
        req.meta["predicted_service"] = est
        req.meta["replica"] = best.replica_id
        best.queue.push(req)
        best.predicted_backlog += est
        self.stats["routed"] += 1
        return best.replica_id

    def hedge_overdue(self, now: float, deadline: float) -> List[Request]:
        """Hedged dispatch: re-route requests that missed their queue-wait
        deadline on a straggling replica.

        Any queued request whose wait exceeds ``deadline`` is cancelled
        from its queue and re-routed to the least-loaded *other* replica
        (straggler mitigation on the serving path).  Each request is
        hedged at most once (``meta["hedged"]``), so repeated sweeps
        cannot bounce a request between replicas forever.
        """
        if len([r for r in self.replicas if r.healthy]) < 2:
            return []
        moved: List[Request] = []
        for r in self.replicas:
            if not r.healthy:
                continue
            overdue = [req for req in r.queue.waiting()
                       if (now - req.arrival) > deadline
                       and not req.meta.get("hedged")]
            for req in overdue:
                r.queue.remove(req.req_id)
                est = req.meta.get("predicted_service") or None
                if est:
                    r.predicted_backlog = max(0.0,
                                              r.predicted_backlog - est)
                req.meta["hedged"] = True
                # carry the known estimate: re-routing must not replace a
                # scored request's prediction with the class-agnostic mean
                self.route(req, now=now, exclude=r.replica_id, est=est)
                self.stats["hedged"] += 1
                moved.append(req)
        return moved

    def on_dispatch(self, replica_id: int, req: Request, now: float,
                    service_estimate: Optional[float] = None) -> None:
        r = self.replicas[replica_id]
        est = service_estimate or req.meta.get("predicted_service", 0.0)
        r.predicted_backlog = max(0.0, r.predicted_backlog - est)
        r.busy_until = now + est

    def fail_replica(self, replica_id: int, now: float = 0.0) -> List[Request]:
        """Replica loss: drain its queue and re-route every queued request.

        Non-preemptive SJF makes replay trivial — nothing mid-flight is lost
        except the active request, which the engine re-enqueues at its head.
        """
        r = self.replicas[replica_id]
        r.healthy = False
        drained = []
        while True:
            req = r.queue.pop(now=now)
            if req is None:
                break
            drained.append(req)
        for req in drained:
            req.meta["failed_over"] = True
            self.route(req, now=now)
            self.stats["failed_over"] += 1
        return drained

    def queue_lengths(self) -> Dict[int, int]:
        return {r.replica_id: len(r.queue) for r in self.replicas}
