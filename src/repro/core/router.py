"""Cluster-level predictive routing (beyond-paper extension).

At fleet scale each model-parallel replica is a serial backend with its own
Clairvoyant admission queue.  The same P(Long) signal the paper uses for
*ordering* is used here for *placement*: join-shortest-predicted-work (JSPW)
— route each request to the replica with the least predicted outstanding
work, where predicted work is the expected service time under the predictor's
class posterior.  Falls back to join-shortest-queue when no predictor is
available.  Hedged dispatch re-enqueues requests from replicas that miss a
deadline (straggler mitigation on the serving path).

Robustness (PR 6): an optional per-replica circuit breaker
(serving/faults.py) feeds placement eligibility — engine failures
recorded via :meth:`PredictiveRouter.record_failure` trip the breaker
open after N consecutive failures, the replica stops receiving traffic
for its cooldown, then a single half-open probe re-admits it on success.
``ReplicaState.healthy`` stays the *manual* kill switch
(:meth:`fail_replica`); a replica takes traffic only when it is healthy
AND its breaker allows.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.scheduler import Request, SJFQueue
from repro.serving.faults import CircuitBreaker


@dataclass
class ReplicaState:
    replica_id: int
    queue: SJFQueue
    busy_until: float = 0.0          # time the in-flight request finishes
    predicted_backlog: float = 0.0   # sum of predicted service of queued reqs
    healthy: bool = True
    breaker: Optional[CircuitBreaker] = None


class PredictiveRouter:
    """JSPW router over N replica admission queues.

    ``policy`` is a registry name or :class:`repro.core.policy.Policy`
    instance; each replica queue resolves it through the policy layer, so
    the fleet can run any registered policy (including preemptive ones on
    backends that support eviction).
    """

    def __init__(self, n_replicas: int, policy="sjf",
                 tau: Optional[float] = None,
                 service_estimate=(2.0, 10.0, 30.0),
                 breaker: Optional[CircuitBreaker] = None):
        """service_estimate: expected service seconds per (short, med, long).
        ``breaker`` is a template circuit breaker cloned per replica
        (None disables automatic failure-driven eligibility)."""
        self.replicas = [
            ReplicaState(i, SJFQueue(policy=policy, tau=tau),
                         breaker=breaker.clone() if breaker else None)
            for i in range(n_replicas)]
        self.service_estimate = np.asarray(service_estimate, float)
        self.stats = {"routed": 0, "hedged": 0, "failed_over": 0,
                      "breaker_opens": 0, "breaker_probes": 0}
        # optional serving.observability.FlightRecorder: route decisions
        # become instant events on the chosen replica's trace track
        self.recorder = None

    def eligible(self, replica_id: int, now: float = 0.0) -> bool:
        """May this replica receive traffic?  ``healthy`` is the manual
        kill switch; the breaker adds automatic failure-driven gating.
        Pure check — the half-open probe slot is only committed when
        :meth:`route` actually places a request on the replica."""
        r = self.replicas[replica_id]
        return r.healthy and (r.breaker is None
                              or r.breaker.would_allow(now))

    def record_failure(self, replica_id: int, now: float) -> None:
        """An engine fault on this replica: feed the breaker (if any)."""
        r = self.replicas[replica_id]
        if r.breaker is not None:
            was_open = r.breaker.state == "open"
            r.breaker.record_failure(now)
            if r.breaker.state == "open" and not was_open:
                self.stats["breaker_opens"] += 1

    def record_success(self, replica_id: int, now: float = 0.0) -> None:
        r = self.replicas[replica_id]
        if r.breaker is not None:
            if r.breaker.state == "half_open":
                self.stats["breaker_probes"] += 1
            r.breaker.record_success(now)

    def predicted_service(self, proba: np.ndarray) -> float:
        """E[service | predictor posterior]."""
        return float(np.dot(np.asarray(proba, float), self.service_estimate))

    def route(self, req: Request, proba: Optional[np.ndarray] = None,
              now: float = 0.0, exclude: Optional[int] = None,
              est: Optional[float] = None) -> int:
        """``est`` overrides the service estimate when the caller already
        knows it (hedging/failover re-routes of scored requests)."""
        if est is None:
            est = (self.predicted_service(proba) if proba is not None
                   else float(self.service_estimate.mean()))
        best, best_cost = None, float("inf")
        for r in self.replicas:
            if r.replica_id == exclude \
                    or not self.eligible(r.replica_id, now):
                continue
            cost = max(r.busy_until - now, 0.0) + r.predicted_backlog + est
            if cost < best_cost:
                best, best_cost = r, cost
        if best is None:
            raise RuntimeError("no healthy replicas")
        if best.breaker is not None:
            best.breaker.allow(now)       # commit the half-open probe slot
        req.meta["predicted_service"] = est
        req.meta["replica"] = best.replica_id
        best.queue.push(req)
        best.predicted_backlog += est
        self.stats["routed"] += 1
        rec = self.recorder
        if rec is not None:
            rec.instant("route", req.req_id, now,
                        track=f"replica{best.replica_id}",
                        args={"replica": best.replica_id,
                              "est": round(est, 4),
                              "backlog": round(best.predicted_backlog, 4)})
        return best.replica_id

    def hedge_overdue(self, now: float, deadline: float) -> List[Request]:
        """Hedged dispatch: re-route requests that missed their queue-wait
        deadline on a straggling replica.

        Any queued request whose wait exceeds ``deadline`` is cancelled
        from its queue and re-routed to the least-loaded *other* replica
        (straggler mitigation on the serving path).  Each request is
        hedged at most once (``meta["hedged"]``), so repeated sweeps
        cannot bounce a request between replicas forever.
        """
        if len([r for r in self.replicas if r.healthy]) < 2:
            return []
        moved: List[Request] = []
        for r in self.replicas:
            if not r.healthy:
                continue
            overdue = [req for req in r.queue.waiting()
                       if (now - req.arrival) > deadline
                       and not req.meta.get("hedged")]
            for req in overdue:
                r.queue.remove(req.req_id)
                est = req.meta.get("predicted_service") or None
                if est:
                    r.predicted_backlog = max(0.0,
                                              r.predicted_backlog - est)
                req.meta["hedged"] = True
                # carry the known estimate: re-routing must not replace a
                # scored request's prediction with the class-agnostic mean
                self.route(req, now=now, exclude=r.replica_id, est=est)
                self.stats["hedged"] += 1
                moved.append(req)
        return moved

    def on_dispatch(self, replica_id: int, req: Request, now: float,
                    service_estimate: Optional[float] = None) -> None:
        r = self.replicas[replica_id]
        est = service_estimate or req.meta.get("predicted_service", 0.0)
        r.predicted_backlog = max(0.0, r.predicted_backlog - est)
        r.busy_until = now + est

    def release(self, replica_id: int, req: Request) -> None:
        """Release a request's predicted backlog without dispatching it
        (shed / terminal failure): the work will never run here."""
        r = self.replicas[replica_id]
        est = req.meta.get("predicted_service", 0.0)
        r.predicted_backlog = max(0.0, r.predicted_backlog - est)

    def on_engine_failure(self, replica_id: int, req: Request,
                          now: float) -> int:
        """Retry-aware failover: record the fault against the replica's
        breaker, then re-route the in-flight request through the existing
        ``exclude``/``est`` path (carrying its known service estimate).
        Falls back to the same replica's queue when no other replica is
        eligible — the request must terminate somewhere, and the repaired
        replica will drain it."""
        self.record_failure(replica_id, now)
        self.release(replica_id, req)
        req.meta["failed_over"] = True
        est = req.meta.get("predicted_service")
        try:
            chosen = self.route(req, now=now, exclude=replica_id, est=est)
            self.stats["failed_over"] += 1
            return chosen
        except RuntimeError:
            r = self.replicas[replica_id]
            r.queue.push_requeue(
                req, req.meta.get("queue_key",
                                  req.meta.get("policy_key0", 0.0)),
                reason="fault")
            r.predicted_backlog += est or 0.0
            return replica_id

    def fail_replica(self, replica_id: int, now: float = 0.0) -> List[Request]:
        """Replica loss: drain its queue and re-route every queued request.

        Non-preemptive SJF makes replay trivial — nothing mid-flight is lost
        except the active request, which the engine re-enqueues at its head.
        """
        r = self.replicas[replica_id]
        r.healthy = False
        drained = []
        while True:
            req = r.queue.pop(now=now)
            if req is None:
                break
            drained.append(req)
        for req in drained:
            req.meta["failed_over"] = True
            # carry the known estimate: re-routing must not replace a
            # scored request's prediction with the class-agnostic mean
            self.route(req, now=now,
                       est=req.meta.get("predicted_service") or None)
            self.stats["failed_over"] += 1
        return drained

    def queue_lengths(self) -> Dict[int, int]:
        return {r.replica_id: len(r.queue) for r in self.replicas}
