"""The Clairvoyant predictor: features -> GBDT -> P(Long).

Three inference paths, all over the same exported ensemble tensors:

* ``predict_p_long``   — numpy host path (per-request admission decision);
* ``kernels.ref.gbdt_predict_ref`` — pure-jnp oracle;
* ``kernels.gbdt_infer`` — Pallas batched kernel (scores whole admission
  batches on-device; the TPU-native analogue of the ONNX C path).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core import features as F
from repro.core.gbdt import GBDTModel, GBDTParams, train_gbdt

LONG_CLASS = 2


@dataclass
class Predictor:
    model: GBDTModel

    def features(self, prompt: str) -> np.ndarray:
        return F.extract(prompt)

    def p_long(self, prompt: str) -> float:
        x = F.extract(prompt)[None, :]
        return float(self.model.predict_p_long(x, LONG_CLASS)[0])

    def p_long_batch(self, prompts: Sequence[str]) -> np.ndarray:
        return self.model.predict_p_long(F.extract_batch(prompts), LONG_CLASS)

    def proba_batch(self, prompts: Sequence[str]) -> np.ndarray:
        return self.model.predict_proba(F.extract_batch(prompts))

    @classmethod
    def train(cls, prompts: Sequence[str], response_lengths: Sequence[int],
              params: Optional[GBDTParams] = None) -> "Predictor":
        from repro.core.ranking import class_labels
        X = F.extract_batch(prompts)
        y = class_labels(np.asarray(response_lengths))
        return cls(model=train_gbdt(X, y, params or GBDTParams()))

    @classmethod
    def train_on_features(cls, X: np.ndarray, y: np.ndarray,
                          params: Optional[GBDTParams] = None) -> "Predictor":
        return cls(model=train_gbdt(X, y, params or GBDTParams()))
