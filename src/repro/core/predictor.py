"""The Clairvoyant predictor: features -> GBDT -> P(Long).

The admission fast path is batched end to end: ``p_long_batch`` runs the
single-pass vectorized feature matcher (``features.extract_batch``) and
scores through the pruned/binned packed ensemble
(``core.ensemble_pack``, native scorer with numpy-traversal fallback).
The inference paths over the same trained ensemble, slowest to fastest:

* ``GBDTModel.predict_margin_dense`` — seed dense traversal (oracle);
* ``GBDTModel.predict_margin`` — packed host path (what this class uses);
* ``kernels.ref.gbdt_margins_ref`` / ``gbdt_margins_packed_ref`` —
  pure-jnp oracles for the device layouts;
* ``kernels.gbdt_infer`` — tree-parallel Pallas kernels, dense and packed
  (score whole admission batches on-device; the TPU-native analogue of
  the paper's ONNX C path).

All fast paths are allclose (rtol 1e-5) to the dense traversal; see
tests/test_ensemble_pack.py and benchmarks/predictor_latency.py.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core import features as F
from repro.core.gbdt import GBDTModel, GBDTParams, train_gbdt

LONG_CLASS = 2


@dataclass
class Predictor:
    model: GBDTModel

    def features(self, prompt: str) -> np.ndarray:
        return F.extract(prompt)

    def _single_path(self):
        """Packed ensemble + reusable (1, F) feature row, cached across
        calls so the serial serving path never re-enters setup code
        (ensemble packing, edge-matrix build, ctypes pointer tuples).
        Like the PackedEnsemble host buffers, the shared row makes
        ``p_long`` not thread-safe — concurrent scorers need one
        Predictor each (the packed tables themselves can be shared)."""
        cached = self.__dict__.get("_single")
        if cached is None:
            packed = self.model.packed()
            packed.bin_input(np.zeros((1, F.N_FEATURES), np.float32))
            cached = (packed, np.empty((1, F.N_FEATURES), np.float32))
            self.__dict__["_single"] = cached
        return cached

    def p_long(self, prompt: str) -> float:
        packed, xbuf = self._single_path()
        xbuf[0] = F.extract(prompt)
        return float(packed.predict_p_long(xbuf, LONG_CLASS)[0])

    def p_long_batch(self, prompts: Sequence[str]) -> np.ndarray:
        return self.model.predict_p_long(F.extract_batch(prompts), LONG_CLASS)

    def proba_batch(self, prompts: Sequence[str]) -> np.ndarray:
        return self.model.predict_proba(F.extract_batch(prompts))

    @classmethod
    def train(cls, prompts: Sequence[str], response_lengths: Sequence[int],
              params: Optional[GBDTParams] = None) -> "Predictor":
        from repro.core.ranking import class_labels
        X = F.extract_batch(prompts)
        y = class_labels(np.asarray(response_lengths))
        return cls(model=train_gbdt(X, y, params or GBDTParams()))

    @classmethod
    def train_on_features(cls, X: np.ndarray, y: np.ndarray,
                          params: Optional[GBDTParams] = None) -> "Predictor":
        return cls(model=train_gbdt(X, y, params or GBDTParams()))
