"""Ranking-accuracy metric (paper §4.1, Algorithm 1) and baselines (Table 7).

Ranking accuracy = fraction of (Short, Long) pairs where the model scores the
Long example strictly higher.  Medium examples are excluded.  Vectorised via
sorting: O((|S|+|L|) log |S|) instead of the naive |S| x |L| product.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

SHORT_MAX = 200   # response tokens: Short < 200
LONG_MIN = 800    # Long >= 800


def class_of(response_tokens: int) -> int:
    """0=Short, 1=Medium, 2=Long (paper's 3-class formulation)."""
    if response_tokens < SHORT_MAX:
        return 0
    if response_tokens < LONG_MIN:
        return 1
    return 2


def class_labels(lengths: np.ndarray) -> np.ndarray:
    lengths = np.asarray(lengths)
    return np.where(lengths < SHORT_MAX, 0,
                    np.where(lengths < LONG_MIN, 1, 2)).astype(np.int64)


def ranking_accuracy(lengths: np.ndarray, scores: np.ndarray,
                     ties: str = "loss") -> float:
    """Algorithm 1.  ``lengths``: true response token counts;
    ``scores``: predicted P(Long).  ties='loss' counts equal scores as
    failures (the paper's strict inequality); ties='half' scores them 0.5
    (used for the coarse baselines whose scores are heavily tied).
    """
    lengths = np.asarray(lengths)
    scores = np.asarray(scores, np.float64)
    s_scores = np.sort(scores[lengths < SHORT_MAX])
    l_scores = scores[lengths >= LONG_MIN]
    if len(s_scores) == 0 or len(l_scores) == 0:
        return float("nan")
    # for each long score: count shorts strictly below / equal
    below = np.searchsorted(s_scores, l_scores, side="left")
    upto = np.searchsorted(s_scores, l_scores, side="right")
    correct = below.sum()
    if ties == "half":
        correct = correct + 0.5 * (upto - below).sum()
    return float(correct) / (len(s_scores) * len(l_scores))


def classification_accuracy(lengths: np.ndarray, proba: np.ndarray) -> float:
    """3-class accuracy (the metric ranking accuracy beats by 21-29 pp)."""
    y = class_labels(lengths)
    return float((proba.argmax(axis=1) == y).mean())


# ---------------------------------------------------------------------------
# Baselines (Table 7)
# ---------------------------------------------------------------------------

def prompt_length_rule_scores(prompt_lens: np.ndarray,
                              threshold: float) -> np.ndarray:
    """Binary score: predicted-long iff prompt token length > threshold."""
    return (np.asarray(prompt_lens) > threshold).astype(np.float64)


def fit_prompt_length_threshold(prompt_lens: np.ndarray,
                                lengths: np.ndarray) -> float:
    """Optimise the rule threshold on the training split (paper Table 7)."""
    cands = np.unique(np.asarray(prompt_lens))
    best_t, best_a = 0.0, -1.0
    for t in cands:
        a = ranking_accuracy(lengths, prompt_length_rule_scores(prompt_lens, t),
                             ties="half")
        if a > best_a:
            best_a, best_t = a, float(t)
    return best_t


def keyword_heuristic_scores(features: np.ndarray) -> np.ndarray:
    """Rule-based score: prompts that *mention* code or structured formats
    are guessed Long.  On chat distributions where code questions get terse
    answers this anti-correlates — the paper measures 4.6-36.3%, far below
    random.  Evaluate with ties='half' (binary scores are heavily tied).
    """
    f = np.asarray(features)
    return f[:, 1] + f[:, 4]  # has_code_keyword + has_format_keyword
