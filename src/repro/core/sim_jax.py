"""JAX replication engine: the serial-server DES as a vmapped scan.

The host engines (``core.sim_fast``) are fastest for one cell on CPU; this
module is the device path for the *embarrassingly parallel* axis of a
sweep — every (policy, tau, rho, seed) cell is an independent simulation,
so the whole grid maps onto hardware as one ``vmap`` over a fixed-length
``lax.scan``.

Each simulation dispatches exactly ``n`` requests, so the scan runs ``n``
steps of O(n) masked vector work (admission mask, FIFO-oldest argmax,
(key, seq) argmin) — O(n^2) lanes per cell, but every lane is data
parallel, which is the right trade for accelerators and keeps the whole
grid in one XLA computation.  Requests must be pre-sorted by
``(arrival, req_id)`` per row, exactly like the host engines.

In float64 mode (``jax.config.update("jax_enable_x64", True)``) the
dispatch trace matches the host engines bitwise; under default float32
the dispatch *order* still matches whenever clock rounding cannot flip a
comparison, and times agree to float32 tolerance (see
tests/test_simulation.py).

This path consumes pre-computed priority-key arrays, so every
*key-based* policy in ``core.policy`` (fcfs / sjf / sjf_oracle /
sjf_quantile / fair_share) runs here unchanged; *preemptive* policies
(srpt / mlfq) need mid-service re-enqueue events, which this fixed-step
scan does not model — ``core.sweep`` routes their rows to the host
preemptive engine (``sim_fast.simulate_grid_preempt``) instead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _simulate_one(arrival, service, key, tau):
    """One cell: (n,) arrays -> (start, finish, promoted, promotions)."""
    n = arrival.shape[0]
    dt = arrival.dtype
    inf = jnp.asarray(jnp.inf, dt)

    def step(carry, _):
        t, done, start, promoted, promos = carry
        next_arr = jnp.where(done, inf, arrival).min()
        queued = (arrival <= t) & ~done
        t = jnp.where(queued.any(), t, jnp.maximum(t, next_arr))
        queued = (arrival <= t) & ~done
        oldest = jnp.argmax(queued)           # first queued = FIFO head
        promote = (t - arrival[oldest]) > tau  # NaN tau: always False
        masked = jnp.where(queued, key, inf)
        pick = jnp.argmax(queued & (masked == masked.min()))
        j = jnp.where(promote, oldest, pick)
        start = start.at[j].set(t)
        t = t + service[j]
        done = done.at[j].set(True)
        promoted = promoted.at[j].set(promote)
        promos = promos + promote.astype(jnp.int32)
        return (t, done, start, promoted, promos), None

    init = (jnp.asarray(0.0, dt), jnp.zeros(n, bool), jnp.zeros(n, dt),
            jnp.zeros(n, bool), jnp.asarray(0, jnp.int32))
    (t, _, start, promoted, promos), _ = jax.lax.scan(
        step, init, None, length=n)
    return start, start + service, promoted, promos


@jax.jit
def _simulate_grid_jit(arrival, service, key, tau):
    return jax.vmap(_simulate_one)(arrival, service, key, tau)


def simulate_grid_jax(arrival, service, key, tau):
    """G independent simulations on the JAX backend, one computation.

    Same contract as :func:`sim_fast.simulate_grid`: (G, n) arrays sorted
    by arrival per row, ``tau`` a length-G sequence with None disabling
    the guard.  Returns numpy ``(start, finish, promoted, promotions)``.
    """
    dt = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    tau_arr = np.array([np.nan if t is None else float(t) for t in tau])
    start, finish, promoted, promos = _simulate_grid_jit(
        jnp.asarray(arrival, dt), jnp.asarray(service, dt),
        jnp.asarray(key, dt), jnp.asarray(tau_arr, dt))
    return (np.asarray(start, np.float64), np.asarray(finish, np.float64),
            np.asarray(promoted, bool), np.asarray(promos, np.int64))
