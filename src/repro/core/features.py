"""The 19 lexical features of Clairvoyant (paper §3.2).

Six numeric features + a 13-way one-hot of the leading instruction verb.
Implemented as a pure string-scanning pass — no regex, no tokenizer loading,
no embedding lookups — so extraction cost is sub-microsecond-ish per prompt
and predictor latency is dominated by model inference, as in the paper.
"""

from __future__ import annotations

from typing import List, Sequence

import numpy as np

# --- keyword tables (paper lists "etc."; these are the expanded sets) -------

CODE_KEYWORDS = (
    "function", "class", "implement", "algorithm", "code", "script",
    "debug", "compile", "python", "javascript", "java", "c++", "sql",
    "api", "library", "module", "refactor", "regex", "program",
)

LENGTH_CONSTRAINT_KEYWORDS = (
    "brief", "briefly", "concise", "concisely", "short answer", "one sentence",
    "in one sentence", "in a sentence", "one word", "tl;dr", "tldr",
    "detailed", "in detail", "in-depth", "comprehensive", "thorough",
    "step by step", "step-by-step", "at length", "elaborate", "essay",
    "paragraphs", "words or less", "word limit",
)

FORMAT_KEYWORDS = (
    "table", "list", "json", "csv", "markdown", "bullet", "bullets",
    "numbered", "outline", "yaml", "xml", "html", "latex", "spreadsheet",
)

CLAUSE_MARKERS = (
    "because", "although", "though", "while", "whereas", "since", "unless",
    "that", "which", "who", "whom", "whose", "when", "where", "if", "after",
    "before", "until", "so that", "such that",
)

INSTRUCTION_VERBS = (
    "what", "write", "explain", "summarize", "how", "list", "implement",
    "compare", "describe", "generate", "why", "define",
)  # 13th category: "other"

VERB_INDEX = {v: i for i, v in enumerate(INSTRUCTION_VERBS)}
N_VERB_FEATURES = len(INSTRUCTION_VERBS) + 1  # + "other"

NUMERIC_FEATURE_NAMES = (
    "prompt_token_len",
    "has_code_keyword",
    "has_length_constraint",
    "ends_with_question",
    "has_format_keyword",
    "clause_count",
)

FEATURE_NAMES: tuple = NUMERIC_FEATURE_NAMES + tuple(
    f"verb_{v}" for v in INSTRUCTION_VERBS
) + ("verb_other",)

N_FEATURES = len(FEATURE_NAMES)
assert N_FEATURES == 19

# Feature-group map for the drop-one ablation study (paper Table 4).
FEATURE_GROUPS = {
    "prompt_token_len": (0,),
    "has_code_keyword": (1,),
    "has_length_constraint": (2,),
    "ends_with_question": (3,),
    "has_format_keyword": (4,),
    "clause_count": (5,),
    "instruction_verb": tuple(range(6, 19)),
}

_SYNONYMS = {
    "summarise": "summarize", "whats": "what", "what's": "what",
    "tell": "describe", "give": "generate", "create": "generate",
    "make": "generate", "show": "list", "enumerate": "list",
    "clarify": "explain", "outline": "summarize", "code": "implement",
    "build": "implement", "develop": "implement", "contrast": "compare",
}


def leading_verb(prompt: str) -> int:
    """Index of the leading instruction verb (12 == 'other')."""
    for word in prompt.split():
        w = word.strip(".,:;!?\"'()[]").lower()
        if not w:
            continue
        w = _SYNONYMS.get(w, w)
        return VERB_INDEX.get(w, len(INSTRUCTION_VERBS))
    return len(INSTRUCTION_VERBS)


def _contains_any(low: str, keywords: Sequence[str]) -> float:
    return 1.0 if any(k in low for k in keywords) else 0.0


def _count_clause_markers(low: str) -> float:
    count = 0
    for word in low.split():
        w = word.strip(".,:;!?\"'()[]")
        if w in CLAUSE_MARKERS:
            count += 1
    # multi-word markers
    count += low.count("so that") + low.count("such that")
    return float(count)


def extract(prompt: str) -> np.ndarray:
    """19-dim float32 feature vector for one prompt."""
    low = prompt.lower()
    vec = np.zeros(N_FEATURES, dtype=np.float32)
    vec[0] = len(prompt) // 4  # BPE approximation, as in the paper
    vec[1] = _contains_any(low, CODE_KEYWORDS)
    vec[2] = _contains_any(low, LENGTH_CONSTRAINT_KEYWORDS)
    vec[3] = 1.0 if prompt.rstrip().endswith("?") else 0.0
    vec[4] = _contains_any(low, FORMAT_KEYWORDS)
    vec[5] = _count_clause_markers(low)
    vec[6 + leading_verb(prompt)] = 1.0
    return vec


def extract_batch(prompts: Sequence[str]) -> np.ndarray:
    """(N, 19) feature matrix."""
    out = np.zeros((len(prompts), N_FEATURES), dtype=np.float32)
    for i, p in enumerate(prompts):
        out[i] = extract(p)
    return out
