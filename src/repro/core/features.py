"""The 19 lexical features of Clairvoyant (paper §3.2) — fast-path edition.

Six numeric features + a 13-way one-hot of the leading instruction verb.

**Text normalization (the feature contract).**  All lexical features are
defined over the *normalized* prompt: lowercased, with the punctuation set
``.,:;!?"'()[]`` and every ASCII whitespace character mapped to a single
space (each punctuation char becomes one space — "short.answer" therefore
matches the "short answer" keyword, while "short, answer" normalizes to a
double space and does not).  Keyword-table features use substring
semantics on the normalized text ("tl;dr" matches via its normalized form
"tl dr").  Clause markers and the leading
verb are token-level: a token is a maximal run of non-space bytes.  This
revision also fixes the seed's clause-marker double counting: "so that" /
"such that" normalize to ``so``+``that`` / ``such``+``that`` and are
counted exactly once via their ``that`` token — the seed counted the
``that`` token *and* added a substring count of the two-word form.

**The fast path.**  ``extract_batch`` scans all prompts in one pass.  The
keyword tables *and* the clause markers are compiled once at import into a
frozen byte-level multi-pattern matcher (``_PatternMatcher``): a
65536-entry bigram-dispatch table (the flattened two-level root of an
Aho-Corasick-style trie), a per-group third-byte gate, and zero-padded
16-byte (key, mask) pairs per pattern.  At batch time the prompts are
joined into a single normalized byte corpus (separated by ``" \\x00 "`` so
no pattern can span two prompts) and matched with a handful of vectorized
numpy passes; hits are attributed to prompts by binary search over the
prompt byte offsets.  Clause-marker patterns carry their trailing space
in the key and verify the leading boundary with one gather, giving exact
token semantics without tokenizing.

``extract`` (single prompt) implements the same contract with scalar
string operations; ``extract_reference`` is the seed-style per-keyword
scan kept as the equivalence oracle and the "old" side of
``benchmarks/predictor_latency.py``.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

# --- keyword tables (paper lists "etc."; these are the expanded sets) -------

CODE_KEYWORDS = (
    "function", "class", "implement", "algorithm", "code", "script",
    "debug", "compile", "python", "javascript", "java", "c++", "sql",
    "api", "library", "module", "refactor", "regex", "program",
)

LENGTH_CONSTRAINT_KEYWORDS = (
    "brief", "briefly", "concise", "concisely", "short answer", "one sentence",
    "in one sentence", "in a sentence", "one word", "tl;dr", "tldr",
    "detailed", "in detail", "in-depth", "comprehensive", "thorough",
    "step by step", "step-by-step", "at length", "elaborate", "essay",
    "paragraphs", "words or less", "word limit",
)

FORMAT_KEYWORDS = (
    "table", "list", "json", "csv", "markdown", "bullet", "bullets",
    "numbered", "outline", "yaml", "xml", "html", "latex", "spreadsheet",
)

CLAUSE_MARKERS = (
    "because", "although", "though", "while", "whereas", "since", "unless",
    "that", "which", "who", "whom", "whose", "when", "where", "if", "after",
    "before", "until", "so that", "such that",
)

INSTRUCTION_VERBS = (
    "what", "write", "explain", "summarize", "how", "list", "implement",
    "compare", "describe", "generate", "why", "define",
)  # 13th category: "other"

VERB_INDEX = {v: i for i, v in enumerate(INSTRUCTION_VERBS)}
N_VERB_FEATURES = len(INSTRUCTION_VERBS) + 1  # + "other"

NUMERIC_FEATURE_NAMES = (
    "prompt_token_len",
    "has_code_keyword",
    "has_length_constraint",
    "ends_with_question",
    "has_format_keyword",
    "clause_count",
)

FEATURE_NAMES: tuple = NUMERIC_FEATURE_NAMES + tuple(
    f"verb_{v}" for v in INSTRUCTION_VERBS
) + ("verb_other",)

N_FEATURES = len(FEATURE_NAMES)
assert N_FEATURES == 19

# Feature-group map for the drop-one ablation study (paper Table 4).
FEATURE_GROUPS = {
    "prompt_token_len": (0,),
    "has_code_keyword": (1,),
    "has_length_constraint": (2,),
    "ends_with_question": (3,),
    "has_format_keyword": (4,),
    "clause_count": (5,),
    "instruction_verb": tuple(range(6, 19)),
}

_SYNONYMS = {
    "summarise": "summarize", "whats": "what", "what's": "what",
    "tell": "describe", "give": "generate", "create": "generate",
    "make": "generate", "show": "list", "enumerate": "list",
    "clarify": "explain", "outline": "summarize", "code": "implement",
    "build": "implement", "develop": "implement", "contrast": "compare",
}

# --- normalization tables ---------------------------------------------------

_PUNCT = ".,:;!?\"'()[]"
_WS = "\t\n\r\x0b\x0c"
_NORMALIZE_STR = str.maketrans({c: " " for c in _PUNCT + _WS})
# Byte-level variant: every translated char is ASCII, so translating the
# utf-8 corpus byte-by-byte is exact (continuation bytes are >= 0x80 and
# untouched) and runs at memcpy speed over the whole batch.
_NORMALIZE_BYTES = bytes(
    32 if chr(i) in _PUNCT + _WS else i for i in range(256))


def _normalized_table(table: Sequence[str]) -> tuple:
    out = []
    for k in table:
        t = k.translate(_NORMALIZE_STR)
        if t not in out:
            out.append(t)
    return tuple(out)


# Keyword tables in normalized space (only "tl;dr" actually changes).
NORM_CODE_KEYWORDS = _normalized_table(CODE_KEYWORDS)
NORM_LENGTH_KEYWORDS = _normalized_table(LENGTH_CONSTRAINT_KEYWORDS)
NORM_FORMAT_KEYWORDS = _normalized_table(FORMAT_KEYWORDS)

_SINGLE_CLAUSE_MARKERS = frozenset(
    m.encode() for m in CLAUSE_MARKERS if " " not in m)

# Verb lookup over normalized first tokens.  Punctuated synonyms ("what's")
# normalize to their first token before insertion.
_VERB_TOKENS_B: dict = {}
for _v, _i in VERB_INDEX.items():
    _VERB_TOKENS_B[_v.encode()] = _i
for _syn, _tgt in _SYNONYMS.items():
    _first = _syn.translate(_NORMALIZE_STR).split()[0]
    _VERB_TOKENS_B.setdefault(_first.encode(), VERB_INDEX[_tgt])
_VERB_OTHER = len(INSTRUCTION_VERBS)


# ---------------------------------------------------------------------------
# Frozen multi-pattern matcher (built once at import)
# ---------------------------------------------------------------------------

def _pack_key(b: bytes, width: int) -> int:
    """Little-endian zero-padded integer key for up to ``width`` bytes."""
    assert len(b) <= width, b
    return int.from_bytes(b.ljust(width, b"\x00"), "little")


# action ids carried per pattern
_ACT_CODE, _ACT_LENGTH, _ACT_FORMAT, _ACT_MARKER = 0, 1, 2, 3


class _PatternMatcher:
    """Single-pass vectorized multi-pattern matcher over the normalized
    corpus.

    Patterns are dispatched on their first two bytes through a 65536-entry
    group table (the flattened two-level root of an Aho-Corasick-style
    trie); a per-group 256-entry third-byte gate prunes candidates, and
    each survivor is verified with one masked uint64x2 compare of its
    16-byte window.  Groups holding several patterns (shared bigram)
    resolve their extra slots on the shrinking subset of candidates that
    reach them.  ``find`` returns (position, action) pairs for every
    pattern occurrence in the corpus.
    """

    def __init__(self, patterns: Sequence):
        groups: dict = {}
        for pid, (b, _act) in enumerate(patterns):
            assert 3 <= len(b) <= 16, b
            groups.setdefault(b[:2], []).append(pid)
        n_groups = len(groups)
        n_slots = max(len(v) for v in groups.values())
        assert n_groups < 127
        self.lut = np.full(65536, -1, np.int8)           # bigram -> group id
        self.third_ok = np.zeros((n_groups, 256), bool)  # 3rd-byte gate
        self.fourth_ok = np.zeros((n_groups, 256), bool)  # 4th-byte gate
        self.key_lo = np.zeros((n_groups, n_slots), np.uint64)
        self.key_hi = np.zeros((n_groups, n_slots), np.uint64)
        self.msk_lo = np.zeros((n_groups, n_slots), np.uint64)
        self.msk_hi = np.zeros((n_groups, n_slots), np.uint64)
        self.act = np.zeros((n_groups, n_slots), np.int8)
        self.group_size = np.zeros(n_groups, np.int16)
        for gid, (bg, pids) in enumerate(groups.items()):
            self.lut[bg[0] << 8 | bg[1]] = gid
            self.group_size[gid] = len(pids)
            for s, pid in enumerate(pids):
                b, act = patterns[pid]
                full = b.ljust(16, b"\x00")
                mask = (b"\xff" * len(b)).ljust(16, b"\x00")
                self.third_ok[gid, b[2]] = True
                if len(b) > 3:
                    self.fourth_ok[gid, b[3]] = True
                else:           # 3-byte pattern: any 4th byte may follow
                    self.fourth_ok[gid, :] = True
                self.key_lo[gid, s] = _pack_key(full[:8], 8)
                self.key_hi[gid, s] = _pack_key(full[8:], 8)
                self.msk_lo[gid, s] = _pack_key(mask[:8], 8)
                self.msk_hi[gid, s] = _pack_key(mask[8:], 8)
                self.act[gid, s] = act
        self.n_slots = n_slots

    def find(self, arr: np.ndarray):
        """All pattern occurrences in ``arr`` -> (positions, action ids).

        ``arr``: uint8 corpus padded with >= 16 trailing space bytes.
        """
        empty = np.zeros(0, np.int64), np.zeros(0, np.int8)
        scan_len = arr.shape[0] - 16
        if scan_len <= 0:
            return empty
        bg = arr[:scan_len].astype(np.uint16) << 8
        bg |= arr[1:scan_len + 1]
        gid = self.lut[bg]
        cand = np.nonzero(gid >= 0)[0]
        if cand.size == 0:
            return empty
        g = gid[cand].astype(np.intp)
        keep = self.third_ok[g, arr[cand + 2]]
        keep &= self.fourth_ok[g, arr[cand + 3]]
        cand, g = cand[keep], g[keep]
        if cand.size == 0:
            return empty
        w = np.lib.stride_tricks.sliding_window_view(arr, 16)[cand] \
            .view(np.uint64)                             # (n_cand, 2)
        w_lo, w_hi = w[:, 0], w[:, 1]
        # slot 0 (every group has one)
        bad = ((w_lo ^ self.key_lo[g, 0]) & self.msk_lo[g, 0]) \
            | ((w_hi ^ self.key_hi[g, 0]) & self.msk_hi[g, 0])
        ok = bad == 0
        hit_pos, hit_act = [cand[ok]], [self.act[g[ok], 0]]
        # remaining slots on the shrinking multi-pattern subset
        sub = np.nonzero(self.group_size[g] > 1)[0]
        for s in range(1, self.n_slots):
            if sub.size == 0:
                break
            gs = g[sub]
            bad = ((w_lo[sub] ^ self.key_lo[gs, s]) & self.msk_lo[gs, s]) \
                | ((w_hi[sub] ^ self.key_hi[gs, s]) & self.msk_hi[gs, s])
            ok = bad == 0
            hit_pos.append(cand[sub[ok]])
            hit_act.append(self.act[gs[ok], s])
            sub = sub[self.group_size[gs] > s + 1]
        return np.concatenate(hit_pos), np.concatenate(hit_act)


def _build_patterns():
    pats = []
    for table, act in ((NORM_CODE_KEYWORDS, _ACT_CODE),
                       (NORM_LENGTH_KEYWORDS, _ACT_LENGTH),
                       (NORM_FORMAT_KEYWORDS, _ACT_FORMAT)):
        for kw in table:
            pats.append((kw.encode(), act))
    # clause markers carry their trailing token boundary in the pattern;
    # the leading boundary is verified per hit
    for m in sorted(_SINGLE_CLAUSE_MARKERS):
        pats.append((m + b" ", _ACT_MARKER))
    return pats


_MATCHER = _PatternMatcher(_build_patterns())
_KW_COLUMN = np.asarray([1, 2, 4], np.int64)   # action id -> feature column


# ---------------------------------------------------------------------------
# Scalar path (same contract as the batch engine)
# ---------------------------------------------------------------------------

def leading_verb(prompt: str) -> int:
    """Index of the leading instruction verb (12 == 'other')."""
    for w in prompt.lower().translate(_NORMALIZE_STR).encode().split(b" "):
        if w:
            return _VERB_TOKENS_B.get(w, _VERB_OTHER)
    return _VERB_OTHER


def _count_clause_markers(norm: str) -> float:
    """Clause-marker token count over the normalized prompt."""
    count = 0
    for w in norm.encode().split(b" "):
        if w in _SINGLE_CLAUSE_MARKERS:
            count += 1
    return float(count)


def _ends_with_question(prompt: str) -> bool:
    for ch in reversed(prompt):
        if not ch.isspace():
            return ch == "?"
    return False


def _contains_any(norm: str, keywords: Sequence[str]) -> float:
    return 1.0 if any(k in norm for k in keywords) else 0.0


def extract(prompt: str) -> np.ndarray:
    """19-dim float32 feature vector for one prompt."""
    norm = prompt.lower().translate(_NORMALIZE_STR)
    vec = np.zeros(N_FEATURES, dtype=np.float32)
    vec[0] = len(prompt) // 4  # BPE approximation, as in the paper
    vec[1] = _contains_any(norm, NORM_CODE_KEYWORDS)
    vec[2] = _contains_any(norm, NORM_LENGTH_KEYWORDS)
    vec[3] = 1.0 if _ends_with_question(prompt) else 0.0
    vec[4] = _contains_any(norm, NORM_FORMAT_KEYWORDS)
    verb = _VERB_OTHER
    first = True
    count = 0
    for w in norm.encode().split(b" "):
        if not w:
            continue
        if first:
            verb = _VERB_TOKENS_B.get(w, _VERB_OTHER)
            first = False
        if w in _SINGLE_CLAUSE_MARKERS:
            count += 1
    vec[5] = float(count)
    vec[6 + verb] = 1.0
    return vec


# ---------------------------------------------------------------------------
# Batched fast path
# ---------------------------------------------------------------------------

def extract_batch(prompts: Sequence[str]) -> np.ndarray:
    """(N, 19) feature matrix, one vectorized pass over all prompts."""
    n = len(prompts)
    out = np.zeros((n, N_FEATURES), dtype=np.float32)
    if n == 0:
        return out
    lows = [p.lower() for p in prompts]
    # " \x00 " separators block cross-prompt matches while keeping a space
    # boundary on both sides of every prompt; 16 trailing spaces pad the
    # 16-byte windows; 1 leading space anchors leading-boundary checks.
    joined = " " + " \x00 ".join(lows) + " " * 16
    raw = joined.encode().translate(_NORMALIZE_BYTES)
    arr = np.frombuffer(raw, np.uint8)
    if len(raw) == len(joined):       # pure-ASCII batch: byte len == char len
        lens = np.fromiter((len(l) for l in lows), np.int64, n)
    else:
        lens = np.fromiter((len(l.encode()) for l in lows), np.int64, n)
    starts = np.empty(n, np.int64)
    starts[0] = 1
    np.cumsum(lens[:-1] + 3, out=starts[1:])
    starts[1:] += 1

    # numeric scalars (one fused Python sweep; rstrip only when the last
    # char is whitespace, the rare case)
    tok_lens = [0] * n
    qidx = []
    for i, p in enumerate(prompts):
        tok_lens[i] = len(p) >> 2
        if p:
            last = p[-1]
            if last == "?" or (last.isspace()
                               and p.rstrip()[-1:] == "?"):
                qidx.append(i)
    out[:, 0] = tok_lens
    out[qidx, 3] = 1.0

    # one matcher pass: keyword bits + clause-marker counts
    pos, act = _MATCHER.find(arr)
    if pos.size:
        pid = np.searchsorted(starts, pos, side="right") - 1
        kw = act < _ACT_MARKER
        out[pid[kw], _KW_COLUMN[act[kw]]] = 1.0
        mk = np.nonzero(act == _ACT_MARKER)[0]
        mk = mk[arr[pos[mk] - 1] == 32]    # leading token boundary
        out[:, 5] = np.bincount(pid[mk], minlength=n)

    # leading verb: first normalized token per prompt.  Fast path: a
    # 16-byte peek suffices when the prompt starts with its token — every
    # verb is < 16 bytes, a prompt shorter than 16 bytes runs into its
    # separator space, and a spaceless 16-byte window means a token too
    # long to be a verb.  Leading whitespace (rare) takes the strip path.
    verbs = [_VERB_OTHER] * n
    get_verb = _VERB_TOKENS_B.get
    starts_l = starts.tolist()
    lens_l = lens.tolist()
    for i in range(n):
        s0 = starts_l[i]
        seg = raw[s0:s0 + 16]
        j = seg.find(b" ")
        if j > 0:
            verbs[i] = get_verb(seg[:j], _VERB_OTHER)
        elif j == 0:
            t = raw[s0:s0 + lens_l[i]].lstrip()
            if t:
                k = t.find(b" ")
                verbs[i] = get_verb(t[:k] if k >= 0 else t, _VERB_OTHER)
    out[np.arange(n), np.asarray(verbs, np.int64) + 6] = 1.0
    return out


# ---------------------------------------------------------------------------
# Reference (seed-style) implementation — equivalence oracle and the "old"
# side of benchmarks/predictor_latency.py.  Same contract and semantics,
# one substring scan per keyword and a Python token loop.
# ---------------------------------------------------------------------------

def _count_clause_markers_reference(norm: str) -> float:
    count = 0
    for w in norm.split(" "):
        if w and w in CLAUSE_MARKERS:
            count += 1
    return float(count)


def extract_reference(prompt: str) -> np.ndarray:
    """Seed-style per-keyword scan (slow; oracle + benchmark baseline)."""
    norm = prompt.lower().translate(_NORMALIZE_STR)
    vec = np.zeros(N_FEATURES, dtype=np.float32)
    vec[0] = len(prompt) // 4
    vec[1] = _contains_any(norm, NORM_CODE_KEYWORDS)
    vec[2] = _contains_any(norm, NORM_LENGTH_KEYWORDS)
    vec[3] = 1.0 if prompt.rstrip().endswith("?") else 0.0
    vec[4] = _contains_any(norm, NORM_FORMAT_KEYWORDS)
    vec[5] = _count_clause_markers_reference(norm)
    vec[6 + leading_verb(prompt)] = 1.0
    return vec
