"""Discrete-event simulation of the serial backend (paper §5.4/§5.5).

Single non-preemptive server fed by the SJFQueue: exactly the M/G/1 setting
of the paper's steady-state analysis and the closed-queue setting of its
burst benchmark.  Service times come either from parametric distributions
(the paper's calibrated Gaussians) or from the framework's roofline-derived
engine cost model (serving/service_time.py).

``simulate`` runs on the vectorized SoA engine (``core.sim_fast`` — C
inner loop with a run-batched numpy fallback); the seed per-event Python
loop is kept as ``simulate_reference``, the trace-equivalence oracle and
the "old" side of ``benchmarks/sim_bench.py``.  For whole grids
(policy x tau x rho x seed) use ``core.sweep`` — one call per sweep, not
one per cell.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.scheduler import Request, SJFQueue


@dataclass
class SimResult:
    requests: List[Request]
    promotions: int
    makespan: float

    def _vals(self, klass: Optional[str], attr: str) -> np.ndarray:
        # wait/sojourn are NaN (not None) before dispatch/completion
        vals = [getattr(r, attr) for r in self.requests
                if klass is None or r.klass == klass]
        return np.array([v for v in vals
                         if v is not None and not math.isnan(v)])

    def percentile(self, q: float, klass: Optional[str] = None,
                   attr: str = "sojourn") -> float:
        v = self._vals(klass, attr)
        return float(np.percentile(v, q)) if len(v) else float("nan")

    def mean(self, klass: Optional[str] = None, attr: str = "sojourn") -> float:
        v = self._vals(klass, attr)
        return float(v.mean()) if len(v) else float("nan")


def simulate_reference(requests: Sequence[Request], policy="sjf",
                       tau: Optional[float] = None) -> SimResult:
    """Seed per-event loop (the trace-equivalence oracle; slow).

    Accepts any *non-preemptive* registered policy (the oracle serves each
    dispatched request to completion); preemptive policies are rejected.
    """
    from repro.core.policy import get_policy
    if get_policy(policy).preemptive:
        raise ValueError("simulate_reference is non-preemptive; use "
                         "simulate() for preemptive policies")
    reqs = sorted(requests, key=lambda r: (r.arrival, r.req_id))
    q = SJFQueue(policy=policy, tau=tau)
    t = 0.0
    i, n = 0, len(reqs)
    done: List[Request] = []
    while i < n or len(q):
        if not len(q):
            t = max(t, reqs[i].arrival)
        while i < n and reqs[i].arrival <= t:
            q.push(reqs[i])
            i += 1
        req = q.pop(now=t)
        if req is None:
            continue
        req.start = t
        t += req.true_service
        req.finish = t
        done.append(req)
    return SimResult(requests=done, promotions=q.stats["promotions"],
                     makespan=t)


def simulate(requests: Sequence[Request], policy="sjf",
             tau: Optional[float] = None, engine: str = "auto",
             recorder=None) -> SimResult:
    """Run the serial-server DES.  ``requests`` carry arrival/p_long/service.

    ``policy`` is a registry name or Policy instance.  For key-based
    policies this keeps the seed loop's contract (start/finish/promoted
    written onto the passed Requests, dispatch-ordered result list) and is
    trace-equivalent bitwise; preemptive policies (srpt/mlfq) run on the
    preemptive engine, where ``start`` is the FIRST dispatch time.

    ``recorder`` (a ``serving.observability.FlightRecorder``) replays the
    result as the live drains' span schema in virtual time — pure
    post-processing over the DES result arrays, zero inner-loop cost.
    """
    from repro.core.sim_fast import RequestBatch, simulate_batch
    reqs = sorted(requests, key=lambda r: (r.arrival, r.req_id))
    n = len(reqs)
    if n == 0:
        return SimResult(requests=[], promotions=0, makespan=0.0)
    res = simulate_batch(RequestBatch.from_requests(reqs), policy=policy,
                         tau=tau, engine=engine)
    for i, r in enumerate(reqs):
        r.start = float(res.start[i])
        r.finish = float(res.finish[i])
        r.promoted = bool(res.promoted[i])
    if recorder is not None:
        from repro.core.sim_fast import record_batch_trace
        record_batch_trace(
            recorder,
            arrival=[r.arrival for r in reqs],
            start=res.start, finish=res.finish,
            req_ids=[r.req_id for r in reqs],
            out_tokens=[r.meta.get("output_tokens")
                        if r.meta.get("output_tokens") is not None
                        else None for r in reqs]
            if any(r.meta.get("output_tokens") is not None
                   for r in reqs) else None)
    done = [reqs[i] for i in np.argsort(res.start, kind="stable")]
    return SimResult(requests=done, promotions=res.promotions,
                     makespan=res.makespan)


def simulate_speculative(requests: Sequence[Request], policy="sjf",
                         tau: Optional[float] = None, *, draft_k: int = 0,
                         draft_cost: float = 0.15,
                         engine: str = "auto") -> SimResult:
    """Serial-server DES with a speculative-decoding backend.

    Mirrors draft-verify decode (serving/generate.py) as a per-request
    service-rate modifier: each request's wall-clock service is
    ``true_service / expected_speedup(accept_rate, draft_k)`` where
    ``accept_rate`` is ``Request.accept_rate`` (None counts as 0.0 — the
    draft overhead is paid regardless).  Acceptance-aware policies
    (``sjf_effective``) receive the per-request acceptance rates through
    ``key_array``; plain policies key exactly as before.  ``draft_k=0``
    is the identity — bitwise trace-equal to :func:`simulate`.
    """
    from dataclasses import replace as _replace

    from repro.core.policy import EffectiveSJF, get_policy
    from repro.core.sim_fast import (RequestBatch, simulate_arrays,
                                     speculative_service)
    reqs = sorted(requests, key=lambda r: (r.arrival, r.req_id))
    n = len(reqs)
    if n == 0:
        return SimResult(requests=[], promotions=0, makespan=0.0)
    pol = get_policy(policy)
    if isinstance(pol, EffectiveSJF):
        # key against this run's actual draft depth/cost
        pol = _replace(pol, draft_k=draft_k, draft_cost=draft_cost)
    if pol.preemptive:
        raise ValueError(
            f"simulate_speculative supports key-based policies only, "
            f"got preemptive {pol.name!r}")
    batch = RequestBatch.from_requests(reqs)      # already arrival-sorted
    accept = np.array([float("nan") if r.accept_rate is None
                       else float(r.accept_rate) for r in reqs], np.float64)
    service = speculative_service(batch.true_service, accept, draft_k,
                                  draft_cost)
    try:
        key = pol.key_array(batch.arrival, batch.p_long, service,
                            tenant=batch.tenant, tenants=batch.tenants,
                            accept_rate=accept)
    except TypeError:                             # acceptance-unaware policy
        key = pol.key_array(batch.arrival, batch.p_long, service,
                            tenant=batch.tenant, tenants=batch.tenants)
    start, finish, promoted, promotions = simulate_arrays(
        batch.arrival, service, key, pol.aging.effective_tau(tau),
        engine=engine)
    for i, r in enumerate(reqs):
        r.start = float(start[i])
        r.finish = float(finish[i])
        r.promoted = bool(promoted[i])
    done = [reqs[i] for i in np.argsort(start, kind="stable")]
    return SimResult(requests=done, promotions=promotions,
                     makespan=float(finish.max()))


def simulate_servers(requests: Sequence[Request], policy="sjf",
                     tau: Optional[float] = None, n_servers: int = 1,
                     slowdown=None, mem_tokens=None,
                     mem_budget=None) -> SimResult:
    """Run the *c-server* DES: ``n_servers`` concurrent decode lanes with
    a per-lane slowdown ``slowdown[k-1]`` at k busy lanes and an optional
    memory-token budget — the bounded-concurrency micro-batching regime
    (serving/batching.py) in virtual time.

    ``mem_tokens`` is aligned with the arrival-sorted request order (the
    same ``(arrival, req_id)`` sort every engine applies).  Key-based
    policies and srpt are supported; the reference simulator stays c=1 —
    at ``n_servers=1`` with unit slowdown this is bitwise trace-equal to
    :func:`simulate` (and the reference) for key policies.
    """
    from repro.core.sim_fast import RequestBatch, simulate_batch_servers
    reqs = sorted(requests, key=lambda r: (r.arrival, r.req_id))
    n = len(reqs)
    if n == 0:
        return SimResult(requests=[], promotions=0, makespan=0.0)
    res = simulate_batch_servers(
        RequestBatch.from_requests(reqs), policy=policy, tau=tau,
        n_servers=n_servers, slowdown=slowdown, mem_tokens=mem_tokens,
        mem_budget=mem_budget)
    for i, r in enumerate(reqs):
        r.start = float(res.start[i])
        r.finish = float(res.finish[i])
        r.promoted = bool(res.promoted[i])
    done = [reqs[i] for i in np.argsort(res.start, kind="stable")]
    return SimResult(requests=done, promotions=res.promotions,
                     makespan=res.makespan)


@dataclass
class PagedSimResult(SimResult):
    """A :class:`SimResult` plus the paged-pool outcome counters."""

    preemptions: int = 0
    prefix_hits: int = 0
    peak_pages: float = 0.0


def simulate_paged(requests: Sequence[Request], policy="sjf",
                   tau: Optional[float] = None, n_servers: int = 1,
                   slowdown=None, *, prompt_tokens, total_tokens,
                   page_size: int, n_pages: int, share_group=None,
                   shared_tokens=None,
                   prefill_saved=None) -> PagedSimResult:
    """Run the *block-paged* c-server DES: the worst-case memory
    reservation of :func:`simulate_servers` replaced by page-granular
    accounting with linear decode growth, youngest-lane preemption on
    pool exhaustion and a shared-prefix cache
    (:func:`repro.core.sim_fast.simulate_grid_paged`).

    Token arrays are aligned with the arrival-sorted request order and
    converted to pages here (``ceil(tokens / page_size)``; shared
    prefixes count whole pages only, as the allocator caches only full
    pages).  ``share_group`` labels requests sharing a prompt prefix of
    ``shared_tokens`` tokens; ``prefill_saved`` is the prefill seconds a
    warm admission skips.
    """
    from repro.core.sim_fast import RequestBatch, simulate_batch_paged
    ps = int(page_size)
    if ps < 1:
        raise ValueError(f"page_size must be >= 1, got {page_size}")
    reqs = sorted(requests, key=lambda r: (r.arrival, r.req_id))
    n = len(reqs)
    if n == 0:
        return PagedSimResult(requests=[], promotions=0, makespan=0.0)
    pp = -(-np.asarray(prompt_tokens, np.float64) // ps)
    tp = -(-np.asarray(total_tokens, np.float64) // ps)
    sp = None if shared_tokens is None \
        else np.asarray(shared_tokens, np.float64) // ps   # full pages only
    res = simulate_batch_paged(
        RequestBatch.from_requests(reqs), policy=policy, tau=tau,
        n_servers=n_servers, slowdown=slowdown, prompt_pages=pp,
        total_pages=tp, n_pages=n_pages, share_group=share_group,
        shared_pages=sp, prefill_saved=prefill_saved)
    for i, r in enumerate(reqs):
        r.start = float(res.start[i])
        r.finish = float(res.finish[i])
        r.promoted = bool(res.promoted[i])
    done = [reqs[i] for i in np.argsort(res.start, kind="stable")]
    return PagedSimResult(requests=done, promotions=res.promotions,
                          makespan=res.makespan,
                          preemptions=res.preemptions,
                          prefix_hits=res.prefix_hits,
                          peak_pages=res.peak_pages)


@dataclass
class FaultSimResult(SimResult):
    """A :class:`SimResult` plus the fault-run outcome counters.  Shed
    requests stay in ``requests`` with ``start = finish = NaN``, so the
    percentile/mean aggregations (which drop NaN) report *goodput*
    latency over served requests only."""

    shed: int = 0
    requeues: int = 0
    timeouts: int = 0

    @property
    def served(self) -> int:
        return len(self.requests) - self.shed - self.timeouts


def simulate_faulty(requests: Sequence[Request], policy="sjf",
                    tau: Optional[float] = None,
                    faults=None, deadline: Optional[float] = None,
                    in_service_timeout: bool = False
                    ) -> FaultSimResult:
    """Run the serial DES under a :class:`~repro.core.sim_fast.ServerFaults`
    timeline (server down/repair windows + stall windows) with optional
    deadline shedding (a request whose queueing delay exceeds ``deadline``
    at dispatch is dropped — only before any service has run; a crashed
    request's remainder is always work-conserving requeued).
    ``in_service_timeout=True`` extends the deadline to the whole sojourn:
    mid-service expiry abandons the request at the deadline instant
    (``meta["timeout"]``, counted in ``timeouts``) — the DES mirror of the
    sidecar's ``deadline_mode="sojourn"``.

    With ``faults=None``/empty and ``deadline=None`` this is bitwise
    trace-equivalent to :func:`simulate` (and the reference oracle) for
    key-based policies; preemptive policies are rejected.
    """
    from repro.core.policy import get_policy
    from repro.core.sim_fast import (RequestBatch, ServerFaults,
                                     simulate_grid_faults)
    pol = get_policy(policy)
    if pol.preemptive:
        raise ValueError("simulate_faulty is non-preemptive; fault "
                         "injection composes with key-based policies only")
    if faults is None:
        faults = ServerFaults()
    reqs = sorted(requests, key=lambda r: (r.arrival, r.req_id))
    n = len(reqs)
    if n == 0:
        return FaultSimResult(requests=[], promotions=0, makespan=0.0)
    b = RequestBatch.from_requests(reqs)
    key = pol.key_array(b.arrival, b.p_long, b.true_service,
                        tenant=b.tenant, tenants=b.tenants)
    start, finish, promoted, promos, shed, timeout, requeues = \
        simulate_grid_faults(
            b.arrival[None], b.true_service[None], key[None],
            (pol.aging.effective_tau(tau),), faults, deadline=deadline,
            in_service_timeout=in_service_timeout)
    for i, r in enumerate(reqs):
        r.start = float(start[0, i])
        r.finish = float(finish[0, i])
        r.promoted = bool(promoted[0, i])
        if shed[0, i]:
            r.meta["shed"] = True
        if timeout[0, i]:
            r.meta["timeout"] = True
    ok = ~shed[0] & ~timeout[0]
    makespan = float(finish[0, ok].max()) if ok.any() else 0.0
    done = [reqs[i] for i in np.argsort(np.where(ok, start[0], np.inf),
                                        kind="stable")]
    return FaultSimResult(requests=done, promotions=int(promos[0]),
                          makespan=makespan, shed=int(shed[0].sum()),
                          requeues=int(requeues[0]),
                          timeouts=int(timeout[0].sum()))


# ---------------------------------------------------------------------------
# Workload generators
# ---------------------------------------------------------------------------

@dataclass
class ServiceDist:
    """Truncated normal service-time distribution (paper §5.5 uses
    N(3.5, 0.8) short / N(8.9, 2.0) long for the RTX 4090 calibration)."""
    mean: float
    std: float
    floor: float = 0.05

    def sample(self, rng, size=None):
        return np.maximum(rng.normal(self.mean, self.std, size), self.floor)


def poisson_workload(rng, n: int, lam: float,
                     short: ServiceDist, long: ServiceDist,
                     mix_long: float = 0.5,
                     p_long_fn: Optional[Callable[[Request], float]] = None
                     ) -> List[Request]:
    """Open-loop Poisson arrivals with a short/long service mix."""
    arrivals = np.cumsum(rng.exponential(1.0 / lam, n))
    out = []
    for k in range(n):
        is_long = rng.random() < mix_long
        dist = long if is_long else short
        r = Request(req_id=k, arrival=float(arrivals[k]),
                    true_service=float(dist.sample(rng)),
                    klass="long" if is_long else "short")
        r.p_long = 1.0 if is_long else 0.0
        out.append(r)
    if p_long_fn is not None:
        for r in out:
            r.p_long = p_long_fn(r)
    return out


def burst_workload(rng, n_short: int, n_long: int,
                   short: ServiceDist, long: ServiceDist,
                   window: float = 0.05) -> List[Request]:
    """The paper's adversarial stress test: all requests arrive within
    ``window`` seconds (asyncio.gather analogue)."""
    out = []
    total = n_short + n_long
    order = rng.permutation(total)
    for pos, k in enumerate(order):
        is_long = k >= n_short
        dist = long if is_long else short
        r = Request(req_id=pos, arrival=float(rng.uniform(0, window)),
                    true_service=float(dist.sample(rng)),
                    klass="long" if is_long else "short")
        r.p_long = 1.0 if is_long else 0.0
        out.append(r)
    return out


def imperfect_predictor(rng, ranking_accuracy: float
                        ) -> Callable[[Request], float]:
    """Synthesise P(Long) scores achieving a target (Short, Long) pairwise
    ranking accuracy — used to propagate measured predictor fidelity into the
    queueing simulation without re-running the real predictor."""
    spread = _spread_for_accuracy(ranking_accuracy)

    def fn(req: Request) -> float:
        base = 0.75 if req.klass == "long" else 0.25
        return float(np.clip(rng.normal(base, spread), 0.0, 1.0))

    return fn


def _spread_for_accuracy(acc: float) -> float:
    """Noise sigma s.t. P(N(.75,s) > N(.25,s)) == acc (two-class gaussians)."""
    acc = min(max(acc, 0.5 + 1e-6), 1.0 - 1e-9)
    # P(X_l > X_s) = Phi(0.5 / (s*sqrt(2)))
    z = _probit(acc)
    return 0.5 / (z * math.sqrt(2.0)) if z > 0 else 1e6


def _probit(p: float) -> float:
    """Inverse standard-normal CDF (Acklam's rational approximation)."""
    a = [-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
         1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00]
    b = [-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
         6.680131188771972e+01, -1.328068155288572e+01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
         -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
         3.754408661907416e+00]
    plow, phigh = 0.02425, 1 - 0.02425
    if p < plow:
        q = math.sqrt(-2 * math.log(p))
        return (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / \
               ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    if p > phigh:
        return -_probit(1 - p)
    q = p - 0.5
    r = q * q
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * q / \
           (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)


# ---------------------------------------------------------------------------
# Queueing theory reference values (paper §2.4)
# ---------------------------------------------------------------------------

def pk_wait_fcfs(lam: float, es: float, es2: float) -> float:
    """Pollaczek-Khinchine mean FCFS waiting time.  es2 = E[S^2]."""
    rho = lam * es
    if rho >= 1.0:
        return float("inf")
    return lam * es2 / (2.0 * (1.0 - rho))


def cs2(service_times: np.ndarray) -> float:
    """Squared coefficient of variation (Table 1)."""
    s = np.asarray(service_times, float)
    return float(s.var() / s.mean() ** 2)
