"""Pruned SoA export of a trained GBDT ensemble + the binned fast paths.

``GBDTModel`` stores complete binary trees: ``2**(max_depth+1) - 1`` dense
slots per tree, most of them dead ``-1`` padding for real models.  This
module repacks a trained ensemble into layouts the admission path can
score fast:

**Flat SoA (host).**  Live nodes of all trees concatenated in per-tree
BFS order with sibling pairs adjacent, so one int32 ``child`` array
encodes both children (left at ``child``, right at ``child + 1``).
Leaves are *self-loops* (``child == self``) with an unsatisfiable
threshold, which removes the per-depth leaf select entirely.  Thresholds
are quantized to per-feature **bin ids**: the pack derives each feature's
edge table from the thresholds the ensemble actually uses, inputs are
binned once per batch (one ``searchsorted`` per feature), and traversal
is pure integer compares — ``go_right = xbin >= thr_bin`` is exactly
``x >= threshold`` for every float input (including NaN, which sorts
past the last edge and goes right, same as the dense traversal).

Two host scorers share this layout:

* a **native scorer** (``core._native``): a C loop nest compiled once at
  first use — trees outer, samples inner, so each tree's node block and
  the whole binned batch stay cache-resident — sharded across OS threads
  (the call releases the GIL).  Margins accumulate in tree order, so they
  are allclose (1 ulp-level) to the dense path, not bitwise;
* a **numpy traversal**: the depth-synchronous (T, B) frontier with
  preallocated index buffers, iterating exactly the pruned max depth.
  Bitwise identical to ``GBDTModel.predict_margin_dense``: same leaf
  values, same per-class pairwise summation order, same base-score add.
  Used when no C compiler is available (``REPRO_NO_NATIVE=1`` forces it).

**Padded per-tree SoA (device).**  The same pruned trees padded to the
max live node count M as ``(T, M)`` tensors with float thresholds
(leaves: ``+inf``) and in-tree child indices, consumed by the
tree-parallel Pallas kernel (``kernels.gbdt_infer``) and its jnp oracle
(``kernels.ref.gbdt_margins_packed_ref``).  The float compare
``go_right = ~(x < thr)`` matches the dense traversal for all finite
inputs; NaN features escape leaf self-loops, so the device path assumes
finite features (the 19 Clairvoyant features always are).

Host buffers are reused across calls and are not thread-safe; concurrent
scoring should use one PackedEnsemble per thread (the table arrays are
immutable and can be shared).
"""

from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import List

import numpy as np

from repro.core import _native

_LEAF_BIN = np.uint16(0xFFFF)   # > any input bin (edge tables cap at 0xFFFE)

_pool = None


def _thread_pool():
    global _pool
    if _pool is None:
        _pool = ThreadPoolExecutor(max(1, min(4, os.cpu_count() or 1)))
    return _pool


@dataclass
class PackedEnsemble:
    # flat SoA over all live nodes (host scorers)
    feat: np.ndarray        # (total,) int32  feature index (0 at leaves)
    thr_bin: np.ndarray     # (total,) uint16 go right iff xbin >= thr_bin
    child: np.ndarray       # (total,) int32  absolute left child; leaf: self
    value: np.ndarray       # (total,) float32
    roots: np.ndarray       # (T,) int32
    # padded per-tree SoA (Pallas kernel / jnp oracle)
    pfeat: np.ndarray       # (T, M) int32
    pthr: np.ndarray        # (T, M) float32, +inf at leaves
    pchild: np.ndarray      # (T, M) int32, in-tree left child; leaf: self
    pvalue: np.ndarray      # (T, M) float32
    bin_edges: List[np.ndarray]   # per feature, sorted float32 thresholds
    n_classes: int
    n_features: int
    depth: int              # max live depth over all trees
    base_score: float = 0.0
    _buffers: dict = field(default_factory=dict, repr=False, compare=False)
    _setup: dict = field(default_factory=dict, repr=False, compare=False)

    @property
    def num_trees(self) -> int:
        return self.roots.shape[0]

    @property
    def num_nodes(self) -> int:
        return self.feat.shape[0]

    def _edges_matrix(self) -> np.ndarray:
        """(F, Emax) NaN-padded per-feature edge tables (cached)."""
        mat = self._setup.get("edges_mat")
        if mat is None:
            emax = max([e.size for e in self.bin_edges] + [1])
            mat = np.full((self.n_features, emax), np.nan, np.float32)
            for f, e in enumerate(self.bin_edges):
                mat[f, :e.size] = e
            self._setup["edges_mat"] = mat
        return mat

    def bin_input(self, X: np.ndarray) -> np.ndarray:
        """(B, n_features) uint16 bin ids.

        Small batches (the serial serving path — B=1 per admission) use
        one broadcast compare against the cached NaN-padded edge matrix:
        ``sum(edges <= x)`` equals ``searchsorted(..., side="right")`` for
        every finite input and costs 3 numpy calls instead of one
        searchsorted per feature.  Large batches keep the per-feature
        searchsorted (linear in edges beats log only while B*Emax is
        small); non-finite inputs also take that path (NaN must sort past
        the last edge, as in the dense traversal).
        """
        X = np.asarray(X, np.float32)
        B = X.shape[0]
        if 0 < B <= 32 and np.isfinite(X).all():
            mat = self._edges_matrix()
            return (mat[None] <= X[:, :, None]).sum(axis=2).astype(np.uint16)
        out = np.empty((B, self.n_features), np.uint16)
        for f in range(self.n_features):
            edges = self.bin_edges[f]
            if edges.size:
                out[:, f] = np.searchsorted(edges, X[:, f], side="right")
            else:
                out[:, f] = 0
        return out

    # -- native scorer ------------------------------------------------------

    def _predict_margin_native(self, Xb: np.ndarray, fn) -> np.ndarray:
        import ctypes
        B = Xb.shape[0]
        K = self.n_classes
        out = np.zeros((B, K), np.float32)
        u16, f32 = ctypes.c_uint16, ctypes.c_float
        args = self._setup.get("cargs")
        if args is None:
            # the table arrays are immutable: build the pointer tuple once
            i32 = ctypes.c_int32
            args = (_native.as_ptr(self.feat, i32),
                    _native.as_ptr(self.thr_bin, u16),
                    _native.as_ptr(self.child, i32),
                    _native.as_ptr(self.value, f32),
                    _native.as_ptr(self.roots, i32),
                    self.roots.shape[0], K)
            self._setup["cargs"] = args

        def run(lo, hi):
            fn(*args, _native.as_ptr(Xb[lo:hi], u16), hi - lo,
               self.n_features, self.depth,
               _native.as_ptr(out[lo:hi], f32))

        # sharding only pays with spare cores; on <=2-core hosts the pool
        # dispatch overhead beats the overlap
        cores = os.cpu_count() or 1
        n_threads = min(4, cores) if cores >= 3 else 1
        if B >= 2 * n_threads and n_threads > 1:
            step = -(-B // n_threads)
            spans = [(lo, min(lo + step, B)) for lo in range(0, B, step)]
            futs = [_thread_pool().submit(run, lo, hi) for lo, hi in spans]
            for f in futs:
                f.result()
        else:
            run(0, B)
        out += self.base_score
        return out

    # -- numpy traversal ----------------------------------------------------

    def _predict_margin_numpy(self, Xb: np.ndarray) -> np.ndarray:
        T = self.roots.shape[0]
        B = Xb.shape[0]
        xb = Xb.ravel()                               # row-major (B, F)
        key = (T, B)
        bufs = self._buffers.get(key)
        if bufs is None:
            bufs = (np.empty((T, B), np.int32), np.empty((T, B), np.int32),
                    np.empty((T, B), np.uint16), np.empty((T, B), np.uint16),
                    np.empty((T, B), bool), np.empty((T, B), np.int32))
            self._buffers = {key: bufs}               # keep one shape only
        idx, fb, tb, xib, go, ch = bufs
        idx[:] = self.roots[:, None]
        colf = np.arange(B, dtype=np.int32) * self.n_features
        for _ in range(self.depth):
            np.take(self.feat, idx, out=fb)
            np.take(self.thr_bin, idx, out=tb)
            np.add(fb, colf[None, :], out=fb)         # flat index into xb
            np.take(xb, fb, out=xib)
            np.greater_equal(xib, tb, out=go)
            np.take(self.child, idx, out=ch)
            np.add(ch, go, out=idx)
        vals = self.value.take(idx)                   # (T, B) float32
        K = self.n_classes
        margins = vals.reshape(T // K, K, B).sum(axis=0).T.copy()
        margins += self.base_score
        return margins

    def predict_margin(self, X: np.ndarray) -> np.ndarray:
        """(B, n_classes) raw margins (allclose to the dense path; bitwise
        equal when the numpy traversal is used)."""
        X = np.asarray(X, np.float32)
        if X.shape[0] == 0:
            return np.zeros((0, self.n_classes), np.float32)
        Xb = self.bin_input(X)
        fn = _native.native_scorer()
        if fn is not None:
            return self._predict_margin_native(Xb, fn)
        return self._predict_margin_numpy(Xb)

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        from repro.core.gbdt import _softmax
        return _softmax(self.predict_margin(X))

    def predict_p_long(self, X: np.ndarray, long_class: int = 2) -> np.ndarray:
        return self.predict_proba(X)[:, long_class]


def pack_ensemble(model) -> PackedEnsemble:
    """Prune a dense ``GBDTModel`` into a :class:`PackedEnsemble`."""
    feats = np.asarray(model.feature)
    thrs = np.asarray(model.threshold, np.float32)
    vals = np.asarray(model.value, np.float32)
    T, N = feats.shape

    n_features = int(max(feats.max(), 0)) + 1
    # per-feature edge tables from the thresholds the ensemble actually uses
    bin_edges = []
    for f in range(n_features):
        used = thrs[feats == f]
        edges = np.unique(used.astype(np.float32))
        assert edges.size <= 0xFFFE, "too many distinct thresholds"
        bin_edges.append(edges)

    tree_feat, tree_bin, tree_thr, tree_child, tree_val = [], [], [], [], []
    max_nodes, max_depth = 1, 0
    for t in range(T):
        order = [0]
        left = []
        depth_of = [0]
        i = 0
        while i < len(order):
            d = order[i]
            if feats[t, d] >= 0 and 2 * d + 2 < N:
                left.append(len(order))
                order.append(2 * d + 1)
                order.append(2 * d + 2)
                depth_of.append(depth_of[i] + 1)
                depth_of.append(depth_of[i] + 1)
            else:
                left.append(i)                      # leaf: self-loop
            i += 1
        m = len(order)
        oa = np.asarray(order)
        lf = np.asarray(left, np.int32)
        fe = feats[t, oa]
        is_leaf = lf == np.arange(m, dtype=np.int32)
        f_packed = np.where(is_leaf, 0, np.maximum(fe, 0)).astype(np.int32)
        th = thrs[t, oa]
        tb = np.empty(m, np.uint16)
        for j in range(m):
            if is_leaf[j]:
                tb[j] = _LEAF_BIN
            else:
                e = bin_edges[fe[j]]
                tb[j] = np.searchsorted(e, th[j], side="left") + 1
        tree_feat.append(f_packed)
        tree_bin.append(tb)
        tree_thr.append(np.where(is_leaf, np.float32(np.inf), th))
        tree_child.append(lf)
        tree_val.append(vals[t, oa])
        max_nodes = max(max_nodes, m)
        max_depth = max(max_depth, max(depth_of))

    total = sum(a.shape[0] for a in tree_feat)
    flat_feat = np.empty(total, np.int32)
    flat_bin = np.empty(total, np.uint16)
    flat_child = np.empty(total, np.int32)
    flat_val = np.empty(total, np.float32)
    roots = np.empty(T, np.int32)
    pfeat = np.zeros((T, max_nodes), np.int32)
    pthr = np.full((T, max_nodes), np.inf, np.float32)
    pchild = np.tile(np.arange(max_nodes, dtype=np.int32), (T, 1))
    pvalue = np.zeros((T, max_nodes), np.float32)
    off = 0
    for t in range(T):
        m = tree_feat[t].shape[0]
        roots[t] = off
        flat_feat[off:off + m] = tree_feat[t]
        flat_bin[off:off + m] = tree_bin[t]
        flat_child[off:off + m] = tree_child[t] + off
        flat_val[off:off + m] = tree_val[t]
        pfeat[t, :m] = tree_feat[t]
        pthr[t, :m] = tree_thr[t]
        pchild[t, :m] = tree_child[t]
        pvalue[t, :m] = tree_val[t]
        off += m

    return PackedEnsemble(
        feat=flat_feat, thr_bin=flat_bin, child=flat_child, value=flat_val,
        roots=roots, pfeat=pfeat, pthr=pthr, pchild=pchild, pvalue=pvalue,
        bin_edges=bin_edges, n_classes=model.n_classes,
        n_features=n_features, depth=max_depth,
        base_score=float(model.base_score))
