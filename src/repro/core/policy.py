"""First-class scheduling policies (paper §3 + beyond-paper extensions).

The paper's contribution is an admission *policy* — predictive SJF with a
starvation guard — but policies used to live as a 3-string tuple whose
priority-key computation was duplicated across four layers (SJFQueue,
``sim_fast.dispatch_key``, ``core.sweep``, ``serving.server``).  This
module makes the policy a value:

* a :class:`Policy` owns the priority key in BOTH forms — ``key_array``
  for the struct-of-arrays simulation engines and ``key`` for the live
  one-request-at-a-time queue — so every consumer computes the same
  ordering from the same code;
* an :class:`AgingRule` generalises the hardwired ``wait > tau``
  starvation guard (``promote_oldest`` is the paper's rule; ``none``
  disables aging regardless of the tau passed at the call site);
* preemptive policies additionally own the preemption rule: when may a
  queued candidate evict the running request (``should_preempt``), what
  key does the evicted request re-enter the queue with (``requeue_key``),
  and — for multi-level feedback — how long a job may run before being
  demoted (``quantum_array``).  The DES engines execute these as
  re-enqueue events (``sim_fast.simulate_grid_preempt``); the live server
  executes them as segment-boundary cancellation + resume from the
  generated prefix (``serving.server``).

Registry
--------
Policies register under string names; ``"fcfs"`` / ``"sjf"`` /
``"sjf_oracle"`` are the seed aliases and stay bitwise trace-equivalent
to the reference simulator.  New in this layer:

``srpt``          preemptive shortest-remaining-predicted-time: the key is
                  the posterior-mean predicted service and decreases as the
                  job receives service; an arrival with a strictly smaller
                  predicted total evicts the running job at the next
                  decision point (Learning-to-Rank scheduling, Fu et al.).
``sjf_quantile``  uncertainty-aware SJF: the key is a high quantile
                  (mean + z*sigma of the two-class posterior mixture) of
                  predicted service, not the posterior mean — hedges
                  against confidently-wrong "short" predictions.
``mlfq``          multi-level feedback: jobs start in the predicted-class
                  queue with a service budget of ``slack x`` their
                  predicted service; jobs that outlive their prediction
                  are demoted to a background level that only runs when
                  the top level is empty.
``sjf_effective`` acceptance-aware SJF for speculative-decoding backends:
                  the key is predicted service divided by the expected
                  speculative speedup of the request's draft acceptance
                  rate — a token-long request that drafts well is
                  *effectively* short and ranks accordingly.
``fair_share``    per-tenant weighted fair share: the key is the tenant's
                  cumulative *predicted* work (weighted), so a tenant
                  flooding the queue only delays itself (start-time fair
                  queueing over the predictor's service estimates, using
                  ``Request.tenant``).

The class-conditional service estimates default to the paper's §5.5
RTX 4090 calibration (N(3.5, 0.8) short / N(8.9, 2.0) long); pass
``short``/``long`` moments to re-calibrate for another backend.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

# Engine execution modes (mirrored by the C loop in core/_native.py).
MODE_NONE = 0        # non-preemptive: key fixed at admission
MODE_SRPT = 1        # preempt on arrival; key decays with service received
MODE_QUANTUM = 2     # preempt on arrival + demote on quantum expiry

#: Key offset added per MLFQ demotion level.  Any level-l key sorts after
#: every level-(l-1) key because base keys are bounded far below this.
LEVEL_STRIDE = 1e9

# Paper §5.5 service calibration (RTX 4090): N(3.5, 0.8) / N(8.9, 2.0).
DEFAULT_SHORT = (3.5, 0.8)
DEFAULT_LONG = (8.9, 2.0)


@dataclass(frozen=True)
class AgingRule:
    """Starvation guard.  ``promote_oldest`` is the paper's §3.4 rule:
    at each dispatch decision, if the FIFO-oldest waiter has waited
    strictly more than tau, it is dispatched regardless of its key.
    ``none`` disables aging even when a tau is passed per-call."""

    mode: str = "promote_oldest"          # "promote_oldest" | "none"
    tau: Optional[float] = None           # default tau (per-call overrides)

    def __post_init__(self):
        if self.mode not in ("promote_oldest", "none"):
            raise ValueError(f"unknown aging mode {self.mode!r}")

    def effective_tau(self, override: Optional[float]) -> Optional[float]:
        """The tau the engines should enforce (None = guard off)."""
        if self.mode == "none":
            return None
        return self.tau if override is None else override


@dataclass(frozen=True)
class Policy:
    """A scheduling policy: priority key + aging + optional preemption.

    Subclasses override the ``key``/``key_array`` pair (they MUST agree)
    and, for preemptive policies, the requeue/quantum hooks.  Instances
    are immutable and shareable; stateful policies (fair share) return a
    per-queue clone from :meth:`fresh`.
    """

    name: str = "policy"
    aging: AgingRule = field(default_factory=AgingRule)
    #: class-conditional service moments (mean, std) for predictor-based
    #: service estimates; paper §5.5 calibration by default
    short: Tuple[float, float] = DEFAULT_SHORT
    long: Tuple[float, float] = DEFAULT_LONG

    # engine contract -------------------------------------------------------
    mode: int = MODE_NONE

    @property
    def preemptive(self) -> bool:
        return self.mode != MODE_NONE

    @property
    def uses_predictor(self) -> bool:
        """Whether the admission path should score prompts (P(Long))."""
        return True

    def fresh(self) -> "Policy":
        """Per-queue instance (identity for stateless policies)."""
        return self

    # priority keys ---------------------------------------------------------
    def key(self, req) -> float:
        """Scalar priority key for the live queue (lower = sooner)."""
        raise NotImplementedError

    def key_array(self, arrival: np.ndarray, p_long: np.ndarray,
                  true_service: np.ndarray, tenant=None,
                  tenants: Sequence[str] = ("default",)) -> np.ndarray:
        """Array form of :meth:`key` over an arrival-sorted batch."""
        raise NotImplementedError

    # predictor-derived service estimate ------------------------------------
    def predicted_service(self, p_long: float) -> float:
        """Posterior-mean service: E[S | P(Long)] under the two-class mix."""
        return (1.0 - p_long) * self.short[0] + p_long * self.long[0]

    def predicted_service_array(self, p_long: np.ndarray) -> np.ndarray:
        p = np.asarray(p_long, np.float64)
        return (1.0 - p) * self.short[0] + p * self.long[0]

    # dispatch feedback (live queue) ----------------------------------------
    def note_dispatch(self, key: float) -> None:
        """Called by the live queue when a request with ``key`` dispatches.
        Stateless policies ignore it; fair share advances its virtual
        clock (SCFQ) so late-joining tenants cannot replay history."""

    # preemption hooks (engines consult these only when ``preemptive``) -----
    # NOTE on engine contract: the compiled DES engines
    # (sim_fast.simulate_grid_preempt / _native.des_preempt_run_many)
    # implement these hook semantics natively for the two built-in modes
    # (strict key comparison, SRPT decay, LEVEL_STRIDE demotion) — they
    # cannot call back into Python per event.  A custom subclass that
    # overrides the hooks with bespoke logic is honored on the live
    # serving path (serving/server.py calls them); array sweeps require
    # one of the built-in modes.
    def should_preempt(self, running_key: float, candidate_key: float) -> bool:
        """May the best queued candidate evict the running request?
        ``running_key`` is the running request's *current* key (for SRPT:
        predicted remaining); strict comparison — ties never preempt."""
        return candidate_key < running_key

    def running_key(self, key0: float, received: float) -> float:
        """Current key of the running request after ``received`` seconds
        of service (SRPT decays; others are static).  Floored at 0: a
        job past its predicted total is "almost done" — it keeps the
        minimal remaining-key rather than going negative (negative keys
        would make a mispredicted long both unpreemptable while running
        and queue-jumping once requeued)."""
        if self.mode == MODE_SRPT:
            return max(key0 - received, 0.0)
        return key0

    def requeue_key(self, key0: float, received: float) -> float:
        """Key a preempted request re-enters the queue with.  For MLFQ
        this is the *demotion* hook (quantum expiry); plain preemption
        re-enters at :meth:`running_key`."""
        return self.running_key(key0, received)

    def quantum_array(self, arrival: np.ndarray, p_long: np.ndarray,
                      true_service: np.ndarray) -> Optional[np.ndarray]:
        """Per-request level-0 service budget (MODE_QUANTUM only)."""
        return None

    def quantum(self, p_long: float) -> Optional[float]:
        return None


# --------------------------------------------------------------------- seed
@dataclass(frozen=True)
class FCFS(Policy):
    """First-come-first-served: key = arrival time."""

    name: str = "fcfs"

    @property
    def uses_predictor(self) -> bool:
        return False

    def key(self, req) -> float:
        return req.arrival

    def key_array(self, arrival, p_long, true_service, tenant=None,
                  tenants=("default",)) -> np.ndarray:
        return arrival


@dataclass(frozen=True)
class PredictedSJF(Policy):
    """The paper's policy: key = P(Long), the continuous predictor score."""

    name: str = "sjf"

    def key(self, req) -> float:
        return req.p_long

    def key_array(self, arrival, p_long, true_service, tenant=None,
                  tenants=("default",)) -> np.ndarray:
        return p_long


@dataclass(frozen=True)
class OracleSJF(Policy):
    """Clairvoyant upper bound: key = true service time."""

    name: str = "sjf_oracle"

    @property
    def uses_predictor(self) -> bool:
        return False

    def key(self, req) -> float:
        return req.true_service

    def key_array(self, arrival, p_long, true_service, tenant=None,
                  tenants=("default",)) -> np.ndarray:
        return true_service


# ---------------------------------------------------------------- extensions
@dataclass(frozen=True)
class PredictedSRPT(Policy):
    """Preemptive shortest-remaining-predicted-time.

    Key = posterior-mean predicted service; while a request runs, its key
    decays by the service received, and an arrival whose predicted total
    is strictly below the running request's predicted remaining evicts it
    at the next decision point (segment boundary on the live engine,
    arrival event in the DES).
    """

    name: str = "srpt"
    mode: int = MODE_SRPT

    def key(self, req) -> float:
        return self.predicted_service(req.p_long)

    def key_array(self, arrival, p_long, true_service, tenant=None,
                  tenants=("default",)) -> np.ndarray:
        return self.predicted_service_array(p_long)


@dataclass(frozen=True)
class QuantileSJF(Policy):
    """Uncertainty-aware SJF: key = high-quantile predicted service.

    Plain SJF keys on the posterior mean, which is a monotone transform
    of P(Long) — it cannot distinguish a 95%-confident "short" from a
    60%-confident one.  This key evaluates predicted service at the
    *pessimistic* posterior ``p_hi = clip(p + z * sqrt(p (1-p)))``
    (z = Phi^-1(q), default q = 0.90): confident predictions keep their
    rank while uncertain mid-posterior scores are hedged toward the long
    class, so a 60%-confident "short" sorts after a 95%-confident one
    (uncertainty-aware length prediction, 2604.00499).
    """

    name: str = "sjf_quantile"
    z: float = 1.2815515655446004          # Phi^-1(0.90)

    def _hedged(self, p):
        p_hi = np.clip(p + self.z * np.sqrt(np.maximum(p * (1.0 - p), 0.0)),
                       0.0, 1.0)
        return (1.0 - p_hi) * self.short[0] + p_hi * self.long[0]

    def key(self, req) -> float:
        return float(self._hedged(float(req.p_long)))

    def key_array(self, arrival, p_long, true_service, tenant=None,
                  tenants=("default",)) -> np.ndarray:
        return self._hedged(np.asarray(p_long, np.float64))


@dataclass(frozen=True)
class MLFQ(Policy):
    """Multi-level feedback over the predicted class.

    Level 0 orders by P(Long) (the paper's key) and grants each job a
    service budget of ``slack x`` its predicted service; a job that
    outlives its prediction is demoted to the background level
    (key + ``LEVEL_STRIDE``), which only runs when level 0 is empty.
    Arrivals preempt strictly-worse running jobs, so a mispredicted
    long can no longer hold the head of the line.
    """

    name: str = "mlfq"
    mode: int = MODE_QUANTUM
    slack: float = 1.5

    def key(self, req) -> float:
        return req.p_long

    def key_array(self, arrival, p_long, true_service, tenant=None,
                  tenants=("default",)) -> np.ndarray:
        return np.asarray(p_long, np.float64)

    def requeue_key(self, key0: float, received: float) -> float:
        return key0 + LEVEL_STRIDE          # demotion

    def quantum_array(self, arrival, p_long, true_service):
        return self.slack * self.predicted_service_array(p_long)

    def quantum(self, p_long: float) -> Optional[float]:
        return self.slack * self.predicted_service(p_long)


@dataclass(frozen=True)
class WeightedFairShare(Policy):
    """Per-tenant weighted fair share over predicted work.

    Key = the tenant's virtual finish tag: ``max(tenant's last finish
    tag, virtual time) + predicted service / weight`` — self-clocked fair
    queueing (SCFQ) over the predictor's estimates.  A tenant flooding
    the queue inflates only its own tags, so light tenants keep
    dispatching; the virtual-time floor (advanced by the live queue via
    :meth:`note_dispatch`) stops a late-joining tenant from replaying
    the incumbents' whole service history.  ``weights`` maps tenant
    name -> share weight (default 1.0; higher = larger share).

    The array form tags a one-shot admission batch from a zero virtual
    clock (the DES engines precompute static keys, so there is no
    dispatch feedback); it matches the scalar form exactly for a fresh
    queue tagged before any dispatch.
    """

    name: str = "fair_share"
    weights: Tuple[Tuple[str, float], ...] = ()

    def fresh(self) -> "WeightedFairShare":
        clone = replace(self)
        object.__setattr__(clone, "_credit", {})
        object.__setattr__(clone, "_vtime", 0.0)
        return clone

    def _weight(self, tenant: str) -> float:
        return dict(self.weights).get(tenant, 1.0)

    def key(self, req) -> float:
        credit = getattr(self, "_credit", None)
        if credit is None:                  # registry instance: lazily init
            credit = {}
            object.__setattr__(self, "_credit", credit)
            object.__setattr__(self, "_vtime", 0.0)
        cost = self.predicted_service(req.p_long) / self._weight(req.tenant)
        start = max(credit.get(req.tenant, 0.0), self._vtime)
        credit[req.tenant] = start + cost
        return credit[req.tenant]

    def note_dispatch(self, key: float) -> None:
        if key > getattr(self, "_vtime", 0.0):
            object.__setattr__(self, "_vtime", key)

    def key_array(self, arrival, p_long, true_service, tenant=None,
                  tenants=("default",)) -> np.ndarray:
        n = len(arrival)
        pred = self.predicted_service_array(p_long)
        if tenant is None:
            tenant = np.zeros(n, np.int32)
        w = np.array([self._weight(t) for t in tenants], np.float64)
        w = w[np.minimum(tenant, len(w) - 1)] if len(w) else np.ones(n)
        share = pred / w
        key = np.empty(n, np.float64)
        for code in np.unique(tenant):
            m = tenant == code
            key[m] = np.cumsum(share[m])
        return key


@dataclass(frozen=True)
class EffectiveSJF(Policy):
    """Acceptance-aware SJF: key = predicted service / expected speedup.

    Under speculative decoding a request's wall-clock cost is not its
    token count — it is the token count divided by the speculative
    speedup, which varies per request with draft acceptance (predictable
    prompts draft well, adversarial ones do not).  This key divides the
    posterior-mean predicted service by
    ``serving.service_time.expected_speedup(accept_rate, draft_k)`` so a
    token-long request that speculates well can rank ahead of a
    token-short one that does not.  Requests without an ``accept_rate``
    (None) fall back to ``prior_accept``; with a uniform acceptance rate
    the key is a positive scalar multiple of plain SJF's, i.e. the
    ordering degenerates to token-count SJF exactly.
    """

    name: str = "sjf_effective"
    draft_k: int = 4
    draft_cost: float = 0.15
    prior_accept: float = 0.5

    def _speedup(self, accept_rate):
        # lazy import: serving.service_time imports core.simulation,
        # which reaches back into this module via core.scheduler
        from repro.serving.service_time import expected_speedup
        return expected_speedup(accept_rate, self.draft_k, self.draft_cost)

    def key(self, req) -> float:
        a = getattr(req, "accept_rate", None)
        if a is None:
            a = self.prior_accept
        return self.predicted_service(req.p_long) / float(self._speedup(a))

    def key_array(self, arrival, p_long, true_service, tenant=None,
                  tenants=("default",), accept_rate=None) -> np.ndarray:
        pred = self.predicted_service_array(p_long)
        if accept_rate is None:
            return pred / float(self._speedup(self.prior_accept))
        a = np.where(np.isnan(np.asarray(accept_rate, np.float64)),
                     self.prior_accept, np.asarray(accept_rate, np.float64))
        return pred / self._speedup(a)


# ------------------------------------------------------------------ registry
_REGISTRY: Dict[str, Policy] = {}


def register(policy: Policy) -> Policy:
    """Register ``policy`` under its name (later wins)."""
    _REGISTRY[policy.name] = policy
    return policy


def registered_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def get_policy(spec) -> Policy:
    """Resolve a policy spec: a :class:`Policy` passes through, a string
    looks up the registry.  Unknown names raise ``ValueError`` listing the
    registered policies (an exception, not an assert, so ``python -O``
    builds fail loudly too)."""
    if isinstance(spec, Policy):
        return spec
    if isinstance(spec, str):
        try:
            return _REGISTRY[spec]
        except KeyError:
            raise ValueError(
                f"unknown policy {spec!r}; registered: "
                f"{', '.join(sorted(_REGISTRY))}") from None
    raise TypeError(f"policy spec must be str or Policy, got {type(spec)!r}")


register(FCFS())
register(PredictedSJF())
register(OracleSJF())
register(PredictedSRPT())
register(QuantileSJF())
register(MLFQ())
register(WeightedFairShare())
register(EffectiveSJF())

#: The seed policy names (kept for backward compatibility; the full set is
#: :func:`registered_names`).
SEED_POLICIES = ("fcfs", "sjf", "sjf_oracle")
