"""SJF admission queue with starvation timeout (paper §3.4).

The queue is an **indexed struct-of-arrays binary min-heap**
(:class:`ArrayHeap`) keyed on ascending ``(P(Long), seq)``, plus:

* **starvation guard** — before each dispatch decision, if the longest-waiting
  request has waited more than tau, it is promoted to the head regardless of
  its predicted priority (tracked via an arrival-order FIFO);
* **lazy cancellation** — client disconnects (and guard promotions) mark
  heap entries dead in O(1) via the heap's position index; tombstones are
  skipped at pop time, and when they outnumber live entries the heap
  compacts in one vectorized pass — amortized O(1) per tombstone, never a
  per-element re-heapify;
* **policy pluggability** — the priority key comes from a first-class
  :class:`repro.core.policy.Policy` (FCFS / SJF / oracle / SRPT / quantile /
  MLFQ / fair share are the same queue with different keys), which is how
  the benchmark ablations flip between conditions.  Preemptive policies
  additionally use :meth:`peek` (best queued key without dispatching) and
  :meth:`push_requeue` (re-admission of an evicted request with its
  policy-computed requeue key).

Medium requests get no discrete treatment: the continuous P(Long) score is
the key, producing the smooth ordering gradient described in the paper.

The simulation fast path (``core.sim_fast``) runs this same dispatch rule
over pure arrays in compiled code; this class is the serving-path
(one-request-at-a-time) form.  ``MinHeap`` is the seed tuple heap, kept
as the equivalence oracle.
"""

from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import numpy as np

from repro.core.policy import SEED_POLICIES, get_policy

#: Seed policy names (compat alias; the registry holds the full set).
POLICIES = SEED_POLICIES


@dataclass
class Request:
    """One admission-layer request."""
    req_id: int
    prompt: str = ""
    arrival: float = 0.0
    p_long: float = 0.0           # predictor score (priority key under sjf)
    true_service: float = 0.0     # oracle service time (sim / oracle policy)
    klass: str = ""               # "short" | "medium" | "long" (ground truth)
    tenant: str = "default"
    # predicted/observed draft acceptance rate under speculative decoding
    # (None = unknown; acceptance-aware policies fall back to their prior)
    accept_rate: Optional[float] = None
    meta: dict = field(default_factory=dict)
    # filled by the dispatcher / simulator
    start: Optional[float] = None
    finish: Optional[float] = None
    promoted: bool = False
    cancelled: bool = False

    @property
    def wait(self) -> float:
        """Queue wait; NaN (not None) before dispatch so aggregation and
        formatting never hit a ``NoneType``."""
        return (self.start - self.arrival) if self.start is not None \
            else float("nan")

    @property
    def sojourn(self) -> float:
        """Queue-to-completion time; NaN before completion."""
        return (self.finish - self.arrival) if self.finish is not None \
            else float("nan")


class MinHeap:
    """Array binary heap of (key, seq, item); seq breaks ties FIFO."""

    def __init__(self):
        self._a: list = []

    def __len__(self):
        return len(self._a)

    def push(self, key, seq, item):
        a = self._a
        a.append((key, seq, item))
        i = len(a) - 1
        while i > 0:
            parent = (i - 1) >> 1
            if a[parent] <= a[i]:
                break
            a[parent], a[i] = a[i], a[parent]
            i = parent

    def pop(self):
        a = self._a
        if not a:
            raise IndexError("pop from empty heap")
        top = a[0]
        last = a.pop()
        if a:
            a[0] = last
            i, n = 0, len(a)
            while True:
                l, r = 2 * i + 1, 2 * i + 2
                smallest = i
                if l < n and a[l] < a[smallest]:
                    smallest = l
                if r < n and a[r] < a[smallest]:
                    smallest = r
                if smallest == i:
                    break
                a[i], a[smallest] = a[smallest], a[i]
                i = smallest
        return top

    def peek(self):
        return self._a[0]

    def invariant_ok(self) -> bool:
        a = self._a
        return all(a[(i - 1) >> 1] <= a[i] for i in range(1, len(a)))


class ArrayHeap:
    """Indexed SoA binary min-heap over ``(key, seq)`` with tombstones.

    Parallel numpy columns (float64 key / int64 seq / int64 id) instead of
    a list of tuples; a position map ``id -> slot`` is maintained through
    sifts so :meth:`kill` is O(1) — mark dead, no re-heapify.  Dead entries
    keep their ordering key, are skipped at pop, and once they outnumber
    the live ones the heap compacts in one vectorized lexsort pass (a
    key-sorted array is a valid binary heap) — amortized O(1) per
    tombstone.
    """

    _MIN_COMPACT = 32     # don't bother compacting tiny heaps

    def __init__(self, capacity: int = 16):
        capacity = max(capacity, 1)
        self._key = np.empty(capacity, np.float64)
        self._seq = np.empty(capacity, np.int64)
        self._id = np.empty(capacity, np.int64)
        self._dead = np.zeros(capacity, bool)
        self._pos: dict[int, int] = {}
        self._n = 0           # slots in use (live + dead)
        self._ndead = 0

    def __len__(self) -> int:
        return self._n - self._ndead

    def _less(self, a: int, b: int) -> bool:
        ka, kb = self._key[a], self._key[b]
        return bool(ka < kb or (ka == kb and self._seq[a] < self._seq[b]))

    def _swap(self, a: int, b: int) -> None:
        k, s, i, d = self._key, self._seq, self._id, self._dead
        k[a], k[b] = k[b], k[a]
        s[a], s[b] = s[b], s[a]
        i[a], i[b] = i[b], i[a]
        d[a], d[b] = d[b], d[a]
        self._pos[int(i[a])] = a
        self._pos[int(i[b])] = b

    def _grow(self) -> None:
        cap = self._key.shape[0] * 2
        for name in ("_key", "_seq", "_id", "_dead"):
            old = getattr(self, name)
            new = np.zeros(cap, old.dtype) if old.dtype == bool \
                else np.empty(cap, old.dtype)
            new[:self._n] = old[:self._n]
            setattr(self, name, new)

    def push(self, key: float, seq: int, item_id: int) -> None:
        slot = self._pos.get(item_id)
        if slot is not None:
            if not self._dead[slot]:
                raise ValueError(f"duplicate heap id {item_id}")
            # cancel-then-retry of the same id: evict the tombstone so the
            # position index stays one-to-one
            self._remove_at(slot)
            self._ndead -= 1
        if self._n == self._key.shape[0]:
            self._grow()
        c = self._n
        self._n += 1
        self._key[c] = key
        self._seq[c] = seq
        self._id[c] = item_id
        self._dead[c] = False
        self._pos[item_id] = c
        self._sift_up(c)

    def _sift_up(self, c: int) -> None:
        while c > 0:
            parent = (c - 1) >> 1
            if not self._less(c, parent):
                break
            self._swap(c, parent)
            c = parent

    def _sift_down(self, c: int) -> None:
        n = self._n
        while True:
            l, r = 2 * c + 1, 2 * c + 2
            smallest = c
            if l < n and self._less(l, smallest):
                smallest = l
            if r < n and self._less(r, smallest):
                smallest = r
            if smallest == c:
                return
            self._swap(c, smallest)
            c = smallest

    def _remove_at(self, slot: int) -> None:
        """Physically delete the entry at ``slot`` (swap-with-last)."""
        last = self._n - 1
        if slot != last:
            self._swap(slot, last)    # moves the victim's pos to `last`...
        self._n = last
        del self._pos[int(self._id[last])]   # ...so delete it afterwards
        if slot < last:
            self._sift_down(slot)
            self._sift_up(slot)

    def _remove_root(self):
        root = (float(self._key[0]), int(self._seq[0]), int(self._id[0]),
                bool(self._dead[0]))
        self._remove_at(0)
        return root

    def kill(self, item_id: int) -> bool:
        """O(1) tombstone; the entry stays in place until popped/compacted."""
        slot = self._pos.get(item_id)
        if slot is None or self._dead[slot]:
            return False
        self._dead[slot] = True
        self._ndead += 1
        if self._ndead > len(self) and self._n >= self._MIN_COMPACT:
            self.compact()
        return True

    def compact(self) -> None:
        """Drop all tombstones in one vectorized pass (sorted => heap)."""
        n = self._n
        live = ~self._dead[:n]
        order = np.lexsort((self._seq[:n][live], self._key[:n][live]))
        for name in ("_key", "_seq", "_id"):
            arr = getattr(self, name)
            arr[:order.shape[0]] = arr[:n][live][order]
        self._n = order.shape[0]
        self._ndead = 0
        self._dead[:self._n] = False
        self._pos = {int(i): s for s, i in enumerate(self._id[:self._n])}

    def pop(self):
        """Min live ``(key, seq, id)``; skips tombstones."""
        while self._n:
            key, seq, item_id, dead = self._remove_root()
            if dead:
                self._ndead -= 1
                continue
            return key, seq, item_id
        raise IndexError("pop from empty heap")

    def peek(self):
        """Min live ``(key, seq, id)`` WITHOUT removing it, or None.
        Dead roots encountered on the way are physically dropped (they
        were already logically deleted), so peek is amortized O(1)."""
        while self._n and self._dead[0]:
            self._remove_at(0)
            self._ndead -= 1
        if not self._n:
            return None
        return float(self._key[0]), int(self._seq[0]), int(self._id[0])

    def invariant_ok(self) -> bool:
        ok = all(not self._less(i, (i - 1) >> 1) for i in range(1, self._n))
        pos_ok = all(int(self._id[s]) == i and s < self._n
                     for i, s in self._pos.items())
        return ok and pos_ok and len(self._pos) == self._n


class SJFQueue:
    """Admission queue implementing the paper's dispatch rule."""

    def __init__(self, policy="sjf", tau: Optional[float] = None):
        # accepts a registry name or a Policy instance; stateful policies
        # (fair share) get a per-queue clone
        self.policy_obj = get_policy(policy).fresh()
        self.policy = self.policy_obj.name
        self.tau = self.policy_obj.aging.effective_tau(tau)
        self._heap = ArrayHeap()
        self._fifo: deque = deque()       # arrival order for starvation guard
        self._seq = itertools.count()
        self._live: dict[int, Request] = {}
        self.stats = {"promotions": 0, "cancellations": 0, "dispatched": 0,
                      "preemptions": 0, "requeues": 0}

    def __len__(self):
        return len(self._live)

    def _key(self, req: Request) -> float:
        return self.policy_obj.key(req)

    def push(self, req: Request) -> None:
        seq = next(self._seq)
        key = self._key(req)
        # preemptive consumers derive requeue keys from the admission key
        # and read the current key back for eligibility scans
        req.meta["policy_key0"] = key
        req.meta["queue_key"] = key
        self._live[req.req_id] = req
        self._heap.push(key, seq, req.req_id)
        self._fifo.append(req)

    def push_requeue(self, req: Request, key: float,
                     reason: str = "preempt") -> None:
        """Re-admit a preempted (``reason="preempt"``) or fault-requeued
        (``reason="fault"``, e.g. engine crash) request with an explicit
        requeue key.  It keeps its original arrival, so the starvation
        guard still sees its true wait; the new heap seq makes re-entries
        FIFO among equal keys."""
        seq = next(self._seq)
        req.meta["queue_key"] = key
        self._live[req.req_id] = req
        self._heap.push(key, seq, req.req_id)
        # re-insert at its arrival rank (a stale FIFO entry may survive from
        # the original push; drop it so the guard sees the request once).
        # The deque is already near-sorted by arrival, so Timsort makes
        # this effectively O(n) per eviction, not O(n log n).
        self._fifo = deque(sorted(
            [r for r in self._fifo if r.req_id != req.req_id] + [req],
            key=lambda r: (r.arrival, r.req_id)))
        self.stats["requeues" if reason == "fault" else "preemptions"] += 1

    def peek(self) -> Optional[tuple]:
        """Best queued ``(key, Request)`` without dispatching (preemption
        checks); skips cancellation tombstones."""
        top = self._heap.peek()
        if top is None:
            return None
        key, _, req_id = top
        return key, self._live[req_id]

    def cancel(self, req_id: int) -> bool:
        """Client disconnect while queued: O(1) lazy heap deletion."""
        req = self._live.pop(req_id, None)
        if req is None:
            return False
        req.cancelled = True
        self._heap.kill(req_id)
        self.stats["cancellations"] += 1
        return True

    def remove(self, req_id: int) -> Optional[Request]:
        """Take a live request out WITHOUT marking it cancelled — used when
        re-routing (hedged dispatch, failover) rather than disconnecting."""
        req = self._live.pop(req_id, None)
        if req is not None:
            self._heap.kill(req_id)
        return req

    def _prune_fifo(self) -> None:
        # drop cancelled or already-dispatched entries from the front
        while self._fifo and (self._fifo[0].cancelled
                              or self._fifo[0].req_id not in self._live):
            self._fifo.popleft()

    def _starving(self, now: float) -> Optional[Request]:
        if self.tau is None:
            return None
        self._prune_fifo()
        if self._fifo and (now - self._fifo[0].arrival) > self.tau:
            return self._fifo[0]
        return None

    def pop(self, now: float) -> Optional[Request]:
        """Next request to dispatch at time ``now`` (None if empty)."""
        victim = self._starving(now)
        if victim is not None:
            # promote the longest-waiting request past the heap; its heap
            # entry becomes a tombstone
            self._fifo.popleft()
            del self._live[victim.req_id]
            self._heap.kill(victim.req_id)
            victim.promoted = True
            self.stats["promotions"] += 1
            self.stats["dispatched"] += 1
            self.policy_obj.note_dispatch(victim.meta.get("queue_key", 0.0))
            return victim
        if len(self._heap):
            key, _, req_id = self._heap.pop()
            req = self._live.pop(req_id)
            self.stats["dispatched"] += 1
            self.policy_obj.note_dispatch(key)
            return req
        return None

    def pop_many(self, k: int, now: float) -> list:
        """Pop up to ``k`` requests for lane back-fill, applying the full
        dispatch rule — starvation check included — *between* pops.

        A naive batched back-fill (take the heap's top-k in one go) gets
        the ordering wrong whenever the guard matters: popping the best
        key can leave the FIFO-oldest waiter over tau, in which case the
        SECOND slot must go to the promoted waiter even though its key
        sorts last.  Each pop here re-evaluates the guard at ``now``, so
        ``pop_many(k, now)`` is exactly ``[pop(now) for _ in range(k)]``
        (tests/test_scheduler.py has the regression test against the
        naive top-k order)."""
        out = []
        for _ in range(int(k)):
            req = self.pop(now=now)
            if req is None:
                break
            out.append(req)
        return out

    def oldest_wait(self, now: float) -> float:
        self._prune_fifo()
        return (now - self._fifo[0].arrival) if self._fifo else 0.0

    def waiting(self) -> list:
        """Snapshot of the live queued requests (arrival order)."""
        return sorted(self._live.values(),
                      key=lambda r: (r.arrival, r.req_id))

    def live(self):
        """Unsorted view of the live queued requests (O(1); for hot-path
        scans that only need a min, not an ordering)."""
        return self._live.values()
