"""SJF admission queue with starvation timeout (paper §3.4).

A from-scratch array-based binary min-heap keyed on ascending P(Long), plus:

* **starvation guard** — before each dispatch decision, if the longest-waiting
  request has waited more than tau, it is promoted to the head regardless of
  its predicted priority (tracked via an arrival-order FIFO);
* **lazy cancellation** — client disconnects mark entries dead; tombstones are
  skipped at pop time (heap deletion without re-heapify);
* **policy pluggability** — FCFS / SJF(predicted) / SJF(oracle) are the same
  queue with different priority keys, which is how the benchmark ablations
  flip between the paper's conditions.

Medium requests get no discrete treatment: the continuous P(Long) score is
the key, producing the smooth ordering gradient described in the paper.
"""

from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

POLICIES = ("fcfs", "sjf", "sjf_oracle")


@dataclass
class Request:
    """One admission-layer request."""
    req_id: int
    prompt: str = ""
    arrival: float = 0.0
    p_long: float = 0.0           # predictor score (priority key under sjf)
    true_service: float = 0.0     # oracle service time (sim / oracle policy)
    klass: str = ""               # "short" | "medium" | "long" (ground truth)
    tenant: str = "default"
    meta: dict = field(default_factory=dict)
    # filled by the dispatcher / simulator
    start: Optional[float] = None
    finish: Optional[float] = None
    promoted: bool = False
    cancelled: bool = False

    @property
    def wait(self) -> float:
        return (self.start - self.arrival) if self.start is not None else None

    @property
    def sojourn(self) -> float:
        return (self.finish - self.arrival) if self.finish is not None else None


class MinHeap:
    """Array binary heap of (key, seq, item); seq breaks ties FIFO."""

    def __init__(self):
        self._a: list = []

    def __len__(self):
        return len(self._a)

    def push(self, key, seq, item):
        a = self._a
        a.append((key, seq, item))
        i = len(a) - 1
        while i > 0:
            parent = (i - 1) >> 1
            if a[parent] <= a[i]:
                break
            a[parent], a[i] = a[i], a[parent]
            i = parent

    def pop(self):
        a = self._a
        if not a:
            raise IndexError("pop from empty heap")
        top = a[0]
        last = a.pop()
        if a:
            a[0] = last
            i, n = 0, len(a)
            while True:
                l, r = 2 * i + 1, 2 * i + 2
                smallest = i
                if l < n and a[l] < a[smallest]:
                    smallest = l
                if r < n and a[r] < a[smallest]:
                    smallest = r
                if smallest == i:
                    break
                a[i], a[smallest] = a[smallest], a[i]
                i = smallest
        return top

    def peek(self):
        return self._a[0]

    def invariant_ok(self) -> bool:
        a = self._a
        return all(a[(i - 1) >> 1] <= a[i] for i in range(1, len(a)))


class SJFQueue:
    """Admission queue implementing the paper's dispatch rule."""

    def __init__(self, policy: str = "sjf", tau: Optional[float] = None):
        assert policy in POLICIES, policy
        self.policy = policy
        self.tau = tau
        self._heap = MinHeap()
        self._fifo: deque = deque()       # arrival order for starvation guard
        self._seq = itertools.count()
        self._live: dict[int, Request] = {}
        self.stats = {"promotions": 0, "cancellations": 0, "dispatched": 0}

    def __len__(self):
        return len(self._live)

    def _key(self, req: Request) -> float:
        if self.policy == "fcfs":
            return req.arrival
        if self.policy == "sjf_oracle":
            return req.true_service
        return req.p_long

    def push(self, req: Request) -> None:
        seq = next(self._seq)
        self._live[req.req_id] = req
        self._heap.push(self._key(req), seq, req)
        self._fifo.append(req)

    def cancel(self, req_id: int) -> bool:
        """Client disconnect while queued: lazy heap deletion."""
        req = self._live.pop(req_id, None)
        if req is None:
            return False
        req.cancelled = True
        self.stats["cancellations"] += 1
        return True

    def _prune_fifo(self) -> None:
        # drop cancelled or already-dispatched entries from the front
        while self._fifo and (self._fifo[0].cancelled
                              or self._fifo[0].req_id not in self._live):
            self._fifo.popleft()

    def _starving(self, now: float) -> Optional[Request]:
        if self.tau is None:
            return None
        self._prune_fifo()
        if self._fifo and (now - self._fifo[0].arrival) > self.tau:
            return self._fifo[0]
        return None

    def pop(self, now: float) -> Optional[Request]:
        """Next request to dispatch at time ``now`` (None if empty)."""
        victim = self._starving(now)
        if victim is not None:
            # promote the longest-waiting request past the heap
            self._fifo.popleft()
            del self._live[victim.req_id]
            victim.promoted = True
            self.stats["promotions"] += 1
            self.stats["dispatched"] += 1
            return victim
        while len(self._heap):
            _, _, req = self._heap.pop()
            if req.cancelled or req.req_id not in self._live:
                continue  # tombstone
            del self._live[req.req_id]
            self.stats["dispatched"] += 1
            return req
        return None

    def oldest_wait(self, now: float) -> float:
        self._prune_fifo()
        return (now - self._fifo[0].arrival) if self._fifo else 0.0
