"""Vectorized serial-backend DES: SoA request batches + compiled engine.

The seed simulator (kept as ``simulation.simulate_reference``) walks one
Python ``Request`` object per event through a tuple-heap — minutes of
interpreter time for the paper's sweep grids.  This module rebuilds that
stack around struct-of-arrays data:

* :class:`RequestBatch` — numpy columns (arrival / true_service / p_long /
  klass codes / tenant codes) for a whole arrival stream, with vectorized
  Poisson and burst generators replacing the per-object loops;
* :func:`simulate_arrays` / :func:`simulate_grid` — the event loop over
  those arrays.  The primary engine is ``_native.des_run_many``, a C loop
  (compiled once at first use) driving an index-based binary min-heap
  keyed on ``(key[i], i)`` with lazy tombstones for starvation
  promotions; ``simulate_grid`` runs G independent simulations
  (policy x tau x rho x seed cells) in ONE call so a whole sweep costs one
  FFI round trip;
* when no C compiler exists, a fallback runs the same per-event loop over
  plain floats with stdlib ``heapq`` (C-speed sifts) — slower than the
  native engine but still well ahead of the object/tuple-heap reference;
* :func:`simulate_grid_preempt` — the *preemptive* counterpart for
  policies with eviction semantics (``core.policy`` MODE_SRPT /
  MODE_QUANTUM): arrivals can evict the running request and re-enqueue
  its remaining service, quantum expiry demotes (MLFQ).  Also a C loop
  with a bitwise-identical heapq fallback;
* :func:`simulate_grid_servers` / :func:`simulate_batch_servers` — the
  *c-server* engine (PR 5): bounded-concurrency decode lanes with a
  per-lane slowdown s(c), a memory-token admission budget and srpt lane
  eviction — the virtual-time mirror of ``serving/batching.py``.  At
  c=1 with unit slowdown it is bitwise trace-equivalent to the serial
  engines (both non-preemptive and srpt rows).

Priority keys come from the policy layer (``core.policy``): every
registered policy — seed fcfs/sjf/sjf_oracle plus srpt, sjf_quantile,
mlfq, fair_share — supplies its key in array form via
:func:`dispatch_key` / ``Policy.key_array``.

Both engines are trace-equivalent to the reference loop — same float64
clock accumulation, same ``(key, seq)`` tie-breaking, same strict
``wait > tau`` promotion rule — bitwise, not just allclose
(tests/test_simulation.py).

Sweep usage (see ``core.sweep`` for the full grid API)::

    from repro.core.sim_fast import RequestBatch, simulate_batch
    from repro.core.sweep import sweep_poisson

    rng = np.random.default_rng(0)
    batch = RequestBatch.poisson(rng, n=2000, lam=0.12, short=S, long=L)
    res = simulate_batch(batch, policy="sjf", tau=10.5)
    res.percentile(50, klass="short")          # one cell

    sweep = sweep_poisson(                      # whole grid, one call
        conditions=[("fcfs", None), ("sjf", 10.5)],
        rhos=(0.5, 0.74, 0.85), seeds=range(5), n=2000,
        short=S, long=L)
    sweep.metric("short_p50")                   # (C, R, S) array
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import _native
from repro.core.policy import (LEVEL_STRIDE, MODE_NONE, MODE_QUANTUM,
                               MODE_SRPT, Policy, get_policy)
from repro.core.scheduler import POLICIES, Request

KLASSES = ("", "short", "medium", "long")
_KLASS_CODE = {k: i for i, k in enumerate(KLASSES)}


def _klass_codes(names: Sequence[str]) -> np.ndarray:
    return np.array([_KLASS_CODE.get(k, 0) for k in names], np.int8)


@dataclass
class RequestBatch:
    """Struct-of-arrays arrival stream (one row per request)."""

    arrival: np.ndarray        # (n,) float64
    true_service: np.ndarray   # (n,) float64
    p_long: np.ndarray         # (n,) float64
    klass: np.ndarray          # (n,) int8, index into KLASSES
    tenant: np.ndarray         # (n,) int32, index into ``tenants``
    req_id: np.ndarray         # (n,) int64
    tenants: Tuple[str, ...] = ("default",)

    def __len__(self) -> int:
        return self.arrival.shape[0]

    def __post_init__(self):
        self.arrival = np.ascontiguousarray(self.arrival, np.float64)
        self.true_service = np.ascontiguousarray(self.true_service,
                                                 np.float64)
        self.p_long = np.ascontiguousarray(self.p_long, np.float64)
        self.klass = np.ascontiguousarray(self.klass, np.int8)
        self.tenant = np.ascontiguousarray(self.tenant, np.int32)
        self.req_id = np.ascontiguousarray(self.req_id, np.int64)

    # -- construction -------------------------------------------------------

    @classmethod
    def from_arrays(cls, arrival, true_service, p_long=None, klass=None,
                    req_id=None) -> "RequestBatch":
        n = len(arrival)
        if p_long is None:
            p_long = np.zeros(n)
        if klass is None:
            klass = np.zeros(n, np.int8)
        else:
            klass = np.asarray(klass)
            if klass.dtype.kind in "US":
                klass = _klass_codes(klass)
        if req_id is None:
            req_id = np.arange(n, dtype=np.int64)
        return cls(arrival=np.asarray(arrival, np.float64),
                   true_service=np.asarray(true_service, np.float64),
                   p_long=np.asarray(p_long, np.float64),
                   klass=np.asarray(klass, np.int8),
                   tenant=np.zeros(n, np.int32), req_id=req_id)

    @classmethod
    def from_requests(cls, requests: Sequence[Request]) -> "RequestBatch":
        tenants = tuple(dict.fromkeys(r.tenant for r in requests)) or \
            ("default",)
        tcode = {t: i for i, t in enumerate(tenants)}
        return cls(
            arrival=np.array([r.arrival for r in requests], np.float64),
            true_service=np.array([r.true_service for r in requests],
                                  np.float64),
            p_long=np.array([r.p_long for r in requests], np.float64),
            klass=_klass_codes([r.klass for r in requests]),
            tenant=np.array([tcode[r.tenant] for r in requests], np.int32),
            req_id=np.array([r.req_id for r in requests], np.int64),
            tenants=tenants)

    def to_requests(self) -> List[Request]:
        return [Request(req_id=int(self.req_id[i]),
                        arrival=float(self.arrival[i]),
                        true_service=float(self.true_service[i]),
                        p_long=float(self.p_long[i]),
                        klass=KLASSES[self.klass[i]],
                        tenant=self.tenants[self.tenant[i]])
                for i in range(len(self))]

    # -- vectorized workload generators -------------------------------------

    @classmethod
    def poisson(cls, rng, n: int, lam: float, short, long,
                mix_long: float = 0.5) -> "RequestBatch":
        """Open-loop Poisson arrivals, short/long service mix (one shot —
        no per-object loop; draw order differs from the seed generator)."""
        arrival = np.cumsum(rng.exponential(1.0 / lam, n))
        is_long = rng.random(n) < mix_long
        service = np.where(is_long, long.sample(rng, n),
                           short.sample(rng, n))
        klass = np.where(is_long, _KLASS_CODE["long"],
                         _KLASS_CODE["short"]).astype(np.int8)
        return cls.from_arrays(arrival, service,
                               p_long=is_long.astype(np.float64),
                               klass=klass)

    @classmethod
    def burst(cls, rng, n_short: int, n_long: int, short, long,
              window: float = 0.05) -> "RequestBatch":
        """All requests arrive within ``window`` seconds (§5.5 stress)."""
        total = n_short + n_long
        is_long = rng.permutation(total) >= n_short
        arrival = rng.uniform(0, window, total)
        service = np.where(is_long, long.sample(rng, total),
                           short.sample(rng, total))
        klass = np.where(is_long, _KLASS_CODE["long"],
                         _KLASS_CODE["short"]).astype(np.int8)
        return cls.from_arrays(arrival, service,
                               p_long=is_long.astype(np.float64),
                               klass=klass)


def dispatch_key(policy, arrival: np.ndarray, p_long: np.ndarray,
                 true_service: np.ndarray, tenant=None,
                 tenants: Sequence[str] = ("default",)) -> np.ndarray:
    """The queue priority key of each request, as an array.

    ``policy`` is a registry name or a :class:`~repro.core.policy.Policy`;
    unknown names raise ``ValueError`` listing the registered policies
    (``get_policy``) — an exception, not an assert, so the check survives
    ``python -O``.  Rows must be arrival-sorted (stateful keys such as
    fair share accumulate in arrival order).
    """
    return get_policy(policy).key_array(arrival, p_long, true_service,
                                        tenant=tenant, tenants=tenants)


def speculative_service(true_service, accept_rate, draft_k: int,
                        draft_cost: float = 0.15) -> np.ndarray:
    """Per-request speculative service-rate modifier.

    Mirrors a draft-verify decode backend in the DES: each request's
    wall-clock service is its serial service divided by
    ``serving.service_time.expected_speedup`` of its draft acceptance
    rate.  NaN acceptance (unknown) is treated as 0.0 — the backend
    still pays the draft overhead it gets nothing back for.
    ``draft_k == 0`` returns the service values unchanged (the
    no-speculation identity, bitwise).
    """
    svc = np.ascontiguousarray(true_service, np.float64)
    if draft_k == 0:
        return svc
    from repro.serving.service_time import expected_speedup
    a = np.asarray(accept_rate, np.float64)
    a = np.where(np.isnan(a), 0.0, a)
    return svc / expected_speedup(a, draft_k, draft_cost)


# ---------------------------------------------------------------------------
# Engines.  Contract: ``arrival`` ascending (ties broken by array index,
# which is the reference's (arrival, req_id) push order -> heap seq).
# ---------------------------------------------------------------------------

def _simulate_arrays_python(arrival, service, key, tau):
    """Fallback engine (no C compiler): the same per-event loop over plain
    floats, with stdlib ``heapq`` doing the (key, seq) sifts in C.  Bitwise
    trace-equivalent to the reference — identical float ops, identical
    tie-breaking, identical strict ``(now - arrival) > tau`` promotion."""
    import heapq
    n = arrival.shape[0]
    arr = arrival.tolist()
    svc = service.tolist()
    ks = key.tolist()
    start = np.zeros(n)
    finish = np.zeros(n)
    promoted = np.zeros(n, bool)
    done = [False] * n
    heap: list = []
    guard = tau is not None
    t = 0.0
    i_arr = 0
    oldest = 0
    promos = 0
    ndone = 0
    while ndone < n:
        if i_arr == ndone:                        # queue empty: jump
            a = arr[i_arr]
            if t < a:
                t = a
        while i_arr < n and arr[i_arr] <= t:
            heapq.heappush(heap, (ks[i_arr], i_arr))
            i_arr += 1
        while done[oldest]:
            oldest += 1
        if guard and (t - arr[oldest]) > tau:
            j = oldest                            # promote past the heap;
            promoted[j] = True                    # stale entry -> tombstone
            promos += 1
        else:
            while True:
                _, j = heapq.heappop(heap)
                if not done[j]:
                    break
        done[j] = True
        start[j] = t
        t += svc[j]
        finish[j] = t
        ndone += 1
    return start, finish, promoted, promos


def simulate_grid(arrival, service, key, tau, engine: str = "auto"):
    """G independent simulations in one call.

    ``arrival``/``service``/``key``: (G, n) float64, each row ascending in
    arrival; ``tau``: length-G sequence (None entries disable the guard).
    Returns ``(start, finish, promoted, promotions)`` with shapes
    ((G, n), (G, n), (G, n) bool, (G,) int64).
    """
    arrival = np.ascontiguousarray(arrival, np.float64)
    service = np.ascontiguousarray(service, np.float64)
    key = np.ascontiguousarray(key, np.float64)
    G, n = arrival.shape
    # NaN = guard disabled (None); any real tau — including negative, which
    # promotes every waiter — keeps the reference's strict wait > tau rule
    tau_arr = np.array([np.nan if t is None else float(t) for t in tau],
                       np.float64)
    if tau_arr.shape != (G,):
        raise ValueError(f"tau must have length {G}")
    start = np.empty((G, n))
    finish = np.empty((G, n))
    promoted_u8 = np.zeros((G, n), np.uint8)
    promotions = np.zeros(G, np.int64)
    if n == 0:
        return start, finish, promoted_u8.astype(bool), promotions
    if engine not in ("auto", "native", "python"):
        raise ValueError(f"unknown engine {engine!r}")
    fn = _native.native_des() if engine in ("auto", "native") else None
    if engine == "native" and fn is None:
        raise RuntimeError("native DES engine unavailable")
    if fn is not None:
        import ctypes
        heap = np.empty(n, np.int32)
        done = np.empty(n, np.uint8)
        pd = ctypes.c_double
        fn(_native.as_ptr(arrival, pd), _native.as_ptr(service, pd),
           _native.as_ptr(key, pd), _native.as_ptr(tau_arr, pd), G, n,
           _native.as_ptr(start, pd), _native.as_ptr(finish, pd),
           _native.as_ptr(promoted_u8, ctypes.c_uint8),
           _native.as_ptr(promotions, ctypes.c_int64),
           _native.as_ptr(heap, ctypes.c_int32),
           _native.as_ptr(done, ctypes.c_uint8))
        return start, finish, promoted_u8.astype(bool), promotions
    promoted = np.zeros((G, n), bool)
    for g in range(G):
        tg = None if np.isnan(tau_arr[g]) else float(tau_arr[g])
        start[g], finish[g], promoted[g], promos = _simulate_arrays_python(
            arrival[g], service[g], key[g], tg)
        promotions[g] = promos
    return start, finish, promoted, promotions


def simulate_arrays(arrival, service, key, tau: Optional[float],
                    engine: str = "auto"):
    """One simulation over flat (n,) arrays; see :func:`simulate_grid`."""
    start, finish, promoted, promotions = simulate_grid(
        arrival[None], service[None], key[None], (tau,), engine=engine)
    return start[0], finish[0], promoted[0], int(promotions[0])


# ---------------------------------------------------------------------------
# Preemptive engine (policy.MODE_SRPT / MODE_QUANTUM).
#
# Service is sliced at *events*: an arrival whose key strictly beats the
# running request's current key evicts it (the remaining service is
# re-enqueued with the policy's requeue key), and in quantum mode a job
# that exhausts its level-0 budget is demoted (key + LEVEL_STRIDE) and
# re-enqueued.  The starvation guard applies at every dispatch decision,
# exactly like the non-preemptive engines.  ``start`` records the FIRST
# dispatch; ``finish`` the completion.
# ---------------------------------------------------------------------------

def _simulate_preempt_python(arrival, service, key, tau, mode, quanta):
    """One preemptive cell over plain floats + stdlib heapq.  The C engine
    (``_native.des_preempt_run_many``) runs the identical event sequence
    with identical float64 arithmetic — results match bitwise."""
    import heapq
    n = arrival.shape[0]
    INF = float("inf")
    arr = arrival.tolist()
    svc = service.tolist()
    k0 = key.tolist()
    curk = list(k0)
    budget = quanta.tolist() if (mode == MODE_QUANTUM and quanta is not None) \
        else [INF] * n
    start = np.zeros(n)
    finish = np.zeros(n)
    promoted = np.zeros(n, bool)
    started = [False] * n
    state = [0] * n           # 0 waiting, 1 queued, 2 running, 3 done
    used = [0.0] * n          # service received so far
    last_seq = [-1] * n
    heap: list = []
    guard = tau is not None
    seqc = 0
    t = 0.0
    i_arr = 0
    oldest = 0
    nq = 0                    # live queued entries
    ndone = 0
    promos = 0
    preempts = 0
    run = -1

    def push(j):
        nonlocal seqc, nq
        heapq.heappush(heap, (curk[j], seqc, j))
        last_seq[j] = seqc
        seqc += 1
        nq += 1

    def pop_valid():
        nonlocal nq
        while True:
            _, s, j = heapq.heappop(heap)
            if state[j] == 1 and s == last_seq[j]:
                nq -= 1
                return j

    def peek_valid_key():
        while heap:
            k, s, j = heap[0]
            if state[j] == 1 and s == last_seq[j]:
                return k
            heapq.heappop(heap)
        return None

    while ndone < n:
        if run < 0:
            if nq == 0 and t < arr[i_arr]:
                t = arr[i_arr]                    # idle: jump to next arrival
            while i_arr < n and arr[i_arr] <= t:
                state[i_arr] = 1
                push(i_arr)
                i_arr += 1
            while state[oldest] == 3:
                oldest += 1
            if guard and state[oldest] == 1 and (t - arr[oldest]) > tau:
                j = oldest                        # starvation promotion past
                promoted[j] = True                # the heap (entry -> stale)
                promos += 1
                nq -= 1
            else:
                j = pop_valid()
            state[j] = 2
            run = j
            if not started[j]:
                started[j] = True
                start[j] = t
        rem = svc[run] - used[run]
        t_fin = t + rem
        t_q = t + (budget[run] - used[run]) if budget[run] < INF else INF
        t_arr = arr[i_arr] if i_arr < n else INF
        if t_fin <= t_arr and t_fin <= t_q:
            t = t_fin                             # completion
            used[run] = svc[run]
            finish[run] = t
            state[run] = 3
            ndone += 1
            run = -1
        elif t_q <= t_arr:
            used[run] += t_q - t                  # quantum expiry: demote
            t = t_q
            budget[run] = INF
            curk[run] = curk[run] + LEVEL_STRIDE
            state[run] = 1
            push(run)
            run = -1
        else:
            used[run] += t_arr - t                # arrival event(s)
            t = t_arr
            while i_arr < n and arr[i_arr] <= t:
                state[i_arr] = 1
                push(i_arr)
                i_arr += 1
            bk = peek_valid_key()
            # SRPT remaining floored at 0 (policy.Policy.running_key): a
            # job past its predicted total keeps the minimal key instead
            # of going negative (unpreemptable + queue-jumping on requeue)
            rk = max(k0[run] - used[run], 0.0) if mode == MODE_SRPT \
                else curk[run]
            if bk is not None and bk < rk:
                if mode == MODE_SRPT:
                    curk[run] = rk
                state[run] = 1                    # evict the running request
                push(run)
                preempts += 1
                j = pop_valid()
                state[j] = 2
                run = j
                if not started[j]:
                    started[j] = True
                    start[j] = t
    return start, finish, promoted, promos, preempts


def simulate_grid_preempt(arrival, service, key, tau, mode, quanta=None,
                          engine: str = "auto"):
    """G independent *preemptive* simulations in one call.

    Same layout as :func:`simulate_grid` plus ``mode`` (length-G ints:
    ``policy.MODE_SRPT`` / ``MODE_QUANTUM``) and ``quanta`` ((G, n)
    level-0 service budgets; ignored for SRPT rows).  Returns
    ``(start, finish, promoted, promotions, preemptions)``.
    """
    arrival = np.ascontiguousarray(arrival, np.float64)
    service = np.ascontiguousarray(service, np.float64)
    key = np.ascontiguousarray(key, np.float64)
    G, n = arrival.shape
    tau_arr = np.array([np.nan if t is None else float(t) for t in tau],
                       np.float64)
    mode_arr = np.ascontiguousarray(mode, np.int8)
    if quanta is None:
        quanta = np.full((G, n), np.inf)
    quanta = np.ascontiguousarray(quanta, np.float64)
    if tau_arr.shape != (G,) or mode_arr.shape != (G,):
        raise ValueError(f"tau and mode must have length {G}")
    start = np.empty((G, n))
    finish = np.empty((G, n))
    promoted = np.zeros((G, n), bool)
    promotions = np.zeros(G, np.int64)
    preemptions = np.zeros(G, np.int64)
    if n == 0:
        return start, finish, promoted, promotions, preemptions
    if engine not in ("auto", "native", "python"):
        raise ValueError(f"unknown engine {engine!r}")
    fn = _native.native_des_preempt() if engine in ("auto", "native") else None
    if engine == "native" and fn is None:
        raise RuntimeError("native preemptive DES engine unavailable")
    if fn is not None:
        import ctypes
        cap = 4 * n                       # pushes <= arrivals+preempts+demotes
        hkey = np.empty(cap, np.float64)
        hseq = np.empty(cap, np.int64)
        hidx = np.empty(cap, np.int32)
        used = np.empty(n, np.float64)
        curk = np.empty(n, np.float64)
        budget = np.empty(n, np.float64)
        lastseq = np.empty(n, np.int64)
        st = np.empty(n, np.uint8)
        promoted_u8 = np.zeros((G, n), np.uint8)
        pd = ctypes.c_double
        fn(_native.as_ptr(arrival, pd), _native.as_ptr(service, pd),
           _native.as_ptr(key, pd), _native.as_ptr(tau_arr, pd),
           _native.as_ptr(quanta, pd),
           _native.as_ptr(mode_arr, ctypes.c_int8), G, n,
           _native.as_ptr(start, pd), _native.as_ptr(finish, pd),
           _native.as_ptr(promoted_u8, ctypes.c_uint8),
           _native.as_ptr(promotions, ctypes.c_int64),
           _native.as_ptr(preemptions, ctypes.c_int64),
           _native.as_ptr(hkey, pd), _native.as_ptr(hseq, ctypes.c_int64),
           _native.as_ptr(hidx, ctypes.c_int32),
           _native.as_ptr(used, pd), _native.as_ptr(curk, pd),
           _native.as_ptr(budget, pd),
           _native.as_ptr(lastseq, ctypes.c_int64),
           _native.as_ptr(st, ctypes.c_uint8))
        return start, finish, promoted_u8.astype(bool), promotions, \
            preemptions
    for g in range(G):
        tg = None if np.isnan(tau_arr[g]) else float(tau_arr[g])
        start[g], finish[g], promoted[g], promos, pre = \
            _simulate_preempt_python(arrival[g], service[g], key[g], tg,
                                     int(mode_arr[g]), quanta[g])
        promotions[g] = promos
        preemptions[g] = pre
    return start, finish, promoted, promotions, preemptions


# ---------------------------------------------------------------------------
# c-server engine (bounded-concurrency decode lanes, serving/batching.py's
# simulation mirror).
#
# The server has ``c`` lanes and a memory-token budget.  Admission follows
# the same dispatch rule as the serial engines — starvation guard, then the
# policy key — applied whenever a lane is free; the queue head is admitted
# only if its memory demand fits the remaining budget (strict order: a
# blocked head is never bypassed).  Lanes in service progress at a
# concurrency-dependent rate: with k busy lanes each lane's service is
# stretched by ``slowdown[k-1]`` (s(1) = 1; batched decode is not free —
# calibrate s(c) from the real engine, benchmarks/batching_bench.py), so
# remaining work is re-scaled whenever the busy count changes.
#
# Modes: MODE_NONE (key policies) and MODE_SRPT (an arrival whose key
# strictly beats the *worst* running lane's current remaining-key evicts
# that lane; eviction releases its memory reservation — resume re-prefills,
# the PR-4 machinery).  MODE_QUANTUM is rejected: per-lane quantum
# accounting under rate re-scaling is future work.
#
# Bitwise contract at c=1 with slowdown (1.0,): MODE_NONE rows reproduce
# ``_simulate_arrays_python`` (and therefore ``simulate_reference``) traces
# exactly — work advances only when the busy count changes, so a request
# admitted at ``t`` finishes at ``t + service*1.0`` with identical float
# ops; MODE_SRPT rows reproduce ``_simulate_preempt_python`` — work
# advances at every event, matching its incremental ``used += dt``
# accumulation (tests/test_batching.py fuzzes both).
# ---------------------------------------------------------------------------

def _simulate_cserver_python(arrival, service, key, tau, c, slowdown,
                             mem, mem_budget, mode):
    import heapq
    n = arrival.shape[0]
    INF = float("inf")
    arr = arrival.tolist()
    svc = service.tolist()
    k0 = key.tolist()
    curk = list(k0)
    s = list(slowdown)
    if len(s) < c:
        raise ValueError(f"slowdown needs >= {c} entries, got {len(s)}")
    srpt = mode == MODE_SRPT
    if mode not in (MODE_NONE, MODE_SRPT):
        raise ValueError("c-server engine supports key-based and srpt "
                         "policies only (quantum/MLFQ accounting under "
                         "rate re-scaling is not implemented)")
    memd = mem.tolist() if mem is not None else None
    start = np.zeros(n)
    finish = np.zeros(n)
    promoted = np.zeros(n, bool)
    started = [False] * n
    state = [0] * n            # 0 waiting, 1 queued, 2 running, 3 done
    used = [0.0] * n           # unscaled service received
    last_seq = [-1] * n
    heap: list = []
    guard = tau is not None
    seqc = 0
    t = 0.0
    last_t = 0.0               # time ``used`` was last advanced
    i_arr = 0
    oldest = 0
    running: list = []
    nq = 0
    ndone = 0
    promos = 0
    preempts = 0
    used_mem = 0.0

    def push(j):
        nonlocal seqc, nq
        heapq.heappush(heap, (curk[j], seqc, j))
        last_seq[j] = seqc
        seqc += 1
        nq += 1

    def heap_best():
        while heap:
            kk, sq, j = heap[0]
            if state[j] == 1 and sq == last_seq[j]:
                return kk, j
            heapq.heappop(heap)
        return None

    def pop_valid():
        nonlocal nq
        while True:
            _, sq, j = heapq.heappop(heap)
            if state[j] == 1 and sq == last_seq[j]:
                nq -= 1
                return j

    def advance(t_new):
        """Credit service progress up to ``t_new`` at the current busy
        count.  Called at every k change; additionally at every event in
        srpt mode (whose preemption key needs up-to-date ``used``)."""
        nonlocal last_t
        kcur = len(running)
        if kcur and t_new > last_t:
            d = (t_new - last_t) / s[kcur - 1]
            for j in running:
                used[j] += d
        last_t = t_new

    def next_completion():
        kcur = len(running)
        if not kcur:
            return INF, -1
        best_j, best_rem = -1, INF
        for j in running:
            r = svc[j] - used[j]
            if r < best_rem:
                best_rem, best_j = r, j
        return last_t + best_rem * s[kcur - 1], best_j

    def run_key(j):
        return max(k0[j] - used[j], 0.0) if srpt else curk[j]

    def fits(j):
        if memd is None:
            return True
        # idle override: all reservations are held by running lanes, so an
        # empty server admits even an over-budget head (it must run
        # eventually; memory pressure may serialize but never deadlock)
        return used_mem + memd[j] <= mem_budget or not running

    def dispatch(j, promo):
        nonlocal promos, used_mem
        advance(t)
        if promo:
            promoted[j] = True
            promos += 1
        state[j] = 2
        running.append(j)
        if memd is not None:
            used_mem += memd[j]
        if not started[j]:
            started[j] = True
            start[j] = t

    def admit_loop():
        nonlocal oldest, nq
        while len(running) < c and nq > 0:
            while state[oldest] == 3:
                oldest += 1
            o = oldest             # FIFO-oldest *queued* (skip running)
            while state[o] != 1:
                o += 1
            if guard and (t - arr[o]) > tau:
                j, promo = o, True
            else:
                j, promo = heap_best()[1], False
            if not fits(j):
                return             # memory-blocked head: no bypass
            if promo:
                nq -= 1            # heap entry goes stale via state change
            else:
                j = pop_valid()
            dispatch(j, promo)

    while ndone < n:
        if not running and nq == 0:
            a = arr[i_arr]
            if t < a:
                t = a
                last_t = t
        t_fin, j_fin = next_completion()
        t_arr = arr[i_arr] if i_arr < n else INF
        if t_fin <= t_arr:                        # completion event
            t = t_fin
            advance(t)
            running.remove(j_fin)
            used[j_fin] = svc[j_fin]
            finish[j_fin] = t
            state[j_fin] = 3
            ndone += 1
            if memd is not None:
                # clear float residue once nothing holds a reservation
                used_mem = max(0.0, used_mem - memd[j_fin]) if running \
                    else 0.0
            while i_arr < n and arr[i_arr] <= t:
                state[i_arr] = 1
                push(i_arr)
                i_arr += 1
            admit_loop()
        else:                                     # arrival event(s)
            if t_arr > t:          # after an idle jump t may already be past
                t = t_arr          # the next arrival; never rewind the clock
            if srpt:
                advance(t)
            while i_arr < n and arr[i_arr] <= t:
                state[i_arr] = 1
                push(i_arr)
                i_arr += 1
            if len(running) < c:
                admit_loop()
            elif srpt:
                best = heap_best()
                if best is not None:
                    victim = max(running, key=lambda j: (run_key(j), j))
                    vk = run_key(victim)
                    # eviction frees the victim's reservation (resume
                    # re-prefills); the candidate must fit what remains
                    fits_after = memd is None or (
                        used_mem - memd[victim] + memd[best[1]]
                        <= mem_budget) or used_mem - memd[victim] <= 0.0
                    if best[0] < vk and fits_after:
                        advance(t)
                        running.remove(victim)
                        if memd is not None:
                            used_mem = max(0.0,
                                           used_mem - memd[victim])
                        curk[victim] = vk
                        state[victim] = 1
                        push(victim)
                        preempts += 1
                        j = pop_valid()
                        dispatch(j, False)
    return start, finish, promoted, promos, preempts


def simulate_grid_servers(arrival, service, key, tau, n_servers: int,
                          slowdown=None, mem=None, mem_budget=None,
                          mode=None):
    """G independent c-server simulations in one call.

    Layout follows :func:`simulate_grid` — ``arrival``/``service``/``key``
    (G, n) float64, rows arrival-sorted; ``tau`` length-G (None = guard
    off) — plus:

    * ``n_servers``: lane count c (shared across rows);
    * ``slowdown``: per-lane service stretch ``s[k-1]`` at k busy lanes
      (default all 1.0 — ideal scaling);
    * ``mem`` (G, n) + ``mem_budget``: per-request memory-token demand
      and the shared budget (None = unconstrained);
    * ``mode``: length-G ints, ``MODE_NONE`` or ``MODE_SRPT`` per row.

    Returns ``(start, finish, promoted, promotions, preemptions)``.
    At c=1 with unit slowdown, MODE_NONE rows are bitwise equal to
    :func:`simulate_grid` and MODE_SRPT rows to
    :func:`simulate_grid_preempt`.
    """
    arrival = np.ascontiguousarray(arrival, np.float64)
    service = np.ascontiguousarray(service, np.float64)
    key = np.ascontiguousarray(key, np.float64)
    G, n = arrival.shape
    c = int(n_servers)
    if c < 1:
        raise ValueError(f"need >= 1 server, got {n_servers}")
    slowdown = tuple(float(x) for x in slowdown) if slowdown is not None \
        else (1.0,) * c
    if any(x <= 0 for x in slowdown):
        raise ValueError(f"slowdown factors must be positive: {slowdown}")
    tau_arr = np.array([np.nan if x is None else float(x) for x in tau],
                       np.float64)
    mode_arr = np.zeros(G, np.int8) if mode is None \
        else np.ascontiguousarray(mode, np.int8)
    if tau_arr.shape != (G,) or mode_arr.shape != (G,):
        raise ValueError(f"tau and mode must have length {G}")
    if mem is not None:
        mem = np.ascontiguousarray(mem, np.float64)
        if mem_budget is None:
            raise ValueError("mem given without mem_budget")
    start = np.empty((G, n))
    finish = np.empty((G, n))
    promoted = np.zeros((G, n), bool)
    promotions = np.zeros(G, np.int64)
    preemptions = np.zeros(G, np.int64)
    if n == 0:
        return start, finish, promoted, promotions, preemptions
    for g in range(G):
        tg = None if np.isnan(tau_arr[g]) else float(tau_arr[g])
        start[g], finish[g], promoted[g], promos, pre = \
            _simulate_cserver_python(
                arrival[g], service[g], key[g], tg, c, slowdown,
                None if mem is None else mem[g], mem_budget,
                int(mode_arr[g]))
        promotions[g] = promos
        preemptions[g] = pre
    return start, finish, promoted, promotions, preemptions


# ---------------------------------------------------------------------------
# Block-paged c-server engine (serving/paging.py's simulation mirror).
#
# Same event loop, dispatch rule and slowdown model as the c-server engine,
# with the worst-case memory reservation replaced by the page-granular
# model the paged engine implements:
#
# * admission charges the PROMPT's pages only (minus the shared-prefix
#   pages when the prefix is already registered — the prefix cache);
# * a running request's footprint grows linearly from its prompt pages to
#   its total pages as decode progresses (one page per page_size tokens,
#   smoothed to a rate — the DES doesn't model page-boundary staircase);
# * pool exhaustion preempts the YOUNGEST-dispatched lane (never a solo
#   lane): its pages are freed and it re-queues work-conserving under its
#   original key, but its re-admission demand is its full current
#   footprint (resume re-prefills prompt + generated, so the pages come
#   back at once).  No admission happens at the exhaustion instant —
#   the freed lane back-fills at the next arrival/completion event —
#   which breaks the release/re-admit livelock the same way the live
#   engine's per-boundary deferral does.
# * a request's shared-prefix group registers at its first dispatch
#   (the live engine registers right after prefill); later members admit
#   warm — their shared pages are free and ``prefill_saved`` seconds of
#   service (the skipped prefix prefill) are discounted.  Cache eviction
#   under pressure is not modeled (cached pages are reclaimable, so they
#   never block an allocation; dropping them early only loses hits).
#
# Bitwise contract at c=1: a solo lane is never preempted and idle-
# override admits every head, so the page model is inert — rows reproduce
# ``_simulate_cserver_python`` (and through it the serial engines) float
# op for float op.
# ---------------------------------------------------------------------------

def _simulate_paged_python(arrival, service, key, tau, c, slowdown, mode,
                           prompt_pages, total_pages, share_group,
                           shared_pages, prefill_saved, n_pages):
    import heapq
    n = arrival.shape[0]
    INF = float("inf")
    arr = arrival.tolist()
    svc = service.tolist()          # mutated: warm admits discount prefill
    k0 = key.tolist()
    curk = list(k0)
    s = list(slowdown)
    if len(s) < c:
        raise ValueError(f"slowdown needs >= {c} entries, got {len(s)}")
    srpt = mode == MODE_SRPT
    if mode not in (MODE_NONE, MODE_SRPT):
        raise ValueError("paged engine supports key-based and srpt "
                         "policies only")
    ppg = [min(float(x), float(n_pages)) for x in prompt_pages]
    tpg = [min(float(x), float(n_pages)) for x in total_pages]
    grp = share_group.tolist()
    spg = shared_pages.tolist()
    saved = prefill_saved.tolist()
    start = np.zeros(n)
    finish = np.zeros(n)
    promoted = np.zeros(n, bool)
    started = [False] * n
    state = [0] * n            # 0 waiting, 1 queued, 2 running, 3 done
    used = [0.0] * n           # unscaled service received
    last_seq = [-1] * n
    base_pg = [0.0] * n        # admission pages (fixed at first dispatch)
    rate = [0.0] * n           # pages per unit of credited service
    disp_seq = [-1] * n        # dispatch order (preemption picks youngest)
    heap: list = []
    guard = tau is not None
    seqc = 0
    dseq = 0
    t = 0.0
    last_t = 0.0
    i_arr = 0
    oldest = 0
    running: list = []
    nq = 0
    ndone = 0
    promos = 0
    preempts = 0
    prefix_hits = 0
    peak_pages = 0.0
    registered: set = set()

    def push(j):
        nonlocal seqc, nq
        heapq.heappush(heap, (curk[j], seqc, j))
        last_seq[j] = seqc
        seqc += 1
        nq += 1

    def heap_best():
        while heap:
            kk, sq, j = heap[0]
            if state[j] == 1 and sq == last_seq[j]:
                return kk, j
            heapq.heappop(heap)
        return None

    def pop_valid():
        nonlocal nq
        while True:
            _, sq, j = heapq.heappop(heap)
            if state[j] == 1 and sq == last_seq[j]:
                nq -= 1
                return j

    def advance(t_new):
        nonlocal last_t
        kcur = len(running)
        if kcur and t_new > last_t:
            d = (t_new - last_t) / s[kcur - 1]
            for j in running:
                used[j] += d
        last_t = t_new

    def next_completion():
        kcur = len(running)
        if not kcur:
            return INF, -1
        best_j, best_rem = -1, INF
        for j in running:
            r = svc[j] - used[j]
            if r < best_rem:
                best_rem, best_j = r, j
        return last_t + best_rem * s[kcur - 1], best_j

    def run_key(j):
        return max(k0[j] - used[j], 0.0) if srpt else curk[j]

    def held(j):
        return min(base_pg[j] + rate[j] * used[j], tpg[j])

    def pool():
        return sum(held(j) for j in running)

    def demand(j):
        """Pages the pool must produce to (re-)dispatch j."""
        if disp_seq[j] >= 0:                       # resume: re-prefills all
            return base_pg[j] + rate[j] * used[j]
        if grp[j] >= 0 and grp[j] in registered:   # warm admit
            return ppg[j] - spg[j]
        return ppg[j]

    def fits(j):
        # idle override, as in the c-server engine: an empty server
        # admits any head (capped demand always fits a full pool)
        return pool() + demand(j) <= n_pages or not running

    def next_exhaustion():
        kcur = len(running)
        if kcur <= 1:                              # solo lane never preempts
            return INF
        r_tot = sum(rate[j] for j in running if used[j] < svc[j])
        if r_tot <= 0.0:
            return INF
        head = n_pages - pool()
        if head <= 0.0:
            return last_t
        return last_t + head * s[kcur - 1] / r_tot

    def dispatch(j, promo):
        nonlocal promos, dseq, prefix_hits, peak_pages
        advance(t)
        if promo:
            promoted[j] = True
            promos += 1
        state[j] = 2
        if disp_seq[j] < 0:                        # first dispatch
            warm = grp[j] >= 0 and grp[j] in registered
            if warm:
                prefix_hits += 1
                base_pg[j] = ppg[j] - spg[j]
                svc[j] = max(svc[j] - saved[j], 1e-12)
            else:
                base_pg[j] = ppg[j]
            span = max(tpg[j] - (spg[j] if warm else 0.0) - base_pg[j], 0.0)
            rate[j] = span / svc[j] if svc[j] > 0 else 0.0
            if grp[j] >= 0:
                registered.add(grp[j])
        disp_seq[j] = dseq
        dseq += 1
        running.append(j)
        peak_pages = max(peak_pages, pool())
        if not started[j]:
            started[j] = True
            start[j] = t

    def admit_loop():
        nonlocal oldest, nq
        while len(running) < c and nq > 0:
            # fits() needs the pool at time t, not at the last credit
            # point; a no-op when running is empty, so the c=1 bitwise
            # contract (which never reaches here with busy lanes) holds
            advance(t)
            while state[oldest] == 3:
                oldest += 1
            o = oldest
            while state[o] != 1:
                o += 1
            if guard and (t - arr[o]) > tau:
                j, promo = o, True
            else:
                j, promo = heap_best()[1], False
            if not fits(j):
                return
            if promo:
                nq -= 1
            else:
                j = pop_valid()
            dispatch(j, promo)

    while ndone < n:
        if not running and nq == 0:
            a = arr[i_arr]
            if t < a:
                t = a
                last_t = t
        t_fin, j_fin = next_completion()
        t_arr = arr[i_arr] if i_arr < n else INF
        t_ex = next_exhaustion()
        if t_fin <= t_arr and t_fin <= t_ex:      # completion event
            t = t_fin
            advance(t)
            running.remove(j_fin)
            used[j_fin] = svc[j_fin]
            finish[j_fin] = t
            state[j_fin] = 3
            ndone += 1
            while i_arr < n and arr[i_arr] <= t:
                state[i_arr] = 1
                push(i_arr)
                i_arr += 1
            admit_loop()
        elif t_arr <= t_ex:                       # arrival event(s)
            if t_arr > t:
                t = t_arr
            if srpt:
                advance(t)
            while i_arr < n and arr[i_arr] <= t:
                state[i_arr] = 1
                push(i_arr)
                i_arr += 1
            if len(running) < c:
                admit_loop()
            elif srpt:
                best = heap_best()
                if best is not None:
                    victim = max(running, key=lambda j: (run_key(j), j))
                    vk = run_key(victim)
                    new_pool = pool() - held(victim)
                    fits_after = (new_pool + demand(best[1]) <= n_pages
                                  or new_pool <= 0.0)
                    if best[0] < vk and fits_after:
                        advance(t)
                        running.remove(victim)
                        curk[victim] = vk
                        state[victim] = 1
                        push(victim)
                        preempts += 1
                        j = pop_valid()
                        dispatch(j, False)
        else:                                     # pool exhaustion
            t = max(t, t_ex)
            advance(t)
            victim = max(running, key=lambda j: disp_seq[j])
            running.remove(victim)
            if not srpt:
                pass                              # key kept: ages from arrival
            else:
                curk[victim] = run_key(victim)
            state[victim] = 1
            push(victim)
            preempts += 1
            # no admit here: the freed lane back-fills at the next real
            # event (the live engine's per-boundary deferral)
    return (start, finish, promoted, promos, preempts, prefix_hits,
            peak_pages)


def simulate_grid_paged(arrival, service, key, tau, n_servers: int,
                        prompt_pages, total_pages, n_pages: int,
                        slowdown=None, mode=None, share_group=None,
                        shared_pages=None, prefill_saved=None):
    """G independent block-paged c-server simulations in one call.

    Layout follows :func:`simulate_grid_servers`, with the memory model
    swapped for pages: ``prompt_pages``/``total_pages`` (G, n) are each
    request's admission and completion footprints in pages, ``n_pages``
    the shared pool.  Optional prefix sharing: ``share_group`` (G, n)
    int (-1 = unshared) labels requests with a common prompt prefix,
    ``shared_pages`` (G, n) the pages that prefix covers and
    ``prefill_saved`` (G, n) the seconds of prefill a warm admission
    skips.  Returns ``(start, finish, promoted, promotions,
    preemptions, prefix_hits, peak_pages)``; the last two are length-G.
    """
    arrival = np.ascontiguousarray(arrival, np.float64)
    service = np.ascontiguousarray(service, np.float64)
    key = np.ascontiguousarray(key, np.float64)
    prompt_pages = np.ascontiguousarray(prompt_pages, np.float64)
    total_pages = np.ascontiguousarray(total_pages, np.float64)
    G, n = arrival.shape
    c = int(n_servers)
    if c < 1:
        raise ValueError(f"need >= 1 server, got {n_servers}")
    if int(n_pages) < 1:
        raise ValueError(f"need >= 1 page, got {n_pages}")
    slowdown = tuple(float(x) for x in slowdown) if slowdown is not None \
        else (1.0,) * c
    if any(x <= 0 for x in slowdown):
        raise ValueError(f"slowdown factors must be positive: {slowdown}")
    tau_arr = np.array([np.nan if x is None else float(x) for x in tau],
                       np.float64)
    mode_arr = np.zeros(G, np.int8) if mode is None \
        else np.ascontiguousarray(mode, np.int8)
    if tau_arr.shape != (G,) or mode_arr.shape != (G,):
        raise ValueError(f"tau and mode must have length {G}")
    share_group = np.full((G, n), -1, np.int64) if share_group is None \
        else np.ascontiguousarray(share_group, np.int64)
    shared_pages = np.zeros((G, n)) if shared_pages is None \
        else np.ascontiguousarray(shared_pages, np.float64)
    prefill_saved = np.zeros((G, n)) if prefill_saved is None \
        else np.ascontiguousarray(prefill_saved, np.float64)
    start = np.empty((G, n))
    finish = np.empty((G, n))
    promoted = np.zeros((G, n), bool)
    promotions = np.zeros(G, np.int64)
    preemptions = np.zeros(G, np.int64)
    prefix_hits = np.zeros(G, np.int64)
    peak_pages = np.zeros(G)
    if n == 0:
        return (start, finish, promoted, promotions, preemptions,
                prefix_hits, peak_pages)
    for g in range(G):
        tg = None if np.isnan(tau_arr[g]) else float(tau_arr[g])
        (start[g], finish[g], promoted[g], promos, pre, hits,
         peak) = _simulate_paged_python(
            arrival[g], service[g], key[g], tg, c, slowdown,
            int(mode_arr[g]), prompt_pages[g], total_pages[g],
            share_group[g], shared_pages[g], prefill_saved[g],
            float(n_pages))
        promotions[g] = promos
        preemptions[g] = pre
        prefix_hits[g] = hits
        peak_pages[g] = peak
    return (start, finish, promoted, promotions, preemptions,
            prefix_hits, peak_pages)


# ---------------------------------------------------------------------------
# Batch-level front end
# ---------------------------------------------------------------------------

@dataclass
class BatchSimResult:
    """Per-request outcomes aligned with the input batch's row order."""

    batch: RequestBatch
    start: np.ndarray          # (n,) float64 (first dispatch, preemptive)
    finish: np.ndarray         # (n,) float64
    promoted: np.ndarray       # (n,) bool
    promotions: int
    makespan: float
    preemptions: int = 0       # preemptive policies only
    prefix_hits: int = 0       # paged engine only (warm admissions)
    peak_pages: float = 0.0    # paged engine only (pool high-water mark)

    def _vals(self, klass: Optional[str], attr: str) -> np.ndarray:
        if attr == "sojourn":
            v = self.finish - self.batch.arrival
        elif attr == "wait":
            v = self.start - self.batch.arrival
        else:
            v = getattr(self, attr)
        if klass is not None:
            v = v[self.batch.klass == _KLASS_CODE[klass]]
        return v

    def percentile(self, q: float, klass: Optional[str] = None,
                   attr: str = "sojourn") -> float:
        v = self._vals(klass, attr)
        return float(np.percentile(v, q)) if len(v) else float("nan")

    def mean(self, klass: Optional[str] = None,
             attr: str = "sojourn") -> float:
        v = self._vals(klass, attr)
        return float(v.mean()) if len(v) else float("nan")


def simulate_batch(batch: RequestBatch, policy="sjf",
                   tau: Optional[float] = None,
                   engine: str = "auto") -> BatchSimResult:
    """Run the serial-server DES over a :class:`RequestBatch`.

    ``policy`` is a registry name or :class:`~repro.core.policy.Policy`;
    preemptive policies route through :func:`simulate_grid_preempt`,
    key-based ones through the (bitwise seed-equivalent) non-preemptive
    engines.
    """
    pol = get_policy(policy)
    tau = pol.aging.effective_tau(tau)
    perm = np.lexsort((batch.req_id, batch.arrival))
    arrival = batch.arrival[perm]
    service = batch.true_service[perm]
    key = pol.key_array(arrival, batch.p_long[perm], service,
                        tenant=batch.tenant[perm], tenants=batch.tenants)
    preemptions = 0
    if pol.preemptive:
        quanta = pol.quantum_array(arrival, batch.p_long[perm], service)
        start_s, finish_s, promoted_s, promos, pre = simulate_grid_preempt(
            arrival[None], service[None], key[None], (tau,),
            (pol.mode,), None if quanta is None else quanta[None],
            engine=engine)
        start_s, finish_s, promoted_s = start_s[0], finish_s[0], promoted_s[0]
        promotions, preemptions = int(promos[0]), int(pre[0])
    else:
        start_s, finish_s, promoted_s, promotions = simulate_arrays(
            arrival, service, key, tau, engine=engine)
    n = len(batch)
    start = np.empty(n)
    finish = np.empty(n)
    promoted = np.empty(n, bool)
    start[perm] = start_s
    finish[perm] = finish_s
    promoted[perm] = promoted_s
    return BatchSimResult(batch=batch, start=start, finish=finish,
                          promoted=promoted, promotions=promotions,
                          makespan=float(finish.max()) if n else 0.0,
                          preemptions=preemptions)


def simulate_batch_servers(batch: RequestBatch, policy="sjf",
                           tau: Optional[float] = None, n_servers: int = 1,
                           slowdown=None, mem_tokens=None,
                           mem_budget=None) -> BatchSimResult:
    """Run the *c-server* DES over a :class:`RequestBatch`.

    ``n_servers`` decode lanes with per-lane slowdown ``slowdown[k-1]``
    at k busy lanes and an optional memory-token budget
    (``mem_tokens`` per request, aligned with the batch's row order).
    Key-based policies and srpt are supported; at ``n_servers=1`` with
    unit slowdown the trace is bitwise-equal to :func:`simulate_batch`.
    """
    pol = get_policy(policy)
    if pol.mode not in (MODE_NONE, MODE_SRPT):
        raise ValueError(f"policy {pol.name!r}: the c-server engine "
                         "supports key-based and srpt policies only")
    tau = pol.aging.effective_tau(tau)
    perm = np.lexsort((batch.req_id, batch.arrival))
    arrival = batch.arrival[perm]
    service = batch.true_service[perm]
    key = pol.key_array(arrival, batch.p_long[perm], service,
                        tenant=batch.tenant[perm], tenants=batch.tenants)
    mem = None
    if mem_tokens is not None:
        mem = np.asarray(mem_tokens, np.float64)[perm][None]
    start_s, finish_s, promoted_s, promos, pre = simulate_grid_servers(
        arrival[None], service[None], key[None], (tau,), n_servers,
        slowdown=slowdown, mem=mem, mem_budget=mem_budget,
        mode=(pol.mode,))
    n = len(batch)
    start = np.empty(n)
    finish = np.empty(n)
    promoted = np.empty(n, bool)
    start[perm] = start_s[0]
    finish[perm] = finish_s[0]
    promoted[perm] = promoted_s[0]
    return BatchSimResult(batch=batch, start=start, finish=finish,
                          promoted=promoted, promotions=int(promos[0]),
                          makespan=float(finish.max()) if n else 0.0,
                          preemptions=int(pre[0]))


def simulate_batch_paged(batch: RequestBatch, policy="sjf",
                         tau: Optional[float] = None, n_servers: int = 1,
                         slowdown=None, *, prompt_pages, total_pages,
                         n_pages: int, share_group=None, shared_pages=None,
                         prefill_saved=None) -> BatchSimResult:
    """Run the block-paged c-server DES over a :class:`RequestBatch`.

    Per-request arrays (``prompt_pages``/``total_pages`` and the optional
    prefix-sharing triple) are aligned with the batch's row order, like
    ``mem_tokens`` in :func:`simulate_batch_servers`.  At ``n_servers=1``
    with unit slowdown and no sharing the trace is bitwise-equal to
    :func:`simulate_batch_servers` (a solo lane never pages out).
    """
    pol = get_policy(policy)
    if pol.mode not in (MODE_NONE, MODE_SRPT):
        raise ValueError(f"policy {pol.name!r}: the paged engine "
                         "supports key-based and srpt policies only")
    tau = pol.aging.effective_tau(tau)
    perm = np.lexsort((batch.req_id, batch.arrival))
    arrival = batch.arrival[perm]
    service = batch.true_service[perm]
    key = pol.key_array(arrival, batch.p_long[perm], service,
                        tenant=batch.tenant[perm], tenants=batch.tenants)

    def _row(x, fill=0.0, dt=np.float64):
        if x is None:
            return None
        return np.asarray(x, dt)[perm][None]
    (start_s, finish_s, promoted_s, promos, pre, hits,
     peak) = simulate_grid_paged(
        arrival[None], service[None], key[None], (tau,), n_servers,
        _row(prompt_pages), _row(total_pages), int(n_pages),
        slowdown=slowdown, mode=(pol.mode,),
        share_group=_row(share_group, dt=np.int64),
        shared_pages=_row(shared_pages),
        prefill_saved=_row(prefill_saved))
    n = len(batch)
    start = np.empty(n)
    finish = np.empty(n)
    promoted = np.empty(n, bool)
    start[perm] = start_s[0]
    finish[perm] = finish_s[0]
    promoted[perm] = promoted_s[0]
    return BatchSimResult(batch=batch, start=start, finish=finish,
                          promoted=promoted, promotions=int(promos[0]),
                          makespan=float(finish.max()) if n else 0.0,
                          preemptions=int(pre[0]),
                          prefix_hits=int(hits[0]),
                          peak_pages=float(peak[0]))


# ---------------------------------------------------------------------------
# Fault-injected serial engine (PR 6).
#
# The DES mirror of the serving-layer fault model (serving/faults.py): the
# single server goes DOWN for repair windows (crash + MTTR), runs SLOW
# inside stall windows, and the scheduler may SHED a request at dispatch
# when its queueing delay already exceeds its deadline budget.  A request
# in flight when the server goes down is requeued *work-conserving* — the
# service it already received is kept (``used``) and only the remainder
# runs after repair — under its ORIGINAL queue key and arrival (so the
# starvation guard still ages it from first arrival).
#
# Equivalence contract: with no fault windows and no deadline, the loop
# performs bitwise the same float ops as ``_simulate_arrays_python``
# (``svc - 0.0 == svc`` and ``rem * 1.0 == rem`` exactly in IEEE-754), so
# no-fault rows are trace-equivalent to every other engine and to the
# reference — the oracle the tests pin.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ServerFaults:
    """One server's fault timeline in virtual time.

    ``downs``: ((down_t, up_t), ...) sorted, non-overlapping — the server
    does no work inside a window.  ``slowdowns``: ((t0, t1, factor), ...) —
    service accrues at ``1/factor`` speed while inside (factors of
    overlapping windows multiply).  Empty tuples = a healthy server.
    """

    downs: Tuple[Tuple[float, float], ...] = ()
    slowdowns: Tuple[Tuple[float, float, float], ...] = ()

    def __post_init__(self):
        last = -float("inf")
        for d, u in self.downs:
            if not (d >= last and u > d):
                raise ValueError("downs must be sorted, non-overlapping "
                                 "windows with up > down")
            last = u
        for t0, t1, f in self.slowdowns:
            if not (t1 > t0 and f > 1.0):
                raise ValueError("slowdown windows need t1 > t0, factor > 1")

    @classmethod
    def random(cls, rng, horizon: float, *, mtbf: float = 0.0,
               mttr: float = 5.0, stall_mtbf: float = 0.0,
               stall_s: float = 10.0,
               stall_factor: float = 2.0) -> "ServerFaults":
        """Poisson crash/stall timelines over ``[0, horizon)``.

        ``mtbf``/``stall_mtbf`` of 0 disable that fault class.  Repair and
        stall durations are fixed (``mttr`` / ``stall_s``) so a sweep axis
        over repair time changes exactly one thing.  Windows drawn from one
        ``rng`` — share the generator across paired conditions.
        """
        downs: List[Tuple[float, float]] = []
        if mtbf > 0.0:
            t = rng.exponential(mtbf)
            while t < horizon:
                downs.append((t, t + mttr))
                t = t + mttr + rng.exponential(mtbf)
        slows: List[Tuple[float, float, float]] = []
        if stall_mtbf > 0.0:
            t = rng.exponential(stall_mtbf)
            while t < horizon:
                slows.append((t, t + stall_s, stall_factor))
                t = t + stall_s + rng.exponential(stall_mtbf)
        return cls(downs=tuple(downs), slowdowns=tuple(slows))


def _simulate_faults_python(arrival, service, key, tau, faults,
                            deadline=None, in_service_timeout=False):
    """Serial fault engine (see module comment above for the contract).

    Returns ``(start, finish, promoted, promos, shed, timeout,
    requeues)``; shed requests carry ``start = finish = NaN``.

    ``deadline`` alone keeps the PR 6 queue-wait semantics: only
    undispatched work is shed, started work always completes.  With
    ``in_service_timeout=True`` the deadline bounds the whole sojourn —
    pre-dispatch expiry still sheds, but a request whose completion
    would land past ``arrival + deadline`` is abandoned AT the deadline
    instant (``timeout[j] = True``, the server is freed at expiry),
    mirroring the sidecar's ``deadline_mode="sojourn"``.
    """
    import heapq
    n = arrival.shape[0]
    arr = arrival.tolist()
    svc = service.tolist()
    ks = key.tolist()
    downs = faults.downs
    slows = faults.slowdowns
    start = np.zeros(n)
    finish = np.zeros(n)
    promoted = np.zeros(n, bool)
    shed = np.zeros(n, bool)
    timeout = np.zeros(n, bool)
    fin = [False] * n            # terminal (served or shed)
    used = [0.0] * n             # service already received (work-conserving)
    last_seq = [-1] * n          # validity stamp of the live heap entry
    heap: list = []              # (key, seq, i): seq breaks ties == index
    guard = tau is not None      # order when no requeue has happened
    t = 0.0
    i_arr = 0
    oldest = 0
    promos = 0
    requeues = 0
    nterm = 0
    nq = 0                       # live (non-tombstone) heap entries
    seq = 0

    def down_until(x):
        for d, u in downs:
            if d <= x < u:
                return u
        return None

    def factor_at(x):
        f = 1.0
        for t0, t1, fac in slows:
            if t0 <= x < t1:
                f *= fac
        return f

    def next_boundary(x):
        b = float("inf")
        for d, _u in downs:
            if x < d < b:
                b = d
        for t0, t1, _f in slows:
            if x < t0 < b:
                b = t0
            if x < t1 < b:
                b = t1
        return b

    while nterm < n:
        if nq == 0:                               # queue empty: jump
            a = arr[i_arr]
            if t < a:
                t = a
        if downs:                                 # never dispatch while down
            u = down_until(t)
            if u is not None:
                t = u
        while i_arr < n and arr[i_arr] <= t:
            heapq.heappush(heap, (ks[i_arr], seq, i_arr))
            last_seq[i_arr] = seq
            seq += 1
            nq += 1
            i_arr += 1
        while fin[oldest]:
            oldest += 1
        was_promo = False
        if guard and (t - arr[oldest]) > tau:
            j = oldest                            # promote past the heap;
            was_promo = True                      # stale entry -> tombstone
        else:
            while True:
                _, s, j = heapq.heappop(heap)
                if not fin[j] and s == last_seq[j]:
                    break
        nq -= 1
        if deadline is not None and used[j] == 0.0 \
                and (t - arr[j]) > deadline:
            shed[j] = True                        # shed at dispatch, never
            fin[j] = True                         # once service has begun
            start[j] = float("nan")
            finish[j] = float("nan")
            nterm += 1
            continue
        if was_promo:
            promoted[j] = True
            promos += 1
        if used[j] == 0.0:
            start[j] = t                          # FIRST dispatch
        # sojourn budget (in_service_timeout): completion past expiry
        # abandons the work at the deadline instant — guarded so the
        # deadline=None path performs zero extra float ops (the bitwise
        # no-fault trace contract)
        exp_j = (arr[j] + deadline) \
            if (in_service_timeout and deadline is not None) else None
        while True:                               # serve, event-sliced
            rem = svc[j] - used[j]
            f = factor_at(t)
            tb = next_boundary(t)
            tc = t + rem * f                      # == t + svc[j] bitwise
            if exp_j is not None and exp_j < tc and exp_j <= tb:
                t = max(t, exp_j)                 # expiry may have passed
                finish[j] = t                     # while the server was down
                timeout[j] = True
                fin[j] = True
                nterm += 1
                break
            if tc <= tb:                          # when no faults active
                t = tc
                finish[j] = t
                fin[j] = True
                nterm += 1
                break
            used[j] += (tb - t) / f               # accrue partial service
            t = tb
            u = down_until(t)
            if u is not None:                     # crash mid-service:
                last_seq[j] = seq                 # work-conserving requeue
                heapq.heappush(heap, (ks[j], seq, j))
                seq += 1
                nq += 1
                requeues += 1
                t = u
                break
    return start, finish, promoted, promos, shed, timeout, requeues


def simulate_grid_faults(arrival, service, key, tau, faults,
                         deadline=None, in_service_timeout=False):
    """G fault-injected simulations in one call (Python engine only —
    fault rows are rare relative to the clean grids the C engine runs).

    ``faults``: one :class:`ServerFaults` shared by every row, or a
    length-G sequence (one timeline per row — pair timelines across
    conditions the same way workloads are paired).  ``deadline``: scalar
    queueing-delay budget or length-G sequence (None disables shedding).
    ``in_service_timeout``: the deadline bounds the whole sojourn —
    mid-service expiry terminates as ``timeout`` instead of completing
    (pre-dispatch expiry stays ``shed``).  Returns ``(start, finish,
    promoted, promotions, shed, timeout, requeues)`` with shed/timeout
    (G, n) bool and requeues (G,) int64 appended to the
    :func:`simulate_grid` contract.
    """
    arrival = np.ascontiguousarray(arrival, np.float64)
    service = np.ascontiguousarray(service, np.float64)
    key = np.ascontiguousarray(key, np.float64)
    G, n = arrival.shape
    tau_arr = np.array([np.nan if t is None else float(t) for t in tau],
                       np.float64)
    if tau_arr.shape != (G,):
        raise ValueError(f"tau must have length {G}")
    if isinstance(faults, ServerFaults):
        faults = [faults] * G
    if len(faults) != G:
        raise ValueError(f"faults must have length {G}")
    if deadline is None or np.isscalar(deadline):
        deadline = [deadline] * G
    start = np.empty((G, n))
    finish = np.empty((G, n))
    promoted = np.zeros((G, n), bool)
    shed = np.zeros((G, n), bool)
    timeout = np.zeros((G, n), bool)
    promotions = np.zeros(G, np.int64)
    requeues = np.zeros(G, np.int64)
    if n == 0:
        return (start, finish, promoted, promotions, shed, timeout,
                requeues)
    for g in range(G):
        tg = None if np.isnan(tau_arr[g]) else float(tau_arr[g])
        (start[g], finish[g], promoted[g], promotions[g], shed[g],
         timeout[g], requeues[g]) = _simulate_faults_python(
            arrival[g], service[g], key[g], tg, faults[g], deadline[g],
            in_service_timeout)
    return start, finish, promoted, promotions, shed, timeout, requeues


# --------------------------------------------------------------------------
# observability bridge
# --------------------------------------------------------------------------
def record_batch_trace(recorder, *, arrival, start, finish, req_ids,
                       ttft=None, out_tokens=None, replica=None,
                       statuses=None, segment_tokens: int = 8,
                       max_segments: int = 4) -> None:
    """Replay a DES result as flight-recorder spans in virtual time.

    Pure post-processing over the result arrays — the C/heapq engines are
    untouched, so tracing a simulation costs nothing unless requested.
    The emitted span schema (request / queue_wait / prefill / decode /
    decode_segment) is identical to what the live drains record, which is
    what makes a sim run and a live drain comparable as flame traces
    (``serving.observability`` holds the shared emitter).
    """
    from repro.serving.observability import record_des_trace
    record_des_trace(recorder, arrival, start, finish, req_ids,
                     ttft=ttft, out_tokens=out_tokens, replica=replica,
                     statuses=statuses, segment_tokens=segment_tokens,
                     max_segments=max_segments)
