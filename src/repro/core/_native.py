"""Runtime-compiled C hot loops: GBDT scorer + discrete-event simulator.

The numpy traversal in ``ensemble_pack`` pays one full (T, B) vector pass
per gather per depth.  This module compiles (once per process, with the
system C compiler via ctypes — no third-party deps) a scalar scorer whose
loop nest is cache-shaped instead: trees outer, samples inner, so each
tree's ~55-node record block and the whole binned input batch stay L1/L2
resident while 4 loads + 1 compare + 1 add walk each (tree, sample) lane.
Margins accumulate class-wise in tree order (sequential, not numpy's
pairwise — results are allclose to, not bitwise equal to, the dense
path).

``des_run_many`` is the serial-backend DES inner loop (see
``core.sim_fast``): G independent simulations over struct-of-arrays
request batches, each driven by an index-based binary min-heap keyed on
``(key[i], i)`` with lazy tombstones for starvation promotions.  All
arithmetic is C ``double`` — bitwise identical to the Python reference
loop (``simulation.simulate_reference``), which also accumulates the
clock in float64.

Compilation is lazy, cached, thread-safe, and entirely optional: any
failure (no compiler, sandboxed tmpdir, exotic platform) degrades to the
pure-numpy paths.  Set ``REPRO_NO_NATIVE=1`` to force the fallbacks.
The exported functions release the GIL (ctypes), so callers can shard
batches across OS threads.
"""

from __future__ import annotations

import ctypes
import os
import subprocess
import tempfile
import threading

_SOURCE = r"""
#include <stdint.h>

/* Tree walks are chains of dependent L1 loads (feat -> x -> child), so a
 * single walk is latency-bound.  Interleaving four independent samples
 * per tree keeps ~4 loads in flight and roughly quadruples throughput. */
void gbdt_score(const int32_t* feat, const uint16_t* thrbin,
                const int32_t* child, const float* value,
                const int32_t* roots, int64_t n_trees, int64_t n_classes,
                const uint16_t* xb, int64_t batch, int64_t n_features,
                int64_t depth, float* out) {
    for (int64_t t = 0; t < n_trees; t++) {
        int64_t k = t % n_classes;
        int32_t root = roots[t];
        int64_t b = 0;
        for (; b + 4 <= batch; b += 4) {
            const uint16_t* x0 = xb + b * n_features;
            const uint16_t* x1 = x0 + n_features;
            const uint16_t* x2 = x1 + n_features;
            const uint16_t* x3 = x2 + n_features;
            int32_t n0 = root, n1 = root, n2 = root, n3 = root;
            for (int64_t d = 0; d < depth; d++) {
                n0 = child[n0] + (x0[feat[n0]] >= thrbin[n0]);
                n1 = child[n1] + (x1[feat[n1]] >= thrbin[n1]);
                n2 = child[n2] + (x2[feat[n2]] >= thrbin[n2]);
                n3 = child[n3] + (x3[feat[n3]] >= thrbin[n3]);
            }
            out[b * n_classes + k] += value[n0];
            out[(b + 1) * n_classes + k] += value[n1];
            out[(b + 2) * n_classes + k] += value[n2];
            out[(b + 3) * n_classes + k] += value[n3];
        }
        for (; b < batch; b++) {
            const uint16_t* xrow = xb + b * n_features;
            int32_t n = root;
            for (int64_t d = 0; d < depth; d++) {
                n = child[n] + (xrow[feat[n]] >= thrbin[n]);
            }
            out[b * n_classes + k] += value[n];
        }
    }
}
"""

_DES_SOURCE = r"""
#include <stdint.h>

/* One serial-server simulation over struct-of-arrays requests, indices
 * pre-sorted by (arrival, req_id).  The admission queue is an indexed
 * binary min-heap over (key[i], i): the seq tiebreak of the Python
 * SJFQueue collapses to the request index because pushes happen in
 * arrival order.  The starvation guard promotes the FIFO-oldest live
 * request past the heap; its stale heap entry becomes a tombstone that
 * pop skips via the done[] flags (lazy deletion, no re-heapify). */
static void des_run_one(const double* arrival, const double* service,
                        const double* key, double tau, int64_t n,
                        double* start, double* finish, uint8_t* promoted,
                        int64_t* promotions,
                        int32_t* heap, uint8_t* done) {
    int64_t hs = 0;          /* heap size (live + tombstones)            */
    int64_t i_arr = 0;       /* next not-yet-admitted arrival            */
    int64_t oldest = 0;      /* FIFO head: min index admitted & undone   */
    int64_t ndone = 0;
    int64_t promos = 0;
    double t = 0.0;
    for (int64_t i = 0; i < n; i++) done[i] = 0;
    while (ndone < n) {
        if (i_arr == ndone) {
            /* queue empty (admitted == done): jump to the next arrival */
            if (t < arrival[i_arr]) t = arrival[i_arr];
        }
        while (i_arr < n && arrival[i_arr] <= t) {
            /* heap push of index i_arr */
            int64_t c = hs++;
            heap[c] = (int32_t)i_arr;
            while (c > 0) {
                int64_t p = (c - 1) >> 1;
                int32_t hc = heap[c], hp = heap[p];
                if (key[hp] < key[hc] ||
                    (key[hp] == key[hc] && hp < hc)) break;
                heap[p] = hc; heap[c] = hp;
                c = p;
            }
            i_arr++;
        }
        while (oldest < i_arr && done[oldest]) oldest++;
        int64_t j;
        /* NaN tau disables the guard (any comparison with NaN is false);
         * negative tau promotes every waiter, like the Python queue. */
        if ((t - arrival[oldest]) > tau) {
            j = oldest;               /* promote past the heap */
            promoted[j] = 1;
            promos++;
        } else {
            /* heap pop, skipping tombstones of promoted requests */
            for (;;) {
                int32_t top = heap[0];
                int64_t last = --hs;
                if (hs > 0) {
                    heap[0] = heap[last];
                    int64_t c = 0;
                    for (;;) {
                        int64_t l = 2 * c + 1, r = l + 1, m = c;
                        if (l < hs && (key[heap[l]] < key[heap[m]] ||
                            (key[heap[l]] == key[heap[m]] &&
                             heap[l] < heap[m]))) m = l;
                        if (r < hs && (key[heap[r]] < key[heap[m]] ||
                            (key[heap[r]] == key[heap[m]] &&
                             heap[r] < heap[m]))) m = r;
                        if (m == c) break;
                        int32_t tmp = heap[c]; heap[c] = heap[m];
                        heap[m] = tmp;
                        c = m;
                    }
                }
                if (!done[top]) { j = top; break; }
            }
        }
        done[j] = 1;
        start[j] = t;
        t += service[j];
        finish[j] = t;
        ndone++;
    }
    *promotions = promos;
}

/* G independent simulations of n requests each; arrays are (G, n)
 * row-major, tau is per-cell (NaN disables the guard).  heap and
 * done are caller-provided scratch of n int32 / n uint8. */
void des_run_many(const double* arrival, const double* service,
                  const double* key, const double* tau,
                  int64_t g, int64_t n,
                  double* start, double* finish, uint8_t* promoted,
                  int64_t* promotions,
                  int32_t* heap, uint8_t* done) {
    for (int64_t s = 0; s < g; s++) {
        int64_t off = s * n;
        des_run_one(arrival + off, service + off, key + off, tau[s], n,
                    start + off, finish + off, promoted + off,
                    promotions + s, heap, done);
    }
}
"""

_DES_PREEMPT_SOURCE = r"""
#include <stdint.h>
#include <math.h>

/* Preemptive serial-server DES (policy modes SRPT / QUANTUM; see
 * core/sim_fast.py _simulate_preempt_python for the reference event
 * sequence — this loop performs the identical float64 arithmetic in the
 * identical order, so results match the Python engine bitwise).
 *
 * The queue is a binary min-heap of (key, seq) entries; an entry is
 * valid iff its job is QUEUED and it is the job's latest push (lastseq),
 * which makes eviction/requeue O(log n) with lazy invalidation.  The
 * starvation guard (strict wait > tau, NaN disables) applies at every
 * dispatch decision, like the non-preemptive engine. */

#define ST_WAIT 0
#define ST_QUEUED 1
#define ST_RUNNING 2
#define ST_DONE 3
#define LEVEL_STRIDE 1e9
#define MODE_SRPT 1
#define MODE_QUANTUM 2

static void pre_push(double* hkey, int64_t* hseq, int32_t* hidx,
                     int64_t* hs, int64_t* seqc,
                     const double* curk, int64_t* lastseq, int64_t j) {
    int64_t c = (*hs)++;
    hkey[c] = curk[j];
    hseq[c] = *seqc;
    hidx[c] = (int32_t)j;
    lastseq[j] = *seqc;
    (*seqc)++;
    while (c > 0) {
        int64_t p = (c - 1) >> 1;
        if (hkey[p] < hkey[c] ||
            (hkey[p] == hkey[c] && hseq[p] < hseq[c])) break;
        double tk = hkey[p]; hkey[p] = hkey[c]; hkey[c] = tk;
        int64_t ts = hseq[p]; hseq[p] = hseq[c]; hseq[c] = ts;
        int32_t ti = hidx[p]; hidx[p] = hidx[c]; hidx[c] = ti;
        c = p;
    }
}

static void pre_drop_root(double* hkey, int64_t* hseq, int32_t* hidx,
                          int64_t* hs) {
    int64_t last = --(*hs);
    if (last > 0) {
        hkey[0] = hkey[last]; hseq[0] = hseq[last]; hidx[0] = hidx[last];
        int64_t c = 0;
        for (;;) {
            int64_t l = 2 * c + 1, r = l + 1, m = c;
            if (l < last && (hkey[l] < hkey[m] ||
                (hkey[l] == hkey[m] && hseq[l] < hseq[m]))) m = l;
            if (r < last && (hkey[r] < hkey[m] ||
                (hkey[r] == hkey[m] && hseq[r] < hseq[m]))) m = r;
            if (m == c) break;
            double tk = hkey[c]; hkey[c] = hkey[m]; hkey[m] = tk;
            int64_t ts = hseq[c]; hseq[c] = hseq[m]; hseq[m] = ts;
            int32_t ti = hidx[c]; hidx[c] = hidx[m]; hidx[m] = ti;
            c = m;
        }
    }
}

static void des_preempt_one(const double* arrival, const double* service,
                            const double* key, double tau,
                            const double* quanta, int8_t mode, int64_t n,
                            double* start, double* finish, uint8_t* promoted,
                            int64_t* promotions, int64_t* preemptions,
                            double* hkey, int64_t* hseq, int32_t* hidx,
                            double* used, double* curk, double* budget,
                            int64_t* lastseq, uint8_t* state) {
    const double INF = HUGE_VAL;
    int64_t hs = 0, seqc = 0;
    int64_t i_arr = 0, oldest = 0, nq = 0, ndone = 0;
    int64_t promos = 0, preempts = 0;
    int64_t run = -1;
    double t = 0.0;
    for (int64_t i = 0; i < n; i++) {
        state[i] = ST_WAIT;
        used[i] = 0.0;
        curk[i] = key[i];
        budget[i] = (mode == MODE_QUANTUM && quanta) ? quanta[i] : INF;
        lastseq[i] = -1;
        start[i] = -1.0;                 /* sentinel: not yet dispatched */
        promoted[i] = 0;
    }
    while (ndone < n) {
        if (run < 0) {
            if (nq == 0 && t < arrival[i_arr]) t = arrival[i_arr];
            while (i_arr < n && arrival[i_arr] <= t) {
                state[i_arr] = ST_QUEUED;
                pre_push(hkey, hseq, hidx, &hs, &seqc, curk, lastseq,
                         i_arr);
                nq++;
                i_arr++;
            }
            while (state[oldest] == ST_DONE) oldest++;
            int64_t j;
            if (state[oldest] == ST_QUEUED && (t - arrival[oldest]) > tau) {
                j = oldest;              /* promote past the heap */
                promoted[j] = 1;
                promos++;
                nq--;
            } else {
                for (;;) {               /* pop until valid */
                    int64_t s = hseq[0];
                    int32_t cand = hidx[0];
                    pre_drop_root(hkey, hseq, hidx, &hs);
                    if (state[cand] == ST_QUEUED && s == lastseq[cand]) {
                        j = cand;
                        nq--;
                        break;
                    }
                }
            }
            state[j] = ST_RUNNING;
            run = j;
            if (start[j] < 0.0) start[j] = t;    /* first dispatch */
        }
        double rem = service[run] - used[run];
        double t_fin = t + rem;
        double t_q = (budget[run] < INF)
            ? t + (budget[run] - used[run]) : INF;
        double t_arr = (i_arr < n) ? arrival[i_arr] : INF;
        if (t_fin <= t_arr && t_fin <= t_q) {
            t = t_fin;                   /* completion */
            used[run] = service[run];
            finish[run] = t;
            state[run] = ST_DONE;
            ndone++;
            run = -1;
        } else if (t_q <= t_arr) {
            used[run] += t_q - t;        /* quantum expiry: demote */
            t = t_q;
            budget[run] = INF;
            curk[run] = curk[run] + LEVEL_STRIDE;
            state[run] = ST_QUEUED;
            pre_push(hkey, hseq, hidx, &hs, &seqc, curk, lastseq, run);
            nq++;
            run = -1;
        } else {
            used[run] += t_arr - t;      /* arrival event(s) */
            t = t_arr;
            while (i_arr < n && arrival[i_arr] <= t) {
                state[i_arr] = ST_QUEUED;
                pre_push(hkey, hseq, hidx, &hs, &seqc, curk, lastseq,
                         i_arr);
                nq++;
                i_arr++;
            }
            /* peek best valid entry, dropping stale roots */
            while (hs > 0) {
                int32_t cand = hidx[0];
                if (state[cand] == ST_QUEUED && hseq[0] == lastseq[cand])
                    break;
                pre_drop_root(hkey, hseq, hidx, &hs);
            }
            if (hs > 0) {
                double bk = hkey[0];
                /* SRPT remaining floored at 0, matching the Python
                 * engine and Policy.running_key */
                double rk = curk[run];
                if (mode == MODE_SRPT) {
                    rk = key[run] - used[run];
                    if (rk < 0.0) rk = 0.0;
                }
                if (bk < rk) {
                    if (mode == MODE_SRPT) curk[run] = rk;
                    state[run] = ST_QUEUED;   /* evict the running request */
                    pre_push(hkey, hseq, hidx, &hs, &seqc, curk, lastseq,
                             run);
                    nq++;
                    preempts++;
                    int64_t j;
                    for (;;) {
                        int64_t s = hseq[0];
                        int32_t cand = hidx[0];
                        pre_drop_root(hkey, hseq, hidx, &hs);
                        if (state[cand] == ST_QUEUED && s == lastseq[cand]) {
                            j = cand;
                            nq--;
                            break;
                        }
                    }
                    state[j] = ST_RUNNING;
                    run = j;
                    if (start[j] < 0.0) start[j] = t;
                }
            }
        }
    }
    *promotions = promos;
    *preemptions = preempts;
}

void des_preempt_run_many(const double* arrival, const double* service,
                          const double* key, const double* tau,
                          const double* quanta, const int8_t* mode,
                          int64_t g, int64_t n,
                          double* start, double* finish, uint8_t* promoted,
                          int64_t* promotions, int64_t* preemptions,
                          double* hkey, int64_t* hseq, int32_t* hidx,
                          double* used, double* curk, double* budget,
                          int64_t* lastseq, uint8_t* state) {
    for (int64_t s = 0; s < g; s++) {
        int64_t off = s * n;
        des_preempt_one(arrival + off, service + off, key + off, tau[s],
                        quanta + off, mode[s], n,
                        start + off, finish + off, promoted + off,
                        promotions + s, preemptions + s,
                        hkey, hseq, hidx, used, curk, budget, lastseq,
                        state);
    }
}
"""

_lock = threading.Lock()
_cache: dict = {}


def _compile_lib(name: str, source: str):
    workdir = tempfile.mkdtemp(prefix=f"repro_{name}_")
    src = os.path.join(workdir, f"{name}.c")
    lib = os.path.join(workdir, f"lib{name}.so")
    with open(src, "w") as f:
        f.write(source)
    for cc in ("cc", "gcc", "clang"):
        try:
            r = subprocess.run([cc, "-O3", "-shared", "-fPIC", src, "-o", lib],
                               capture_output=True, timeout=120)
        except (OSError, subprocess.TimeoutExpired):
            continue
        if r.returncode == 0:
            break
    else:
        return None
    return ctypes.CDLL(lib)


def _compile_gbdt():
    dll = _compile_lib("gbdt_score", _SOURCE)
    if dll is None:
        return None
    fn = dll.gbdt_score
    i64 = ctypes.c_int64
    p = ctypes.POINTER
    fn.argtypes = [p(ctypes.c_int32), p(ctypes.c_uint16), p(ctypes.c_int32),
                   p(ctypes.c_float), p(ctypes.c_int32), i64, i64,
                   p(ctypes.c_uint16), i64, i64, i64, p(ctypes.c_float)]
    fn.restype = None
    return fn


def _compile_des():
    dll = _compile_lib("des_run", _DES_SOURCE)
    if dll is None:
        return None
    fn = dll.des_run_many
    i64 = ctypes.c_int64
    p = ctypes.POINTER
    pd = p(ctypes.c_double)
    fn.argtypes = [pd, pd, pd, pd, i64, i64, pd, pd, p(ctypes.c_uint8),
                   p(ctypes.c_int64), p(ctypes.c_int32), p(ctypes.c_uint8)]
    fn.restype = None
    return fn


def _compile_des_preempt():
    dll = _compile_lib("des_preempt", _DES_PREEMPT_SOURCE)
    if dll is None:
        return None
    fn = dll.des_preempt_run_many
    i64 = ctypes.c_int64
    p = ctypes.POINTER
    pd = p(ctypes.c_double)
    p64 = p(ctypes.c_int64)
    fn.argtypes = [pd, pd, pd, pd, pd, p(ctypes.c_int8), i64, i64,
                   pd, pd, p(ctypes.c_uint8), p64, p64,
                   pd, p64, p(ctypes.c_int32),
                   pd, pd, pd, p64, p(ctypes.c_uint8)]
    fn.restype = None
    return fn


def _native_fn(name: str, builder):
    if name in _cache:
        return _cache[name]
    with _lock:
        if name not in _cache:
            if os.environ.get("REPRO_NO_NATIVE"):
                _cache[name] = None
            else:
                try:
                    _cache[name] = builder()
                except Exception:
                    _cache[name] = None
    return _cache[name]


def native_scorer():
    """The compiled GBDT scorer function, or None when unavailable."""
    return _native_fn("gbdt", _compile_gbdt)


def native_des():
    """The compiled DES engine (``des_run_many``), or None."""
    return _native_fn("des", _compile_des)


def native_des_preempt():
    """The compiled preemptive DES engine, or None."""
    return _native_fn("des_preempt", _compile_des_preempt)


def as_ptr(arr, ctype):
    return arr.ctypes.data_as(ctypes.POINTER(ctype))
