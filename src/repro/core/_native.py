"""Runtime-compiled C scorer for the packed GBDT admission path.

The numpy traversal in ``ensemble_pack`` pays one full (T, B) vector pass
per gather per depth.  This module compiles (once per process, with the
system C compiler via ctypes — no third-party deps) a scalar scorer whose
loop nest is cache-shaped instead: trees outer, samples inner, so each
tree's ~55-node record block and the whole binned input batch stay L1/L2
resident while 4 loads + 1 compare + 1 add walk each (tree, sample) lane.
Margins accumulate class-wise in tree order (sequential, not numpy's
pairwise — results are allclose to, not bitwise equal to, the dense
path).

Compilation is lazy, cached, thread-safe, and entirely optional: any
failure (no compiler, sandboxed tmpdir, exotic platform) degrades to the
pure-numpy traversal.  Set ``REPRO_NO_NATIVE=1`` to force the fallback.
The exported function releases the GIL (ctypes), so callers can shard a
batch across OS threads.
"""

from __future__ import annotations

import ctypes
import os
import subprocess
import tempfile
import threading

_SOURCE = r"""
#include <stdint.h>

/* Tree walks are chains of dependent L1 loads (feat -> x -> child), so a
 * single walk is latency-bound.  Interleaving four independent samples
 * per tree keeps ~4 loads in flight and roughly quadruples throughput. */
void gbdt_score(const int32_t* feat, const uint16_t* thrbin,
                const int32_t* child, const float* value,
                const int32_t* roots, int64_t n_trees, int64_t n_classes,
                const uint16_t* xb, int64_t batch, int64_t n_features,
                int64_t depth, float* out) {
    for (int64_t t = 0; t < n_trees; t++) {
        int64_t k = t % n_classes;
        int32_t root = roots[t];
        int64_t b = 0;
        for (; b + 4 <= batch; b += 4) {
            const uint16_t* x0 = xb + b * n_features;
            const uint16_t* x1 = x0 + n_features;
            const uint16_t* x2 = x1 + n_features;
            const uint16_t* x3 = x2 + n_features;
            int32_t n0 = root, n1 = root, n2 = root, n3 = root;
            for (int64_t d = 0; d < depth; d++) {
                n0 = child[n0] + (x0[feat[n0]] >= thrbin[n0]);
                n1 = child[n1] + (x1[feat[n1]] >= thrbin[n1]);
                n2 = child[n2] + (x2[feat[n2]] >= thrbin[n2]);
                n3 = child[n3] + (x3[feat[n3]] >= thrbin[n3]);
            }
            out[b * n_classes + k] += value[n0];
            out[(b + 1) * n_classes + k] += value[n1];
            out[(b + 2) * n_classes + k] += value[n2];
            out[(b + 3) * n_classes + k] += value[n3];
        }
        for (; b < batch; b++) {
            const uint16_t* xrow = xb + b * n_features;
            int32_t n = root;
            for (int64_t d = 0; d < depth; d++) {
                n = child[n] + (xrow[feat[n]] >= thrbin[n]);
            }
            out[b * n_classes + k] += value[n];
        }
    }
}
"""

_lock = threading.Lock()
_cached = False
_fn = None


def _compile():
    workdir = tempfile.mkdtemp(prefix="repro_gbdt_")
    src = os.path.join(workdir, "gbdt_score.c")
    lib = os.path.join(workdir, "libgbdt_score.so")
    with open(src, "w") as f:
        f.write(_SOURCE)
    for cc in ("cc", "gcc", "clang"):
        try:
            r = subprocess.run([cc, "-O3", "-shared", "-fPIC", src, "-o", lib],
                               capture_output=True, timeout=120)
        except (OSError, subprocess.TimeoutExpired):
            continue
        if r.returncode == 0:
            break
    else:
        return None
    dll = ctypes.CDLL(lib)
    fn = dll.gbdt_score
    i64 = ctypes.c_int64
    p = ctypes.POINTER
    fn.argtypes = [p(ctypes.c_int32), p(ctypes.c_uint16), p(ctypes.c_int32),
                   p(ctypes.c_float), p(ctypes.c_int32), i64, i64,
                   p(ctypes.c_uint16), i64, i64, i64, p(ctypes.c_float)]
    fn.restype = None
    return fn


def native_scorer():
    """The compiled scorer function, or None when unavailable."""
    global _cached, _fn
    if _cached:
        return _fn
    with _lock:
        if not _cached:
            if os.environ.get("REPRO_NO_NATIVE"):
                _fn = None
            else:
                try:
                    _fn = _compile()
                except Exception:
                    _fn = None
            _cached = True
    return _fn


def as_ptr(arr, ctype):
    return arr.ctypes.data_as(ctypes.POINTER(ctype))
