"""One-shot sweep grids over the queueing simulation (paper §5.4/§5.5).

Every end-to-end number in the paper is a grid — policy x tau for Table 9,
policy x rho x seed for Fig. 3, policy x workload x run for Table 8.  The
seed benchmarks walked those grids cell by cell through the per-object
simulator; this module runs a whole grid through the vectorized engine
(``core.sim_fast``) in ONE call:

    from repro.core.sweep import sweep_poisson
    res = sweep_poisson(
        conditions=[("fcfs", None), ("sjf", 10.5), ("sjf", None)],
        rhos=(0.5, 0.74), seeds=range(5), n=2000, short=S, long=L)
    res.metric("short_p50")          # (C, R, S) ndarray
    res.metric("short_p50").mean(-1) # seed-averaged (C, R)

Workloads are generated once per (rho, seed) cell — vectorized, no Request
objects — and shared across all conditions (paired comparison, as the seed
benchmarks did via deepcopy).  Backends: ``auto`` (compiled C engine,
stdlib-heapq fallback) and ``jax`` (vmapped scan, ``core.sim_jax``) for
running the per-cell axis on an accelerator.

Conditions are policy specs: registry names ("fcfs", "srpt", ...) or
``core.policy.Policy`` instances for custom parameters.  Preemptive
policies (srpt / mlfq) are routed row-wise to the preemptive host engine
(``sim_fast.simulate_grid_preempt``); key-based rows run on the requested
backend, so one grid can mix both.

``sweep_lanes`` / ``sweep_lane_batches`` add the batch-degree axis
(PR 5): policy x decode-lane count x KV-memory budget through the
c-server engine (``sim_fast.simulate_grid_servers``) with a calibrated
per-lane slowdown — the grid that decomposes how much of the scheduling
win bounded-concurrency batching recovers by itself.

``run_grid`` is the non-DES counterpart used by the accuracy-table
benchmarks (model x feature-group, model x baseline): one call evaluates
a cartesian grid of cells and returns the keyed results.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import Policy, get_policy
from repro.core.sim_fast import (RequestBatch, simulate_grid,
                                 simulate_grid_preempt,
                                 simulate_grid_servers)

#: A sweep condition: (policy spec, tau).  The policy spec is a registry
#: name ("fcfs", "sjf", "srpt", ...) or a Policy instance (for custom
#: parameters, e.g. ``QuantileSJF(z=2.0)``); SweepResult indexes
#: conditions by the resolved policy name.
Condition = Tuple[object, Optional[float]]       # (policy spec, tau)

METRICS = ("short_p50", "short_p95", "short_p99", "long_p50", "long_p95",
           "long_p99", "mean_sojourn", "mean_wait", "promotions", "makespan")


@dataclass
class SweepResult:
    """Metric arrays over a conditions x rhos x seeds grid."""

    conditions: Tuple[Condition, ...]
    rhos: Tuple[float, ...]
    seeds: Tuple[int, ...]
    metrics: Dict[str, np.ndarray]               # each (C, R, S)

    def metric(self, name: str) -> np.ndarray:
        return self.metrics[name]

    def condition_index(self, policy: str, tau: Optional[float]) -> int:
        return self.conditions.index((policy, tau))


def _percentile_metrics(start: np.ndarray, finish: np.ndarray,
                        promotions: int, arrival: np.ndarray,
                        short_mask: np.ndarray,
                        long_mask: np.ndarray) -> Tuple[float, ...]:
    sojourn = finish - arrival
    wait = start - arrival
    s, l = sojourn[short_mask], sojourn[long_mask]

    def pct(v, q):
        return float(np.percentile(v, q)) if v.size else float("nan")

    return (pct(s, 50), pct(s, 95), pct(s, 99),
            pct(l, 50), pct(l, 95), pct(l, 99),
            float(sojourn.mean()), float(wait.mean()),
            float(promotions), float(finish.max()))


def sweep_batches(batches: Sequence[RequestBatch],
                  conditions: Sequence[Condition],
                  backend: str = "auto", return_arrays: bool = False):
    """Simulate every (condition, batch) cell in one engine call.

    Returns ``{metric: (C, B) ndarray}``.  All batches must have equal
    length (stacked into one (C*B, n) grid).  With ``return_arrays``,
    additionally returns ``(arrival, klass, start, finish, promoted)`` as
    (C*B, n) arrays (row ``c * B + g``, each row in its batch's
    arrival-sorted order) for callers that pool raw sojourns across cells.
    """
    C, B = len(conditions), len(batches)
    n = len(batches[0])
    assert all(len(b) == n for b in batches), "batches must be same length"
    policies = [get_policy(p) for p, _ in conditions]

    # sort each batch once; reuse the sorted arrays for every condition
    sorted_cols = []
    for b in batches:
        perm = np.lexsort((b.req_id, b.arrival))
        sorted_cols.append((b.arrival[perm], b.true_service[perm],
                            b.p_long[perm], b.klass[perm], b.tenant[perm],
                            b.tenants))

    arrival = np.empty((C * B, n))
    service = np.empty((C * B, n))
    key = np.empty((C * B, n))
    quanta = np.full((C * B, n), np.inf)
    taus: List[Optional[float]] = []
    modes = np.zeros(C * B, np.int8)
    for c, ((_, tau), pol) in enumerate(zip(conditions, policies)):
        for g, (arr, svc, pl, _, tc, tn) in enumerate(sorted_cols):
            row = c * B + g
            arrival[row] = arr
            service[row] = svc
            key[row] = pol.key_array(arr, pl, svc, tenant=tc, tenants=tn)
            taus.append(pol.aging.effective_tau(tau))
            modes[row] = pol.mode
            if pol.preemptive:
                q = pol.quantum_array(arr, pl, svc)
                if q is not None:
                    quanta[row] = q

    # preemptive rows run on the host preemptive engine; key-based rows on
    # the requested backend (the vmapped jax path is non-preemptive)
    pre = modes != 0
    start = np.empty((C * B, n))
    finish = np.empty((C * B, n))
    promoted = np.zeros((C * B, n), bool)
    promotions = np.zeros(C * B, np.int64)
    if (~pre).any():
        rows = np.flatnonzero(~pre)
        taus_np = [taus[r] for r in rows]
        if backend == "jax":
            from repro.core.sim_jax import simulate_grid_jax
            s, f, pr, pm = simulate_grid_jax(
                arrival[rows], service[rows], key[rows], taus_np)
        else:
            s, f, pr, pm = simulate_grid(
                arrival[rows], service[rows], key[rows], taus_np,
                engine=backend)
        start[rows], finish[rows], promoted[rows] = s, f, pr
        promotions[rows] = pm
    if pre.any():
        rows = np.flatnonzero(pre)
        s, f, pr, pm, _ = simulate_grid_preempt(
            arrival[rows], service[rows], key[rows],
            [taus[r] for r in rows], modes[rows], quanta[rows],
            engine="auto" if backend == "jax" else backend)
        start[rows], finish[rows], promoted[rows] = s, f, pr
        promotions[rows] = pm

    from repro.core.sim_fast import _KLASS_CODE
    out = {m: np.empty((C, B)) for m in METRICS}
    for c in range(C):
        for g in range(B):
            row = c * B + g
            klass = sorted_cols[g][3]
            vals = _percentile_metrics(
                start[row], finish[row], int(promotions[row]),
                arrival[row], klass == _KLASS_CODE["short"],
                klass == _KLASS_CODE["long"])
            for m, v in zip(METRICS, vals):
                out[m][c, g] = v
    if return_arrays:
        klass = np.tile(np.stack([cols[3] for cols in sorted_cols]),
                        (C, 1))
        return out, (arrival, klass, start, finish, promoted)
    return out


def sweep_poisson(conditions: Sequence[Condition], rhos: Sequence[float],
                  seeds: Sequence[int], n: int, short, long,
                  mix_long: float = 0.5,
                  backend: str = "auto") -> SweepResult:
    """The paper's steady-state grid: conditions x rhos x seeds, one call.

    ``rho = lam * E[S]`` fixes the arrival rate per rho; one workload per
    (rho, seed) is shared across all conditions.
    """
    specs = tuple((p, t) for p, t in conditions)
    conditions = tuple((get_policy(p).name, t) for p, t in specs)
    rhos = tuple(float(r) for r in rhos)
    seeds = tuple(int(s) for s in seeds)
    es = mix_long * long.mean + (1.0 - mix_long) * short.mean
    batches = []
    for rho in rhos:
        lam = rho / es
        for seed in seeds:
            rng = np.random.default_rng(seed)
            batches.append(RequestBatch.poisson(rng, n, lam, short, long,
                                                mix_long=mix_long))
    flat = sweep_batches(batches, specs, backend=backend)
    C, R, S = len(conditions), len(rhos), len(seeds)
    return SweepResult(conditions=conditions, rhos=rhos, seeds=seeds,
                       metrics={m: v.reshape(C, R, S)
                                for m, v in flat.items()})


def sweep_burst(conditions: Sequence[Condition], seeds: Sequence[int],
                n_short: int, n_long: int, short, long,
                window: float = 0.05,
                backend: str = "auto") -> SweepResult:
    """The §5.5 burst grid: all requests arrive within ``window``."""
    specs = tuple((p, t) for p, t in conditions)
    conditions = tuple((get_policy(p).name, t) for p, t in specs)
    seeds = tuple(int(s) for s in seeds)
    batches = [RequestBatch.burst(np.random.default_rng(s), n_short, n_long,
                                  short, long, window=window)
               for s in seeds]
    flat = sweep_batches(batches, specs, backend=backend)
    C, S = len(conditions), len(seeds)
    return SweepResult(conditions=conditions, rhos=(float("nan"),),
                       seeds=seeds,
                       metrics={m: v.reshape(C, 1, S)
                                for m, v in flat.items()})


#: Named per-request acceptance-rate generators for ``sweep_speculative``:
#: name -> fn(rng, n) returning (n,) draft-acceptance rates in [0, 1).
ACCEPT_DISTS = {
    "high": lambda rng, n: np.full(n, 0.9),
    "low": lambda rng, n: np.full(n, 0.2),
    "uniform": lambda rng, n: rng.uniform(0.05, 0.95, n),
    "bimodal": lambda rng, n: np.where(rng.random(n) < 0.5, 0.9, 0.1),
}


@dataclass
class SpeculativeSweepResult:
    """Metric arrays over a conditions x draft-K x acceptance x seeds grid."""

    conditions: Tuple[Condition, ...]
    draft_ks: Tuple[int, ...]
    accept_dists: Tuple[str, ...]
    seeds: Tuple[int, ...]
    metrics: Dict[str, np.ndarray]               # each (C, K, A, S)

    def metric(self, name: str) -> np.ndarray:
        return self.metrics[name]

    def condition_index(self, policy: str, tau: Optional[float]) -> int:
        return self.conditions.index((policy, tau))


def sweep_speculative(conditions: Sequence[Condition],
                      draft_ks: Sequence[int],
                      accept_dists: Sequence,
                      seeds: Sequence[int], n: int, short, long,
                      mix_long: float = 0.5, rho: float = 0.85,
                      draft_cost: float = 0.15,
                      backend: str = "auto") -> SpeculativeSweepResult:
    """The speculative-decoding grid: policy x draft-K x acceptance x seed.

    Mirrors draft-verify decode in the DES as a per-request service-rate
    modifier (``sim_fast.speculative_service``): one Poisson workload per
    seed (rho fixes the arrival rate against the *serial* mean service)
    is shared across every (policy, K, acceptance) cell; each cell scales
    services by ``1 / expected_speedup(accept_rate, K)`` with acceptance
    rates drawn from the named generator (:data:`ACCEPT_DISTS`, or pass
    ``(name, fn)`` pairs).  Acceptance-aware policies (``sjf_effective``)
    receive the per-request rates through ``key_array``; plain policies
    key as usual — the grid that shows when acceptance-aware admission
    beats token-count SJF (heterogeneous acceptance) and when it
    degenerates to it (uniform acceptance).  ``draft_k = 0`` cells are
    the unmodified serial grid.  Key-based policies only.
    """
    from repro.core.sim_fast import _KLASS_CODE, speculative_service
    specs = tuple((p, t) for p, t in conditions)
    policies = [get_policy(p) for p, _ in specs]
    for pol in policies:
        if pol.preemptive:
            raise ValueError(
                f"sweep_speculative supports key-based policies only, "
                f"got preemptive {pol.name!r}")
    conds = tuple((pol.name, t) for pol, (_, t) in zip(policies, specs))
    draft_ks = tuple(int(k) for k in draft_ks)
    dists = [(d, ACCEPT_DISTS[d]) if isinstance(d, str) else (d[0], d[1])
             for d in accept_dists]
    names = tuple(name for name, _ in dists)
    seeds = tuple(int(s) for s in seeds)
    C, K, A, S = len(conds), len(draft_ks), len(dists), len(seeds)

    es = mix_long * long.mean + (1.0 - mix_long) * short.mean
    lam = rho / es
    base = []                        # per seed: arrival-sorted columns
    for seed in seeds:
        rng = np.random.default_rng(seed)
        b = RequestBatch.poisson(rng, n, lam, short, long,
                                 mix_long=mix_long)
        perm = np.lexsort((b.req_id, b.arrival))
        base.append((b.arrival[perm], b.true_service[perm], b.p_long[perm],
                     b.klass[perm], b.tenant[perm], b.tenants))
    accept = {}                      # (ai, si) -> (n,) acceptance rates
    for ai, (_, fn) in enumerate(dists):
        for si, seed in enumerate(seeds):
            accept[ai, si] = np.clip(
                np.asarray(fn(np.random.default_rng((seed, 7919 + ai)), n),
                           np.float64), 0.0, 1.0)

    R = C * K * A * S
    arrival = np.empty((R, n))
    service = np.empty((R, n))
    key = np.empty((R, n))
    taus: List[Optional[float]] = []
    from dataclasses import replace as _replace

    from repro.core.policy import EffectiveSJF
    for c, (pol, (_, tau)) in enumerate(zip(policies, specs)):
        for ki, k in enumerate(draft_ks):
            # acceptance-aware policies must key against the cell's
            # actual draft depth/cost, not their registry defaults (at
            # K=0 the key degenerates to plain predicted service)
            pol_k = _replace(pol, draft_k=k, draft_cost=draft_cost) \
                if isinstance(pol, EffectiveSJF) else pol
            for ai in range(A):
                for si in range(S):
                    row = ((c * K + ki) * A + ai) * S + si
                    arr, svc, pl, _, tc, tn = base[si]
                    a = accept[ai, si]
                    eff = speculative_service(svc, a, k, draft_cost)
                    arrival[row] = arr
                    service[row] = eff
                    try:
                        key[row] = pol_k.key_array(
                            arr, pl, eff, tenant=tc, tenants=tn,
                            accept_rate=a)
                    except TypeError:      # acceptance-unaware policy
                        key[row] = pol_k.key_array(arr, pl, eff,
                                                   tenant=tc, tenants=tn)
                    taus.append(pol.aging.effective_tau(tau))

    if backend == "jax":
        from repro.core.sim_jax import simulate_grid_jax
        start, finish, _, promotions = simulate_grid_jax(
            arrival, service, key, taus)
    else:
        start, finish, _, promotions = simulate_grid(
            arrival, service, key, taus, engine=backend)

    out = {m: np.empty((C, K, A, S)) for m in METRICS}
    for c in range(C):
        for ki in range(K):
            for ai in range(A):
                for si in range(S):
                    row = ((c * K + ki) * A + ai) * S + si
                    klass = base[si][3]
                    vals = _percentile_metrics(
                        start[row], finish[row], int(promotions[row]),
                        arrival[row], klass == _KLASS_CODE["short"],
                        klass == _KLASS_CODE["long"])
                    for m, v in zip(METRICS, vals):
                        out[m][c, ki, ai, si] = v
    return SpeculativeSweepResult(conditions=conds, draft_ks=draft_ks,
                                  accept_dists=names, seeds=seeds,
                                  metrics=out)


@dataclass
class LaneSweepResult:
    """Metric arrays over a conditions x lanes x budgets x seeds grid."""

    conditions: Tuple[Condition, ...]
    lanes: Tuple[int, ...]
    budgets: Tuple[Optional[float], ...]
    seeds: Tuple[int, ...]
    metrics: Dict[str, np.ndarray]               # each (C, L, B, S)

    def metric(self, name: str) -> np.ndarray:
        return self.metrics[name]


def sweep_lanes(conditions: Sequence[Condition], lanes: Sequence[int],
                seeds: Sequence[int], n: int, rho: float, short, long,
                mix_long: float = 0.5, slowdown=None,
                budgets: Sequence[Optional[float]] = (None,),
                mem_tokens_per_s: float = 60.0) -> LaneSweepResult:
    """The batch-degree grid: policy x lane-count x KV-budget x seed,
    answering "how much of the scheduling win does batching recover, and
    what does predictive admission still add on top" in one call.

    * ``lanes``: decode-lane counts c (c=1 rows are bitwise-equal to the
      serial engine for key policies, so the existing sweeps anchor the
      grid);
    * ``slowdown``: per-lane service stretch ``s[k-1]`` at k busy lanes,
      covering at least ``max(lanes)`` entries (calibrate from the real
      engine — ``benchmarks/batching_bench.py`` measures it); default
      ideal scaling;
    * ``budgets``: KV-memory budgets in *memory tokens* (None =
      lane-limited only).  A request's demand is its KV residency proxy
      ``true_service x mem_tokens_per_s`` (service seconds x decode
      rate ~ output tokens pinned in cache).

    One workload per seed at the given ``rho`` is shared across every
    (condition, c, budget) cell — paired comparisons, like
    :func:`sweep_poisson`.  Conditions may mix key-based policies and
    srpt; quantum policies (mlfq) are rejected by the c-server engine.
    """
    specs = tuple((p, t) for p, t in conditions)
    named = tuple((get_policy(p).name, t) for p, t in specs)
    lanes = tuple(int(c) for c in lanes)
    budgets = tuple(budgets)
    seeds = tuple(int(s) for s in seeds)
    es = mix_long * long.mean + (1.0 - mix_long) * short.mean
    lam = rho / es
    batches = [RequestBatch.poisson(np.random.default_rng(s), n, lam,
                                    short, long, mix_long=mix_long)
               for s in seeds]
    out = sweep_lane_batches(batches, specs, lanes, budgets=budgets,
                             slowdown=slowdown,
                             mem_tokens_per_s=mem_tokens_per_s)
    return LaneSweepResult(conditions=named, lanes=lanes, budgets=budgets,
                           seeds=seeds, metrics=out)


def sweep_lane_batches(batches: Sequence[RequestBatch],
                       conditions: Sequence[Condition],
                       lanes: Sequence[int],
                       budgets: Sequence[Optional[float]] = (None,),
                       slowdown=None,
                       mem_tokens_per_s: float = 60.0) -> Dict[str, np.ndarray]:
    """Batch-level core of :func:`sweep_lanes` (the analogue of
    :func:`sweep_batches`): callers that prepare their own workloads —
    e.g. to inject noisy predictor scores — pass them directly.

    Returns ``{metric: (C, L, B, G) ndarray}`` over conditions x lanes x
    budgets x batches.
    """
    policies = [get_policy(p) for p, _ in conditions]
    lanes = tuple(int(c) for c in lanes)
    budgets = tuple(budgets)
    if slowdown is None:
        slowdown = (1.0,) * max(lanes)
    slowdown = tuple(float(x) for x in slowdown)
    C, G = len(conditions), len(batches)
    n = len(batches[0])
    assert all(len(b) == n for b in batches), "batches must be same length"

    sorted_cols = []
    for b in batches:
        perm = np.lexsort((b.req_id, b.arrival))
        sorted_cols.append((b.arrival[perm], b.true_service[perm],
                            b.p_long[perm], b.klass[perm], b.tenant[perm],
                            b.tenants))

    arrival = np.empty((C * G, n))
    service = np.empty((C * G, n))
    key = np.empty((C * G, n))
    mem = np.empty((C * G, n))
    taus: List[Optional[float]] = []
    modes = np.zeros(C * G, np.int8)
    for c_i, ((_, tau), pol) in enumerate(zip(conditions, policies)):
        for g, (arr, svc, pl, _, tc, tn) in enumerate(sorted_cols):
            row = c_i * G + g
            arrival[row] = arr
            service[row] = svc
            key[row] = pol.key_array(arr, pl, svc, tenant=tc, tenants=tn)
            mem[row] = svc * mem_tokens_per_s
            taus.append(pol.aging.effective_tau(tau))
            modes[row] = pol.mode

    from repro.core.sim_fast import _KLASS_CODE
    out = {m: np.empty((C, len(lanes), len(budgets), G)) for m in METRICS}
    for li, c in enumerate(lanes):
        for bi, budget in enumerate(budgets):
            start, finish, _, promotions, _ = simulate_grid_servers(
                arrival, service, key, taus, c, slowdown=slowdown[:c],
                mem=None if budget is None else mem,
                mem_budget=budget, mode=modes)
            for c_i in range(C):
                for g in range(G):
                    row = c_i * G + g
                    klass = sorted_cols[g][3]
                    vals = _percentile_metrics(
                        start[row], finish[row], int(promotions[row]),
                        arrival[row], klass == _KLASS_CODE["short"],
                        klass == _KLASS_CODE["long"])
                    for m, v in zip(METRICS, vals):
                        out[m][c_i, li, bi, g] = v
    return out


@dataclass
class PagingSweepResult:
    """Metric arrays over conditions x page-size x budget x share x seed."""

    conditions: Tuple[Condition, ...]
    page_sizes: Tuple[int, ...]
    budgets: Tuple[float, ...]                   # memory tokens
    share_ratios: Tuple[float, ...]
    seeds: Tuple[int, ...]
    metrics: Dict[str, np.ndarray]               # each (C, P, B, R, S)

    def metric(self, name: str) -> np.ndarray:
        return self.metrics[name]


PAGING_METRICS = METRICS + ("preemptions", "prefix_hits", "peak_pages")


def sweep_paging(conditions: Sequence[Condition],
                 page_sizes: Sequence[int], budgets: Sequence[float],
                 share_ratios: Sequence[float], seeds: Sequence[int],
                 n: int, rho: float, short, long, mix_long: float = 0.5,
                 n_servers: int = 4, slowdown=None,
                 mem_tokens_per_s: float = 60.0, prompt_frac: float = 0.35,
                 shared_tokens: Optional[float] = None,
                 prefill_s_per_token: float = 5e-4) -> PagingSweepResult:
    """The block-paged memory grid: policy x page-size x byte-budget x
    prefix-share-ratio through the paged c-server engine
    (``sim_fast.simulate_grid_paged``), answering how much sojourn the
    page-granular accounting recovers at a FIXED budget, and how page
    size and prefix sharing move it.

    * ``page_sizes`` x ``budgets``: the pool is ``budget // page_size``
      pages — the same memory-token budget sliced at different
      granularities (big pages waste more of the last partial page;
      the DES's linear-growth model shows the admission-level effect);
    * ``share_ratios``: each request independently shares a fixed
      ``shared_tokens``-token system prefix with probability r.  Warm
      admissions skip those pages and ``shared_tokens x
      prefill_s_per_token`` seconds of prefill;
    * request memory: total residency ``true_service x
      mem_tokens_per_s`` tokens, of which ``prompt_frac`` is prompt
      (admission-time) and the rest decode growth.  ``shared_tokens``
      defaults to half the mean prompt.

    One workload per seed is shared across every cell (paired).
    Returns metric arrays ``(C, P, B, R, S)``; beyond the standard
    sojourn metrics: ``preemptions`` (pool-exhaustion pageouts),
    ``prefix_hits`` (warm admissions) and ``peak_pages``.
    """
    from repro.core.sim_fast import _KLASS_CODE, simulate_grid_paged
    specs = tuple((p, t) for p, t in conditions)
    named = tuple((get_policy(p).name, t) for p, t in specs)
    policies = [get_policy(p) for p, _ in specs]
    page_sizes = tuple(int(p) for p in page_sizes)
    budgets = tuple(float(b) for b in budgets)
    share_ratios = tuple(float(r) for r in share_ratios)
    seeds = tuple(int(s) for s in seeds)
    if slowdown is None:
        slowdown = (1.0,) * int(n_servers)
    es = mix_long * long.mean + (1.0 - mix_long) * short.mean
    lam = rho / es
    if shared_tokens is None:
        shared_tokens = 0.5 * prompt_frac * es * mem_tokens_per_s
    C, G = len(specs), len(seeds)

    arrival = np.empty((C * G, n))
    service = np.empty((C * G, n))
    key = np.empty((C * G, n))
    total_tok = np.empty((C * G, n))
    prompt_tok = np.empty((C * G, n))
    taus: List[Optional[float]] = []
    modes = np.zeros(C * G, np.int8)
    klasses = []
    shared_mask = {}                 # seed index -> per-ratio request mask
    for g, s in enumerate(seeds):
        rng = np.random.default_rng(s)
        b = RequestBatch.poisson(rng, n, lam, short, long,
                                 mix_long=mix_long)
        perm = np.lexsort((b.req_id, b.arrival))
        arr, svc = b.arrival[perm], b.true_service[perm]
        pl, tc, tn = b.p_long[perm], b.tenant[perm], b.tenants
        klasses.append(b.klass[perm])
        # one uniform draw per request, thresholded per ratio: raising r
        # only ADDS shared requests (nested masks, cleaner trends)
        u = rng.random(n)
        shared_mask[g] = {r: u < r for r in share_ratios}
        tot = svc * mem_tokens_per_s
        for c_i, ((_, tau), pol) in enumerate(zip(specs, policies)):
            row = c_i * G + g
            arrival[row] = arr
            service[row] = svc
            key[row] = pol.key_array(arr, pl, svc, tenant=tc, tenants=tn)
            total_tok[row] = tot
            prompt_tok[row] = prompt_frac * tot
            taus.append(pol.aging.effective_tau(tau))
            modes[row] = pol.mode

    shape = (C, len(page_sizes), len(budgets), len(share_ratios), G)
    out = {m: np.empty(shape) for m in PAGING_METRICS}
    for ri, ratio in enumerate(share_ratios):
        grp = np.full((C * G, n), -1, np.int64)
        shared = np.zeros((C * G, n))
        saved = np.zeros((C * G, n))
        ptok = prompt_tok.copy()
        ttok = total_tok.copy()
        for g in range(G):
            m = shared_mask[g][ratio]
            for c_i in range(C):
                row = c_i * G + g
                grp[row, m] = 0                      # one system prefix
                shared[row, m] = shared_tokens
                saved[row, m] = shared_tokens * prefill_s_per_token
                ptok[row, m] += shared_tokens        # prefix + private
                ttok[row, m] += shared_tokens
        for pi, ps in enumerate(page_sizes):
            for bi, budget in enumerate(budgets):
                n_pages = max(1, int(budget // ps))
                (start, finish, _, promotions, preempts, hits,
                 peak) = simulate_grid_paged(
                    arrival, service, key, taus, n_servers,
                    -(-ptok // ps), -(-ttok // ps), n_pages,
                    slowdown=slowdown, mode=modes, share_group=grp,
                    shared_pages=shared // ps,
                    prefill_saved=saved)
                for c_i in range(C):
                    for g in range(G):
                        row = c_i * G + g
                        klass = klasses[g]
                        vals = _percentile_metrics(
                            start[row], finish[row], int(promotions[row]),
                            arrival[row],
                            klass == _KLASS_CODE["short"],
                            klass == _KLASS_CODE["long"])
                        cell = (c_i, pi, bi, ri, g)
                        for m, v in zip(METRICS, vals):
                            out[m][cell] = v
                        out["preemptions"][cell] = float(preempts[row])
                        out["prefix_hits"][cell] = float(hits[row])
                        out["peak_pages"][cell] = float(peak[row])
    return PagingSweepResult(conditions=named, page_sizes=page_sizes,
                             budgets=budgets, share_ratios=share_ratios,
                             seeds=seeds, metrics=out)


def run_grid(axes: Dict[str, Sequence], fn: Callable) -> Dict[tuple, object]:
    """Evaluate ``fn(**point)`` over the cartesian product of ``axes``.

    The non-DES grid helper: the accuracy tables (model x feature-group,
    model x baseline) run their whole grid through one call and get back
    ``{(v1, v2, ...): fn_result}`` keyed in axis order.
    """
    names = list(axes)
    return {combo: fn(**dict(zip(names, combo)))
            for combo in itertools.product(*(axes[k] for k in names))}


# ---------------------------------------------------------------------------
# Fault-injection grid (PR 6): conditions x crash-MTBF x repair x seeds.
# ---------------------------------------------------------------------------

FAULT_METRICS = METRICS + ("goodput", "shed_rate", "timeout_rate",
                           "requeues")


@dataclass
class FaultSweepResult:
    """Metric arrays over a conditions x mtbfs x repairs x seeds grid.

    ``mtbf = inf`` rows are the no-fault baseline (and are bitwise
    trace-equal to the clean engines).  Latency metrics aggregate served
    requests only; ``goodput`` is served requests per unit makespan,
    ``shed_rate`` the pre-dispatch shed fraction, ``timeout_rate`` the
    in-service deadline-expiry fraction (always 0 unless the sweep ran
    with ``in_service_timeout=True``), ``requeues`` crash-requeue count.
    """

    conditions: Tuple[Condition, ...]
    mtbfs: Tuple[float, ...]
    repairs: Tuple[float, ...]
    seeds: Tuple[int, ...]
    metrics: Dict[str, np.ndarray]               # each (C, F, R, S)

    def metric(self, name: str) -> np.ndarray:
        return self.metrics[name]

    def condition_index(self, policy: str, tau) -> int:
        return self.conditions.index((policy, tau))


def sweep_faults(conditions: Sequence[Condition], mtbfs: Sequence[float],
                 repairs: Sequence[float], seeds: Sequence[int],
                 n: int, short, long, rho: float = 0.7,
                 mix_long: float = 0.5, deadline: Optional[float] = None,
                 in_service_timeout: bool = False,
                 stall_mtbf: float = 0.0, stall_s: float = 10.0,
                 stall_factor: float = 2.0,
                 batches: Optional[Sequence[RequestBatch]] = None
                 ) -> FaultSweepResult:
    """The robustness grid: does the scheduling win survive faults?

    One Poisson workload per seed is shared across every condition and
    every fault cell; one fault timeline per (mtbf, repair, seed) cell is
    shared across all conditions — fully paired comparisons on both axes.
    ``mtbf = inf`` (or 0) disables crashes for that column, giving the
    in-grid no-fault baseline.  Key-based conditions only (the fault
    engine is non-preemptive).  ``batches`` (one per seed) overrides the
    internal Poisson generation — use for noisy-predictor workloads.
    """
    from repro.core.sim_fast import ServerFaults, simulate_grid_faults
    specs = tuple((p, t) for p, t in conditions)
    policies = [get_policy(p) for p, _ in specs]
    if any(p.preemptive for p in policies):
        raise ValueError("sweep_faults supports key-based policies only")
    conditions = tuple((p.name, t) for p, (_, t) in zip(policies, specs))
    mtbfs = tuple(float(m) for m in mtbfs)
    repairs = tuple(float(r) for r in repairs)
    seeds = tuple(int(s) for s in seeds)
    C, F, R, S = len(conditions), len(mtbfs), len(repairs), len(seeds)

    es = mix_long * long.mean + (1.0 - mix_long) * short.mean
    lam = rho / es
    if batches is not None and len(batches) != S:
        raise ValueError(f"need one batch per seed ({S})")
    cols = []
    for si, seed in enumerate(seeds):
        if batches is not None:
            b = batches[si]
        else:
            rng = np.random.default_rng(seed)
            b = RequestBatch.poisson(rng, n, lam, short, long,
                                     mix_long=mix_long)
        perm = np.lexsort((b.req_id, b.arrival))
        cols.append((b.arrival[perm], b.true_service[perm],
                     b.p_long[perm], b.klass[perm], b.tenant[perm],
                     b.tenants))

    # one timeline per (mtbf, repair, seed) — horizon covers the busy
    # period with slack for repair-time queue growth
    timelines = {}
    for fi, mtbf in enumerate(mtbfs):
        for ri, rep in enumerate(repairs):
            for si, seed in enumerate(seeds):
                horizon = float(cols[si][0][-1]) + 20.0 * es
                rng = np.random.default_rng((seed, fi, ri, 7))
                eff = 0.0 if not np.isfinite(mtbf) else mtbf
                timelines[fi, ri, si] = ServerFaults.random(
                    rng, horizon, mtbf=eff, mttr=rep,
                    stall_mtbf=stall_mtbf, stall_s=stall_s,
                    stall_factor=stall_factor)

    G = C * F * R * S
    n = cols[0][0].shape[0]           # batches may override the target n
    arrival = np.empty((G, n))
    service = np.empty((G, n))
    key = np.empty((G, n))
    taus: List[Optional[float]] = []
    faults = []
    for c, ((_, tau), pol) in enumerate(zip(specs, policies)):
        for fi in range(F):
            for ri in range(R):
                for si in range(S):
                    row = ((c * F + fi) * R + ri) * S + si
                    arr, svc, pl, _, tc, tn = cols[si]
                    arrival[row] = arr
                    service[row] = svc
                    key[row] = pol.key_array(arr, pl, svc, tenant=tc,
                                             tenants=tn)
                    taus.append(pol.aging.effective_tau(tau))
                    faults.append(timelines[fi, ri, si])
    start, finish, promoted, promotions, shed, timeout, requeues = \
        simulate_grid_faults(arrival, service, key, taus, faults,
                             deadline=deadline,
                             in_service_timeout=in_service_timeout)

    from repro.core.sim_fast import _KLASS_CODE
    out = {m: np.empty((C, F, R, S)) for m in FAULT_METRICS}
    for c in range(C):
        for fi in range(F):
            for ri in range(R):
                for si in range(S):
                    row = ((c * F + fi) * R + ri) * S + si
                    klass = cols[si][3]
                    ok = ~shed[row] & ~timeout[row]
                    vals = _percentile_metrics(
                        start[row][ok], finish[row][ok],
                        int(promotions[row]), arrival[row][ok],
                        (klass == _KLASS_CODE["short"])[ok],
                        (klass == _KLASS_CODE["long"])[ok])
                    mk = float(finish[row][ok].max()) if ok.any() else 0.0
                    vals = vals[:-1] + (mk,)
                    for m, v in zip(METRICS, vals):
                        out[m][c, fi, ri, si] = v
                    out["goodput"][c, fi, ri, si] = \
                        (ok.sum() / mk) if mk > 0 else 0.0
                    out["shed_rate"][c, fi, ri, si] = shed[row].mean()
                    out["timeout_rate"][c, fi, ri, si] = \
                        timeout[row].mean()
                    out["requeues"][c, fi, ri, si] = requeues[row]
    return FaultSweepResult(conditions=conditions, mtbfs=mtbfs,
                            repairs=repairs, seeds=seeds, metrics=out)
