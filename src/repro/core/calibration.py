"""Starvation-timeout calibration (paper §3.4: tau = 3 x mu_short).

mu_short must be the mean Short-request *sojourn* time under representative
mixed-workload queueing conditions — NOT the isolated sequential service time
(the paper is emphatic about this distinction).  ``measure_mu_short``
reproduces profiler/measure_mu_short.py: dispatch a concurrent mixed burst,
average Short sojourns.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.simulation import ServiceDist, burst_workload, simulate

TAU_MULTIPLIER = 3.0  # the paper's Pareto-elbow choice


def measure_mu_short(short: ServiceDist, long: ServiceDist,
                     n_short: int = 50, n_long: int = 50,
                     policy: str = "sjf", seed: int = 0,
                     effective_rate: float = 1.0) -> float:
    """Mean short-request sojourn under a mixed concurrent burst.

    ``effective_rate`` rescales both class distributions by the backend's
    aggregate speculative speedup (``serving.service_time
    .expected_speedup``) so tau is calibrated against the sojourns the
    speculative backend actually produces.  The default 1.0 divides by
    one — an IEEE-exact identity, so pre-speculation calibrations are
    bitwise unchanged.
    """
    rng = np.random.default_rng(seed)
    if effective_rate != 1.0:
        if effective_rate <= 0.0:
            raise ValueError(
                f"effective_rate must be positive, got {effective_rate}")
        short = ServiceDist(mean=short.mean / effective_rate,
                            std=short.std / effective_rate,
                            floor=short.floor / effective_rate)
        long = ServiceDist(mean=long.mean / effective_rate,
                           std=long.std / effective_rate,
                           floor=long.floor / effective_rate)
    reqs = burst_workload(rng, n_short, n_long, short, long)
    res = simulate(reqs, policy=policy, tau=None)
    return res.mean(klass="short", attr="sojourn")


def calibrate_tau(short: ServiceDist, long: ServiceDist,
                  multiplier: float = TAU_MULTIPLIER, **kw) -> float:
    """tau = multiplier x mu_short (default 3x, the paper's heuristic)."""
    return multiplier * measure_mu_short(short, long, **kw)
