"""Straggler detection and mitigation.

Training side: per-step wall-time EWMA with z-score outlier detection —
flags slow steps/hosts so the launcher can exclude a host (elastic.py) or
enable backup execution.  Serving side: the router's hedged dispatch
(core/router.py) re-enqueues requests whose replica missed its deadline —
for non-preemptive SJF this is safe by construction (nothing mid-flight is
lost except the active request, replayed at the head).
"""

from __future__ import annotations

import math
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class StepTimer:
    alpha: float = 0.1          # EWMA coefficient
    z_threshold: float = 3.0    # flag steps slower than mean + z*std
    warmup: int = 5
    mean: float = 0.0
    var: float = 0.0
    count: int = 0
    flagged: List[int] = field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        self.count += 1
        if self.count <= self.warmup:
            # prime the statistics
            d = seconds - self.mean
            self.mean += d / self.count
            self.var += d * (seconds - self.mean)
            return False
        std = math.sqrt(max(self.var / max(self.count - 1, 1), 1e-12))
        # floor at 5% of the mean: near-constant step times must not make
        # ordinary jitter look like a straggler
        std = max(std, 0.05 * self.mean)
        is_straggler = seconds > self.mean + self.z_threshold * std
        if is_straggler:
            self.flagged.append(step)
        else:
            # only track "normal" steps in the running stats
            d = seconds - self.mean
            self.mean = (1 - self.alpha) * self.mean + self.alpha * seconds
            self.var = (1 - self.alpha) * self.var + self.alpha * d * d
        return is_straggler


@dataclass
class HostMonitor:
    """Cross-host step-time comparison (each host reports durations)."""
    slow_ratio: float = 1.5     # host is a straggler at 1.5x median
    window: int = 20
    history: Dict[str, deque] = field(default_factory=dict)

    def observe(self, host: str, seconds: float) -> None:
        self.history.setdefault(
            host, deque(maxlen=self.window)).append(seconds)

    def stragglers(self) -> List[str]:
        if len(self.history) < 2:
            return []
        medians = {h: sorted(v)[len(v) // 2] for h, v in self.history.items()
                   if v}
        overall = sorted(medians.values())[len(medians) // 2]
        return [h for h, m in medians.items() if m > self.slow_ratio * overall]
