"""Elastic scaling: survive node loss by re-meshing and resuming.

Flow on failure (orchestrated by launch/train.py):
  1. detect reduced device count (heartbeat timeout / restart with fewer hosts)
  2. ``make_elastic_mesh(n_remaining)`` — keep the model axis intact (TP
     shards of the weights must stay complete), shrink the data axis
  3. ``restore`` the latest checkpoint against shardings resolved on the new
     mesh (checkpoint.py restore IS the reshard)
  4. rescale per-host batch or raise microbatch count so the GLOBAL batch is
     preserved, and continue from the recorded data step (the synthetic
     stream is a pure function of (seed, step, host) — no replay log needed)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax

from repro.launch.mesh import make_elastic_mesh
from repro.sharding.partition import tree_shardings
from repro.training import checkpoint as ckpt


@dataclass
class ElasticPlan:
    mesh: Any
    data_parallel: int
    microbatch_scale: int   # multiply microbatches by this to keep global batch


def plan_remesh(device_count: int, model_parallel: int,
                old_data_parallel: int) -> ElasticPlan:
    mesh = make_elastic_mesh(device_count, model_parallel)
    new_dp = device_count // model_parallel
    if old_data_parallel % new_dp:
        raise ValueError(
            f"cannot keep global batch: old dp {old_data_parallel} not a "
            f"multiple of new dp {new_dp}")
    return ElasticPlan(mesh=mesh, data_parallel=new_dp,
                       microbatch_scale=old_data_parallel // new_dp)


def resume_on_mesh(state_template: Any, state_axes: Any, mesh,
                   ckpt_root, step: Optional[int] = None) -> Any:
    """Restore the latest checkpoint resharded onto ``mesh``."""
    shardings = tree_shardings(state_template, state_axes, mesh)
    return ckpt.restore(state_template, ckpt_root, step=step,
                        shardings=shardings)
