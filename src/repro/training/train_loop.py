"""Train-step factory: loss -> grads -> optimizer, pjit-ready.

``make_train_step(cfg, opt_cfg)`` returns a pure function
``train_step(state, batch) -> (state, metrics)`` suitable for ``jax.jit``
with explicit in/out shardings (see launch/dryrun.py and launch/train.py).
Gradient accumulation (microbatching) loops with ``lax.scan`` over microbatch
slices — compute/comm overlap falls out of GSPMD pipelining the per-microbatch
reduce with the next microbatch's compute.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.model import LM
from repro.training.optimizer import (OptConfig, OptState, apply_updates,
                                      init_opt_state, opt_state_axes)


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def init_train_state(cfg, opt_cfg: OptConfig, key) -> TrainState:
    lm = LM(cfg)
    params = lm.init(key)
    return TrainState(params=params, opt=init_opt_state(params, opt_cfg))


def abstract_train_state(cfg, opt_cfg: OptConfig):
    """(ShapeDtypeStruct TrainState, logical-axes TrainState) — no alloc."""
    lm = LM(cfg)
    p_shapes, p_axes = lm.abstract_params()
    o_shapes = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), p_shapes)
    o_axes = opt_state_axes(p_axes, opt_cfg)
    return (TrainState(params=p_shapes, opt=o_shapes),
            TrainState(params=p_axes, opt=o_axes))


def make_train_step(cfg, opt_cfg: OptConfig, microbatches: int = 1,
                    remat: bool = True, accum_dtype: str = "float32"):
    """``accum_dtype``: gradient-accumulation buffer dtype.  The f32 tree is
    2x params — at 400B params that alone is ~12 GB/device (double-buffered
    scan carry), so the biggest MoE archs accumulate in bf16."""
    lm = LM(cfg)
    acc_dt = jnp.dtype(accum_dtype)

    def loss_fn(params, batch):
        return lm.loss(params, batch, remat=remat)

    def train_step(state: TrainState, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            def slice_mb(x, i):
                mb = x.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def acc_body(carry, i):
                loss_acc, grad_acc = carry
                mb = jax.tree.map(lambda x: slice_mb(x, i), batch)
                loss, grads = jax.value_and_grad(loss_fn)(state.params, mb)
                grad_acc = jax.tree.map(
                    lambda a, g: (a + g.astype(acc_dt)).astype(acc_dt),
                    grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), state.params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros(()), zeros), jnp.arange(microbatches))
            loss = loss / microbatches
            grads = jax.tree.map(
                lambda g: g.astype(jnp.float32) / microbatches, grads)

        params, opt, metrics = apply_updates(state.params, grads, state.opt,
                                             opt_cfg)
        metrics["loss"] = loss
        return TrainState(params=params, opt=opt), metrics

    return train_step
