"""Sharded checkpointing with atomic commits and elastic restore.

Layout (one directory per step):

    ckpt_root/
      step_000420.tmp.<nonce>/   # written here first
      step_000420/               # atomic rename after fsync
        manifest.msgpack         # treedef, shapes, dtypes, crc32 digests
        leaf_00000.npy ...       # one file per pytree leaf

Design points for 1000+ nodes:
* atomic tmp+rename commit — a crash mid-save never corrupts the latest
  checkpoint; ``latest_step`` only believes committed directories;
* integrity digests (crc32 per leaf) verified on restore;
* restore is *resharding*: arrays are loaded host-side and ``device_put``
  against whatever mesh/sharding the caller provides — the elastic path
  (512 -> 256 chips) is just a restore with a different mesh;
* async save: ``AsyncCheckpointer`` snapshots to host memory synchronously
  (cheap) and writes in a background thread, overlapping I/O with the next
  training steps — the paper-trail for "checkpoint/restart" fault tolerance;
* bounded retention (keep_last) so disks on long runs don't fill.

In a real multi-host deployment each host writes only its addressable
shards; on this single-process container the full array is written.  The
manifest format carries per-leaf shape/dtype so that change is local.
"""

from __future__ import annotations

import os
import pathlib
import shutil
import threading
import time
import uuid
import zlib
from typing import Any, Optional

import jax
import msgpack
import numpy as np

MANIFEST = "manifest.msgpack"


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def save(state: Any, root: str | os.PathLike, step: int,
         keep_last: Optional[int] = None) -> pathlib.Path:
    root = pathlib.Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:06d}"
    tmp = root / f"step_{step:06d}.tmp.{uuid.uuid4().hex[:8]}"
    tmp.mkdir(parents=True)

    leaves, treedef = jax.tree.flatten(state)
    digests = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        path = tmp / _leaf_name(i)
        with open(path, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        digests.append(zlib.crc32(arr.tobytes()))

    manifest = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "shapes": [list(np.asarray(l).shape) for l in leaves],
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "digests": digests,
        "time": time.time(),
    }
    mpath = tmp / MANIFEST
    with open(mpath, "wb") as f:
        f.write(msgpack.packb(manifest))
        f.flush()
        os.fsync(f.fileno())

    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit

    if keep_last:
        steps = sorted(all_steps(root))
        for s in steps[:-keep_last]:
            shutil.rmtree(root / f"step_{s:06d}", ignore_errors=True)
    return final


def all_steps(root: str | os.PathLike) -> list[int]:
    root = pathlib.Path(root)
    out = []
    for d in root.glob("step_*"):
        if d.is_dir() and ".tmp." not in d.name and (d / MANIFEST).exists():
            out.append(int(d.name.split("_")[1]))
    return sorted(out)


def latest_step(root: str | os.PathLike) -> Optional[int]:
    steps = all_steps(root)
    return steps[-1] if steps else None


def restore(template: Any, root: str | os.PathLike, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True) -> Any:
    """Restore into the structure of ``template``.

    ``shardings``: optional pytree of NamedShardings (same structure) — each
    leaf is device_put with its sharding, which is also the elastic-remesh
    path.  Without it, arrays go to the default device.
    """
    root = pathlib.Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = root / f"step_{step:06d}"
    manifest = msgpack.unpackb((d / MANIFEST).read_bytes())

    _, treedef = jax.tree.flatten(template)
    if manifest["num_leaves"] != treedef.num_leaves:
        raise ValueError(
            f"checkpoint has {manifest['num_leaves']} leaves; "
            f"template has {treedef.num_leaves}")

    sh_leaves = (jax.tree.leaves(
        shardings, is_leaf=lambda x: hasattr(x, "device_set"))
        if shardings is not None else [None] * manifest["num_leaves"])

    leaves = []
    for i in range(manifest["num_leaves"]):
        arr = np.load(d / _leaf_name(i))
        if verify and zlib.crc32(arr.tobytes()) != manifest["digests"][i]:
            raise IOError(f"checkpoint leaf {i} failed integrity check")
        if sh_leaves[i] is not None:
            leaves.append(jax.device_put(arr, sh_leaves[i]))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree.unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Snapshot synchronously, write asynchronously (overlaps with compute)."""

    def __init__(self, root: str | os.PathLike, keep_last: int = 3):
        self.root = pathlib.Path(root)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, state: Any, step: int) -> None:
        self.wait()  # one outstanding save at a time
        host_state = jax.tree.map(lambda l: np.asarray(l), state)

        def _run():
            try:
                save(host_state, self.root, step, keep_last=self.keep_last)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
