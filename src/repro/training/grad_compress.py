"""Int8 gradient compression with error feedback (distributed-opt trick).

Replaces the f32 data-axis all-reduce with the two-phase quantized exchange:

    q = quant8(g + e)                      # error-feedback input
    chunks = all_to_all(q)                 # phase 1: 1 byte/elem on the wire
    partial = sum(dequant(chunks))         # local reduction
    out = all_gather(quant8(partial))      # phase 2: 1 byte/elem
    e' = (g + e) - dequant(q)              # residual kept locally

Wire bytes: ~2x1 B/elem vs 2x4 B/elem for a ring f32 all-reduce -> 4x less
collective traffic on the gradient exchange.  Error feedback makes the
quantization noise a *delayed* correction instead of a bias (1-bit-Adam
lineage), which is what keeps convergence intact.

Expressed with shard_map over the data axis; per-tensor scale in f32.
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quant8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(g, axis_name: str):
    """Mean over ``axis_name`` of g via int8 two-phase exchange.

    Must run inside shard_map with ``axis_name`` manual.  g: any shape; the
    leading dim must be divisible by the axis size (pad upstream).
    """
    n = jax.lax.psum(1, axis_name)
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    q, scale = _quant8(flat)
    # phase 1: scatter chunks to owners
    chunks = q.reshape(n, -1)
    mine = jax.lax.all_to_all(chunks, axis_name, split_axis=0,
                              concat_axis=0, tiled=True)
    scales = jax.lax.all_gather(scale, axis_name)          # (n,)
    part = jnp.sum(mine.reshape(n, -1).astype(jnp.float32)
                   * scales[:, None], axis=0) / n
    # phase 2: gather reduced chunks back
    q2, s2 = _quant8(part)
    full_q = jax.lax.all_gather(q2, axis_name)             # (n, chunk)
    full_s = jax.lax.all_gather(s2, axis_name)
    out = (full_q.astype(jnp.float32) * full_s[:, None]).reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(g.shape)


def make_compressed_allreduce(mesh, axis_name: str = "data"):
    """Returns mean_fn(tree) -> tree, reducing over ``axis_name`` with int8
    compression + error feedback state threaded explicitly."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding.compat import shard_map_unchecked

    def one(g):
        fn = functools.partial(compressed_psum_mean, axis_name=axis_name)
        # output IS replicated (phase-2 all-gather), but the checker cannot
        # infer that through the quantize/dequantize ops
        return shard_map_unchecked(fn, mesh, P(), P())(g)

    def mean_fn(tree):
        return jax.tree.map(one, tree)

    return mean_fn


def apply_error_feedback(grads: Any, error: Any,
                         quantize=_quant8, dequantize=_dequant8
                         ) -> Tuple[Any, Any]:
    """(compensated_quantized_grads, new_error) per leaf, host/jit-agnostic."""
    def one(g, e):
        comp = g.astype(jnp.float32) + e
        q, s = quantize(comp)
        deq = dequantize(q, s)
        return deq, comp - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]))


def init_error_state(grads_template: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_template)
