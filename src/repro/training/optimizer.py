"""Optimizers from scratch: AdamW and Adafactor, sharding-transparent.

Moments mirror the parameter pytree, so under 2D (FSDP x TP) weight sharding
the optimizer state is automatically fully sharded over the whole mesh
(ZeRO-style for free).  ``moment_dtype`` trades optimizer-state memory for
precision — the 400B-class MoE archs need bf16 moments to fit 16 GB/chip at
512 chips (see EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    kind: str = "adamw"          # adamw | adafactor
    warmup_steps: int = 100


class OptState(NamedTuple):
    step: jax.Array
    m: Any          # adamw: first moment  | adafactor: row stats
    v: Any          # adamw: second moment | adafactor: col stats


def init_opt_state(params, cfg: OptConfig) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    if cfg.kind == "adamw":
        zeros = lambda p: jnp.zeros_like(p, dtype=dt)
        return OptState(step=jnp.zeros((), jnp.int32),
                        m=jax.tree.map(zeros, params),
                        v=jax.tree.map(zeros, params))
    if cfg.kind == "adafactor":
        def row(p):
            if p.ndim < 2:
                return jnp.zeros_like(p, dtype=jnp.float32)
            return jnp.zeros(p.shape[:-1], jnp.float32)

        def col(p):
            if p.ndim < 2:
                return jnp.zeros((), jnp.float32)
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)

        return OptState(step=jnp.zeros((), jnp.int32),
                        m=jax.tree.map(row, params),
                        v=jax.tree.map(col, params))
    raise ValueError(cfg.kind)


def opt_state_axes(params_axes, cfg: OptConfig):
    """Logical axes for the optimizer state (mirrors params)."""
    from repro.sharding.rules import is_axes_leaf
    if cfg.kind == "adamw":
        return OptState(step=(), m=params_axes, v=params_axes)
    strip_last = lambda a: a[:-1] if len(a) >= 2 else a
    strip_mid = lambda a: (a[:-2] + a[-1:]) if len(a) >= 2 else ()
    mp = jax.tree.map(strip_last, params_axes, is_leaf=is_axes_leaf)
    vp = jax.tree.map(strip_mid, params_axes, is_leaf=is_axes_leaf)
    return OptState(step=(), m=mp, v=vp)


def _map_multi(fn, n_out: int, *trees):
    """tree.map for functions returning n_out values (tuple-structure-safe)."""
    leaves0, treedef = jax.tree.flatten(trees[0])
    rest = [jax.tree.leaves(t) for t in trees[1:]]
    outs = [fn(*args) for args in zip(leaves0, *rest)]
    return tuple(jax.tree.unflatten(treedef, [o[i] for o in outs])
                 for i in range(n_out))


def _schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def apply_updates(params, grads, state: OptState, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    step = state.step + 1
    lr = _schedule(cfg, step)

    if cfg.kind == "adamw":
        b1, b2 = cfg.betas
        mdt = jnp.dtype(cfg.moment_dtype)

        def upd(p, g, m, v):
            m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
            m_new = b1 * m32 + (1 - b1) * g
            v_new = b2 * v32 + (1 - b2) * g * g
            mhat = m_new / (1 - b1 ** step.astype(jnp.float32))
            vhat = v_new / (1 - b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
                p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                    m_new.astype(mdt), v_new.astype(mdt))

        new_p, new_m, new_v = _map_multi(upd, 3, params, grads,
                                         state.m, state.v)
        return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm,
                                                     "lr": lr}

    if cfg.kind == "adafactor":
        eps = 1e-30
        decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

        def upd(p, g, r, c):
            g32 = g.astype(jnp.float32)
            if p.ndim < 2:
                v_new = decay * r + (1 - decay) * (g32 * g32)
                delta = g32 / jnp.sqrt(v_new + eps)
                return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                        v_new, c)
            r_new = decay * r + (1 - decay) * jnp.mean(g32 * g32, axis=-1)
            c_new = decay * c + (1 - decay) * jnp.mean(g32 * g32, axis=-2)
            rc = r_new / jnp.maximum(jnp.mean(r_new, axis=-1, keepdims=True),
                                     eps)
            vhat = rc[..., None] * c_new[..., None, :]
            delta = g32 / jnp.sqrt(vhat + eps)
            return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                    r_new, c_new)

        new_p, new_m, new_v = _map_multi(upd, 3, params, grads,
                                         state.m, state.v)
        return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm,
                                                     "lr": lr}
    raise ValueError(cfg.kind)
