"""llama4-maverick-400b-a17b [moe].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts top-1.
Llama-4 interleaves dense and MoE FFN layers (interleave step 2), which is also
what makes the totals work out: 24 MoE layers x 128 x 3*5120*8192 ~= 386B plus
dense/attention/embedding ~= 400B total, ~17B active.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""

from repro.configs.base import ATTN, ATTN_MOE, ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=(ATTN, ATTN_MOE),
    num_experts=128,
    experts_per_token=1,
    mlp_activation="silu",
    rope_theta=500000.0,
)
