"""xlstm-350m [ssm].

24L d_model=1024 4H d_ff=0 vocab=50304 — alternating sLSTM + mLSTM blocks
(xLSTM, arXiv:2405.04517).  No separate FFN (d_ff=0): each xLSTM block carries
its own up/down projection.  Sub-quadratic: state-based decode, runs long_500k.
[arXiv:2405.04517; unverified]
"""

from repro.configs.base import MLSTM, SLSTM, ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    block_pattern=(SLSTM, MLSTM),
    tie_embeddings=True,
)
