"""smollm-360m [dense].

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152 — llama architecture,
small.  [hf:HuggingFaceTB/SmolLM-135M; hf]
"""

from repro.configs.base import ATTN, ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    block_pattern=(ATTN,),
    mlp_activation="silu",
    rope_theta=10000.0,
    tie_embeddings=True,
)
