"""llama-3.2-vision-90b [vlm].

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256 — cross-attention
image layers every 5th layer (20 of 100).  The vision encoder is a STUB:
``input_specs()`` supplies precomputed patch embeddings of shape
(batch, num_image_tokens, d_model).  [hf:meta-llama/Llama-3.2-11B-Vision;
unverified]
"""

from repro.configs.base import ATTN, XATTN, ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    block_pattern=(ATTN, ATTN, ATTN, ATTN, XATTN),
    mlp_activation="silu",
    rope_theta=500000.0,
    num_image_tokens=1024,
)
