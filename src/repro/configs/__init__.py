"""Config registry: ``get_config(arch_id)`` resolves the exact assigned config.

Arch ids use the assignment spelling (e.g. ``llama4-maverick-400b-a17b``);
module names use underscores.
"""

from __future__ import annotations

import importlib

from repro.configs.base import SHAPE_NAMES, SHAPES, ArchConfig, ShapeConfig

_ARCH_MODULES = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "dbrx-132b": "dbrx_132b",
    "xlstm-350m": "xlstm_350m",
    "granite-8b": "granite_8b",
    "smollm-360m": "smollm_360m",
    "gemma-2b": "gemma_2b",
    "qwen3-32b": "qwen3_32b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "musicgen-large": "musicgen_large",
    # The paper's own serving backend (not part of the assigned matrix).
    "gemma3-4b-edge": "gemma3_4b_edge",
}

# The ten assigned architectures (dry-run matrix rows).
ARCH_NAMES = tuple(n for n in _ARCH_MODULES if n != "gemma3-4b-edge")
ALL_ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def matrix_cells(include_skips: bool = False):
    """Yield (arch, shape) cells of the 10x4 assignment matrix.

    With ``include_skips=False`` (default) the 8 structural long_500k skips for
    pure full-attention archs are omitted (32 runnable cells).
    """
    for arch_name in ARCH_NAMES:
        cfg = get_config(arch_name)
        for shape_name in SHAPE_NAMES:
            if include_skips or cfg.supports_shape(shape_name):
                yield arch_name, shape_name


__all__ = [
    "ArchConfig",
    "ShapeConfig",
    "SHAPES",
    "SHAPE_NAMES",
    "ARCH_NAMES",
    "ALL_ARCH_NAMES",
    "get_config",
    "get_shape",
    "matrix_cells",
]
