"""gemma-2b [dense].

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000 — GeGLU MLP,
head_dim=256 (attn_dim 2048), multi-query attention.  [arXiv:2403.08295; hf]
"""

from repro.configs.base import ATTN, ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    block_pattern=(ATTN,),
    mlp_activation="gelu",
    rope_theta=10000.0,
    tie_embeddings=True,
)
