"""gemma3-4b-edge [dense] — the paper's own serving backend.

Clairvoyant's end-to-end experiments run Ollama with Gemma3:4b (and
Llama3.1:8b, covered by granite-8b's llama-architecture config).  This config
mirrors Gemma3-4b's published text stack: 34L d_model=2560 8H (GQA kv=4)
head_dim=256 d_ff=10240 vocab=262144.  Used by the serving examples and the
service-time calibration; not part of the assigned 10-arch dry-run matrix.
"""

from repro.configs.base import ATTN, ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b-edge",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    block_pattern=(ATTN,),
    mlp_activation="gelu",
    rope_theta=1000000.0,
    tie_embeddings=True,
)
