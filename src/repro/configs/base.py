"""Architecture and shape configuration for the repro framework.

Every assigned architecture is described by an :class:`ArchConfig`.  The config
is a frozen dataclass so it can be hashed and used as a jit static argument.

Layer stacks are expressed as a *block pattern*: the smallest repeating unit of
heterogeneous blocks (e.g. Jamba's ``7×mamba + 1×attn``).  The full model is
``pattern × repeats`` and the runtime scans over repeats, keeping HLO size (and
compile time) independent of depth.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple

# Block kinds understood by models/transformer.py
ATTN = "attn"            # self-attention + dense MLP
ATTN_MOE = "attn_moe"    # self-attention + MoE MLP
XATTN = "xattn"          # cross-attention (VLM) + dense MLP
MAMBA = "mamba"          # selective-SSM block + dense MLP
MAMBA_MOE = "mamba_moe"  # selective-SSM block + MoE MLP
SLSTM = "slstm"          # xLSTM scalar-memory block
MLSTM = "mlstm"          # xLSTM matrix-memory block

BLOCK_KINDS = (ATTN, ATTN_MOE, XATTN, MAMBA, MAMBA_MOE, SLSTM, MLSTM)

SUBQUADRATIC_KINDS = (MAMBA, MAMBA_MOE, SLSTM, MLSTM)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

SHAPE_NAMES = tuple(SHAPES)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # Repeating block pattern; len(block_pattern) must divide num_layers.
    block_pattern: Tuple[str, ...] = (ATTN,)

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # MLP / attention details
    mlp_activation: str = "silu"  # silu (SwiGLU) | gelu (GeGLU)
    qk_norm: bool = False
    rope_theta: float = 500000.0
    logit_softcap: float = 0.0

    # SSM (mamba) details
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2

    # VLM
    num_image_tokens: int = 0    # length of precomputed patch-embedding sequence
    # Audio
    audio_frontend: bool = False  # inputs are precomputed frame embeddings

    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # Shape applicability -------------------------------------------------
    # Pure full-attention archs skip long_500k (needs sub-quadratic attention).
    def supports_shape(self, shape_name: str) -> bool:
        if shape_name == "long_500k":
            return self.subquadratic
        return True

    @property
    def subquadratic(self) -> bool:
        """True if the sequence-mixing stack is sub-quadratic (SSM / hybrid).

        A hybrid with a small attention fraction still decodes a 500k context in
        O(seq) bandwidth per token (linear, not quadratic), so hybrids qualify.
        """
        return any(k in SUBQUADRATIC_KINDS for k in self.block_pattern)

    @property
    def pattern_repeats(self) -> int:
        assert self.num_layers % len(self.block_pattern) == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern length {len(self.block_pattern)}"
        )
        return self.num_layers // len(self.block_pattern)

    @property
    def attn_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    # Parameter accounting -------------------------------------------------
    def param_count(self) -> int:
        """Exact parameter count of the model as constructed by models/model.py."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        total = 0
        if not self.audio_frontend:
            total += v * d  # input embedding (audio uses the frame stub)
        if not self.tie_embeddings:
            total += v * d  # lm head
        total += d  # final norm
        for kind in self.block_pattern:
            total += self._block_params(kind) * self.pattern_repeats
        return total

    def _block_params(self, kind: str) -> int:
        d, ff = self.d_model, self.d_ff
        attn = (
            d * self.attn_dim          # Wq
            + 2 * d * self.kv_dim      # Wk, Wv
            + self.attn_dim * d        # Wo
            + d                        # pre-norm
            + (2 * self.head_dim if self.qk_norm else 0)
        )
        mlp = 3 * d * ff + d if ff else 0  # gate, up, down + pre-norm
        moe = 0
        if kind in (ATTN_MOE, MAMBA_MOE):
            moe = self.num_experts * 3 * d * ff + d * self.num_experts + d
            mlp = 0
        mamba = 0
        if kind in (MAMBA, MAMBA_MOE):
            attn = 0  # mamba blocks replace attention entirely
            di, n = self.d_inner, self.ssm_state_dim
            mamba = (
                2 * d * di            # in_proj (x and z branches)
                + di * self.ssm_conv_width
                + di * (n * 2 + 1)    # B, C, dt projections (x -> B,C,dt)
                + di * n              # A_log
                + di                  # D skip
                + di                  # dt bias
                + di * d              # out_proj
                + d                   # pre-norm
            )
        if kind == MLSTM:
            ad = self.attn_dim
            attn = (
                3 * d * ad                # q, k, v projections
                + 2 * d * self.num_heads  # i, f gate projections (per head)
                + ad * d                  # out proj
                + 2 * d                   # pre-norm + norm2
                + 2 * d * d               # up/down proj block
            )
            mlp = 0
        if kind == SLSTM:
            ad, hd = self.attn_dim, self.head_dim
            attn = (
                4 * d * ad                       # z,i,f,o input projections
                + 4 * self.num_heads * hd * hd   # block-diagonal recurrent
                + 4 * ad                         # gate biases
                + ad * d                         # out proj
                + 2 * d                          # pre-norm + norm2
                + 2 * d * d                      # up/down proj block
            )
            mlp = 0
        return attn + mlp + moe + mamba

    def active_param_count(self) -> int:
        """Active parameters per token (MoE archs activate experts_per_token)."""
        if not self.num_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        per_moe_block_inactive = (self.num_experts - self.experts_per_token) * 3 * d * ff
        n_moe_blocks = sum(
            1 for k in self.block_pattern if k in (ATTN_MOE, MAMBA_MOE)
        ) * self.pattern_repeats
        return self.param_count() - n_moe_blocks * per_moe_block_inactive

    # Reduced config for CPU smoke tests ------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config: identical block pattern, small dims."""
        num_heads = min(self.num_heads, 4)
        num_kv = max(1, min(self.num_kv_heads, num_heads))
        # preserve MQA/GQA structure
        while num_heads % num_kv:
            num_kv -= 1
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=len(self.block_pattern),
            d_model=64,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            num_image_tokens=16 if self.num_image_tokens else 0,
            ssm_state_dim=4,
            dtype="float32",
        )
