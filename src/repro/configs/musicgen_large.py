"""musicgen-large [audio].

48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048 — decoder-only
transformer over EnCodec tokens.  The EnCodec frontend is a STUB:
``input_specs()`` supplies precomputed frame embeddings (batch, seq, d_model);
the LM head predicts the 2048-way codebook.  [arXiv:2306.05284; hf]
"""

from repro.configs.base import ATTN, ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    block_pattern=(ATTN,),
    mlp_activation="gelu",
    rope_theta=10000.0,
    audio_frontend=True,
)
