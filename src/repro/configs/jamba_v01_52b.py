"""jamba-v0.1-52b [hybrid].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16 experts top-2.
Mamba + attention at a 1:7 ratio (one attention layer per 8), MoE on every
other layer.  Sub-quadratic overall: Mamba layers decode from O(1) state; the
four attention layers hold the (sharded) KV cache.  Runs long_500k.
[arXiv:2403.19887; hf]
"""

from repro.configs.base import ATTN, MAMBA, MAMBA_MOE, ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    # 8-layer period: 7 mamba (4 of them MoE) + 1 attention.  MoE every other
    # layer as in Jamba v0.1.
    block_pattern=(MAMBA, MAMBA_MOE, MAMBA, MAMBA_MOE, ATTN, MAMBA_MOE, MAMBA, MAMBA_MOE),
    num_experts=16,
    experts_per_token=2,
    mlp_activation="silu",
    ssm_state_dim=16,
    ssm_conv_width=4,
    ssm_expand=2,
)
