"""Mamba-style selective SSM block (Jamba's sequence mixer).

TPU adaptation: the CUDA selective-scan kernel is replaced by a
chunked-parallel scan — ``lax.scan`` over sequence chunks (recurrent carry =
SSM state) with ``lax.associative_scan`` inside each chunk.  This keeps the
working set at O(batch * chunk * d_inner * N) (VMEM-friendly) and the
sequential depth at S/chunk, instead of either a full O(S) recurrence (serial,
hostile to the MXU) or a full-sequence associative scan (O(S * d_inner * N)
live memory).

Simplification vs. the reference CUDA implementation: dt is a scalar per token
(projected from x) plus a learned per-channel bias, rather than a low-rank
per-channel projection.  Noted in DESIGN.md; the state-space recurrence,
selective B/C, conv stem, and gating match Mamba.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dtype_of, init_dense, rmsnorm
from repro.sharding import constrain

CHUNK = 128


def init_mamba(cfg, key):
    dt_ = dtype_of(cfg)
    ks = jax.random.split(key, 5)
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state_dim
    w = cfg.ssm_conv_width
    params = {
        "norm": jnp.ones((d,), dtype=dt_),
        "in_proj": init_dense(ks[0], d, 2 * di, dt_),           # x and z branches
        "conv_w": (jax.random.normal(ks[1], (w, di)) * w ** -0.5).astype(dt_),
        "x_proj": init_dense(ks[2], di, 2 * n + 1, dt_),        # -> B, C, dt
        "A_log": jnp.log(1.0 + jnp.arange(1, n + 1, dtype=jnp.float32))
        * jnp.ones((di, 1), jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "out_proj": init_dense(ks[3], di, d, dt_, scale=di ** -0.5),
    }
    axes = {
        "norm": ("embed",),
        "in_proj": ("embed_w", "ssm_inner"),
        "conv_w": ("conv", "ssm_inner"),
        "x_proj": ("ssm_inner", None),
        "A_log": ("ssm_inner", "ssm_state"),
        "D": ("ssm_inner",),
        "dt_bias": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed_w"),
    }
    return params, axes


def _ssm_coeffs_chunk(p, xc, bcd):
    """SSM coefficients for ONE chunk.  xc: (B,Ck,di); bcd: (B,Ck,2N+1).

    The (B, S, di, N) discretised tensors must never exist for the whole
    sequence — at jamba's train_4k cell that is ~0.5 PB.  They are built
    chunk-by-chunk inside the scan and die with the chunk.
    """
    n = (bcd.shape[-1] - 1) // 2
    Bmat, Cmat, dt_raw = bcd[..., :n], bcd[..., n:2 * n], bcd[..., -1:]
    # dt: scalar-per-token projection plus a learned per-channel bias
    dt = jax.nn.softplus(dt_raw)[..., None] \
        + jax.nn.softplus(p["dt_bias"])[None, None, :, None]  # (B,Ck,di,1)
    A = -jnp.exp(p["A_log"])  # (di, N), negative
    dA = jnp.exp(dt * A[None, None])                           # (B,Ck,di,N)
    x32 = xc.astype(jnp.float32)
    dBx = dt * Bmat[:, :, None, :] * x32[..., None]            # (B,Ck,di,N)
    return dA, dBx, Cmat


def _chunk_scan(dA, dBx, h0):
    """Associative scan within a chunk.  dA,dBx: (B,Ck,di,N); h0: (B,di,N).

    h_t = dA_t * h_{t-1} + dBx_t.  Returns (h_all (B,Ck,di,N), h_last).
    """
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    a, b = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    h_all = a * h0[:, None] + b
    return h_all, h_all[:, -1]


def mamba_mix(p, x_in, conv_state=None, ssm_state=None):
    """Core mixer.  x_in: (B, S, d_model) already normed.

    Returns (y (B,S,d_model-projected? no: di->out in caller), new states).
    Here we return the di-space output BEFORE out_proj.
    """
    B, S, _ = x_in.shape
    xz = dense(x_in, p["in_proj"])
    xr, z = jnp.split(xz, 2, axis=-1)          # (B,S,di) each
    xr = constrain(xr, "batch", "seq", "ssm_inner")
    di = xr.shape[-1]
    w = p["conv_w"].shape[0]

    # causal depthwise conv, width w
    if conv_state is None:
        pad = jnp.zeros((B, w - 1, di), xr.dtype)
    else:
        pad = conv_state.astype(xr.dtype)
    xp = jnp.concatenate([pad, xr], axis=1)    # (B, S+w-1, di)
    xc = sum(xp[:, i:i + S, :] * p["conv_w"][i][None, None, :] for i in range(w))
    xc = jax.nn.silu(xc)
    new_conv_state = xp[:, -(w - 1):, :]

    bcd = dense(xc, p["x_proj"]).astype(jnp.float32)   # (B,S,2N+1) — small
    n = (bcd.shape[-1] - 1) // 2
    h0 = jnp.zeros((B, di, n), jnp.float32) if ssm_state is None else ssm_state

    # scan over chunks of the sequence; coefficients built per chunk
    chunk = min(CHUNK, S)
    npad = (-S) % chunk
    if npad:
        xc_p = jnp.pad(xc, ((0, 0), (0, npad), (0, 0)))
        bcd_p = jnp.pad(bcd, ((0, 0), (0, npad), (0, 0)))
    else:
        xc_p, bcd_p = xc, bcd
    nchunks = (S + npad) // chunk
    # keep the chunk-index dim unsharded (see models/attention.py note)
    xc_c = xc_p.reshape(B, nchunks, chunk, di).transpose(1, 0, 2, 3)
    xc_c = constrain(xc_c, None, "batch", None, "ssm_inner")
    bcd_c = bcd_p.reshape(B, nchunks, chunk, 2 * n + 1).transpose(1, 0, 2, 3)
    bcd_c = constrain(bcd_c, None, "batch", None, None)

    # remat the chunk body: backward would otherwise hold every chunk's full
    # (B, chunk, di, N) discretised history at once
    @functools.partial(jax.checkpoint, prevent_cse=False)
    def step(h, xs):
        xcc, bcdc = xs
        da, dbx, cmat = _ssm_coeffs_chunk(p, xcc, bcdc)
        h_all, h_last = _chunk_scan(da, dbx, h)
        yc = jnp.einsum("bsdn,bsn->bsd", h_all, cmat)
        yc = yc + p["D"][None, None, :] * xcc.astype(jnp.float32)
        return h_last, yc.astype(x_in.dtype)

    h_last, y_chunks = jax.lax.scan(step, h0, (xc_c, bcd_c))
    y_chunks = constrain(y_chunks, None, "batch", None, "ssm_inner")
    y = y_chunks.transpose(1, 0, 2, 3).reshape(B, S + npad, di)[:, :S]
    y = y * jax.nn.silu(z)
    return y, (new_conv_state, h_last)


def mamba_block(cfg, p, x, *, mode: str, cache=None):
    """Full block with pre-norm, residual.  Returns (x_out, new_cache)."""
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    if mode == "train":
        y, _ = mamba_mix(p, h)
        new_cache = None
    elif mode == "prefill":
        y, (conv_s, ssm_s) = mamba_mix(p, h)
        new_cache = {"conv": conv_s, "ssm": ssm_s}
    else:  # decode: x is (B, 1, D)
        y, (conv_s, ssm_s) = mamba_mix(
            p, h, conv_state=cache["conv"], ssm_state=cache["ssm"])
        new_cache = {"conv": conv_s, "ssm": ssm_s}
    return x + dense(y, p["out_proj"]), new_cache
