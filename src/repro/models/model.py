"""The LM: embedding, scanned block stack, head, losses, prefill/decode.

Pure-functional API; ``LM`` only holds the config.  All functions are
jit/pjit-compatible.  Batches are dicts:

* text archs:  {"tokens": (B,S) i32, "labels": (B,S) i32}
* vlm:         + {"image_embeds": (B, T_img, D) bf16}
* audio:       {"frames": (B,S,D) bf16, "labels": (B,S) i32}  (frontend stub)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ATTN_MOE, MAMBA, MAMBA_MOE, MLSTM, SLSTM, XATTN, ArchConfig
from repro.models import transformer as tf
from repro.models.layers import dense, dtype_of, init_dense, rmsnorm
from repro.sharding import constrain

LOSS_CHUNK = 512  # sequence-chunked cross entropy (never materialize f32 logits)
AUX_LOSS_WEIGHT = 0.01


def init_model(cfg: ArchConfig, key) -> tuple[Dict[str, Any], Dict[str, Any]]:
    """Returns (params, axes).  Block params are stacked over repeats."""
    dt = dtype_of(cfg)
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    params: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}

    if not cfg.audio_frontend:
        params["embed"] = (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model))
                           * cfg.d_model ** -0.5).astype(dt)
        axes["embed"] = ("vocab", "embed_w")

    def init_rep(k):
        ks = jax.random.split(k, len(cfg.block_pattern))
        return tuple(tf.init_block(cfg, kind, ks[i])[0]
                     for i, kind in enumerate(cfg.block_pattern))

    rep_keys = jax.random.split(k_blocks, cfg.pattern_repeats)
    params["blocks"] = jax.vmap(init_rep)(rep_keys)
    from repro.sharding.rules import is_axes_leaf
    block_axes = _block_axes(cfg)
    axes["blocks"] = jax.tree.map(lambda a: (None, *a), block_axes,
                                  is_leaf=is_axes_leaf)

    params["final_norm"] = jnp.ones((cfg.d_model,), dtype=dt)
    axes["final_norm"] = ("embed",)
    if not cfg.tie_embeddings:
        params["head"] = init_dense(k_head, cfg.d_model, cfg.vocab_size, dt)
        axes["head"] = ("embed_w", "vocab")
    return params, axes


def _block_axes(cfg):
    """Axes for one repeat of the pattern (static; no array allocation)."""
    captured = {}

    def f(key):
        ks = jax.random.split(key, len(cfg.block_pattern))
        out, ax = [], []
        for i, kind in enumerate(cfg.block_pattern):
            p, a = tf.init_block(cfg, kind, ks[i])
            out.append(p)
            ax.append(a)
        captured["axes"] = tuple(ax)
        return tuple(out)

    jax.eval_shape(f, jax.random.PRNGKey(0))
    return captured["axes"]


class LM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- init --------------------------------------------------------------
    def init(self, key):
        return init_model(self.cfg, key)[0]

    def abstract_params(self):
        """(ShapeDtypeStruct tree, axes tree) with no allocation."""
        captured = {}

        def f(key):
            p, a = init_model(self.cfg, key)
            captured["axes"] = a
            return p

        shapes = jax.eval_shape(f, jax.random.key(0))
        return shapes, captured["axes"]

    def param_count_actual(self) -> int:
        shapes, _ = self.abstract_params()
        import math
        return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))

    # -- embedding / head ----------------------------------------------------
    def _embed_in(self, params, batch):
        cfg = self.cfg
        if cfg.audio_frontend:
            x = batch["frames"].astype(dtype_of(cfg))
        else:
            x = params["embed"][batch["tokens"]]
        return constrain(x, "batch", "seq", "embed")

    def _head(self, params, x):
        cfg = self.cfg
        h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        w = params["embed"].T if cfg.tie_embeddings else params["head"]
        logits = jnp.matmul(h, w, preferred_element_type=jnp.float32)
        return constrain(logits.astype(jnp.float32), "batch", "seq", "vocab")

    # -- training forward / loss --------------------------------------------
    def forward(self, params, batch, remat: bool = True):
        cfg = self.cfg
        x = self._embed_in(params, batch)
        img = batch.get("image_embeds")
        x, _, aux = tf.run_stack(cfg, params["blocks"], x, mode="train",
                                 image_embeds=img, remat=remat)
        return self._head(params, x), aux

    def loss(self, params, batch, remat: bool = True):
        """Sequence-chunked next-token CE + MoE aux loss.

        The f32 logits for (B,S,V) are never materialized: we scan over
        sequence chunks, rematerializing each chunk's logits in the backward
        pass.  This is the memory-dominant term for large-vocab archs.
        """
        cfg = self.cfg
        x = self._embed_in(params, batch)
        img = batch.get("image_embeds")
        x, _, aux = tf.run_stack(cfg, params["blocks"], x, mode="train",
                                 image_embeds=img, remat=remat)
        h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        w = params["embed"].T if cfg.tie_embeddings else params["head"]
        labels = batch["labels"]
        B, S = labels.shape

        chunk = min(LOSS_CHUNK, S)
        pad = (-S) % chunk
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        nc = (S + pad) // chunk
        hc = h.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)
        lc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def chunk_loss(h_chunk, l_chunk):
            logits = jnp.matmul(h_chunk, w, preferred_element_type=jnp.float32)
            logits = logits.astype(jnp.float32)
            valid = l_chunk >= 0
            lse = jax.nn.logsumexp(logits, axis=-1)
            tgt = jnp.take_along_axis(
                logits, jnp.maximum(l_chunk, 0)[..., None], axis=-1)[..., 0]
            nll = jnp.where(valid, lse - tgt, 0.0)
            return nll.sum(), valid.sum()

        def body(carry, xs):
            tot, cnt = carry
            s, n = chunk_loss(*xs)
            return (tot + s, cnt + n), None

        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
            (hc, lc))
        ce = tot / jnp.maximum(cnt, 1).astype(jnp.float32)
        return ce + AUX_LOSS_WEIGHT * aux

    # -- serving -------------------------------------------------------------
    def prefill(self, params, batch, pad_to: Optional[int] = None,
                prompt_len=None, caches=None, fill_to=None):
        """Full-prompt forward building the decode cache.

        Returns (last_logits (B,V), caches).  Attention KV caches are padded
        to ``pad_to`` slots if given.

        ``caches`` switches to *extend* (continuation) prefill: the batch is
        a suffix appended at the supplied caches' fill level (the paged
        engine's preemption resume re-prefills only the generated tokens).
        ``fill_to`` then overrides the post-prefill fill level (base fill +
        suffix length rather than the suffix length alone).

        ``prompt_len`` (optional dynamic scalar) enables *bucketed* prefill:
        the token batch may be right-padded to a bucket length; logits are
        gathered at position ``prompt_len - 1`` and the attention fill level
        ``t`` is reset to ``prompt_len`` so decode overwrites the pad slots
        in order.  Because prefill attention is causal and pads sit at the
        end, positions < prompt_len never attend a pad slot, and decode masks
        slots > t — pad KV is dead until overwritten.  Only valid for padded
        inputs on architectures whose per-position state is causal-local
        (pure attention stacks); SSM/xLSTM recurrences would fold pad tokens
        into their state, so callers pass exact-length inputs there.

        ``prompt_len`` may also be a (B,) vector — mixed-length prompts
        sharing one padded batch (the micro-batching lane back-fill): each
        row's logits come from its own last position and the caches carry
        per-sequence fill levels (``t`` (repeats, B)), the layout the
        per-lane decode path consumes.
        """
        cfg = self.cfg
        x = self._embed_in(params, batch)
        img = batch.get("image_embeds")
        x, caches, _ = tf.run_stack(cfg, params["blocks"], x, mode="prefill",
                                    caches=caches, image_embeds=img,
                                    remat=False)
        if prompt_len is None:
            last = x[:, -1:, :]
        else:
            pl = jnp.asarray(prompt_len, jnp.int32)
            if pl.ndim == 0:
                last = jax.lax.dynamic_slice_in_dim(x, pl - 1, 1, axis=1)
            else:
                last = jnp.take_along_axis(x, (pl - 1)[:, None, None],
                                           axis=1)
            caches = _set_fill(cfg, caches, pl if fill_to is None else fill_to)
        logits = self._head(params, last)[:, 0]
        if pad_to is not None:
            caches = _pad_kv(cfg, caches, pad_to)
        return logits, caches

    def decode_step(self, params, caches, batch_step):
        """One decode step.

        batch_step: {"tokens": (B,1)} or {"frames": (B,1,D)}; cache slot/mask
        positions ride inside the attention caches ("t").
        Returns (logits (B,V), new_caches).
        """
        cfg = self.cfg
        x = self._embed_in(params, batch_step)
        img = batch_step.get("image_embeds")
        x, caches, _ = tf.run_stack(cfg, params["blocks"], x, mode="decode",
                                    caches=caches, image_embeds=img,
                                    remat=False)
        logits = self._head(params, x)[:, 0]
        return logits, caches

    def verify_step(self, params, caches, batch_step):
        """Speculative verification: score W consecutive positions in one
        dispatch.

        batch_step: {"tokens": (B, W)} — the pending token plus K = W-1
        draft tokens per sequence.  Each token is written into the KV cache
        at its absolute position (fill level ``t`` + offset) and attends
        its own causal prefix, so position ``w``'s logits are the logits
        serial decode would produce after consuming the first ``w + 1``
        tokens.  The cache fill level is *not* advanced — callers commit
        the accepted prefix by resetting ``t`` (models/attention.py
        mode="verify"), which is also how rejected drafts roll back.
        Returns (logits (B, W, V), caches).
        """
        cfg = self.cfg
        x = self._embed_in(params, batch_step)
        x, caches, _ = tf.run_stack(cfg, params["blocks"], x, mode="verify",
                                    caches=caches, remat=False)
        logits = self._head(params, x)
        return logits, caches

    # -- cache construction ---------------------------------------------------
    def init_cache(self, batch_size: int, max_len: int, t0: int = 0):
        """Zero caches (stacked over repeats) for decode-from-scratch or as
        dry-run input specs.  ``t0`` sets the current fill level.

        Attention caches are *ring buffers* of ``max_len`` slots: decode
        writes the step-``t`` KV at slot ``t % max_len`` and attends slots
        ``<= t`` (all of them once wrapped), so a request is never
        reallocated a larger cache when generation approaches the buffer
        end — capacity bounds the attention window, not the output length.
        ``t`` is the absolute fill level (RoPE positions stay absolute).
        """
        cfg = self.cfg
        dt = dtype_of(cfg)
        rep = cfg.pattern_repeats
        B, KV, hd = batch_size, cfg.num_kv_heads, cfg.head_dim
        caches = []
        for kind in cfg.block_pattern:
            if kind in (ATTN, ATTN_MOE):
                caches.append({
                    "k": jnp.zeros((rep, B, max_len, KV, hd), dt),
                    "v": jnp.zeros((rep, B, max_len, KV, hd), dt),
                    "t": jnp.full((rep,), t0, jnp.int32),
                })
            elif kind == XATTN:
                caches.append({
                    "k": jnp.zeros((rep, B, cfg.num_image_tokens, KV, hd), dt),
                    "v": jnp.zeros((rep, B, cfg.num_image_tokens, KV, hd), dt),
                })
            elif kind in (MAMBA, MAMBA_MOE):
                caches.append({
                    "conv": jnp.zeros((rep, B, cfg.ssm_conv_width - 1,
                                       cfg.d_inner), dt),
                    "ssm": jnp.zeros((rep, B, cfg.d_inner, cfg.ssm_state_dim),
                                     jnp.float32),
                })
            elif kind == MLSTM:
                H = cfg.num_heads
                caches.append({
                    "C": jnp.zeros((rep, B, H, hd, hd), jnp.float32),
                    "n": jnp.zeros((rep, B, H, hd), jnp.float32),
                    "m": jnp.full((rep, B, H), -1e30, jnp.float32),
                })
            elif kind == SLSTM:
                H = cfg.num_heads
                z = jnp.zeros((rep, B, H, hd), jnp.float32)
                caches.append({"c": z, "n": z, "h": z,
                               "m": jnp.full((rep, B, H, hd), -1e30,
                                             jnp.float32)})
            else:
                raise ValueError(kind)
        return tuple(caches)

    def init_paged_cache(self, batch_size: int, max_len: int,
                         n_pages: int, page_size: int):
        """Zero block-paged caches (serving/paging.py).

        Attention K/V live in a shared physical pool of ``n_pages`` pages
        (``page_size`` slots each, physical page 0 pinned as the trash
        page) instead of per-lane ring buffers; each lane addresses its
        logical window of ``max_len`` slots through a per-lane block
        table ``bt`` (zeros = unallocated, pointing at trash) and its own
        fill level ``t``.  Every layer shares the lane's table — a
        physical page index selects the same page in every layer's pool,
        so the allocator hands out layer-agnostic page ids.  Attention-
        only stacks: recurrent blocks have no paged analogue here.
        """
        cfg = self.cfg
        dt = dtype_of(cfg)
        rep = cfg.pattern_repeats
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        if max_len % page_size:
            raise ValueError(f"max_len {max_len} not a multiple of "
                             f"page_size {page_size}")
        P = max_len // page_size
        caches = []
        for kind in cfg.block_pattern:
            if kind not in (ATTN, ATTN_MOE):
                raise ValueError(
                    f"block-paged KV needs a pure-attention stack, got {kind}")
            caches.append({
                "k": jnp.zeros((rep, n_pages, page_size, KV, hd), dt),
                "v": jnp.zeros((rep, n_pages, page_size, KV, hd), dt),
                "t": jnp.zeros((rep, batch_size), jnp.int32),
                "bt": jnp.zeros((rep, batch_size, P), jnp.int32),
            })
        return tuple(caches)

    def cache_axes(self):
        """Logical axes tree matching init_cache output."""
        cfg = self.cfg
        axes = []
        for kind in cfg.block_pattern:
            if kind in (ATTN, ATTN_MOE):
                axes.append({
                    "k": (None, "batch", "kv_seq", "kv_heads", "head_dim"),
                    "v": (None, "batch", "kv_seq", "kv_heads", "head_dim"),
                    "t": (None,),
                })
            elif kind == XATTN:
                axes.append({
                    "k": (None, "batch", "image_seq", "kv_heads", "head_dim"),
                    "v": (None, "batch", "image_seq", "kv_heads", "head_dim"),
                })
            elif kind in (MAMBA, MAMBA_MOE):
                axes.append({
                    "conv": (None, "batch", "conv", "ssm_inner"),
                    "ssm": (None, "batch", "ssm_inner", "ssm_state"),
                })
            elif kind == MLSTM:
                axes.append({
                    "C": (None, "batch", "heads", "head_dim", "head_dim"),
                    "n": (None, "batch", "heads", "head_dim"),
                    "m": (None, "batch", "heads"),
                })
            elif kind == SLSTM:
                a = (None, "batch", "heads", "head_dim")
                axes.append({"c": a, "n": a, "h": a, "m": a})
        return tuple(axes)


def _set_fill(cfg, caches, t):
    """Reset every attention cache's fill level to ``t``: a dynamic scalar
    (shared across the batch, the serial path) or a (B,) vector (per-
    sequence levels — the cache ``t`` becomes (repeats, B), the layout
    the per-lane decode path consumes)."""
    out = []
    for kind, c in zip(cfg.block_pattern, caches):
        if kind in (ATTN, ATTN_MOE):
            c = dict(c)
            if jnp.ndim(t) == 0:
                c["t"] = jnp.full_like(c["t"], t)
            else:
                c["t"] = jnp.broadcast_to(t[None, :],
                                          c["t"].shape + t.shape)
        out.append(c)
    return tuple(out)


def _pad_kv(cfg, caches, pad_to: int):
    out = []
    for kind, c in zip(cfg.block_pattern, caches):
        if kind in (ATTN, ATTN_MOE) and c["k"].shape[2] < pad_to:
            extra = pad_to - c["k"].shape[2]
            c = dict(c)
            c["k"] = jnp.pad(c["k"], ((0, 0), (0, 0), (0, extra), (0, 0), (0, 0)))
            c["v"] = jnp.pad(c["v"], ((0, 0), (0, 0), (0, extra), (0, 0), (0, 0)))
        out.append(c)
    return tuple(out)
