"""Foundational layers: RMSNorm, RoPE, gated MLPs, init helpers.

Every ``init_*`` function returns ``(params, axes)`` — two pytrees of
identical structure where ``axes`` leaves are tuples of logical axis names
consumed by ``repro.sharding`` (see rules.py).  Keeping the annotation next to
the initializer is what makes adding an architecture a one-file change.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import constrain


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def init_dense(key, in_dim: int, out_dim: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


# §Perf flag: route dense() through a custom VJP whose backward matmuls take
# bf16 operands with f32 accumulation — keeps the FSDP weight-gradient
# all-gathers on bf16 bytes instead of pre-converted f32 (2x wire + HBM).
PERF = {"bf16_grad_matmuls": False}


@jax.custom_vjp
def _dense_bf16vjp(x, w):
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)


def _dense_fwd(x, w):
    return _dense_bf16vjp(x, w), (x, w)


def _dense_bwd(res, g):
    x, w = res
    gb = g.astype(w.dtype)
    dx = jnp.matmul(gb, w.T, preferred_element_type=jnp.float32).astype(x.dtype)
    x2 = x.reshape(-1, x.shape[-1])
    g2 = gb.reshape(-1, gb.shape[-1])
    dw = jnp.matmul(x2.T, g2, preferred_element_type=jnp.float32)
    return dx, dw.astype(w.dtype)


_dense_bf16vjp.defvjp(_dense_fwd, _dense_bwd)


def dense(x, w):
    """Matmul with f32 accumulation, result cast back to input dtype."""
    if PERF["bf16_grad_matmuls"]:
        return _dense_bf16vjp(x, w).astype(x.dtype)
    return jnp.matmul(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype):
    return jnp.ones((d,), dtype=dtype), ("embed",)


def rmsnorm(x, w, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """Apply RoPE.  x: (..., S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    # broadcast over head axis: (..., S, 1, half)
    angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(cfg, key):
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    d, ff = cfg.d_model, cfg.d_ff
    params = {
        "norm": jnp.ones((d,), dtype=dt),
        "w_gate": init_dense(k1, d, ff, dt),
        "w_up": init_dense(k2, d, ff, dt),
        "w_down": init_dense(k3, ff, d, dt, scale=ff ** -0.5),
    }
    axes = {
        "norm": ("embed",),
        "w_gate": ("embed_w", "mlp"),
        "w_up": ("embed_w", "mlp"),
        "w_down": ("mlp", "embed_w"),
    }
    return params, axes


def apply_mlp(cfg, p, x):
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    act = jax.nn.silu if cfg.mlp_activation == "silu" else jax.nn.gelu
    gate = dense(h, p["w_gate"])
    up = dense(h, p["w_up"])
    hidden = act(gate) * up
    hidden = constrain(hidden, "batch", "seq", "mlp")
    return x + dense(hidden, p["w_down"])


# ---------------------------------------------------------------------------
# Expert MLP weights (used by moe.py): stacked over the expert axis
# ---------------------------------------------------------------------------

def init_expert_mlp(cfg, key):
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    params = {
        "w_gate": (jax.random.normal(k1, (e, d, ff)) * d ** -0.5).astype(dt),
        "w_up": (jax.random.normal(k2, (e, d, ff)) * d ** -0.5).astype(dt),
        "w_down": (jax.random.normal(k3, (e, ff, d)) * ff ** -0.5).astype(dt),
    }
    axes = {
        "w_gate": ("experts", "embed_w", "expert_mlp"),
        "w_up": ("experts", "embed_w", "expert_mlp"),
        "w_down": ("experts", "expert_mlp", "embed_w"),
    }
    return params, axes
