"""Attention: GQA/MQA self-attention (train/prefill/decode) and cross-attention.

Training/prefill attention is a chunked streaming-softmax ("flash") pure-JAX
implementation: memory is O(q_chunk * kv_chunk) per step instead of O(S^2),
which is what lets the 32k-prefill and 4k-train cells fit — XLA does not do
this fusion for you.  The Pallas kernels in repro/kernels mirror this
computation for real-TPU deployment and are validated against it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dtype_of, init_dense, rmsnorm, rope
from repro.sharding import constrain

NEG_INF = -1e30

# §Perf flags (launch/perf experiments flip these; defaults = baseline).
# DECODE_CAST_F32: cast the whole KV cache to f32 before the decode einsums
# (baseline) vs native-dtype einsums with f32 accumulation only.
PERF = {"decode_cast_f32": True}


def init_attention(cfg, key, cross: bool = False):
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    params = {
        "norm": jnp.ones((d,), dtype=dt),
        "wq": init_dense(ks[0], d, cfg.attn_dim, dt),
        "wk": init_dense(ks[1], d, cfg.kv_dim, dt),
        "wv": init_dense(ks[2], d, cfg.kv_dim, dt),
        "wo": init_dense(ks[3], cfg.attn_dim, d, dt, scale=cfg.attn_dim ** -0.5),
    }
    axes = {
        "norm": ("embed",),
        "wq": ("embed_w", "qkv"),
        "wk": ("embed_w", "qkv"),
        "wv": ("embed_w", "qkv"),
        "wo": ("qkv", "embed_w"),
    }
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones((cfg.head_dim,), dtype=dt)
        params["k_norm"] = jnp.ones((cfg.head_dim,), dtype=dt)
        axes["q_norm"] = ("head_dim",)
        axes["k_norm"] = ("head_dim",)
    return params, axes


def _project_qkv(cfg, p, x, positions, use_rope: bool = True):
    """x (B,S,D) -> q (B,S,H,hd), k/v (B,S,KV,hd)."""
    B, S, _ = x.shape
    q = dense(x, p["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = dense(x, p["wk"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = dense(x, p["wv"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _group(q, num_kv):
    """(B,S,H,hd) -> (B,S,KV,G,hd) grouping query heads over KV heads."""
    B, S, H, hd = q.shape
    assert H % num_kv == 0, (H, num_kv)
    return q.reshape(B, S, num_kv, H // num_kv, hd)


def flash_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                    kv_offset: int = 0, q_chunk: int = 512, kv_chunk: int = 1024,
                    kv_len=None):
    """Chunked streaming-softmax attention.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd); GQA via head grouping.
    ``kv_len``: optional scalar — keys at absolute positions >= kv_len are
    masked out (decode with a partially filled cache).
    Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq = -(-Sq // q_chunk)
    nkv = -(-Skv // kv_chunk)
    # pad to chunk multiples
    q_pad, kv_pad = nq * q_chunk - Sq, nkv * kv_chunk - Skv
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))

    # scan axes lead: (nq, B, q_chunk, KV, G, hd) / (nkv, B, kv_chunk, KV, hd)
    # The chunk-index dim must stay UNSHARDED: left to propagation, GSPMD
    # shards it across devices and then "involuntarily fully rematerializes"
    # (replicates) every dynamic-slice in the scan.
    qg = _group(q, KV).reshape(B, nq, q_chunk, KV, G, hd) \
        .transpose(1, 0, 2, 3, 4, 5).astype(jnp.float32)
    qg = constrain(qg, None, "batch", None, None, None, None)
    kg = k.reshape(B, nkv, kv_chunk, KV, hd) \
        .transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    kg = constrain(kg, None, "batch", None, None, None)
    vg = v.reshape(B, nkv, kv_chunk, KV, hd) \
        .transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    vg = constrain(vg, None, "batch", None, None, None)

    limit = Skv if kv_len is None else kv_len

    # Nested remat: without it, the backward pass keeps every (q, kv) chunk's
    # probability block alive simultaneously (~16 GB/device at train_4k).
    # Checkpointing both scan bodies stores only the O(block) carries and
    # recomputes the probabilities in the backward sweep — the flash-attention
    # backward recurrence, expressed through jax.checkpoint.
    @functools.partial(jax.checkpoint, prevent_cse=False)
    def q_step(_, qi):
        qc, q_idx = qi  # qc: (B, qck, KV, G, hd)
        q_pos = q_offset + q_idx * q_chunk + jnp.arange(q_chunk)

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_step(carry, ki):
            m, l, acc = carry
            kc, vc, k_idx = ki
            k_pos = kv_offset + k_idx * kv_chunk + jnp.arange(kv_chunk)
            # logits: (B, KV, G, qck, kck)
            logits = jnp.einsum("bqkgh,bskh->bkgqs", qc, kc) * scale
            mask = k_pos[None, :] < limit
            if causal:
                mask = mask & (q_pos[:, None] >= k_pos[None, :])
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p, vc)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kg, vg, jnp.arange(nkv)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,KV,G,qck,hd)
        return None, out

    _, outs = jax.lax.scan(q_step, None, (qg, jnp.arange(nq)))
    # outs: (nq, B, KV, G, qck, hd) -> (B, nq*qck, KV*G, hd)
    outs = constrain(outs, None, "batch", None, None, None, None)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * q_chunk, H, hd)
    return out[:, :Sq].astype(q.dtype)


def decode_attention(q, cache_k, cache_v, t):
    """Single-position attention over a (ring-buffer) KV cache.

    q: (B, 1, H, hd); cache_k/v: (B, S, KV, hd); t: absolute fill level —
    a scalar shared by the batch (the serial path) or a (B,) vector of
    per-sequence levels (the micro-batching decode lanes, which prefill
    at different prompt lengths).  Slots <= t are attended (the current
    token's KV has been written at slot t % S).  While t < S the mask is
    the usual prefix mask; once the ring wraps (t >= S) every slot holds
    one of the S most recent tokens and ``arange(S) <= t`` is all-true,
    so the same predicate serves both regimes — no separate "wrapped"
    code path.

    With PERF["decode_cast_f32"]=False, the cache is consumed in its native
    dtype with f32 accumulation inside the einsum — the f32 cache copies
    (2x cache bytes per layer per token) disappear from the HBM stream.
    """
    B, _, H, hd = q.shape
    S, KV = cache_k.shape[1], cache_k.shape[2]
    G = H // KV
    if PERF["decode_cast_f32"]:
        qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
        k_in, v_in = cache_k.astype(jnp.float32), cache_v.astype(jnp.float32)
    else:
        qg = q.reshape(B, KV, G, hd)
        k_in, v_in = cache_k, cache_v
    logits = jnp.einsum("bkgh,bskh->bkgs", qg, k_in,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    t_b = t if jnp.ndim(t) == 0 else t[:, None, None, None]
    mask = jnp.arange(S)[None, None, None, :] <= t_b
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w.astype(v_in.dtype), v_in,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def verify_attention(q, cache_k, cache_v, t):
    """W-position attention over a (ring-buffer) KV cache — the speculative
    verification forward.

    q: (B, W, H, hd); cache_k/v: (B, S, KV, hd); t: the pre-verify fill
    level — a scalar shared by the batch or a (B,) vector of per-lane
    levels.  Query ``w`` attends slots ``<= t + w``: exactly the mask
    ``decode_attention`` applies at fill level ``t + w``, with the same
    einsum contraction layout, PERF cast handling and softmax, so row
    ``w`` of the verify output is a bitwise candidate for the serial
    decode output at that position (tests/test_speculative.py holds the
    equality end to end).
    """
    B, W, H, hd = q.shape
    S, KV = cache_k.shape[1], cache_k.shape[2]
    G = H // KV
    if PERF["decode_cast_f32"]:
        qg = q.reshape(B, W, KV, G, hd).astype(jnp.float32)
        k_in, v_in = cache_k.astype(jnp.float32), cache_v.astype(jnp.float32)
    else:
        qg = q.reshape(B, W, KV, G, hd)
        k_in, v_in = cache_k, cache_v
    qg = qg.transpose(0, 2, 3, 1, 4)                      # (B, KV, G, W, hd)
    logits = jnp.einsum("bkgwh,bskh->bkgws", qg, k_in,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    w_idx = jnp.arange(W, dtype=jnp.int32)
    if jnp.ndim(t) == 0:
        limit = (t + w_idx)[None, :, None]                # (1, W, 1)
    else:
        limit = (t[:, None] + w_idx[None, :])[:, :, None]  # (B, W, 1)
    mask = (jnp.arange(S)[None, None, :] <= limit)[:, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgws,bskh->bkgwh", w.astype(v_in.dtype), v_in,
                     preferred_element_type=jnp.float32)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, W, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Self-attention block (pre-norm, residual)
# ---------------------------------------------------------------------------

def attn_block(cfg, p, x, *, mode: str, pos_offset, cache=None):
    """Returns (x_out, new_cache).

    mode "train": full causal attention, no cache returned.
    mode "prefill": causal attention; returns {"k","v","t"} cache.  With a
    cache supplied (extend/continuation prefill, the paged engine's
    preemption resume), x is the *suffix*: new KV is written into the
    existing buffer at its fill level ``t`` and the suffix attends the
    cached prefix plus itself — row-for-row bitwise identical to a full
    re-prefill of prefix+suffix at the same buffer extent, because each
    query row's online-softmax accumulation is independent of the other
    rows and fully-masked kv chunks contribute exact zeros.
    mode "decode": x is (B,1,D); the cache is a ring buffer of S slots —
    the new KV is written at slot ``t % S`` (t = absolute fill level, RoPE
    stays absolute) so generation past the cache capacity wraps onto the
    oldest slots instead of forcing a larger allocation; while t < S this
    is exactly the old append-at-t behavior.  ``t`` is a scalar shared by
    the batch, or a (B,) vector of per-sequence fill levels (decode
    lanes): each sequence then gets its own RoPE position, ring slot and
    attention window, so one natively batched step serves lanes that
    prefilled at different prompt lengths.  A cache carrying a block
    table ("bt") is block-paged (serving/paging.py): "k"/"v" are shared
    physical pools (n_pages, page, KV, hd) and each lane reads/writes its
    logical window through its table row; unallocated slots point at the
    pinned trash page 0 and dead lanes past the window write there.
    """
    B = x.shape[0]
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    if mode in ("train", "prefill"):
        S = x.shape[1]
        if mode == "prefill" and cache is not None:
            # extend: append S suffix tokens at the buffer's fill level
            plen = cache["t"]          # scalar fill level, traced
            positions = plen + jnp.arange(S)
            q, k, v = _project_qkv(cfg, p, h, positions)
            kbuf = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), plen, axis=1)
            vbuf = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), plen, axis=1)
            out = flash_attention(q, kbuf, vbuf, causal=True, q_offset=plen)
            new_cache = {"k": kbuf, "v": vbuf, "t": plen + S}
            out = constrain(out, "batch", "seq", "heads", "head_dim")
            out = out.reshape(B, -1, cfg.attn_dim)
            return x + dense(out, p["wo"]), new_cache
        positions = jnp.arange(S)
        q, k, v = _project_qkv(cfg, p, h, positions)
        q = constrain(q, "batch", "seq", "heads", "head_dim")
        out = flash_attention(q, k, v, causal=True)
        new_cache = None
        if mode == "prefill":
            new_cache = {"k": k, "v": v, "t": jnp.asarray(S, jnp.int32)}
    elif mode == "verify":
        # Speculative verification: x is (B, W, D) — the pending token plus
        # K draft tokens.  Token w lands at absolute position t + w; all W
        # KVs are written up front and each query masks its own prefix
        # (slot <= t + w), so chain token w attends the draft tokens before
        # it through their just-written target KV — the same values serial
        # decode would have produced and written at those slots.  The fill
        # level is NOT advanced here: the caller commits the accepted
        # length by resetting "t" afterwards (rejected-draft rollback =
        # don't advance; stale KV past the new fill level stays masked and
        # is overwritten in order by later decode/verify writes, so
        # rollback costs no recompilation and no cleanup pass).
        t = cache["t"]
        W = x.shape[1]
        per_seq = jnp.ndim(t) != 0
        w_idx = jnp.arange(W, dtype=jnp.int32)
        positions = (t[:, None] + w_idx[None, :]) if per_seq else t + w_idx
        q, k, v = _project_qkv(cfg, p, h, positions)
        pos = positions if per_seq else jnp.broadcast_to(
            positions[None, :], (B, W))
        if "bt" in cache:                      # block-paged pool
            bt = cache["bt"]                   # (B, P)
            pool_k, pool_v = cache["k"], cache["v"]
            n_pages, page = pool_k.shape[0], pool_k.shape[1]
            P = bt.shape[1]
            max_len = P * page
            page_slot = jnp.minimum(pos // jnp.int32(page), jnp.int32(P - 1))
            pg = jnp.take_along_axis(bt, page_slot, axis=1)
            pg = jnp.where(pos < max_len, pg, jnp.int32(0))
            gs = pg * page + jax.lax.rem(pos, jnp.int32(page))
            KV, hd = pool_k.shape[2], pool_k.shape[3]
            flat_k = pool_k.reshape(n_pages * page, KV, hd)
            flat_v = pool_v.reshape(n_pages * page, KV, hd)
            # duplicate indices only ever hit the trash page (live slots
            # are privately owned), where write order is irrelevant
            flat_k = flat_k.at[gs.reshape(-1)].set(
                k.astype(flat_k.dtype).reshape(B * W, KV, hd))
            flat_v = flat_v.at[gs.reshape(-1)].set(
                v.astype(flat_v.dtype).reshape(B * W, KV, hd))
            ck_pool = flat_k.reshape(n_pages, page, KV, hd)
            cv_pool = flat_v.reshape(n_pages, page, KV, hd)
            k_log = ck_pool[bt].reshape(B, max_len, KV, hd)
            v_log = cv_pool[bt].reshape(B, max_len, KV, hd)
            out = verify_attention(q, k_log, v_log, t)
            new_cache = {"k": ck_pool, "v": cv_pool, "t": t, "bt": bt}
        else:                                  # ring buffer
            S = cache["k"].shape[1]
            # out-of-range positions (a stopped or near-capacity lane's
            # verify window past the buffer) are dropped rather than
            # wrapped: unlike decode, a wrapped verify write could clobber
            # a live early slot before its own masked read.
            gs = jnp.where(
                pos < S,
                jnp.arange(B, dtype=jnp.int32)[:, None] * S + pos,
                jnp.int32(B * S))
            KV, hd = cache["k"].shape[2], cache["k"].shape[3]
            flat_k = cache["k"].reshape(B * S, KV, hd)
            flat_v = cache["v"].reshape(B * S, KV, hd)
            flat_k = flat_k.at[gs.reshape(-1)].set(
                k.astype(flat_k.dtype).reshape(B * W, KV, hd), mode="drop")
            flat_v = flat_v.at[gs.reshape(-1)].set(
                v.astype(flat_v.dtype).reshape(B * W, KV, hd), mode="drop")
            ck = flat_k.reshape(B, S, KV, hd)
            cv = flat_v.reshape(B, S, KV, hd)
            ck = constrain(ck, "batch", "kv_seq", "kv_heads", "head_dim")
            cv = constrain(cv, "batch", "kv_seq", "kv_heads", "head_dim")
            out = verify_attention(q, ck, cv, t)
            new_cache = {"k": ck, "v": cv, "t": t}
        out = constrain(out, "batch", "seq", "heads", "head_dim")
        out = out.reshape(B, -1, cfg.attn_dim)
        return x + dense(out, p["wo"]), new_cache
    elif cache is not None and "bt" in cache:  # block-paged decode
        t = cache["t"]                         # (B,) per-lane fill levels
        bt = cache["bt"]                       # (B, P) int32 page per block
        pool_k, pool_v = cache["k"], cache["v"]    # (Np, page, KV, hd)
        n_pages, page = pool_k.shape[0], pool_k.shape[1]
        P = bt.shape[1]
        max_len = P * page
        positions = t[:, None]
        q, k, v = _project_qkv(cfg, p, h, positions)
        # write: lane b's step-t KV lands in physical page bt[b, t//page]
        # at in-page slot t%page.  Lanes past their window (stopped lanes
        # whose t keeps advancing until segment end) are routed to the
        # pinned trash page so they can never clobber a live or shared
        # page; live lanes never collide (decode always writes a
        # privately owned page — registration stops short of the write
        # frontier), so the batched scatter is deterministic where it
        # matters.
        page_slot = jnp.minimum(t // jnp.int32(page), jnp.int32(P - 1))
        pg = jnp.take_along_axis(bt, page_slot[:, None], axis=1)[:, 0]
        pg = jnp.where(t < max_len, pg, jnp.int32(0))
        gs = pg * page + jax.lax.rem(t, jnp.int32(page))
        KV, hd = pool_k.shape[2], pool_k.shape[3]
        flat_k = pool_k.reshape(n_pages * page, KV, hd)
        flat_v = pool_v.reshape(n_pages * page, KV, hd)
        flat_k = flat_k.at[gs].set(k.astype(flat_k.dtype)[:, 0])
        flat_v = flat_v.at[gs].set(v.astype(flat_v.dtype)[:, 0])
        new_pool_k = flat_k.reshape(n_pages, page, KV, hd)
        new_pool_v = flat_v.reshape(n_pages, page, KV, hd)
        # read: gather each lane's logical window through its table, then
        # the exact same masked attention as the ring path — bitwise
        # equal because every logical slot holds the same value either
        # way and the shapes/einsums are identical.
        k_log = new_pool_k[bt].reshape(B, max_len, KV, hd)
        v_log = new_pool_v[bt].reshape(B, max_len, KV, hd)
        out = decode_attention(q, k_log, v_log, t)
        new_cache = {"k": new_pool_k, "v": new_pool_v, "t": t + 1, "bt": bt}
        out = constrain(out, "batch", "seq", "heads", "head_dim")
        out = out.reshape(B, -1, cfg.attn_dim)
        return x + dense(out, p["wo"]), new_cache
    else:  # decode
        t = cache["t"]  # absolute fill level(s); () shared or (B,) per-seq
        S = cache["k"].shape[1]
        per_seq = jnp.ndim(t) != 0
        positions = t[:, None] if per_seq else jnp.full((1,), t, jnp.int32)
        q, k, v = _project_qkv(cfg, p, h, positions)
        slot = jax.lax.rem(t, jnp.int32(S))
        if per_seq:
            # per-sequence ring write as a one-hot select: XLA CPU lowers
            # batched scatters to a slow generic loop, but this select
            # vectorizes (it streams the cache once, which decode does
            # anyway for the attention reads)
            hit = (jnp.arange(S)[None, :] == slot[:, None])[..., None, None]
            ck = jnp.where(hit, k.astype(cache["k"].dtype)[:, :1], cache["k"])
            cv = jnp.where(hit, v.astype(cache["v"].dtype)[:, :1], cache["v"])
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        ck = constrain(ck, "batch", "kv_seq", "kv_heads", "head_dim")
        cv = constrain(cv, "batch", "kv_seq", "kv_heads", "head_dim")
        out = decode_attention(q, ck, cv, t)
        new_cache = {"k": ck, "v": cv, "t": t + 1}
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    out = out.reshape(B, -1, cfg.attn_dim)
    return x + dense(out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# Cross-attention block (VLM): queries from text, KV from image embeddings
# ---------------------------------------------------------------------------

def xattn_block(cfg, p, x, *, mode: str, image_embeds=None, cache=None):
    """image_embeds: (B, T_img, D).  Cache holds projected image KV."""
    B = x.shape[0]
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    q = dense(h, p["wq"]).reshape(B, -1, cfg.num_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    if cache is not None and "k" in cache and mode == "decode":
        k, v = cache["k"], cache["v"]
        new_cache = cache
    else:
        assert image_embeds is not None, "xattn needs image embeddings"
        k = dense(image_embeds, p["wk"]).reshape(B, -1, cfg.num_kv_heads, cfg.head_dim)
        v = dense(image_embeds, p["wv"]).reshape(B, -1, cfg.num_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
        new_cache = {"k": k, "v": v} if mode in ("prefill", "decode") else None
    out = flash_attention(q, k, v, causal=False)
    out = out.reshape(B, -1, cfg.attn_dim)
    return x + dense(out, p["wo"]), new_cache
