"""Block dispatch and the scanned layer stack.

The model is ``block_pattern x pattern_repeats``.  We scan over repeats with
the per-position params stacked on a leading axis, so HLO size and compile
time are O(pattern length), not O(depth) — essential for lowering 40
(arch x shape) dry-run cells on 512 devices, and the production choice anyway.
Caches ride along as scan xs/ys: prefill emits per-repeat caches as ys,
decode consumes and re-emits them.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, ATTN_MOE, MAMBA, MAMBA_MOE, MLSTM,
                                SLSTM, XATTN)
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import apply_mlp, init_mlp
from repro.sharding import constrain


# Dry-run cost graphs set this to the repeat count so cost_analysis (which
# counts while bodies once) sees every layer.  Production graphs leave it 1.
SCAN_UNROLL = {"n": 1}


def init_block(cfg, kind: str, key):
    """Returns (params, axes) for one block of the given kind."""
    k1, k2 = jax.random.split(key)
    if kind == ATTN:
        ap, aa = attn_lib.init_attention(cfg, k1)
        mp, ma = init_mlp(cfg, k2)
        return {"attn": ap, "mlp": mp}, {"attn": aa, "mlp": ma}
    if kind == ATTN_MOE:
        ap, aa = attn_lib.init_attention(cfg, k1)
        mp, ma = moe_lib.init_moe(cfg, k2)
        return {"attn": ap, "moe": mp}, {"attn": aa, "moe": ma}
    if kind == XATTN:
        ap, aa = attn_lib.init_attention(cfg, k1, cross=True)
        mp, ma = init_mlp(cfg, k2)
        return {"xattn": ap, "mlp": mp}, {"xattn": aa, "mlp": ma}
    if kind == MAMBA:
        sp, sa = ssm_lib.init_mamba(cfg, k1)
        mp, ma = init_mlp(cfg, k2)
        return {"mamba": sp, "mlp": mp}, {"mamba": sa, "mlp": ma}
    if kind == MAMBA_MOE:
        sp, sa = ssm_lib.init_mamba(cfg, k1)
        mp, ma = moe_lib.init_moe(cfg, k2)
        return {"mamba": sp, "moe": mp}, {"mamba": sa, "moe": ma}
    if kind == SLSTM:
        return xlstm_lib.init_slstm(cfg, k1)
    if kind == MLSTM:
        return xlstm_lib.init_mlstm(cfg, k1)
    raise ValueError(kind)


def apply_block(cfg, kind: str, p, x, *, mode: str, cache=None,
                image_embeds=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in (ATTN, ATTN_MOE):
        x, new_cache = attn_lib.attn_block(cfg, p["attn"], x, mode=mode,
                                           pos_offset=0, cache=cache)
    elif kind == XATTN:
        x, new_cache = attn_lib.xattn_block(cfg, p["xattn"], x, mode=mode,
                                            image_embeds=image_embeds,
                                            cache=cache)
    elif kind in (MAMBA, MAMBA_MOE):
        x, new_cache = ssm_lib.mamba_block(cfg, p["mamba"], x, mode=mode,
                                           cache=cache)
    elif kind == SLSTM:
        return (*xlstm_lib.slstm_block(cfg, p, x, mode=mode, cache=cache), aux)
    elif kind == MLSTM:
        return (*xlstm_lib.mlstm_block(cfg, p, x, mode=mode, cache=cache), aux)
    else:
        raise ValueError(kind)

    if kind in (ATTN_MOE, MAMBA_MOE):
        x, aux = moe_lib.apply_moe(cfg, p["moe"], x)
    else:
        x = apply_mlp(cfg, p["mlp"], x)
    return x, new_cache, aux


def run_stack(cfg, blocks_params, x, *, mode: str, caches=None,
              image_embeds=None, remat: bool = True):
    """Scan the pattern x repeats stack.

    blocks_params: tuple over pattern positions, leaves stacked (repeats, ...).
    caches: matching stacked cache pytree (or None).
    Returns (x, new_caches, aux_total).
    """
    pattern = cfg.block_pattern

    # Per-block remat nested inside the per-pattern-step remat: the backward
    # sweep of one pattern step then peaks at max-over-blocks residuals
    # instead of sum-over-blocks (8 blocks/step for jamba).
    def block_fn(kind, p, x, c):
        return apply_block(cfg, kind, p, x, mode=mode, cache=c,
                           image_embeds=image_embeds)

    if mode == "train" and remat:
        block_fn = jax.checkpoint(block_fn, prevent_cse=False,
                                  static_argnums=(0,))

    def body(carry, xs):
        x, aux = carry
        blk_params, blk_caches = xs
        x = constrain(x, "batch", "seq_sp", "embed")
        new_caches = []
        for pos, kind in enumerate(pattern):
            c = None if blk_caches is None else blk_caches[pos]
            x, nc, a = block_fn(kind, blk_params[pos], x, c)
            new_caches.append(nc)
            aux = aux + a
        return (x, aux), tuple(new_caches)

    if mode == "train" and remat:
        body = jax.checkpoint(body, prevent_cse=False)

    aux0 = jnp.zeros((), jnp.float32)
    (x, aux), new_caches = jax.lax.scan(
        body, (x, aux0), (blocks_params, caches),
        unroll=min(SCAN_UNROLL["n"], cfg.pattern_repeats))
    return x, new_caches, aux
