"""Mixture-of-Experts with grouped sort-based capacity dispatch (EP).

Tokens are processed in G groups, one per data shard.  Routing, sorting,
capacity-packing, and combine are all *group-local* (vmapped over G, with G
sharded on the data axis) — a global argsort over the token axis cannot be
sharded by GSPMD and replicates multi-GiB index tensors on every device (we
measured 400+ GiB/device on jamba@train_4k before grouping).  The only
cross-device movement is the (G, E, C, D) expert-buffer resharding:
G:data <-> E:model, i.e. exactly the canonical MoE all-to-all.

Within a group: top-k route, stable-sort by expert id, pack into an
(E, C, D) buffer (overflow dropped — capacity-factor MoE), one batched einsum
per expert weight, weighted scatter-add back.  Memory is linear in tokens.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dtype_of, init_dense, init_expert_mlp, rmsnorm
from repro.sharding import constrain, current_mesh


def init_moe(cfg, key):
    dt = dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    experts, e_axes = init_expert_mlp(cfg, k2)
    params = {
        "norm": jnp.ones((cfg.d_model,), dtype=dt),
        "router": init_dense(k1, cfg.d_model, cfg.num_experts, jnp.float32),
        "experts": experts,
    }
    axes = {
        "norm": ("embed",),
        "router": ("embed_w", "experts"),
        "experts": e_axes,
    }
    return params, axes


def _num_groups(batch: int, seq: int) -> int:
    """Dispatch groups == device count (falls back to 1 off-mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return 1
    g = mesh.size
    while g > 1 and (batch * seq) % g:
        g //= 2
    return max(g, 1)


def _dispatch_group(ht, probs, K: int, C: int):
    """Group-local dispatch.  ht: (T, D); probs: (T, E).

    Returns (xs (E, C, D), combine info) — pure function, vmapped over G.
    """
    T, D = ht.shape
    E = probs.shape[-1]
    gate_w, expert_idx = jax.lax.top_k(probs, K)          # (T, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    flat_e = expert_idx.reshape(-1)                       # (T*K,)
    flat_tok = jnp.repeat(jnp.arange(T), K)
    flat_w = gate_w.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se, stok, sw = flat_e[order], flat_tok[order], flat_w[order]

    counts = jnp.zeros((E,), jnp.int32).at[se].add(1)
    group_start = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(counts)[:-1]])
    pos_in_expert = jnp.arange(T * K, dtype=jnp.int32) - group_start[se]
    keep = pos_in_expert < C
    dst = jnp.where(keep, se * C + pos_in_expert, E * C)  # drop row at end

    buf = jnp.zeros((E * C + 1, D), dtype=ht.dtype)
    buf = buf.at[dst].set(ht[stok])
    return buf[: E * C].reshape(E, C, D), (stok, sw, dst, keep)


def _combine_group(out_e, info, T: int):
    """out_e: (E, C, D) expert outputs -> (T, D) f32 combine."""
    E, C, D = out_e.shape
    stok, sw, dst, keep = info
    out_flat = out_e.reshape(E * C, D)
    gathered = jnp.where(keep[:, None],
                         out_flat[jnp.minimum(dst, E * C - 1)], 0.0)
    combined = jnp.zeros((T, D), dtype=jnp.float32)
    return combined.at[stok].add(gathered.astype(jnp.float32) * sw[:, None])


def _group_spec(mesh):
    """PartitionSpec sharding the group axis over every mesh axis."""
    from jax.sharding import PartitionSpec as P
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    return P(axes)


def apply_moe(cfg, p, x):
    """x: (B, S, D) -> (x + moe(x), aux_loss).

    Dispatch and combine run under ``shard_map`` (one group per device):
    GSPMD cannot keep sort/scatter sharded and silently replicates the
    (tokens, d_model) gather network on every device — shard_map makes
    locality structural.  The expert einsum itself stays in GSPMD land; the
    (G:devices) -> (G:data, E:model) reshard at the boundary is the MoE
    all-to-all.
    """
    from jax.sharding import PartitionSpec as P
    from repro.sharding.compat import shard_map_fn
    shard_map = shard_map_fn()

    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    G = _num_groups(B, S)
    Tg = B * S // G
    hg = h.reshape(G, Tg, D)

    C = max(1, int(math.ceil(Tg * K / E * cfg.moe_capacity_factor)))

    def route_and_dispatch(hg_blk, router_w):
        """Router + top-k + pack, token-local (runs per device)."""
        logits = jnp.einsum("gtd,de->gte", hg_blk.astype(jnp.float32),
                            router_w, preferred_element_type=jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        xs, info = jax.vmap(
            lambda ht, pr: _dispatch_group(ht, pr, K, C))(hg_blk, probs)
        return xs, probs, info

    combine = jax.vmap(lambda oe, inf: _combine_group(oe, inf, Tg))

    mesh = current_mesh()
    use_manual = mesh is not None and mesh.size > 1 and G == mesh.size
    if use_manual:
        gs = _group_spec(mesh)
        gN = lambda n: P(*gs, *([None] * n))
        xs, probs, info = shard_map(
            route_and_dispatch, mesh=mesh,
            in_specs=(gN(2), P(None, None)),
            out_specs=(gN(3), gN(2), (gN(1), gN(1), gN(1), gN(1))),
        )(hg, p["router"])
    else:
        xs, probs, info = route_and_dispatch(hg, p["router"])

    # Switch-style load-balance aux loss (global across groups)
    me = probs.mean(axis=(0, 1))
    top1 = jnp.argmax(probs, axis=-1)
    ce = jnp.zeros((E,), jnp.float32).at[top1.reshape(-1)].add(1.0) / (B * S)
    aux = E * jnp.sum(me * ce)

    # reshard G:(all devices) -> (G:data, E:model) — the MoE all-to-all
    xs = constrain(xs, "batch", "experts", "cap", "embed")

    # ---- per-expert gated MLP (shared weights across groups) -----------
    act = jax.nn.silu if cfg.mlp_activation == "silu" else jax.nn.gelu
    w = p["experts"]
    gate = jnp.einsum("gecd,edf->gecf", xs, w["w_gate"],
                      preferred_element_type=jnp.float32).astype(h.dtype)
    up = jnp.einsum("gecd,edf->gecf", xs, w["w_up"],
                    preferred_element_type=jnp.float32).astype(h.dtype)
    hidden = act(gate) * up
    hidden = constrain(hidden, "batch", "experts", "cap", "expert_mlp")
    out_e = jnp.einsum("gecf,efd->gecd", hidden, w["w_down"],
                       preferred_element_type=jnp.float32).astype(h.dtype)
    out_e = constrain(out_e, "batch", "experts", "cap", "embed")

    if use_manual:
        gs = _group_spec(mesh)
        combined = shard_map(
            combine, mesh=mesh,
            in_specs=(P(*gs, None, None, None),
                      (P(*gs, None), P(*gs, None), P(*gs, None),
                       P(*gs, None))),
            out_specs=P(*gs, None, None))(out_e, info)
    else:
        combined = combine(out_e, info)
    out = combined.reshape(B, S, D).astype(x.dtype)
    return x + out, aux
