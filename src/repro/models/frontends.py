"""Modality frontends (stubs) and input spec construction.

Per the assignment, [vlm]/[audio] entries specify the transformer BACKBONE
only; the modality frontend is a STUB — ``input_specs()`` provides
precomputed patch/frame embeddings as ``ShapeDtypeStruct`` stand-ins (dry-run)
or random arrays (smoke tests / examples).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig


def batch_axes(cfg: ArchConfig, shape: ShapeConfig):
    """Logical sharding axes per batch entry (same keys as input_specs)."""
    axes = {}
    if shape.kind == "train":
        if cfg.audio_frontend:
            axes["frames"] = ("batch", "seq", "embed")
        else:
            axes["tokens"] = ("batch", "seq")
        axes["labels"] = ("batch", "seq")
    elif shape.kind == "prefill":
        if cfg.audio_frontend:
            axes["frames"] = ("batch", "seq", "embed")
        else:
            axes["tokens"] = ("batch", "seq")
    else:  # decode: one new token
        if cfg.audio_frontend:
            axes["frames"] = ("batch", "seq", "embed")
        else:
            axes["tokens"] = ("batch", "seq")
    if cfg.num_image_tokens:
        axes["image_embeds"] = ("batch", "image_seq", "embed")
    return axes


def input_specs(cfg: ArchConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    decode-kind shapes describe ONE new token (the KV cache of seq_len is a
    separate argument produced by ``LM.init_cache`` / ``cache_specs``).
    """
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    dt = jnp.dtype(cfg.dtype)
    f = jax.ShapeDtypeStruct
    specs = {}
    if cfg.audio_frontend:
        specs["frames"] = f((B, S, cfg.d_model), dt)
    else:
        specs["tokens"] = f((B, S), jnp.int32)
    if shape.kind == "train":
        specs["labels"] = f((B, S), jnp.int32)
    if cfg.num_image_tokens:
        specs["image_embeds"] = f((B, cfg.num_image_tokens, cfg.d_model), dt)
    return specs


def make_batch(cfg: ArchConfig, shape: ShapeConfig, key=None, batch_size=None,
               seq_len=None):
    """Concrete random batch matching input_specs (smoke tests, examples)."""
    key = key if key is not None else jax.random.key(0)
    B = batch_size or shape.global_batch
    S = seq_len or (shape.seq_len if shape.kind != "decode" else 1)
    k1, k2, k3 = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    batch = {}
    if cfg.audio_frontend:
        batch["frames"] = jax.random.normal(k1, (B, S, cfg.d_model)).astype(dt)
    else:
        batch["tokens"] = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
    if shape.kind == "train":
        batch["labels"] = jax.random.randint(k2, (B, S), 0, cfg.vocab_size)
    if cfg.num_image_tokens:
        batch["image_embeds"] = jax.random.normal(
            k3, (B, cfg.num_image_tokens, cfg.d_model)).astype(dt)
    return batch
