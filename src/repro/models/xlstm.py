"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

TPU adaptation (arXiv:2405.04517 targets fused CUDA kernels):

* mLSTM — exponential-gated linear attention with a matrix state C (hd x hd
  per head).  Training/prefill uses the *chunkwise-parallel* form: recurrence
  across chunks (``lax.scan`` carry = (C, n, m) state), quadratic
  intra-chunk attention with log-space gate-decay weights.  This keeps MXU
  utilisation high (chunk-sized matmuls) with O(S/chunk) sequential depth.
  Decode is the exact sequential recurrence — O(1) state per token, which is
  why xlstm runs the long_500k cell.
* sLSTM — per-channel scalar memory with block-diagonal (per-head) recurrent
  gate matrices.  Inherently sequential (the normalizer recurrence forbids a
  parallel form); we precompute all input-side gate projections in parallel
  and scan only the tiny recurrent update.

Both use the max-stabilizer trick from the paper: gates live in log space,
states carry a running max ``m``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dtype_of, init_dense, rmsnorm
from repro.sharding import constrain

MLSTM_CHUNK = 256


def _logsig(x):
    return jax.nn.log_sigmoid(x)


# ===========================================================================
# mLSTM
# ===========================================================================

def init_mlstm(cfg, key):
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 8)
    d, ad, H = cfg.d_model, cfg.attn_dim, cfg.num_heads
    params = {
        "norm": jnp.ones((d,), dtype=dt),
        "wq": init_dense(ks[0], d, ad, dt),
        "wk": init_dense(ks[1], d, ad, dt),
        "wv": init_dense(ks[2], d, ad, dt),
        "wi": init_dense(ks[3], d, H, jnp.float32),
        "wf": init_dense(ks[4], d, H, jnp.float32),
        "wo_out": init_dense(ks[5], ad, d, dt, scale=ad ** -0.5),
        "norm2": jnp.ones((d,), dtype=dt),
        "up": init_dense(ks[6], d, d, dt),
        "down": init_dense(ks[7], d, d, dt),
    }
    axes = {
        "norm": ("embed",), "norm2": ("embed",),
        "wq": ("embed_w", "qkv"), "wk": ("embed_w", "qkv"),
        "wv": ("embed_w", "qkv"),
        "wi": ("embed_w", "heads"), "wf": ("embed_w", "heads"),
        "wo_out": ("qkv", "embed_w"),
        "up": ("embed_w", "mlp"), "down": ("mlp", "embed_w"),
    }
    return params, axes


def _mlstm_qkvif(cfg, p, h):
    B, S, _ = h.shape
    H, hd = cfg.num_heads, cfg.head_dim
    q = dense(h, p["wq"]).reshape(B, S, H, hd).astype(jnp.float32) * hd ** -0.5
    k = dense(h, p["wk"]).reshape(B, S, H, hd).astype(jnp.float32) * hd ** -0.5
    v = dense(h, p["wv"]).reshape(B, S, H, hd).astype(jnp.float32)
    i_pre = jnp.matmul(h.astype(jnp.float32), p["wi"])  # (B,S,H)
    f_pre = jnp.matmul(h.astype(jnp.float32), p["wf"])
    return q, k, v, i_pre, f_pre


def mlstm_chunked(q, k, v, i_pre, f_pre, state=None, chunk: int = MLSTM_CHUNK):
    """Chunkwise-parallel stabilized mLSTM.

    q,k,v: (B,S,H,hd) f32; i_pre,f_pre: (B,S,H).
    state: optional (C (B,H,hd,hd), n (B,H,hd), m (B,H)).
    Returns (out (B,S,H,hd), state).
    """
    B, S, H, hd = q.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        zf = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, zf) for a in (q, k, v))
        i_pre = jnp.pad(i_pre, ((0, 0), (0, pad), (0, 0)), constant_values=-1e9)
        f_pre = jnp.pad(f_pre, ((0, 0), (0, pad), (0, 0)), constant_values=30.0)
    L = chunk
    nc = (S + pad) // L

    def csplit(a):
        return a.reshape(B, nc, L, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))

    qc, kc, vc = csplit(q), csplit(k), csplit(v)  # (nc,B,L,H,hd)
    ic, fc = csplit(i_pre), csplit(f_pre)         # (nc,B,L,H)

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def step(carry, xs):
        C, n, m = carry
        qq, kk, vv, ii, ff = xs  # (B,L,H,hd) / (B,L,H)
        logf = _logsig(ff)                         # (B,L,H)
        F = jnp.cumsum(logf, axis=1)               # inclusive
        # intra-chunk log weights w[t,s] = F_t - F_s + i_s  for s <= t
        w = F[:, :, None, :] - F[:, None, :, :] + ii[:, None, :, :]  # (B,t,s,H)
        tmask = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
        w = jnp.where(tmask, w, -1e30)
        m_intra = w.max(axis=2)                    # (B,L,H)
        m_inter = F + m[:, None, :]                # (B,L,H)
        m_t = jnp.maximum(m_intra, m_inter)
        # intra attention
        logits = jnp.einsum("blhd,bshd->blsh", qq, kk)
        wexp = jnp.exp(w - m_t[:, :, None, :])
        num = jnp.einsum("blsh,bshd->blhd", logits * wexp, vv)
        den = jnp.einsum("blsh->blh", logits * wexp)
        # inter (carry) contribution
        scale_in = jnp.exp(m_inter - m_t)          # (B,L,H)
        num = num + scale_in[..., None] * jnp.einsum("blhd,bhde->blhe", qq, C)
        den = den + scale_in * jnp.einsum("blhd,bhd->blh", qq, n)
        out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # state update to end of chunk
        FL = F[:, -1:, :]                          # (B,1,H)
        m_state = jnp.maximum(FL[:, 0] + m, (FL - F + ii).max(axis=1))
        sw = jnp.exp(FL - F + ii - m_state[:, None, :])   # (B,L,H)
        C_new = jnp.exp(FL[:, 0] + m - m_state)[..., None, None] * C + \
            jnp.einsum("blh,blhd,blhe->bhde", sw, kk, vv)
        n_new = jnp.exp(FL[:, 0] + m - m_state)[..., None] * n + \
            jnp.einsum("blh,blhd->bhd", sw, kk)
        return (C_new, n_new, m_state), out

    state_f, outs = jax.lax.scan(step, (C0, n0, m0), (qc, kc, vc, ic, fc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S + pad, H, hd)[:, :S]
    return out, state_f


def mlstm_step(q, k, v, i_pre, f_pre, state):
    """Exact sequential mLSTM for one token.  q,k,v: (B,1,H,hd)."""
    C, n, m = state
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]        # (B,H,hd)
    logf = _logsig(f_pre[:, 0])                   # (B,H)
    i1 = i_pre[:, 0]
    m_new = jnp.maximum(logf + m, i1)
    fprime = jnp.exp(logf + m - m_new)
    iprime = jnp.exp(i1 - m_new)
    C_new = fprime[..., None, None] * C + iprime[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k1, v1)
    n_new = fprime[..., None] * n + iprime[..., None] * k1
    num = jnp.einsum("bhd,bhde->bhe", q1, C_new)
    den = jnp.einsum("bhd,bhd->bh", q1, n_new)
    out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return out[:, None], (C_new, n_new, m_new)


def mlstm_block(cfg, p, x, *, mode: str, cache=None):
    B = x.shape[0]
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k, v, i_pre, f_pre = _mlstm_qkvif(cfg, p, h)
    if mode == "train":
        out, _ = mlstm_chunked(q, k, v, i_pre, f_pre)
        new_cache = None
    elif mode == "prefill":
        out, st = mlstm_chunked(q, k, v, i_pre, f_pre)
        new_cache = {"C": st[0], "n": st[1], "m": st[2]}
    else:
        st = (cache["C"], cache["n"], cache["m"])
        out, st = mlstm_step(q, k, v, i_pre, f_pre, st)
        new_cache = {"C": st[0], "n": st[1], "m": st[2]}
    out = out.reshape(B, -1, cfg.attn_dim).astype(x.dtype)
    x = x + dense(out, p["wo_out"])
    h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
    return x + dense(jax.nn.gelu(dense(h2, p["up"])), p["down"]), new_cache


# ===========================================================================
# sLSTM
# ===========================================================================

def init_slstm(cfg, key):
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 5)
    d, ad, H, hd = cfg.d_model, cfg.attn_dim, cfg.num_heads, cfg.head_dim
    params = {
        "norm": jnp.ones((d,), dtype=dt),
        "w_gates": init_dense(ks[0], d, 4 * ad, jnp.float32),  # z,i,f,o
        "r_gates": (jax.random.normal(ks[1], (4, H, hd, hd)) * hd ** -0.5
                    ).astype(jnp.float32),
        "b_gates": jnp.zeros((4, ad), jnp.float32),
        "wo_out": init_dense(ks[2], ad, d, dt, scale=ad ** -0.5),
        "norm2": jnp.ones((d,), dtype=dt),
        "up": init_dense(ks[3], d, d, dt),
        "down": init_dense(ks[4], d, d, dt),
    }
    axes = {
        "norm": ("embed",), "norm2": ("embed",),
        "w_gates": ("embed_w", "qkv"),
        "r_gates": (None, "heads", "head_dim", "head_dim"),
        "b_gates": (None, "qkv"),
        "wo_out": ("qkv", "embed_w"),
        "up": ("embed_w", "mlp"), "down": ("mlp", "embed_w"),
    }
    return params, axes


def _slstm_cell(p, wx_t, state):
    """One sLSTM step.  wx_t: (B,4,H,hd) precomputed input projections."""
    c, n, h, m = state                            # each (B,H,hd)
    rec = jnp.einsum("bhd,ghde->bghe", h, p["r_gates"])  # (B,4,H,hd)
    H, hd = h.shape[1], h.shape[2]
    pre = wx_t + rec + p["b_gates"].reshape(1, 4, H, hd)
    z = jnp.tanh(pre[:, 0])
    i_pre, f_pre, o_pre = pre[:, 1], pre[:, 2], pre[:, 3]
    logf = _logsig(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    iprime = jnp.exp(i_pre - m_new)
    fprime = jnp.exp(logf + m - m_new)
    c_new = fprime * c + iprime * z
    n_new = fprime * n + iprime
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_block(cfg, p, x, *, mode: str, cache=None):
    B, S, _ = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    hin = rmsnorm(x, p["norm"], cfg.norm_eps)
    wx = jnp.matmul(hin.astype(jnp.float32), p["w_gates"])  # (B,S,4*ad)
    wx = wx.reshape(B, S, 4, H, hd)

    if mode == "decode" and cache is not None:
        state = (cache["c"], cache["n"], cache["h"], cache["m"])
    else:
        zeros = jnp.zeros((B, H, hd), jnp.float32)
        state = (zeros, zeros, zeros, jnp.full((B, H, hd), -1e30, jnp.float32))

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def step(st, wx_t):
        return _slstm_cell(p, wx_t, st)

    state, hs = jax.lax.scan(step, state, wx.transpose(1, 0, 2, 3, 4))
    out = hs.transpose(1, 0, 2, 3).reshape(B, S, cfg.attn_dim).astype(x.dtype)
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"c": state[0], "n": state[1], "h": state[2], "m": state[3]}
    x = x + dense(out, p["wo_out"])
    h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
    return x + dense(jax.nn.gelu(dense(h2, p["up"])), p["down"]), new_cache
