"""Jitted public wrappers around the Pallas kernels.

``interpret`` resolves automatically: compiled on real TPU backends,
interpret-mode (Python execution of the kernel body) on CPU — which is how
this container validates the kernels.  Layout adaptation to/from the model's
(B, S, H, hd) convention lives here so kernels stay in their TPU-native
(B, H, S, hd) layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_kernel
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.gbdt_infer import (gbdt_margins_kernel,
                                      gbdt_margins_packed_kernel)


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_kv: int = 128):
    """Model-layout wrapper: q (B,S,H,hd), k/v (B,S,KV,hd) -> (B,S,H,hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_kernel(qt, kt, vt, causal=causal, block_q=block_q,
                               block_kv=block_kv, interpret=_auto_interpret())
    return o.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("block_kv",))
def decode_attention(q, cache_k, cache_v, t, *, block_kv: int = 256):
    """q (B,1,H,hd), cache (B,S,KV,hd), fill level t -> (B,1,H,hd)."""
    B, _, H, hd = q.shape
    KV = cache_k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    kt = cache_k.transpose(0, 2, 1, 3)
    vt = cache_v.transpose(0, 2, 1, 3)
    o = decode_attention_kernel(qg, kt, vt, t, block_kv=block_kv,
                                interpret=_auto_interpret())
    return o.reshape(B, 1, H, hd)


@functools.partial(jax.jit, static_argnames=("n_classes",))
def gbdt_margins(X, feature, threshold, value, *, n_classes: int = 3):
    return gbdt_margins_kernel(X, feature, threshold, value,
                               n_classes=n_classes,
                               interpret=_auto_interpret())


@functools.partial(jax.jit, static_argnames=("n_classes", "depth"))
def gbdt_margins_packed(X, feature, threshold, child, value, *,
                        depth: int, n_classes: int = 3):
    """Pruned-layout tree-parallel kernel (see core.ensemble_pack)."""
    return gbdt_margins_packed_kernel(X, feature, threshold, child, value,
                                      depth=depth, n_classes=n_classes,
                                      interpret=_auto_interpret())


def preferred_gbdt_layout() -> str:
    """Which ensemble layout scores faster on the current backend.

    Measured on the 450-tree Clairvoyant ensemble (B=512, block sweep over
    block_b 128-512 x block_t 48-450): in interpret mode (CPU) the DENSE
    kernel wins (~35-48 us/req vs ~41-53 packed across shapes).  Interpret
    cost is per-op, so it scales with the gather count of the unrolled
    walk — dense does 3 ``take_along_axis`` per level (feat, x, thr),
    packed does 4 (the explicit child indirection) — and both unroll the
    same depth on this ensemble (pruned depth == max_depth when any tree
    is full); the packed layout's smaller node tensors (M=101 vs N=127
    slots) buy nothing host-side.  On TPU the compiled packed kernel is
    preferred: ~20% less VMEM traffic per tree block, no dead-subtree
    lanes, and one fewer select per level (leaves self-loop instead of
    being masked).
    """
    return "packed" if jax.default_backend() == "tpu" else "dense"


def gbdt_margins_best(X, model):
    """Score a batch with whichever device layout wins on this backend
    (see :func:`preferred_gbdt_layout`).  ``model`` is a
    ``core.gbdt.GBDTModel``."""
    X = jnp.asarray(X, jnp.float32)
    if preferred_gbdt_layout() == "packed":
        return gbdt_margins_packed_from(model.packed(), X)
    return gbdt_margins(X, jnp.asarray(model.feature),
                        jnp.asarray(model.threshold),
                        jnp.asarray(model.value),
                        n_classes=int(model.n_classes))


def gbdt_margins_packed_from(packed, X):
    """Score with a host-side :class:`~repro.core.ensemble_pack.PackedEnsemble`."""
    return gbdt_margins_packed(
        jnp.asarray(X, jnp.float32), jnp.asarray(packed.pfeat),
        jnp.asarray(packed.pthr), jnp.asarray(packed.pchild),
        jnp.asarray(packed.pvalue), depth=int(packed.depth),
        n_classes=int(packed.n_classes))


def gbdt_proba(X, feature, threshold, value, *, n_classes: int = 3):
    m = gbdt_margins(X, feature, threshold, value, n_classes=n_classes)
    return jax.nn.softmax(m, axis=-1)
