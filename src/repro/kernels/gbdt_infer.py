"""Pallas TPU kernels: batched GBDT ensemble inference (the predictor).

The Clairvoyant predictor scores admission batches: margins for K classes
from T trees.  Both kernels are **tree-parallel**: the grid tiles
batch x tree blocks ``(nb, nt)``, each program advances a 2-D
``(block_t, block_b)`` traversal frontier — node indices evolve as a pure
VPU select/gather pattern — and accumulates its tree block's per-class
contribution into the output block, which is revisited across the inner
(tree) grid axis.  This replaces the seed's round-serial ``fori_loop``
over T//K rounds with depth-unrolled work across all trees of a block at
once.

Two layouts are supported:

* ``gbdt_margins_kernel`` — the dense complete-binary-tree tensors
  exported by ``train_gbdt`` ((T, N), ``feature < 0`` marks leaves,
  children of i at 2i+1 / 2i+2);
* ``gbdt_margins_packed_kernel`` — the pruned padded layout from
  ``core.ensemble_pack`` ((T, M) with in-tree left-child indices, leaf
  self-loops and ``+inf`` leaf thresholds), which skips dead subtrees and
  needs no leaf mask.  Finite features assumed (NaN would escape a leaf
  self-loop); the 19 Clairvoyant features always are.

Tree t contributes to class t % K (XGBoost multi:softprob layout); tree
blocks are padded to a multiple of K with zero-valued stub trees so the
in-block class interleave stays aligned.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401  (TPU backend)


def _class_accumulate(o_ref, contrib, n_classes):
    """contrib: (block_t, block_b) per-tree values -> (block_b, K) margins."""
    bt, bb = contrib.shape
    per_class = contrib.reshape(bt // n_classes, n_classes, bb).sum(axis=0)
    o_ref[...] += per_class.T


def _gbdt_dense_kernel(x_ref, feat_ref, thr_ref, val_ref, o_ref, *,
                       n_classes: int, max_depth: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                        # (block_b, F)
    feat = feat_ref[...]                  # (block_t, N) int32
    thr = thr_ref[...]                    # (block_t, N) f32
    val = val_ref[...]                    # (block_t, N) f32
    bt, bb = feat.shape[0], x.shape[0]
    xt = x.T                              # (F, block_b)
    idx = jnp.zeros((bt, bb), jnp.int32)
    for _ in range(max_depth):
        f = jnp.take_along_axis(feat, idx, axis=1)          # (bt, bb)
        is_leaf = f < 0
        xi = jnp.take_along_axis(xt, jnp.maximum(f, 0), axis=0)
        t = jnp.take_along_axis(thr, idx, axis=1)
        nxt = jnp.where(xi < t, 2 * idx + 1, 2 * idx + 2)
        idx = jnp.where(is_leaf, idx, nxt)
    v = jnp.take_along_axis(val, idx, axis=1)
    _class_accumulate(o_ref, v, n_classes)


def _gbdt_packed_kernel(x_ref, feat_ref, thr_ref, child_ref, val_ref, o_ref,
                        *, n_classes: int, depth: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                        # (block_b, F)
    feat = feat_ref[...]                  # (block_t, M) int32
    thr = thr_ref[...]                    # (block_t, M) f32 (+inf at leaves)
    child = child_ref[...]                # (block_t, M) int32
    val = val_ref[...]
    bt, bb = feat.shape[0], x.shape[0]
    xt = x.T
    idx = jnp.zeros((bt, bb), jnp.int32)
    for _ in range(depth):
        f = jnp.take_along_axis(feat, idx, axis=1)
        xi = jnp.take_along_axis(xt, f, axis=0)
        t = jnp.take_along_axis(thr, idx, axis=1)
        c = jnp.take_along_axis(child, idx, axis=1)
        go_right = jnp.logical_not(xi < t)  # leaves: x < +inf -> stay
        idx = c + go_right.astype(jnp.int32)
    v = jnp.take_along_axis(val, idx, axis=1)
    _class_accumulate(o_ref, v, n_classes)


def _pad_grid(X, trees, n_classes, block_b, block_t):
    """Pad batch to block_b and trees to a K-aligned block_t multiple."""
    B = X.shape[0]
    T = trees[0].shape[0]
    block_b = max(1, min(block_b, B))
    block_t = max(n_classes, min(block_t - block_t % n_classes, T))
    pad_b = (-B) % block_b
    pad_t = (-T) % block_t
    if pad_b:
        X = jnp.pad(X, ((0, pad_b), (0, 0)))
    return X, pad_b, pad_t, block_b, block_t


@functools.partial(jax.jit, static_argnames=(
    "n_classes", "block_b", "block_t", "interpret"))
def gbdt_margins_kernel(X, feature, threshold, value, *, n_classes: int = 3,
                        block_b: int = 128, block_t: int = 48,
                        interpret: bool = True):
    """Dense layout. X: (B, F) f32; ensemble tensors (T, N) -> (B, K)."""
    B, F = X.shape
    T, N = feature.shape
    max_depth = int(math.log2(N + 1)) - 1
    X, pad_b, pad_t, block_b, block_t = _pad_grid(
        X.astype(jnp.float32), (feature,), n_classes, block_b, block_t)
    if pad_t:
        # stub trees: leaf at the root with zero value
        feature = jnp.pad(feature, ((0, pad_t), (0, 0)),
                          constant_values=-1)
        threshold = jnp.pad(threshold, ((0, pad_t), (0, 0)))
        value = jnp.pad(value, ((0, pad_t), (0, 0)))
    nb = (B + pad_b) // block_b
    nt = (T + pad_t) // block_t

    kernel = functools.partial(_gbdt_dense_kernel, n_classes=n_classes,
                               max_depth=max_depth)
    out = pl.pallas_call(
        kernel,
        grid=(nb, nt),
        in_specs=[
            pl.BlockSpec((block_b, F), lambda i, j: (i, 0)),
            pl.BlockSpec((block_t, N), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, N), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, N), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, n_classes), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B + pad_b, n_classes), jnp.float32),
        interpret=interpret,
    )(X, feature.astype(jnp.int32), threshold.astype(jnp.float32),
      value.astype(jnp.float32))
    return out[:B]


@functools.partial(jax.jit, static_argnames=(
    "n_classes", "depth", "block_b", "block_t", "interpret"))
def gbdt_margins_packed_kernel(X, feature, threshold, child, value, *,
                               depth: int, n_classes: int = 3,
                               block_b: int = 128, block_t: int = 48,
                               interpret: bool = True):
    """Packed layout (see core.ensemble_pack). Tensors (T, M) -> (B, K)."""
    B, F = X.shape
    T, M = feature.shape
    X, pad_b, pad_t, block_b, block_t = _pad_grid(
        X.astype(jnp.float32), (feature,), n_classes, block_b, block_t)
    if pad_t:
        # stub trees: self-looping zero-valued leaf at the root
        feature = jnp.pad(feature, ((0, pad_t), (0, 0)))
        threshold = jnp.pad(threshold, ((0, pad_t), (0, 0)),
                            constant_values=jnp.inf)
        child = jnp.pad(child, ((0, pad_t), (0, 0)))
        value = jnp.pad(value, ((0, pad_t), (0, 0)))
    nb = (B + pad_b) // block_b
    nt = (T + pad_t) // block_t

    kernel = functools.partial(_gbdt_packed_kernel, n_classes=n_classes,
                               depth=depth)
    out = pl.pallas_call(
        kernel,
        grid=(nb, nt),
        in_specs=[
            pl.BlockSpec((block_b, F), lambda i, j: (i, 0)),
            pl.BlockSpec((block_t, M), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, M), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, M), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, M), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, n_classes), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B + pad_b, n_classes), jnp.float32),
        interpret=interpret,
    )(X, feature.astype(jnp.int32), threshold.astype(jnp.float32),
      child.astype(jnp.int32), value.astype(jnp.float32))
    return out[:B]
