"""Pallas TPU kernel: batched GBDT ensemble inference (the predictor).

The Clairvoyant predictor scores admission batches: margins for K classes
from T depth-d complete binary trees.  TPU adaptation of the ONNX-Runtime CPU
path: the whole ensemble (900 trees x 127 nodes x 3 tensors ~= 1.4 MB) is
pinned in VMEM; each program scores a block of requests by depth-unrolled
traversal — node indices evolve as idx = 2*idx + 1 + (x[feat] >= thr), a pure
VPU select/gather pattern with no HBM traffic after the first load.

Tree t contributes to class t % K (XGBoost multi:softprob layout).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gbdt_kernel(x_ref, feat_ref, thr_ref, val_ref, o_ref, *,
                 n_classes: int, max_depth: int, block_b: int):
    x = x_ref[...]                        # (block_b, F)
    feat = feat_ref[...]                  # (T, N) int32
    thr = thr_ref[...]                    # (T, N) f32
    val = val_ref[...]                    # (T, N) f32
    T = feat.shape[0]
    rounds = T // n_classes

    def eval_tree(t, x):
        idx = jnp.zeros((block_b,), jnp.int32)
        for _ in range(max_depth):
            f = feat[t, idx]                       # (block_b,)
            is_leaf = f < 0
            xi = jnp.take_along_axis(
                x, jnp.maximum(f, 0)[:, None], axis=1)[:, 0]
            go_left = xi < thr[t, idx]
            nxt = jnp.where(go_left, 2 * idx + 1, 2 * idx + 2)
            idx = jnp.where(is_leaf, idx, nxt)
        return val[t, idx]

    def round_body(r, acc):
        contribs = [eval_tree(r * n_classes + c, x) for c in range(n_classes)]
        return acc + jnp.stack(contribs, axis=1)

    margins = jax.lax.fori_loop(
        0, rounds, round_body, jnp.zeros((block_b, n_classes), jnp.float32))
    o_ref[...] = margins


def gbdt_margins_kernel(X, feature, threshold, value, *, n_classes: int = 3,
                        block_b: int = 128, interpret: bool = True):
    """X: (B, F) f32; ensemble tensors (T, N).  Returns (B, n_classes)."""
    import math
    B, F = X.shape
    T, N = feature.shape
    max_depth = int(math.log2(N + 1)) - 1
    block_b = min(block_b, B)
    pad = (-B) % block_b
    if pad:
        X = jnp.pad(X, ((0, pad), (0, 0)))
    nb = (B + pad) // block_b

    kernel = functools.partial(_gbdt_kernel, n_classes=n_classes,
                               max_depth=max_depth, block_b=block_b)

    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, F), lambda i: (i, 0)),
            pl.BlockSpec((T, N), lambda i: (0, 0)),
            pl.BlockSpec((T, N), lambda i: (0, 0)),
            pl.BlockSpec((T, N), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, n_classes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B + pad, n_classes), jnp.float32),
        interpret=interpret,
    )(X.astype(jnp.float32), feature.astype(jnp.int32),
      threshold.astype(jnp.float32), value.astype(jnp.float32))
    return out[:B]
