"""Pallas TPU kernel: causal flash attention (prefill / training path).

Tiling: grid (batch, q_heads, q_blocks, kv_blocks) with the KV axis
innermost; a VMEM scratch accumulator carries the streaming-softmax state
(m, l, acc) across KV blocks, so HBM traffic is one pass over Q/K/V and one
write of O — the flash-attention recurrence mapped onto the MXU with
(block_q x head_dim) x (head_dim x block_kv) matmuls.

GQA is native: the K/V BlockSpec index-maps query head h to KV head
h // (H // KV), so no KV replication is materialised.

Block sizes default to 128 (MXU-aligned); head_dim rides whole (128/256 for
the assigned archs — both VMEM-friendly: 3 tiles x 128 x 256 x 4B < 0.5 MB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, block_q: int, block_kv: int, scale: float):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)
    nkv = pl.num_programs(3)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (bkv, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        k_pos = ikv * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=1)
    acc_new = acc_prev * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(ikv == nkv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool = True):
    """q: (B, H, S, hd); k, v: (B, KV, S, hd) -> (B, H, S, hd)."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    assert H % KV == 0, (H, KV)
    G = H // KV
    block_q = min(block_q, S)
    block_kv = min(block_kv, S)
    assert S % block_q == 0 and S % block_kv == 0, (S, block_q, block_kv)
    nq, nkv = S // block_q, S // block_kv
    grid = (B, H, nq, nkv)

    kernel = functools.partial(
        _flash_kernel, causal=causal, block_q=block_q, block_kv=block_kv,
        scale=hd ** -0.5)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, iq, ikv: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b, h, iq, ikv, G=G: (b, h // G, ikv, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b, h, iq, ikv, G=G: (b, h // G, ikv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ikv: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
