"""Pallas TPU kernel: single-token GQA decode attention over a KV cache.

Decode is memory-bound: the whole KV cache streams HBM->VMEM once per token.
The kernel tiles the cache sequence axis; each (batch, head) program streams
KV blocks through VMEM carrying the online-softmax state, masking slots
beyond the current fill level ``t``.  ``t`` is the *absolute* fill level of
the ring-buffer cache (models/attention.py writes step t at slot ``t % S``):
while t < S the predicate ``slot <= t`` masks the unwritten suffix, and once
the ring wraps it is all-true — every slot then holds one of the S most
recent tokens, so the same kernel serves both regimes.  All G query heads of a KV group share
the same K/V block fetch (q is laid out (B, KV, G, hd) so the group rides in
one block) — on real hardware this is the G-fold HBM-bandwidth saving that
makes GQA decode fast; the grid never re-reads a KV block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(t_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, block_kv: int, scale: float):
    ikv = pl.program_id(2)
    nkv = pl.num_programs(2)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bkv, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    t = t_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = ikv * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos <= t, s, NEG_INF)            # (G, bkv)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=1)
    acc_new = acc_prev * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(ikv == nkv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def _paged_decode_kernel(bt_ref, t_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, page: int, scale: float):
    b = pl.program_id(0)
    ip = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (page, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    t = t_ref[b]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = ip * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos <= t, s, NEG_INF)            # (G, page)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=1)
    acc_new = acc_prev * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(ip == np_ - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_decode_attention_kernel(q, k_pages, v_pages, block_table, t, *,
                                  interpret: bool = True):
    """Block-paged variant: K/V live in a shared physical page pool and
    each sequence reads its logical window through a block table.

    q: (B, KV, G, hd) one query token, grouped; k_pages, v_pages:
    (n_pages, KV, page, hd) physical pool; block_table: (B, P) int32
    physical page backing logical block p of sequence b; t: (B,) int32
    per-sequence fill levels (logical slots <= t[b] attend).  Returns
    (B, KV, G, hd).

    The block table and fill levels ride as scalar-prefetch operands
    (``PrefetchScalarGridSpec``): the index map dereferences
    ``bt[b, ip]`` to pick which physical page the (b, head, ip) program
    streams, so the gather happens in the DMA schedule — the kernel body
    is the same online-softmax loop as the dense ring kernel, with the
    grid's page axis standing in for the kv-block axis.  Unallocated
    table slots point at the pinned trash page (0); they sit beyond the
    fill level so the mask discards whatever garbage they hold.
    """
    B, KV, G, hd = q.shape
    n_pages, _, page, _ = k_pages.shape
    P = block_table.shape[1]
    grid = (B, KV, P)
    bt = jnp.asarray(block_table, jnp.int32)
    t_arr = jnp.asarray(t, jnp.int32).reshape(B)

    kernel = functools.partial(_paged_decode_kernel, page=page,
                               scale=hd ** -0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, h, ip, bt_ref, t_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, page, hd),
                         lambda b, h, ip, bt_ref, t_ref:
                         (bt_ref[b, ip], h, 0, 0)),
            pl.BlockSpec((1, 1, page, hd),
                         lambda b, h, ip, bt_ref, t_ref:
                         (bt_ref[b, ip], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, ip, bt_ref, t_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(bt, t_arr, q, k_pages, v_pages)


def decode_attention_kernel(q, k, v, t, *, block_kv: int = 256,
                            interpret: bool = True):
    """q: (B, KV, G, hd) one query token, grouped; k, v: (B, KV, S, hd);
    t: scalar int32 absolute fill level (slots <= t attend; all slots once
    the ring has wrapped, t >= S).  Returns (B, KV, G, hd).
    """
    B, KV, G, hd = q.shape
    S = k.shape[2]
    block_kv = min(block_kv, S)
    assert S % block_kv == 0, (S, block_kv)
    nkv = S // block_kv
    grid = (B, KV, nkv)
    t_arr = jnp.asarray(t, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, block_kv=block_kv,
                               scale=hd ** -0.5)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ikv: (0,)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ikv: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, ikv: (b, h, ikv, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, ikv: (b, h, ikv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ikv: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(t_arr, q, k, v)
