"""Pallas TPU kernel: single-token GQA decode attention over a KV cache.

Decode is memory-bound: the whole KV cache streams HBM->VMEM once per token.
The kernel tiles the cache sequence axis; each (batch, head) program streams
KV blocks through VMEM carrying the online-softmax state, masking slots
beyond the current fill level ``t``.  ``t`` is the *absolute* fill level of
the ring-buffer cache (models/attention.py writes step t at slot ``t % S``):
while t < S the predicate ``slot <= t`` masks the unwritten suffix, and once
the ring wraps it is all-true — every slot then holds one of the S most
recent tokens, so the same kernel serves both regimes.  All G query heads of a KV group share
the same K/V block fetch (q is laid out (B, KV, G, hd) so the group rides in
one block) — on real hardware this is the G-fold HBM-bandwidth saving that
makes GQA decode fast; the grid never re-reads a KV block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(t_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, block_kv: int, scale: float):
    ikv = pl.program_id(2)
    nkv = pl.num_programs(2)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bkv, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    t = t_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = ikv * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos <= t, s, NEG_INF)            # (G, bkv)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=1)
    acc_new = acc_prev * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(ikv == nkv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def decode_attention_kernel(q, k, v, t, *, block_kv: int = 256,
                            interpret: bool = True):
    """q: (B, KV, G, hd) one query token, grouped; k, v: (B, KV, S, hd);
    t: scalar int32 absolute fill level (slots <= t attend; all slots once
    the ring has wrapped, t >= S).  Returns (B, KV, G, hd).
    """
    B, KV, G, hd = q.shape
    S = k.shape[2]
    block_kv = min(block_kv, S)
    assert S % block_kv == 0, (S, block_kv)
    nkv = S // block_kv
    grid = (B, KV, nkv)
    t_arr = jnp.asarray(t, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, block_kv=block_kv,
                               scale=hd ** -0.5)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ikv: (0,)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ikv: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, ikv: (b, h, ikv, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, ikv: (b, h, ikv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ikv: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(t_arr, q, k, v)
