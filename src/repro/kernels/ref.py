"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, H, S, hd); k, v: (B, KV, S, hd) -> (B, H, S, hd)."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, S, hd).astype(jnp.float32)
    s = jnp.einsum("bkgqh,bksh->bkgqs", qg, k.astype(jnp.float32)) * hd ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksh->bkgqh", w, v.astype(jnp.float32))
    return o.reshape(B, H, S, hd).astype(q.dtype)


def decode_attention_ref(q, k, v, t):
    """q: (B, KV, G, hd); k, v: (B, KV, S, hd); slots <= t attend.
    ``t``: scalar, or (B,) per-sequence fill levels (decode lanes)."""
    B, KV, G, hd = q.shape
    S = k.shape[2]
    s = jnp.einsum("bkgh,bksh->bkgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    t_b = t if jnp.ndim(t) == 0 else t[:, None, None, None]
    mask = jnp.arange(S)[None, None, None, :] <= t_b
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksh->bkgh", w, v.astype(jnp.float32))
    return o.astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, block_table, t):
    """Block-paged decode oracle: gather the logical KV window through
    the per-sequence block table, then plain masked softmax attention.

    q: (B, KV, G, hd); k_pages, v_pages: (n_pages, KV, page, hd) shared
    physical pool; block_table: (B, P) int32 physical page per logical
    block; t: (B,) int32 fill levels (logical slots <= t attend).
    Returns (B, KV, G, hd).
    """
    B = q.shape[0]
    KV, ps, hd = k_pages.shape[1:]
    P = block_table.shape[1]
    k = k_pages[block_table]                       # (B, P, KV, ps, hd)
    k = k.transpose(0, 2, 1, 3, 4).reshape(B, KV, P * ps, hd)
    v = v_pages[block_table]
    v = v.transpose(0, 2, 1, 3, 4).reshape(B, KV, P * ps, hd)
    return decode_attention_ref(q, k, v, t)


def gbdt_margins_ref(X, feature, threshold, value, *, n_classes: int = 3):
    """Vectorised complete-tree traversal.  X: (B,F); tensors (T,N)."""
    import math
    X = X.astype(jnp.float32)
    B = X.shape[0]
    T, N = feature.shape
    max_depth = int(math.log2(N + 1)) - 1
    idx = jnp.zeros((T, B), jnp.int32)
    tr = jnp.arange(T)[:, None]
    for _ in range(max_depth):
        f = feature[tr, idx]                     # (T, B)
        is_leaf = f < 0
        xi = X[jnp.arange(B)[None, :], jnp.maximum(f, 0)]
        go_left = xi < threshold[tr, idx]
        nxt = jnp.where(go_left, 2 * idx + 1, 2 * idx + 2)
        idx = jnp.where(is_leaf, idx, nxt)
    vals = value[tr, idx]                        # (T, B)
    vals = vals.reshape(T // n_classes, n_classes, B)
    return vals.sum(axis=0).T                    # (B, n_classes)


def gbdt_margins_packed_ref(X, feature, threshold, child, value, *,
                            depth: int, n_classes: int = 3):
    """Pruned-layout oracle (see core.ensemble_pack).  Tensors (T, M):
    in-tree left-child indices, leaf self-loops with +inf thresholds."""
    X = X.astype(jnp.float32)
    B = X.shape[0]
    T = feature.shape[0]
    idx = jnp.zeros((T, B), jnp.int32)
    tr = jnp.arange(T)[:, None]
    for _ in range(depth):
        f = feature[tr, idx]                     # (T, B)
        xi = X[jnp.arange(B)[None, :], f]
        go_right = jnp.logical_not(xi < threshold[tr, idx])
        idx = child[tr, idx] + go_right.astype(jnp.int32)
    vals = value[tr, idx]                        # (T, B)
    vals = vals.reshape(T // n_classes, n_classes, B)
    return vals.sum(axis=0).T                    # (B, n_classes)
