"""OpenAI-compatible request/response dataclasses (the sidecar's wire shapes).

The paper's proxy intercepts /v1/chat/completions-style requests; here the
transport is in-process (the framework serves from the same binary), but the
schema is preserved so an HTTP front-end is a thin adapter.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import List, Optional

_ids = itertools.count(1)


@dataclass
class CompletionRequest:
    prompt: str
    max_tokens: int = 1024
    model: str = "default"
    tenant: str = "default"
    stream: bool = False
    request_id: int = field(default_factory=lambda: next(_ids))
    created: float = field(default_factory=time.monotonic)


#: Terminal states a response can report.  Every submitted request ends
#: in exactly one of these (the server's no-lost-requests invariant):
#: ``ok`` served to completion; ``shed`` dropped by admission control
#: (queue overflow) or a deadline budget before service; ``failed`` the
#: backend faulted and the bounded retries were exhausted; ``timeout``
#: the deadline expired while in service; ``cancelled`` client
#: disconnect (queued or mid-generation).
STATUSES = ("ok", "shed", "failed", "timeout", "cancelled")


@dataclass
class CompletionResponse:
    request_id: int
    text: str
    tokens_generated: int
    queue_wait_s: float
    service_s: float
    ttft_s: Optional[float] = None      # time to first token
    promoted: bool = False              # starvation-guard promotion
    replica: int = 0
    p_long: float = 0.0
    klass: str = ""                     # ground-truth class, if known
    status: str = "ok"                  # terminal state (see STATUSES)
    error: Optional[str] = None         # human-readable failure reason
    retries: int = 0                    # fault retries before terminating
    degraded: bool = False              # admitted under predictor outage

    def __post_init__(self):
        if self.status not in STATUSES:
            raise ValueError(f"unknown status {self.status!r}")

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    @property
    def sojourn_s(self) -> float:
        return self.queue_wait_s + self.service_s
