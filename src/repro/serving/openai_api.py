"""OpenAI-compatible request/response dataclasses (the sidecar's wire shapes).

The paper's proxy intercepts /v1/chat/completions-style requests; here the
transport is in-process (the framework serves from the same binary), but the
schema is preserved so an HTTP front-end is a thin adapter.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import List, Optional

_ids = itertools.count(1)


@dataclass
class CompletionRequest:
    prompt: str
    max_tokens: int = 1024
    model: str = "default"
    tenant: str = "default"
    stream: bool = False
    request_id: int = field(default_factory=lambda: next(_ids))
    created: float = field(default_factory=time.monotonic)


@dataclass
class CompletionResponse:
    request_id: int
    text: str
    tokens_generated: int
    queue_wait_s: float
    service_s: float
    ttft_s: Optional[float] = None      # time to first token
    promoted: bool = False              # starvation-guard promotion
    replica: int = 0
    p_long: float = 0.0
    klass: str = ""                     # ground-truth class, if known

    @property
    def sojourn_s(self) -> float:
        return self.queue_wait_s + self.service_s
