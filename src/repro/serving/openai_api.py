"""OpenAI-compatible request/response shapes (the sidecar's wire schema).

The paper's proxy intercepts /v1/chat/completions-style requests.  Two
transports share these dataclasses: the in-process path (the framework
serves from the same binary — examples, benchmarks, the batch drains)
and the real asyncio HTTP/SSE sidecar (``serving/http_sidecar.py``),
which serializes them with the helpers at the bottom of this module.

Request ids are **per-server**: ``CompletionRequest.request_id``
defaults to ``None`` and is assigned by ``ClairvoyantServer`` at
admission from a server-local counter.  (It used to draw from a
process-global ``itertools.count``, which meant two servers in one
process shared an id space — ids depended on construction history, and
an id recycled across servers could cross-poison the duplicate-terminal
guard in ``_finish``.  Per-server allocation makes every server's id
stream dense and deterministic; explicit ids are still honored, with a
duplicate-submission check at admission.)
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class CompletionRequest:
    prompt: str
    max_tokens: int = 1024
    model: str = "default"
    tenant: str = "default"
    stream: bool = False
    #: assigned by the server at admission when None (per-server counter)
    request_id: Optional[int] = None
    created: float = field(default_factory=time.monotonic)


#: Terminal states a response can report.  Every submitted request ends
#: in exactly one of these (the server's no-lost-requests invariant):
#: ``ok`` served to completion; ``shed`` dropped by admission control
#: (queue overflow) or a deadline budget before service; ``failed`` the
#: backend faulted and the bounded retries were exhausted; ``timeout``
#: the deadline expired while in service; ``cancelled`` client
#: disconnect (queued or mid-generation).
STATUSES = ("ok", "shed", "failed", "timeout", "cancelled")

#: Wire mapping for the five terminal statuses (the sidecar's response
#: codes).  ``cancelled`` uses 499 (client-closed-request, the de-facto
#: convention) — usually unsendable because the client is gone, but it
#: keeps logs and the non-disconnect cancel path (server shutdown)
#: well-defined.
HTTP_STATUS = {
    "ok": 200,
    "shed": 429,        # admission overflow / rate limit / deadline shed
    "failed": 502,      # backend fault, retries exhausted
    "timeout": 504,     # deadline expired in service
    "cancelled": 499,   # client closed request
}


@dataclass
class CompletionResponse:
    request_id: int
    text: str
    tokens_generated: int
    queue_wait_s: float
    service_s: float
    ttft_s: Optional[float] = None      # time to first token
    promoted: bool = False              # starvation-guard promotion
    replica: int = 0
    p_long: float = 0.0
    klass: str = ""                     # ground-truth class, if known
    status: str = "ok"                  # terminal state (see STATUSES)
    error: Optional[str] = None         # human-readable failure reason
    retries: int = 0                    # fault retries before terminating
    degraded: bool = False              # admitted under predictor outage
    accept_rate: Optional[float] = None  # draft acceptance (speculative only)

    def __post_init__(self):
        if self.status not in STATUSES:
            raise ValueError(f"unknown status {self.status!r}")

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    @property
    def sojourn_s(self) -> float:
        return self.queue_wait_s + self.service_s


# --------------------------------------------------------------------------
# Wire serialization (OpenAI chat-completion shapes + clairvoyant extras)
# --------------------------------------------------------------------------

def chat_completion_body(resp: CompletionResponse, model: str,
                         created: Optional[float] = None,
                         extra: Optional[dict] = None) -> dict:
    """Non-streaming /v1/chat/completions response body.

    ``extra`` merges additional keys into the ``clairvoyant`` block —
    the sidecar uses it to surface the online ranking-fidelity snapshot
    alongside the per-request scheduling facts."""
    finish = "stop" if resp.status == "ok" else resp.status
    body = {
        "id": f"chatcmpl-{resp.request_id}",
        "object": "chat.completion",
        "created": int(created if created is not None else time.time()),
        "model": model,
        "choices": [{
            "index": 0,
            "message": {"role": "assistant", "content": resp.text},
            "finish_reason": finish,
        }],
        "usage": {"completion_tokens": resp.tokens_generated},
        "clairvoyant": {
            "status": resp.status,
            "queue_wait_s": resp.queue_wait_s,
            "service_s": resp.service_s,
            "ttft_s": resp.ttft_s,
            "p_long": resp.p_long,
            "replica": resp.replica,
            "retries": resp.retries,
            "promoted": resp.promoted,
            "degraded": resp.degraded,
            "accept_rate": resp.accept_rate,
        },
    }
    if resp.error:
        body["clairvoyant"]["error"] = resp.error
    if extra:
        body["clairvoyant"].update(extra)
    return body


def chat_chunk_body(request_id: int, model: str, delta: str,
                    finish_reason: Optional[str] = None) -> dict:
    """One streaming chat.completion.chunk (SSE ``data:`` payload)."""
    d: dict = {"content": delta} if delta else {}
    return {
        "id": f"chatcmpl-{request_id}",
        "object": "chat.completion.chunk",
        "model": model,
        "choices": [{"index": 0, "delta": d,
                     "finish_reason": finish_reason}],
    }


def error_body(status: str, message: str,
               request_id: Optional[int] = None) -> dict:
    """Terminal error payload (both the JSON body of non-200 responses
    and the final SSE frame of a stream that ended non-ok)."""
    return {"error": {"type": status, "message": message,
                      "request_id": request_id}}
