"""KV-budgeted continuous micro-batching: bounded-concurrency decode lanes.

The paper treats serial dispatch as forced by memory — cloud-style
continuous batching needs tens of GB of concurrent KV cache, so an edge
backend runs one request at a time and leans entirely on admission
ordering.  Between those extremes sits the regime this module models: a
small number of concurrent decode **lanes** (c = 2-8) admitted under an
explicit KV-memory budget, the setting where ranking-aware admission and
batching compose (SJF-by-rank *inside* continuous batching).

Two pieces:

* :class:`KVBudget` — a bytes accountant.  The worst-case KV footprint of
  a request is ``tokens x bytes_per_token(cfg)`` where ``tokens`` is the
  ring-buffer capacity the request can actually fill
  (``min(max_len, prompt_len + max_new)``) and ``bytes_per_token`` is the
  per-position cache cost across the whole stack (attention: K+V x
  layers x kv_heads x head_dim x dtype; recurrent blocks contribute 0 —
  their state is O(1) in sequence length and accounted as a fixed
  per-lane term).  Admission *reserves* the worst case up front, exactly
  like vLLM-style block allocators reserve capacity before scheduling a
  sequence; retirement releases it.
* :class:`LaneManager` — lane occupancy + admission.  The policy-ordered
  queue head is admitted into a free lane only when its worst-case
  footprint fits the remaining budget; a head that does not fit **blocks
  admission** (strict policy order — no smaller request may bypass it,
  which would re-introduce the unpredictable reordering the paper's
  admission layer exists to remove).  Per-lane state tracks the request,
  its prompt length, tokens produced, tenant, and eviction count; retired
  lanes release their reservation and are back-filled by the engine via a
  fresh prefill into the vacant cache slot.

The real-decode side lives in ``serving.generate.LaneDecoder`` (the
stacked-cache segment loop) and ``serving.engine.BatchedRealEngine`` (the
admission/retire/back-fill orchestration); the simulation mirror is
``core.sim_fast.simulate_batch_servers`` (c-server DES with a
memory-token constraint and a calibrated per-lane slowdown s(c)).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from repro.configs.base import ATTN, ATTN_MOE

_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2}


def kv_bytes_per_token(cfg) -> int:
    """Worst-case KV-cache bytes one sequence position costs across the
    stack: K+V entries for every attention layer.  Recurrent blocks
    (SSM/xLSTM) hold O(1) state per lane and contribute nothing per
    token; their fixed cost rides in the per-lane base term."""
    dt = _DTYPE_BYTES.get(cfg.dtype, 4)
    n_attn = sum(k in (ATTN, ATTN_MOE) for k in cfg.block_pattern)
    return 2 * n_attn * cfg.pattern_repeats * cfg.num_kv_heads \
        * cfg.head_dim * dt


class KVBudget:
    """Byte accountant for concurrent KV caches.

    ``total_bytes`` is the box's KV-memory budget; :meth:`reserve` admits
    a worst-case footprint, :meth:`release` returns it.  ``peak_bytes``
    records the high-water mark for reporting.
    """

    def __init__(self, total_bytes: int):
        if total_bytes <= 0:
            raise ValueError(f"budget must be positive, got {total_bytes}")
        self.total_bytes = int(total_bytes)
        self.used_bytes = 0
        self.peak_bytes = 0

    @classmethod
    def from_config(cls, cfg, capacity: int, n_lanes: int) -> "KVBudget":
        """The budget that exactly fits ``n_lanes`` full ring buffers of
        ``capacity`` slots — the default when the caller gives a lane
        count instead of a byte budget."""
        return cls(max(1, n_lanes * capacity * kv_bytes_per_token(cfg)))

    @property
    def available_bytes(self) -> int:
        return self.total_bytes - self.used_bytes

    def fits(self, nbytes: int) -> bool:
        return nbytes <= self.available_bytes

    def reserve(self, nbytes: int) -> None:
        if not self.fits(nbytes):
            raise ValueError(
                f"KV budget exceeded: want {nbytes}, "
                f"available {self.available_bytes} of {self.total_bytes}")
        self.used_bytes += nbytes
        self.peak_bytes = max(self.peak_bytes, self.used_bytes)

    def release(self, nbytes: int) -> None:
        self.used_bytes = max(0, self.used_bytes - int(nbytes))


@dataclass
class LaneState:
    """One decode lane's live request."""

    lane: int
    req_id: int = -1
    prompt_len: int = 0
    max_new: int = 0
    produced: int = 0              # tokens emitted incl. the prefill token
    tenant: str = "default"
    footprint_bytes: int = 0       # budget reservation held by this lane
    evictions: int = 0             # times this lane's request was evicted
    admit_t: float = 0.0           # wall/virtual admission time
    ttft_s: float = 0.0
    tokens: List[int] = field(default_factory=list)
    meta: dict = field(default_factory=dict)
    # block-paged mode (serving/paging.py): physical pages owned by this
    # lane, and how many leading prompt tokens were satisfied from the
    # prefix cache (0 under worst-case ring accounting)
    pages: List[int] = field(default_factory=list)
    prefix_len: int = 0
    # speculative decoding (serving/generate.py): draft positions proposed
    # for this lane and how many of them the target verified and accepted
    drafted: int = 0
    accepted: int = 0

    @property
    def accept_rate(self) -> Optional[float]:
        """Observed draft acceptance rate, None before any draft ran."""
        return self.accepted / self.drafted if self.drafted else None


class LaneManager:
    """Occupancy + memory-aware admission over ``n_lanes`` decode lanes.

    The manager owns *bookkeeping only* — which lane holds which request
    and how many bytes each reservation pinned; the engine owns the
    caches and the segment loop.  That split keeps the admission rule
    testable without a model.
    """

    def __init__(self, n_lanes: int, budget: KVBudget,
                 bytes_per_token: int, capacity: int):
        if n_lanes < 1:
            raise ValueError(f"need >= 1 lane, got {n_lanes}")
        self.n_lanes = n_lanes
        self.budget = budget
        self.bytes_per_token = int(bytes_per_token)
        self.capacity = int(capacity)
        self.lanes: List[Optional[LaneState]] = [None] * n_lanes
        self.stats = {"admitted": 0, "retired": 0, "backfills": 0,
                      "evictions": 0, "blocked_on_budget": 0}

    # ------------------------------------------------------------- occupancy
    def free_lanes(self) -> List[int]:
        return [i for i, s in enumerate(self.lanes) if s is None]

    def busy_lanes(self) -> List[int]:
        return [i for i, s in enumerate(self.lanes) if s is not None]

    def lane_of(self, req_id: int) -> Optional[int]:
        for i, s in enumerate(self.lanes):
            if s is not None and s.req_id == req_id:
                return i
        return None

    # -------------------------------------------------------------- admission
    def footprint(self, prompt_len: int, max_new: int) -> int:
        """Worst-case KV bytes: the ring slots this request can fill."""
        tokens = min(self.capacity, int(prompt_len) + int(max_new))
        return tokens * self.bytes_per_token

    def can_admit(self, prompt_len: int, max_new: int) -> bool:
        """Fits the remaining budget?  The degenerate case — an idle
        manager whose head exceeds even the EMPTY budget — admits anyway:
        the request must run eventually and a serial backend would have
        run it, so memory pressure may serialize but never deadlock."""
        need = self.footprint(prompt_len, max_new)
        if self.budget.fits(need):
            return True
        return self.budget.used_bytes == 0

    def admit(self, lane: int, *, req_id: int, prompt_len: int,
              max_new: int, tenant: str = "default", admit_t: float = 0.0,
              meta: Optional[dict] = None, backfill: bool = False
              ) -> LaneState:
        if self.lanes[lane] is not None:
            raise ValueError(f"lane {lane} is occupied")
        need = self.footprint(prompt_len, max_new)
        if not self.budget.fits(need):
            if self.budget.used_bytes:
                raise ValueError(
                    f"admit over budget: want {need}, "
                    f"available {self.budget.available_bytes}")
            need = self.budget.available_bytes   # oversized head, idle box
        self.budget.reserve(need)
        st = LaneState(lane=lane, req_id=req_id, prompt_len=int(prompt_len),
                       max_new=int(max_new), tenant=tenant,
                       footprint_bytes=need, admit_t=admit_t,
                       meta=dict(meta or {}))
        self.lanes[lane] = st
        self.stats["admitted"] += 1
        if backfill:
            self.stats["backfills"] += 1
        return st

    def retire(self, lane: int) -> LaneState:
        st = self.lanes[lane]
        if st is None:
            raise ValueError(f"lane {lane} is already free")
        self.lanes[lane] = None
        self.budget.release(st.footprint_bytes)
        self.stats["retired"] += 1
        return st

    def evict(self, lane: int) -> LaneState:
        """Take a running request off its lane mid-flight (disconnect or
        preemption at a segment boundary).  The returned state carries
        the generated prefix so the caller can resume it later by
        re-prefilling prompt + prefix (the PR-4 resume machinery)."""
        st = self.retire(lane)
        st.evictions += 1
        self.stats["evictions"] += 1
        self.stats["retired"] -= 1       # an eviction is not a completion
        return st
