"""Block-paged KV cache: refcounted pages, block tables, prefix reuse.

PR 5's :class:`~repro.serving.batching.KVBudget` charges every lane its
*worst-case* ring footprint — ``min(max_len, prompt + max_new)`` slots —
at admission.  When memory binds, lanes sit empty over phantom bytes:
a request that will generate 160 tokens blocks three others the moment it
is admitted, even while it holds one page of prompt KV.  This module
replaces that accounting with vLLM-style block paging:

* the KV pool is carved into fixed ``page_size``-token **pages** shared
  by all lanes; each lane owns a **block table** mapping logical slots
  ``t // page_size`` to physical pages;
* admission charges only the pages the prefill will fill
  (*charge-as-blocks-fill*); decode allocates one page at a time as the
  sequence crosses page boundaries, and exhaustion preempts the
  youngest-admitted lane (its pages are freed, the request re-enters the
  engine's pending list and later resumes by re-prefilling prompt +
  generated prefix — the PR-4 resume rule, so tokens stay bitwise-equal
  to an uninterrupted run);
* full pages whose KV was computed by prefill are **content-addressed**
  by a chained hash of their token ids; a page whose refcount drops to
  zero parks in an LRU *reclaimable* set instead of being scrubbed, so a
  later request with the same prompt prefix (shared system prompt,
  multi-turn history) re-acquires the pages and prefills only its
  suffix.

Physical page 0 is reserved as the **trash page**: unallocated block-
table slots point at it, and the decode path routes the dead writes of
stopped lanes (which keep stepping until the segment ends) there, so a
masked lane can never clobber a shared page.

The device side lives in ``models/attention.py`` (block-table decode
branch), ``serving/generate.py`` (:class:`PagedLaneDecoder`) and
``kernels/decode_attention.py`` (the Pallas paged kernel); the engine
integration is ``serving.engine.PagedBatchedEngine`` and the DES mirror
is ``core.sim_fast.simulate_grid_paged``.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.batching import KVBudget, LaneManager, LaneState

TRASH_PAGE = 0


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` cache slots."""
    if n_tokens <= 0:
        return 0
    return -(-int(n_tokens) // int(page_size))


def chain_hashes(token_ids: Sequence[int], page_size: int) -> List[bytes]:
    """Content hash per *full* page, chained so a page's hash commits to
    every token before it (causal KV: the values inside page ``i`` depend
    on all tokens ``< (i+1) * page_size``, so equal chained hashes imply
    bitwise-equal page contents under greedy prefill)."""
    out: List[bytes] = []
    prev = b""
    n_full = len(token_ids) // page_size
    for i in range(n_full):
        chunk = token_ids[i * page_size:(i + 1) * page_size]
        h = hashlib.blake2b(digest_size=16)
        h.update(prev)
        h.update(b",".join(str(int(t)).encode() for t in chunk))
        prev = h.digest()
        out.append(prev)
    return out


class PageError(RuntimeError):
    """Raised on allocation from an exhausted pool (engine bug: callers
    must check :meth:`BlockAllocator.can_allocate` / preempt first)."""


class BlockAllocator:
    """Refcounted fixed-size page pool with an LRU prefix cache.

    Every usable page is in exactly one of three states:

    * **free** — never registered (or content invalidated); in ``_free``;
    * **cached** — refcount 0 but content-addressed (hash registered);
      parked in the ``_lru`` OrderedDict, reclaimable in LRU order;
    * **held** — refcount >= 1, owned by one or more live sequences.

    ``n_pages`` counts usable pages; the trash page (physical id 0) is
    extra and permanently pinned, so physical ids run ``0..n_pages``.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 1:
            raise ValueError(f"need >= 1 usable page, got {n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        # physical ids: 0 = trash (pinned), 1..n_pages usable
        self.refcount = [1] + [0] * self.n_pages
        self._free: deque = deque(range(1, self.n_pages + 1))
        self._lru: "OrderedDict[int, bytes]" = OrderedDict()  # page -> hash
        self._page_hash: Dict[int, bytes] = {}                # held+cached
        self._table: Dict[bytes, int] = {}                    # hash -> page
        self.stats = {"allocated": 0, "freed": 0, "prefix_queries": 0,
                      "prefix_hits": 0, "prefix_hit_pages": 0,
                      "cache_evictions": 0, "registered": 0, "peak_used": 0}

    # ------------------------------------------------------------- accounting
    @property
    def used_pages(self) -> int:
        """Pages held by live sequences (refcount >= 1, trash excluded)."""
        return self.n_pages - len(self._free) - len(self._lru)

    @property
    def reclaimable_pages(self) -> int:
        return len(self._free) + len(self._lru)

    def page_states(self) -> dict:
        """Pool occupancy by state: ``free`` (never/no-longer mapped),
        ``cached`` (LRU-parked prefix pages, reclaimable), ``held``
        (referenced by live sequences).  free+cached+held == total."""
        return {"total": self.n_pages, "free": len(self._free),
                "cached": len(self._lru), "held": self.used_pages}

    def can_allocate(self, n: int) -> bool:
        return n <= self.reclaimable_pages

    # ------------------------------------------------------------- allocation
    def _pop_page(self) -> int:
        if self._free:
            return self._free.popleft()
        # reclaim the least-recently-parked cached page; its content is
        # gone from the index, so future prefixes can no longer hit it
        page, h = self._lru.popitem(last=False)
        del self._table[h]
        del self._page_hash[page]
        self.stats["cache_evictions"] += 1
        return page

    def allocate(self, n: int) -> List[int]:
        """All-or-nothing grab of ``n`` fresh pages (refcount 1 each)."""
        if not self.can_allocate(n):
            raise PageError(f"out of pages: want {n}, "
                            f"reclaimable {self.reclaimable_pages}")
        pages = []
        for _ in range(n):
            p = self._pop_page()
            self.refcount[p] = 1
            pages.append(p)
        self.stats["allocated"] += n
        self.stats["peak_used"] = max(self.stats["peak_used"],
                                      self.used_pages)
        return pages

    def acquire(self, page: int) -> None:
        """Take one more reference on an existing page (prefix share);
        revives a cached (refcount-0) page out of the LRU."""
        if page == TRASH_PAGE:
            raise ValueError("cannot acquire the trash page")
        if self.refcount[page] == 0:
            if page not in self._lru:
                raise ValueError(f"page {page} is free, not cached")
            del self._lru[page]
        self.refcount[page] += 1
        self.stats["peak_used"] = max(self.stats["peak_used"],
                                      self.used_pages)

    def release(self, page: int) -> None:
        """Drop one reference; at zero the page parks in the LRU if its
        content is registered, else returns to the free list."""
        if page == TRASH_PAGE:
            raise ValueError("cannot release the trash page")
        if self.refcount[page] <= 0:
            raise ValueError(f"double free of page {page}")
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            h = self._page_hash.get(page)
            if h is not None:
                self._lru[page] = h
            else:
                self._free.append(page)
            self.stats["freed"] += 1

    def release_seq(self, pages: Sequence[int]) -> None:
        for p in pages:
            self.release(p)

    # ------------------------------------------------------------ prefix reuse
    def probe_prefix(self, hashes: Sequence[bytes]) -> int:
        """Longest registered prefix (in pages) — no refcount changes."""
        n = 0
        for h in hashes:
            if h not in self._table:
                break
            n += 1
        return n

    def match_prefix(self, token_ids: Sequence[int],
                     acquire: bool = True) -> Tuple[int, List[int]]:
        """Longest usable cached prefix of ``token_ids``.

        Returns ``(n_tokens, pages)``.  Only *full* pages match, and the
        hit is capped one token short of the prompt so a resumed prefill
        always has >= 1 suffix token to produce last-position logits.
        With ``acquire`` the pages are referenced (caller owns them).
        """
        self.stats["prefix_queries"] += 1
        cap = (len(token_ids) - 1) // self.page_size
        if cap <= 0:
            return 0, []
        hashes = chain_hashes(token_ids, self.page_size)[:cap]
        n = self.probe_prefix(hashes)
        if n == 0:
            return 0, []
        pages = [self._table[h] for h in hashes[:n]]
        if acquire:
            for p in pages:
                self.acquire(p)
        self.stats["prefix_hits"] += 1
        self.stats["prefix_hit_pages"] += n
        return n * self.page_size, pages

    def register(self, pages: Sequence[int], hashes: Sequence[bytes]) -> None:
        """Content-address held pages (after prefill computed their KV).
        A hash already registered to a *different* page keeps the first
        mapping (dedup of the index, not of storage)."""
        for p, h in zip(pages, hashes):
            if h in self._table:
                continue
            old = self._page_hash.get(p)
            if old is not None:
                # page re-used for new content under the same owner
                self._table.pop(old, None)
            self._table[h] = p
            self._page_hash[p] = h
            self.stats["registered"] += 1

    def invalidate(self, page: int) -> None:
        """Drop a held page's content address (its bytes are about to be
        overwritten with unrelated KV)."""
        h = self._page_hash.pop(page, None)
        if h is not None and self._table.get(h) == page:
            del self._table[h]

    def reset_transient(self) -> None:
        """Release every live reference (crash recovery between runs):
        registered pages park in the LRU — the prefix cache survives —
        and anonymous pages return to the free list."""
        for p in range(1, self.n_pages + 1):
            while self.refcount[p] > 0:
                self.release(p)

    def drop_cache(self) -> None:
        """Forget every cached (LRU-parked) prefix page.  The engine
        calls this whenever it rebuilds the device pools from scratch —
        the pages' contents no longer exist, so advertising their hashes
        would serve zeros to the next prefix hit."""
        while self._lru:
            p, _ = self._lru.popitem(last=False)
            self.invalidate(p)
            self._free.append(p)

    # --------------------------------------------------------------- checking
    def check(self) -> None:
        """Invariants (test hook): refcounts never negative, conservation
        (free + cached + held == n_pages), index consistency."""
        assert self.refcount[TRASH_PAGE] >= 1, "trash page unpinned"
        held = 0
        for p in range(1, self.n_pages + 1):
            rc = self.refcount[p]
            assert rc >= 0, f"negative refcount on page {p}: {rc}"
            held += rc > 0
        free, cached = len(self._free), len(self._lru)
        assert free + cached + held == self.n_pages, \
            (free, cached, held, self.n_pages)
        assert not (set(self._free) & set(self._lru)), "page in two states"
        for p in self._lru:
            assert self.refcount[p] == 0, f"cached page {p} is held"
        for h, p in self._table.items():
            assert self._page_hash.get(p) == h, f"index skew on page {p}"


class PagedLaneManager(LaneManager):
    """Lane occupancy with charge-as-blocks-fill admission.

    Same interface/stats as :class:`~repro.serving.batching.LaneManager`
    (the engine drives both through one code path) but memory accounting
    runs in pages through a shared :class:`BlockAllocator`:

    * :meth:`can_admit` asks whether the *prompt's* non-shared pages fit
      — not the worst case; decode growth is paid later, page by page
      (:meth:`grow`), with preemption on exhaustion;
    * admission takes references on cached prefix pages (prefix reuse)
      and allocates only the suffix;
    * retire/evict release the lane's pages — content-addressed ones
      park in the allocator's LRU and seed future prefix hits.

    The byte-denominated ``budget`` is kept in sync with the allocator
    (``used = used_pages * page_bytes``) so budget-style reporting
    (``peak_bytes``) stays comparable with the worst-case manager.
    """

    def __init__(self, n_lanes: int, allocator: BlockAllocator,
                 bytes_per_token: int, capacity: int,
                 overhead_pages: int = 0):
        page_bytes = allocator.page_size * max(1, int(bytes_per_token))
        budget = KVBudget(max(1, allocator.n_pages * page_bytes))
        super().__init__(n_lanes, budget, bytes_per_token, capacity)
        need_solo = pages_for(capacity, allocator.page_size) \
            + int(overhead_pages)
        if allocator.n_pages < need_solo:
            raise ValueError(
                f"pool of {allocator.n_pages} pages cannot hold one "
                f"full sequence of {capacity} tokens at page_size "
                f"{allocator.page_size} plus {overhead_pages} overhead "
                f"pages (need {need_solo})")
        self.allocator = allocator
        self.page_size = allocator.page_size
        self._page_bytes = page_bytes
        self._admit_seq = 0
        # fixed per-lane ANONYMOUS page charge (speculative decoding: the
        # draft model's ring KV is real memory the pool must account for,
        # even though it is never content-addressed or block-mapped)
        self.overhead_pages = int(overhead_pages)
        self._overhead: dict = {}            # lane -> anonymous pages
        self.stats["preemptions"] = 0

    # -------------------------------------------------------------- plumbing
    def _sync_budget(self) -> None:
        used = self.allocator.used_pages * self._page_bytes
        self.budget.used_bytes = used
        self.budget.peak_bytes = max(self.budget.peak_bytes, used)

    def footprint(self, prompt_len: int, max_new: int) -> int:
        """Bytes charged AT ADMISSION: the prompt's pages only."""
        tokens = min(self.capacity, int(prompt_len))
        return pages_for(tokens, self.page_size) * self._page_bytes

    # -------------------------------------------------------------- admission
    def can_admit(self, prompt_len: int, max_new: int,
                  ids: Optional[Sequence[int]] = None) -> bool:
        """Do the prompt's *non-shared* pages fit right now?  Cached
        prefix pages cost nothing extra (acquiring them removes them
        from the reclaimable set but they already hold the right KV).
        An idle manager admits unconditionally — the constructor
        guarantees the pool holds one full sequence."""
        if not self.busy_lanes():
            return True
        want = pages_for(min(self.capacity, int(prompt_len)), self.page_size)
        hit_pages = 0
        if ids is not None and len(ids):
            cap = (len(ids) - 1) // self.page_size
            if cap > 0:
                hashes = chain_hashes(ids, self.page_size)[:cap]
                hit_pages = self.allocator.probe_prefix(hashes)
        return self.allocator.can_allocate(
            max(0, want - hit_pages) + self.overhead_pages)

    def admit(self, lane: int, *, req_id: int, prompt_len: int,
              max_new: int, tenant: str = "default", admit_t: float = 0.0,
              meta: Optional[dict] = None, backfill: bool = False,
              ids: Optional[Sequence[int]] = None) -> LaneState:
        """Admit with prefix matching: reference the cached prefix pages,
        allocate pages for the rest of the prompt.  ``ids`` is the full
        prefill input (prompt + any resume prefix)."""
        if self.lanes[lane] is not None:
            raise ValueError(f"lane {lane} is occupied")
        n_tok = min(self.capacity, int(prompt_len))
        hit_tokens, pages = (0, [])
        if ids is not None and len(ids):
            hit_tokens, pages = self.allocator.match_prefix(ids)
        try:
            fresh = self.allocator.allocate(
                pages_for(n_tok, self.page_size) - len(pages))
        except PageError:
            self.allocator.release_seq(pages)
            raise
        try:
            self._overhead[lane] = self.allocator.allocate(
                self.overhead_pages)
        except PageError:
            self.allocator.release_seq(pages + fresh)
            raise
        pages = pages + fresh
        self._sync_budget()
        st = LaneState(lane=lane, req_id=req_id, prompt_len=int(prompt_len),
                       max_new=int(max_new), tenant=tenant,
                       footprint_bytes=(len(pages) + self.overhead_pages)
                       * self._page_bytes,
                       admit_t=admit_t, meta=dict(meta or {}))
        st.pages = pages
        st.prefix_len = hit_tokens
        self._admit_seq += 1
        st.meta["_admit_seq"] = self._admit_seq
        self.lanes[lane] = st
        self.stats["admitted"] += 1
        if backfill:
            self.stats["backfills"] += 1
        return st

    # ----------------------------------------------------------------- growth
    def grow(self, lane: int, need_pages: int) -> bool:
        """Extend a lane's block table to ``need_pages`` pages; False on
        exhaustion (caller preempts and retries)."""
        st = self.lanes[lane]
        extra = int(need_pages) - len(st.pages)
        if extra <= 0:
            return True
        if not self.allocator.can_allocate(extra):
            return False
        st.pages.extend(self.allocator.allocate(extra))
        st.footprint_bytes = len(st.pages) * self._page_bytes
        self._sync_budget()
        return True

    def youngest_busy(self) -> Optional[int]:
        """Preemption victim: the most recently admitted busy lane."""
        busy = self.busy_lanes()
        if not busy:
            return None
        return max(busy, key=lambda ln: self.lanes[ln].meta["_admit_seq"])

    def register_prompt(self, lane: int, ids: Sequence[int]) -> None:
        """Content-address the lane's full prompt pages (post-prefill)."""
        st = self.lanes[lane]
        hashes = chain_hashes(ids, self.page_size)
        self.allocator.register(st.pages[:len(hashes)], hashes)

    # ---------------------------------------------------------------- release
    def _release_lane(self, lane: int) -> LaneState:
        st = self.lanes[lane]
        if st is None:
            raise ValueError(f"lane {lane} is already free")
        self.lanes[lane] = None
        self.allocator.release_seq(st.pages)
        self.allocator.release_seq(self._overhead.pop(lane, []))
        self._sync_budget()
        return st

    def retire(self, lane: int) -> LaneState:
        st = self._release_lane(lane)
        self.stats["retired"] += 1
        return st

    def evict(self, lane: int) -> LaneState:
        st = self._release_lane(lane)
        st.evictions += 1
        self.stats["evictions"] += 1
        return st

    def preempt(self, lane: int) -> LaneState:
        """Memory preemption (page exhaustion): like :meth:`evict` but
        counted separately — the request is requeued inside the engine,
        not terminated."""
        st = self._release_lane(lane)
        st.evictions += 1
        self.stats["preemptions"] += 1
        return st
